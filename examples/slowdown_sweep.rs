//! Slowdown sweep: `T_loop^par` as a continuous function of the injected
//! chunk-calculation delay (0 → 400 µs) for **all four execution models**
//! side by side — a finer-grained view of the paper's three-scenario design
//! that shows *where* CCA's serialized calculation crosses into saturation,
//! and how the two-level HIER-DCA (arXiv 1903.09510) tracks flat DCA while
//! keeping the coordinator nearly idle.
//!
//! AF has no closed form, so the DCA-RMA column is structurally unsupported
//! (§4) and prints `n/a`.
//!
//! Run: `cargo run --release --example slowdown_sweep`

use dca_dls::config::{ClusterConfig, ExecutionModel};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::workload::mandelbrot::Mandelbrot;
use dca_dls::workload::IterationCost;

fn main() -> anyhow::Result<()> {
    println!("building Mandelbrot cost profile…");
    let cost = IterationCost::record_mandelbrot(&Mandelbrot::paper(2_000));
    let tech = TechniqueKind::Af; // the paper's most delay-sensitive technique

    println!("\n== AF on Mandelbrot, 256 ranks: T_par vs injected calc delay ==\n");
    println!(
        "{:>9} {:>12} {:>12} {:>12} {:>12} {:>9}",
        "delay[µs]", "CCA[s]", "DCA[s]", "DCA-RMA[s]", "HIER-DCA[s]", "CCA/DCA"
    );
    for delay_us in [0.0, 5.0, 10.0, 25.0, 50.0, 100.0, 200.0, 400.0] {
        let mut cells: Vec<Option<f64>> = vec![];
        for model in ExecutionModel::ALL {
            if tech == TechniqueKind::Af && model == ExecutionModel::DcaRma {
                cells.push(None); // unsupported by design (§4)
                continue;
            }
            let cluster = ClusterConfig::minihpc();
            let cfg = DesConfig {
                delay: InjectedDelay::calculation_only(delay_us * 1e-6),
                ..DesConfig::new(
                    LoopParams::new(262_144, cluster.total_ranks()),
                    tech,
                    model,
                    cluster,
                    cost.clone(),
                )
            };
            cells.push(Some(simulate(&cfg)?.t_par()));
        }
        let fmt = |c: &Option<f64>| match c {
            Some(t) => format!("{t:>12.2}"),
            None => format!("{:>12}", "n/a"),
        };
        let ratio = match (cells[0], cells[1]) {
            (Some(cca), Some(dca)) if dca > 0.0 => cca / dca,
            _ => f64::NAN,
        };
        let bar = "#".repeat((ratio * 10.0).min(60.0) as usize);
        println!(
            "{delay_us:>9.0} {} {} {} {} {ratio:>9.2} {bar}",
            fmt(&cells[0]),
            fmt(&cells[1]),
            fmt(&cells[2]),
            fmt(&cells[3]),
        );
    }
    println!("\nThe CCA column saturates once the master's serialized (delay + calc)");
    println!("exceeds the workers' mean chunk-turnaround — DCA never does (§6), and");
    println!("HIER-DCA additionally keeps the global coordinator to O(node-chunks)");
    println!("messages, paying the delay in parallel at both hierarchy levels.");
    Ok(())
}
