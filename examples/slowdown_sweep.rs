//! Slowdown sweep: `T_loop^par` as a continuous function of the injected
//! chunk-calculation delay (0 → 400 µs), CCA vs DCA — a finer-grained view
//! of the paper's three-scenario design that shows *where* CCA's serialized
//! calculation crosses into saturation.
//!
//! Run: `cargo run --release --example slowdown_sweep`

use dca_dls::config::{ClusterConfig, ExecutionModel};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::workload::mandelbrot::Mandelbrot;
use dca_dls::workload::IterationCost;

fn main() -> anyhow::Result<()> {
    println!("building Mandelbrot cost profile…");
    let cost = IterationCost::record_mandelbrot(&Mandelbrot::paper(2_000));
    let tech = TechniqueKind::Af; // the paper's most delay-sensitive technique

    println!("\n== AF on Mandelbrot, 256 ranks: T_par vs injected calc delay ==\n");
    println!("{:>9} {:>12} {:>12} {:>9}", "delay[µs]", "CCA T_par[s]", "DCA T_par[s]", "CCA/DCA");
    for delay_us in [0.0, 5.0, 10.0, 25.0, 50.0, 100.0, 200.0, 400.0] {
        let mut t = vec![];
        for model in [ExecutionModel::Cca, ExecutionModel::Dca] {
            let cluster = ClusterConfig::minihpc();
            let cfg = DesConfig {
                params: LoopParams::new(262_144, cluster.total_ranks()),
                technique: tech,
                model,
                delay: InjectedDelay::calculation_only(delay_us * 1e-6),
                cluster,
                cost: cost.clone(),
                pe_speed: vec![],
            };
            t.push(simulate(&cfg)?.t_par());
        }
        let ratio = t[0] / t[1];
        let bar = "#".repeat((ratio * 10.0).min(60.0) as usize);
        println!("{delay_us:>9.0} {:>12.2} {:>12.2} {ratio:>9.2} {bar}", t[0], t[1]);
    }
    println!("\nThe CCA column saturates once the master's serialized (delay + calc)");
    println!("exceeds the workers' mean chunk-turnaround — DCA never does (§6).");
    Ok(())
}
