//! PSIA (spin images) both ways:
//!
//! 1. **Paper scale** — 256 simulated ranks over the Table 3-calibrated
//!    iteration-cost model (the Fig. 4 workload);
//! 2. **Host scale** — a real multi-threaded run where chunks execute actual
//!    spin-image computations over the synthetic point cloud.
//!
//! Run: `cargo run --release --example psia_cluster`

use std::sync::Arc;

use dca_dls::config::{ClusterConfig, ExecutionModel};
use dca_dls::coordinator::{self, EngineConfig};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::sched::verify_coverage;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::workload::psia::Psia;
use dca_dls::workload::{IterationCost, Workload};

fn main() -> anyhow::Result<()> {
    // --- 1. paper scale (DES) ---------------------------------------------
    println!("== PSIA, 256 simulated ranks, N=262144, delay 100 µs ==\n");
    println!("{:<8} {:>12} {:>12}", "tech", "CCA T_par[s]", "DCA T_par[s]");
    for tech in [
        TechniqueKind::Static,
        TechniqueKind::Gss,
        TechniqueKind::Fac2,
        TechniqueKind::Tfss,
        TechniqueKind::Af,
    ] {
        let mut t = vec![];
        for model in [ExecutionModel::Cca, ExecutionModel::Dca] {
            let cluster = ClusterConfig::minihpc();
            let cfg = DesConfig {
                delay: InjectedDelay::calculation_only(100e-6),
                ..DesConfig::new(
                    LoopParams::new(262_144, cluster.total_ranks()),
                    tech,
                    model,
                    cluster,
                    IterationCost::psia_table3(0xF16_4),
                )
            };
            t.push(simulate(&cfg)?.t_par());
        }
        println!("{:<8} {:>12.3} {:>12.3}", tech.name(), t[0], t[1]);
    }

    // --- 2. host scale (real threads, real spin images) --------------------
    let workers = std::thread::available_parallelism()
        .map(|c| c.get() as u32)
        .unwrap_or(4)
        .clamp(2, 8);
    let n = 2_048u64;
    println!("\n== real spin-image execution, {workers} worker threads, N={n} ==\n");
    let workload: Arc<dyn Workload> = Arc::new(Psia::synthetic(1_024, n, 0x5e1a));
    let reference = workload.execute_range(0, n);
    for (tech, model) in [
        (TechniqueKind::Fac2, ExecutionModel::Cca),
        (TechniqueKind::Fac2, ExecutionModel::Dca),
        (TechniqueKind::Af, ExecutionModel::Dca),
        (TechniqueKind::Gss, ExecutionModel::DcaRma),
    ] {
        let cfg = EngineConfig::new(LoopParams::new(n, workers), tech, model);
        let t0 = std::time::Instant::now();
        let r = coordinator::run(&cfg, Arc::clone(&workload))?;
        verify_coverage(&r.sorted_assignments(), n)
            .map_err(|e| anyhow::anyhow!("coverage: {e}"))?;
        assert_eq!(r.checksum, reference, "checksum mismatch");
        println!(
            "{:<5} {:<8} wall={:.3}s chunks={:>4} messages={:>5}  checksum OK",
            tech.name(),
            model.name(),
            t0.elapsed().as_secs_f64(),
            r.stats.chunks,
            r.stats.messages
        );
    }
    Ok(())
}
