//! Quickstart: the library in five minutes.
//!
//! 1. Generate a technique's chunk schedule in both forms (Table 2 style).
//! 2. Self-schedule a real loop across threads with CCA and DCA.
//! 3. Drive the LB4MPI-compatible API exactly like Listing 1.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;
use std::thread;

use dca_dls::config::ExecutionModel;
use dca_dls::coordinator::{self, EngineConfig};
use dca_dls::lb4mpi::{
    configure_chunk_calculation_mode, dls_end_chunk, dls_end_loop, dls_parameters_setup,
    dls_start_chunk, dls_start_loop, dls_terminated, CalcMode,
};
use dca_dls::sched::{closed_form_schedule, recursive_schedule, verify_coverage};
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, Technique, TechniqueKind};
use dca_dls::workload::synthetic::{CostShape, Synthetic};
use dca_dls::workload::Workload;

fn main() -> anyhow::Result<()> {
    // --- 1. chunk calculation, both forms --------------------------------
    let params = LoopParams::new(1000, 4);
    let gss = Technique::new(TechniqueKind::Gss, &params);

    let closed = closed_form_schedule(&gss, &params); // DCA / Eq. 14
    let recursive = recursive_schedule(&gss, &params); // CCA / Eq. 4
    println!("GSS closed   : {:?}", closed.iter().map(|a| a.size).collect::<Vec<_>>());
    println!("GSS recursive: {:?}", recursive.iter().map(|a| a.size).collect::<Vec<_>>());
    verify_coverage(&closed, params.n).unwrap();
    verify_coverage(&recursive, params.n).unwrap();

    // --- 2. self-schedule a real loop over threads -----------------------
    let workload: Arc<dyn Workload> =
        Arc::new(Synthetic::new(20_000, 2e-6, CostShape::Jittered, 42));
    for model in [ExecutionModel::Cca, ExecutionModel::Dca, ExecutionModel::DcaRma] {
        let cfg = EngineConfig::new(
            LoopParams::new(20_000, 4),
            TechniqueKind::Fac2,
            model,
        );
        let r = coordinator::run(&cfg, Arc::clone(&workload))?;
        println!(
            "{:<8} T_par={:.4}s chunks={:>3} messages={:>4} checksum={:#018x}",
            model.name(),
            r.stats.t_par,
            r.stats.chunks,
            r.stats.messages,
            r.checksum
        );
    }

    // --- 3. the LB4MPI API (Listing 1) ------------------------------------
    let mut infos = dls_parameters_setup(4, InjectedDelay::none());
    configure_chunk_calculation_mode(&infos[0], CalcMode::Decentralized);
    let params = LoopParams::new(10_000, 4);
    let handles: Vec<_> = infos
        .drain(..)
        .map(|mut info| {
            let params = params.clone();
            thread::spawn(move || {
                dls_start_loop(&mut info, &params, TechniqueKind::Tss);
                while !dls_terminated(&info) {
                    if let Some((start, size)) = dls_start_chunk(&mut info) {
                        // "execute" the chunk
                        std::hint::black_box(start + size);
                        dls_end_chunk(&mut info);
                    }
                }
                dls_end_loop(&mut info)
            })
        })
        .collect();
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap().0).sum();
    println!("LB4MPI API: {total} iterations scheduled across 4 ranks (expected 10000)");
    assert_eq!(total, 10_000);
    Ok(())
}
