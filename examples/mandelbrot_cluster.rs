//! Mandelbrot on the simulated 256-rank miniHPC: the Fig. 5 workload,
//! one DES run per (technique × approach) at a chosen injected delay.
//!
//! Run: `cargo run --release --example mandelbrot_cluster [-- delay_us]`

use dca_dls::config::{ClusterConfig, ExecutionModel};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::workload::mandelbrot::Mandelbrot;
use dca_dls::workload::IterationCost;

fn main() -> anyhow::Result<()> {
    let delay_us: f64 = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(100.0);
    println!("building Mandelbrot cost profile (512², CT scaled to 2000)…");
    let cost = IterationCost::record_mandelbrot(&Mandelbrot::paper(2_000));

    println!(
        "\n== Mandelbrot, 256 ranks, N=262144, injected calc delay {delay_us} µs ==\n"
    );
    println!(
        "{:<8} {:>12} {:>12} {:>9} {:>9}",
        "tech", "CCA T_par[s]", "DCA T_par[s]", "CCA S", "DCA S"
    );
    for tech in TechniqueKind::EVALUATED {
        let mut t = vec![];
        let mut chunks = vec![];
        for model in [ExecutionModel::Cca, ExecutionModel::Dca] {
            let cluster = ClusterConfig::minihpc();
            let cfg = DesConfig {
                delay: InjectedDelay::calculation_only(delay_us * 1e-6),
                ..DesConfig::new(
                    LoopParams::new(262_144, cluster.total_ranks()),
                    tech,
                    model,
                    cluster,
                    cost.clone(),
                )
            };
            let r = simulate(&cfg)?;
            t.push(r.t_par());
            chunks.push(r.stats.chunks);
        }
        println!(
            "{:<8} {:>12.3} {:>12.3} {:>9} {:>9}",
            tech.name(),
            t[0],
            t[1],
            chunks[0],
            chunks[1]
        );
    }
    println!("\n(AF row is the Fig. 5c case: fine chunks make the serialized CCA delay explode)");
    Ok(())
}
