//! **End-to-end driver** — proves all three layers compose on a real
//! workload:
//!
//!   L3 rust coordinator (CCA & DCA self-scheduling over worker threads)
//!     → chunk assignments
//!   L2 JAX model + L1 Pallas kernel, AOT-lowered to `artifacts/*.hlo.txt`
//!     → executed per chunk through PJRT (no Python at run time)
//!
//! Both paper workloads run: the full 512×512 Mandelbrot image (N = 262,144
//! loop iterations, CT per artifacts/meta.json) and a PSIA spin-image batch.
//! Every run is validated three ways: full coverage (each iteration
//! scheduled exactly once), checksum equality against the rust-native
//! implementation, and CCA/DCA agreement.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example e2e_full_stack`

use std::sync::Arc;
use std::time::Instant;

use dca_dls::config::ExecutionModel;
use dca_dls::coordinator::{self, EngineConfig};
use dca_dls::runtime::workload::{PjrtMandelbrot, PjrtPsia};
use dca_dls::runtime::Runtime;
use dca_dls::sched::verify_coverage;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::workload::Workload;

fn main() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    anyhow::ensure!(
        dir.join("meta.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );
    let rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}   artifacts: {}", rt.platform(), dir.display());
    let workers = std::thread::available_parallelism()
        .map(|c| c.get() as u32)
        .unwrap_or(4)
        .clamp(2, 8);

    // ---- Mandelbrot: the full paper image through the Pallas kernel ------
    let mandel = Arc::new(PjrtMandelbrot::new(&dir)?);
    let n = mandel.n(); // 262,144
    println!("\n== Mandelbrot 512²  N={n}  CT={}  {workers} workers ==", rt.meta.mandelbrot.ct);
    let native = rt.meta.mandelbrot_native();
    let t0 = Instant::now();
    let reference: u64 = (0..n).map(|i| native.escape_count(i) as u64).sum();
    println!(
        "native reference: checksum={reference:#x}  ({:.2}s single-thread)",
        t0.elapsed().as_secs_f64()
    );

    // XLA's FMA contraction shifts ~4 boundary pixels out of 262,144 vs the
    // native f64 loop — compare with a tiny relative budget; CCA vs DCA
    // (both through PJRT) must agree EXACTLY.
    let mut pjrt_checksums = vec![];
    for (tech, model) in [
        (TechniqueKind::Fac2, ExecutionModel::Cca),
        (TechniqueKind::Fac2, ExecutionModel::Dca),
        (TechniqueKind::Gss, ExecutionModel::Dca),
    ] {
        let cfg = EngineConfig::new(LoopParams::new(n, workers), tech, model);
        let t0 = Instant::now();
        let r = coordinator::run(&cfg, Arc::clone(&mandel) as Arc<dyn Workload>)?;
        let wall = t0.elapsed().as_secs_f64();
        verify_coverage(&r.sorted_assignments(), n)
            .map_err(|e| anyhow::anyhow!("coverage: {e}"))?;
        let drift = (r.checksum as i64 - reference as i64).unsigned_abs();
        anyhow::ensure!(
            drift < 1024,
            "{tech}/{model:?}: PJRT checksum {:#x} too far from native {reference:#x}",
            r.checksum
        );
        println!(
            "{:<5} {:<4} wall={wall:>7.2}s  chunks={:>4}  msgs={:>5}  coverage OK, native drift {drift} (FMA)",
            tech.name(),
            model.name(),
            r.stats.chunks,
            r.stats.messages
        );
        pjrt_checksums.push(r.checksum);
    }
    anyhow::ensure!(
        pjrt_checksums.windows(2).all(|w| w[0] == w[1]),
        "CCA and DCA must compute identical results"
    );
    println!("CCA ≡ DCA ≡ GSS-DCA: identical PJRT checksums ✓");

    // ---- PSIA: spin images through the Pallas kernel ---------------------
    let n_img = 4_096u64;
    let psia = Arc::new(PjrtPsia::new(&dir, n_img, 0x5e1a_5e1a)?);
    println!(
        "\n== PSIA  N={n_img} spin images  cloud M={}  {workers} workers ==",
        rt.meta.spin_image.m
    );
    for model in [ExecutionModel::Cca, ExecutionModel::Dca] {
        let cfg = EngineConfig::new(LoopParams::new(n_img, workers), TechniqueKind::Fac2, model);
        let t0 = Instant::now();
        let r = coordinator::run(&cfg, Arc::clone(&psia) as Arc<dyn Workload>)?;
        verify_coverage(&r.sorted_assignments(), n_img)
            .map_err(|e| anyhow::anyhow!("coverage: {e}"))?;
        println!(
            "FAC   {:<4} wall={:>7.2}s  chunks={:>4}  msgs={:>5}  checksum={:#x}",
            model.name(),
            t0.elapsed().as_secs_f64(),
            r.stats.chunks,
            r.stats.messages,
            r.checksum
        );
    }

    // CCA and DCA must produce the same answer — they schedule the same loop.
    println!("\ne2e: all layers compose — L3 scheduling × L2 JAX model × L1 Pallas kernel ✓");
    Ok(())
}
