//! Validation of the **recursive N-level** scheduling tree on both
//! substrates: the depth-3 (rack → node → socket) coverage matrix across
//! all 12 evaluated techniques × {0, 100 µs} inter-rack latency on the DES,
//! coverage + checksum for the threaded engine at depth 3, exact
//! threaded ≡ DES serial-schedule equivalence at depth 3, edge geometries
//! (fan-out 1 at any level, N < total ranks, single-socket nodes), and the
//! adaptive-watermark satellite claim (auto is never worse than
//! fetch-on-exhaustion on the PR 2 prefetch scenario).

use std::sync::Arc;

use dca_dls::config::{ClusterConfig, ExecutionModel, HierParams, SchedPath};
use dca_dls::coordinator::{self, EngineConfig, RunResult};
use dca_dls::des::{simulate, DesConfig, DesResult};
use dca_dls::sched::verify_coverage;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{CandidateSet, LoopParams, TechniqueKind};
use dca_dls::workload::synthetic::{CostShape, Synthetic};
use dca_dls::workload::{IterationCost, Workload};

/// Tentpole property at depth 3: for every technique the lock-free CAS
/// fast path and the two-phase ledger emit bit-identical serial schedules
/// and chunk counts. Pinned on the deterministic-equality geometry (see
/// `tests/threaded_hier.rs::equivalence_des_cfg` for the reasoning): a
/// dedicated single-parent chain `[1, 1, 8]` over one uniform-latency
/// node, so two-phase commits stay in reservation order at every level.
#[test]
fn lockfree_matches_two_phase_schedule_depth3() {
    let mk = |kind: TechniqueKind, path: SchedPath| {
        let cluster = ClusterConfig {
            nodes: 1,
            ranks_per_node: 8,
            break_after: 0,
            ..ClusterConfig::minihpc()
        };
        let mut cfg = DesConfig::new(
            LoopParams::new(4_096, cluster.total_ranks()),
            kind,
            ExecutionModel::HierDca,
            cluster,
            IterationCost::Constant(1e-5),
        );
        cfg.hier = HierParams::default().with_levels(3).with_fanouts(&[1, 1, 8]);
        cfg.sched_path = path;
        simulate(&cfg).unwrap_or_else(|e| panic!("{kind} {path}: {e}"))
    };
    for kind in TechniqueKind::ALL {
        let two = mk(kind, SchedPath::TwoPhase);
        let fast = mk(kind, SchedPath::LockFree);
        verify_coverage(&fast.sorted_assignments(), 4_096)
            .unwrap_or_else(|e| panic!("{kind}: {e}"));
        assert_eq!(
            two.sorted_assignments(),
            fast.sorted_assignments(),
            "{kind} depth 3: serial schedules must be bit-identical across grant paths"
        );
        assert_eq!(two.stats.chunks, fast.stats.chunks, "{kind}: chunk counts");
        assert!(
            fast.t_par() <= two.t_par(),
            "{kind} depth 3: lockfree t_par {} must not exceed two-phase {}",
            fast.t_par(),
            two.t_par()
        );
        assert_eq!(fast.fast_grants > 0, kind.supports_fast_path(), "{kind}: CAS eligibility");
    }
}

/// ISSUE 5 regression property at depth 3: single-candidate adaptivity
/// (the controller probes every grant but can never switch) emits serial
/// schedules and t_par bit-identical to the static run, on the two-phase,
/// lock-free, and auto grant paths alike.
#[test]
fn single_candidate_adaptive_is_bit_identical_depth3() {
    let mk = |kind: TechniqueKind, path: SchedPath, adaptive: bool| {
        let cluster = ClusterConfig {
            nodes: 1,
            ranks_per_node: 8,
            break_after: 0,
            ..ClusterConfig::minihpc()
        };
        let mut cfg = DesConfig::new(
            LoopParams::new(4_096, cluster.total_ranks()),
            kind,
            ExecutionModel::HierDca,
            cluster,
            IterationCost::Constant(1e-5),
        );
        cfg.hier = HierParams::default().with_levels(3).with_fanouts(&[1, 1, 8]);
        cfg.sched_path = path;
        if adaptive {
            cfg.hier = cfg
                .hier
                .with_adaptive()
                .with_probe_interval(1)
                .with_candidates(CandidateSet::EMPTY.try_with(kind).unwrap());
        }
        simulate(&cfg).unwrap_or_else(|e| panic!("{kind} {path} adaptive={adaptive}: {e}"))
    };
    for kind in TechniqueKind::ALL {
        if !kind.has_closed_form() {
            continue;
        }
        let mut pairs = vec![(SchedPath::TwoPhase, SchedPath::TwoPhase)];
        if kind.supports_fast_path() {
            pairs.push((SchedPath::LockFree, SchedPath::LockFree));
            pairs.push((SchedPath::LockFree, SchedPath::Auto));
        } else {
            pairs.push((SchedPath::TwoPhase, SchedPath::Auto));
        }
        for (static_path, adaptive_path) in pairs {
            let s = mk(kind, static_path, false);
            let a = mk(kind, adaptive_path, true);
            assert_eq!(
                s.sorted_assignments(),
                a.sorted_assignments(),
                "{kind} depth 3 {static_path}/{adaptive_path}: schedules"
            );
            assert_eq!(s.t_par(), a.t_par(), "{kind} {static_path}/{adaptive_path}");
            assert!(a.switch_events.is_empty(), "{kind}");
        }
    }
}

/// Adaptive rebinding at depth 3 under exponential slowdown: the mid-tier
/// AND leaf-tier controllers may rebind, coverage stays exact across the
/// three protocol levels, and the run replays deterministically.
#[test]
fn depth3_adaptive_rebinds_and_covers() {
    const N: u64 = 20_000;
    let mk = || {
        let cluster = ClusterConfig {
            nodes: 4,
            ranks_per_node: 4,
            racks: 2,
            ..ClusterConfig::minihpc()
        };
        let mut cfg = DesConfig::new(
            LoopParams::new(N, cluster.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cluster,
            IterationCost::Constant(1e-5),
        );
        cfg.delay = InjectedDelay::exponential_calculation(100e-6, 11);
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss)
            .with_levels(3)
            .with_fanouts(&[2, 2, 4])
            .with_adaptive()
            .with_probe_interval(4)
            .with_candidates(CandidateSet::parse("ss,gss,fac").unwrap());
        simulate(&cfg).unwrap()
    };
    let r = mk();
    verify_coverage(&r.sorted_assignments(), N).unwrap();
    assert!(!r.switch_events.is_empty(), "slowdown must trigger rebinds");
    assert!(
        r.switch_events.iter().all(|e| e.level >= 1),
        "the root's outer technique stays static: {:?}",
        r.switch_events
    );
    let replay = mk();
    assert_eq!(r.assignments, replay.assignments, "depth-3 adaptive replay");
    assert_eq!(r.switch_events, replay.switch_events);
}

/// The threaded engine's lock-free leaf at depth 3: coverage + checksum
/// stay exact with real CAS grants under the two-master spine.
#[test]
fn threaded_depth3_lockfree_covers_with_matching_checksum() {
    const N: u64 = 4_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 17));
    let reference = w.execute_range(0, N);
    for kind in [TechniqueKind::Fac2, TechniqueKind::Ss, TechniqueKind::Gss] {
        let cfg = hier_engine(N, 8, &[2, 2, 2], kind, HierParams::default()).with_lockfree();
        let r = run_covered(&cfg, &w, N, kind.name());
        assert_eq!(r.checksum, reference, "{kind}: checksum");
        assert!(r.fast_grants > 0, "{kind}: leaf CAS grants happened");
        assert!(r.level_messages[0] > 0, "{kind}: root protocol stays two-phase");
    }
}

/// 4 racks × 2 nodes × 4 ranks = 32 ranks, the depth-3 DES geometry.
fn racked_cluster(inter_rack: f64) -> ClusterConfig {
    ClusterConfig {
        nodes: 8,
        ranks_per_node: 4,
        racks: 4,
        inter_rack_latency: inter_rack,
        ..ClusterConfig::minihpc()
    }
}

fn depth3_des_cfg(n: u64, kind: TechniqueKind, cluster: ClusterConfig) -> DesConfig {
    let mut cfg = DesConfig::new(
        LoopParams::new(n, cluster.total_ranks()),
        kind,
        ExecutionModel::HierDca,
        cluster,
        IterationCost::Constant(1e-5),
    );
    cfg.hier = HierParams::default().with_levels(3).with_fanouts(&[4, 2, 4]);
    cfg
}

fn hier_engine(
    n: u64,
    p: u32,
    fanouts: &[u32],
    outer: TechniqueKind,
    hier: HierParams,
) -> EngineConfig {
    let mut cfg = EngineConfig::new(LoopParams::new(n, p), outer, ExecutionModel::HierDca);
    cfg.nodes = fanouts[0];
    cfg.hier = hier.with_levels(fanouts.len() as u32).with_fanouts(fanouts);
    cfg
}

fn run_covered(cfg: &EngineConfig, w: &Arc<dyn Workload>, n: u64, label: &str) -> RunResult {
    let r = coordinator::run(cfg, Arc::clone(w)).unwrap_or_else(|e| panic!("{label}: {e}"));
    verify_coverage(&r.sorted_assignments(), n).unwrap_or_else(|e| panic!("{label}: {e}"));
    r
}

/// The acceptance matrix: all 12 evaluated techniques × {0, 100 µs}
/// inter-rack latency cover the loop exactly at depth 3 on the DES, with
/// the per-level message split reconciling at every cell.
#[test]
fn depth3_covers_all_techniques_both_rack_latencies() {
    const N: u64 = 4_096;
    for kind in TechniqueKind::EVALUATED {
        for inter_rack in [0.0, 100e-6] {
            let cfg = depth3_des_cfg(N, kind, racked_cluster(inter_rack));
            let r = simulate(&cfg)
                .unwrap_or_else(|e| panic!("{kind} @ rack {}µs: {e}", inter_rack * 1e6));
            verify_coverage(&r.sorted_assignments(), N)
                .unwrap_or_else(|e| panic!("{kind} @ rack {}µs: {e}", inter_rack * 1e6));
            assert_eq!(r.level_messages.len(), 3, "{kind}");
            assert_eq!(
                r.stats.messages,
                r.level_messages.iter().sum::<u64>(),
                "{kind}: level split must reconcile"
            );
            assert_eq!(
                r.stats.messages,
                r.intra_node_messages + r.inter_node_messages,
                "{kind}: latency split must reconcile"
            );
            assert!(r.level_messages.iter().all(|&m| m > 0), "{kind}: all levels ran");
        }
    }
}

/// Depth-3 runs replay deterministically on the DES.
#[test]
fn depth3_deterministic_replay() {
    let cfg = depth3_des_cfg(6_000, TechniqueKind::Fac2, racked_cluster(100e-6));
    let a = simulate(&cfg).unwrap();
    let b = simulate(&cfg).unwrap();
    assert_eq!(a.assignments, b.assignments);
    assert_eq!(a.t_par(), b.t_par());
    assert_eq!(a.level_messages, b.level_messages);
}

/// Mixed per-level techniques (`--techniques fac,gss,ss`) cover at depth 3.
#[test]
fn depth3_mixed_level_techniques_cover() {
    const N: u64 = 4_096;
    let mut cfg = depth3_des_cfg(N, TechniqueKind::Fac2, racked_cluster(100e-6));
    cfg.hier = HierParams::with_inner(TechniqueKind::Ss)
        .with_levels(3)
        .with_fanouts(&[4, 2, 4])
        .with_mid(1, TechniqueKind::Gss);
    let r = simulate(&cfg).unwrap();
    verify_coverage(&r.sorted_assignments(), N).unwrap();
    // SS at the leaf level: unit sub-chunks dominate.
    let ones = r.assignments.iter().filter(|a| a.size == 1).count();
    assert!(ones > r.assignments.len() / 2, "leaf SS must produce unit chunks");
}

/// Coverage + checksum for all 12 evaluated techniques on the **threaded**
/// engine at depth 3 (2×2×2 = 8 ranks), message splits reconciling.
#[test]
fn threaded_depth3_covers_all_techniques_with_matching_checksum() {
    const N: u64 = 4_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 17));
    let reference = w.execute_range(0, N);
    for kind in TechniqueKind::EVALUATED {
        let cfg = hier_engine(N, 8, &[2, 2, 2], kind, HierParams::default());
        let r = run_covered(&cfg, &w, N, kind.name());
        assert_eq!(r.checksum, reference, "{kind}: checksum");
        assert_eq!(r.level_messages.len(), 3, "{kind}");
        assert_eq!(
            r.stats.messages,
            r.level_messages.iter().sum::<u64>(),
            "{kind}: level split must reconcile"
        );
        assert_eq!(
            r.stats.messages,
            r.intra_node_messages + r.inter_node_messages,
            "{kind}: latency split must reconcile"
        );
        assert!(r.level_messages[0] > 0, "{kind}: root protocol ran");
    }
}

/// Edge geometries at depth 3 on the threaded engine: fan-out 1 at the
/// top, middle, and leaf level (single-socket nodes — every rank a
/// master), more ranks than iterations, and a fully serial tree.
#[test]
fn threaded_depth3_edge_geometries() {
    let cases: [(u64, u32, [u32; 3], &str); 6] = [
        (2_000, 8, [1, 2, 4], "fanout 1 at the top level"),
        (2_000, 8, [2, 1, 4], "fanout 1 at the middle level"),
        (2_000, 4, [2, 2, 1], "single-socket nodes (leaf fan-out 1)"),
        (5, 8, [2, 2, 2], "N < total ranks"),
        (1_000, 1, [1, 1, 1], "fully serial tree"),
        (2_000, 8, [8, 1, 1], "wide root, degenerate lower levels"),
    ];
    for (n, p, fanouts, label) in cases {
        let w: Arc<dyn Workload> =
            Arc::new(Synthetic::new(n.max(64), 1e-7, CostShape::Uniform, 5));
        let reference = w.execute_range(0, n);
        let cfg = hier_engine(n, p, &fanouts, TechniqueKind::Gss, HierParams::default());
        let r = run_covered(&cfg, &w, n, label);
        assert_eq!(r.checksum, reference, "{label}: checksum");
        assert_eq!(r.per_rank.len(), p as usize, "{label}: one summary per rank");
    }
}

/// The same edge geometries cover on the DES (single-rank leaf groups need
/// computing masters, i.e. the default `break_after > 0`).
#[test]
fn des_depth3_edge_geometries() {
    let cases: [(u64, u32, u32, [u32; 3], &str); 4] = [
        (2_000, 2, 4, [1, 2, 4], "fanout 1 at the top level"),
        (2_000, 2, 4, [2, 1, 4], "fanout 1 at the middle level"),
        (1_000, 4, 1, [2, 2, 1], "single-socket nodes"),
        (5, 2, 4, [2, 2, 2], "N < total ranks"),
    ];
    for (n, nodes, rpn, fanouts, label) in cases {
        let cluster = ClusterConfig {
            nodes,
            ranks_per_node: rpn,
            ..ClusterConfig::minihpc()
        };
        let mut cfg = DesConfig::new(
            LoopParams::new(n, cluster.total_ranks()),
            TechniqueKind::Gss,
            ExecutionModel::HierDca,
            cluster,
            IterationCost::Constant(1e-5),
        );
        cfg.hier = HierParams::default().with_levels(3).with_fanouts(&fanouts);
        let r = simulate(&cfg).unwrap_or_else(|e| panic!("{label}: {e}"));
        verify_coverage(&r.sorted_assignments(), n).unwrap_or_else(|e| panic!("{label}: {e}"));
    }
}

/// Cross-engine equivalence at depth 3: on the fully serial tree (fan-out
/// 1 at every level) both engines are deterministic, and because every
/// level drives the *same* `hier::protocol` ledger, the granted
/// `(step, start, size)` sequences must be identical for every closed-form
/// technique. (AF is excluded: its sizes depend on measured wall-clock
/// timings by design.)
#[test]
fn threaded_and_des_depth3_grant_identical_serial_schedules() {
    const N: u64 = 2_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-8, CostShape::Uniform, 9));
    for kind in TechniqueKind::ALL {
        if kind == TechniqueKind::Af {
            continue;
        }
        let cfg = hier_engine(N, 1, &[1, 1, 1], kind, HierParams::default());
        let threaded = run_covered(&cfg, &w, N, kind.name());

        let cluster = ClusterConfig { nodes: 1, ranks_per_node: 1, ..ClusterConfig::minihpc() };
        let mut des_cfg = DesConfig::new(
            LoopParams::new(N, 1),
            kind,
            ExecutionModel::HierDca,
            cluster,
            IterationCost::Constant(1e-6),
        );
        des_cfg.hier = HierParams::default().with_levels(3).with_fanouts(&[1, 1, 1]);
        let des = simulate(&des_cfg).unwrap_or_else(|e| panic!("{kind}: {e}"));
        assert_eq!(
            threaded.sorted_assignments(),
            des.sorted_assignments(),
            "{kind}: depth-3 serial schedules must be identical across engines"
        );
    }
}

/// The adaptive-watermark satellite claim, asserted on the DES over the
/// PR 2 prefetch scenario (4×4 ranks, expensive 200 µs inter-node fabric):
/// `--watermark auto` must never be worse than fetch-on-exhaustion
/// (watermark off), in both total scheduling wait and `T_par` — the EWMA
/// round trip × measured drain rate hides the fetch without hand tuning.
#[test]
fn auto_watermark_never_worse_than_fetch_on_exhaustion() {
    const N: u64 = 20_000;
    let cluster = ClusterConfig {
        nodes: 4,
        ranks_per_node: 4,
        inter_node_latency: 200e-6,
        ..ClusterConfig::minihpc()
    };
    let mk = |hier: HierParams| {
        let cfg = DesConfig {
            hier,
            ..DesConfig::new(
                LoopParams::new(N, cluster.total_ranks()),
                TechniqueKind::Fac2,
                ExecutionModel::HierDca,
                cluster.clone(),
                IterationCost::Constant(2e-5),
            )
        };
        let r = simulate(&cfg).unwrap();
        verify_coverage(&r.sorted_assignments(), N).unwrap();
        r
    };
    let inner = HierParams::with_inner(TechniqueKind::Ss);
    let exhaust = mk(inner);
    let auto = mk(inner.with_auto_watermark());
    assert!(
        auto.stats.sched_overhead <= exhaust.stats.sched_overhead,
        "auto watermark sched wait {} must not exceed fetch-on-exhaustion {}",
        auto.stats.sched_overhead,
        exhaust.stats.sched_overhead
    );
    assert!(
        auto.t_par() <= exhaust.t_par(),
        "auto watermark T_par {} must not exceed fetch-on-exhaustion {}",
        auto.t_par(),
        exhaust.t_par()
    );
}

/// A deeper staged queue (prefetch depth 3) keeps exact coverage and a
/// matching checksum on the threaded engine at depth 3.
#[test]
fn threaded_depth3_deep_prefetch_queue_covers() {
    const N: u64 = 4_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 23));
    let reference = w.execute_range(0, N);
    let hier = HierParams::with_inner(TechniqueKind::Ss)
        .with_watermark(64)
        .with_prefetch_depth(3);
    let cfg = hier_engine(N, 8, &[2, 2, 2], TechniqueKind::Fac2, hier);
    let r = run_covered(&cfg, &w, N, "deep prefetch");
    assert_eq!(r.checksum, reference);
}

/// The auto watermark also holds up on the threaded engine: coverage and
/// checksum stay exact (its payoff is asserted deterministically on the
/// DES above).
#[test]
fn threaded_auto_watermark_covers() {
    const N: u64 = 4_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 29));
    let reference = w.execute_range(0, N);
    let hier = HierParams::with_inner(TechniqueKind::Ss).with_auto_watermark();
    let cfg = hier_engine(N, 4, &[2, 2], TechniqueKind::Fac2, hier);
    let r = run_covered(&cfg, &w, N, "auto watermark");
    assert_eq!(r.checksum, reference);
}

/// Depth-3 trees with a 100 µs rack class confine cross-node traffic: the
/// root (rack) protocol carries far fewer messages than the leaf protocol,
/// and the DES's per-level counters expose exactly that.
#[test]
fn depth3_confines_expensive_traffic_to_the_top_level() {
    let cfg = depth3_des_cfg(8_192, TechniqueKind::Fac2, racked_cluster(100e-6));
    let r = simulate(&cfg).unwrap();
    verify_coverage(&r.sorted_assignments(), 8_192).unwrap();
    assert!(
        r.level_messages[0] * 10 < r.level_messages[2],
        "root protocol {} should be ≫ rarer than the leaf protocol {}",
        r.level_messages[0],
        r.level_messages[2]
    );
    assert!(
        r.level_messages[1] < r.level_messages[2],
        "middle protocol {} should be rarer than the leaf protocol {}",
        r.level_messages[1],
        r.level_messages[2]
    );
}
