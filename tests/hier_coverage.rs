//! Coverage and sanity of the hierarchical `HierDca` model at paper scale:
//! every iteration of the loop must be scheduled exactly once — no gaps, no
//! overlaps — for **all 12 evaluated techniques × the slowdown scenarios**
//! (no-delay, constant 10/100 µs, exponential mean 10/100 µs) on the full
//! 256-rank miniHPC geometry, with the constant slowdown additionally
//! exercised at the assignment injection site.

use dca_dls::config::{ClusterConfig, ExecutionModel, HierParams};
use dca_dls::des::{simulate, DesConfig, DesResult};
use dca_dls::sched::verify_coverage;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::workload::IterationCost;

const N: u64 = 8_192;

fn hier_cfg(kind: TechniqueKind, delay: InjectedDelay, inner: HierParams) -> DesConfig {
    let cluster = ClusterConfig::minihpc(); // 16 × 16 = 256 ranks
    DesConfig {
        delay,
        hier: inner,
        ..DesConfig::new(
            LoopParams::new(N, cluster.total_ranks()),
            kind,
            ExecutionModel::HierDca,
            cluster,
            IterationCost::Constant(1e-5),
        )
    }
}

/// The acceptance matrix: 12 techniques × {no-delay, 10 µs, 100 µs}
/// calculation slowdown at 256 ranks.
#[test]
fn hier_covers_all_techniques_all_calc_scenarios_256_ranks() {
    for kind in TechniqueKind::EVALUATED {
        for delay_s in [0.0, 10e-6, 100e-6] {
            let cfg = hier_cfg(
                kind,
                InjectedDelay::calculation_only(delay_s),
                HierParams::default(),
            );
            let r = simulate(&cfg)
                .unwrap_or_else(|e| panic!("{kind} @ {}µs: {e}", delay_s * 1e6));
            verify_coverage(&r.sorted_assignments(), N)
                .unwrap_or_else(|e| panic!("{kind} @ {}µs: {e}", delay_s * 1e6));
            assert!(r.t_par() > 0.0, "{kind} @ {}µs", delay_s * 1e6);
            assert_eq!(r.rma_ops, 0, "{kind}: hier uses no RMA");
        }
    }
}

/// Same matrix with **exponentially distributed** (bursty) calculation
/// slowdown — mean 10 µs and 100 µs — deterministic per (seed, rank, time)
/// so the run replays; coverage must hold under irregular perturbation too.
#[test]
fn hier_covers_all_techniques_exponential_scenarios_256_ranks() {
    for kind in TechniqueKind::EVALUATED {
        for mean_s in [10e-6, 100e-6] {
            let cfg = hier_cfg(
                kind,
                InjectedDelay::exponential_calculation(mean_s, 0xE4_0001),
                HierParams::default(),
            );
            let r = simulate(&cfg)
                .unwrap_or_else(|e| panic!("{kind} @ exp {}µs: {e}", mean_s * 1e6));
            verify_coverage(&r.sorted_assignments(), N)
                .unwrap_or_else(|e| panic!("{kind} @ exp {}µs: {e}", mean_s * 1e6));
            assert!(r.t_par() > 0.0, "{kind} @ exp {}µs", mean_s * 1e6);
        }
    }
}

/// Exponential runs replay bit-identically (the draws are deterministic in
/// (seed, rank, virtual time), not in wall-clock randomness).
#[test]
fn hier_exponential_deterministic() {
    let cfg = hier_cfg(
        TechniqueKind::Fac2,
        InjectedDelay::exponential_calculation(100e-6, 7),
        HierParams::default(),
    );
    let a = simulate(&cfg).unwrap();
    let b = simulate(&cfg).unwrap();
    assert_eq!(a.t_par(), b.t_par());
    assert_eq!(a.assignments, b.assignments);
}

/// Same matrix with the §7 assignment-site slowdown: the delay lands on the
/// node masters' commit path (and the coordinator's outer commits) — the
/// schedule must still tile the loop exactly.
#[test]
fn hier_covers_all_techniques_assignment_scenarios_256_ranks() {
    for kind in TechniqueKind::EVALUATED {
        for delay_s in [10e-6, 100e-6] {
            let cfg = hier_cfg(
                kind,
                InjectedDelay::assignment_only(delay_s),
                HierParams::default(),
            );
            let r = simulate(&cfg)
                .unwrap_or_else(|e| panic!("{kind} @ {}µs: {e}", delay_s * 1e6));
            verify_coverage(&r.sorted_assignments(), N)
                .unwrap_or_else(|e| panic!("{kind} @ {}µs: {e}", delay_s * 1e6));
        }
    }
}

/// Mixed technique pairs: a batched outer level with every inner technique.
#[test]
fn hier_covers_mixed_inner_techniques_256_ranks() {
    for inner in TechniqueKind::EVALUATED {
        let cfg = hier_cfg(
            TechniqueKind::Fac2,
            InjectedDelay::calculation_only(100e-6),
            HierParams::with_inner(inner),
        );
        let r = simulate(&cfg).unwrap_or_else(|e| panic!("FAC▸{inner}: {e}"));
        verify_coverage(&r.sorted_assignments(), N).unwrap_or_else(|e| panic!("FAC▸{inner}: {e}"));
    }
}

/// Determinism at full scale: the hierarchical event loop replays
/// bit-identically.
#[test]
fn hier_deterministic_at_256_ranks() {
    let cfg = hier_cfg(
        TechniqueKind::Gss,
        InjectedDelay::calculation_only(100e-6),
        HierParams::default(),
    );
    let a = simulate(&cfg).unwrap();
    let b = simulate(&cfg).unwrap();
    assert_eq!(a.t_par(), b.t_par());
    assert_eq!(a.stats.messages, b.stats.messages);
    assert_eq!(a.assignments, b.assignments);
}

/// Every rank participates: with 256 ranks and a batched technique the
/// granted iterations must be spread across all 16 nodes.
#[test]
fn hier_all_nodes_receive_work() {
    let cfg = hier_cfg(TechniqueKind::Fac2, InjectedDelay::none(), HierParams::default());
    let r = simulate(&cfg).unwrap();
    verify_coverage(&r.sorted_assignments(), N).unwrap();
    // Node-chunk boundaries are invisible in assignments, but with N=8192
    // over 16 nodes a healthy run produces far more chunks than nodes.
    assert!(r.stats.chunks >= 16, "chunks={}", r.stats.chunks);
    assert!(r.stats.messages > 0);
}
