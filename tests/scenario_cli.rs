//! End-to-end tests for the `dca-dls scenario` subcommand family and its
//! documented exit-code contract (docs/scenario-spec.md):
//!
//!   0 — every expectation of every spec held,
//!   1 — a spec parsed and ran but an expectation failed (or the run
//!       errored),
//!   2 — a spec (or the command line) could not be understood.
//!
//! The fixtures under `tests/fixtures/` pin one spec per exit code; the
//! committed suite under `scenarios/` is parse-validated spec-by-spec and
//! the cheapest cell is run end-to-end against its blessed baseline.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use dca_dls::report::json::Json;
use dca_dls::scenario::parse_scenario;

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn fixture(name: &str) -> String {
    repo_root().join("tests/fixtures").join(name).display().to_string()
}

/// Run the built binary from the repository root (so the default
/// `scenarios` directory of `scenario list` resolves).
fn dca_dls(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_dca-dls"))
        .args(args)
        .current_dir(repo_root())
        .output()
        .expect("spawn dca-dls")
}

fn code(out: &Output) -> i32 {
    out.status.code().expect("exit code (not signal)")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn passing_spec_exits_zero() {
    let out = dca_dls(&["scenario", "run", &fixture("scenario_pass.json")]);
    assert_eq!(code(&out), 0, "stderr: {}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("fixture-pass: PASS"), "stdout: {text}");
    assert!(text.contains("[PASS] t_par"), "stdout: {text}");
}

#[test]
fn failed_expectation_exits_one() {
    let out = dca_dls(&["scenario", "run", &fixture("scenario_fail.json")]);
    assert_eq!(code(&out), 1);
    let text = stdout(&out);
    assert!(text.contains("fixture-fail: FAIL"), "stdout: {text}");
    assert!(text.contains("[FAIL] t_par"), "stdout: {text}");
}

#[test]
fn malformed_spec_exits_two() {
    for verb in ["run", "validate", "explain"] {
        let out = dca_dls(&["scenario", verb, &fixture("scenario_bad.json")]);
        assert_eq!(code(&out), 2, "`scenario {verb}` on a bad spec");
        assert!(
            stderr(&out).contains("error"),
            "`scenario {verb}` stderr: {}",
            stderr(&out)
        );
    }
}

#[test]
fn one_failure_taints_a_multi_spec_run() {
    let out = dca_dls(&[
        "scenario",
        "run",
        &fixture("scenario_pass.json"),
        &fixture("scenario_fail.json"),
    ]);
    assert_eq!(code(&out), 1);
    let text = stdout(&out);
    assert!(text.contains("fixture-pass: PASS"), "stdout: {text}");
    assert!(text.contains("fixture-fail: FAIL"), "stdout: {text}");
}

#[test]
fn unknown_verb_and_missing_args_exit_two() {
    assert_eq!(code(&dca_dls(&["scenario", "frobnicate"])), 2);
    assert_eq!(code(&dca_dls(&["scenario"])), 2);
    assert_eq!(code(&dca_dls(&["scenario", "run"])), 2);
    assert_eq!(code(&dca_dls(&["scenario", "validate"])), 2);
    assert_eq!(
        code(&dca_dls(&["scenario", "run", "--no-such-flag", &fixture("scenario_pass.json")])),
        2
    );
}

/// `--jobs N` executes the specs on worker threads but still prints the
/// reports in list order and keeps the worst exit code.
#[test]
fn parallel_jobs_keep_order_and_worst_exit_code() {
    let out = dca_dls(&[
        "scenario",
        "run",
        "--jobs",
        "2",
        &fixture("scenario_pass.json"),
        &fixture("scenario_fail.json"),
    ]);
    assert_eq!(code(&out), 1, "stderr: {}", stderr(&out));
    let text = stdout(&out);
    let pass = text.find("fixture-pass: PASS").expect("pass report");
    let fail = text.find("fixture-fail: FAIL").expect("fail report");
    assert!(pass < fail, "reports must print in list order: {text}");

    // Usage errors: a zero job count, and --jobs with --stream-metrics.
    let out = dca_dls(&["scenario", "run", "--jobs", "0", &fixture("scenario_pass.json")]);
    assert_eq!(code(&out), 2, "--jobs 0 is a usage error");
    let out = dca_dls(&[
        "scenario",
        "run",
        "--jobs",
        "2",
        "--stream-metrics",
        "-",
        &fixture("scenario_pass.json"),
    ]);
    assert_eq!(code(&out), 2, "--jobs cannot stream one virtual-time order");
}

#[test]
fn validate_and_explain_accept_good_specs() {
    let out = dca_dls(&["scenario", "validate", &fixture("scenario_pass.json")]);
    assert_eq!(code(&out), 0);
    assert!(stdout(&out).contains("ok (fixture-pass)"), "stdout: {}", stdout(&out));

    let out = dca_dls(&["scenario", "explain", &fixture("scenario_pass.json")]);
    assert_eq!(code(&out), 0);
    assert!(stdout(&out).contains("fixture-pass"), "stdout: {}", stdout(&out));
}

#[test]
fn json_report_has_the_documented_schema() {
    let out = dca_dls(&["scenario", "run", "--json", &fixture("scenario_pass.json")]);
    assert_eq!(code(&out), 0, "stderr: {}", stderr(&out));
    let report = Json::parse(stdout(&out).trim()).expect("report parses as JSON");
    assert_eq!(
        report.get("schema").and_then(Json::as_str),
        Some("dca-dls/scenario-report/v1")
    );
    assert_eq!(report.get("name").and_then(Json::as_str), Some("fixture-pass"));
    assert_eq!(report.get("passed").map(|j| j.render()), Some("true".into()));
    let Some(Json::Arr(checks)) = report.get("checks") else {
        panic!("report has no checks array: {}", report.render());
    };
    assert!(!checks.is_empty());
    let t_par = report
        .get("observed")
        .and_then(|o| o.get("t_par"))
        .and_then(Json::as_f64)
        .expect("observed.t_par");
    assert!(t_par > 0.0);
}

#[test]
fn stream_metrics_writes_schema_tagged_ndjson() {
    let dest = std::env::temp_dir().join(format!("dcadls-scenario-stream-{}.ndjson", std::process::id()));
    let dest_s = dest.display().to_string();
    let out = dca_dls(&["scenario", "run", &fixture("scenario_pass.json"), "--stream-metrics", &dest_s]);
    assert_eq!(code(&out), 0, "stderr: {}", stderr(&out));
    let text = std::fs::read_to_string(&dest).expect("stream file written");
    std::fs::remove_file(&dest).ok();
    let lines: Vec<_> = text.lines().filter(|l| !l.is_empty()).collect();
    assert!(!lines.is_empty(), "stream file is empty");
    for line in &lines {
        let record = Json::parse(line).expect("NDJSON line parses");
        assert_eq!(
            record.get("schema").and_then(Json::as_str),
            Some("dca-dls/stream/v1"),
            "line: {line}"
        );
        assert!(record.get("event").is_some() && record.get("t").is_some(), "line: {line}");
    }
}

#[test]
fn scenario_list_reads_the_committed_suite() {
    let out = dca_dls(&["scenario", "list"]);
    assert_eq!(code(&out), 0, "stderr: {}", stderr(&out));
    let text = stdout(&out);
    for name in [
        "hier-calc-100us",
        "adaptive-exp-slowdown",
        "dca-ss-lockfree",
        "tenants-fair-share",
        "hier-prefetch",
    ] {
        assert!(text.contains(name), "`scenario list` is missing {name}: {text}");
    }
}

#[test]
fn committed_scenarios_all_parse() {
    let dir = repo_root().join("scenarios");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("scenarios/ exists") {
        let path = entry.expect("dir entry").path();
        if !path.extension().is_some_and(|x| x == "json") {
            continue;
        }
        let text = std::fs::read_to_string(&path).expect("read spec");
        let sc = parse_scenario(&text)
            .unwrap_or_else(|e| panic!("{} does not parse: {e:#}", path.display()));
        assert!(!sc.name.is_empty());
        seen += 1;
    }
    assert!(seen >= 5, "expected the five committed scenarios, found {seen}");
}

/// The cheapest committed baseline cell (flat DCA SS over the lock-free
/// path, 50 000 iterations on 64 ranks) must reproduce end-to-end.
#[test]
fn committed_lockfree_cell_reproduces_its_baseline() {
    let spec = repo_root().join("scenarios/dca-ss-lockfree.json");
    let out = dca_dls(&["scenario", "run", "--json", &spec.display().to_string()]);
    assert_eq!(code(&out), 0, "stderr: {}", stderr(&out));
    let report = Json::parse(stdout(&out).trim()).expect("report parses");
    assert_eq!(report.get("passed").map(|j| j.render()), Some("true".into()));
}
