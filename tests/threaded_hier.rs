//! Validation of the **threaded** two-level engine (`coordinator::hier`):
//! exact loop coverage and matching checksums for all 12 evaluated
//! techniques, edge geometries (`rpn = 1`, `nodes = 1`, `N < P`, `P = 1`),
//! cross-engine equivalence against the DES on a fully serial geometry
//! (both consume the shared `hier::protocol` ledger, so the schedules must
//! be identical), and the outer-prefetch payoff asserted deterministically
//! on the DES.

use std::sync::Arc;

use dca_dls::config::{ClusterConfig, ExecutionModel, HierParams};
use dca_dls::coordinator::{self, EngineConfig, RunResult};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::sched::{verify_coverage, Assignment};
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::workload::synthetic::{CostShape, Synthetic};
use dca_dls::workload::{IterationCost, Workload};

fn hier_engine(n: u64, p: u32, nodes: u32, outer: TechniqueKind, hier: HierParams) -> EngineConfig {
    let mut cfg = EngineConfig::new(LoopParams::new(n, p), outer, ExecutionModel::HierDca);
    cfg.nodes = nodes;
    cfg.hier = hier;
    cfg
}

fn run_covered(cfg: &EngineConfig, w: &Arc<dyn Workload>, n: u64, label: &str) -> RunResult {
    let r = coordinator::run(cfg, Arc::clone(w)).unwrap_or_else(|e| panic!("{label}: {e}"));
    verify_coverage(&r.sorted_assignments(), n).unwrap_or_else(|e| panic!("{label}: {e}"));
    r
}

/// Exact coverage + checksum for all 12 evaluated techniques as the outer
/// (and, by default, inner) technique on a 2×2 geometry — the threaded
/// analogue of `tests/hier_coverage.rs`.
#[test]
fn threaded_hier_covers_all_techniques_with_matching_checksum() {
    const N: u64 = 6_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 11));
    let reference = w.execute_range(0, N);
    for kind in TechniqueKind::EVALUATED {
        let cfg = hier_engine(N, 4, 2, kind, HierParams::default());
        let r = run_covered(&cfg, &w, N, kind.name());
        assert_eq!(r.checksum, reference, "{kind}: checksum");
        assert!(r.inter_node_messages > 0, "{kind}: outer protocol ran");
        assert!(r.intra_node_messages > 0, "{kind}: inner protocol ran");
        assert_eq!(r.stats.messages, r.intra_node_messages + r.inter_node_messages, "{kind}");
    }
}

/// A batched outer level with every inner technique (mixed pairs).
#[test]
fn threaded_hier_covers_mixed_inner_techniques() {
    const N: u64 = 5_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Uniform, 3));
    let reference = w.execute_range(0, N);
    for inner in TechniqueKind::EVALUATED {
        let cfg = hier_engine(N, 4, 2, TechniqueKind::Fac2, HierParams::with_inner(inner));
        let r = run_covered(&cfg, &w, N, &format!("FAC▸{inner}"));
        assert_eq!(r.checksum, reference, "FAC▸{inner}: checksum");
    }
}

/// Edge geometries: single-rank nodes (masters do everything), a single
/// node (the outer level degenerates), more ranks than iterations, and a
/// fully serial run.
#[test]
fn threaded_hier_edge_geometries() {
    let cases: [(u64, u32, u32, &str); 4] = [
        (2_000, 4, 4, "rpn=1 (masters compute everything)"),
        (2_000, 4, 1, "nodes=1 (degenerate outer level)"),
        (5, 8, 2, "N < P (more ranks than iterations)"),
        (1_000, 1, 1, "serial (one master, no workers)"),
    ];
    for (n, p, nodes, label) in cases {
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(n.max(64), 1e-7, CostShape::Uniform, 5));
        let reference = w.execute_range(0, n);
        let cfg = hier_engine(n, p, nodes, TechniqueKind::Gss, HierParams::default());
        let r = run_covered(&cfg, &w, n, label);
        assert_eq!(r.checksum, reference, "{label}: checksum");
        assert_eq!(r.per_rank.len(), p as usize, "{label}: one summary per rank");
    }
}

/// Prefetch mode on the threaded engine: still exact coverage and an
/// identical checksum (the staged-install path is exercised for real).
#[test]
fn threaded_hier_prefetch_covers() {
    const N: u64 = 4_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 23));
    let reference = w.execute_range(0, N);
    let hier = HierParams::with_inner(TechniqueKind::Ss).with_watermark(64);
    let cfg = hier_engine(N, 4, 2, TechniqueKind::Fac2, hier);
    let r = run_covered(&cfg, &w, N, "prefetch");
    assert_eq!(r.checksum, reference);
}

/// Block placement requires `nodes | P`.
#[test]
fn threaded_hier_rejects_indivisible_geometry() {
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(100, 1e-7, CostShape::Uniform, 1));
    let cfg = hier_engine(100, 4, 3, TechniqueKind::Gss, HierParams::default());
    let e = coordinator::run(&cfg, w).unwrap_err();
    assert!(e.to_string().contains("divide"), "{e}");
}

/// Cross-engine equivalence: on a fully serial geometry (1 node × 1 rank)
/// both engines are deterministic, and because they drive the *same*
/// `hier::protocol` ledger, the granted `(step, start, size)` sequences
/// must be identical for every closed-form technique. (AF is excluded: its
/// sizes depend on measured wall-clock timings by design.)
#[test]
fn threaded_and_des_hier_grant_identical_serial_schedules() {
    const N: u64 = 3_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-8, CostShape::Uniform, 9));
    for kind in TechniqueKind::ALL {
        if kind == TechniqueKind::Af {
            continue;
        }
        let cfg = hier_engine(N, 1, 1, kind, HierParams::default());
        let threaded = run_covered(&cfg, &w, N, kind.name());

        let cluster = ClusterConfig { nodes: 1, ranks_per_node: 1, ..ClusterConfig::minihpc() };
        let des_cfg = DesConfig {
            params: LoopParams::new(N, 1),
            technique: kind,
            model: ExecutionModel::HierDca,
            delay: InjectedDelay::none(),
            cluster,
            cost: IterationCost::Constant(1e-6),
            pe_speed: vec![],
            hier: HierParams::default(),
        };
        let des = simulate(&des_cfg).unwrap_or_else(|e| panic!("{kind}: {e}"));
        let mut des_sorted: Vec<Assignment> = des.assignments.clone();
        des_sorted.sort_by_key(|a| a.start);
        assert_eq!(
            threaded.sorted_assignments(),
            des_sorted,
            "{kind}: serial schedules must be identical across engines"
        );
    }
}

/// The outer-prefetch payoff, asserted deterministically on the DES (which
/// shares the ledger with the threaded engine): with an expensive
/// inter-node fabric, prefetching the next node-chunk below a watermark
/// must strictly reduce both the total scheduling wait and `T_par`
/// compared to fetch-on-exhaustion.
#[test]
fn prefetch_beats_fetch_on_exhaustion() {
    const N: u64 = 20_000;
    let cluster = ClusterConfig {
        nodes: 4,
        ranks_per_node: 4,
        inter_node_latency: 200e-6, // make the outer round trip expensive
        ..ClusterConfig::minihpc()
    };
    let mk = |hier: HierParams| {
        let cfg = DesConfig {
            params: LoopParams::new(N, cluster.total_ranks()),
            technique: TechniqueKind::Fac2,
            model: ExecutionModel::HierDca,
            delay: InjectedDelay::none(),
            cluster: cluster.clone(),
            cost: IterationCost::Constant(2e-5),
            pe_speed: vec![],
            hier,
        };
        let r = simulate(&cfg).unwrap();
        let mut sorted = r.assignments.clone();
        sorted.sort_by_key(|a| a.start);
        verify_coverage(&sorted, N).unwrap();
        r
    };
    let inner = HierParams::with_inner(TechniqueKind::Ss);
    let exhaust = mk(inner);
    let prefetch = mk(inner.with_watermark(256));
    assert!(
        prefetch.stats.sched_overhead < exhaust.stats.sched_overhead,
        "prefetch sched wait {} must beat fetch-on-exhaustion {}",
        prefetch.stats.sched_overhead,
        exhaust.stats.sched_overhead
    );
    assert!(
        prefetch.t_par() < exhaust.t_par(),
        "prefetch T_par {} must beat fetch-on-exhaustion {}",
        prefetch.t_par(),
        exhaust.t_par()
    );
}

/// Prefetch keeps exact coverage across the full technique matrix on the
/// DES (staging + stale-`seq` NACK interplay under every chunk pattern).
#[test]
fn prefetch_covers_all_techniques_des() {
    const N: u64 = 4_000;
    let cluster = ClusterConfig { nodes: 2, ranks_per_node: 4, ..ClusterConfig::minihpc() };
    for kind in TechniqueKind::EVALUATED {
        let cfg = DesConfig {
            params: LoopParams::new(N, cluster.total_ranks()),
            technique: kind,
            model: ExecutionModel::HierDca,
            delay: InjectedDelay::calculation_only(10e-6),
            cluster: cluster.clone(),
            cost: IterationCost::Constant(1e-5),
            pe_speed: vec![],
            hier: HierParams::default().with_watermark(64),
        };
        let r = simulate(&cfg).unwrap_or_else(|e| panic!("{kind}: {e}"));
        let mut sorted = r.assignments.clone();
        sorted.sort_by_key(|a| a.start);
        verify_coverage(&sorted, N).unwrap_or_else(|e| panic!("{kind}: {e}"));
    }
}
