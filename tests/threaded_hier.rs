//! Validation of the **threaded** two-level engine (`coordinator::hier`):
//! exact loop coverage and matching checksums for all 12 evaluated
//! techniques, edge geometries (`rpn = 1`, `nodes = 1`, `N < P`, `P = 1`),
//! cross-engine equivalence against the DES on a fully serial geometry
//! (both consume the shared `hier::protocol` ledger, so the schedules must
//! be identical), and the outer-prefetch payoff asserted deterministically
//! on the DES.

use std::sync::Arc;

use dca_dls::config::{ClusterConfig, ExecutionModel, HierParams, SchedPath};
use dca_dls::coordinator::{self, EngineConfig, RunResult};
use dca_dls::des::{simulate, DesConfig, DesResult};
use dca_dls::sched::{verify_coverage, Assignment};
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{CandidateSet, LoopParams, TechniqueKind};
use dca_dls::workload::synthetic::{CostShape, Synthetic};
use dca_dls::workload::{IterationCost, Workload};

/// The schedule-equivalence property scenario: a **dedicated** master
/// (`break_after = 0`) serving a uniform-latency single-node group. With
/// every requester identical, two-phase commits land in reservation order,
/// so the two-phase schedule *is* the canonical table schedule the CAS
/// path always emits — and the equality below is deterministic, not a
/// race-prone coincidence. (On heterogeneous-latency geometries the
/// two-phase tail legitimately shifts by commit order — §3 only requires
/// disjoint coverage — which is why the property pins this geometry.)
fn equivalence_des_cfg(kind: TechniqueKind, path: SchedPath, levels: u32) -> DesConfig {
    let cluster = ClusterConfig {
        nodes: 1,
        ranks_per_node: 8,
        break_after: 0,
        ..ClusterConfig::minihpc()
    };
    let mut cfg = DesConfig::new(
        LoopParams::new(4_096, cluster.total_ranks()),
        kind,
        if levels == 0 { ExecutionModel::Dca } else { ExecutionModel::HierDca },
        cluster,
        IterationCost::Constant(1e-5),
    );
    if levels == 2 {
        cfg.hier = HierParams::default().with_levels(2).with_fanouts(&[1, 8]);
    } else if levels == 3 {
        cfg.hier = HierParams::default().with_levels(3).with_fanouts(&[1, 1, 8]);
    }
    cfg.sched_path = path;
    cfg
}

/// Run one equivalence cell and assert the tentpole property: bit-identical
/// serial schedules (sorted by start) and chunk counts between the
/// two-phase ledger and the CAS fast path, with the fast path never slower.
pub fn assert_equivalent(kind: TechniqueKind, levels: u32) -> (DesResult, DesResult) {
    let two = simulate(&equivalence_des_cfg(kind, SchedPath::TwoPhase, levels))
        .unwrap_or_else(|e| panic!("{kind} two-phase: {e}"));
    let fast = simulate(&equivalence_des_cfg(kind, SchedPath::LockFree, levels))
        .unwrap_or_else(|e| panic!("{kind} lockfree: {e}"));
    verify_coverage(&fast.sorted_assignments(), 4_096).unwrap_or_else(|e| panic!("{kind}: {e}"));
    assert_eq!(
        two.sorted_assignments(),
        fast.sorted_assignments(),
        "{kind} depth {levels}: serial schedules must be bit-identical across grant paths"
    );
    assert_eq!(two.stats.chunks, fast.stats.chunks, "{kind}: chunk counts");
    assert!(
        fast.t_par() <= two.t_par(),
        "{kind} depth {levels}: lockfree t_par {} must not exceed two-phase {}",
        fast.t_par(),
        two.t_par()
    );
    if kind.supports_fast_path() {
        assert!(fast.fast_grants > 0, "{kind}: CAS grants happened");
    } else {
        assert_eq!(fast.fast_grants, 0, "{kind}: AF/TAP fall back to two-phase");
        assert_eq!(fast.t_par(), two.t_par(), "{kind}: fallback is bit-identical");
    }
    (two, fast)
}

/// Tentpole property, flat: for every technique the lock-free CAS path and
/// the two-phase DCA protocol emit bit-identical serial schedules.
#[test]
fn lockfree_matches_two_phase_schedule_flat() {
    for kind in TechniqueKind::ALL {
        let (_, fast) = assert_equivalent(kind, 0);
        if kind.supports_fast_path() {
            assert_eq!(fast.stats.messages, 0, "{kind}: flat fast path needs no messages");
        }
    }
}

/// Tentpole property, depth 2: same equality through a leaf ledger that is
/// installed/replaced chunk by chunk (seq bumps, table re-binding).
#[test]
fn lockfree_matches_two_phase_schedule_depth2() {
    for kind in TechniqueKind::ALL {
        assert_equivalent(kind, 2);
    }
}

/// `SchedPath::Auto` without adaptivity IS the lock-free path: bit-identical
/// schedules, t_par, and CAS accounting for every technique, flat and
/// depth 2 (including the AF/TAP two-phase fallbacks).
#[test]
fn auto_path_matches_lockfree_when_static() {
    for levels in [0u32, 2] {
        for kind in TechniqueKind::ALL {
            let lf = simulate(&equivalence_des_cfg(kind, SchedPath::LockFree, levels))
                .unwrap_or_else(|e| panic!("{kind} lockfree: {e}"));
            let auto = simulate(&equivalence_des_cfg(kind, SchedPath::Auto, levels))
                .unwrap_or_else(|e| panic!("{kind} auto: {e}"));
            assert_eq!(lf.assignments, auto.assignments, "{kind} depth {levels}");
            assert_eq!(lf.t_par(), auto.t_par(), "{kind} depth {levels}");
            assert_eq!(lf.fast_grants, auto.fast_grants, "{kind} depth {levels}");
            assert_eq!(lf.stats.messages, auto.stats.messages, "{kind} depth {levels}");
        }
    }
}

/// ISSUE 5 regression property: with adaptivity driven by a
/// **single-candidate set** (probing every grant, so the controller runs
/// constantly but can never switch), the emitted serial schedules and
/// t_par are bit-identical to the static PR 4 paths — for every
/// closed-form technique × {flat, depth-2} × every applicable grant path.
/// (AF cannot be a candidate; its static runs are untouched by
/// construction since `adaptive` defaults off.)
#[test]
fn single_candidate_adaptive_is_bit_identical() {
    for levels in [0u32, 2] {
        for kind in TechniqueKind::ALL {
            if !kind.has_closed_form() {
                continue;
            }
            // (static path, adaptive path) pairs that must coincide exactly.
            // Flat adaptive runs two-phase under Auto (once the coordinator
            // disappears nobody could rebind); a non-fast-path leaf (TAP)
            // starts two-phase under Auto as well.
            let mut pairs = vec![(SchedPath::TwoPhase, SchedPath::TwoPhase)];
            if levels != 0 && kind.supports_fast_path() {
                pairs.push((SchedPath::LockFree, SchedPath::LockFree));
                pairs.push((SchedPath::LockFree, SchedPath::Auto));
            } else {
                pairs.push((SchedPath::TwoPhase, SchedPath::Auto));
            }
            for (static_path, adaptive_path) in pairs {
                let s = simulate(&equivalence_des_cfg(kind, static_path, levels))
                    .unwrap_or_else(|e| panic!("{kind} static {static_path}: {e}"));
                let mut cfg = equivalence_des_cfg(kind, adaptive_path, levels);
                cfg.hier = cfg
                    .hier
                    .with_adaptive()
                    .with_probe_interval(1)
                    .with_candidates(CandidateSet::EMPTY.try_with(kind).unwrap());
                let a = simulate(&cfg)
                    .unwrap_or_else(|e| panic!("{kind} adaptive {adaptive_path}: {e}"));
                assert_eq!(
                    s.sorted_assignments(),
                    a.sorted_assignments(),
                    "{kind} depth {levels} {static_path}/{adaptive_path}: schedules"
                );
                assert_eq!(
                    s.t_par(),
                    a.t_par(),
                    "{kind} depth {levels} {static_path}/{adaptive_path}: t_par"
                );
                assert!(a.switch_events.is_empty(), "{kind}: nothing to switch to");
            }
        }
    }
}

/// The adaptive controller under extreme (exponential) slowdown, on the
/// DES: starting every subtree on SS — the worst inner technique for an
/// overhead-dominated regime — the controllers must rebind (switch events
/// recorded), keep exact coverage through the mid-chunk stale-`seq` NACKs,
/// replay deterministically, and beat the static SS run outright.
/// (Validated numerically through the Python reference model, which also
/// blesses the bench row: adapt/best-static = 0.966 on the bench cell.)
#[test]
fn adaptive_rebinds_under_slowdown_and_covers() {
    const N: u64 = 30_000;
    let cluster = ClusterConfig { nodes: 4, ranks_per_node: 4, ..ClusterConfig::minihpc() };
    let mk = |adaptive: bool| {
        let mut cfg = DesConfig::new(
            LoopParams::new(N, cluster.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cluster.clone(),
            IterationCost::Constant(1e-5),
        );
        cfg.delay = InjectedDelay::exponential_calculation(100e-6, 3);
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss);
        if adaptive {
            cfg.hier = cfg
                .hier
                .with_adaptive()
                .with_probe_interval(4)
                .with_candidates(CandidateSet::parse("ss,gss,fac").unwrap());
        }
        simulate(&cfg).unwrap()
    };
    let stat = mk(false);
    let adapt = mk(true);
    verify_coverage(&adapt.sorted_assignments(), N).unwrap();
    assert!(
        !adapt.switch_events.is_empty(),
        "the controllers must have rebound under a 10× overhead regime"
    );
    assert!(adapt.switch_events.iter().all(|e| e.level == 1), "leaf-level rebinds");
    assert!(
        adapt.t_par() < stat.t_par(),
        "adaptive {} must beat its own static starting technique {}",
        adapt.t_par(),
        stat.t_par()
    );
    assert!(stat.switch_events.is_empty(), "static runs record no switches");
    let replay = mk(true);
    assert_eq!(adapt.assignments, replay.assignments, "adaptive replay");
    assert_eq!(adapt.t_par(), replay.t_par());
    assert_eq!(adapt.switch_events, replay.switch_events);
}

/// `SchedPath::Auto` demotion, deterministically on the DES: a lock-free
/// SS leaf whose only alternative candidate is the measurement-coupled TAP
/// must start with CAS grants, rebind to TAP once the overhead EWMAs are
/// primed, demote those subtrees to the two-phase protocol, and still
/// cover the loop exactly with a deterministic replay.
#[test]
fn auto_demotes_subtree_on_tap_rebind() {
    const N: u64 = 20_000;
    let cluster = ClusterConfig { nodes: 2, ranks_per_node: 4, ..ClusterConfig::minihpc() };
    let mk = || {
        let mut cfg = DesConfig::new(
            LoopParams::new(N, cluster.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cluster.clone(),
            IterationCost::Constant(1e-5),
        );
        cfg.delay = InjectedDelay::exponential_calculation(100e-6, 7);
        cfg.sched_path = SchedPath::Auto;
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss)
            .with_adaptive()
            .with_probe_interval(8)
            .with_candidates(CandidateSet::parse("ss,tap").unwrap());
        simulate(&cfg).unwrap()
    };
    let r = mk();
    verify_coverage(&r.sorted_assignments(), N).unwrap();
    assert!(r.fast_grants > 0, "the run started on the CAS path");
    assert!(
        r.switch_events.iter().any(|e| e.to == TechniqueKind::Tap),
        "a TAP rebind must have demoted a subtree: {:?}",
        r.switch_events
    );
    assert!(r.stats.messages > 0, "post-demotion grants travel as messages");
    let replay = mk();
    assert_eq!(r.assignments, replay.assignments, "demotion replay");
    assert_eq!(r.switch_events, replay.switch_events);
}

/// Pure `SchedPath::LockFree` + adaptivity: TAP is stripped from the
/// candidate set, so rebinds republish fresh tables and the leaf NEVER
/// demotes — every switch lands on a fast-path technique and CAS grants
/// keep flowing.
#[test]
fn lockfree_adaptive_rebinds_without_demoting() {
    const N: u64 = 20_000;
    let cluster = ClusterConfig { nodes: 2, ranks_per_node: 4, ..ClusterConfig::minihpc() };
    let mut cfg = DesConfig::new(
        LoopParams::new(N, cluster.total_ranks()),
        TechniqueKind::Fac2,
        ExecutionModel::HierDca,
        cluster,
        IterationCost::Constant(1e-5),
    );
    cfg.delay = InjectedDelay::exponential_calculation(100e-6, 7);
    cfg.sched_path = SchedPath::LockFree;
    cfg.hier = HierParams::with_inner(TechniqueKind::Ss)
        .with_adaptive()
        .with_probe_interval(8)
        .with_candidates(CandidateSet::parse("ss,tap,gss").unwrap());
    let r = simulate(&cfg).unwrap();
    verify_coverage(&r.sorted_assignments(), N).unwrap();
    assert!(r.fast_grants > 0);
    assert!(!r.switch_events.is_empty(), "overhead regime must trigger rebinds");
    assert!(
        r.switch_events.iter().all(|e| e.to.supports_fast_path()),
        "pure lock-free never rebinds to TAP: {:?}",
        r.switch_events
    );
}

/// The threaded engine under adaptivity: coverage and checksum stay exact
/// while the real master threads rebind their slots (timing-dependent, so
/// only structural properties are asserted).
#[test]
fn threaded_adaptive_covers_with_matching_checksum() {
    const N: u64 = 6_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 11));
    let reference = w.execute_range(0, N);
    let hier = HierParams::with_inner(TechniqueKind::Ss)
        .with_adaptive()
        .with_probe_interval(4)
        .with_candidates(CandidateSet::parse("ss,gss,fac").unwrap());
    let cfg = hier_engine(N, 4, 2, TechniqueKind::Fac2, hier);
    let r = run_covered(&cfg, &w, N, "threaded adaptive");
    assert_eq!(r.checksum, reference);
}

/// The threaded `SchedPath::Auto` engine with a TAP candidate in play:
/// starting from STATIC (the worst tail chunk) on a jittered workload, the
/// zero-overhead fast-path probe is imbalance-driven, so a TAP rebind —
/// and with it the freeze-and-demote machinery plus the hybrid worker
/// loop's post-demotion `Step → Commit` branch — is reachable on real
/// threads. Timing-dependent, so coverage and checksum are the hard
/// assertions; when switches fire they must all land on TAP.
#[test]
fn threaded_auto_adaptive_with_tap_candidate_covers() {
    const N: u64 = 6_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 23));
    let reference = w.execute_range(0, N);
    let hier = HierParams::with_inner(TechniqueKind::Static)
        .with_adaptive()
        .with_probe_interval(2)
        .with_candidates(CandidateSet::parse("static,tap").unwrap());
    let mut cfg = hier_engine(N, 4, 2, TechniqueKind::Fac2, hier);
    cfg.sched_path = SchedPath::Auto;
    let r = run_covered(&cfg, &w, N, "threaded auto adaptive");
    assert_eq!(r.checksum, reference);
    for e in &r.switch_events {
        assert_eq!(e.to, TechniqueKind::Tap, "only TAP is on offer: {e:?}");
    }
}

fn hier_engine(n: u64, p: u32, nodes: u32, outer: TechniqueKind, hier: HierParams) -> EngineConfig {
    let mut cfg = EngineConfig::new(LoopParams::new(n, p), outer, ExecutionModel::HierDca);
    cfg.nodes = nodes;
    cfg.hier = hier;
    cfg
}

fn run_covered(cfg: &EngineConfig, w: &Arc<dyn Workload>, n: u64, label: &str) -> RunResult {
    let r = coordinator::run(cfg, Arc::clone(w)).unwrap_or_else(|e| panic!("{label}: {e}"));
    verify_coverage(&r.sorted_assignments(), n).unwrap_or_else(|e| panic!("{label}: {e}"));
    r
}

/// Exact coverage + checksum for all 12 evaluated techniques as the outer
/// (and, by default, inner) technique on a 2×2 geometry — the threaded
/// analogue of `tests/hier_coverage.rs`.
#[test]
fn threaded_hier_covers_all_techniques_with_matching_checksum() {
    const N: u64 = 6_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 11));
    let reference = w.execute_range(0, N);
    for kind in TechniqueKind::EVALUATED {
        let cfg = hier_engine(N, 4, 2, kind, HierParams::default());
        let r = run_covered(&cfg, &w, N, kind.name());
        assert_eq!(r.checksum, reference, "{kind}: checksum");
        assert!(r.inter_node_messages > 0, "{kind}: outer protocol ran");
        assert!(r.intra_node_messages > 0, "{kind}: inner protocol ran");
        assert_eq!(r.stats.messages, r.intra_node_messages + r.inter_node_messages, "{kind}");
    }
}

/// A batched outer level with every inner technique (mixed pairs).
#[test]
fn threaded_hier_covers_mixed_inner_techniques() {
    const N: u64 = 5_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Uniform, 3));
    let reference = w.execute_range(0, N);
    for inner in TechniqueKind::EVALUATED {
        let cfg = hier_engine(N, 4, 2, TechniqueKind::Fac2, HierParams::with_inner(inner));
        let r = run_covered(&cfg, &w, N, &format!("FAC▸{inner}"));
        assert_eq!(r.checksum, reference, "FAC▸{inner}: checksum");
    }
}

/// Edge geometries: single-rank nodes (masters do everything), a single
/// node (the outer level degenerates), more ranks than iterations, and a
/// fully serial run.
#[test]
fn threaded_hier_edge_geometries() {
    let cases: [(u64, u32, u32, &str); 4] = [
        (2_000, 4, 4, "rpn=1 (masters compute everything)"),
        (2_000, 4, 1, "nodes=1 (degenerate outer level)"),
        (5, 8, 2, "N < P (more ranks than iterations)"),
        (1_000, 1, 1, "serial (one master, no workers)"),
    ];
    for (n, p, nodes, label) in cases {
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(n.max(64), 1e-7, CostShape::Uniform, 5));
        let reference = w.execute_range(0, n);
        let cfg = hier_engine(n, p, nodes, TechniqueKind::Gss, HierParams::default());
        let r = run_covered(&cfg, &w, n, label);
        assert_eq!(r.checksum, reference, "{label}: checksum");
        assert_eq!(r.per_rank.len(), p as usize, "{label}: one summary per rank");
    }
}

/// Prefetch mode on the threaded engine: still exact coverage and an
/// identical checksum (the staged-install path is exercised for real).
#[test]
fn threaded_hier_prefetch_covers() {
    const N: u64 = 4_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 23));
    let reference = w.execute_range(0, N);
    let hier = HierParams::with_inner(TechniqueKind::Ss).with_watermark(64);
    let cfg = hier_engine(N, 4, 2, TechniqueKind::Fac2, hier);
    let r = run_covered(&cfg, &w, N, "prefetch");
    assert_eq!(r.checksum, reference);
}

/// Block placement requires `nodes | P`.
#[test]
fn threaded_hier_rejects_indivisible_geometry() {
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(100, 1e-7, CostShape::Uniform, 1));
    let cfg = hier_engine(100, 4, 3, TechniqueKind::Gss, HierParams::default());
    let e = coordinator::run(&cfg, w).unwrap_err();
    assert!(e.to_string().contains("divide"), "{e}");
}

/// Cross-engine equivalence: on a fully serial geometry (1 node × 1 rank)
/// both engines are deterministic, and because they drive the *same*
/// `hier::protocol` ledger, the granted `(step, start, size)` sequences
/// must be identical for every closed-form technique. (AF is excluded: its
/// sizes depend on measured wall-clock timings by design.)
#[test]
fn threaded_and_des_hier_grant_identical_serial_schedules() {
    const N: u64 = 3_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-8, CostShape::Uniform, 9));
    for kind in TechniqueKind::ALL {
        if kind == TechniqueKind::Af {
            continue;
        }
        let cfg = hier_engine(N, 1, 1, kind, HierParams::default());
        let threaded = run_covered(&cfg, &w, N, kind.name());

        let cluster = ClusterConfig { nodes: 1, ranks_per_node: 1, ..ClusterConfig::minihpc() };
        let des_cfg = DesConfig {
            technique: kind,
            model: ExecutionModel::HierDca,
            cluster,
            ..DesConfig::for_test(N, 1)
        };
        let des = simulate(&des_cfg).unwrap_or_else(|e| panic!("{kind}: {e}"));
        let mut des_sorted: Vec<Assignment> = des.assignments.clone();
        des_sorted.sort_by_key(|a| a.start);
        assert_eq!(
            threaded.sorted_assignments(),
            des_sorted,
            "{kind}: serial schedules must be identical across engines"
        );
    }
}

/// The outer-prefetch payoff, asserted deterministically on the DES (which
/// shares the ledger with the threaded engine): with an expensive
/// inter-node fabric, prefetching the next node-chunk below a watermark
/// must strictly reduce both the total scheduling wait and `T_par`
/// compared to fetch-on-exhaustion.
#[test]
fn prefetch_beats_fetch_on_exhaustion() {
    const N: u64 = 20_000;
    let cluster = ClusterConfig {
        nodes: 4,
        ranks_per_node: 4,
        inter_node_latency: 200e-6, // make the outer round trip expensive
        ..ClusterConfig::minihpc()
    };
    let mk = |hier: HierParams| {
        let cfg = DesConfig {
            hier,
            ..DesConfig::new(
                LoopParams::new(N, cluster.total_ranks()),
                TechniqueKind::Fac2,
                ExecutionModel::HierDca,
                cluster.clone(),
                IterationCost::Constant(2e-5),
            )
        };
        let r = simulate(&cfg).unwrap();
        let mut sorted = r.assignments.clone();
        sorted.sort_by_key(|a| a.start);
        verify_coverage(&sorted, N).unwrap();
        r
    };
    let inner = HierParams::with_inner(TechniqueKind::Ss);
    let exhaust = mk(inner);
    let prefetch = mk(inner.with_watermark(256));
    assert!(
        prefetch.stats.sched_overhead < exhaust.stats.sched_overhead,
        "prefetch sched wait {} must beat fetch-on-exhaustion {}",
        prefetch.stats.sched_overhead,
        exhaust.stats.sched_overhead
    );
    assert!(
        prefetch.t_par() < exhaust.t_par(),
        "prefetch T_par {} must beat fetch-on-exhaustion {}",
        prefetch.t_par(),
        exhaust.t_par()
    );
}

/// The threaded engine's lock-free leaf level: exact coverage and matching
/// checksums for every fast-path technique, with CAS grants happening and
/// the leaf message traffic collapsing.
#[test]
fn threaded_lockfree_leaf_covers_with_matching_checksum() {
    const N: u64 = 6_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 11));
    let reference = w.execute_range(0, N);
    for kind in TechniqueKind::EVALUATED {
        let cfg = hier_engine(N, 4, 2, kind, HierParams::default()).with_lockfree();
        let r = run_covered(&cfg, &w, N, kind.name());
        assert_eq!(r.checksum, reference, "{kind}: checksum");
        if kind.supports_fast_path() {
            assert!(r.fast_grants > 0, "{kind}: leaf grants took the CAS path");
        } else {
            assert_eq!(r.fast_grants, 0, "{kind}: AF/TAP fall back to two-phase");
            assert!(r.intra_node_messages > 0, "{kind}: two-phase leaf protocol ran");
        }
        assert!(r.inter_node_messages > 0, "{kind}: outer protocol stays two-phase");
    }
}

/// Threaded lock-free + fixed-watermark prefetch: the worker-side Nudge
/// path (the master cannot observe CAS grants) keeps coverage and checksum
/// exact.
#[test]
fn threaded_lockfree_prefetch_nudge_covers() {
    const N: u64 = 4_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Jittered, 23));
    let reference = w.execute_range(0, N);
    let hier = HierParams::with_inner(TechniqueKind::Ss).with_watermark(64);
    let cfg = hier_engine(N, 4, 2, TechniqueKind::Fac2, hier).with_lockfree();
    let r = run_covered(&cfg, &w, N, "lockfree prefetch");
    assert_eq!(r.checksum, reference);
    assert!(r.fast_grants > 0);
}

/// Lock-free edge geometries: single-rank groups (masters CAS for
/// themselves), one node, N < P, and fully serial.
#[test]
fn threaded_lockfree_edge_geometries() {
    let cases: [(u64, u32, u32, &str); 4] = [
        (2_000, 4, 4, "rpn=1 (masters CAS everything)"),
        (2_000, 4, 1, "nodes=1 (degenerate outer level)"),
        (5, 8, 2, "N < P (more ranks than iterations)"),
        (1_000, 1, 1, "serial (one master, no workers)"),
    ];
    for (n, p, nodes, label) in cases {
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(n.max(64), 1e-7, CostShape::Uniform, 5));
        let reference = w.execute_range(0, n);
        let cfg = hier_engine(n, p, nodes, TechniqueKind::Gss, HierParams::default())
            .with_lockfree();
        let r = run_covered(&cfg, &w, n, label);
        assert_eq!(r.checksum, reference, "{label}: checksum");
        assert!(r.fast_grants > 0, "{label}: CAS grants happened");
    }
}

/// Cross-engine equivalence on the lock-free path: on the fully serial
/// geometry both engines are deterministic, and because the threaded CAS
/// loop walks the same precomputed table the DES's fused grants replay,
/// the serial schedules must be identical (the two-phase twin of this test
/// is `threaded_and_des_hier_grant_identical_serial_schedules`).
#[test]
fn threaded_and_des_lockfree_grant_identical_serial_schedules() {
    const N: u64 = 3_000;
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-8, CostShape::Uniform, 9));
    for kind in TechniqueKind::ALL {
        if kind == TechniqueKind::Af {
            continue;
        }
        let cfg = hier_engine(N, 1, 1, kind, HierParams::default()).with_lockfree();
        let threaded = run_covered(&cfg, &w, N, kind.name());

        let cluster = ClusterConfig { nodes: 1, ranks_per_node: 1, ..ClusterConfig::minihpc() };
        let mut des_cfg = DesConfig::new(
            LoopParams::new(N, 1),
            kind,
            ExecutionModel::HierDca,
            cluster,
            IterationCost::Constant(1e-6),
        );
        des_cfg.sched_path = SchedPath::LockFree;
        let des = simulate(&des_cfg).unwrap_or_else(|e| panic!("{kind}: {e}"));
        assert_eq!(
            threaded.sorted_assignments(),
            des.sorted_assignments(),
            "{kind}: lock-free serial schedules must be identical across engines"
        );
    }
}

/// Prefetch keeps exact coverage across the full technique matrix on the
/// DES (staging + stale-`seq` NACK interplay under every chunk pattern).
#[test]
fn prefetch_covers_all_techniques_des() {
    const N: u64 = 4_000;
    let cluster = ClusterConfig { nodes: 2, ranks_per_node: 4, ..ClusterConfig::minihpc() };
    for kind in TechniqueKind::EVALUATED {
        let cfg = DesConfig {
            delay: InjectedDelay::calculation_only(10e-6),
            hier: HierParams::default().with_watermark(64),
            ..DesConfig::new(
                LoopParams::new(N, cluster.total_ranks()),
                kind,
                ExecutionModel::HierDca,
                cluster.clone(),
                IterationCost::Constant(1e-5),
            )
        };
        let r = simulate(&cfg).unwrap_or_else(|e| panic!("{kind}: {e}"));
        let mut sorted = r.assignments.clone();
        sorted.sort_by_key(|a| a.start);
        verify_coverage(&sorted, N).unwrap_or_else(|e| panic!("{kind}: {e}"));
    }
}
