//! Failure injection and hostile configurations: the library must either
//! work or reject loudly — never hang, overlap, or drop iterations.

use std::sync::Arc;

use dca_dls::config::{ClusterConfig, DelaySite, ExecutionModel, HierParams};
use dca_dls::coordinator::{self, EngineConfig};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::report::figures::{run_figure, App, FigureConfig};
use dca_dls::sched::verify_coverage;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::workload::synthetic::{CostShape, Synthetic};
use dca_dls::workload::{IterationCost, Workload};

/// A hierarchical variant of [`DesConfig::for_test`] on a `nodes × rpn`
/// miniHPC-latency geometry.
fn hier_des(n: u64, nodes: u32, rpn: u32) -> DesConfig {
    DesConfig {
        model: ExecutionModel::HierDca,
        cluster: ClusterConfig { nodes, ranks_per_node: rpn, ..ClusterConfig::minihpc() },
        ..DesConfig::for_test(n, nodes * rpn)
    }
}

#[test]
fn des_more_ranks_than_iterations() {
    let mut cfg = DesConfig::for_test(5, 32);
    for model in [ExecutionModel::Cca, ExecutionModel::Dca, ExecutionModel::DcaRma] {
        cfg.model = model;
        let r = simulate(&cfg).unwrap();
        let mut a = r.assignments.clone();
        a.sort_by_key(|x| x.start);
        verify_coverage(&a, 5).unwrap();
    }
}

#[test]
fn des_single_iteration_single_rank() {
    let r = simulate(&DesConfig::for_test(1, 1)).unwrap();
    assert_eq!(r.assignments.len(), 1);
    assert_eq!(r.assignments[0].size, 1);
}

#[test]
fn hier_more_ranks_than_iterations() {
    // 32 ranks chasing 5 iterations through a two-level tree: most node
    // masters receive nothing, every level must still drain cleanly.
    let r = simulate(&hier_des(5, 4, 8)).unwrap();
    verify_coverage(&r.sorted_assignments(), 5).unwrap();
}

#[test]
fn hier_single_iteration_any_depth() {
    // N=1: exactly one master wins the only chunk — at depth 2 and with a
    // third tree level stacked on top.
    let r = simulate(&hier_des(1, 4, 4)).unwrap();
    verify_coverage(&r.sorted_assignments(), 1).unwrap();
    assert_eq!(r.assignments.len(), 1);
    let mut deep = hier_des(1, 4, 4);
    deep.hier = HierParams::default().with_levels(3).with_fanouts(&[2, 2, 4]);
    let r = simulate(&deep).unwrap();
    verify_coverage(&r.sorted_assignments(), 1).unwrap();
}

#[test]
fn hier_single_rank_cluster() {
    // One rank IS the whole tree: coordinator, node master and worker
    // collapse onto rank 0 (which computes, breakAfter > 0).
    let r = simulate(&hier_des(100, 1, 1)).unwrap();
    verify_coverage(&r.sorted_assignments(), 100).unwrap();
}

#[test]
fn hier_zero_cost_iterations_deep_tree() {
    // Zero-cost iterations collapse all execution onto identical
    // timestamps; scheduling must stay deterministic and exact at depth 2
    // and depth 3 (FIFO event ordering, not time, is the tiebreak).
    for levels in [2u32, 3] {
        let mut cfg = hier_des(2_000, 4, 4);
        cfg.cost = IterationCost::Constant(0.0);
        if levels == 3 {
            cfg.hier = HierParams::default().with_levels(3).with_fanouts(&[2, 2, 4]);
        }
        let a = simulate(&cfg).unwrap_or_else(|e| panic!("depth {levels}: {e}"));
        verify_coverage(&a.sorted_assignments(), 2_000)
            .unwrap_or_else(|e| panic!("depth {levels}: {e}"));
        let b = simulate(&cfg).unwrap();
        assert_eq!(a.assignments, b.assignments, "depth {levels}: replay drifted");
    }
}

#[test]
fn des_extreme_slowdown_still_terminates() {
    let mut cfg = DesConfig::for_test(500, 8);
    // 50 ms each!
    cfg.delay = InjectedDelay { calculation: 0.05, assignment: 0.05, ..InjectedDelay::none() };
    for model in [ExecutionModel::Cca, ExecutionModel::Dca] {
        cfg.model = model;
        let r = simulate(&cfg).unwrap();
        let mut a = r.assignments.clone();
        a.sort_by_key(|x| x.start);
        verify_coverage(&a, 500).unwrap();
        assert!(r.t_par() > 0.0);
    }
}

#[test]
fn des_heterogeneous_speeds() {
    // One PE 10× slower: non-adaptive DLS can't fully compensate (FAC2's
    // first-batch chunk on the slow PE is a fixed cost), but self-scheduling
    // must still roughly halve STATIC's makespan (the floor is FAC2's
    // first-batch chunk on the slow PE: 3125 iters at 10×).
    let run = |tech| {
        let mut cfg = DesConfig::for_test(50_000, 8);
        cfg.technique = tech;
        cfg.pe_speed = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.1];
        simulate(&cfg).unwrap()
    };
    let dls = run(TechniqueKind::Fac2);
    let stat = run(TechniqueKind::Static);
    assert!(
        dls.t_par() < stat.t_par() * 0.55,
        "FAC2 ({:.3}s) must beat STATIC ({:.3}s) under a 10x-slow PE",
        dls.t_par(),
        stat.t_par()
    );
    // And with min-size (SS-like) chunks the imbalance nearly vanishes.
    let ss = run(TechniqueKind::Ss);
    assert!(ss.stats.imbalance < 0.1, "SS imbalance {:.3}", ss.stats.imbalance);
}

#[test]
fn des_master_slowdown_scenario() {
    // The paper's motivating story: slow the MASTER's CPU only. CCA suffers
    // (all calculations serialized on the slow PE); DCA's coordinator only
    // bumps counters so it suffers far less.
    let mut speeds = vec![1.0; 64];
    speeds[0] = 0.25; // master/coordinator 4× slower
    let mk = |model| {
        let cfg = DesConfig {
            technique: TechniqueKind::Ss, // maximal scheduling traffic
            model,
            delay: InjectedDelay::calculation_only(100e-6),
            cluster: ClusterConfig { nodes: 4, ranks_per_node: 16, ..ClusterConfig::minihpc() },
            cost: IterationCost::Constant(0.002),
            pe_speed: speeds.clone(),
            ..DesConfig::for_test(65_536, 64)
        };
        simulate(&cfg).unwrap().t_par()
    };
    let cca = mk(ExecutionModel::Cca);
    let dca = mk(ExecutionModel::Dca);
    assert!(
        cca > dca,
        "slow master must hurt CCA ({cca:.2}s) more than DCA ({dca:.2}s)"
    );
}

#[test]
fn engine_zero_size_loop_rejected() {
    // LoopParams::new refuses n=0 by assertion.
    let r = std::panic::catch_unwind(|| LoopParams::new(0, 4));
    assert!(r.is_err());
}

#[test]
fn engine_more_workers_than_iterations() {
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(3, 1e-7, CostShape::Uniform, 1));
    for model in [ExecutionModel::Cca, ExecutionModel::Dca, ExecutionModel::DcaRma] {
        let cfg = EngineConfig::new(LoopParams::new(3, 8), TechniqueKind::Gss, model);
        let r = coordinator::run(&cfg, Arc::clone(&w)).unwrap();
        verify_coverage(&r.sorted_assignments(), 3).unwrap();
    }
}

#[test]
fn figure_runner_skips_af_rma_and_completes() {
    let mut cfg = FigureConfig::quick(App::Psia);
    cfg.techniques = vec![TechniqueKind::Af];
    cfg.models = vec![ExecutionModel::Dca, ExecutionModel::DcaRma];
    cfg.delays = vec![0.0];
    cfg.reps = 1;
    let rows = run_figure(&cfg).unwrap();
    // AF × DCA-RMA skipped; AF × DCA present.
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].model, ExecutionModel::Dca);
}

#[test]
fn assignment_site_delay_runs_everywhere() {
    let mut cfg = FigureConfig::quick(App::Psia);
    cfg.techniques = vec![TechniqueKind::Tss];
    cfg.delays = vec![100e-6];
    cfg.delay_site = DelaySite::Assignment;
    cfg.reps = 1;
    let rows = run_figure(&cfg).unwrap();
    assert_eq!(rows.len(), 2);
    for r in rows {
        assert!(r.runs.t_par_mean > 0.0);
    }
}

#[test]
fn des_rejects_af_on_rma() {
    let mut cfg = DesConfig::for_test(100, 4);
    cfg.technique = TechniqueKind::Af;
    cfg.model = ExecutionModel::DcaRma;
    assert!(simulate(&cfg).is_err());
}

#[test]
fn des_rejects_rank_mismatch() {
    let mut cfg = DesConfig::for_test(100, 4);
    cfg.params = LoopParams::new(100, 8); // ≠ cluster ranks
    assert!(simulate(&cfg).is_err());
}
