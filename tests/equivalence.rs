//! Property tests over the §4 transformations: for every technique with a
//! closed form, the straightforward (DCA) and recursive (CCA) formulas must
//! schedule the same loop — *exactly* step-for-step where the math is exact
//! (TSS, FISS, STATIC, SS, FSC, RND, TFSS), and with full coverage plus
//! bounded drift where iterated ceilings legitimately diverge (GSS, TAP,
//! FAC2, VISS, PLS).
//!
//! Randomized sweeps use a seeded SplitMix64 — no external proptest crate is
//! available in this build environment, so the harness is hand-rolled but
//! exhaustive-by-seed and fully reproducible.

use dca_dls::sched::{closed_form_schedule, recursive_schedule, verify_coverage};
use dca_dls::techniques::{rnd::splitmix64, LoopParams, Technique, TechniqueKind};

/// Deterministic (n, p) sample space: n ∈ [1, 500k], p ∈ [1, 512].
fn cases(seed: u64, count: usize) -> Vec<(u64, u32)> {
    let mut s = seed;
    (0..count)
        .map(|_| {
            s = splitmix64(s);
            let n = 1 + s % 500_000;
            s = splitmix64(s);
            let p = 1 + (s % 512) as u32;
            (n, p)
        })
        .collect()
}

/// Techniques whose two forms are mathematically identical step-for-step.
const EXACT: [TechniqueKind; 6] = [
    TechniqueKind::Static,
    TechniqueKind::Ss,
    TechniqueKind::Fsc,
    TechniqueKind::Tss,
    TechniqueKind::Fiss,
    TechniqueKind::Rnd,
];

/// Techniques where iterated ceilings drift but coverage must hold.
const DRIFTING: [TechniqueKind; 6] = [
    TechniqueKind::Gss,
    TechniqueKind::Tap,
    TechniqueKind::Fac2,
    TechniqueKind::Tfss,
    TechniqueKind::Viss,
    TechniqueKind::Pls,
];

#[test]
fn exact_forms_agree_step_for_step() {
    for (n, p) in cases(0xE9_0001, 60) {
        let params = LoopParams::new(n, p);
        for kind in EXACT {
            let t = Technique::new(kind, &params);
            let closed = closed_form_schedule(&t, &params);
            let recursive = recursive_schedule(&t, &params);
            assert_eq!(
                closed, recursive,
                "{kind} at (n={n}, p={p}): forms must be identical"
            );
        }
    }
}

#[test]
fn drifting_forms_both_cover_exactly() {
    for (n, p) in cases(0xE9_0002, 60) {
        let params = LoopParams::new(n, p);
        for kind in DRIFTING {
            let t = Technique::new(kind, &params);
            let closed = closed_form_schedule(&t, &params);
            let recursive = recursive_schedule(&t, &params);
            verify_coverage(&closed, n)
                .unwrap_or_else(|e| panic!("{kind} closed (n={n},p={p}): {e}"));
            verify_coverage(&recursive, n)
                .unwrap_or_else(|e| panic!("{kind} recursive (n={n},p={p}): {e}"));
        }
    }
}

#[test]
fn gss_drift_is_bounded() {
    // Closed ⌈qⁱ·N/P⌉ vs iterated ⌈R/P⌉ differ by at most a few iterations
    // per step — never by a whole batch.
    for (n, p) in cases(0xE9_0003, 30) {
        if n < p as u64 * 4 {
            continue;
        }
        let params = LoopParams::new(n, p);
        let t = Technique::new(TechniqueKind::Gss, &params);
        let closed = closed_form_schedule(&t, &params);
        let recursive = recursive_schedule(&t, &params);
        let steps = closed.len().min(recursive.len());
        for i in 0..steps / 2 {
            let a = closed[i].size as i64;
            let b = recursive[i].size as i64;
            let bound = 2 + i as i64; // drift accumulates ≤ 1/step
            assert!(
                (a - b).abs() <= bound,
                "GSS (n={n},p={p}) step {i}: closed {a} vs recursive {b}"
            );
        }
    }
}

#[test]
fn decreasing_techniques_decrease_in_both_forms() {
    for (n, p) in cases(0xE9_0004, 25) {
        let params = LoopParams::new(n, p);
        for kind in [TechniqueKind::Gss, TechniqueKind::Tss, TechniqueKind::Tfss] {
            let t = Technique::new(kind, &params);
            for schedule in [closed_form_schedule(&t, &params), recursive_schedule(&t, &params)] {
                // Ignore the final clipped chunk.
                let sizes: Vec<u64> = schedule.iter().map(|a| a.size).collect();
                let inner = &sizes[..sizes.len().saturating_sub(1)];
                assert!(
                    inner.windows(2).all(|w| w[0] >= w[1]),
                    "{kind} (n={n},p={p}): must be non-increasing: {sizes:?}"
                );
            }
        }
    }
}

#[test]
fn chunk_counts_comparable_between_forms() {
    // The drift must not change the schedule's *scale*: chunk counts of the
    // two forms stay within 2× of each other.
    for (n, p) in cases(0xE9_0005, 40) {
        let params = LoopParams::new(n, p);
        for kind in DRIFTING {
            let t = Technique::new(kind, &params);
            let c = closed_form_schedule(&t, &params).len() as f64;
            let r = recursive_schedule(&t, &params).len() as f64;
            assert!(
                c / r < 2.0 && r / c < 2.0,
                "{kind} (n={n},p={p}): counts {c} vs {r}"
            );
        }
    }
}

#[test]
fn extreme_geometries() {
    // n=1, p=1, p>n, p=n — every technique must still cover.
    for (n, p) in [(1u64, 1u32), (1, 64), (7, 64), (64, 64), (65, 64), (1000, 1)] {
        let params = LoopParams::new(n, p);
        for kind in TechniqueKind::ALL {
            if !kind.has_closed_form() {
                continue;
            }
            let t = Technique::new(kind, &params);
            verify_coverage(&closed_form_schedule(&t, &params), n)
                .unwrap_or_else(|e| panic!("{kind} closed (n={n},p={p}): {e}"));
            verify_coverage(&recursive_schedule(&t, &params), n)
                .unwrap_or_else(|e| panic!("{kind} recursive (n={n},p={p}): {e}"));
        }
    }
}

#[test]
fn min_chunk_respected_everywhere() {
    for min_chunk in [1u64, 2, 5, 17] {
        let mut params = LoopParams::new(10_000, 16);
        params.min_chunk = min_chunk;
        for kind in TechniqueKind::ALL {
            if !kind.has_closed_form() {
                continue;
            }
            let t = Technique::new(kind, &params);
            let schedule = closed_form_schedule(&t, &params);
            verify_coverage(&schedule, 10_000).unwrap();
            // All chunks except possibly the last meet the minimum.
            for a in &schedule[..schedule.len() - 1] {
                assert!(
                    a.size >= min_chunk,
                    "{kind} min_chunk={min_chunk}: chunk of {} at step {}",
                    a.size,
                    a.step
                );
            }
        }
    }
}
