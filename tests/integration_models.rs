//! Cross-model integration: the DES, the three threaded engines, and the
//! LB4MPI facade must agree with each other on what gets scheduled.

use std::sync::Arc;
use std::thread;

use dca_dls::config::ExecutionModel;
use dca_dls::coordinator::{self, EngineConfig};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::lb4mpi::*;
use dca_dls::sched::verify_coverage;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::workload::synthetic::{CostShape, Synthetic};
use dca_dls::workload::{IterationCost, Workload};

const N: u64 = 8_192;
const P: u32 = 4;

fn des_chunk_multiset(model: ExecutionModel, kind: TechniqueKind) -> Vec<u64> {
    let cfg = DesConfig {
        technique: kind,
        model,
        cost: IterationCost::Constant(1e-5),
        ..DesConfig::for_test(N, P)
    };
    let r = simulate(&cfg).unwrap();
    let mut v: Vec<u64> = r.assignments.iter().map(|a| a.size).collect();
    v.sort_unstable();
    v
}

fn engine_chunk_multiset(model: ExecutionModel, kind: TechniqueKind) -> Vec<u64> {
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Uniform, 5));
    let cfg = EngineConfig::new(LoopParams::new(N, P), kind, model);
    let r = coordinator::run(&cfg, w).unwrap();
    let mut v: Vec<u64> = r.sorted_assignments().iter().map(|a| a.size).collect();
    v.sort_unstable();
    v
}

/// The DES and the real engine run the same protocols; what must agree:
///
/// * **CCA** — the master serializes calculation+assignment, so the chunk
///   multiset is fully deterministic: DES ≡ engine exactly.
/// * **DCA** — sizes are per-step deterministic but end-of-loop clipping
///   depends on *commit order*, which real threads race on: totals and
///   non-tail chunks must agree; the clipped tail may shuffle.
#[test]
fn des_and_engine_agree_on_deterministic_schedules() {
    for kind in [TechniqueKind::Static, TechniqueKind::Fsc, TechniqueKind::Tss] {
        let des = des_chunk_multiset(ExecutionModel::Cca, kind);
        let eng = engine_chunk_multiset(ExecutionModel::Cca, kind);
        assert_eq!(des, eng, "{kind} Cca");

        let des = des_chunk_multiset(ExecutionModel::Dca, kind);
        let eng = engine_chunk_multiset(ExecutionModel::Dca, kind);
        assert_eq!(des.iter().sum::<u64>(), eng.iter().sum::<u64>(), "{kind} Dca total");
        // Multisets agree on everything above the clip region (sorted
        // ascending ⇒ the racy clipped chunks sort first; chunk counts may
        // differ by a ticket or two, so compare the common suffix).
        let body = des.len().min(eng.len()).saturating_sub(P as usize + 2);
        assert_eq!(
            des[des.len() - body..],
            eng[eng.len() - body..],
            "{kind} Dca body"
        );
    }
}

/// CCA in the DES and the LB4MPI facade evaluate the same recursive
/// formulas; with a single rank both are fully sequential ⇒ identical
/// schedules even for order-dependent techniques.
#[test]
fn single_rank_lb4mpi_matches_des_cca() {
    for kind in [TechniqueKind::Gss, TechniqueKind::Fac2, TechniqueKind::Viss] {
        let des = des_chunk_multiset_1rank(kind);
        let fac = lb4mpi_chunks_1rank(kind);
        assert_eq!(des, fac, "{kind}");
    }
}

fn des_chunk_multiset_1rank(kind: TechniqueKind) -> Vec<u64> {
    let cfg = DesConfig {
        technique: kind,
        model: ExecutionModel::Cca,
        ..DesConfig::for_test(N, 1)
    };
    let r = simulate(&cfg).unwrap();
    r.assignments.iter().map(|a| a.size).collect()
}

fn lb4mpi_chunks_1rank(kind: TechniqueKind) -> Vec<u64> {
    let mut infos = dls_parameters_setup(1, InjectedDelay::none());
    let params = LoopParams::new(N, 1);
    let info = &mut infos[0];
    dls_start_loop(info, &params, kind);
    let mut out = vec![];
    while !dls_terminated(info) {
        if let Some((_s, size)) = dls_start_chunk(info) {
            out.push(size);
            dls_end_chunk(info);
        }
    }
    dls_end_loop(info);
    out
}

/// All three engines compute identical checksums for all techniques.
#[test]
fn engines_checksum_identical() {
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 1e-7, CostShape::Bimodal {
        spike_ratio: 8.0,
        spike_frac: 0.1,
    }, 99));
    let reference = w.execute_range(0, N);
    for kind in [TechniqueKind::Gss, TechniqueKind::Af, TechniqueKind::Rnd] {
        for model in [ExecutionModel::Cca, ExecutionModel::Dca, ExecutionModel::DcaRma] {
            if kind == TechniqueKind::Af && model == ExecutionModel::DcaRma {
                continue;
            }
            let cfg = EngineConfig::new(LoopParams::new(N, P), kind, model);
            let r = coordinator::run(&cfg, Arc::clone(&w)).unwrap();
            assert_eq!(r.checksum, reference, "{kind} {model:?}");
            verify_coverage(&r.sorted_assignments(), N).unwrap();
        }
    }
}

/// LB4MPI threads under both modes cover the loop with injected delays on.
#[test]
fn lb4mpi_with_delays_covers() {
    for mode in [CalcMode::Centralized, CalcMode::Decentralized] {
        let mut infos = dls_parameters_setup(P, InjectedDelay::calculation_only(20e-6));
        configure_chunk_calculation_mode(&infos[0], mode);
        let params = LoopParams::new(2_000, P);
        let handles: Vec<_> = infos
            .drain(..)
            .map(|mut info| {
                let params = params.clone();
                thread::spawn(move || {
                    dls_start_loop(&mut info, &params, TechniqueKind::Gss);
                    let mut ranges = vec![];
                    while !dls_terminated(&info) {
                        if let Some((start, size)) = dls_start_chunk(&mut info) {
                            ranges.push((start, size));
                            dls_end_chunk(&mut info);
                        }
                    }
                    dls_end_loop(&mut info);
                    ranges
                })
            })
            .collect();
        let mut all: Vec<(u64, u64)> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        let mut cursor = 0;
        for (start, size) in all {
            assert_eq!(start, cursor, "{mode:?}: gap/overlap at {start}");
            cursor = start + size;
        }
        assert_eq!(cursor, 2_000, "{mode:?}");
    }
}

/// Injected calculation delay hurts the threaded CCA engine measurably more
/// than DCA when chunks are fine — the paper's claim validated on real
/// threads with real spinning, not just the DES.
#[test]
fn real_threads_show_the_paper_effect() {
    let w: Arc<dyn Workload> = Arc::new(Synthetic::new(3_000, 1e-6, CostShape::Uniform, 5));
    let run = |model, d| {
        let mut cfg = EngineConfig::new(LoopParams::new(3_000, P), TechniqueKind::Ss, model);
        cfg.delay = InjectedDelay::calculation_only(d);
        coordinator::run(&cfg, Arc::clone(&w)).unwrap().stats.t_par
    };
    // Medians over repeats to tame scheduler noise.
    let med = |model, d| {
        let mut xs: Vec<f64> = (0..5).map(|_| run(model, d)).collect();
        xs.sort_by(f64::total_cmp);
        xs[2]
    };
    let cca = med(ExecutionModel::Cca, 50e-6) / med(ExecutionModel::Cca, 0.0);
    let dca = med(ExecutionModel::Dca, 50e-6) / med(ExecutionModel::Dca, 0.0);
    // 3000 chunks × 50µs serialized ≈ 150ms on a ~few-ms loop: CCA must blow
    // up; DCA pays the delay in parallel.
    assert!(
        cca > dca,
        "CCA degradation ({cca:.2}x) must exceed DCA ({dca:.2}x) on real threads"
    );
}
