//! Golden tests pinning the paper's **Table 2** exactly: the chunk-size
//! sequence of every technique with a closed form at (N=1000, P=4, Table 2
//! parameters), as produced by the straightforward/DCA formulas.

use dca_dls::sched::{closed_form_schedule, verify_coverage};
use dca_dls::techniques::{LoopParams, Technique, TechniqueKind};

fn sizes(kind: TechniqueKind) -> Vec<u64> {
    let params = LoopParams::new(1000, 4);
    let t = Technique::new(kind, &params);
    let s = closed_form_schedule(&t, &params);
    verify_coverage(&s, 1000).unwrap();
    s.iter().map(|a| a.size).collect()
}

#[test]
fn static_row() {
    assert_eq!(sizes(TechniqueKind::Static), vec![250; 4]);
}

#[test]
fn ss_row() {
    let s = sizes(TechniqueKind::Ss);
    assert_eq!(s.len(), 1000);
    assert!(s.iter().all(|&k| k == 1));
}

#[test]
fn fsc_row() {
    // Table 2: 59 chunks of 17, last 14.
    let s = sizes(TechniqueKind::Fsc);
    assert_eq!(s.len(), 59);
    assert!(s[..58].iter().all(|&k| k == 17));
    assert_eq!(s[58], 14);
}

#[test]
fn gss_row() {
    assert_eq!(
        sizes(TechniqueKind::Gss),
        vec![250, 188, 141, 106, 80, 60, 45, 34, 26, 19, 15, 11, 8, 6, 5, 4, 2]
    );
}

#[test]
fn tap_row_head() {
    // With the paper's (µ=0.1, σ=0.0005, α=0.0605), v_α≈3·10⁻⁴ barely
    // perturbs GSS; Table 2's head matches (the printed tail "…5,3,3" is not
    // reproducible from Eq. 16 with these parameters — see EXPERIMENTS.md).
    let s = sizes(TechniqueKind::Tap);
    assert_eq!(&s[..15], &[250, 188, 141, 106, 80, 60, 45, 34, 26, 19, 15, 11, 8, 6, 5]);
}

#[test]
fn tss_row() {
    assert_eq!(
        sizes(TechniqueKind::Tss),
        vec![125, 117, 109, 101, 93, 85, 77, 69, 61, 53, 45, 37, 28]
    );
}

#[test]
fn fac_row() {
    let expect: Vec<u64> = [125u64, 63, 32, 16, 8, 4, 2]
        .iter()
        .flat_map(|&k| std::iter::repeat(k).take(4))
        .collect();
    assert_eq!(sizes(TechniqueKind::Fac2), expect);
    assert_eq!(sizes(TechniqueKind::Fac2).len(), 28);
}

#[test]
fn tfss_row() {
    assert_eq!(
        sizes(TechniqueKind::Tfss),
        vec![113, 113, 113, 113, 81, 81, 81, 81, 49, 49, 49, 49, 17, 11]
    );
}

#[test]
fn fiss_row() {
    assert_eq!(
        sizes(TechniqueKind::Fiss),
        vec![50, 50, 50, 50, 83, 83, 83, 83, 116, 116, 116, 116, 4]
    );
}

#[test]
fn viss_row() {
    assert_eq!(
        sizes(TechniqueKind::Viss),
        vec![62, 62, 62, 62, 93, 93, 93, 93, 108, 108, 108, 56]
    );
}

#[test]
fn pls_row() {
    assert_eq!(
        sizes(TechniqueKind::Pls),
        vec![175, 175, 175, 175, 75, 57, 43, 32, 24, 18, 14, 11, 8, 6, 5, 4, 3]
    );
}

#[test]
fn rnd_row_properties() {
    // RND is seeded; pin its *properties*: bounds and coverage.
    let s = sizes(TechniqueKind::Rnd);
    assert!(s.iter().all(|&k| (1..=250).contains(&k)));
    assert_eq!(s.iter().sum::<u64>(), 1000);
}

#[test]
fn chunk_counts_match_table2() {
    // The "Total number of chunks" column for deterministic techniques.
    for (kind, count) in [
        (TechniqueKind::Static, 4),
        (TechniqueKind::Ss, 1000),
        (TechniqueKind::Fsc, 59),
        (TechniqueKind::Gss, 17),
        (TechniqueKind::Tap, 17),
        (TechniqueKind::Tss, 13),
        (TechniqueKind::Fac2, 28),
        (TechniqueKind::Tfss, 14),
        (TechniqueKind::Fiss, 13),
        (TechniqueKind::Viss, 12),
        (TechniqueKind::Pls, 17),
    ] {
        assert_eq!(sizes(kind).len(), count, "{kind}");
    }
}
