//! Multi-tenant session tests (scheduler-as-a-service, PR 6).
//!
//! The three load-bearing properties:
//!
//! 1. **Single-tenant bit-identity** — a one-tenant session over the whole
//!    cluster reproduces [`dca_dls::des::simulate`]'s flat DCA run *exactly*
//!    (t_par, finish vector, assignments, message/event counts) on both the
//!    two-phase and lock-free paths: the arbitration layer costs nothing
//!    when there is nothing to arbitrate.
//! 2. **Fair-share tightness** — K identical tenants under fair share stay
//!    within one chunk of each other at every grant (probe point) when
//!    requests are serialized, and within an in-flight-bounded envelope on
//!    a parallel cluster.
//! 3. **The acceptance scenario** — 100+ seeded tenants over the shared
//!    256-rank cluster: deterministic, per-tenant coverage exact, and no
//!    rank ever executes two tenants' iterations at overlapping instants.

use dca_dls::config::{ClusterConfig, ExecutionModel, SchedPath};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::sched::verify_coverage;
use dca_dls::techniques::{rnd::splitmix64, LoopParams, TechniqueKind};
use dca_dls::tenant::{
    simulate_session, ArbitrationPolicy, SessionConfig, TenantSpec, TenantState,
};
use dca_dls::workload::IterationCost;

/// Techniques admitted to sessions that also support the CAS fast path.
const TECHS: [TechniqueKind; 5] = [
    TechniqueKind::Ss,
    TechniqueKind::Gss,
    TechniqueKind::Tss,
    TechniqueKind::Fac2,
    TechniqueKind::Fiss,
];

/// The flat-DES config equivalent to a default single-tenant session:
/// whole-cluster placement, constant 1 µs iterations, no delay.
fn flat_cfg(n: u64, p: u32, tech: TechniqueKind, path: SchedPath) -> DesConfig {
    let mut cfg = DesConfig::new(
        LoopParams::new(n, p),
        tech,
        ExecutionModel::Dca,
        ClusterConfig::small(p),
        IterationCost::Constant(1e-6),
    );
    cfg.sched_path = path;
    cfg
}

#[test]
fn single_tenant_session_is_bit_identical_to_flat_des() {
    for path in [SchedPath::TwoPhase, SchedPath::LockFree] {
        for tech in TECHS {
            let (n, p) = (3_000, 8);
            let flat = simulate(&flat_cfg(n, p, tech, path)).unwrap();
            let session = SessionConfig::new(ClusterConfig::small(p))
                .with_sched_path(path)
                .admit(TenantSpec::new("solo", n, tech));
            let out = simulate_session(&session).unwrap();
            assert_eq!(out.tenants.len(), 1);
            let t = &out.tenants[0];
            assert_eq!(t.state, TenantState::Completed, "{tech} {path:?}");
            let r = &t.result;
            assert_eq!(r.t_par(), flat.t_par(), "{tech} {path:?}: t_par");
            assert_eq!(r.finish, flat.finish, "{tech} {path:?}: finish vector");
            assert_eq!(r.assignments, flat.assignments, "{tech} {path:?}: schedule");
            assert_eq!(r.stats.messages, flat.stats.messages, "{tech} {path:?}: messages");
            assert_eq!(r.stats.chunks, flat.stats.chunks, "{tech} {path:?}: chunks");
            assert_eq!(r.fast_grants, flat.fast_grants, "{tech} {path:?}: fast grants");
            assert_eq!(r.events, flat.events, "{tech} {path:?}: event count");
            assert_eq!(
                r.rank0_service_busy, flat.rank0_service_busy,
                "{tech} {path:?}: host service busy"
            );
            assert_eq!(
                (r.intra_node_messages, r.inter_node_messages),
                (flat.intra_node_messages, flat.inter_node_messages),
                "{tech} {path:?}: message classes"
            );
            if path == SchedPath::LockFree {
                assert_eq!(r.stats.messages, 0, "{tech}: lock-free sends no messages");
            }
        }
    }
}

/// Replay a session's grant trace: per-tenant running totals plus the
/// largest chunk seen so far at every probe point.
fn replay(
    trace: &[(u32, u64)],
    k: usize,
    mut probe: impl FnMut(usize, &[u64], u64),
) {
    let mut granted = vec![0u64; k];
    let mut cmax = 0u64;
    for (i, &(t, size)) in trace.iter().enumerate() {
        granted[t as usize] += size;
        cmax = cmax.max(size);
        probe(i, &granted, cmax);
    }
}

#[test]
fn fair_share_keeps_identical_tenants_within_one_chunk_when_serialized() {
    // One rank hosting K identical loops ⇒ at most one request in flight,
    // so granted totals ARE the arbiter's accounts: after every grant the
    // spread must be at most the largest chunk granted so far.
    for tech in [TechniqueKind::Ss, TechniqueKind::Gss] {
        let k = 4;
        let mut cfg = SessionConfig::new(ClusterConfig::small(1))
            .with_policy(ArbitrationPolicy::FairShare);
        cfg.record_grant_trace = true;
        for i in 0..k {
            cfg = cfg.admit(TenantSpec::new(format!("t{i}"), 400, tech));
        }
        let out = simulate_session(&cfg).unwrap();
        assert!(!out.grant_trace.is_empty());
        replay(&out.grant_trace, k, |i, granted, cmax| {
            let hi = *granted.iter().max().unwrap();
            let lo = *granted.iter().min().unwrap();
            assert!(
                hi - lo <= cmax,
                "{tech} probe {i}: spread {} > one chunk ({cmax}); totals {granted:?}",
                hi - lo
            );
        });
        for t in &out.tenants {
            assert_eq!(t.granted_iters, 400);
            assert_eq!(t.state, TenantState::Completed);
        }
    }
}

#[test]
fn fair_share_spread_is_inflight_bounded_on_a_parallel_cluster() {
    // On p ranks up to p requests are in flight, so granted totals can
    // momentarily trail the (one-chunk-tight) arbiter accounts by one
    // chunk per in-flight request: spread ≤ (p + 1) · cmax. FIFO has no
    // such bound — its spread reaches a whole tenant's loop.
    let (k, p, n) = (4usize, 8u32, 800u64);
    for tech in [TechniqueKind::Ss, TechniqueKind::Gss] {
        let mut cfg = SessionConfig::new(ClusterConfig::small(p))
            .with_policy(ArbitrationPolicy::FairShare);
        cfg.record_grant_trace = true;
        for i in 0..k {
            cfg = cfg.admit(TenantSpec::new(format!("t{i}"), n, tech));
        }
        let out = simulate_session(&cfg).unwrap();
        let bound = |cmax: u64| (p as u64 + 1) * cmax;
        replay(&out.grant_trace, k, |i, granted, cmax| {
            let hi = *granted.iter().max().unwrap();
            let lo = *granted.iter().min().unwrap();
            assert!(
                hi - lo <= bound(cmax),
                "{tech} probe {i}: spread {} > {}; totals {granted:?}",
                hi - lo,
                bound(cmax)
            );
        });
        for t in &out.tenants {
            assert_eq!(t.granted_iters, n, "{tech}: full coverage");
        }
        assert!(out.jain_fairness > 0.9, "{tech}: Jain {}", out.jain_fairness);
    }
}

#[test]
fn strict_priority_and_fifo_order_completions() {
    // Two same-shaped loops on one rank: under strict priority the urgent
    // class finishes first regardless of id; under FIFO the earlier
    // arrival does, regardless of granted balance.
    let base = |policy| {
        SessionConfig::new(ClusterConfig::small(1)).with_policy(policy)
    };
    let cfg = base(ArbitrationPolicy::StrictPriority)
        .admit(TenantSpec::new("laid-back", 500, TechniqueKind::Ss).with_priority(5))
        .admit(TenantSpec::new("urgent", 500, TechniqueKind::Ss).with_priority(0));
    let out = simulate_session(&cfg).unwrap();
    assert!(
        out.tenants[1].completion < out.tenants[0].completion,
        "urgent ({}) should beat laid-back ({})",
        out.tenants[1].completion,
        out.tenants[0].completion
    );
    let cfg = base(ArbitrationPolicy::Fifo)
        .admit(TenantSpec::new("late", 500, TechniqueKind::Ss).arriving_at(1e-5))
        .admit(TenantSpec::new("early", 500, TechniqueKind::Ss));
    let out = simulate_session(&cfg).unwrap();
    assert!(
        out.tenants[1].completion < out.tenants[0].completion,
        "FIFO must finish the earlier arrival first"
    );
}

#[test]
fn eviction_keeps_an_exactly_scheduled_granted_prefix() {
    // Cancel a big loop mid-run: the tenant ends Evicted, granted+dropped
    // accounts for every iteration, and the granted prefix is a gapless
    // schedule of [0, granted).
    let cfg = SessionConfig::new(ClusterConfig::small(8))
        .admit(TenantSpec::new("victim", 200_000, TechniqueKind::Ss).cancelled_at(2e-3))
        .admit(TenantSpec::new("survivor", 2_000, TechniqueKind::Gss));
    let out = simulate_session(&cfg).unwrap();
    let victim = &out.tenants[0];
    assert_eq!(victim.state, TenantState::Evicted);
    assert!(victim.dropped_iters > 0, "cancel_at landed after the loop drained");
    assert!(victim.granted_iters > 0, "cancel_at landed before any grant");
    assert_eq!(victim.granted_iters + victim.dropped_iters, 200_000);
    verify_coverage(&victim.result.sorted_assignments(), victim.granted_iters)
        .expect("granted prefix is exactly scheduled");
    let survivor = &out.tenants[1];
    assert_eq!(survivor.state, TenantState::Completed);
    verify_coverage(&survivor.result.sorted_assignments(), 2_000).unwrap();
    // A pre-arrival cancel evicts without ever running.
    let cfg = SessionConfig::new(ClusterConfig::small(4))
        .admit(TenantSpec::new("never-ran", 10_000, TechniqueKind::Ss).arriving_at(1.0).cancelled_at(0.5))
        .admit(TenantSpec::new("runs", 1_000, TechniqueKind::Ss));
    let out = simulate_session(&cfg).unwrap();
    assert_eq!(out.tenants[0].state, TenantState::Evicted);
    assert_eq!(out.tenants[0].granted_iters, 0);
    assert_eq!(out.tenants[0].dropped_iters, 10_000);
    assert_eq!(out.tenants[1].state, TenantState::Completed);
}

/// The acceptance scenario's seeded tenant population: `k` loops with
/// mixed techniques, staggered arrivals, varied weights and overlapping
/// block placements over a `ranks`-rank cluster.
fn acceptance_session(seed: u64, k: u32, ranks: u32, path: SchedPath) -> SessionConfig {
    let mut cfg =
        SessionConfig::new(ClusterConfig::minihpc()).with_sched_path(path);
    assert_eq!(cfg.cluster.total_ranks(), ranks);
    cfg.record_exec_spans = true;
    for i in 0..k {
        let h = splitmix64(seed ^ (0xACCE97 + i as u64));
        let n = 500 + h % 1_501; // 500..=2000
        let tech = TECHS[((h >> 8) % TECHS.len() as u64) as usize];
        let span = (4u32 << ((h >> 16) % 5)).min(ranks); // 4..64 ranks
        let offset = ((h >> 24) % ranks as u64) as u32;
        let weight = 1 + (h >> 32) % 4;
        let arrival = (i as f64) * 5e-5;
        cfg = cfg.admit(
            TenantSpec::new(format!("t{i}"), n, tech)
                .arriving_at(arrival)
                .weighted(weight)
                .placed_at(offset, span),
        );
    }
    cfg
}

#[test]
fn hundred_tenant_session_is_deterministic_covered_and_overlap_free() {
    for path in [SchedPath::TwoPhase, SchedPath::LockFree] {
        let cfg = acceptance_session(0x5E55, 120, 256, path);
        let out = simulate_session(&cfg).unwrap();
        // Determinism: a second run of the same config is identical.
        let again = simulate_session(&cfg).unwrap();
        assert_eq!(out.events, again.events, "{path:?}: event count drifted");
        assert_eq!(out.makespan, again.makespan, "{path:?}: makespan drifted");
        for (a, b) in out.tenants.iter().zip(&again.tenants) {
            assert_eq!(a.completion, b.completion, "{path:?}: tenant {} drifted", a.id);
            assert_eq!(a.granted_iters, b.granted_iters);
        }
        // Every tenant completed with exact coverage of its own loop.
        assert_eq!(out.tenants.len(), 120);
        for t in &out.tenants {
            assert_eq!(t.state, TenantState::Completed, "{path:?}: tenant {}", t.id);
            let n = cfg.tenants[t.id as usize].n;
            assert_eq!(t.granted_iters, n);
            verify_coverage(&t.result.sorted_assignments(), n)
                .unwrap_or_else(|e| panic!("{path:?}: tenant {}: {e}", t.id));
        }
        // No rank ever executes two tenants' iterations at the same
        // instant: per-rank exec spans are disjoint.
        assert_eq!(out.exec_spans.len(), 256);
        let mut multi_tenant_ranks = 0;
        for (r, spans) in out.exec_spans.iter().enumerate() {
            let mut sorted = spans.clone();
            sorted.sort_by_key(|s| (s.start_ns, s.end_ns));
            if sorted.windows(2).any(|w| w[0].tenant != w[1].tenant) {
                multi_tenant_ranks += 1;
            }
            for w in sorted.windows(2) {
                assert!(
                    w[1].start_ns >= w[0].end_ns,
                    "{path:?}: rank {r}: span [{}, {}) of tenant {} overlaps \
                     [{}, {}) of tenant {}",
                    w[1].start_ns,
                    w[1].end_ns,
                    w[1].tenant,
                    w[0].start_ns,
                    w[0].end_ns,
                    w[0].tenant
                );
            }
        }
        // The scenario genuinely exercises sharing: most ranks served
        // several tenants.
        assert!(
            multi_tenant_ranks > 64,
            "{path:?}: only {multi_tenant_ranks} ranks saw more than one tenant"
        );
    }
}

#[test]
fn session_rejects_bad_specs() {
    let c = ClusterConfig::small(4);
    // AF has no closed form.
    let cfg = SessionConfig::new(c.clone())
        .admit(TenantSpec::new("af", 100, TechniqueKind::Af));
    assert!(simulate_session(&cfg).is_err());
    // Empty sessions, empty loops, out-of-range placements.
    assert!(simulate_session(&SessionConfig::new(c.clone())).is_err());
    let cfg = SessionConfig::new(c.clone())
        .admit(TenantSpec::new("empty", 0, TechniqueKind::Ss));
    assert!(simulate_session(&cfg).is_err());
    let cfg = SessionConfig::new(c)
        .admit(TenantSpec::new("wide", 100, TechniqueKind::Ss).placed_at(0, 9));
    assert!(simulate_session(&cfg).is_err());
}
