//! Property tests pinning the PDES determinism guarantee (docs/pdes.md):
//! for every thread count, the sharded engine returns a result
//! bit-identical to the sequential event loop — same schedule, same
//! makespan, same protocol counters. The partition is geometry-derived
//! (node groups for the flat models, level-1 subtrees for HIER-DCA), so
//! the thread count only changes who *executes* a shard, never what any
//! shard observes.

use dca_dls::config::{ClusterConfig, ExecutionModel, HierParams, SchedPath};
use dca_dls::des::{pdes::PdesMode, simulate, DesConfig, DesResult};
use dca_dls::sched::Assignment;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{CandidateSet, LoopParams, TechniqueKind};
use dca_dls::tenant::{session_slowdowns, SessionConfig, TenantSpec};
use dca_dls::workload::IterationCost;

const THREADS: [u32; 3] = [2, 4, 8];

fn cluster(nodes: u32, rpn: u32) -> ClusterConfig {
    ClusterConfig { nodes, ranks_per_node: rpn, ..ClusterConfig::minihpc() }
}

/// Everything the guarantee covers, in one comparable value.
fn fingerprint(r: &DesResult) -> (Vec<Assignment>, f64, u64, Vec<u64>, u64) {
    (
        r.sorted_assignments(),
        r.t_par(),
        r.fast_grants,
        r.level_messages.clone(),
        r.stats.messages,
    )
}

#[test]
fn flat_dca_is_thread_count_invariant() {
    for path in [SchedPath::TwoPhase, SchedPath::LockFree] {
        let mk = |threads: u32| {
            let cl = cluster(4, 4);
            let mut cfg = DesConfig::new(
                LoopParams::new(40_000, cl.total_ranks()),
                TechniqueKind::Fac2,
                ExecutionModel::Dca,
                cl,
                IterationCost::Constant(1e-5),
            )
            .with_threads(threads);
            cfg.sched_path = path;
            simulate(&cfg).unwrap()
        };
        let seq = mk(1);
        assert!(seq.pdes.is_none(), "{path:?}: one thread keeps the sequential loop");
        let base = fingerprint(&seq);
        for t in THREADS {
            let par = mk(t);
            assert_eq!(base, fingerprint(&par), "{path:?} t={t}");
            assert!(par.pdes.is_some(), "{path:?} t={t}");
        }
    }
}

#[test]
fn hier_depth3_is_thread_count_invariant() {
    for path in [SchedPath::TwoPhase, SchedPath::LockFree] {
        let mk = |threads: u32| {
            let cl = ClusterConfig { racks: 2, ..cluster(4, 4) };
            let mut cfg = DesConfig::new(
                LoopParams::new(24_000, cl.total_ranks()),
                TechniqueKind::Fac2,
                ExecutionModel::HierDca,
                cl,
                IterationCost::Constant(1e-5),
            )
            .with_threads(threads);
            cfg.hier = HierParams::with_inner(TechniqueKind::Ss)
                .with_levels(3)
                .with_fanouts(&[2, 2, 4]);
            cfg.sched_path = path;
            simulate(&cfg).unwrap()
        };
        let base = fingerprint(&mk(1));
        for t in THREADS {
            assert_eq!(base, fingerprint(&mk(t)), "{path:?} t={t}");
        }
    }
}

/// The fused master tier (`--master-lockfree`) routes its atom ops through
/// the same level-0 choke point, so it must shard just as exactly.
#[test]
fn hier_master_lockfree_is_thread_count_invariant() {
    let mk = |threads: u32| {
        let cl = cluster(4, 4);
        let mut cfg = DesConfig::new(
            LoopParams::new(24_000, cl.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads);
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss).with_master_lockfree();
        cfg.sched_path = SchedPath::LockFree;
        simulate(&cfg).unwrap()
    };
    let seq = mk(1);
    assert!(seq.fast_grants > 0, "the fused master tier must actually engage");
    let base = fingerprint(&seq);
    for t in THREADS {
        assert_eq!(base, fingerprint(&mk(t)), "t={t}");
    }
}

/// Adversarial cell for the optimistic window: SS grant traffic over a
/// tight cross-node latency keeps every round sparse (so the controller
/// opens the window) while cross-shard replies keep landing exactly one
/// lookahead past the horizon — inside the speculated span — so the
/// hybrid executor is forced to roll back and replay, round after round.
/// The result must still be bit-identical to the sequential loop and to
/// the conservative executor at every thread count.
#[test]
fn hybrid_rollbacks_fire_and_preserve_bit_identity() {
    let mk = |threads: u32, mode: PdesMode| {
        let cl = cluster(4, 4);
        let cfg = DesConfig::new(
            LoopParams::new(20_000, cl.total_ranks()),
            TechniqueKind::Ss,
            ExecutionModel::Dca,
            cl,
            IterationCost::Constant(1e-6),
        )
        .with_threads(threads)
        .with_pdes_mode(mode);
        simulate(&cfg).unwrap()
    };
    let base = fingerprint(&mk(1, PdesMode::Hybrid));
    for t in THREADS {
        let cons = mk(t, PdesMode::Conservative);
        let p = cons.pdes.as_ref().unwrap();
        assert_eq!(p.rollbacks, 0, "conservative never speculates (t={t})");
        assert_eq!(p.speculated_events, 0, "t={t}");
        assert_eq!(base, fingerprint(&cons), "conservative t={t}");

        let hyb = mk(t, PdesMode::Hybrid);
        let p = hyb.pdes.as_ref().unwrap();
        assert!(p.speculated_events > 0, "the window must open on this cell (t={t})");
        assert!(p.rollbacks > 0, "stragglers must violate the window here (t={t})");
        assert_eq!(base, fingerprint(&hyb), "hybrid t={t}");
    }
}

/// `--adaptive` under sharding: the rebinding controllers must produce the
/// exact switch trace the sequential run produces, at every thread count,
/// for both the flat-DCA controller (shard-0-local eras) and the
/// hierarchical per-persona controllers (merged in (time, level, master)
/// order). The heterogeneous exponential delay keeps rebind times distinct.
#[test]
fn adaptive_switch_trace_is_thread_count_invariant() {
    let mk_flat = |threads: u32| {
        let cl = cluster(4, 4);
        let mut cfg = DesConfig::new(
            LoopParams::new(20_000, cl.total_ranks()),
            TechniqueKind::Ss,
            ExecutionModel::Dca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads);
        cfg.hier = HierParams::default()
            .with_adaptive()
            .with_probe_interval(8)
            .with_candidates(CandidateSet::parse("ss,gss,fac").unwrap());
        cfg.delay = InjectedDelay::exponential_calculation(100e-6, 5);
        simulate(&cfg).unwrap()
    };
    let mk_hier = |threads: u32| {
        let cl = cluster(2, 4);
        let mut cfg = DesConfig::new(
            LoopParams::new(20_000, cl.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads);
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss)
            .with_adaptive()
            .with_probe_interval(8)
            .with_candidates(CandidateSet::parse("ss,tap").unwrap());
        cfg.sched_path = SchedPath::Auto;
        cfg.delay = InjectedDelay::exponential_calculation(100e-6, 7);
        simulate(&cfg).unwrap()
    };
    for (label, mk) in [("flat", &mk_flat as &dyn Fn(u32) -> DesResult), ("hier", &mk_hier)] {
        let seq = mk(1);
        assert!(
            !seq.switch_events.is_empty(),
            "{label}: the controller must actually rebind on this cell"
        );
        let base = fingerprint(&seq);
        for t in THREADS {
            let par = mk(t);
            assert!(par.pdes.is_some(), "{label} t={t}");
            assert_eq!(seq.switch_events, par.switch_events, "{label} t={t}");
            assert_eq!(base, fingerprint(&par), "{label} t={t}");
        }
    }
}

/// `--stream-metrics` under sharding: the merged per-shard tick series
/// must rebuild the sequential stream record-for-record (rendered JSON
/// compared verbatim), for a flat cell and a hierarchical cell with
/// subtree entries.
#[test]
fn stream_records_are_thread_count_invariant() {
    let render = |r: &DesResult| -> Vec<String> {
        r.stream.iter().map(|j| j.render()).collect()
    };
    let mk_flat = |threads: u32| {
        let cl = cluster(4, 4);
        let cfg = DesConfig::new(
            LoopParams::new(40_000, cl.total_ranks()),
            TechniqueKind::Gss,
            ExecutionModel::Dca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads)
        .with_stream_interval(1e-3);
        simulate(&cfg).unwrap()
    };
    let mk_hier = |threads: u32| {
        let cl = cluster(4, 4);
        let mut cfg = DesConfig::new(
            LoopParams::new(24_000, cl.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads)
        .with_stream_interval(1e-3);
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss);
        simulate(&cfg).unwrap()
    };
    for (label, mk) in [("flat", &mk_flat as &dyn Fn(u32) -> DesResult), ("hier", &mk_hier)] {
        let seq = mk(1);
        let base = render(&seq);
        assert!(base.len() >= 2, "{label}: the cell must emit interval records");
        if label == "hier" {
            assert!(
                seq.stream.iter().any(|r| r.get("subtrees").is_some()),
                "hier stream must carry subtree entries"
            );
        }
        for t in THREADS {
            let par = mk(t);
            assert!(par.pdes.is_some(), "{label} t={t}");
            assert_eq!(base, render(&par), "{label} t={t}");
        }
    }
}

/// A seeded multi-tenant session: `des_threads` fans the `--slowdown` solo
/// baselines out, and the whole report — session outcome and every
/// slowdown ratio — must not depend on the thread count.
#[test]
fn session_slowdowns_are_thread_count_invariant() {
    const TECHS: [TechniqueKind; 3] =
        [TechniqueKind::Ss, TechniqueKind::Gss, TechniqueKind::Fac2];
    let mk = |threads: u32| {
        let mut cfg = SessionConfig::new(ClusterConfig::small(16)).with_des_threads(threads);
        for i in 0..6u64 {
            cfg = cfg.admit(
                TenantSpec::new(format!("t{i}"), 400 + 97 * i, TECHS[(i % 3) as usize])
                    .arriving_at(i as f64 * 1e-4),
            );
        }
        session_slowdowns(&cfg).unwrap()
    };
    let (o1, s1, m1) = mk(1);
    assert_eq!(s1.len(), 6);
    for t in THREADS {
        let (o, s, m) = mk(t);
        assert_eq!(s1, s, "t={t}");
        assert_eq!(m1, m, "t={t}");
        assert_eq!(o1.makespan, o.makespan, "t={t}");
        assert_eq!(o1.messages, o.messages, "t={t}");
        assert_eq!(o1.jain_fairness, o.jain_fairness, "t={t}");
    }
}
