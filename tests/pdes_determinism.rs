//! Property tests pinning the PDES determinism guarantee (docs/pdes.md):
//! for every thread count, the sharded engine returns a result
//! bit-identical to the sequential event loop — same schedule, same
//! makespan, same protocol counters. The partition is geometry-derived
//! (node groups for the flat models, level-1 subtrees for HIER-DCA), so
//! the thread count only changes who *executes* a shard, never what any
//! shard observes.

use dca_dls::config::{ClusterConfig, ExecutionModel, HierParams, SchedPath};
use dca_dls::des::{
    pdes::{PdesMode, WINDOW_MULT_MAX},
    simulate, DesConfig, DesResult,
};
use dca_dls::sched::Assignment;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{rnd::splitmix64, CandidateSet, LoopParams, TechniqueKind};
use dca_dls::tenant::{
    session_slowdowns, simulate_session, SessionConfig, SessionOutcome, TenantId, TenantSpec,
};
use dca_dls::workload::IterationCost;

const THREADS: [u32; 3] = [2, 4, 8];

fn cluster(nodes: u32, rpn: u32) -> ClusterConfig {
    ClusterConfig { nodes, ranks_per_node: rpn, ..ClusterConfig::minihpc() }
}

/// Everything the guarantee covers, in one comparable value.
fn fingerprint(r: &DesResult) -> (Vec<Assignment>, f64, u64, Vec<u64>, u64) {
    (
        r.sorted_assignments(),
        r.t_par(),
        r.fast_grants,
        r.level_messages.clone(),
        r.stats.messages,
    )
}

#[test]
fn flat_dca_is_thread_count_invariant() {
    for path in [SchedPath::TwoPhase, SchedPath::LockFree] {
        let mk = |threads: u32| {
            let cl = cluster(4, 4);
            let mut cfg = DesConfig::new(
                LoopParams::new(40_000, cl.total_ranks()),
                TechniqueKind::Fac2,
                ExecutionModel::Dca,
                cl,
                IterationCost::Constant(1e-5),
            )
            .with_threads(threads);
            cfg.sched_path = path;
            simulate(&cfg).unwrap()
        };
        let seq = mk(1);
        assert!(seq.pdes.is_none(), "{path:?}: one thread keeps the sequential loop");
        let base = fingerprint(&seq);
        for t in THREADS {
            let par = mk(t);
            assert_eq!(base, fingerprint(&par), "{path:?} t={t}");
            assert!(par.pdes.is_some(), "{path:?} t={t}");
        }
    }
}

#[test]
fn hier_depth3_is_thread_count_invariant() {
    for path in [SchedPath::TwoPhase, SchedPath::LockFree] {
        let mk = |threads: u32| {
            let cl = ClusterConfig { racks: 2, ..cluster(4, 4) };
            let mut cfg = DesConfig::new(
                LoopParams::new(24_000, cl.total_ranks()),
                TechniqueKind::Fac2,
                ExecutionModel::HierDca,
                cl,
                IterationCost::Constant(1e-5),
            )
            .with_threads(threads);
            cfg.hier = HierParams::with_inner(TechniqueKind::Ss)
                .with_levels(3)
                .with_fanouts(&[2, 2, 4]);
            cfg.sched_path = path;
            simulate(&cfg).unwrap()
        };
        let base = fingerprint(&mk(1));
        for t in THREADS {
            assert_eq!(base, fingerprint(&mk(t)), "{path:?} t={t}");
        }
    }
}

/// The fused master tier (`--master-lockfree`) routes its atom ops through
/// the same level-0 choke point, so it must shard just as exactly.
#[test]
fn hier_master_lockfree_is_thread_count_invariant() {
    let mk = |threads: u32| {
        let cl = cluster(4, 4);
        let mut cfg = DesConfig::new(
            LoopParams::new(24_000, cl.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads);
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss).with_master_lockfree();
        cfg.sched_path = SchedPath::LockFree;
        simulate(&cfg).unwrap()
    };
    let seq = mk(1);
    assert!(seq.fast_grants > 0, "the fused master tier must actually engage");
    let base = fingerprint(&seq);
    for t in THREADS {
        assert_eq!(base, fingerprint(&mk(t)), "t={t}");
    }
}

/// Adversarial cell for the optimistic window: SS grant traffic over a
/// tight cross-node latency keeps every round sparse (so the controller
/// opens the window) while cross-shard replies keep landing exactly one
/// lookahead past the horizon — inside the speculated span — so the
/// hybrid executor is forced to roll back and replay, round after round.
/// The result must still be bit-identical to the sequential loop and to
/// the conservative executor at every thread count.
#[test]
fn hybrid_rollbacks_fire_and_preserve_bit_identity() {
    let mk = |threads: u32, mode: PdesMode| {
        let cl = cluster(4, 4);
        let cfg = DesConfig::new(
            LoopParams::new(20_000, cl.total_ranks()),
            TechniqueKind::Ss,
            ExecutionModel::Dca,
            cl,
            IterationCost::Constant(1e-6),
        )
        .with_threads(threads)
        .with_pdes_mode(mode);
        simulate(&cfg).unwrap()
    };
    let base = fingerprint(&mk(1, PdesMode::Hybrid));
    for t in THREADS {
        let cons = mk(t, PdesMode::Conservative);
        let p = cons.pdes.as_ref().unwrap();
        assert_eq!(p.rollbacks, 0, "conservative never speculates (t={t})");
        assert_eq!(p.speculated_events, 0, "t={t}");
        assert_eq!(base, fingerprint(&cons), "conservative t={t}");

        let hyb = mk(t, PdesMode::Hybrid);
        let p = hyb.pdes.as_ref().unwrap();
        assert!(p.speculated_events > 0, "the window must open on this cell (t={t})");
        assert!(p.rollbacks > 0, "stragglers must violate the window here (t={t})");
        assert_eq!(base, fingerprint(&hyb), "hybrid t={t}");
    }
}

/// `--adaptive` under sharding: the rebinding controllers must produce the
/// exact switch trace the sequential run produces, at every thread count,
/// for both the flat-DCA controller (shard-0-local eras) and the
/// hierarchical per-persona controllers (merged in (time, level, master)
/// order). The heterogeneous exponential delay keeps rebind times distinct.
#[test]
fn adaptive_switch_trace_is_thread_count_invariant() {
    let mk_flat = |threads: u32| {
        let cl = cluster(4, 4);
        let mut cfg = DesConfig::new(
            LoopParams::new(20_000, cl.total_ranks()),
            TechniqueKind::Ss,
            ExecutionModel::Dca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads);
        cfg.hier = HierParams::default()
            .with_adaptive()
            .with_probe_interval(8)
            .with_candidates(CandidateSet::parse("ss,gss,fac").unwrap());
        cfg.delay = InjectedDelay::exponential_calculation(100e-6, 5);
        simulate(&cfg).unwrap()
    };
    let mk_hier = |threads: u32| {
        let cl = cluster(2, 4);
        let mut cfg = DesConfig::new(
            LoopParams::new(20_000, cl.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads);
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss)
            .with_adaptive()
            .with_probe_interval(8)
            .with_candidates(CandidateSet::parse("ss,tap").unwrap());
        cfg.sched_path = SchedPath::Auto;
        cfg.delay = InjectedDelay::exponential_calculation(100e-6, 7);
        simulate(&cfg).unwrap()
    };
    for (label, mk) in [("flat", &mk_flat as &dyn Fn(u32) -> DesResult), ("hier", &mk_hier)] {
        let seq = mk(1);
        assert!(
            !seq.switch_events.is_empty(),
            "{label}: the controller must actually rebind on this cell"
        );
        let base = fingerprint(&seq);
        for t in THREADS {
            let par = mk(t);
            assert!(par.pdes.is_some(), "{label} t={t}");
            assert_eq!(seq.switch_events, par.switch_events, "{label} t={t}");
            assert_eq!(base, fingerprint(&par), "{label} t={t}");
        }
    }
}

/// `--stream-metrics` under sharding: the merged per-shard tick series
/// must rebuild the sequential stream record-for-record (rendered JSON
/// compared verbatim), for a flat cell and a hierarchical cell with
/// subtree entries.
#[test]
fn stream_records_are_thread_count_invariant() {
    let render = |r: &DesResult| -> Vec<String> {
        r.stream.iter().map(|j| j.render()).collect()
    };
    let mk_flat = |threads: u32| {
        let cl = cluster(4, 4);
        let cfg = DesConfig::new(
            LoopParams::new(40_000, cl.total_ranks()),
            TechniqueKind::Gss,
            ExecutionModel::Dca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads)
        .with_stream_interval(1e-3);
        simulate(&cfg).unwrap()
    };
    let mk_hier = |threads: u32| {
        let cl = cluster(4, 4);
        let mut cfg = DesConfig::new(
            LoopParams::new(24_000, cl.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads)
        .with_stream_interval(1e-3);
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss);
        simulate(&cfg).unwrap()
    };
    for (label, mk) in [("flat", &mk_flat as &dyn Fn(u32) -> DesResult), ("hier", &mk_hier)] {
        let seq = mk(1);
        let base = render(&seq);
        assert!(base.len() >= 2, "{label}: the cell must emit interval records");
        if label == "hier" {
            assert!(
                seq.stream.iter().any(|r| r.get("subtrees").is_some()),
                "hier stream must carry subtree entries"
            );
        }
        for t in THREADS {
            let par = mk(t);
            assert!(par.pdes.is_some(), "{label} t={t}");
            assert_eq!(base, render(&par), "{label} t={t}");
        }
    }
}

/// The tentpole cell for multi-Δ speculation: the same adversarial SS
/// cell as above, now asserting the controller's *depth*. A single-Δ span
/// provably admits no stragglers (every in-span send arrives ≥ Δ later,
/// past the span's end), so the rollbacks the previous test pins can only
/// come from deepened windows. This cell makes that explicit: the sparse
/// regime must escalate to ≥ 2Δ, rollbacks must fire inside the deepened
/// span and charge the incremental-checkpoint journal, and both the deep
/// run and a run capped at 1Δ must stay bit-identical to the sequential
/// loop — the cap moves counters, never results.
#[test]
fn multi_delta_windows_escalate_and_stay_bit_identical() {
    let mk = |threads: u32, cap: u32| {
        let cl = cluster(4, 4);
        let cfg = DesConfig::new(
            LoopParams::new(20_000, cl.total_ranks()),
            TechniqueKind::Ss,
            ExecutionModel::Dca,
            cl,
            IterationCost::Constant(1e-6),
        )
        .with_threads(threads)
        .with_pdes_mode(PdesMode::Hybrid)
        .with_window_mult_max(cap);
        simulate(&cfg).unwrap()
    };
    let base = fingerprint(&mk(1, WINDOW_MULT_MAX));
    for t in THREADS {
        let deep = mk(t, WINDOW_MULT_MAX);
        let p = deep.pdes.as_ref().unwrap();
        assert!(p.speculated_events > 0, "the window must open on this cell (t={t})");
        assert!(
            p.window_multiple >= 2,
            "the sparse regime must escalate past 1Δ (t={t}, got {})",
            p.window_multiple
        );
        assert!(p.rollbacks > 0, "stragglers must land inside the deepened span (t={t})");
        assert!(
            p.checkpoint_bytes > 0,
            "deepened windows must charge the undo journal (t={t})"
        );
        assert_eq!(base, fingerprint(&deep), "deep t={t}");

        let capped = mk(t, 1);
        let p = capped.pdes.as_ref().unwrap();
        assert!(p.speculated_events > 0, "1Δ speculation still runs (t={t})");
        assert!(p.window_multiple <= 1, "t={t}: cap ignored ({})", p.window_multiple);
        assert_eq!(p.rollbacks, 0, "1Δ spans admit no stragglers (t={t})");
        assert_eq!(base, fingerprint(&capped), "capped t={t}");
    }
}

/// A seeded multi-tenant session: `des_threads` fans the `--slowdown` solo
/// baselines out, and the whole report — session outcome and every
/// slowdown ratio — must not depend on the thread count.
#[test]
fn session_slowdowns_are_thread_count_invariant() {
    const TECHS: [TechniqueKind; 3] =
        [TechniqueKind::Ss, TechniqueKind::Gss, TechniqueKind::Fac2];
    let mk = |threads: u32| {
        let mut cfg = SessionConfig::new(ClusterConfig::small(16)).with_des_threads(threads);
        for i in 0..6u64 {
            cfg = cfg.admit(
                TenantSpec::new(format!("t{i}"), 400 + 97 * i, TECHS[(i % 3) as usize])
                    .arriving_at(i as f64 * 1e-4),
            );
        }
        session_slowdowns(&cfg).unwrap()
    };
    let (o1, s1, m1) = mk(1);
    assert_eq!(s1.len(), 6);
    for t in THREADS {
        let (o, s, m) = mk(t);
        assert_eq!(s1, s, "t={t}");
        assert_eq!(m1, m, "t={t}");
        assert_eq!(o1.makespan, o.makespan, "t={t}");
        assert_eq!(o1.messages, o.messages, "t={t}");
        assert_eq!(o1.jain_fairness, o.jain_fairness, "t={t}");
    }
}

/// Per-tenant grant sequences must match exactly; the merged interleaving
/// is allowed to permute only *simultaneous* cross-domain grants
/// (docs/tenancy.md), which per-tenant projection is blind to.
fn per_tenant_traces(trace: &[(TenantId, u64)], tenants: usize) -> Vec<Vec<u64>> {
    let mut per: Vec<Vec<u64>> = vec![Vec::new(); tenants];
    for &(id, sz) in trace {
        per[id as usize].push(sz);
    }
    per
}

/// The 120-tenant acceptance mix (the `tests/tenants.rs` geometry: seeded
/// sizes, five techniques, staggered arrivals, varied weights, random
/// overlapping block placements over the 256-rank cluster) run through the
/// sharded session loop. Everything the session reports — per-tenant
/// assignments, completions, turnarounds, the Jain index, per-rank exec
/// spans, the grant trace — must be bit-identical to the sequential loop
/// at every worker count, with zero rollbacks: the arbiter-domain
/// partition leaves nothing to misspeculate.
#[test]
fn sharded_session_matches_sequential_on_the_acceptance_mix() {
    const TECHS: [TechniqueKind; 5] = [
        TechniqueKind::Ss,
        TechniqueKind::Gss,
        TechniqueKind::Tss,
        TechniqueKind::Fac2,
        TechniqueKind::Fiss,
    ];
    let mk = |threads: u32| -> SessionOutcome {
        let mut cfg = SessionConfig::new(ClusterConfig::minihpc()).with_des_threads(threads);
        cfg.record_exec_spans = true;
        cfg.record_grant_trace = true;
        let ranks = cfg.cluster.total_ranks();
        for i in 0..120u32 {
            let h = splitmix64(0x5E55 ^ (0xACCE97 + i as u64));
            let n = 500 + h % 1_501; // 500..=2000
            let tech = TECHS[((h >> 8) % TECHS.len() as u64) as usize];
            let span = (4u32 << ((h >> 16) % 5)).min(ranks); // 4..64 ranks
            let offset = ((h >> 24) % ranks as u64) as u32;
            let weight = 1 + (h >> 32) % 4;
            cfg = cfg.admit(
                TenantSpec::new(format!("t{i}"), n, tech)
                    .arriving_at(i as f64 * 5e-5)
                    .weighted(weight)
                    .placed_at(offset, span),
            );
        }
        simulate_session(&cfg).unwrap()
    };
    let seq = mk(1);
    assert!(seq.pdes.is_none(), "one thread keeps the sequential loop");
    let seq_traces = per_tenant_traces(&seq.grant_trace, seq.tenants.len());
    for t in THREADS {
        let par = mk(t);
        let p = par.pdes.as_ref().expect("the sharded loop must engage");
        assert_eq!(p.rollbacks, 0, "nothing to misspeculate across domains (t={t})");
        assert!(p.arbiter_epochs > 0, "t={t}");
        assert_eq!(seq.makespan, par.makespan, "t={t}");
        assert_eq!(seq.events, par.events, "t={t}");
        assert_eq!(seq.messages, par.messages, "t={t}");
        assert_eq!(seq.jain_fairness, par.jain_fairness, "t={t}");
        assert_eq!(seq.exec_spans, par.exec_spans, "t={t}");
        for (a, b) in seq.tenants.iter().zip(&par.tenants) {
            assert_eq!(a.state, b.state, "t={t} tenant {}", a.id);
            assert_eq!(a.completion, b.completion, "t={t} tenant {}", a.id);
            assert_eq!(a.turnaround, b.turnaround, "t={t} tenant {}", a.id);
            assert_eq!(a.granted_iters, b.granted_iters, "t={t} tenant {}", a.id);
            assert_eq!(
                a.result.sorted_assignments(),
                b.result.sorted_assignments(),
                "t={t} tenant {}",
                a.id
            );
        }
        assert_eq!(seq.grant_trace.len(), par.grant_trace.len(), "t={t}");
        assert_eq!(
            seq_traces,
            per_tenant_traces(&par.grant_trace, par.tenants.len()),
            "t={t}"
        );
    }
}

/// Four disjoint placement blocks form four arbiter domains: the sharded
/// loop must report `shards == 4` with rollback-free hybrid epochs, and
/// the whole `--slowdown` report — every ratio, the mean, the session
/// outcome — must be bit-identical to the sequential loop.
#[test]
fn disjoint_placements_shard_into_domains_and_stay_bit_identical() {
    const TECHS: [TechniqueKind; 4] =
        [TechniqueKind::Ss, TechniqueKind::Gss, TechniqueKind::Tss, TechniqueKind::Fac2];
    let mk = |threads: u32| {
        let mut cfg = SessionConfig::new(ClusterConfig::small(32))
            .with_des_threads(threads)
            .with_des_mode(PdesMode::Hybrid);
        cfg.record_grant_trace = true;
        for d in 0..4u64 {
            let base = (d * 8) as u32;
            cfg = cfg
                .admit(
                    TenantSpec::new(format!("d{d}-bulk"), 6_000, TECHS[d as usize])
                        .placed_at(base, 8),
                )
                .admit(
                    TenantSpec::new(format!("d{d}-spike"), 1_200, TECHS[((d + 1) % 4) as usize])
                        .arriving_at(2e-3 * (d + 1) as f64)
                        .weighted(2)
                        .placed_at(base, 8),
                );
        }
        session_slowdowns(&cfg).unwrap()
    };
    let (seq, s1, m1) = mk(1);
    assert!(seq.pdes.is_none());
    let seq_traces = per_tenant_traces(&seq.grant_trace, seq.tenants.len());
    for t in THREADS {
        let (out, s, m) = mk(t);
        let p = out.pdes.as_ref().expect("the sharded loop must engage");
        assert_eq!(p.shards, 4, "four disjoint blocks ⇒ four arbiter domains (t={t})");
        assert_eq!(p.mode, PdesMode::Hybrid, "t={t}");
        assert_eq!(p.rollbacks, 0, "t={t}");
        assert!(p.arbiter_epochs > 0, "t={t}");
        assert_eq!(s1, s, "t={t}");
        assert_eq!(m1, m, "t={t}");
        assert_eq!(seq.makespan, out.makespan, "t={t}");
        assert_eq!(seq.events, out.events, "t={t}");
        assert_eq!(seq.messages, out.messages, "t={t}");
        assert_eq!(seq.jain_fairness, out.jain_fairness, "t={t}");
        assert_eq!(
            seq_traces,
            per_tenant_traces(&out.grant_trace, out.tenants.len()),
            "t={t}"
        );
    }
}
