//! Property tests pinning the PDES determinism guarantee (docs/pdes.md):
//! for every thread count, the sharded engine returns a result
//! bit-identical to the sequential event loop — same schedule, same
//! makespan, same protocol counters. The partition is geometry-derived
//! (node groups for the flat models, level-1 subtrees for HIER-DCA), so
//! the thread count only changes who *executes* a shard, never what any
//! shard observes.

use dca_dls::config::{ClusterConfig, ExecutionModel, HierParams, SchedPath};
use dca_dls::des::{simulate, DesConfig, DesResult};
use dca_dls::sched::Assignment;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::tenant::{session_slowdowns, SessionConfig, TenantSpec};
use dca_dls::workload::IterationCost;

const THREADS: [u32; 3] = [2, 4, 8];

fn cluster(nodes: u32, rpn: u32) -> ClusterConfig {
    ClusterConfig { nodes, ranks_per_node: rpn, ..ClusterConfig::minihpc() }
}

/// Everything the guarantee covers, in one comparable value.
fn fingerprint(r: &DesResult) -> (Vec<Assignment>, f64, u64, Vec<u64>, u64) {
    (
        r.sorted_assignments(),
        r.t_par(),
        r.fast_grants,
        r.level_messages.clone(),
        r.stats.messages,
    )
}

#[test]
fn flat_dca_is_thread_count_invariant() {
    for path in [SchedPath::TwoPhase, SchedPath::LockFree] {
        let mk = |threads: u32| {
            let cl = cluster(4, 4);
            let mut cfg = DesConfig::new(
                LoopParams::new(40_000, cl.total_ranks()),
                TechniqueKind::Fac2,
                ExecutionModel::Dca,
                cl,
                IterationCost::Constant(1e-5),
            )
            .with_threads(threads);
            cfg.sched_path = path;
            simulate(&cfg).unwrap()
        };
        let seq = mk(1);
        assert!(seq.pdes.is_none(), "{path:?}: one thread keeps the sequential loop");
        let base = fingerprint(&seq);
        for t in THREADS {
            let par = mk(t);
            assert_eq!(base, fingerprint(&par), "{path:?} t={t}");
            assert!(par.pdes.is_some(), "{path:?} t={t}");
        }
    }
}

#[test]
fn hier_depth3_is_thread_count_invariant() {
    for path in [SchedPath::TwoPhase, SchedPath::LockFree] {
        let mk = |threads: u32| {
            let cl = ClusterConfig { racks: 2, ..cluster(4, 4) };
            let mut cfg = DesConfig::new(
                LoopParams::new(24_000, cl.total_ranks()),
                TechniqueKind::Fac2,
                ExecutionModel::HierDca,
                cl,
                IterationCost::Constant(1e-5),
            )
            .with_threads(threads);
            cfg.hier = HierParams::with_inner(TechniqueKind::Ss)
                .with_levels(3)
                .with_fanouts(&[2, 2, 4]);
            cfg.sched_path = path;
            simulate(&cfg).unwrap()
        };
        let base = fingerprint(&mk(1));
        for t in THREADS {
            assert_eq!(base, fingerprint(&mk(t)), "{path:?} t={t}");
        }
    }
}

/// The fused master tier (`--master-lockfree`) routes its atom ops through
/// the same level-0 choke point, so it must shard just as exactly.
#[test]
fn hier_master_lockfree_is_thread_count_invariant() {
    let mk = |threads: u32| {
        let cl = cluster(4, 4);
        let mut cfg = DesConfig::new(
            LoopParams::new(24_000, cl.total_ranks()),
            TechniqueKind::Fac2,
            ExecutionModel::HierDca,
            cl,
            IterationCost::Constant(1e-5),
        )
        .with_threads(threads);
        cfg.hier = HierParams::with_inner(TechniqueKind::Ss).with_master_lockfree();
        cfg.sched_path = SchedPath::LockFree;
        simulate(&cfg).unwrap()
    };
    let seq = mk(1);
    assert!(seq.fast_grants > 0, "the fused master tier must actually engage");
    let base = fingerprint(&seq);
    for t in THREADS {
        assert_eq!(base, fingerprint(&mk(t)), "t={t}");
    }
}

/// A seeded multi-tenant session: `des_threads` fans the `--slowdown` solo
/// baselines out, and the whole report — session outcome and every
/// slowdown ratio — must not depend on the thread count.
#[test]
fn session_slowdowns_are_thread_count_invariant() {
    const TECHS: [TechniqueKind; 3] =
        [TechniqueKind::Ss, TechniqueKind::Gss, TechniqueKind::Fac2];
    let mk = |threads: u32| {
        let mut cfg = SessionConfig::new(ClusterConfig::small(16)).with_des_threads(threads);
        for i in 0..6u64 {
            cfg = cfg.admit(
                TenantSpec::new(format!("t{i}"), 400 + 97 * i, TECHS[(i % 3) as usize])
                    .arriving_at(i as f64 * 1e-4),
            );
        }
        session_slowdowns(&cfg).unwrap()
    };
    let (o1, s1, m1) = mk(1);
    assert_eq!(s1.len(), 6);
    for t in THREADS {
        let (o, s, m) = mk(t);
        assert_eq!(s1, s, "t={t}");
        assert_eq!(m1, m, "t={t}");
        assert_eq!(o1.makespan, o.makespan, "t={t}");
        assert_eq!(o1.messages, o.messages, "t={t}");
        assert_eq!(o1.jain_fairness, o.jain_fairness, "t={t}");
    }
}
