//! Doc-sync gate for `docs/metrics-schema.md`.
//!
//! The schema doc is normative: every Prometheus metric the registry
//! renders and every NDJSON field the stream producers emit must have a
//! first-column backticked row in the doc, and every documented name must
//! still be produced by the code (modulo a small allowlist for fields
//! that only appear under producers this test does not drive, e.g.
//! `slowdown`). Adding a metric without a doc row — or deleting a metric
//! while its row lingers — fails here, not in review.

use std::collections::BTreeSet;
use std::path::Path;

use dca_dls::config::{ClusterConfig, ExecutionModel, HierParams};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::obs::stream;
use dca_dls::obs::{EngineMetrics, MetricsRegistry, SessionMetrics};
use dca_dls::report::json::Json;
use dca_dls::sched::adaptive::SwitchEvent;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{CandidateSet, LoopParams, TechniqueKind};
use dca_dls::tenant::{simulate_session, SessionConfig, TenantSpec};
use dca_dls::workload::IterationCost;

fn doc_text() -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs/metrics-schema.md");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()))
}

/// First-column backticked names: lines shaped `| `name` | ...`.
fn documented_names(doc: &str) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for line in doc.lines() {
        let Some(rest) = line.strip_prefix("| `") else {
            continue;
        };
        let Some(end) = rest.find('`') else { continue };
        let name = &rest[..end];
        if !name.is_empty()
            && name
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        {
            names.insert(name.to_string());
        }
    }
    names
}

/// Metric names from the `# TYPE <name> <kind>` exposition lines.
fn prometheus_names(rendered: &str) -> BTreeSet<String> {
    rendered
        .lines()
        .filter_map(|l| l.strip_prefix("# TYPE "))
        .filter_map(|l| l.split_whitespace().next())
        .map(str::to_string)
        .collect()
}

/// Every object key in a record tree, plus each record's `event` value
/// (the record-type vocabulary is documented in the same table style).
fn collect_emitted(j: &Json, keys: &mut BTreeSet<String>, events: &mut BTreeSet<String>) {
    match j {
        Json::Obj(fields) => {
            for (k, v) in fields {
                keys.insert(k.clone());
                if k == "event" {
                    if let Some(e) = v.as_str() {
                        events.insert(e.to_string());
                    }
                }
                collect_emitted(v, keys, events);
            }
        }
        Json::Arr(items) => {
            for item in items {
                collect_emitted(item, keys, events);
            }
        }
        _ => {}
    }
}

/// Drive every stream producer once and return all records:
/// flat interval records, hierarchical+adaptive interval records with
/// subtree entries (and switch records when the controller rebinds), a
/// session with interval + terminal tenant records — plus one synthetic
/// switch record so its fields are covered even if the adaptive cell
/// happens not to rebind, and the `pdes` summary object from a sharded
/// run (documented alongside the stream vocabulary).
fn all_stream_records() -> Vec<Json> {
    let mut records = Vec::new();

    let flat = DesConfig::new(
        LoopParams::new(4_000, 16),
        TechniqueKind::Gss,
        ExecutionModel::Dca,
        ClusterConfig::small(16),
        IterationCost::Constant(1e-5),
    )
    .with_stream_interval(1e-4);
    let flat = simulate(&flat).expect("flat stream cell");
    assert!(
        flat.stream.len() >= 2,
        "flat cell must emit interval records (got {})",
        flat.stream.len()
    );
    records.extend(flat.stream);

    // Mirrors the Python-port smoke cell (4×4 ranks, exp calculation
    // delay, probe every 4 grants) where the controller primes its EWMAs
    // and rebinds several times.
    let mut hier = DesConfig::new(
        LoopParams::new(8_192, 16),
        TechniqueKind::Fac2,
        ExecutionModel::HierDca,
        ClusterConfig {
            nodes: 4,
            ranks_per_node: 4,
            ..ClusterConfig::minihpc()
        },
        IterationCost::Constant(1e-5),
    )
    .with_stream_interval(1e-3);
    hier.hier = HierParams::with_inner(TechniqueKind::Ss)
        .with_adaptive()
        .with_probe_interval(4)
        .with_candidates(CandidateSet::parse("ss,gss,fac").expect("candidate set"));
    hier.delay = InjectedDelay::exponential_calculation(100e-6, 0xAD0001);
    let hier = simulate(&hier).expect("hier stream cell");
    assert!(
        hier.stream
            .iter()
            .any(|r| r.get("subtrees").is_some()),
        "hier interval records must carry subtree entries"
    );
    records.extend(hier.stream);

    let mut session = SessionConfig::new(ClusterConfig::small(16)).with_stream_interval(1e-3);
    session = session
        .admit(
            TenantSpec::new("bulk", 40_000, TechniqueKind::Ss)
                .with_cost(IterationCost::Constant(1e-5)),
        )
        .admit(
            TenantSpec::new("late", 2_000, TechniqueKind::Gss)
                .with_cost(IterationCost::Constant(1e-5))
                .arriving_at(2e-3),
        );
    let outcome = simulate_session(&session).expect("session stream cell");
    assert!(
        outcome
            .stream
            .iter()
            .any(|r| r.get("event").and_then(Json::as_str) == Some("tenant")),
        "session stream must end with terminal tenant records"
    );
    records.extend(outcome.stream);

    records.push(stream::switch_record(&SwitchEvent {
        at_s: 0.0,
        level: 1,
        master: 0,
        from: TechniqueKind::Ss,
        to: TechniqueKind::Gss,
        predicted_ratio: 0.8,
    }));

    // Not a stream record: the `pdes` summary object exactly as
    // `dca-dls hier --json` emits it, built from a really-sharded run so
    // the doc's PDES table stays pinned to the executor (no allowlist).
    let mut sharded = DesConfig::new(
        LoopParams::new(8_192, 16),
        TechniqueKind::Fac2,
        ExecutionModel::HierDca,
        ClusterConfig {
            nodes: 4,
            ranks_per_node: 4,
            ..ClusterConfig::minihpc()
        },
        IterationCost::Constant(1e-5),
    )
    .with_threads(2);
    sharded.hier = HierParams::with_inner(TechniqueKind::Ss);
    let p = simulate(&sharded)
        .expect("sharded cell")
        .pdes
        .expect("two DES threads must shard this tree");
    records.push(Json::obj().field(
        "pdes",
        Json::obj()
            .field("shards", p.shards)
            .field("threads", p.threads)
            .field("mode", p.mode.as_str())
            .field("rounds", p.rounds)
            .field("lookahead_ns", p.lookahead_ns)
            .field("window_ns", p.window_ns)
            .field("horizon_stalls", p.horizon_stalls)
            .field("mailbox_depth_max", p.mailbox_depth_max)
            .field("rollbacks", p.rollbacks)
            .field("speculated_events", p.speculated_events)
            .field("checkpoint_bytes", p.checkpoint_bytes)
            .field("window_multiple", p.window_multiple),
    ));

    // And the session-side summary as `dca-dls tenants --json` emits it,
    // from a really-sharded session (two disjoint placement blocks ⇒ two
    // arbiter domains) so the doc's arbiter-epoch row stays pinned to the
    // sharded session loop.
    let session = SessionConfig::new(ClusterConfig::small(16))
        .with_des_threads(2)
        .admit(
            TenantSpec::new("left", 3_000, TechniqueKind::Ss)
                .with_cost(IterationCost::Constant(1e-5))
                .placed_at(0, 8),
        )
        .admit(
            TenantSpec::new("right", 3_000, TechniqueKind::Gss)
                .with_cost(IterationCost::Constant(1e-5))
                .placed_at(8, 8),
        );
    let p = simulate_session(&session)
        .expect("sharded session cell")
        .pdes
        .expect("two workers over two domains must shard this session");
    assert_eq!(p.shards, 2, "two disjoint blocks must form two arbiter domains");
    assert!(p.arbiter_epochs > 0, "the epoch exchange must actually run");
    assert_eq!(p.rollbacks, 0, "arbiter domains leave nothing to misspeculate");
    records.push(Json::obj().field(
        "pdes",
        Json::obj()
            .field("shards", p.shards)
            .field("threads", p.threads)
            .field("mode", p.mode.as_str())
            .field("arbiter_epochs", p.arbiter_epochs)
            .field("window_multiple", p.window_multiple)
            .field("speculated_events", p.speculated_events)
            .field("rollbacks", p.rollbacks),
    ));

    records
}

#[test]
fn prometheus_metrics_are_documented_and_vice_versa() {
    let doc = documented_names(&doc_text());

    let registry = MetricsRegistry::new();
    let engine = EngineMetrics::register(&registry);
    let session = SessionMetrics::register(&registry);
    engine.on_grant(64, 1e-6, false);
    engine.on_grant(32, 0.0, true);
    session.admitted.inc();
    session.active.add(1.0);

    let rendered = registry.render_prometheus();
    let metrics = prometheus_names(&rendered);
    assert!(!metrics.is_empty(), "registry rendered no metrics");

    let undocumented: Vec<_> = metrics.difference(&doc).collect();
    assert!(
        undocumented.is_empty(),
        "metrics missing from docs/metrics-schema.md: {undocumented:?}"
    );
}

#[test]
fn stream_fields_are_documented_and_vice_versa() {
    let doc = documented_names(&doc_text());
    assert!(
        doc.len() >= 30,
        "doc table extraction looks broken: only {} names found",
        doc.len()
    );

    let mut keys = BTreeSet::new();
    let mut events = BTreeSet::new();
    for record in all_stream_records() {
        collect_emitted(&record, &mut keys, &mut events);
    }

    // Code → docs: every emitted key and record type needs a row.
    let undocumented: Vec<_> = keys
        .iter()
        .filter(|k| !doc.contains(*k))
        .chain(events.iter().filter(|e| !doc.contains(*e)))
        .collect();
    assert!(
        undocumented.is_empty(),
        "stream fields missing from docs/metrics-schema.md: {undocumented:?}"
    );

    // Docs → code: every documented name must be produced by this test's
    // runs, be a Prometheus metric (checked above), or sit on the
    // allowlist of fields only emitted by producers not driven here
    // (`slowdown` needs a solo-baseline sweep; the EWMAs appear only once
    // a controller primes — the adaptive cell primes them, but they stay
    // listed so a seed tweak cannot break the docs build).
    const ALLOWLIST: &[&str] = &["slowdown", "mu_hat", "sigma_hat", "overhead_hat"];
    let registry = MetricsRegistry::new();
    EngineMetrics::register(&registry);
    SessionMetrics::register(&registry);
    let metrics = prometheus_names(&registry.render_prometheus());

    let stale: Vec<_> = doc
        .iter()
        .filter(|name| {
            !keys.contains(*name)
                && !events.contains(*name)
                && !metrics.contains(*name)
                && !ALLOWLIST.contains(&name.as_str())
        })
        .collect();
    assert!(
        stale.is_empty(),
        "docs/metrics-schema.md documents names the code never emits: {stale:?}"
    );
}
