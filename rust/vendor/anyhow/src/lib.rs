//! Minimal, offline, API-compatible subset of the `anyhow` crate — just the
//! surface this repository uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait.
//!
//! The build environment has no network access to crates.io, so the real
//! crate is replaced by this shim via a path dependency. Error values carry
//! their cause chain as rendered strings; `{e}` prints the outermost
//! message, `{e:#}` prints the full chain joined by `": "`, matching the
//! formats the binaries rely on.

use std::fmt;

/// A string-chained error value. `chain[0]` is the outermost (most recent)
/// context message; deeper entries are the causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result<T, anyhow::Error>`, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
    }

    #[test]
    fn option_context() {
        let v: Result<u32> = None.context("empty");
        assert_eq!(format!("{}", v.unwrap_err()), "empty");
        let v: Result<u32> = Some(7).context("empty");
        assert_eq!(v.unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(format!("{}", inner(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", inner(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
