//! Compile-only API stub of the `xla` crate surface this repository uses:
//! `PjRtClient` → `HloModuleProto`/`XlaComputation` → `PjRtLoadedExecutable`
//! → `Literal`. The build environment is offline, so the real crate (and
//! its bundled `xla_extension` binaries) cannot be fetched; this stub keeps
//! the `pjrt`-gated call sites **type-checking** (CI's
//! `cargo check --features pjrt` leg) while failing loudly at runtime.
//!
//! Constructing a client or parsing an HLO module always returns
//! [`Error::Unavailable`], so no executable path is ever reachable; the
//! methods past those entry points are `unreachable!`-bodied on purpose —
//! they exist purely so the real code's types line up. Swap this path
//! dependency for the real crate to actually execute artifacts.

use std::fmt;

/// The stub's only error: the real XLA runtime is not linked in.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: the vendored `xla` stub has no runtime — replace \
                 rust/vendor/xla with the real crate to execute artifacts"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can be built from / read back as.
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// PJRT client handle (stub: cannot be constructed).
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client — always fails in the stub.
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unreachable!("stub PjRtClient cannot be constructed")
    }
}

/// Parsed HLO module (stub: cannot be constructed).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text — always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled executable (stub: unreachable past the failing client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable!("stub PjRtLoadedExecutable cannot be constructed")
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unreachable!("stub PjRtBuffer cannot be constructed")
    }
}

/// A host literal (stub: constructible so input-building code compiles, but
/// never consumable — execution is unreachable).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Read back as a host vector — unreachable (no output literal can
    /// exist without a real runtime).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_points_fail_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nowhere.hlo.txt").is_err());
        let lit = Literal::vec1(&[1i32, 2, 3]).reshape(&[3, 1]).unwrap();
        assert!(lit.to_vec::<i32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"), "{msg}");
    }
}
