//! The two-level hierarchical model (`HierDca`) on **real threads** — the
//! wall-clock counterpart of the DES protocol in [`crate::hier`], sharing
//! its chunk-ledger state machine ([`crate::hier::protocol`]) so both
//! engines validate literally the same two-phase reserve/commit and
//! stale-`seq` NACK semantics.
//!
//! Thread topology for `P` ranks split into `nodes` groups of `rpn = P /
//! nodes` (block placement, like [`crate::substrate::topology::Topology`]):
//!
//! * the **global coordinator** runs on the calling thread (fabric rank
//!   `P`), owns the outer [`WorkQueue`] over the whole loop, and serves the
//!   outer DCA protocol: `OuterGet → OuterStep` reserves a node-step,
//!   `OuterCommit → OuterChunk` grants a node-chunk. Node-chunk sizes are
//!   calculated **on the node masters** with the outer technique bound to
//!   `P = nodes` — distributed chunk calculation one level up, so the
//!   injected calculation delay is paid in parallel across nodes;
//! * each **node master** (first rank of its group) is *non-dedicated*: it
//!   serves its local ranks' inner protocol from the shared
//!   [`NodeLedger`], runs the outer protocol against the coordinator, and
//!   executes iterations itself, draining its message queue between
//!   execution slices so local ranks are never starved for a whole chunk;
//! * each **local rank** self-schedules against its node master exactly
//!   like a flat DCA worker, with the node-chunk `seq` threaded through the
//!   two-phase exchange: phase-1 `Step` replies carry the node-chunk length
//!   so the worker binds the inner technique itself (no shared memory), and
//!   a commit against a replaced node-chunk is NACKed into a fresh `Step`.
//!
//! **Outer prefetch** ([`crate::config::HierParams::prefetch_watermark`]):
//! masters request the next node-chunk once the current one drops to the
//! watermark; the reply is staged in the ledger and promoted when the
//! current chunk drains, hiding the outer round trip entirely — measurably
//! lower scheduling wait than fetch-on-exhaustion (see
//! `tests/threaded_hier.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Instant;

use super::protocol::{AfInfo, PerfReport};
use super::{execute_chunk, EngineConfig, RankSummary, RunResult};
use crate::hier::protocol::{af_recap, with_np, InnerCommit, NodeLedger};
use crate::sched::{Assignment, StepTicket, WorkQueue};
use crate::substrate::delay::spin_for;
use crate::substrate::msg::{fabric, Endpoint};
use crate::techniques::af::{af_requester_chunk, AfCalculator, AfGlobals, PeStats};
use crate::techniques::{Technique, TechniqueKind};
use crate::workload::Workload;

/// Iterations a master executes between message-queue drains — the threaded
/// analogue of the LB tool's `breakAfter` interleaving.
const MASTER_SLICE: u64 = 256;

/// Wire messages of both tiers (one fabric carries both; the tiers are told
/// apart by the variant).
#[derive(Debug, Clone, Copy)]
enum Msg {
    // -- inner tier: local rank ↔ its node master ------------------------
    /// Phase 1 request: "reserve me a local step" (+ AF perf piggyback).
    Get { rank: u32, report: Option<PerfReport> },
    /// Phase 1 reply: reserved step of node-chunk `seq`; `chunk_len` lets
    /// the worker bind the inner technique itself, `remaining` feeds AF.
    Step { step: u64, remaining: u64, seq: u64, chunk_len: u64, af: Option<AfInfo> },
    /// Phase 2 request: "commit my locally calculated `size` for `step`".
    Commit { rank: u32, step: u64, size: u64, seq: u64 },
    /// Phase 2 reply: the granted absolute range.
    Chunk(Assignment),
    /// No work left anywhere — terminate.
    Done,
    // -- outer tier: node master ↔ global coordinator --------------------
    /// Master asks for an outer step (+ node-throughput piggyback for AF).
    OuterGet { node: u32, report: Option<PerfReport> },
    /// Coordinator reply: reserved outer step (+ AF aggregates). Handling
    /// it *is* the outer chunk calculation, on the master's CPU.
    OuterStep { ticket: StepTicket, af: Option<AfInfo> },
    /// Master commits its node-chunk size.
    OuterCommit { node: u32, ticket: StepTicket, size: u64 },
    /// Coordinator reply: the committed node-chunk.
    OuterChunk(Assignment),
    /// Coordinator reply: the loop is exhausted.
    OuterDone,
}

/// Block-placement geometry of the run (the threaded analogue of
/// [`crate::substrate::topology::Topology`], without latency classes —
/// latencies here are real).
#[derive(Debug, Clone, Copy)]
struct Geom {
    nodes: u32,
    rpn: u32,
    p: u32,
}

impl Geom {
    fn node_of(&self, rank: u32) -> u32 {
        rank / self.rpn
    }

    fn master_rank(&self, node: u32) -> u32 {
        node * self.rpn
    }

    /// The global coordinator's fabric rank.
    fn coord(&self) -> u32 {
        self.p
    }
}

/// Message counters split by latency class. Inner traffic is always
/// intra-node; outer traffic is inter-node **except node 0's**, because the
/// coordinator is hosted on node 0's master on the real machine (and in the
/// DES) — keeping the split directly comparable across the two substrates.
#[derive(Debug, Default)]
struct Tally {
    intra: AtomicU64,
    inter: AtomicU64,
}

impl Tally {
    /// Count one outer-tier message for `node`'s master.
    fn count_outer(&self, node: u32) {
        if node == 0 {
            self.intra.fetch_add(1, Ordering::Relaxed);
        } else {
            self.inter.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Run the threaded two-level engine: `P` rank threads (masters + local
/// ranks) plus the global coordinator loop on the calling thread.
pub fn run(cfg: &EngineConfig, workload: Arc<dyn Workload>) -> anyhow::Result<RunResult> {
    let p = cfg.params.p;
    let nodes = cfg.nodes;
    anyhow::ensure!(p >= 1, "need at least one worker");
    anyhow::ensure!(nodes >= 1, "need at least one node");
    anyhow::ensure!(
        p % nodes == 0,
        "the two-level engine places ranks in blocks: nodes ({nodes}) must divide \
         the worker count ({p})"
    );
    let geom = Geom { nodes, rpn: p / nodes, p };
    let (mut eps, _sent) = fabric::<Msg>(p + 1);
    let coord_ep = eps.pop().expect("coordinator endpoint");
    let barrier = Arc::new(Barrier::new(p as usize + 1));
    let tally = Arc::new(Tally::default());

    let mut handles = Vec::with_capacity(p as usize);
    for ep in eps {
        let rank = ep.rank();
        let w = Arc::clone(&workload);
        let b = Arc::clone(&barrier);
        let t = Arc::clone(&tally);
        let c = cfg.clone();
        handles.push(thread::spawn(move || {
            if rank % geom.rpn == 0 {
                NodeMaster::new(c, geom, ep, w, t).run(&b)
            } else {
                worker_loop(&c, geom, ep, w, &b, &t)
            }
        }));
    }

    coordinator_loop(cfg, geom, coord_ep, &barrier, &tally)?;

    let per_rank: Vec<RankSummary> =
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect();
    let intra = tally.intra.load(Ordering::Relaxed);
    let inter = tally.inter.load(Ordering::Relaxed);
    Ok(RunResult::assemble_split(per_rank, intra, inter))
}

// ---------------------------------------------------------------------------
// global coordinator

/// Outer-protocol service loop — assignment only, O(1) work per message;
/// the node-chunk *calculation* happens on the masters.
fn coordinator_loop(
    cfg: &EngineConfig,
    geom: Geom,
    ep: Endpoint<Msg>,
    barrier: &Barrier,
    tally: &Tally,
) -> anyhow::Result<()> {
    let outer_params = with_np(&cfg.params, cfg.params.n, geom.nodes);
    let is_af = cfg.technique == TechniqueKind::Af;
    let mut af = is_af.then(|| AfCalculator::new(&outer_params));
    let mut q = WorkQueue::from_params(&cfg.params);
    let mut active = geom.nodes;

    let send = |ep: &Endpoint<Msg>, dst: u32, msg: Msg| -> anyhow::Result<()> {
        tally.count_outer(geom.node_of(dst));
        ep.send(dst, msg)?;
        Ok(())
    };

    barrier.wait();
    while active > 0 {
        let env = ep.recv()?;
        match env.payload {
            Msg::OuterGet { node, report } => {
                if let (Some(af), Some(PerfReport { iters, elapsed })) = (af.as_mut(), report) {
                    af.record(node as usize, iters, elapsed);
                }
                let reply = match q.begin_step() {
                    Some(ticket) => {
                        let info = af
                            .as_ref()
                            .and_then(|a| a.globals())
                            .map(|g| AfInfo { d: g.d, e: g.e });
                        Msg::OuterStep { ticket, af: info }
                    }
                    None => {
                        active -= 1;
                        Msg::OuterDone
                    }
                };
                send(&ep, env.src, reply)?;
            }
            Msg::OuterCommit { node: _, ticket, size } => {
                // Chunk ASSIGNMENT — the only synchronized outer operation.
                spin_for(cfg.delay.assignment);
                // Outer AF: re-cap against fresh R (stale-ticket protection).
                let size = if is_af { af_recap(size, q.remaining(), geom.nodes) } else { size };
                let reply = match q.commit(ticket, size) {
                    Some(a) => Msg::OuterChunk(a),
                    None => {
                        active -= 1;
                        Msg::OuterDone
                    }
                };
                send(&ep, env.src, reply)?;
            }
            other => anyhow::bail!("hier coordinator got unexpected message: {other:?}"),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// node master

/// A non-dedicated node master: serves the inner protocol, drives the outer
/// protocol, and executes iterations itself between message drains.
struct NodeMaster {
    cfg: EngineConfig,
    geom: Geom,
    ep: Endpoint<Msg>,
    workload: Arc<dyn Workload>,
    tally: Arc<Tally>,
    node: u32,
    inner_kind: TechniqueKind,
    /// Outer technique bound to `P = nodes` (`None` for AF).
    outer_tech: Option<Technique>,
    ledger: NodeLedger,
    /// Local ranks whose requests arrived while no local work existed.
    parked: Vec<u32>,
    fetching: bool,
    global_done: bool,
    /// `Done` replies sent to local ranks (termination tracking).
    done_sent: u32,
    /// Inner-AF calculator over this node's local ranks (index `rank % rpn`).
    inner_af: Option<AfCalculator>,
    /// Outer-AF: this node's chunk-throughput statistics.
    node_stats: PeStats,
    outer_report: Option<PerfReport>,
    installed_iters: u64,
    installed_at: Instant,
    /// The master's own worker-personality statistics (AF µ/σ).
    my_stats: PeStats,
    out: RankSummary,
}

impl NodeMaster {
    fn new(
        cfg: EngineConfig,
        geom: Geom,
        ep: Endpoint<Msg>,
        workload: Arc<dyn Workload>,
        tally: Arc<Tally>,
    ) -> Self {
        let rank = ep.rank();
        let node = geom.node_of(rank);
        let inner_kind = cfg.hier.inner_or(cfg.technique);
        let outer_params = with_np(&cfg.params, cfg.params.n, geom.nodes);
        let inner_proto = with_np(&cfg.params, cfg.params.n, geom.rpn);
        NodeMaster {
            outer_tech: (cfg.technique != TechniqueKind::Af)
                .then(|| Technique::new(cfg.technique, &outer_params)),
            ledger: NodeLedger::new(inner_kind, &cfg.params, geom.rpn),
            inner_af: (inner_kind == TechniqueKind::Af)
                .then(|| AfCalculator::new(&inner_proto)),
            cfg,
            geom,
            ep,
            workload,
            tally,
            node,
            inner_kind,
            parked: Vec::new(),
            fetching: false,
            global_done: false,
            done_sent: 0,
            node_stats: PeStats::default(),
            outer_report: None,
            installed_iters: 0,
            installed_at: Instant::now(),
            my_stats: PeStats::default(),
            out: RankSummary { rank, ..Default::default() },
        }
    }

    fn run(mut self, barrier: &Barrier) -> RankSummary {
        barrier.wait();
        let t0 = Instant::now();
        self.installed_at = Instant::now();
        self.fetch();
        loop {
            // Serve everything pending before (and between) own work.
            while let Some(env) = self.ep.try_recv() {
                self.handle(env.payload);
            }
            if self.finished() {
                break;
            }
            if self.ledger.has_work() {
                self.own_step();
                continue;
            }
            // Ledger drained: make sure the next node-chunk is on its way
            // (idempotent — no-op when a fetch is in flight or the loop is
            // done). Without this, a master whose *own* grant consumed the
            // last iterations would block below with no fetch pending and,
            // with no local ranks to wake it (rpn = 1), deadlock — the DES
            // counterpart is `Own::NeedWork`'s park + fetch.
            self.fetch();
            // Nothing local to do: block until the outer reply (or a late
            // local request) arrives. This is the master's scheduling wait.
            let t_wait = Instant::now();
            match self.ep.recv() {
                Ok(env) => {
                    self.out.sched_wait += t_wait.elapsed().as_secs_f64();
                    self.handle(env.payload);
                }
                Err(_) => break,
            }
        }
        self.out.finish = t0.elapsed().as_secs_f64();
        self.out
    }

    /// All local ranks terminated, the loop is exhausted, and nothing is
    /// left in the ledger.
    fn finished(&self) -> bool {
        self.global_done && !self.ledger.has_work() && self.done_sent == self.geom.rpn - 1
    }

    // -- messaging ---------------------------------------------------------

    fn send_worker(&self, rank: u32, msg: Msg) {
        self.tally.intra.fetch_add(1, Ordering::Relaxed);
        self.ep.send(rank, msg).expect("local rank hung up early");
    }

    fn send_coord(&self, msg: Msg) {
        self.tally.count_outer(self.node);
        self.ep.send(self.geom.coord(), msg).expect("coordinator hung up early");
    }

    // -- service -----------------------------------------------------------

    fn handle(&mut self, msg: Msg) {
        match msg {
            Msg::Get { rank, report } => {
                self.record_inner_report(rank, report);
                self.serve_get(rank);
            }
            Msg::Commit { rank, step, size, seq } => {
                // Inner chunk ASSIGNMENT — serialized on this master's CPU,
                // but only contended by its own node's ranks.
                spin_for(self.cfg.delay.assignment);
                match self.ledger.commit(step, size, seq) {
                    InnerCommit::Granted(a) => {
                        self.send_worker(rank, Msg::Chunk(a));
                        self.after_grant();
                    }
                    // Stale seq: the node-chunk was replaced while this
                    // commit was in flight — NACK into a fresh phase 1.
                    InnerCommit::Stale => self.serve_get(rank),
                    InnerCommit::Drained => self.park_or_done(rank),
                }
            }
            Msg::OuterStep { ticket, af } => {
                // The outer chunk CALCULATION runs here, on the master's own
                // CPU — distributed across nodes, paying the injected delay
                // in parallel (the DCA idea, one level up).
                spin_for(self.cfg.delay.calculation);
                let size = self.outer_calc(ticket, af);
                self.send_coord(Msg::OuterCommit { node: self.node, ticket, size });
            }
            Msg::OuterChunk(a) => {
                self.fetching = false;
                if self.installed_iters == 0 {
                    self.installed_at = Instant::now();
                }
                self.installed_iters += a.size;
                self.ledger.install(a);
                self.unpark();
            }
            Msg::OuterDone => {
                self.fetching = false;
                self.global_done = true;
                self.unpark();
            }
            other => panic!("node master {}: unexpected {other:?}", self.out.rank),
        }
    }

    fn record_inner_report(&mut self, rank: u32, report: Option<PerfReport>) {
        if let (Some(af), Some(PerfReport { iters, elapsed })) = (self.inner_af.as_mut(), report) {
            af.record((rank % self.geom.rpn) as usize, iters, elapsed);
        }
    }

    /// Serve a phase-1 request: reserve, park, or terminate the rank.
    fn serve_get(&mut self, rank: u32) {
        match self.ledger.reserve() {
            Some((step, remaining, seq)) => {
                let af = self.inner_af_info();
                let chunk_len = self.ledger.current_len();
                self.send_worker(rank, Msg::Step { step, remaining, seq, chunk_len, af });
            }
            None if self.global_done => {
                self.send_worker(rank, Msg::Done);
                self.done_sent += 1;
            }
            None => {
                self.parked.push(rank);
                self.fetch();
            }
        }
    }

    fn park_or_done(&mut self, rank: u32) {
        if self.global_done {
            self.send_worker(rank, Msg::Done);
            self.done_sent += 1;
        } else {
            self.parked.push(rank);
            self.fetch();
        }
    }

    /// Re-serve every parked rank (after a node-chunk install or the global
    /// Done).
    fn unpark(&mut self) {
        let parked = std::mem::take(&mut self.parked);
        for rank in parked {
            self.serve_get(rank);
        }
    }

    /// Outer prefetch: request the next node-chunk while the current one is
    /// still being consumed, once it drops to the watermark.
    fn after_grant(&mut self) {
        if self.ledger.wants_prefetch(self.cfg.hier.prefetch_watermark) {
            self.fetch();
        }
    }

    /// Trigger an outer fetch unless one is already in flight; finalizes the
    /// consumed node-chunk's throughput report (outer-AF feedback).
    fn fetch(&mut self) {
        if self.fetching || self.global_done {
            return;
        }
        self.fetching = true;
        if self.installed_iters > 0 {
            let iters = self.installed_iters;
            let elapsed = self.installed_at.elapsed().as_secs_f64().max(1e-12);
            self.node_stats.record(iters, elapsed);
            self.outer_report = Some(PerfReport { iters, elapsed });
            self.installed_iters = 0;
        }
        let report = self.outer_report.take();
        self.send_coord(Msg::OuterGet { node: self.node, report });
    }

    fn inner_af_info(&self) -> Option<AfInfo> {
        self.inner_af.as_ref().and_then(|a| a.globals()).map(|g| AfInfo { d: g.d, e: g.e })
    }

    /// Outer chunk size, computed on this master (closed form of the outer
    /// technique at the reserved step, or AF's Eq. 11 over node throughput).
    fn outer_calc(&self, ticket: StepTicket, af: Option<AfInfo>) -> u64 {
        if self.cfg.technique == TechniqueKind::Af {
            af_requester_chunk(
                &self.node_stats,
                af.map(|i| AfGlobals { d: i.d, e: i.e }),
                ticket.remaining,
                self.geom.nodes,
                self.cfg.params.min_chunk.max(1),
            )
        } else {
            self.outer_tech
                .as_ref()
                .expect("non-AF outer technique has a closed form")
                .closed_chunk(ticket.step)
        }
    }

    // -- the master's own worker personality -------------------------------

    /// One self-scheduling step of the master's own personality: reserve →
    /// calculate (paying the injected delay) → commit → execute.
    fn own_step(&mut self) {
        let Some((step, remaining, seq)) = self.ledger.reserve() else { return };
        spin_for(self.cfg.delay.calculation);
        let size = self.own_calc(step, remaining, seq);
        spin_for(self.cfg.delay.assignment);
        match self.ledger.commit(step, size, seq) {
            InnerCommit::Granted(a) => {
                self.after_grant();
                self.execute_own(a);
            }
            // A fresh node-chunk replaced the current one mid-step (cannot
            // happen single-threadedly, but the protocol allows it) — the
            // main loop simply re-reserves.
            InnerCommit::Stale => {}
            InnerCommit::Drained => self.fetch(),
        }
    }

    fn own_calc(&self, step: u64, remaining: u64, seq: u64) -> u64 {
        if self.inner_kind == TechniqueKind::Af {
            af_requester_chunk(
                &self.my_stats,
                self.inner_af_info().map(|i| AfGlobals { d: i.d, e: i.e }),
                remaining,
                self.geom.rpn,
                self.cfg.params.min_chunk.max(1),
            )
        } else {
            self.ledger
                .closed_inner_size(step, seq)
                .unwrap_or_else(|| self.cfg.params.min_chunk.max(1))
        }
    }

    /// Execute an own chunk in `MASTER_SLICE`-iteration segments, draining
    /// the message queue between segments (non-dedicated master: local
    /// ranks keep being served while the master computes).
    fn execute_own(&mut self, a: Assignment) {
        let t = Instant::now();
        let mut sum = 0u64;
        let mut cursor = a.start;
        while cursor < a.end() {
            let len = MASTER_SLICE.min(a.end() - cursor);
            sum = sum.wrapping_add(self.workload.execute_range(cursor, len));
            cursor += len;
            while let Some(env) = self.ep.try_recv() {
                self.handle(env.payload);
            }
        }
        let elapsed = t.elapsed().as_secs_f64();
        self.out.checksum = self.out.checksum.wrapping_add(sum);
        self.out.chunks += 1;
        self.out.iters += a.size;
        self.out.assignments.push(a);
        self.my_stats.record(a.size, elapsed);
        if let Some(af) = self.inner_af.as_mut() {
            af.record(0, a.size, elapsed);
        }
    }
}

// ---------------------------------------------------------------------------
// local ranks

/// A local rank: flat-DCA-style two-phase self-scheduling against its node
/// master, with the node-chunk `seq` threaded through both phases.
fn worker_loop(
    cfg: &EngineConfig,
    geom: Geom,
    ep: Endpoint<Msg>,
    workload: Arc<dyn Workload>,
    barrier: &Barrier,
    tally: &Tally,
) -> RankSummary {
    let rank = ep.rank();
    let master = geom.master_rank(geom.node_of(rank));
    let inner_kind = cfg.hier.inner_or(cfg.technique);
    let is_af = inner_kind == TechniqueKind::Af;
    let bootstrap = cfg.params.min_chunk.max(1);
    // Inner technique bound to the current node-chunk, cached by `seq`.
    let mut bound: Option<(u64, Technique)> = None;
    let mut my_stats = PeStats::default();
    let mut out = RankSummary { rank, ..Default::default() };
    let mut report = None;
    let send = |dst: u32, msg: Msg| {
        tally.intra.fetch_add(1, Ordering::Relaxed);
        ep.send(dst, msg).expect("node master hung up early");
    };
    barrier.wait();
    let t0 = Instant::now();
    'outer: loop {
        let t_req = Instant::now();
        send(master, Msg::Get { rank, report });
        let mut env = ep.recv().expect("node master hung up early");
        out.sched_wait += t_req.elapsed().as_secs_f64();
        loop {
            match env.payload {
                Msg::Step { step, remaining, seq, chunk_len, af } => {
                    // Distributed inner calculation, on this rank's CPU —
                    // the injected delay is paid here, in parallel.
                    spin_for(cfg.delay.calculation);
                    let size = if is_af {
                        af_requester_chunk(
                            &my_stats,
                            af.map(|i| AfGlobals { d: i.d, e: i.e }),
                            remaining,
                            geom.rpn,
                            bootstrap,
                        )
                    } else {
                        if !bound.as_ref().is_some_and(|(s, _)| *s == seq) {
                            let params = with_np(&cfg.params, chunk_len, geom.rpn);
                            bound = Some((seq, Technique::new(inner_kind, &params)));
                        }
                        bound.as_ref().expect("technique bound above").1.closed_chunk(step)
                    };
                    let t_commit = Instant::now();
                    send(master, Msg::Commit { rank, step, size, seq });
                    env = ep.recv().expect("node master hung up early");
                    out.sched_wait += t_commit.elapsed().as_secs_f64();
                    // The reply is a Chunk, a NACK Step (stale seq), or Done
                    // — loop to handle whichever arrived.
                }
                Msg::Chunk(a) => {
                    let (sum, elapsed) = execute_chunk(workload.as_ref(), a);
                    out.checksum = out.checksum.wrapping_add(sum);
                    out.chunks += 1;
                    out.iters += a.size;
                    out.assignments.push(a);
                    my_stats.record(a.size, elapsed);
                    report = Some(PerfReport { iters: a.size, elapsed });
                    break;
                }
                Msg::Done => break 'outer,
                other => panic!("rank {rank}: unexpected {other:?}"),
            }
        }
    }
    out.finish = t0.elapsed().as_secs_f64();
    out
}
