//! The hierarchical model (`HierDca`) on **real threads** at any tree depth
//! — the wall-clock counterpart of the DES protocol in [`crate::hier`],
//! sharing its chunk-ledger state machine ([`crate::hier::protocol`]) so
//! both engines validate literally the same two-phase reserve/commit and
//! stale-`seq` NACK semantics at every level.
//!
//! Thread topology for `P` ranks under a depth-`k`
//! [`crate::config::LevelPlan`] (block placement, like
//! [`crate::substrate::topology::Topology`]):
//!
//! * the **root** (level 0) runs on the calling thread (fabric rank `P`),
//!   owns a ledger pre-installed with the whole loop, and serves the
//!   level-0 DCA protocol: `MGet → MStep` reserves a step, `MCommit →
//!   MChunk` grants a chunk. Chunk sizes are calculated **on the
//!   requesting masters** with the level-0 technique bound to
//!   `P = fanout₀` — distributed chunk calculation at tree granularity;
//! * each **hosting rank** (the first rank of a lowest-level group) is
//!   *non-dedicated*: it runs one master persona per tree level of its
//!   subtree spine — each persona serves its children's protocol from its
//!   own shared-[`NodeLedger`] and drives the parent protocol one level up
//!   (self-addressed messages when parent and child share the rank) — and
//!   executes iterations itself, draining its message queue between
//!   execution slices so children are never starved for a whole chunk;
//! * each **leaf rank** self-schedules against its master exactly like a
//!   flat DCA worker, with the chunk `seq` threaded through the two-phase
//!   exchange: phase-1 `Step` replies carry the chunk length so the worker
//!   binds the level technique itself (no shared memory), and a commit
//!   against a replaced chunk is NACKed into a fresh `Step`.
//!
//! **Prefetch** ([`crate::config::HierParams::watermark`]): every master
//! persona requests the next chunk once its current one drops to the
//! watermark; replies are staged in the ledger (a FIFO of configurable
//! depth) and promoted as the current chunk drains, hiding the parent round
//! trip. [`crate::config::WatermarkMode::Auto`] derives the watermark from
//! an EWMA of the persona's observed fetch round trip and its subtree's
//! measured drain rate.
//!
//! **Adaptive execution slice**: instead of a fixed 256-iteration drain
//! interval, a master slices its own chunk execution to target a bounded
//! service latency ([`SLICE_TARGET_LATENCY`]), recomputed per chunk from
//! its measured per-iteration cost — see [`master_slice`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Instant;

use super::protocol::{AfInfo, PerfReport};
use super::{execute_chunk, EngineConfig, RankSummary, RunResult};
use crate::config::{SchedPath, WatermarkMode};
use crate::hier::protocol::{
    auto_watermark, fast_len_ok, with_np, AtomicLedger, FastLedger, InnerCommit, NodeLedger,
    RttEwma,
};
use crate::obs::EngineMetrics;
use crate::sched::adaptive::{AdaptiveController, SwitchEvent};
use crate::sched::Assignment;
use crate::substrate::delay::spin_for;
use crate::substrate::msg::{fabric, Endpoint};
use crate::techniques::af::{af_requester_chunk, AfCalculator, AfGlobals, PeStats};
use crate::techniques::{Technique, TechniqueKind};
use crate::workload::Workload;

/// Service latency the adaptive execution slice targets: a master drains
/// its message queue at least this often while executing its own chunk.
const SLICE_TARGET_LATENCY: f64 = 200e-6;

/// Slice used until the master has measured its own per-iteration cost —
/// the historical fixed `MASTER_SLICE`.
const DEFAULT_MASTER_SLICE: u64 = 256;

/// Ceiling keeping one pathological (near-zero) cost sample from turning
/// the slice into "never drain".
const MAX_MASTER_SLICE: u64 = 65_536;

/// Iterations a master executes between message-queue drains, sized so one
/// slice occupies roughly [`SLICE_TARGET_LATENCY`] at the measured
/// per-iteration cost (`None` = not measured yet ⇒ the fixed default).
/// With long iterations (PSIA: 73 ms) this floors at 1, matching the A3
/// `breakAfter` ablation's guidance; with sub-µs iterations it caps at
/// [`MAX_MASTER_SLICE`] so drains still happen.
pub(crate) fn master_slice(per_iter_secs: Option<f64>) -> u64 {
    match per_iter_secs {
        Some(c) if c > 0.0 => ((SLICE_TARGET_LATENCY / c) as u64).clamp(1, MAX_MASTER_SLICE),
        _ => DEFAULT_MASTER_SLICE,
    }
}

/// Wire messages of all tiers (one fabric carries every protocol level;
/// master-tier messages name their protocol level explicitly).
#[derive(Debug, Clone, Copy)]
enum Msg {
    // -- leaf tier: leaf rank ↔ its lowest-level master ------------------
    /// Phase 1 request: "reserve me a local step" (+ AF perf piggyback).
    Get { rank: u32, report: Option<PerfReport> },
    /// Phase 1 reply: reserved step of chunk `seq`; `chunk_len` + `tech`
    /// let the worker bind the chunk's technique itself (the slot is
    /// re-bindable, so the wire must carry it), `remaining` feeds AF.
    Step {
        step: u64,
        remaining: u64,
        seq: u64,
        chunk_len: u64,
        tech: TechniqueKind,
        af: Option<AfInfo>,
    },
    /// Phase 2 request: "commit my locally calculated `size` for `step`".
    Commit { rank: u32, step: u64, size: u64, seq: u64 },
    /// Phase 2 reply: the granted absolute range.
    Chunk(Assignment),
    /// No work left anywhere — terminate.
    Done,
    // -- master tier: level-(level+1) master ↔ its level-`level` parent --
    /// Child master `from` asks its parent for a step (+ subtree-throughput
    /// piggyback for AF).
    MGet { level: u32, from: u32, report: Option<PerfReport> },
    /// Parent reply: reserved step (+ AF aggregates + the parent chunk's
    /// length and bound technique). Handling it *is* the chunk calculation,
    /// on the child master's CPU.
    MStep {
        level: u32,
        step: u64,
        remaining: u64,
        seq: u64,
        chunk_len: u64,
        tech: TechniqueKind,
        af: Option<AfInfo>,
    },
    /// Child master commits its chunk size.
    MCommit { level: u32, from: u32, step: u64, size: u64, seq: u64 },
    /// Parent reply: the committed chunk.
    MChunk { level: u32, a: Assignment },
    /// Parent reply: the parent's share of the loop is exhausted.
    MDone { level: u32 },
    /// Lock-free leaf only: a worker noticed the published chunk draining
    /// to the fixed watermark and nudges its master to prefetch — the
    /// master cannot observe CAS grants, so the watermark signal must
    /// travel as a message (once per chunk `seq`).
    Nudge { rank: u32 },
}

/// Block-placement geometry of the scheduling tree: a resolved
/// [`crate::config::LevelPlan`] (the single source of the placement math,
/// shared with the DES) plus a hot copy of its fan-outs. Latency classes
/// are unused here — latencies are real.
#[derive(Debug, Clone)]
struct Geom {
    plan: crate::config::LevelPlan,
    fanouts: Vec<u32>,
    p: u32,
}

impl Geom {
    fn k(&self) -> usize {
        self.fanouts.len()
    }

    /// Ranks under one level-`d` subtree.
    fn subtree(&self, d: usize) -> u32 {
        self.plan.subtree_ranks(d)
    }

    /// Rank hosting level-`d` master `j`.
    fn host_rank(&self, d: usize, j: u32) -> u32 {
        self.plan.host_rank(d, j)
    }

    /// The lowest-level group a rank belongs to (the "node" of the
    /// two-level special case — used for the intra/inter message split).
    fn group_of(&self, rank: u32) -> u32 {
        rank / self.fanouts[self.k() - 1]
    }

    /// Master levels hosted on `rank` (ascending; empty for leaf ranks).
    fn levels_of(&self, rank: u32) -> Vec<usize> {
        (1..self.k()).filter(|&d| rank % self.subtree(d) == 0).collect()
    }

    /// The root's fabric rank (the calling thread).
    fn coord(&self) -> u32 {
        self.p
    }
}

/// Message counters split by latency class and by protocol level. The
/// intra/inter classification matches the DES: endpoints are classified by
/// *hosting rank* (the root counts as rank 0 — the coordinator is hosted on
/// the first group's master on the real machine), so group-0 root traffic
/// is intra-node, keeping the split directly comparable across substrates.
#[derive(Debug)]
struct Tally {
    intra: AtomicU64,
    inter: AtomicU64,
    levels: Vec<AtomicU64>,
}

impl Tally {
    fn new(k: usize) -> Self {
        Tally {
            intra: AtomicU64::new(0),
            inter: AtomicU64::new(0),
            levels: (0..k).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Count one protocol-`level` message between hosting ranks `a` and `b`
    /// (pass the root as rank 0).
    fn count(&self, geom: &Geom, level: usize, a: u32, b: u32) {
        self.levels[level].fetch_add(1, Ordering::Relaxed);
        if geom.group_of(a) == geom.group_of(b) {
            self.intra.fetch_add(1, Ordering::Relaxed);
        } else {
            self.inter.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Run the threaded hierarchical engine: `P` rank threads (masters +
/// leaves) plus the root service loop on the calling thread.
pub fn run(cfg: &EngineConfig, workload: Arc<dyn Workload>) -> anyhow::Result<RunResult> {
    let p = cfg.params.p;
    anyhow::ensure!(p >= 1, "need at least one worker");
    anyhow::ensure!(cfg.nodes >= 1, "need at least one node");
    let plan = cfg.hier.plan_threaded(cfg.technique, p, cfg.nodes)?;
    anyhow::ensure!(
        plan.depth() >= 2,
        "the threaded hierarchical engine needs ≥ 2 levels; a depth-1 tree IS the \
         flat DCA protocol — run `--model dca` instead (the DES supports --levels 1)"
    );
    let fanouts: Vec<u32> = plan.levels.iter().map(|l| l.fanout).collect();
    let geom = Geom { plan, fanouts, p };
    let (mut eps, _sent) = fabric::<Msg>(p + 1);
    let coord_ep = eps.pop().expect("coordinator endpoint");
    let barrier = Arc::new(Barrier::new(p as usize + 1));
    let tally = Arc::new(Tally::new(geom.k()));

    // Lock-free leaf level: one shared CAS ledger per lowest-level group;
    // local ranks grant straight off it, the master stages/publishes into
    // it. AF/TAP leaves (and over-long loops) stay two-phase.
    let leaf_fanout = geom.fanouts[geom.k() - 1];
    let leaf_tech = cfg.hier.tech_of_level(geom.k() - 1, cfg.technique);
    let fast_leaf = cfg.sched_path.wants_lockfree()
        && leaf_tech.supports_fast_path()
        && fast_len_ok(cfg.params.n)
        // Memory guard: probe the worst-case leaf table (a node chunk can
        // be as long as the whole loop) under the step cap; a schedule too
        // big to tabulate keeps the leaf on the two-phase protocol.
        && crate::techniques::ChunkTable::build_capped(
            leaf_tech,
            &with_np(&cfg.params, cfg.params.n, leaf_fanout),
            crate::techniques::MAX_FAST_TABLE_STEPS,
        )
        .is_some();
    let shared_leaf: Option<Vec<Arc<AtomicLedger>>> = fast_leaf.then(|| {
        (0..p / leaf_fanout).map(|_| Arc::new(AtomicLedger::new())).collect()
    });

    let mut handles = Vec::with_capacity(p as usize);
    for ep in eps {
        let rank = ep.rank();
        let w = Arc::clone(&workload);
        let b = Arc::clone(&barrier);
        let t = Arc::clone(&tally);
        let c = cfg.clone();
        let g = geom.clone();
        let shared = shared_leaf
            .as_ref()
            .map(|v| Arc::clone(&v[(rank / leaf_fanout) as usize]));
        handles.push(thread::spawn(move || {
            if rank % g.fanouts[g.k() - 1] == 0 {
                TreeMaster::new(c, g, ep, w, t, shared).run(&b)
            } else {
                worker_loop(&c, &g, ep, w, &b, &t, shared)
            }
        }));
    }

    coordinator_loop(cfg, &geom, coord_ep, &barrier, &tally)?;

    let per_rank: Vec<RankSummary> =
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect();
    let intra = tally.intra.load(Ordering::Relaxed);
    let inter = tally.inter.load(Ordering::Relaxed);
    let levels = tally.levels.iter().map(|l| l.load(Ordering::Relaxed)).collect();
    Ok(RunResult::assemble_split(per_rank, intra, inter, levels))
}

// ---------------------------------------------------------------------------
// the root (global coordinator)

/// Level-0 service loop — assignment only, O(1) work per message; the chunk
/// *calculation* happens on the requesting masters. The root's ledger is
/// installed once with the whole loop, so its `seq` never moves and no
/// commit against it can be stale.
fn coordinator_loop(
    cfg: &EngineConfig,
    geom: &Geom,
    ep: Endpoint<Msg>,
    barrier: &Barrier,
    tally: &Tally,
) -> anyhow::Result<()> {
    let f0 = geom.fanouts[0];
    let outer_params = with_np(&cfg.params, cfg.params.n, f0);
    let is_af = cfg.technique == TechniqueKind::Af;
    let mut af = is_af.then(|| AfCalculator::new(&outer_params));
    let mut ledger = NodeLedger::new(cfg.technique, &cfg.params, f0);
    ledger.install(Assignment { step: 0, start: 0, size: cfg.params.n });
    let mut active = f0;

    let send = |ep: &Endpoint<Msg>, dst: u32, msg: Msg| -> anyhow::Result<()> {
        // The root is hosted on rank 0 for classification purposes.
        tally.count(geom, 0, 0, dst);
        ep.send(dst, msg)?;
        Ok(())
    };

    barrier.wait();
    while active > 0 {
        let env = ep.recv()?;
        match env.payload {
            Msg::MGet { level: 0, from, report } => {
                if let (Some(af), Some(PerfReport { iters, elapsed })) = (af.as_mut(), report) {
                    af.record(from as usize, iters, elapsed);
                }
                let reply = match ledger.reserve() {
                    Some((step, remaining, seq)) => {
                        let info = af
                            .as_ref()
                            .and_then(|a| a.globals())
                            .map(|g| AfInfo { d: g.d, e: g.e });
                        Msg::MStep {
                            level: 0,
                            step,
                            remaining,
                            seq,
                            chunk_len: ledger.current_len(),
                            // The root's slot is never rebound (its chunk is
                            // installed once) — always the outer technique.
                            tech: ledger.chunk_kind(seq).unwrap_or(cfg.technique),
                            af: info,
                        }
                    }
                    None => {
                        active -= 1;
                        Msg::MDone { level: 0 }
                    }
                };
                send(&ep, env.src, reply)?;
            }
            Msg::MCommit { level: 0, from: _, step, size, seq } => {
                // Chunk ASSIGNMENT — the only synchronized root operation.
                spin_for(cfg.delay.assignment);
                // (Outer AF's fresh-R re-cap happens inside the ledger.)
                let reply = match ledger.commit(step, size, seq) {
                    InnerCommit::Granted(a) => Msg::MChunk { level: 0, a },
                    InnerCommit::Stale => {
                        unreachable!("the root's chunk is never replaced, so seq cannot go stale")
                    }
                    InnerCommit::Drained => {
                        active -= 1;
                        Msg::MDone { level: 0 }
                    }
                };
                send(&ep, env.src, reply)?;
            }
            other => anyhow::bail!("hier coordinator got unexpected message: {other:?}"),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// hosting ranks (master personas + own worker personality)

/// One master persona: the server side of protocol `level` (its ledger and
/// parked children) plus its child side in protocol `level - 1`.
struct TPersona {
    /// Protocol level this persona serves (`1..=k-1`; the root is level 0
    /// on the calling thread).
    level: usize,
    /// Master index at this level.
    index: u32,
    ledger: NodeLedger,
    /// Children whose requests arrived while the ledger was empty: leaf
    /// ranks at the deepest level, child master indices elsewhere.
    parked: Vec<u32>,
    fetching: bool,
    global_done: bool,
    /// `Done` replies sent to children (termination tracking).
    done_sent: u32,
    /// AF calculator over this persona's children (when this level's
    /// technique is AF).
    af_calc: Option<AfCalculator>,
    /// Subtree chunk-throughput statistics (upward-AF feedback + adaptive
    /// watermark drain rate).
    stats: PeStats,
    pending_report: Option<PerfReport>,
    installed_iters: u64,
    installed_at: Instant,
    /// When the in-flight parent fetch was issued (adaptive watermark).
    fetch_sent: Instant,
    /// EWMA of observed parent-fetch round trips (shared protocol policy).
    rtt: RttEwma,
    /// Child-side closed-form binding for protocol `level - 1`, cached by
    /// the parent chunk's `seq`.
    bound: Option<(u64, Technique)>,
    /// SimAS-style controller re-binding this persona's technique slot
    /// (`--adaptive`).
    adapt: Option<AdaptiveController>,
}

/// A non-dedicated hosting rank: serves every master persona of its subtree
/// spine, drives each persona's parent protocol, and executes iterations
/// itself between message drains.
struct TreeMaster {
    cfg: EngineConfig,
    geom: Geom,
    ep: Endpoint<Msg>,
    workload: Arc<dyn Workload>,
    tally: Arc<Tally>,
    /// Personas hosted here, ascending by level; the last one serves the
    /// leaf protocol and backs the own worker personality.
    personas: Vec<TPersona>,
    /// Lock-free leaf ledger (Some ⇒ the leaf persona's `NodeLedger` is
    /// bypassed: local ranks CAS-grant off the shared word, this master
    /// stages/publishes installs and serves slow-path refills).
    fast: Option<FastLedger>,
    /// The rank's own worker-personality statistics (AF µ/σ + the adaptive
    /// execution slice's per-iteration cost).
    my_stats: PeStats,
    /// Wall-clock anchor for controller observations and switch events.
    t0: Instant,
    out: RankSummary,
    /// Streaming-observability handles (None when no registry is attached).
    em: Option<EngineMetrics>,
}

impl TreeMaster {
    fn new(
        cfg: EngineConfig,
        geom: Geom,
        ep: Endpoint<Msg>,
        workload: Arc<dyn Workload>,
        tally: Arc<Tally>,
        fast_shared: Option<Arc<AtomicLedger>>,
    ) -> Self {
        let rank = ep.rank();
        let n = cfg.params.n;
        let staged_cap = cfg.hier.staged_capacity();
        let k1 = geom.k() - 1;
        let fast = fast_shared.map(|shared| {
            FastLedger::new(
                shared,
                cfg.hier.tech_of_level(k1, cfg.technique),
                &cfg.params,
                geom.fanouts[k1],
                staged_cap,
            )
        });
        // Pure LockFree restricts leaf candidates to fast-path techniques
        // (rebinds republish, never demote); Auto keeps the full set.
        let leaf_fast_only = cfg.sched_path == SchedPath::LockFree && fast.is_some();
        let k1_level = geom.k() - 1;
        let personas = geom
            .levels_of(rank)
            .into_iter()
            .map(|level| {
                let tech = cfg.hier.tech_of_level(level, cfg.technique);
                let fanout = geom.fanouts[level];
                TPersona {
                    level,
                    index: rank / geom.subtree(level),
                    ledger: NodeLedger::new(tech, &cfg.params, fanout)
                        .with_staged_capacity(staged_cap),
                    parked: Vec::new(),
                    fetching: false,
                    global_done: false,
                    done_sent: 0,
                    af_calc: (tech == TechniqueKind::Af)
                        .then(|| AfCalculator::new(&with_np(&cfg.params, n, fanout))),
                    stats: PeStats::default(),
                    pending_report: None,
                    installed_iters: 0,
                    installed_at: Instant::now(),
                    fetch_sent: Instant::now(),
                    rtt: RttEwma::default(),
                    bound: None,
                    adapt: cfg.hier.adaptive.enabled.then(|| {
                        AdaptiveController::new(
                            tech,
                            &cfg.params,
                            fanout,
                            cfg.hier.adaptive,
                            leaf_fast_only && level == k1_level,
                        )
                    }),
                }
            })
            .collect();
        let em = cfg.metrics.as_deref().map(EngineMetrics::register);
        TreeMaster {
            cfg,
            geom,
            ep,
            workload,
            tally,
            personas,
            fast,
            my_stats: PeStats::default(),
            t0: Instant::now(),
            out: RankSummary { rank, ..Default::default() },
            em,
        }
    }

    /// Unassigned work at the leaf level, whichever ledger form holds it.
    fn leaf_has_work(&self) -> bool {
        match &self.fast {
            Some(f) => f.has_work(),
            None => self.personas[self.leaf_slot()].ledger.has_work(),
        }
    }

    /// Persona slot serving protocol `level` (hosted here by construction).
    fn slot(&self, level: usize) -> usize {
        self.personas
            .iter()
            .position(|pr| pr.level == level)
            .expect("persona for this level is hosted on this rank")
    }

    /// The leaf-serving persona's slot (always the deepest one).
    fn leaf_slot(&self) -> usize {
        self.personas.len() - 1
    }

    fn run(mut self, barrier: &Barrier) -> RankSummary {
        barrier.wait();
        let t0 = Instant::now();
        self.t0 = t0;
        for pr in &mut self.personas {
            pr.installed_at = Instant::now();
        }
        // Kick the fetch chain: the leaf persona asks its parent, which (on
        // this or another rank) asks its parent, … up to the root.
        self.fetch(self.leaf_slot());
        loop {
            // Serve everything pending before (and between) own work.
            while let Some(env) = self.ep.try_recv() {
                self.handle(env.payload);
            }
            if self.finished() {
                break;
            }
            if self.leaf_has_work() {
                self.own_step();
                continue;
            }
            // Leaf ledger drained: make sure the next chunk is on its way
            // (idempotent — no-op when a fetch is in flight or the loop is
            // done). Without this, a master whose *own* grant consumed the
            // last iterations would block below with no fetch pending and,
            // with no children to wake it, deadlock — the DES counterpart
            // is `Own::NeedWork`'s park + fetch.
            self.fetch(self.leaf_slot());
            // Nothing local to do: block until a reply (or a late request)
            // arrives. This is the master's scheduling wait.
            let t_wait = Instant::now();
            match self.ep.recv() {
                Ok(env) => {
                    self.out.sched_wait += t_wait.elapsed().as_secs_f64();
                    self.handle(env.payload);
                }
                Err(_) => break,
            }
        }
        self.out.finish = t0.elapsed().as_secs_f64();
        self.out
    }

    /// Every persona terminated: its parent said Done, its ledger drained,
    /// and every child got its Done (the own personality is the one leaf
    /// child that is not messaged).
    fn finished(&self) -> bool {
        let k1 = self.geom.k() - 1;
        self.personas.iter().all(|pr| {
            let target = if pr.level == k1 {
                self.geom.fanouts[pr.level] - 1
            } else {
                self.geom.fanouts[pr.level]
            };
            let has_work =
                if pr.level == k1 { self.leaf_has_work() } else { pr.ledger.has_work() };
            pr.global_done && !has_work && pr.done_sent == target
        })
    }

    // -- messaging ---------------------------------------------------------

    /// Send a protocol-`level` message to fabric rank `dst`, classified
    /// between hosting ranks `a` and `b`.
    fn send_msg(&self, level: usize, a: u32, b: u32, dst: u32, msg: Msg) {
        self.tally.count(&self.geom, level, a, b);
        self.ep.send(dst, msg).expect("peer hung up early");
    }

    fn send_worker(&self, rank: u32, msg: Msg) {
        self.send_msg(self.geom.k() - 1, self.out.rank, rank, rank, msg);
    }

    /// Send to the parent of persona `slot` (the root when its level is 1).
    fn send_parent(&self, slot: usize, msg: Msg) {
        let pr = &self.personas[slot];
        let d = pr.level - 1;
        if d == 0 {
            // Fabric rank P, classified as hosted on rank 0.
            self.send_msg(0, self.out.rank, 0, self.geom.coord(), msg);
        } else {
            let parent = self.geom.host_rank(d, pr.index / self.geom.fanouts[d]);
            self.send_msg(d, self.out.rank, parent, parent, msg);
        }
    }

    /// Send a serve-side reply from persona `slot` to child master `to`.
    fn send_child_master(&self, slot: usize, to: u32, msg: Msg) {
        let level = self.personas[slot].level;
        let child = self.geom.host_rank(level + 1, to);
        self.send_msg(level, self.out.rank, child, child, msg);
    }

    // -- service -----------------------------------------------------------

    fn handle(&mut self, msg: Msg) {
        match msg {
            Msg::Get { rank, report } => {
                let slot = self.leaf_slot();
                self.record_child_report(slot, rank % self.geom.fanouts[self.geom.k() - 1], report);
                if self.fast.is_some() {
                    self.serve_get_fast(rank);
                } else {
                    self.serve_get(rank);
                }
            }
            Msg::Nudge { rank: _ } => {
                // Lock-free prefetch signal: a worker saw the published
                // chunk drain to the fixed watermark.
                let slot = self.leaf_slot();
                self.after_grant(slot);
            }
            Msg::Commit { rank, step, size, seq } => {
                // Leaf chunk ASSIGNMENT — serialized on this rank's CPU, but
                // only contended by its own group's ranks.
                spin_for(self.cfg.delay.assignment);
                let slot = self.leaf_slot();
                match self.personas[slot].ledger.commit(step, size, seq) {
                    InnerCommit::Granted(a) => {
                        self.send_worker(rank, Msg::Chunk(a));
                        self.adaptive_tick(slot);
                        self.after_grant(slot);
                    }
                    // Stale seq: the chunk was replaced while this commit
                    // was in flight — NACK into a fresh phase 1.
                    InnerCommit::Stale => self.serve_get(rank),
                    InnerCommit::Drained => self.park_or_done(slot, rank),
                }
            }
            Msg::MGet { level, from, report } => {
                let slot = self.slot(level as usize);
                let local = from % self.geom.fanouts[level as usize];
                self.record_child_report(slot, local, report);
                self.serve_mget(slot, from);
            }
            Msg::MCommit { level, from, step, size, seq } => {
                spin_for(self.cfg.delay.assignment);
                let slot = self.slot(level as usize);
                match self.personas[slot].ledger.commit(step, size, seq) {
                    InnerCommit::Granted(a) => {
                        self.send_child_master(slot, from, Msg::MChunk { level, a });
                        self.adaptive_tick(slot);
                        self.after_grant(slot);
                    }
                    InnerCommit::Stale => self.serve_mget(slot, from),
                    InnerCommit::Drained => self.park_or_done(slot, from),
                }
            }
            Msg::MStep { level, step, remaining, seq, chunk_len, tech, af } => {
                // The chunk CALCULATION runs here, on the child master's own
                // CPU — distributed across the tree, paying the injected
                // delay in parallel (the DCA idea, at every level).
                spin_for(self.cfg.delay.calculation);
                let slot = self.slot(level as usize + 1);
                let size = self.child_calc(slot, step, remaining, seq, chunk_len, tech, af);
                let from = self.personas[slot].index;
                self.send_parent(slot, Msg::MCommit { level, from, step, size, seq });
            }
            Msg::MChunk { level, a } => {
                let slot = self.slot(level as usize + 1);
                self.install(slot, a);
            }
            Msg::MDone { level } => {
                let slot = self.slot(level as usize + 1);
                self.personas[slot].fetching = false;
                self.personas[slot].global_done = true;
                self.unpark(slot);
            }
            other => panic!("hosting rank {}: unexpected {other:?}", self.out.rank),
        }
    }

    fn record_child_report(&mut self, slot: usize, local: u32, report: Option<PerfReport>) {
        if let (Some(af), Some(PerfReport { iters, elapsed })) =
            (self.personas[slot].af_calc.as_mut(), report)
        {
            af.record(local as usize, iters, elapsed);
        }
        let now_s = self.t0.elapsed().as_secs_f64();
        let leaf_fast = self.fast.is_some() && slot == self.leaf_slot();
        if let (Some(ctl), Some(PerfReport { iters, elapsed })) =
            (self.personas[slot].adapt.as_mut(), report)
        {
            if leaf_fast {
                // CAS-path reports aggregate every chunk since the child's
                // previous slow-path request — µ̂/σ̂ only; a whole-window
                // gap is not a per-grant overhead sample.
                ctl.observe_exec(iters, elapsed);
            } else {
                ctl.observe_chunk(local, iters, elapsed, now_s);
            }
        }
    }

    /// Count one grant served from persona `slot`'s ledger toward its probe
    /// cadence; on a due probe, rebind the slot — mid-chunk on the
    /// two-phase ledger ([`NodeLedger::rebind_now`], in-flight commits NACK
    /// via the stale `seq`), freeze-and-republish on the lock-free leaf
    /// ([`FastLedger::rebind`]), or **demote the leaf to two-phase**
    /// ([`FastLedger::demote`]) when the new binding is measurement-coupled
    /// (the `SchedPath::Auto` fallback).
    fn adaptive_tick(&mut self, slot: usize) {
        let due = match self.personas[slot].adapt.as_mut() {
            Some(ctl) => ctl.tick_grant(),
            None => return,
        };
        if !due {
            return;
        }
        let leaf = self.personas[slot].level == self.geom.k() - 1;
        let remaining = match &self.fast {
            Some(f) if leaf => f.shared().remaining(),
            _ => self.personas[slot].ledger.remaining(),
        };
        let from = match &self.fast {
            Some(f) if leaf => f.bound_kind(),
            _ => self.personas[slot].ledger.bound_kind(),
        };
        // On the CAS path the per-grant cost is one atomic op — probe with
        // zero overhead (tail imbalance is all that is left to optimize);
        // everywhere else, with the measured overhead EWMA.
        let ctl = self.personas[slot].adapt.as_mut().expect("checked above");
        let decision = if leaf && self.fast.is_some() {
            ctl.probe_on_fast_path(remaining)
        } else {
            ctl.probe(remaining)
        };
        let Some((to, predicted_ratio)) = decision else { return };
        if leaf && self.fast.is_some() {
            if to.supports_fast_path() {
                self.fast.as_mut().expect("checked").rebind(to);
            } else {
                // Demote: freeze the CAS word for good, move every
                // unassigned range into the two-phase ledger under the new
                // binding, and serve this group over messages from now on.
                let moved = self.fast.take().expect("checked").demote();
                self.personas[slot].ledger.rebind(to);
                for a in moved {
                    self.personas[slot].ledger.install(a);
                }
                // Parked ranks (if any) re-serve through the slow path.
                self.unpark(slot);
            }
        } else {
            self.personas[slot].ledger.rebind_now(to);
        }
        if let Some(m) = &self.em {
            m.switches.inc();
        }
        self.out.switches.push(SwitchEvent {
            at_s: self.t0.elapsed().as_secs_f64(),
            level: self.personas[slot].level as u32,
            master: self.personas[slot].index,
            from,
            to,
            predicted_ratio,
        });
    }

    fn af_info(&self, slot: usize) -> Option<AfInfo> {
        self.personas[slot]
            .af_calc
            .as_ref()
            .and_then(|a| a.globals())
            .map(|g| AfInfo { d: g.d, e: g.e })
    }

    /// Serve a leaf phase-1 request: reserve, park, or terminate the rank.
    fn serve_get(&mut self, rank: u32) {
        let slot = self.leaf_slot();
        match self.personas[slot].ledger.reserve() {
            Some((step, remaining, seq)) => {
                let af = self.af_info(slot);
                let ledger = &self.personas[slot].ledger;
                let chunk_len = ledger.current_len();
                let tech = ledger.chunk_kind(seq).unwrap_or_else(|| ledger.bound_kind());
                self.send_worker(rank, Msg::Step { step, remaining, seq, chunk_len, tech, af });
            }
            None if self.personas[slot].global_done => {
                self.send_worker(rank, Msg::Done);
                self.personas[slot].done_sent += 1;
            }
            None => {
                self.personas[slot].parked.push(rank);
                self.fetch(slot);
            }
        }
    }

    /// Serve a master-tier phase-1 request at persona `slot` from child
    /// master `to` — the same logic as the leaf path, one level up.
    fn serve_mget(&mut self, slot: usize, to: u32) {
        let level = self.personas[slot].level as u32;
        match self.personas[slot].ledger.reserve() {
            Some((step, remaining, seq)) => {
                let af = self.af_info(slot);
                let ledger = &self.personas[slot].ledger;
                let chunk_len = ledger.current_len();
                let tech = ledger.chunk_kind(seq).unwrap_or_else(|| ledger.bound_kind());
                self.send_child_master(
                    slot,
                    to,
                    Msg::MStep { level, step, remaining, seq, chunk_len, tech, af },
                );
            }
            None if self.personas[slot].global_done => {
                self.send_child_master(slot, to, Msg::MDone { level });
                self.personas[slot].done_sent += 1;
            }
            None => {
                self.personas[slot].parked.push(to);
                self.fetch(slot);
            }
        }
    }

    fn park_or_done(&mut self, slot: usize, child: u32) {
        if self.personas[slot].global_done {
            if self.personas[slot].level == self.geom.k() - 1 {
                self.send_worker(child, Msg::Done);
            } else {
                let level = self.personas[slot].level as u32;
                self.send_child_master(slot, child, Msg::MDone { level });
            }
            self.personas[slot].done_sent += 1;
        } else {
            self.personas[slot].parked.push(child);
            self.fetch(slot);
        }
    }

    /// Re-serve every parked child (after a chunk install or the Done).
    fn unpark(&mut self, slot: usize) {
        let parked = std::mem::take(&mut self.personas[slot].parked);
        let leaf = self.personas[slot].level == self.geom.k() - 1;
        for child in parked {
            if leaf && self.fast.is_some() {
                self.serve_get_fast(child);
            } else if leaf {
                self.serve_get(child);
            } else {
                self.serve_mget(slot, child);
            }
        }
    }

    /// Resolve persona `slot`'s prefetch watermark: the shared
    /// [`auto_watermark`] policy over wall-clock inputs (the DES resolves
    /// identically over virtual time).
    fn watermark(&self, slot: usize) -> Option<u64> {
        match self.cfg.hier.watermark {
            WatermarkMode::Off => None,
            WatermarkMode::Fixed(w) => Some(w),
            WatermarkMode::Auto => {
                let pr = &self.personas[slot];
                Some(auto_watermark(pr.rtt.value(), pr.stats.mu()))
            }
        }
    }

    /// Prefetch: request the next chunk while the current one is still
    /// being consumed, once it drops to the watermark (and the staged queue
    /// has room).
    fn after_grant(&mut self, slot: usize) {
        let watermark = self.watermark(slot);
        let wants = match &self.fast {
            Some(f) if slot == self.leaf_slot() => f.wants_prefetch(watermark),
            _ => self.personas[slot].ledger.wants_prefetch(watermark),
        };
        if wants {
            self.fetch(slot);
        }
    }

    /// Serve a leaf phase-1 request on the lock-free path (reached through
    /// the slow-path refill: a worker found the CAS word drained): the
    /// master performs the fused grant on the worker's behalf — promoting
    /// staged chunks — or parks it behind a parent fetch.
    fn serve_get_fast(&mut self, rank: u32) {
        let slot = self.leaf_slot();
        if self.fast.is_none() {
            // Demoted while this request was queued — serve two-phase.
            self.serve_get(rank);
            return;
        }
        match self.fast.as_mut().expect("fast leaf mode").grant() {
            Some((a, _remaining)) => {
                self.out.fast_grants += 1;
                self.send_worker(rank, Msg::Chunk(a));
                self.adaptive_tick(slot);
                self.after_grant(slot);
            }
            None if self.personas[slot].global_done => {
                self.send_worker(rank, Msg::Done);
                self.personas[slot].done_sent += 1;
            }
            None => {
                self.personas[slot].parked.push(rank);
                self.fetch(slot);
            }
        }
    }

    /// Trigger persona `slot`'s parent fetch unless one is already in
    /// flight; finalizes the consumed chunk's throughput report (upward-AF
    /// feedback) and stamps the fetch time for the round-trip EWMA.
    fn fetch(&mut self, slot: usize) {
        if self.personas[slot].fetching || self.personas[slot].global_done {
            return;
        }
        let pr = &mut self.personas[slot];
        pr.fetching = true;
        if pr.installed_iters > 0 {
            let iters = pr.installed_iters;
            let elapsed = pr.installed_at.elapsed().as_secs_f64().max(1e-12);
            pr.stats.record(iters, elapsed);
            pr.pending_report = Some(PerfReport { iters, elapsed });
            pr.installed_iters = 0;
        }
        pr.fetch_sent = Instant::now();
        let report = pr.pending_report.take();
        let level = (pr.level - 1) as u32;
        let from = pr.index;
        self.send_parent(slot, Msg::MGet { level, from, report });
    }

    /// Install a chunk fetched over the parent protocol into persona
    /// `slot`'s ledger (the lock-free form at a fast leaf).
    fn install(&mut self, slot: usize, a: Assignment) {
        let leaf = self.personas[slot].level == self.geom.k() - 1;
        let pr = &mut self.personas[slot];
        pr.rtt.observe(pr.fetch_sent.elapsed().as_secs_f64());
        pr.fetching = false;
        if pr.installed_iters == 0 {
            pr.installed_at = Instant::now();
        }
        pr.installed_iters += a.size;
        match &mut self.fast {
            Some(f) if leaf => f.install(a),
            _ => pr.ledger.install(a),
        }
        self.unpark(slot);
    }

    /// Child-side chunk-size calculation for persona `slot`'s parent
    /// protocol (AF's Eq. 11 over subtree throughput, or the technique the
    /// parent's `MStep` announced, bound to the parent chunk and cached by
    /// `seq` — rebinds always bump the parent's `seq`, so the cache key
    /// stays sound).
    fn child_calc(
        &mut self,
        slot: usize,
        step: u64,
        remaining: u64,
        seq: u64,
        chunk_len: u64,
        tech: TechniqueKind,
        af: Option<AfInfo>,
    ) -> u64 {
        let d = self.personas[slot].level - 1;
        if tech == TechniqueKind::Af {
            af_requester_chunk(
                &self.personas[slot].stats,
                af.map(|i| AfGlobals { d: i.d, e: i.e }),
                remaining,
                self.geom.fanouts[d],
                self.cfg.params.min_chunk.max(1),
            )
        } else {
            let fanout = self.geom.fanouts[d];
            let params = with_np(&self.cfg.params, chunk_len, fanout);
            let pr = &mut self.personas[slot];
            if !pr.bound.as_ref().is_some_and(|(s, _)| *s == seq) {
                pr.bound = Some((seq, Technique::new(tech, &params)));
            }
            pr.bound.as_ref().expect("technique bound above").1.closed_chunk(step)
        }
    }

    // -- the rank's own worker personality ---------------------------------

    /// One self-scheduling step of the rank's own personality against the
    /// leaf persona's ledger: reserve → calculate (paying the injected
    /// delay) → commit → execute. On the lock-free path the whole exchange
    /// is one CAS (racing fairly with this group's local ranks) and no
    /// calculation delay exists to pay.
    fn own_step(&mut self) {
        let slot = self.leaf_slot();
        if self.fast.is_some() {
            let granted = self.fast.as_mut().expect("checked").grant();
            match granted {
                Some((a, _remaining)) => {
                    self.out.fast_grants += 1;
                    if let Some(m) = &self.em {
                        m.on_grant(a.size, 0.0, true);
                    }
                    self.adaptive_tick(slot);
                    self.after_grant(slot);
                    self.execute_own(a);
                }
                None => self.fetch(slot),
            }
            return;
        }
        let Some((step, remaining, seq)) = self.personas[slot].ledger.reserve() else { return };
        spin_for(self.cfg.delay.calculation);
        let size = self.own_calc(slot, step, remaining, seq);
        spin_for(self.cfg.delay.assignment);
        match self.personas[slot].ledger.commit(step, size, seq) {
            InnerCommit::Granted(a) => {
                // The master's own grants never cross the wire — account
                // them on the message-free path whatever the ledger form.
                if let Some(m) = &self.em {
                    m.on_grant(a.size, 0.0, true);
                }
                self.adaptive_tick(slot);
                self.after_grant(slot);
                self.execute_own(a);
            }
            // A fresh chunk replaced the current one mid-step (cannot
            // happen single-threadedly, but the protocol allows it) — the
            // main loop simply re-reserves.
            InnerCommit::Stale => {}
            InnerCommit::Drained => self.fetch(slot),
        }
    }

    fn own_calc(&self, slot: usize, step: u64, remaining: u64, seq: u64) -> u64 {
        let k1 = self.geom.k() - 1;
        // The binding follows the CHUNK the step was reserved from — the
        // slot may have been rebound since the configured level technique.
        match self.personas[slot].ledger.chunk_kind(seq) {
            Some(TechniqueKind::Af) => af_requester_chunk(
                &self.my_stats,
                self.af_info(slot).map(|i| AfGlobals { d: i.d, e: i.e }),
                remaining,
                self.geom.fanouts[k1],
                self.cfg.params.min_chunk.max(1),
            ),
            _ => self
                .personas[slot]
                .ledger
                .closed_inner_size(step, seq)
                .unwrap_or_else(|| self.cfg.params.min_chunk.max(1)),
        }
    }

    /// Execute an own chunk in adaptive slices, draining the message queue
    /// between segments (non-dedicated master: children keep being served
    /// while this rank computes). The slice targets a bounded service
    /// latency from the measured per-iteration cost — see [`master_slice`].
    fn execute_own(&mut self, a: Assignment) {
        let slice = master_slice(self.my_stats.mu());
        let t = Instant::now();
        let mut sum = 0u64;
        let mut cursor = a.start;
        while cursor < a.end() {
            let len = slice.min(a.end() - cursor);
            sum = sum.wrapping_add(self.workload.execute_range(cursor, len));
            cursor += len;
            while let Some(env) = self.ep.try_recv() {
                self.handle(env.payload);
            }
        }
        let elapsed = t.elapsed().as_secs_f64();
        self.out.record_chunk(sum, a);
        self.my_stats.record(a.size, elapsed);
        let slot = self.leaf_slot();
        if let Some(af) = self.personas[slot].af_calc.as_mut() {
            af.record(0, a.size, elapsed);
        }
        // Own executions feed the leaf controller's µ̂/σ̂ (exec-only: the
        // master's inter-chunk gaps are full of its service duties, not
        // per-grant overhead).
        if let Some(ctl) = self.personas[slot].adapt.as_mut() {
            ctl.observe_exec(a.size, elapsed);
        }
    }
}

// ---------------------------------------------------------------------------
// leaf ranks

/// A leaf rank: flat-DCA-style two-phase self-scheduling against its
/// lowest-level master, with the chunk `seq` threaded through both phases —
/// or, on the lock-free fast path, straight CAS grants off the group's
/// shared ledger word.
fn worker_loop(
    cfg: &EngineConfig,
    geom: &Geom,
    ep: Endpoint<Msg>,
    workload: Arc<dyn Workload>,
    barrier: &Barrier,
    tally: &Tally,
    fast: Option<Arc<AtomicLedger>>,
) -> RankSummary {
    if let Some(ledger) = fast {
        return lockfree_leaf_loop(cfg, geom, ep, &ledger, workload, barrier, tally);
    }
    let rank = ep.rank();
    let k1 = geom.k() - 1;
    let leaf_fanout = geom.fanouts[k1];
    let master = rank - rank % leaf_fanout;
    let bootstrap = cfg.params.min_chunk.max(1);
    // Leaf technique bound to the current chunk, cached by `seq` (rebinds
    // always bump the master's `seq`, so the key stays sound; the kind
    // itself travels on every `Step`).
    let mut bound: Option<(u64, Technique)> = None;
    let mut my_stats = PeStats::default();
    let mut out = RankSummary { rank, ..Default::default() };
    let mut report = None;
    let em = cfg.metrics.as_deref().map(EngineMetrics::register);
    let send = |dst: u32, msg: Msg| {
        tally.count(geom, k1, rank, dst);
        ep.send(dst, msg).expect("master hung up early");
    };
    barrier.wait();
    let t0 = Instant::now();
    'outer: loop {
        let t_req = Instant::now();
        send(master, Msg::Get { rank, report });
        let mut env = ep.recv().expect("master hung up early");
        let mut wait = t_req.elapsed().as_secs_f64();
        out.sched_wait += wait;
        loop {
            match env.payload {
                Msg::Step { step, remaining, seq, chunk_len, tech, af } => {
                    // Distributed leaf calculation, on this rank's CPU — the
                    // injected delay is paid here, in parallel.
                    spin_for(cfg.delay.calculation);
                    let size = if tech == TechniqueKind::Af {
                        af_requester_chunk(
                            &my_stats,
                            af.map(|i| AfGlobals { d: i.d, e: i.e }),
                            remaining,
                            leaf_fanout,
                            bootstrap,
                        )
                    } else {
                        if !bound.as_ref().is_some_and(|(s, _)| *s == seq) {
                            let params = with_np(&cfg.params, chunk_len, leaf_fanout);
                            bound = Some((seq, Technique::new(tech, &params)));
                        }
                        bound.as_ref().expect("technique bound above").1.closed_chunk(step)
                    };
                    let t_commit = Instant::now();
                    send(master, Msg::Commit { rank, step, size, seq });
                    env = ep.recv().expect("master hung up early");
                    let commit_wait = t_commit.elapsed().as_secs_f64();
                    out.sched_wait += commit_wait;
                    wait += commit_wait;
                    // The reply is a Chunk, a NACK Step (stale seq), or Done
                    // — loop to handle whichever arrived.
                }
                Msg::Chunk(a) => {
                    if let Some(m) = &em {
                        m.on_grant(a.size, wait, false);
                    }
                    let (sum, elapsed) = execute_chunk(workload.as_ref(), a);
                    out.record_chunk(sum, a);
                    my_stats.record(a.size, elapsed);
                    report = Some(PerfReport { iters: a.size, elapsed });
                    break;
                }
                Msg::Done => break 'outer,
                other => panic!("rank {rank}: unexpected {other:?}"),
            }
        }
    }
    out.finish = t0.elapsed().as_secs_f64();
    out
}

/// The lock-free leaf loop: CAS-grant off the shared word; when it drains,
/// fall back to the two-phase slow path (`Get` → the master promotes a
/// staged chunk / parks us behind a parent fetch → `Chunk` or `Done`).
/// Under a fixed prefetch watermark the worker nudges its master once per
/// chunk when the tail crosses the watermark — the master cannot observe
/// CAS grants, so the signal travels as a message.
///
/// The slow path also speaks the full two-phase `Step → Commit` exchange:
/// once a `SchedPath::Auto` master **demotes** the group (an adaptive
/// rebind to a measurement-coupled technique), the frozen word never
/// grants again and every subsequent chunk arrives through this protocol,
/// sized by the technique each `Step` announces (always closed-form — AF
/// can never be rebound to).
fn lockfree_leaf_loop(
    cfg: &EngineConfig,
    geom: &Geom,
    ep: Endpoint<Msg>,
    ledger: &AtomicLedger,
    workload: Arc<dyn Workload>,
    barrier: &Barrier,
    tally: &Tally,
) -> RankSummary {
    let rank = ep.rank();
    let k1 = geom.k() - 1;
    let leaf_fanout = geom.fanouts[k1];
    let master = rank - rank % leaf_fanout;
    let fixed_watermark = match cfg.hier.watermark {
        WatermarkMode::Fixed(w) => Some(w),
        // Auto/Off: prefetch is the master's drain-time concern only.
        _ => None,
    };
    let mut nudged_seq = 0u64;
    // Chunk-bound technique for the two-phase slow path (post-demotion),
    // cached by the master's `seq`.
    let mut bound: Option<(u64, Technique)> = None;
    // Execution accumulated since the last slow-path request — piggybacked
    // on the next `Get` so the master's adaptive controller observes the
    // CAS path's µ/σ (it cannot see the grants themselves).
    let mut acc_iters = 0u64;
    let mut acc_elapsed = 0.0f64;
    let mut out = RankSummary { rank, ..Default::default() };
    let em = cfg.metrics.as_deref().map(EngineMetrics::register);
    let send = |dst: u32, msg: Msg| {
        tally.count(geom, k1, rank, dst);
        ep.send(dst, msg).expect("master hung up early");
    };
    barrier.wait();
    let t0 = Instant::now();
    'outer: loop {
        let t_req = Instant::now();
        match ledger.try_grant() {
            Some((a, remaining, seq)) => {
                let grant_wait = t_req.elapsed().as_secs_f64();
                out.sched_wait += grant_wait;
                out.fast_grants += 1;
                if let Some(m) = &em {
                    m.on_grant(a.size, grant_wait, true);
                }
                if let Some(wm) = fixed_watermark {
                    if remaining <= wm && nudged_seq != seq {
                        nudged_seq = seq;
                        send(master, Msg::Nudge { rank });
                    }
                }
                let (sum, elapsed) = execute_chunk(workload.as_ref(), a);
                out.record_chunk(sum, a);
                acc_iters += a.size;
                acc_elapsed += elapsed;
            }
            None => {
                let report = (acc_iters > 0)
                    .then_some(PerfReport { iters: acc_iters, elapsed: acc_elapsed });
                acc_iters = 0;
                acc_elapsed = 0.0;
                send(master, Msg::Get { rank, report });
                let mut env = ep.recv().expect("master hung up early");
                let mut wait = t_req.elapsed().as_secs_f64();
                out.sched_wait += wait;
                loop {
                    match env.payload {
                        Msg::Chunk(a) => {
                            if let Some(m) = &em {
                                m.on_grant(a.size, wait, false);
                            }
                            let (sum, elapsed) = execute_chunk(workload.as_ref(), a);
                            out.record_chunk(sum, a);
                            acc_iters += a.size;
                            acc_elapsed += elapsed;
                            break;
                        }
                        Msg::Step { step, remaining: _, seq, chunk_len, tech, af: _ } => {
                            // Two-phase cycle (post-demotion, or a NACK
                            // re-serve): calculate with the announced
                            // technique, commit, handle whatever replies.
                            spin_for(cfg.delay.calculation);
                            if !bound.as_ref().is_some_and(|(s, _)| *s == seq) {
                                let params = with_np(&cfg.params, chunk_len, leaf_fanout);
                                bound = Some((seq, Technique::new(tech, &params)));
                            }
                            let size = bound
                                .as_ref()
                                .expect("technique bound above")
                                .1
                                .closed_chunk(step);
                            let t_commit = Instant::now();
                            send(master, Msg::Commit { rank, step, size, seq });
                            env = ep.recv().expect("master hung up early");
                            let commit_wait = t_commit.elapsed().as_secs_f64();
                            out.sched_wait += commit_wait;
                            wait += commit_wait;
                        }
                        Msg::Done => break 'outer,
                        other => panic!("rank {rank}: unexpected {other:?}"),
                    }
                }
            }
        }
    }
    out.finish = t0.elapsed().as_secs_f64();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The slice-sizing function is deterministic and bounded: unmeasured
    /// cost falls back to the historical 256, the measured path targets
    /// [`SLICE_TARGET_LATENCY`], and both ends clamp.
    #[test]
    fn master_slice_targets_bounded_service_latency() {
        assert_eq!(master_slice(None), 256, "unmeasured ⇒ historical default");
        assert_eq!(master_slice(Some(0.0)), 256, "degenerate cost ⇒ default");
        assert_eq!(master_slice(Some(-1.0)), 256);
        // 200 µs target / 1 µs per iteration = 200 iterations per slice.
        assert_eq!(master_slice(Some(1e-6)), 200);
        // Long iterations (the PSIA regime) floor at 1 — matching the A3
        // ablation's "anything above 1 starves the queue" guidance.
        assert_eq!(master_slice(Some(73e-3)), 1);
        assert_eq!(master_slice(Some(1.0)), 1);
        // Absurdly cheap iterations cap so drains still happen.
        assert_eq!(master_slice(Some(1e-15)), MAX_MASTER_SLICE);
        // Monotone: costlier iterations never grow the slice.
        let costs = [1e-9, 1e-8, 1e-7, 1e-6, 1e-5, 1e-4, 1e-3];
        let slices: Vec<u64> = costs.iter().map(|&c| master_slice(Some(c))).collect();
        assert!(slices.windows(2).all(|w| w[0] >= w[1]), "{slices:?}");
    }
}
