//! DCA over **two-sided** messages — this paper's headline contribution
//! (§4–5): distributed chunk calculation on a substrate every MPI runtime
//! supports.
//!
//! Per chunk the worker makes two round trips:
//!
//! 1. `GetStep → Step` — the coordinator *reserves* a step index `i`
//!    (constant-time counter bump; no formula evaluation, no injected delay);
//! 2. the worker evaluates the **straightforward** formula `K_i` locally —
//!    this is where the §6 injected slowdown lands, and it runs in parallel
//!    across all `P` workers;
//! 3. `Commit → Chunk` — the coordinator grants the iteration range.
//!
//! AF (no closed form) rides the same protocol with the extra
//! synchronization of §4: `Step` carries `R_i` (in the ticket) and the
//! global `(D, E)` aggregates; the worker combines them with its *local* µ.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Instant;

use super::protocol::{AfInfo, CoordMsg, Msg, PerfReport, WorkerMsg};
use super::{execute_chunk, EngineConfig, RankSummary, RunResult};
use crate::hier::protocol::{fast_len_ok, with_np, AtomicLedger};
use crate::obs::EngineMetrics;
use crate::sched::adaptive::{AdaptiveController, SwitchEvent};
use crate::sched::WorkQueue;
use crate::substrate::delay::spin_for;
use crate::substrate::msg::{fabric, Endpoint};
use crate::techniques::af::{af_chunk, AfCalculator, PeStats};
use crate::techniques::{ChunkTable, Technique, TechniqueKind};
use crate::workload::Workload;

/// Run the DCA two-sided engine: `P` worker threads + the coordinator
/// service loop on the calling thread — or, on the lock-free fast path, no
/// coordinator at all.
pub fn run(cfg: &EngineConfig, workload: Arc<dyn Workload>) -> anyhow::Result<RunResult> {
    let p = cfg.params.p;
    anyhow::ensure!(p >= 1, "need at least one worker");
    // Adaptive runs keep the two-phase protocol: once the coordinator
    // disappears, nobody is left to rebind the precomputed whole-loop table
    // (`--lockfree --adaptive` is rejected upstream; `Auto` demotes here).
    if cfg.sched_path.wants_lockfree()
        && cfg.technique.supports_fast_path()
        && fast_len_ok(cfg.params.n)
        && !cfg.hier.adaptive.enabled
    {
        // The capped build doubles as the memory guard: an SS-like
        // schedule beyond MAX_FAST_TABLE_STEPS falls back to the
        // O(1)-memory two-phase protocol instead of materializing it.
        if let Some(table) = ChunkTable::build_capped(
            cfg.technique,
            &cfg.params,
            crate::techniques::MAX_FAST_TABLE_STEPS,
        ) {
            return run_lockfree(cfg, workload, Arc::new(table));
        }
    }
    let (mut eps, sent) = fabric::<Msg>(p + 1);
    let coord_ep = eps.pop().expect("coordinator endpoint");
    let barrier = Arc::new(Barrier::new(p as usize + 1));

    let mut handles = Vec::with_capacity(p as usize);
    for ep in eps {
        let w = Arc::clone(&workload);
        let b = Arc::clone(&barrier);
        let c = cfg.clone();
        handles.push(thread::spawn(move || worker_loop(&c, ep, p, w, b)));
    }

    let coord_switches = coordinator_loop(cfg, coord_ep, &barrier)?;

    let per_rank: Vec<RankSummary> =
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect();
    let mut out = RunResult::assemble(per_rank, sent.load(Ordering::Relaxed));
    out.switch_events.extend(coord_switches);
    Ok(out)
}

/// The lock-free DCA engine (§4 taken to the arXiv 1901.02773 endpoint, on
/// shared memory): the reserve/commit message exchange collapses into **one
/// CAS per chunk** on the shared packed `(start, seq)` word, with the chunk
/// size an array lookup in the precomputed [`ChunkTable`]. No coordinator
/// thread, no messages, no per-chunk calculation (hence no injected
/// calculation delay — there is nothing left to slow down). The emitted
/// schedule is the technique's canonical serial schedule: grant order ≡
/// step order by construction.
fn run_lockfree(
    cfg: &EngineConfig,
    workload: Arc<dyn Workload>,
    table: Arc<ChunkTable>,
) -> anyhow::Result<RunResult> {
    let p = cfg.params.p;
    let ledger = Arc::new(AtomicLedger::new());
    ledger.publish(1, 0, table);
    let barrier = Arc::new(Barrier::new(p as usize));
    let em = cfg.metrics.as_deref().map(EngineMetrics::register);
    let mut handles = Vec::with_capacity(p as usize);
    for rank in 0..p {
        let w = Arc::clone(&workload);
        let b = Arc::clone(&barrier);
        let l = Arc::clone(&ledger);
        let m = em.clone();
        handles.push(thread::spawn(move || lockfree_worker(rank, &l, w, &b, m)));
    }
    let per_rank: Vec<RankSummary> =
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect();
    Ok(RunResult::assemble(per_rank, 0))
}

/// Lock-free worker: CAS-grant → execute, until the table drains.
fn lockfree_worker(
    rank: u32,
    ledger: &AtomicLedger,
    workload: Arc<dyn Workload>,
    barrier: &Barrier,
    em: Option<EngineMetrics>,
) -> RankSummary {
    let mut out = RankSummary { rank, ..Default::default() };
    barrier.wait();
    let t0 = Instant::now();
    loop {
        let t_req = Instant::now();
        let Some((a, _remaining, _seq)) = ledger.try_grant() else { break };
        let wait = t_req.elapsed().as_secs_f64();
        out.sched_wait += wait;
        out.fast_grants += 1;
        if let Some(m) = &em {
            m.on_grant(a.size, wait, true);
        }
        let (sum, _elapsed) = execute_chunk(workload.as_ref(), a);
        out.record_chunk(sum, a);
    }
    out.finish = t0.elapsed().as_secs_f64();
    out
}

/// Coordinator service loop — assignment only, O(1) work per message.
/// Under adaptive selection the coordinator additionally owns the
/// technique slot: phase-1 replies announce the slot's current kind, child
/// reports feed the controller's EWMAs, and every `probe_interval` grants
/// the closed-form probe may rebind the slot for all *subsequent* steps
/// (in-flight steps keep the kind their reply carried — the work queue
/// clips any size, so the mixed schedule still covers exactly). Returns
/// the switch-event trace.
fn coordinator_loop(
    cfg: &EngineConfig,
    ep: Endpoint<Msg>,
    barrier: &Barrier,
) -> anyhow::Result<Vec<SwitchEvent>> {
    let params = &cfg.params;
    let is_af = cfg.technique == TechniqueKind::Af;
    let mut af = is_af.then(|| AfCalculator::new(params));
    let mut adapt = cfg.hier.adaptive.enabled.then(|| {
        AdaptiveController::new(cfg.technique, params, params.p, cfg.hier.adaptive, false)
    });
    let em = cfg.metrics.as_deref().map(EngineMetrics::register);
    let mut switches = Vec::new();
    let mut q = WorkQueue::from_params(params);
    let mut active = params.p;
    // The slot's current binding era: (technique, rebased step 0, bound
    // length) — switches re-bind to the remainder like a fresh hierarchical
    // chunk install, so granted sizes match the probe's model.
    let mut era = (cfg.technique, 0u64, params.n);

    barrier.wait();
    let t0 = Instant::now();
    while active > 0 {
        let env = ep.recv()?;
        match env.payload {
            Msg::ToCoord(WorkerMsg::GetStep { rank, report }) => {
                if let (Some(af), Some(PerfReport { iters, elapsed })) = (af.as_mut(), report) {
                    af.record(rank as usize, iters, elapsed);
                }
                if let (Some(ctl), Some(PerfReport { iters, elapsed })) = (adapt.as_mut(), report)
                {
                    ctl.observe_chunk(rank, iters, elapsed, t0.elapsed().as_secs_f64());
                }
                match q.begin_step() {
                    Some(ticket) => {
                        let af_info = af
                            .as_ref()
                            .and_then(|a| a.globals())
                            .map(|g| AfInfo { d: g.d, e: g.e });
                        let (tech, base_step, bound_n) = era;
                        ep.send(
                            env.src,
                            Msg::ToWorker(CoordMsg::Step {
                                ticket,
                                af: af_info,
                                tech,
                                base_step,
                                bound_n,
                            }),
                        )?;
                    }
                    None => {
                        ep.send(env.src, Msg::ToWorker(CoordMsg::Done))?;
                        active -= 1;
                    }
                }
            }
            Msg::ToCoord(WorkerMsg::Commit { ticket, size, .. }) => {
                // Chunk ASSIGNMENT — the only synchronized operation (§3).
                spin_for(cfg.delay.assignment);
                // AF: re-cap against fresh R (stale-ticket protection, §4).
                let size = if is_af {
                    size.min(q.remaining().div_ceil(params.p as u64).max(1))
                } else {
                    size
                };
                match q.commit(ticket, size) {
                    Some(a) => {
                        ep.send(env.src, Msg::ToWorker(CoordMsg::Chunk(a)))?;
                        if let Some(ctl) = adapt.as_mut() {
                            if ctl.tick_grant() {
                                let from = ctl.current();
                                if let Some((to, predicted_ratio)) = ctl.probe(q.remaining()) {
                                    era = (to, q.step(), q.remaining().max(1));
                                    if let Some(m) = &em {
                                        m.switches.inc();
                                    }
                                    switches.push(SwitchEvent {
                                        at_s: t0.elapsed().as_secs_f64(),
                                        level: 0,
                                        master: 0,
                                        from,
                                        to,
                                        predicted_ratio,
                                    });
                                }
                            }
                        }
                    }
                    None => {
                        ep.send(env.src, Msg::ToWorker(CoordMsg::Done))?;
                        active -= 1;
                    }
                }
            }
            other => anyhow::bail!("DCA coordinator got unexpected message: {other:?}"),
        }
    }
    Ok(switches)
}

/// Worker: reserve step → calculate locally (parallel!) → commit → execute.
fn worker_loop(
    cfg: &EngineConfig,
    ep: Endpoint<Msg>,
    coord: u32,
    workload: Arc<dyn Workload>,
    barrier: Arc<Barrier>,
) -> RankSummary {
    let rank = ep.rank();
    let em = cfg.metrics.as_deref().map(EngineMetrics::register);
    let bootstrap = cfg.params.min_chunk.max(1);
    // The binding era announced by the last phase-1 reply: technique bound
    // to `(bound_n, P)` with rebased steps. Static runs bind exactly once
    // (the configured technique over the whole loop).
    let mut bound: Option<(TechniqueKind, u64, u64, Technique)> = None;
    let mut my_stats = PeStats::default(); // local µ for AF
    let mut out = RankSummary { rank, ..Default::default() };
    let mut report = None;
    barrier.wait();
    let t0 = Instant::now();
    'outer: loop {
        let t_req = Instant::now();
        ep.send(coord, Msg::ToCoord(WorkerMsg::GetStep { rank, report }))
            .expect("coordinator hung up early");
        let env = ep.recv().expect("coordinator hung up early");
        let reserve_wait = t_req.elapsed().as_secs_f64();
        out.sched_wait += reserve_wait;
        let (ticket, af_info, tech, base_step, bound_n) = match env.payload {
            Msg::ToWorker(CoordMsg::Step { ticket, af, tech, base_step, bound_n }) => {
                (ticket, af, tech, base_step, bound_n)
            }
            Msg::ToWorker(CoordMsg::Done) => break 'outer,
            other => panic!("worker {rank}: unexpected {other:?}"),
        };

        // Chunk CALCULATION — distributed: happens here, on the worker,
        // concurrently with every other worker's calculation. The injected
        // slowdown is paid in parallel, not serialized at a master. The
        // binding is whatever this step's reply announced.
        spin_for(cfg.delay.calculation);
        let k = if tech == TechniqueKind::Af {
            match (my_stats.measured().then(|| my_stats.mu()).flatten(), af_info) {
                (Some(mu), Some(AfInfo { d, e })) => af_chunk(
                    crate::techniques::af::AfGlobals { d, e },
                    mu,
                    ticket.remaining,
                    cfg.params.p,
                ),
                _ => bootstrap,
            }
        } else {
            let same_era = bound
                .as_ref()
                .is_some_and(|(k, b, n, _)| (*k, *b, *n) == (tech, base_step, bound_n));
            if !same_era {
                let params = with_np(&cfg.params, bound_n, cfg.params.p);
                bound = Some((tech, base_step, bound_n, Technique::new(tech, &params)));
            }
            bound.as_ref().expect("bound above").3.closed_chunk(ticket.step - base_step)
        };

        let t_commit = Instant::now();
        ep.send(coord, Msg::ToCoord(WorkerMsg::Commit { rank, ticket, size: k }))
            .expect("coordinator hung up early");
        let env = ep.recv().expect("coordinator hung up early");
        let commit_wait = t_commit.elapsed().as_secs_f64();
        out.sched_wait += commit_wait;
        match env.payload {
            Msg::ToWorker(CoordMsg::Chunk(a)) => {
                if let Some(m) = &em {
                    m.on_grant(a.size, reserve_wait + commit_wait, false);
                }
                let (sum, elapsed) = execute_chunk(workload.as_ref(), a);
                out.record_chunk(sum, a);
                my_stats.record(a.size, elapsed);
                report = Some(PerfReport { iters: a.size, elapsed });
            }
            Msg::ToWorker(CoordMsg::Done) => break 'outer,
            other => panic!("worker {rank}: unexpected {other:?}"),
        }
    }
    out.finish = t0.elapsed().as_secs_f64();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecutionModel;
    use crate::sched::verify_coverage;
    use crate::techniques::LoopParams;
    use crate::workload::synthetic::{CostShape, Synthetic};

    fn run_kind(kind: TechniqueKind, n: u64, p: u32) -> RunResult {
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(n, 5e-8, CostShape::Uniform, 3));
        let cfg = EngineConfig::new(LoopParams::new(n, p), kind, ExecutionModel::Dca);
        run(&cfg, w).unwrap()
    }

    #[test]
    fn gss_covers() {
        let r = run_kind(TechniqueKind::Gss, 10_000, 4);
        verify_coverage(&r.sorted_assignments(), 10_000).unwrap();
    }

    #[test]
    fn dca_sends_more_messages_than_cca() {
        // §7: "DCA incurs more communication messages than CCA".
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(4_000, 5e-8, CostShape::Uniform, 3));
        let params = LoopParams::new(4_000, 4);
        let c = super::super::cca::run(
            &EngineConfig::new(params.clone(), TechniqueKind::Tss, ExecutionModel::Cca),
            Arc::clone(&w),
        )
        .unwrap();
        let d = run(
            &EngineConfig::new(params, TechniqueKind::Tss, ExecutionModel::Dca),
            w,
        )
        .unwrap();
        // TSS chunk counts are identical in both forms ⇒ strictly more msgs.
        assert_eq!(c.stats.chunks, d.stats.chunks);
        assert!(d.stats.messages > c.stats.messages);
    }

    #[test]
    fn af_needs_no_closed_form_but_covers() {
        let r = run_kind(TechniqueKind::Af, 4_000, 4);
        verify_coverage(&r.sorted_assignments(), 4_000).unwrap();
    }

    /// The lock-free engine covers the loop with zero messages, the
    /// canonical serial schedule (identical to `closed_form_schedule`), and
    /// every grant accounted as a CAS.
    #[test]
    fn lockfree_covers_with_canonical_schedule_and_zero_messages() {
        use crate::sched::closed_form_schedule;
        const N: u64 = 20_000;
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 5e-8, CostShape::Uniform, 3));
        for kind in TechniqueKind::EVALUATED {
            if !kind.supports_fast_path() {
                continue;
            }
            let params = LoopParams::new(N, 4);
            let cfg = EngineConfig::new(params.clone(), kind, ExecutionModel::Dca).with_lockfree();
            let r = run(&cfg, Arc::clone(&w)).unwrap_or_else(|e| panic!("{kind}: {e}"));
            let sorted = r.sorted_assignments();
            verify_coverage(&sorted, N).unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert_eq!(r.stats.messages, 0, "{kind}: the coordinator disappeared");
            assert_eq!(r.fast_grants, r.stats.chunks, "{kind}: every grant is a CAS");
            let tech = Technique::new(kind, &params);
            assert_eq!(
                sorted,
                closed_form_schedule(&tech, &params),
                "{kind}: CAS grants must emit the canonical serial schedule"
            );
        }
    }

    /// AF/TAP requested with the lock-free path fall back to the two-phase
    /// engine (measurement-coupled sizing cannot be tabulated).
    #[test]
    fn lockfree_falls_back_for_measurement_coupled_techniques() {
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(4_000, 5e-8, CostShape::Uniform, 3));
        for kind in [TechniqueKind::Af, TechniqueKind::Tap] {
            let cfg = EngineConfig::new(LoopParams::new(4_000, 4), kind, ExecutionModel::Dca)
                .with_lockfree();
            let r = run(&cfg, Arc::clone(&w)).unwrap();
            verify_coverage(&r.sorted_assignments(), 4_000).unwrap();
            assert_eq!(r.fast_grants, 0, "{kind}: no CAS grants on the fallback");
            assert!(r.stats.messages > 0, "{kind}: two-phase protocol ran");
        }
    }

    /// The threaded flat coordinator with adaptivity on: coverage and the
    /// switch-event plumbing hold on real threads (timing-dependent, so
    /// only structural properties are asserted); a single-candidate set
    /// still emits the technique's own schedule.
    #[test]
    fn adaptive_coordinator_covers_and_traces() {
        use crate::techniques::CandidateSet;
        const N: u64 = 8_000;
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 5e-8, CostShape::Uniform, 3));
        let mut cfg =
            EngineConfig::new(LoopParams::new(N, 4), TechniqueKind::Ss, ExecutionModel::Dca);
        cfg.hier = cfg
            .hier
            .with_adaptive()
            .with_probe_interval(8)
            .with_candidates(CandidateSet::parse("ss,gss,fac").unwrap());
        let r = run(&cfg, Arc::clone(&w)).unwrap();
        verify_coverage(&r.sorted_assignments(), N).unwrap();
        assert_eq!(r.fast_grants, 0, "adaptive keeps the two-phase protocol");
        for e in &r.switch_events {
            assert_eq!((e.level, e.master), (0, 0), "flat switches live on the coordinator");
        }
        // Single-candidate: never switches, schedule is SS's own.
        let mut cfg1 =
            EngineConfig::new(LoopParams::new(N, 4), TechniqueKind::Ss, ExecutionModel::Dca);
        cfg1.hier = cfg1
            .hier
            .with_adaptive()
            .with_candidates(CandidateSet::EMPTY.try_with(TechniqueKind::Ss).unwrap());
        let r1 = run(&cfg1, w).unwrap();
        assert!(r1.switch_events.is_empty());
        assert_eq!(r1.stats.chunks, N, "SS grants one iteration per chunk");
    }

    /// `Auto` without adaptivity is the lock-free engine; with adaptivity
    /// the flat engine stays two-phase (nobody is left to rebind a
    /// precomputed table), and the contradictory LockFree+adaptive combo
    /// errors out.
    #[test]
    fn auto_path_rules_flat_threaded() {
        const N: u64 = 4_000;
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 5e-8, CostShape::Uniform, 3));
        let mut auto =
            EngineConfig::new(LoopParams::new(N, 4), TechniqueKind::Gss, ExecutionModel::Dca);
        auto.sched_path = crate::config::SchedPath::Auto;
        let r = run(&auto, Arc::clone(&w)).unwrap();
        assert_eq!(r.fast_grants, r.stats.chunks, "static Auto IS lock-free");
        assert_eq!(r.stats.messages, 0);
        let mut auto_ad = auto.clone();
        auto_ad.hier = auto_ad.hier.with_adaptive();
        let r = run(&auto_ad, Arc::clone(&w)).unwrap();
        verify_coverage(&r.sorted_assignments(), N).unwrap();
        assert_eq!(r.fast_grants, 0, "adaptive Auto runs two-phase");
        let mut bad = auto_ad;
        bad.sched_path = crate::config::SchedPath::LockFree;
        assert!(crate::coordinator::run(&bad, w).is_err());
    }

    /// With a registry attached, both grant paths account every chunk:
    /// two-phase pays 4 protocol messages per grant, the CAS path none.
    #[test]
    fn metrics_registry_accounts_grants_on_both_paths() {
        use crate::obs::MetricsRegistry;
        const N: u64 = 4_000;
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(N, 5e-8, CostShape::Uniform, 3));
        let reg = Arc::new(MetricsRegistry::new());
        let cfg =
            EngineConfig::new(LoopParams::new(N, 4), TechniqueKind::Gss, ExecutionModel::Dca)
                .with_metrics(Arc::clone(&reg));
        let r = run(&cfg, Arc::clone(&w)).unwrap();
        let em = EngineMetrics::register(&reg);
        assert_eq!(em.grants.get(), r.stats.chunks);
        assert_eq!(em.iters.get(), N);
        assert_eq!(em.messages.get(), 4 * r.stats.chunks);
        assert_eq!(em.fast_grants.get(), 0);
        assert_eq!(em.chunk_iters.count(), r.stats.chunks);
        assert!(em.chunk_iters.sum() as u64 == N);
        assert!(reg.render_prometheus().contains("dcadls_sched_grants_total"));

        let reg2 = Arc::new(MetricsRegistry::new());
        let fast = run(&cfg.clone().with_lockfree().with_metrics(Arc::clone(&reg2)), w).unwrap();
        let em2 = EngineMetrics::register(&reg2);
        assert_eq!(em2.fast_grants.get(), fast.stats.chunks);
        assert_eq!(em2.grants.get(), fast.stats.chunks);
        assert_eq!(em2.messages.get(), 0, "no protocol messages on the CAS path");
    }

    #[test]
    fn closed_form_sizes_track_table2() {
        // The DCA engine evaluates the Table 2 closed forms per step; the
        // *multiset* of sizes matches Table 2's head exactly (the tail can
        // shift by commit-order clipping, which is legal — §3 only requires
        // disjoint full coverage).
        let r = run_kind(TechniqueKind::Gss, 1_000, 4);
        let mut sizes: Vec<u64> = r.sorted_assignments().iter().map(|a| a.size).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(sizes.iter().sum::<u64>(), 1_000);
        assert_eq!(&sizes[..6], &[250, 188, 141, 106, 80, 60], "head of {sizes:?}");
        assert!((16..=21).contains(&(sizes.len() as u64)), "count {}", sizes.len());
    }
}
