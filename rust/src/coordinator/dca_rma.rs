//! DCA over the **one-sided RMA window** — the PDP'19 original (Fig. 3):
//! no coordinator service loop at all. Workers reserve a step and claim an
//! iteration range directly with passive-target atomics; the chunk
//! calculation between the two accesses is fully parallel and lock-free.
//!
//! Only techniques with a straightforward formula are supported — exactly
//! the limitation the paper ascribes to this variant (AF's `R_i`/(µ,σ)
//! synchronization needs the message-based coordinator of [`super::dca`]).

use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Instant;

use super::{execute_chunk, EngineConfig, RankSummary, RunResult};
use crate::substrate::delay::spin_for;
use crate::substrate::rma::RmaWindow;
use crate::techniques::{Technique, TechniqueKind};
use crate::workload::Workload;

/// Run the RMA-based DCA engine: `P` symmetric worker threads, no
/// coordinator thread, zero scheduling messages.
pub fn run(cfg: &EngineConfig, workload: Arc<dyn Workload>) -> anyhow::Result<RunResult> {
    anyhow::ensure!(
        cfg.technique != TechniqueKind::Af,
        "AF has no straightforward chunk formula; DCA-RMA cannot schedule it \
         (use ExecutionModel::Dca, which synchronizes R_i and (D,E) — §4)"
    );
    let p = cfg.params.p;
    anyhow::ensure!(p >= 1, "need at least one worker");
    let window = Arc::new(RmaWindow::new(cfg.params.n, cfg.params.min_chunk));
    let barrier = Arc::new(Barrier::new(p as usize));

    let handles: Vec<_> = (0..p)
        .map(|rank| {
            let w = Arc::clone(&workload);
            let win = Arc::clone(&window);
            let b = Arc::clone(&barrier);
            let c = cfg.clone();
            thread::spawn(move || worker_loop(&c, rank, win, w, b))
        })
        .collect();

    let per_rank: Vec<RankSummary> =
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect();
    Ok(RunResult::assemble(per_rank, 0))
}

fn worker_loop(
    cfg: &EngineConfig,
    rank: u32,
    window: Arc<RmaWindow>,
    workload: Arc<dyn Workload>,
    barrier: Arc<Barrier>,
) -> RankSummary {
    let technique = Technique::new(cfg.technique, &cfg.params);
    let mut out = RankSummary { rank, ..Default::default() };
    barrier.wait();
    let t0 = Instant::now();
    while let Some((step, _lp)) = {
        let t_req = Instant::now();
        let r = window.reserve_step();
        out.sched_wait += t_req.elapsed().as_secs_f64();
        r
    } {
        // Distributed chunk calculation — outside any critical section.
        spin_for(cfg.delay.calculation);
        let k = technique.closed_chunk(step);
        // Assignment: one atomic claim (the §7-ablation delay applies here).
        spin_for(cfg.delay.assignment);
        let t_claim = Instant::now();
        let Some(a) = window.claim(step, k) else { break };
        out.sched_wait += t_claim.elapsed().as_secs_f64();
        let (sum, _elapsed) = execute_chunk(workload.as_ref(), a);
        out.record_chunk(sum, a);
    }
    out.finish = t0.elapsed().as_secs_f64();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecutionModel;
    use crate::sched::verify_coverage;
    use crate::techniques::LoopParams;
    use crate::workload::synthetic::{CostShape, Synthetic};

    fn cfg(kind: TechniqueKind, n: u64, p: u32) -> EngineConfig {
        EngineConfig::new(LoopParams::new(n, p), kind, ExecutionModel::DcaRma)
    }

    #[test]
    fn covers_with_zero_messages() {
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(10_000, 5e-8, CostShape::Uniform, 3));
        let r = run(&cfg(TechniqueKind::Fac2, 10_000, 8), w).unwrap();
        verify_coverage(&r.sorted_assignments(), 10_000).unwrap();
        assert_eq!(r.stats.messages, 0, "RMA path exchanges no messages");
    }

    #[test]
    fn af_is_rejected_with_useful_error() {
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(100, 1e-8, CostShape::Uniform, 3));
        let err = run(&cfg(TechniqueKind::Af, 100, 2), w).unwrap_err().to_string();
        assert!(err.contains("straightforward"), "{err}");
    }

    #[test]
    fn matches_two_sided_dca_chunk_totals() {
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(5_000, 5e-8, CostShape::Uniform, 3));
        let rma = run(&cfg(TechniqueKind::Tss, 5_000, 4), Arc::clone(&w)).unwrap();
        let two = super::super::dca::run(
            &EngineConfig::new(LoopParams::new(5_000, 4), TechniqueKind::Tss, ExecutionModel::Dca),
            w,
        )
        .unwrap();
        assert_eq!(
            rma.sorted_assignments().iter().map(|a| a.size).sum::<u64>(),
            two.sorted_assignments().iter().map(|a| a.size).sum::<u64>(),
        );
    }
}
