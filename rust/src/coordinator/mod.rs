//! The execution models (§3–§5) as real multi-threaded engines: `P` worker
//! threads self-schedule a [`Workload`] through a master (CCA), a
//! coordinator (DCA), or a recursive depth-`k` coordinator → master tree
//! (HIER-DCA) — wall-clock measured, chunks actually executed.
//!
//! | model | calculation | assignment | messages/chunk |
//! |---|---|---|---|
//! | [`cca`]      | master, **serialized** (+injected delay) | master | 2 |
//! | [`dca`]      | worker, **parallel** (+injected delay)   | coordinator (counter bump) | 4 |
//! | [`dca_rma`]  | worker, **parallel**                     | atomic fetch-ops, no coordinator CPU | 0 |
//! | [`hier`]     | N-level, **parallel**: every tier's requesters size their own chunks | root + one master ledger per tree level | 4 per chunk at each level, over that level's fabric |
//!
//! The [`hier`] engine's message pattern is the arXiv 1903.09510 protocol
//! generalized to any depth: leaf ranks run `Get → Step`, `Commit → Chunk`
//! against their lowest-level master (intra-node traffic), while each
//! non-dedicated master persona — the hosting ranks also execute
//! iterations — runs the same two-phase exchange one level up for whole
//! level-chunks, optionally prefetching the next chunk below a (fixed or
//! EWMA-adaptive) watermark. Depth 2 is the classic two-level hierarchy.
//!
//! These engines validate the protocol end-to-end at host scale; the
//! paper-scale (256-rank) numbers come from the calibrated DES in
//! [`crate::des`], which models the same protocols event-by-event.

pub mod cca;
pub mod dca;
pub mod dca_rma;
pub mod hier;
pub mod protocol;

use std::sync::Arc;
use std::time::Instant;

use crate::config::{ExecutionModel, HierParams, SchedPath};
use crate::metrics::LoopStats;
use crate::obs::MetricsRegistry;
use crate::sched::adaptive::SwitchEvent;
use crate::sched::Assignment;
use crate::substrate::delay::InjectedDelay;
use crate::techniques::{LoopParams, TechniqueKind};
use crate::workload::Workload;

/// Configuration for one engine run.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Loop + technique parameters; `params.p` = number of worker threads.
    pub params: LoopParams,
    pub technique: TechniqueKind,
    pub model: ExecutionModel,
    pub delay: InjectedDelay,
    /// Hierarchical-tree parameters (depth, per-level techniques/fan-outs,
    /// prefetch policy) — used only by [`ExecutionModel::HierDca`].
    pub hier: HierParams,
    /// Default node-group count for the depth-2 tree (must divide
    /// `params.p`; block placement); deeper trees take explicit fan-outs
    /// from `hier`. Ignored by the flat engines.
    pub nodes: u32,
    /// Grant protocol: the default two-phase message exchange, or the
    /// lock-free CAS fast path ([`SchedPath::LockFree`]) — a real one-word
    /// CAS on the shared packed ledger here, applied by [`dca`] (the whole
    /// coordinator disappears) and by [`hier`]'s leaf level. AF/TAP and the
    /// other models ignore it.
    pub sched_path: SchedPath,
    /// Observability sink: when set, every engine registers the
    /// [`crate::obs::EngineMetrics`] bundle here and accounts grants,
    /// messages, waits and switches on the grant path (registration is
    /// idempotent — threads share one set of atomics). `None` (the
    /// default) costs nothing.
    pub metrics: Option<Arc<MetricsRegistry>>,
}

impl EngineConfig {
    pub fn new(params: LoopParams, technique: TechniqueKind, model: ExecutionModel) -> Self {
        EngineConfig {
            params,
            technique,
            model,
            delay: InjectedDelay::none(),
            hier: HierParams::default(),
            nodes: 1,
            sched_path: SchedPath::default(),
            metrics: None,
        }
    }

    /// Attach a metrics registry the run's engines will update.
    pub fn with_metrics(mut self, registry: Arc<MetricsRegistry>) -> Self {
        self.metrics = Some(registry);
        self
    }

    /// Switch the grant protocol to the lock-free CAS fast path.
    pub fn with_lockfree(mut self) -> Self {
        self.sched_path = SchedPath::LockFree;
        self
    }
}

/// Per-worker outcome, accumulated inside the worker thread.
#[derive(Debug, Clone, Default)]
pub struct RankSummary {
    pub rank: u32,
    /// Chunks this worker executed.
    pub chunks: u64,
    /// Iterations this worker executed.
    pub iters: u64,
    /// Seconds from the start barrier to this worker's termination.
    pub finish: f64,
    /// Seconds spent waiting on scheduling round trips.
    pub sched_wait: f64,
    /// Wrapping-sum checksum of executed iterations.
    pub checksum: u64,
    /// Lock-free CAS grants this rank performed ([`SchedPath::LockFree`]).
    pub fast_grants: u64,
    /// Technique-slot rebinds this rank's master personas decided
    /// (adaptive selection; empty for plain workers).
    pub switches: Vec<SwitchEvent>,
    /// The chunks, for coverage verification.
    pub assignments: Vec<Assignment>,
}

impl RankSummary {
    /// Account one executed chunk (checksum, counters, coverage log) — the
    /// single definition every engine's execution site folds through.
    pub(crate) fn record_chunk(&mut self, sum: u64, a: Assignment) {
        self.checksum = self.checksum.wrapping_add(sum);
        self.chunks += 1;
        self.iters += a.size;
        self.assignments.push(a);
    }
}

/// Outcome of one engine run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub stats: LoopStats,
    pub per_rank: Vec<RankSummary>,
    /// Combined checksum over all executed iterations (order-independent).
    pub checksum: u64,
    /// Messages on the cheap latency class (all traffic for the flat
    /// single-fabric engines; under [`hier`], master ↔ local-rank traffic
    /// plus node 0's outer traffic — the coordinator is hosted on node 0's
    /// master, as in the DES).
    pub intra_node_messages: u64,
    /// Messages crossing nodes (under [`hier`], the coordinator ↔ master
    /// outer traffic of nodes 1..; zero for the flat engines). The
    /// classification matches the DES split, so `messages/chunk` stays
    /// directly comparable across substrates.
    pub inter_node_messages: u64,
    /// Messages per scheduling-protocol level, outer first: one entry per
    /// tree level under [`hier`] (`Σ = stats.messages`), a single entry for
    /// the flat engines.
    pub level_messages: Vec<u64>,
    /// Chunks granted through the lock-free CAS fast path (summed over
    /// ranks); 0 on the two-phase path.
    pub fast_grants: u64,
    /// Technique-slot rebinds across every master persona (and the flat
    /// coordinator), ordered by decision time; empty on static runs.
    pub switch_events: Vec<SwitchEvent>,
}

impl RunResult {
    /// Assemble from worker summaries + the fabric's message counter.
    pub(crate) fn assemble(mut per_rank: Vec<RankSummary>, messages: u64) -> Self {
        per_rank.sort_by_key(|r| r.rank);
        let finish: Vec<f64> = per_rank.iter().map(|r| r.finish).collect();
        let chunks = per_rank.iter().map(|r| r.chunks).sum();
        let wait = per_rank.iter().map(|r| r.sched_wait).sum();
        let checksum = per_rank.iter().fold(0u64, |a, r| a.wrapping_add(r.checksum));
        let fast_grants = per_rank.iter().map(|r| r.fast_grants).sum();
        let mut switch_events: Vec<SwitchEvent> =
            per_rank.iter().flat_map(|r| r.switches.iter().copied()).collect();
        switch_events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        RunResult {
            stats: LoopStats::from_finish_times(&finish, chunks, wait, messages),
            per_rank,
            checksum,
            intra_node_messages: messages,
            inter_node_messages: 0,
            level_messages: vec![messages],
            fast_grants,
            switch_events,
        }
    }

    /// Assemble with the hier engine's message splits: two latency tiers
    /// plus one counter per protocol level. The flat total is their sum.
    pub(crate) fn assemble_split(
        per_rank: Vec<RankSummary>,
        intra: u64,
        inter: u64,
        levels: Vec<u64>,
    ) -> Self {
        let mut out = Self::assemble(per_rank, intra + inter);
        out.intra_node_messages = intra;
        out.inter_node_messages = inter;
        out.level_messages = levels;
        out
    }

    /// All assignments across ranks, sorted by `start` — for verification.
    pub fn sorted_assignments(&self) -> Vec<Assignment> {
        let mut v: Vec<Assignment> =
            self.per_rank.iter().flat_map(|r| r.assignments.iter().copied()).collect();
        v.sort_unstable_by_key(|a| a.start);
        v
    }
}

/// Execute one chunk against the workload, timing it.
pub(crate) fn execute_chunk(workload: &dyn Workload, a: Assignment) -> (u64, f64) {
    let t = Instant::now();
    let checksum = workload.execute_range(a.start, a.size);
    (checksum, t.elapsed().as_secs_f64())
}

/// Run a configured engine to completion.
pub fn run(cfg: &EngineConfig, workload: Arc<dyn Workload>) -> anyhow::Result<RunResult> {
    anyhow::ensure!(
        cfg.params.n <= workload.n(),
        "loop ({}) larger than workload ({})",
        cfg.params.n,
        workload.n()
    );
    anyhow::ensure!(
        cfg.delay.dist == crate::substrate::delay::DelayDist::Constant,
        "the threaded engine only injects constant delays (it spins wall-clock \
         time); run distributional slowdown scenarios through the DES"
    );
    if cfg.hier.adaptive.enabled {
        anyhow::ensure!(
            matches!(cfg.model, ExecutionModel::Dca | ExecutionModel::HierDca),
            "adaptive technique selection applies to the DCA protocols \
             (DCA / HIER-DCA), not {}",
            cfg.model
        );
        anyhow::ensure!(
            !(cfg.model == ExecutionModel::Dca && cfg.technique == TechniqueKind::Af),
            "flat adaptive DCA cannot start from AF; start from a closed-form \
             technique (the hierarchical engine supports AF starts)"
        );
        anyhow::ensure!(
            !(cfg.model == ExecutionModel::Dca && cfg.sched_path == SchedPath::LockFree),
            "flat DCA cannot combine --lockfree with --adaptive (the CAS path \
             tabulates the whole loop up front); use --sched-path auto or drop \
             --adaptive"
        );
    }
    match cfg.model {
        ExecutionModel::Cca => cca::run(cfg, workload),
        ExecutionModel::Dca => dca::run(cfg, workload),
        ExecutionModel::DcaRma => dca_rma::run(cfg, workload),
        ExecutionModel::HierDca => hier::run(cfg, workload),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::verify_coverage;
    use crate::workload::synthetic::{CostShape, Synthetic};

    fn tiny_workload() -> Arc<dyn Workload> {
        Arc::new(Synthetic::new(5_000, 1e-7, CostShape::Jittered, 11))
    }

    /// Every (model × technique) combination — including the two-level
    /// engine on a 2×2 geometry — schedules the full loop with exact
    /// coverage and a consistent checksum.
    #[test]
    fn all_models_all_techniques_cover() {
        let w = tiny_workload();
        let reference = w.execute_range(0, 5_000);
        for model in ExecutionModel::ALL {
            for kind in TechniqueKind::ALL {
                if kind == TechniqueKind::Af && model == ExecutionModel::DcaRma {
                    continue; // unsupported by design (§4)
                }
                let params = LoopParams::new(5_000, 4);
                let mut cfg = EngineConfig::new(params, kind, model);
                if model == ExecutionModel::HierDca {
                    cfg.nodes = 2;
                }
                let r = run(&cfg, Arc::clone(&w))
                    .unwrap_or_else(|e| panic!("{model} {kind}: {e}"));
                verify_coverage(&r.sorted_assignments(), 5_000)
                    .unwrap_or_else(|e| panic!("{model} {kind}: {e}"));
                assert_eq!(r.checksum, reference, "{model} {kind}: checksum");
                assert!(r.stats.t_par > 0.0);
                assert!(r.stats.chunks > 0);
                assert_eq!(
                    r.stats.messages,
                    r.intra_node_messages + r.inter_node_messages,
                    "{model} {kind}: message split must reconcile"
                );
                if model == ExecutionModel::HierDca {
                    assert!(r.inter_node_messages > 0, "{kind}: outer protocol ran");
                }
            }
        }
    }

    #[test]
    fn exponential_delay_rejected_by_threaded_engine() {
        let w = tiny_workload();
        let mut cfg = EngineConfig::new(
            LoopParams::new(100, 2),
            TechniqueKind::Gss,
            ExecutionModel::Dca,
        );
        cfg.delay = crate::substrate::delay::InjectedDelay::exponential_calculation(1e-5, 1);
        let e = run(&cfg, w).unwrap_err();
        assert!(e.to_string().contains("constant"), "{e}");
    }

    #[test]
    fn af_rma_rejected() {
        let w = tiny_workload();
        let cfg = EngineConfig::new(
            LoopParams::new(100, 2),
            TechniqueKind::Af,
            ExecutionModel::DcaRma,
        );
        assert!(run(&cfg, w).is_err());
    }

    #[test]
    fn loop_larger_than_workload_rejected() {
        let w = tiny_workload();
        let cfg = EngineConfig::new(
            LoopParams::new(10_000, 2),
            TechniqueKind::Gss,
            ExecutionModel::Cca,
        );
        assert!(run(&cfg, w).is_err());
    }
}
