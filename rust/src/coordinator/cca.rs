//! CCA — the centralized chunk-calculation master–worker model (§3): the
//! execution scheme of the original LB tool / LB4MPI / DSS.
//!
//! The master owns the work queue and, for **every** request, evaluates the
//! technique's (recursive) chunk formula *inside its service loop*. The §6
//! injected delay lands there too — so with `S` total chunks the critical
//! path absorbs `≈ S·d` of serialized delay, plus the queueing behind it.
//! That serialization is exactly what Figs. 4c/5c show degrading CCA.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Instant;

use super::protocol::{CoordMsg, Msg, PerfReport, WorkerMsg};
use super::{execute_chunk, EngineConfig, RankSummary, RunResult};
use crate::sched::WorkQueue;
use crate::substrate::delay::spin_for;
use crate::substrate::msg::{fabric, Endpoint};
use crate::techniques::af::AfCalculator;
use crate::techniques::{Technique, TechniqueKind};
use crate::workload::Workload;

/// Run the CCA master–worker engine: `P` worker threads + the master service
/// loop on the calling thread (the master is rank `P` on the fabric — it is
/// PE 0's "service personality"; the DES additionally models the
/// non-dedicated master's `breakAfter` interleaving).
pub fn run(cfg: &EngineConfig, workload: Arc<dyn Workload>) -> anyhow::Result<RunResult> {
    let p = cfg.params.p;
    anyhow::ensure!(p >= 1, "need at least one worker");
    let (mut eps, sent) = fabric::<Msg>(p + 1);
    let coord_ep = eps.pop().expect("coordinator endpoint");
    let barrier = Arc::new(Barrier::new(p as usize + 1));

    let mut handles = Vec::with_capacity(p as usize);
    for ep in eps {
        let w = Arc::clone(&workload);
        let b = Arc::clone(&barrier);
        handles.push(thread::spawn(move || worker_loop(ep, p, w, b)));
    }

    master_loop(cfg, coord_ep, &barrier)?;

    let per_rank: Vec<RankSummary> =
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect();
    Ok(RunResult::assemble(per_rank, sent.load(Ordering::Relaxed)))
}

/// The master service loop: receive → (delay + calculate) → assign → reply.
fn master_loop(
    cfg: &EngineConfig,
    ep: Endpoint<Msg>,
    barrier: &Barrier,
) -> anyhow::Result<()> {
    let params = &cfg.params;
    let technique = Technique::new(cfg.technique, params);
    let is_af = cfg.technique == TechniqueKind::Af;
    let mut af = is_af.then(|| AfCalculator::new(params));
    let mut q = WorkQueue::from_params(params);
    let mut st = technique.fresh_recursive();
    let mut active = params.p;

    barrier.wait();
    while active > 0 {
        let env = ep.recv()?;
        let Msg::ToCoord(WorkerMsg::Request { rank, report }) = env.payload else {
            anyhow::bail!("CCA master got unexpected message: {:?}", env.payload);
        };
        if let (Some(af), Some(PerfReport { iters, elapsed })) = (af.as_mut(), report) {
            af.record(rank as usize, iters, elapsed);
        }
        // Chunk CALCULATION — centralized, so the injected slowdown
        // serializes here, once per scheduling step.
        spin_for(cfg.delay.calculation);
        let k = match af.as_ref() {
            Some(af) => af.chunk(rank as usize, q.remaining()),
            None => technique.recursive_chunk(&mut st, q.remaining()),
        };
        // Chunk ASSIGNMENT (the §7-ablation delay site).
        spin_for(cfg.delay.assignment);
        match q.assign(k) {
            Some(a) => ep.send(env.src, Msg::ToWorker(CoordMsg::Chunk(a)))?,
            None => {
                ep.send(env.src, Msg::ToWorker(CoordMsg::Done))?;
                active -= 1;
            }
        }
    }
    Ok(())
}

/// Worker: request → execute → report, until `Done`.
fn worker_loop(
    ep: Endpoint<Msg>,
    coord: u32,
    workload: Arc<dyn Workload>,
    barrier: Arc<Barrier>,
) -> RankSummary {
    let rank = ep.rank();
    let mut out = RankSummary { rank, ..Default::default() };
    let mut report = None;
    barrier.wait();
    let t0 = Instant::now();
    loop {
        let t_req = Instant::now();
        ep.send(coord, Msg::ToCoord(WorkerMsg::Request { rank, report }))
            .expect("master hung up early");
        let env = ep.recv().expect("master hung up early");
        out.sched_wait += t_req.elapsed().as_secs_f64();
        match env.payload {
            Msg::ToWorker(CoordMsg::Chunk(a)) => {
                let (sum, elapsed) = execute_chunk(workload.as_ref(), a);
                out.record_chunk(sum, a);
                report = Some(PerfReport { iters: a.size, elapsed });
            }
            Msg::ToWorker(CoordMsg::Done) => break,
            other => panic!("worker {rank}: unexpected {other:?}"),
        }
    }
    out.finish = t0.elapsed().as_secs_f64();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecutionModel;
    use crate::sched::verify_coverage;
    use crate::techniques::LoopParams;
    use crate::workload::synthetic::{CostShape, Synthetic};

    fn run_kind(kind: TechniqueKind, n: u64, p: u32) -> RunResult {
        let w: Arc<dyn Workload> = Arc::new(Synthetic::new(n, 5e-8, CostShape::Uniform, 3));
        let cfg = EngineConfig::new(LoopParams::new(n, p), kind, ExecutionModel::Cca);
        run(&cfg, w).unwrap()
    }

    #[test]
    fn gss_covers_and_counts_chunks() {
        let r = run_kind(TechniqueKind::Gss, 10_000, 4);
        verify_coverage(&r.sorted_assignments(), 10_000).unwrap();
        // Recursive GSS at (10k, 4) produces ~30 chunks.
        assert!(r.stats.chunks > 15 && r.stats.chunks < 60, "chunks={}", r.stats.chunks);
        // 2 messages per chunk + P final Done round trips.
        assert_eq!(r.stats.messages, 2 * r.stats.chunks + 2 * 4);
    }

    #[test]
    fn af_adapts_and_covers() {
        let r = run_kind(TechniqueKind::Af, 4_000, 4);
        verify_coverage(&r.sorted_assignments(), 4_000).unwrap();
        // AF bootstraps with unit chunks then grows.
        let max = r.sorted_assignments().iter().map(|a| a.size).max().unwrap();
        assert!(max > 1, "AF should grow past bootstrap chunks");
    }

    #[test]
    fn single_worker_degenerates_fine() {
        let r = run_kind(TechniqueKind::Fac2, 1_000, 1);
        verify_coverage(&r.sorted_assignments(), 1_000).unwrap();
        assert_eq!(r.per_rank.len(), 1);
    }

    #[test]
    fn work_is_distributed() {
        let r = run_kind(TechniqueKind::Ss, 2_000, 4);
        // With SS every worker should get some chunks.
        for rs in &r.per_rank {
            assert!(rs.chunks > 0, "rank {} starved", rs.rank);
        }
    }
}
