//! Wire protocol between workers and the master/coordinator.
//!
//! The message shapes encode the paper's central distinction:
//!
//! * **CCA** needs one round trip per chunk — `Request → Chunk` — but the
//!   master computes the chunk size inside the service loop (serialized).
//! * **DCA** needs two round trips — `GetStep → Step`, then
//!   `Commit → Chunk` — but the coordinator only bumps counters; the size
//!   is computed worker-side between the two trips (parallel). This is the
//!   "more communication messages than CCA" trade §7 discusses.

use crate::sched::{Assignment, StepTicket};
use crate::techniques::TechniqueKind;

/// A worker's performance report for its previously executed chunk —
/// piggybacked on scheduling requests so AF's per-PE (µ, σ) stay current
/// without extra messages.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfReport {
    /// Iterations in the finished chunk.
    pub iters: u64,
    /// Wall-clock seconds the chunk took.
    pub elapsed: f64,
}

/// AF synchronization data carried on the DCA phase-1 reply: the global
/// aggregates every PE needs to evaluate Eq. 11 (§4: "AF with DCA requires
/// additional synchronization").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AfInfo {
    /// `D = Σ σ_p²/µ_p`.
    pub d: f64,
    /// `E = (Σ 1/µ_p)⁻¹`.
    pub e: f64,
}

/// Worker → master/coordinator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WorkerMsg {
    /// CCA: "I am free — calculate and assign me a chunk."
    Request { rank: u32, report: Option<PerfReport> },
    /// DCA phase 1: "reserve me a scheduling step."
    GetStep { rank: u32, report: Option<PerfReport> },
    /// DCA phase 2: "I calculated `size` for my reserved step; assign it."
    Commit { rank: u32, ticket: StepTicket, size: u64 },
}

impl WorkerMsg {
    pub fn rank(&self) -> u32 {
        match self {
            WorkerMsg::Request { rank, .. }
            | WorkerMsg::GetStep { rank, .. }
            | WorkerMsg::Commit { rank, .. } => *rank,
        }
    }
}

/// Master/coordinator → worker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CoordMsg {
    /// An assigned chunk (CCA reply, or DCA commit reply).
    Chunk(Assignment),
    /// DCA phase-1 reply: the reserved step + AF aggregates when relevant,
    /// plus the coordinator slot's binding at reservation time — the
    /// configured technique over the whole loop (`base_step = 0`,
    /// `bound_n = N`) on static runs. An adaptive switch re-binds to the
    /// unassigned remainder with step indices rebased (the flat analogue of
    /// the hierarchical fresh-chunk install): the worker sizes with
    /// `tech@(bound_n, P)` at step `ticket.step − base_step`, so the
    /// schedule granted after a switch is the schedule the probe modeled.
    Step {
        ticket: StepTicket,
        af: Option<AfInfo>,
        tech: TechniqueKind,
        base_step: u64,
        bound_n: u64,
    },
    /// No work left — terminate (the `DLS_Terminated` condition).
    Done,
}

/// Both directions share one fabric payload type.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Msg {
    ToCoord(WorkerMsg),
    ToWorker(CoordMsg),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_extraction() {
        let t = StepTicket { step: 3, remaining: 10 };
        assert_eq!(WorkerMsg::Request { rank: 7, report: None }.rank(), 7);
        assert_eq!(WorkerMsg::GetStep { rank: 8, report: None }.rank(), 8);
        assert_eq!(WorkerMsg::Commit { rank: 9, ticket: t, size: 5 }.rank(), 9);
    }
}
