//! Versioned scenario specs: one JSON document = one reproducible cell.
//!
//! The `dca-dls scenario` subcommand family (`list | validate | explain |
//! run`) operates on documents of schema [`SCENARIO_SCHEMA`], which unify
//! what `benches/hier_sweep.rs`, `benches/sched_throughput.rs` and
//! `tenants --demo` previously hand-rolled: a named DES cell (flat or
//! hierarchical, any grant path, optional adaptive controller) or a
//! multi-tenant session, plus the expectations the run is checked against.
//! The committed cells live under `scenarios/`; their expected values come
//! from `benches/baselines/` and are cross-validated by the Python port.
//!
//! The document format is normative in `docs/scenario-spec.md`. Exit codes
//! of `scenario run` (stable, scriptable):
//!
//! * `0` — every scenario ran and every expectation held,
//! * `1` — a scenario ran but an expectation failed,
//! * `2` — the spec itself was unreadable or invalid.

use crate::config::{ClusterConfig, ExecutionModel, HierParams, SchedPath};
use crate::des::{pdes::PdesMode, simulate, DesConfig};
use crate::report::json::Json;
use crate::substrate::delay::InjectedDelay;
use crate::techniques::{CandidateSet, LoopParams, TechniqueKind};
use crate::tenant::spec::parse_session_spec;
use crate::tenant::{session_slowdowns, simulate_session, SessionConfig};
use crate::workload::IterationCost;

/// Schema tag every scenario document must carry — bump on breaking
/// changes to the document format.
pub const SCENARIO_SCHEMA: &str = "dca-dls/scenario/v1";

/// Relative tolerance applied to value expectations when the document
/// does not set `expect.tol`.
pub const DEFAULT_TOL: f64 = 0.10;

/// A parsed, fully resolved scenario document.
pub struct Scenario {
    pub name: String,
    pub description: String,
    pub body: Body,
    pub expect: Expectations,
}

/// What a scenario runs: one DES cell, or one multi-tenant session.
pub enum Body {
    Des(Box<DesConfig>),
    Session {
        cfg: Box<SessionConfig>,
        /// Re-run each tenant solo and report slowdowns (forced on when
        /// `expect.mean_slowdown` is set).
        slowdown: bool,
    },
}

/// The checks `scenario run` applies after the run. Value expectations are
/// relative (`|observed − expected| ≤ tol · expected`); bound expectations
/// are absolute.
#[derive(Debug, Clone, Default)]
pub struct Expectations {
    /// Expected `t_par` in seconds (DES scenarios only).
    pub t_par: Option<f64>,
    /// Relative tolerance for the value expectations ([`DEFAULT_TOL`]).
    pub tol: f64,
    /// Minimum adaptive switch count (DES scenarios only).
    pub min_switches: Option<u64>,
    /// Expected mean per-tenant slowdown vs solo (session scenarios only).
    pub mean_slowdown: Option<f64>,
    /// Minimum Jain fairness index (session scenarios only).
    pub min_jain: Option<f64>,
}

impl Expectations {
    fn is_empty(&self) -> bool {
        self.t_par.is_none()
            && self.min_switches.is_none()
            && self.mean_slowdown.is_none()
            && self.min_jain.is_none()
    }
}

/// One evaluated expectation.
pub struct Check {
    pub label: String,
    pub ok: bool,
    pub detail: String,
}

/// The outcome of `run_scenario`: per-expectation verdicts, the observed
/// quantities (for `--json`), and the run's stream records when a
/// `stream_interval` was requested.
pub struct RunReport {
    pub name: String,
    pub passed: bool,
    pub checks: Vec<Check>,
    pub observed: Json,
    pub stream: Vec<Json>,
}

fn as_bool(j: &Json) -> Option<bool> {
    match j {
        Json::Bool(b) => Some(*b),
        _ => None,
    }
}

fn req_str<'a>(doc: &'a Json, key: &str) -> anyhow::Result<&'a str> {
    doc.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("scenario is missing the string field \"{key}\""))
}

/// Parse and fully resolve one scenario document. Every error out of here
/// is a *spec* error (exit code 2 territory): unknown fields' spellings,
/// missing requirements, unresolvable techniques/models, bad geometry.
pub fn parse_scenario(text: &str) -> anyhow::Result<Scenario> {
    let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("bad scenario JSON: {e}"))?;
    let schema = req_str(&doc, "schema")?;
    anyhow::ensure!(
        schema == SCENARIO_SCHEMA,
        "unsupported scenario schema \"{schema}\" (this build understands \"{SCENARIO_SCHEMA}\")"
    );
    let name = req_str(&doc, "name")?.to_string();
    let description =
        doc.get("description").and_then(Json::as_str).unwrap_or_default().to_string();
    let expect = parse_expect(doc.get("expect"))?;
    let kind = req_str(&doc, "kind")?;
    let body = match kind {
        "des" => {
            anyhow::ensure!(
                expect.mean_slowdown.is_none() && expect.min_jain.is_none(),
                "expect.mean_slowdown/min_jain apply to session scenarios only"
            );
            let des = doc
                .get("des")
                .ok_or_else(|| anyhow::anyhow!("kind \"des\" needs a \"des\" object"))?;
            Body::Des(Box::new(parse_des(des)?))
        }
        "session" => {
            anyhow::ensure!(
                expect.t_par.is_none() && expect.min_switches.is_none(),
                "expect.t_par/min_switches apply to des scenarios only"
            );
            let session = doc
                .get("session")
                .ok_or_else(|| anyhow::anyhow!("kind \"session\" needs a \"session\" object"))?;
            let cluster = parse_cluster(doc.get("cluster"))?;
            // The session sub-object is exactly the `tenants --spec` file
            // format — re-render it and reuse that parser verbatim.
            let cfg = parse_session_spec(&session.render(), cluster)?;
            let slowdown = doc.get("slowdown").and_then(as_bool).unwrap_or(false)
                || expect.mean_slowdown.is_some();
            Body::Session { cfg: Box::new(cfg), slowdown }
        }
        other => anyhow::bail!("unknown scenario kind \"{other}\" (expect \"des\" or \"session\")"),
    };
    Ok(Scenario { name, description, body, expect })
}

fn parse_expect(j: Option<&Json>) -> anyhow::Result<Expectations> {
    let mut e = Expectations { tol: DEFAULT_TOL, ..Default::default() };
    let Some(j) = j else { return Ok(e) };
    anyhow::ensure!(matches!(j, Json::Obj(_)), "\"expect\" must be an object");
    e.t_par = j.get("t_par").and_then(Json::as_f64);
    if let Some(tol) = j.get("tol").and_then(Json::as_f64) {
        anyhow::ensure!(tol > 0.0 && tol < 1.0, "expect.tol must be in (0, 1), got {tol}");
        e.tol = tol;
    }
    e.min_switches = j.get("min_switches").and_then(Json::as_u64);
    e.mean_slowdown = j.get("mean_slowdown").and_then(Json::as_f64);
    e.min_jain = j.get("min_jain").and_then(Json::as_f64);
    if let Json::Obj(fields) = j {
        for (k, _) in fields {
            anyhow::ensure!(
                ["t_par", "tol", "min_switches", "mean_slowdown", "min_jain"]
                    .contains(&k.as_str()),
                "unknown expectation \"{k}\""
            );
        }
    }
    Ok(e)
}

/// `cluster` resolution: absent ⇒ the paper's 16×16 miniHPC; `{"ranks": R}`
/// ⇒ a single-node cluster of `R` ranks; otherwise miniHPC with `nodes` /
/// `ranks_per_node` / `racks` / `rack_latency_us` overridden.
fn parse_cluster(j: Option<&Json>) -> anyhow::Result<ClusterConfig> {
    let Some(j) = j else { return Ok(ClusterConfig::minihpc()) };
    anyhow::ensure!(matches!(j, Json::Obj(_)), "\"cluster\" must be an object");
    if let Some(ranks) = j.get("ranks").and_then(Json::as_u64) {
        anyhow::ensure!(
            j.get("nodes").is_none() && j.get("ranks_per_node").is_none(),
            "cluster.ranks is exclusive with nodes/ranks_per_node"
        );
        return Ok(ClusterConfig::small(ranks as u32));
    }
    let mut cluster = ClusterConfig::minihpc();
    if let Some(nodes) = j.get("nodes").and_then(Json::as_u64) {
        cluster.nodes = nodes as u32;
    }
    if let Some(rpn) = j.get("ranks_per_node").and_then(Json::as_u64) {
        cluster.ranks_per_node = rpn as u32;
    }
    if let Some(racks) = j.get("racks").and_then(Json::as_u64) {
        cluster.racks = racks as u32;
    }
    if let Some(us) = j.get("rack_latency_us").and_then(Json::as_f64) {
        cluster.inter_rack_latency = us * 1e-6;
    }
    anyhow::ensure!(
        cluster.racks >= 1 && cluster.nodes % cluster.racks == 0,
        "cluster.racks ({}) must evenly divide the node count ({})",
        cluster.racks,
        cluster.nodes
    );
    Ok(cluster)
}

fn parse_des(j: &Json) -> anyhow::Result<DesConfig> {
    let n = j
        .get("n")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("des.n (loop size) is required"))?;
    let tech_name = req_str(j, "technique")?;
    let technique = TechniqueKind::parse(tech_name)
        .ok_or_else(|| anyhow::anyhow!("unknown technique \"{tech_name}\""))?;
    let model = match j.get("model").and_then(Json::as_str) {
        None => ExecutionModel::Dca,
        Some(m) => ExecutionModel::parse(m)
            .ok_or_else(|| anyhow::anyhow!("unknown model \"{m}\" (cca|dca|rma|hier)"))?,
    };
    let cluster = parse_cluster(j.get("cluster"))?;
    let cost = match j.get("cost") {
        None => IterationCost::Constant(1e-5),
        Some(c) => IterationCost::Constant(
            c.as_f64()
                .filter(|x| x.is_finite() && *x > 0.0)
                .ok_or_else(|| anyhow::anyhow!("des.cost must be a positive seconds number"))?,
        ),
    };
    let params = LoopParams::new(n, cluster.total_ranks());
    let mut cfg = DesConfig::new(params, technique, model, cluster, cost);
    cfg.record_assignments =
        j.get("record_assignments").and_then(as_bool).unwrap_or(false);
    if let Some(p) = j.get("sched_path").and_then(Json::as_str) {
        cfg.sched_path = SchedPath::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown sched_path \"{p}\" (two-phase|lockfree|auto)"))?;
    }
    cfg.delay = parse_delay(j.get("delay"))?;
    cfg.hier = parse_hier(j, model)?;
    if let Some(t) = j.get("des_threads") {
        let t = t
            .as_u64()
            .filter(|t| *t <= u32::MAX as u64)
            .ok_or_else(|| anyhow::anyhow!("des.des_threads must be a thread count (0 = auto)"))?;
        cfg.des_threads = t as u32;
    }
    if let Some(m) = j.get("des_mode") {
        let m = m
            .as_str()
            .and_then(PdesMode::parse)
            .ok_or_else(|| anyhow::anyhow!("des.des_mode must be \"conservative\" or \"hybrid\""))?;
        anyhow::ensure!(
            j.get("des_threads").is_some(),
            "des.des_mode only applies to sharded runs — set des.des_threads too"
        );
        cfg.pdes_mode = m;
    }
    Ok(cfg)
}

fn parse_delay(j: Option<&Json>) -> anyhow::Result<InjectedDelay> {
    let Some(j) = j else { return Ok(InjectedDelay::none()) };
    anyhow::ensure!(matches!(j, Json::Obj(_)), "\"delay\" must be an object");
    let us = j
        .get("us")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("delay.us (microseconds) is required"))?;
    let site = j.get("site").and_then(Json::as_str).unwrap_or("calculation");
    let dist = j.get("dist").and_then(Json::as_str).unwrap_or("constant");
    let seconds = us * 1e-6;
    match (site, dist) {
        ("calculation", "constant") => Ok(InjectedDelay::calculation_only(seconds)),
        ("assignment", "constant") => Ok(InjectedDelay::assignment_only(seconds)),
        ("calculation", "exponential") => {
            let seed = j.get("seed").and_then(Json::as_u64).unwrap_or(0);
            Ok(InjectedDelay::exponential_calculation(seconds, seed))
        }
        ("assignment", "exponential") => {
            anyhow::bail!("exponential delays apply to the calculation site only")
        }
        (s, d) => anyhow::bail!(
            "unknown delay site/dist \"{s}\"/\"{d}\" \
             (site: calculation|assignment, dist: constant|exponential)"
        ),
    }
}

fn parse_hier(j: &Json, model: ExecutionModel) -> anyhow::Result<HierParams> {
    let hier_keys =
        ["inner", "levels", "fanouts", "watermark", "prefetch_depth", "adaptive"];
    if model != ExecutionModel::HierDca {
        for k in hier_keys {
            // `adaptive` also applies to flat DCA — everything else is
            // hierarchy-only.
            if k != "adaptive" {
                anyhow::ensure!(
                    j.get(k).is_none(),
                    "des.{k} only applies to the hierarchical model (\"model\": \"hier\")"
                );
            }
        }
    }
    let mut hier = match j.get("inner").and_then(Json::as_str) {
        None => HierParams::default(),
        Some(name) => HierParams::with_inner(
            TechniqueKind::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown inner technique \"{name}\""))?,
        ),
    };
    if let Some(k) = j.get("levels").and_then(Json::as_u64) {
        anyhow::ensure!(
            (1..=crate::config::MAX_LEVELS as u64).contains(&k),
            "des.levels must be in 1..={}",
            crate::config::MAX_LEVELS
        );
        hier = hier.with_levels(k as u32);
    }
    if let Some(Json::Arr(raw)) = j.get("fanouts") {
        let fanouts: Vec<u32> = raw
            .iter()
            .map(|x| {
                x.as_u64()
                    .filter(|f| *f >= 1)
                    .map(|f| f as u32)
                    .ok_or_else(|| anyhow::anyhow!("des.fanouts entries must be counts ≥ 1"))
            })
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            !fanouts.is_empty() && fanouts.len() <= hier.depth(),
            "des.fanouts takes at most des.levels ({}) entries",
            hier.depth()
        );
        hier = hier.with_fanouts(&fanouts);
    }
    match j.get("watermark") {
        None => {}
        Some(w) => match (w.as_str(), w.as_u64()) {
            (Some("auto"), _) => hier = hier.with_auto_watermark(),
            (None, Some(0)) => {}
            (None, Some(w)) => hier = hier.with_watermark(w),
            _ => anyhow::bail!("des.watermark must be an iteration count or \"auto\""),
        },
    }
    if let Some(q) = j.get("prefetch_depth").and_then(Json::as_u64) {
        anyhow::ensure!(q >= 1, "des.prefetch_depth must be ≥ 1");
        hier = hier.with_prefetch_depth(q as u32);
    }
    if let Some(a) = j.get("adaptive") {
        anyhow::ensure!(matches!(a, Json::Obj(_)), "des.adaptive must be an object");
        hier = hier.with_adaptive();
        if let Some(g) = a.get("probe_interval").and_then(Json::as_u64) {
            anyhow::ensure!(g >= 1, "adaptive.probe_interval must be ≥ 1");
            hier = hier.with_probe_interval(g as u32);
        }
        if let Some(c) = a.get("candidates").and_then(Json::as_str) {
            hier = hier.with_candidates(CandidateSet::parse(c)?);
        }
    }
    Ok(hier)
}

/// Human-readable summary of a resolved scenario (the `explain` verb).
pub fn explain(sc: &Scenario) -> String {
    let mut out = format!("{}: {}\n", sc.name, sc.description);
    match &sc.body {
        Body::Des(cfg) => {
            out.push_str(&format!(
                "  kind      des — {} on {}, N = {}, {} ranks ({}×{}, {} rack{})\n",
                cfg.technique.name(),
                cfg.model.label_adaptive(cfg.hier.depth() as u32, cfg.hier.adaptive.enabled),
                cfg.params.n,
                cfg.cluster.total_ranks(),
                cfg.cluster.nodes,
                cfg.cluster.ranks_per_node,
                cfg.cluster.racks,
                if cfg.cluster.racks == 1 { "" } else { "s" },
            ));
            out.push_str(&format!(
                "  grants    {} path, cost {:?}, delay {:?}\n",
                cfg.sched_path.name(),
                cfg.cost,
                cfg.delay,
            ));
            if cfg.model == ExecutionModel::HierDca {
                out.push_str(&format!(
                    "  tree      depth {}, inner {}\n",
                    cfg.hier.depth(),
                    cfg.hier.inner.map(|t| t.name()).unwrap_or("(outer)"),
                ));
            }
            if cfg.des_threads != 1 {
                out.push_str(&format!(
                    "  pdes      {} executor, {} DES threads\n",
                    cfg.pdes_mode.as_str(),
                    if cfg.des_threads == 0 {
                        "auto".to_string()
                    } else {
                        cfg.des_threads.to_string()
                    },
                ));
            }
        }
        Body::Session { cfg, slowdown } => {
            out.push_str(&format!(
                "  kind      session — {} tenants over {} shared ranks, policy {}, {} path{}\n",
                cfg.tenants.len(),
                cfg.cluster.total_ranks(),
                cfg.policy,
                cfg.sched_path.name(),
                if *slowdown { ", with solo slowdown re-runs" } else { "" },
            ));
            if cfg.des_threads != 1 {
                out.push_str(&format!(
                    "  substrate sharded session loop — {} workers, {} epochs\n",
                    if cfg.des_threads == 0 {
                        "auto".to_string()
                    } else {
                        cfg.des_threads.to_string()
                    },
                    cfg.des_mode.as_str(),
                ));
            }
        }
    }
    let e = &sc.expect;
    if e.is_empty() {
        out.push_str("  expect    (nothing — the run only has to complete)\n");
    }
    if let Some(t) = e.t_par {
        out.push_str(&format!("  expect    t_par = {t} ± {:.0}%\n", e.tol * 100.0));
    }
    if let Some(k) = e.min_switches {
        out.push_str(&format!("  expect    ≥ {k} adaptive switches\n"));
    }
    if let Some(s) = e.mean_slowdown {
        out.push_str(&format!("  expect    mean slowdown = {s} ± {:.0}%\n", e.tol * 100.0));
    }
    if let Some(jn) = e.min_jain {
        out.push_str(&format!("  expect    Jain fairness ≥ {jn}\n"));
    }
    out
}

fn rel_check(label: &str, observed: f64, expected: f64, tol: f64) -> Check {
    let ok = (observed - expected).abs() <= tol * expected.abs();
    Check {
        label: label.to_string(),
        ok,
        detail: format!(
            "observed {observed:.7}, expected {expected:.7} ± {:.0}% ({})",
            tol * 100.0,
            if ok { "ok" } else { "FAIL" }
        ),
    }
}

fn bound_check(label: &str, observed: f64, min: f64) -> Check {
    let ok = observed >= min;
    Check {
        label: label.to_string(),
        ok,
        detail: format!("observed {observed}, need ≥ {min} ({})", if ok { "ok" } else { "FAIL" }),
    }
}

/// Run one scenario and evaluate its expectations. `stream_interval > 0`
/// additionally collects the run's NDJSON stream records. Errors out of
/// here are *run* infrastructure failures (still exit code 1 — the spec
/// was valid).
pub fn run_scenario(sc: &Scenario, stream_interval: f64) -> anyhow::Result<RunReport> {
    let mut checks = Vec::new();
    let (observed, stream) = match &sc.body {
        Body::Des(cfg) => {
            let mut cfg = (**cfg).clone();
            cfg.stream_interval = stream_interval;
            let r = simulate(&cfg)?;
            if let Some(t) = sc.expect.t_par {
                checks.push(rel_check("t_par", r.t_par(), t, sc.expect.tol));
            }
            if let Some(k) = sc.expect.min_switches {
                checks.push(bound_check("switches", r.switch_events.len() as f64, k as f64));
            }
            let mut observed = Json::obj()
                .field("t_par", r.t_par())
                .field("chunks", r.stats.chunks)
                .field("messages", r.stats.messages)
                .field("fast_grants", r.fast_grants)
                .field("events", r.events)
                .field("switches", r.switch_events.len() as u64);
            if let Some(p) = &r.pdes {
                observed = observed.field(
                    "pdes",
                    Json::obj()
                        .field("shards", p.shards)
                        .field("threads", p.threads)
                        .field("mode", p.mode.as_str())
                        .field("rollbacks", p.rollbacks),
                );
            }
            (observed, r.stream)
        }
        Body::Session { cfg, slowdown } => {
            let mut cfg = (**cfg).clone();
            cfg.stream_interval = stream_interval;
            let (outcome, mean) = if *slowdown {
                let (o, _, mean) = session_slowdowns(&cfg)?;
                (o, Some(mean))
            } else {
                (simulate_session(&cfg)?, None)
            };
            if let (Some(s), Some(mean)) = (sc.expect.mean_slowdown, mean) {
                checks.push(rel_check("mean_slowdown", mean, s, sc.expect.tol));
            }
            if let Some(jn) = sc.expect.min_jain {
                checks.push(bound_check("jain_fairness", outcome.jain_fairness, jn));
            }
            let mut observed = Json::obj()
                .field("makespan", outcome.makespan)
                .field("events", outcome.events)
                .field("messages", outcome.messages)
                .field("tenants", outcome.tenants.len() as u64)
                .field("jain_fairness", outcome.jain_fairness);
            if let Some(mean) = mean {
                observed = observed.field("mean_slowdown", mean);
            }
            if let Some(p) = &outcome.pdes {
                observed = observed.field(
                    "pdes",
                    Json::obj()
                        .field("shards", p.shards)
                        .field("threads", p.threads)
                        .field("mode", p.mode.as_str())
                        .field("arbiter_epochs", p.arbiter_epochs)
                        .field("rollbacks", p.rollbacks),
                );
            }
            (observed, outcome.stream)
        }
    };
    let passed = checks.iter().all(|c| c.ok);
    Ok(RunReport { name: sc.name.clone(), passed, checks, observed, stream })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn des_doc(expect: &str) -> String {
        format!(
            r#"{{
              "schema": "dca-dls/scenario/v1",
              "name": "unit-des",
              "kind": "des",
              "des": {{
                "n": 2000, "technique": "GSS",
                "cluster": {{"ranks": 4}}, "cost": 1e-6
              }},
              "expect": {expect}
            }}"#
        )
    }

    #[test]
    fn des_scenario_round_trips_and_passes() {
        let sc = parse_scenario(&des_doc(r#"{"t_par": 5.1e-4, "tol": 0.5}"#)).unwrap();
        assert_eq!(sc.name, "unit-des");
        let Body::Des(cfg) = &sc.body else { panic!("des body") };
        assert_eq!(cfg.params.n, 2000);
        assert_eq!(cfg.cluster.total_ranks(), 4);
        let report = run_scenario(&sc, 0.0).unwrap();
        assert!(report.passed, "{:?}", report.checks.iter().map(|c| &c.detail).collect::<Vec<_>>());
        assert!(report.stream.is_empty(), "no stream requested");
        assert!(report.observed.get("t_par").is_some());
    }

    #[test]
    fn failed_expectation_reports_not_errors() {
        let sc = parse_scenario(&des_doc(r#"{"t_par": 99.0, "tol": 0.01}"#)).unwrap();
        let report = run_scenario(&sc, 0.0).unwrap();
        assert!(!report.passed);
        assert_eq!(report.checks.len(), 1);
        assert!(report.checks[0].detail.contains("FAIL"));
    }

    #[test]
    fn run_with_stream_interval_collects_records() {
        let sc = parse_scenario(&des_doc("{}")).unwrap();
        let report = run_scenario(&sc, 1e-5).unwrap();
        assert!(report.passed, "no expectations ⇒ pass");
        assert!(!report.stream.is_empty(), "streaming requested");
    }

    #[test]
    fn spec_errors_are_rejected() {
        for (doc, why) in [
            ("{", "unterminated"),
            (r#"{"schema": "nope/v0", "name": "x", "kind": "des", "des": {}}"#, "schema"),
            (r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "wat"}"#, "kind"),
            (
                r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "des",
                   "des": {"technique": "GSS"}}"#,
                "missing n",
            ),
            (
                r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "des",
                   "des": {"n": 100, "technique": "WAT"}}"#,
                "unknown technique",
            ),
            (
                r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "des",
                   "des": {"n": 100, "technique": "GSS", "inner": "SS"}}"#,
                "hier-only key on flat model",
            ),
            (
                r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "des",
                   "des": {"n": 100, "technique": "GSS", "des_threads": "many"}}"#,
                "non-numeric des_threads",
            ),
            (
                r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "des",
                   "des": {"n": 100, "technique": "GSS", "des_threads": 4,
                           "des_mode": "optimistic"}}"#,
                "unknown des_mode",
            ),
            (
                r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "des",
                   "des": {"n": 100, "technique": "GSS", "des_mode": "hybrid"}}"#,
                "des_mode without des_threads",
            ),
            (
                r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "des",
                   "des": {"n": 100, "technique": "GSS"},
                   "expect": {"t_per": 1.0}}"#,
                "unknown expectation",
            ),
            (
                r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "session",
                   "session": {"tenants": []}}"#,
                "empty session",
            ),
            (
                r#"{"schema": "dca-dls/scenario/v1", "name": "x", "kind": "session",
                   "session": {"tenants": [{"name": "t", "n": 10, "technique": "SS"}]},
                   "expect": {"t_par": 1.0}}"#,
                "des expectation on session",
            ),
        ] {
            assert!(parse_scenario(doc).is_err(), "{why} must be a spec error");
        }
    }

    #[test]
    fn session_scenario_runs_with_slowdown() {
        let sc = parse_scenario(
            r#"{
              "schema": "dca-dls/scenario/v1",
              "name": "unit-session",
              "kind": "session",
              "cluster": {"ranks": 4},
              "session": {
                "policy": "fair",
                "tenants": [
                  {"name": "a", "n": 400, "technique": "SS", "cost": 1e-6},
                  {"name": "b", "n": 400, "technique": "GSS", "arrival": 1e-4, "cost": 1e-6}
                ]
              },
              "expect": {"mean_slowdown": 1.0, "tol": 0.9, "min_jain": 0.5}
            }"#,
        )
        .unwrap();
        let Body::Session { slowdown, .. } = &sc.body else { panic!("session body") };
        assert!(slowdown, "mean_slowdown expectation forces solo re-runs");
        let report = run_scenario(&sc, 0.0).unwrap();
        assert!(report.passed, "{:?}", report.checks.iter().map(|c| &c.detail).collect::<Vec<_>>());
        assert!(report.observed.get("mean_slowdown").is_some());
    }

    #[test]
    fn explain_names_the_cell() {
        let sc = parse_scenario(&des_doc(r#"{"t_par": 1.0}"#)).unwrap();
        let text = explain(&sc);
        assert!(text.contains("unit-des"));
        assert!(text.contains("GSS"));
        assert!(text.contains("t_par = 1"));
    }

    /// A sharded scenario cell must run through the PDES executor (the
    /// summary is attached) and observe the exact same result the
    /// sequential run would — the same t_par either way, by the PDES
    /// determinism guarantee.
    #[test]
    fn pdes_des_scenario_runs_sharded_and_matches_sequential() {
        let doc = |threads: &str| {
            format!(
                r#"{{
                  "schema": "dca-dls/scenario/v1",
                  "name": "unit-pdes",
                  "kind": "des",
                  "des": {{
                    "n": 4000, "technique": "GSS",
                    "cluster": {{"nodes": 4, "ranks_per_node": 4}}, "cost": 1e-6,
                    "des_threads": {threads}, "des_mode": "hybrid"
                  }}
                }}"#
            )
        };
        let sc = parse_scenario(&doc("4")).unwrap();
        let Body::Des(cfg) = &sc.body else { panic!("des body") };
        assert_eq!(cfg.des_threads, 4);
        assert_eq!(cfg.pdes_mode, PdesMode::Hybrid);
        let text = explain(&sc);
        assert!(text.contains("hybrid executor"), "{text}");
        let sharded = run_scenario(&sc, 0.0).unwrap();
        let p = sharded.observed.get("pdes").expect("sharded run attaches a pdes summary");
        assert!(p.get("shards").and_then(Json::as_u64).unwrap() >= 2);

        // `des_threads: 0` (auto) must also shard, and both must equal the
        // sequential t_par bit for bit.
        let auto = run_scenario(&parse_scenario(&doc("0")).unwrap(), 0.0).unwrap();
        assert!(auto.observed.get("pdes").is_some(), "auto must resolve to ≥ 2 threads here");
        let mut seq = parse_scenario(&doc("4")).unwrap();
        if let Body::Des(cfg) = &mut seq.body {
            cfg.des_threads = 1;
        }
        let seq = run_scenario(&seq, 0.0).unwrap();
        for r in [&sharded, &auto] {
            assert_eq!(
                r.observed.get("t_par").and_then(Json::as_f64),
                seq.observed.get("t_par").and_then(Json::as_f64),
                "PDES scenario must be bit-identical to sequential"
            );
        }
    }

    #[test]
    fn hier_des_with_adaptive_parses() {
        let sc = parse_scenario(
            r#"{
              "schema": "dca-dls/scenario/v1",
              "name": "unit-hier",
              "kind": "des",
              "des": {
                "n": 4000, "technique": "FAC2", "model": "hier", "inner": "SS",
                "cluster": {"nodes": 2, "ranks_per_node": 2}, "cost": 1e-6,
                "delay": {"site": "calculation", "us": 10, "dist": "exponential", "seed": 7},
                "adaptive": {"probe_interval": 4, "candidates": "ss,gss,fac"}
              }
            }"#,
        )
        .unwrap();
        let Body::Des(cfg) = &sc.body else { panic!("des body") };
        assert_eq!(cfg.model, ExecutionModel::HierDca);
        assert!(cfg.hier.adaptive.enabled);
        assert_eq!(cfg.cluster.total_ranks(), 4);
        let report = run_scenario(&sc, 0.0).unwrap();
        assert!(report.passed);
    }
}
