//! # dca-dls — Distributed Chunk Calculation for Dynamic Loop Self-Scheduling
//!
//! Reproduction of Eleliemy & Ciorba, *"A Distributed Chunk Calculation
//! Approach for Self-scheduling of Parallel Applications on Distributed-memory
//! Systems"* (2021), as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper separates the two operations of every self-scheduling step:
//!
//! * **chunk calculation** — a per-technique mathematical formula; needs *no*
//!   synchronization when expressed in *straightforward* (closed) form, and can
//!   therefore run on the requesting worker (DCA),
//! * **chunk assignment** — advancing the central work queue; needs exclusive
//!   access, and stays on a coordinator (or an atomic RMA window).
//!
//! Layer 3 (this crate) implements thirteen DLS techniques in both recursive
//! (CCA) and closed (DCA) form, the CCA master–worker and DCA coordinator
//! execution models over simulated MPI substrates, a deterministic
//! discrete-event simulator that regenerates the paper's 256-rank experiments
//! (Figs. 4–5), a two-level **hierarchical** model ([`hier`], the §7 /
//! arXiv 1903.09510 follow-up: global coordinator → per-node masters →
//! local ranks), and a real multi-threaded engine that executes chunks
//! through AOT-compiled JAX/Pallas artifacts via PJRT (layers 2/1, see
//! `python/`).
//!
//! ## Quick start
//!
//! ```no_run
//! use dca_dls::prelude::*;
//!
//! let params = LoopParams::new(1_000, 4);
//! let tech = Technique::new(TechniqueKind::Gss, &params);
//! let chunks = dca_dls::sched::closed_form_schedule(&tech, &params);
//! assert_eq!(chunks.iter().map(|c| c.size).sum::<u64>(), 1_000);
//! ```

pub mod config;
pub mod coordinator;
pub mod des;
pub mod hier;
pub mod lb4mpi;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod substrate;
pub mod techniques;
pub mod tenant;
pub mod workload;

/// Commonly used items, re-exported for examples and downstream users.
pub mod prelude {
    pub use crate::config::{
        DelaySite, ExecutionModel, ExperimentConfig, HierParams, LevelPlan, LevelSpec,
        WatermarkMode,
    };
    pub use crate::metrics::LoopStats;
    pub use crate::sched::{Assignment, WorkQueue};
    pub use crate::techniques::{LoopParams, Technique, TechniqueKind};
    pub use crate::workload::{IterationCost, Workload};
}
