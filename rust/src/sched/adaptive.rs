//! SimAS-style adaptive per-subtree technique selection (arXiv 1912.02050's
//! online-selection idea, driven by the lightweight runtime measurements of
//! arXiv 2007.07977 instead of a nested simulation).
//!
//! Every subtree master (and the flat DCA coordinator) owns one
//! [`AdaptiveController`]. The controller maintains **per-subtree EWMAs**
//! of what the master can actually observe on either substrate:
//!
//! * `µ̂` — mean per-iteration execution time, from the per-chunk
//!   performance reports its children already piggyback on scheduling
//!   requests (the same channel AF uses);
//! * `σ̂` — dispersion of those per-iteration rates (the imbalance risk of
//!   a large tail chunk);
//! * `ô` — per-grant scheduling overhead: the gap between a child's
//!   consecutive chunk completions minus the chunk's execution time. This
//!   is the full round trip *including the injected calculation delay* —
//!   exactly the quantity that decides whether fine-grained techniques (SS)
//!   drown in overhead under slowdown.
//!
//! At the probe cadence (every `probe_interval` grants) the controller runs
//! a **closed-form probe** over the candidate set: each candidate's chunk
//! count `C` and tail-chunk size `K_tail` are read off its precomputed
//! [`ChunkTable`] prefix sums (memoized per bucketed length — no nested DES,
//! no schedule materialization kept around), and plugged into the cost
//! model
//!
//! ```text
//! t̂(tech) = (L·µ̂ + C·ô) / f  +  (1 − 1/f) · K_tail · (µ̂ + σ̂)
//! ```
//!
//! — parallel work plus per-chunk overhead spread over the `f` children,
//! plus a straggler term for the schedule's final chunk (executed by one
//! child while its `f − 1` peers idle, padded by the observed dispersion).
//! The model is deliberately coarse: it only has to *rank* candidates, and
//! every input is an EWMA that tracks the perturbation the run is actually
//! experiencing. A switch is taken only when the best candidate is
//! predicted to beat the current binding by more than
//! [`PROBE_HYSTERESIS`], so a single-candidate set (or a probe that keeps
//! confirming the current technique) never perturbs the schedule at all —
//! the property the bit-identical regression tests pin.
//!
//! Probes are charged no virtual time on the DES: the real cost is a few
//! table walks amortized over `probe_interval` grants, off the grant
//! critical path (the threaded engine simply pays it inline).
//!
//! AF can never be switched *to* — it has no closed form to probe
//! ([`crate::techniques::CandidateSet`] cannot represent it) — but a run
//! *starting* on AF is switched away from as soon as the EWMAs are primed
//! (its unprobeable current binding scores `+∞`).

use std::collections::HashMap;

use crate::config::AdaptiveParams;
use crate::techniques::{ChunkTable, LoopParams, TechniqueKind};

/// Relative margin a candidate must beat the current binding by before the
/// controller switches — hysteresis against estimate noise and thrashing.
pub const PROBE_HYSTERESIS: f64 = 0.05;

/// Step-count budget per probed table: an SS-like schedule beyond this is
/// scored unviable (`+∞`) instead of materialized — it could never win a
/// probe it takes that many grants to execute.
pub const PROBE_STEP_CAP: u64 = 1 << 20;

/// EWMA weight of the newest observation sample.
pub const OBS_EWMA_ALPHA: f64 = 0.25;

/// One technique-slot rebind, as recorded in run results and JSON exports
/// (the switch-event trace).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwitchEvent {
    /// When the rebind was decided (virtual seconds on the DES, wall-clock
    /// seconds since the run barrier on the threaded engine).
    pub at_s: f64,
    /// Protocol level of the rebound ledger (0 = flat DCA coordinator).
    pub level: u32,
    /// Master index within the level (0 for the flat coordinator).
    pub master: u32,
    pub from: TechniqueKind,
    pub to: TechniqueKind,
    /// Predicted `t̂(to) / t̂(from)` at switch time (< 1 − hysteresis; 0.0
    /// when the current binding was unprobeable, i.e. AF).
    pub predicted_ratio: f64,
}

/// Scalar EWMA (first sample taken verbatim).
#[derive(Debug, Clone, Copy, Default)]
struct Ewma {
    v: f64,
    primed: bool,
}

impl Ewma {
    fn observe(&mut self, x: f64) {
        if self.primed {
            self.v = OBS_EWMA_ALPHA * x + (1.0 - OBS_EWMA_ALPHA) * self.v;
        } else {
            self.v = x;
            self.primed = true;
        }
    }

    fn value(&self) -> Option<f64> {
        self.primed.then_some(self.v)
    }
}

/// The probe's schedule statistics for one `(technique, length)` binding:
/// chunk count and tail-chunk size, read off the table's prefix sums.
/// `None` = unviable (no closed form, or over the step cap).
type ScheduleStats = Option<(u64, u64)>;

/// Per-subtree adaptive controller — see the module docs. `Clone` so a
/// PDES shard checkpoint can snapshot it for rollback.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    base: LoopParams,
    fanout: u32,
    candidates: Vec<TechniqueKind>,
    probe_interval: u32,
    grants_since_probe: u32,
    current: TechniqueKind,
    /// EWMA of per-iteration execution time (s/iter).
    mu: Ewma,
    /// EWMA of squared deviation of per-iteration rates around `µ̂`.
    var: Ewma,
    /// EWMA of per-grant scheduling overhead (s/chunk).
    overhead: Ewma,
    /// Per-child last observation timestamp (s) for the overhead gap.
    last_seen_s: Vec<Option<f64>>,
    /// Probe-stat memo, keyed by `(kind, bucketed length)`.
    memo: HashMap<(TechniqueKind, u64), ScheduleStats>,
    switches: u32,
}

/// Round a probe length down to a power of two so the memo stays
/// logarithmic in the lengths seen (flat probes shrink every time).
fn bucket_len(len: u64) -> u64 {
    let len = len.max(1);
    1u64 << (63 - len.leading_zeros() as u64)
}

impl AdaptiveController {
    /// Controller for a subtree whose ledger subdivides chunks among
    /// `fanout` children, currently bound to `initial`. `fast_only`
    /// restricts the candidates to fast-path techniques (the pure
    /// `SchedPath::LockFree` rule — rebinding must never force a demotion).
    pub fn new(
        initial: TechniqueKind,
        base: &LoopParams,
        fanout: u32,
        params: AdaptiveParams,
        fast_only: bool,
    ) -> Self {
        let set = if fast_only {
            params.candidates().fast_path_only()
        } else {
            params.candidates()
        };
        AdaptiveController {
            base: base.clone(),
            fanout: fanout.max(1),
            candidates: set.iter().collect(),
            probe_interval: params.probe_interval().max(1),
            grants_since_probe: 0,
            current: initial,
            mu: Ewma::default(),
            var: Ewma::default(),
            overhead: Ewma::default(),
            last_seen_s: vec![None; fanout.max(1) as usize],
            memo: HashMap::new(),
            switches: 0,
        }
    }

    /// The technique the controller currently considers bound.
    pub fn current(&self) -> TechniqueKind {
        self.current
    }

    /// Rebinds performed so far.
    pub fn switch_count(&self) -> u32 {
        self.switches
    }

    /// Current `µ̂` EWMA — mean per-iteration execution time (s/iter) — if
    /// primed. Exposed for the observability stream.
    pub fn mu_hat(&self) -> Option<f64> {
        self.mu.value()
    }

    /// Current `σ̂` — square root of the squared-deviation EWMA (s/iter) —
    /// if primed.
    pub fn sigma_hat(&self) -> Option<f64> {
        self.var.value().map(f64::sqrt)
    }

    /// Current `ô` EWMA — per-grant scheduling overhead (s/chunk) — if
    /// primed.
    pub fn overhead_hat(&self) -> Option<f64> {
        self.overhead.value()
    }

    /// Fold in one finished chunk observed from `child` (local index) at
    /// time `now_s`: `iters` iterations took `elapsed_s` of pure execution.
    /// The gap since the child's previous observation, minus the execution
    /// time, is the per-grant overhead sample.
    pub fn observe_chunk(&mut self, child: u32, iters: u64, elapsed_s: f64, now_s: f64) {
        if iters == 0 {
            return;
        }
        self.observe_exec(iters, elapsed_s);
        let c = child as usize;
        if c >= self.last_seen_s.len() {
            self.last_seen_s.resize(c + 1, None);
        }
        if let Some(prev) = self.last_seen_s[c] {
            let gap = now_s - prev;
            self.overhead.observe((gap - elapsed_s).max(0.0));
        }
        self.last_seen_s[c] = Some(now_s);
    }

    /// µ̂/σ̂-only observation, for samples whose round-trip gap cannot be
    /// attributed to single grants — the threaded lock-free leaf's
    /// aggregated reports (a slow-path `Get` summarizes every CAS-granted
    /// chunk since the previous one), and the master's own executions.
    /// Feeding these through [`Self::observe_chunk`] would poison the
    /// per-grant overhead EWMA with whole-window gaps.
    pub fn observe_exec(&mut self, iters: u64, elapsed_s: f64) {
        if iters == 0 {
            return;
        }
        let rate = elapsed_s / iters as f64;
        if let Some(mu) = self.mu.value() {
            let dev = rate - mu;
            self.var.observe(dev * dev);
        }
        self.mu.observe(rate);
    }

    /// Count one grant served from the subtree's ledger; `true` when a
    /// probe is due.
    pub fn tick_grant(&mut self) -> bool {
        self.grants_since_probe += 1;
        if self.grants_since_probe >= self.probe_interval {
            self.grants_since_probe = 0;
            true
        } else {
            false
        }
    }

    /// Predicted completion time of a `len`-iteration chunk under `kind`
    /// with per-grant overhead `o` — the closed-form cost model of the
    /// module docs. `None` until `µ̂` is primed; `+∞`-equivalent (`None`)
    /// for unviable schedules.
    fn estimate(&mut self, kind: TechniqueKind, len: u64, o: f64) -> Option<f64> {
        let mu = self.mu.value()?;
        let lenb = bucket_len(len);
        let stats = *self
            .memo
            .entry((kind, lenb))
            .or_insert_with(|| schedule_stats(kind, &self.base, self.fanout, lenb));
        let (chunks, k_tail) = stats?;
        let f = self.fanout as f64;
        let sigma = self.var.value().map(f64::sqrt).unwrap_or(0.0);
        let l = lenb as f64;
        Some((l * mu + chunks as f64 * o) / f + (1.0 - 1.0 / f) * k_tail as f64 * (mu + sigma))
    }

    /// Run one probe over `remaining` unassigned iterations, with the
    /// **measured** per-grant overhead EWMA. Returns the switch to take —
    /// `(new kind, predicted ratio)` — or `None` when the current binding
    /// survives (including: measurements not primed yet, no viable
    /// candidate, or no candidate beating the hysteresis margin). On
    /// `Some`, the controller's notion of the current binding is already
    /// updated; the caller performs the actual ledger rebind.
    pub fn probe(&mut self, remaining: u64) -> Option<(TechniqueKind, f64)> {
        let o = self.overhead.value()?;
        self.probe_at(remaining, o)
    }

    /// [`Self::probe`] for a subtree currently granting over the lock-free
    /// CAS word: the per-grant cost there is a single atomic op, charged as
    /// **zero** (the threaded master cannot observe per-CAS gaps, and any
    /// aggregated estimate would be a whole-window artifact — see
    /// [`Self::observe_exec`]). Probes then need only `µ̂` and rank the
    /// candidates by work + tail imbalance, which is exactly what is left
    /// to optimize on a path with no exchange to amortize.
    pub fn probe_on_fast_path(&mut self, remaining: u64) -> Option<(TechniqueKind, f64)> {
        self.probe_at(remaining, 0.0)
    }

    fn probe_at(&mut self, remaining: u64, o: f64) -> Option<(TechniqueKind, f64)> {
        if remaining == 0 || self.mu.value().is_none() {
            return None;
        }
        let current = self.current;
        let cur_est = self.estimate(current, remaining, o);
        let mut best: Option<(TechniqueKind, f64)> = None;
        for kind in self.candidates.clone() {
            if kind == current {
                continue;
            }
            if let Some(est) = self.estimate(kind, remaining, o) {
                // Strict `<` keeps ties on the earliest candidate in ALL
                // order — deterministic.
                if best.is_none_or(|(_, b)| est < b) {
                    best = Some((kind, est));
                }
            }
        }
        let (to, best_est) = best?;
        let (take, ratio) = match cur_est {
            // An unprobeable current binding (AF) loses to any viable
            // candidate the moment measurements exist.
            None => (true, 0.0),
            Some(cur) => (best_est < cur * (1.0 - PROBE_HYSTERESIS), best_est / cur),
        };
        if !take {
            return None;
        }
        self.current = to;
        self.switches += 1;
        Some((to, ratio))
    }
}

/// `(chunk count, tail-chunk size)` of `kind` bound to a `len`-iteration
/// chunk subdivided among `fanout` requesters — read off the precomputed
/// [`ChunkTable`] prefix sums; `None` when `kind` has no closed form or the
/// schedule blows the probe step cap.
fn schedule_stats(
    kind: TechniqueKind,
    base: &LoopParams,
    fanout: u32,
    len: u64,
) -> ScheduleStats {
    let params = crate::hier::protocol::with_np(base, len, fanout);
    let table = ChunkTable::build_capped(kind, &params, PROBE_STEP_CAP)?;
    Some((table.steps(), table.last_chunk()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AdaptiveParams;
    use crate::techniques::CandidateSet;

    fn params(interval: u32, cands: &str) -> AdaptiveParams {
        AdaptiveParams {
            enabled: true,
            probe_interval: interval,
            candidates: CandidateSet::parse(cands).unwrap(),
        }
    }

    fn ctl(initial: TechniqueKind, cands: &str) -> AdaptiveController {
        AdaptiveController::new(
            initial,
            &LoopParams::new(100_000, 64),
            16,
            params(1, cands),
            false,
        )
    }

    /// Prime the EWMAs with a uniform workload: per-iteration cost `mu`,
    /// per-grant overhead `o` (each child reports chunks `elapsed + o`
    /// apart, so the gap-minus-exec overhead sample is exactly `o`).
    fn prime(c: &mut AdaptiveController, mu: f64, o: f64) {
        for round in 0..4u32 {
            for child in 0..4u32 {
                let iters = 32u64;
                let elapsed = iters as f64 * mu;
                let now = (round + 1) as f64 * (elapsed + o) + child as f64 * 1e-9;
                c.observe_chunk(child, iters, elapsed, now);
            }
        }
    }

    #[test]
    fn probe_needs_primed_measurements() {
        let mut c = ctl(TechniqueKind::Ss, "ss,fac");
        assert!(c.tick_grant());
        assert_eq!(c.probe(10_000), None, "no µ̂/ô yet ⇒ no switch");
        assert_eq!(c.current(), TechniqueKind::Ss);
    }

    #[test]
    fn heavy_overhead_switches_away_from_ss() {
        let mut c = ctl(TechniqueKind::Ss, "ss,gss,fac");
        // 10 µs iterations, 100 µs per-grant overhead: SS pays the overhead
        // once per iteration — a batched candidate must win the probe.
        prime(&mut c, 1e-5, 1e-4);
        let (to, ratio) = c.probe(8_192).expect("must switch");
        assert_ne!(to, TechniqueKind::Ss);
        assert!(ratio < 1.0 - PROBE_HYSTERESIS, "ratio {ratio}");
        assert_eq!(c.current(), to);
        assert_eq!(c.switch_count(), 1);
        // Re-probing from the better binding never thrashes back to SS.
        if let Some((again, _)) = c.probe(8_192) {
            assert_ne!(again, TechniqueKind::Ss, "switched back into the overhead trap");
        }
    }

    #[test]
    fn single_candidate_set_never_switches() {
        let mut c = ctl(TechniqueKind::Gss, "gss");
        prime(&mut c, 1e-5, 1e-3);
        assert_eq!(c.probe(8_192), None, "only candidate == current");
        assert_eq!(c.switch_count(), 0);
    }

    #[test]
    fn hysteresis_holds_near_parity() {
        // Candidates whose estimates are close (GSS vs FAC under mild
        // overhead) must not flip the binding back and forth.
        let mut c = ctl(TechniqueKind::Fac2, "gss,fac");
        prime(&mut c, 1e-5, 1e-7);
        let first = c.probe(8_192);
        if let Some((to, _)) = first {
            // If it switched once, the reverse probe must not undo it.
            assert_eq!(c.probe(8_192), None, "thrash after switch to {to}");
        }
    }

    #[test]
    fn unprobeable_current_is_replaced_once_measured() {
        let mut c = ctl(TechniqueKind::Af, "gss,fac");
        assert_eq!(c.probe(8_192), None, "unprimed");
        prime(&mut c, 1e-5, 1e-5);
        let (to, ratio) = c.probe(8_192).expect("AF must be switched away from");
        assert!(to == TechniqueKind::Gss || to == TechniqueKind::Fac2);
        assert_eq!(ratio, 0.0, "AF's estimate is unprobeable");
    }

    #[test]
    fn fast_only_strips_tap() {
        let c = AdaptiveController::new(
            TechniqueKind::Ss,
            &LoopParams::new(10_000, 16),
            4,
            params(4, "ss,tap,gss"),
            true,
        );
        assert!(!c.candidates.contains(&TechniqueKind::Tap));
        assert!(c.candidates.contains(&TechniqueKind::Ss));
        assert!(c.candidates.contains(&TechniqueKind::Gss));
    }

    /// The CAS-path probe variant: runs on µ̂ alone (exec-only
    /// observations — no gaps, so the measured-overhead probe stays
    /// silent), charges zero per-grant overhead, and therefore never flees
    /// a fine-grained technique for overhead reasons — only tail imbalance
    /// can drive a switch.
    #[test]
    fn fast_path_probe_runs_on_exec_observations_alone() {
        let mut c = ctl(TechniqueKind::Static, "static,ss,tap");
        // Jittered per-iteration rates: σ̂ > 0 primes the imbalance term.
        for (i, rate) in [1e-5, 3e-5, 1e-5, 4e-5, 2e-5, 3e-5].iter().enumerate() {
            c.observe_exec(32, 32.0 * rate * ((i % 2) as f64 + 1.0));
        }
        assert_eq!(c.probe(8_192), None, "no gap samples ⇒ the measured probe waits");
        // STATIC's huge tail chunk loses to a small-tail candidate even at
        // zero overhead.
        let (to, _) = c.probe_on_fast_path(8_192).expect("tail imbalance drives the switch");
        assert_ne!(to, TechniqueKind::Static);
        // From SS (tail = 1), zero overhead gives nothing to improve.
        let mut c = ctl(TechniqueKind::Ss, "static,ss,tap");
        for _ in 0..4 {
            c.observe_exec(32, 32.0 * 1e-5);
        }
        assert_eq!(c.probe_on_fast_path(8_192), None, "SS is tail-optimal at ô = 0");
    }

    #[test]
    fn tick_grant_fires_every_interval() {
        let mut c = AdaptiveController::new(
            TechniqueKind::Ss,
            &LoopParams::new(1_000, 8),
            4,
            params(3, "ss,gss"),
            false,
        );
        let fired: Vec<bool> = (0..7).map(|_| c.tick_grant()).collect();
        assert_eq!(fired, vec![false, false, true, false, false, true, false]);
    }

    #[test]
    fn bucketing_keeps_the_memo_small_and_deterministic() {
        assert_eq!(bucket_len(1), 1);
        assert_eq!(bucket_len(0), 1);
        assert_eq!(bucket_len(1023), 512);
        assert_eq!(bucket_len(1024), 1024);
        assert_eq!(bucket_len(1025), 1024);
        let mut c = ctl(TechniqueKind::Ss, "ss,gss");
        prime(&mut c, 1e-5, 1e-4);
        for len in [4_000u64, 4_001, 4_095] {
            c.probe(len);
        }
        // All three lengths share one bucket per kind (+ the Ss current).
        assert!(c.memo.len() <= 4, "memo holds {} entries", c.memo.len());
    }

    #[test]
    fn schedule_stats_match_the_chunk_table() {
        let base = LoopParams::new(100_000, 64);
        let (c, k_tail) =
            schedule_stats(TechniqueKind::Ss, &base, 4, 500).expect("SS fits the cap");
        assert_eq!((c, k_tail), (500, 1));
        assert!(schedule_stats(TechniqueKind::Af, &base, 4, 500).is_none());
        // Over-cap schedules are unviable rather than materialized.
        assert!(schedule_stats(TechniqueKind::Ss, &base, 4, PROBE_STEP_CAP + 1).is_none());
    }

    /// Determinism: identical observation sequences produce identical
    /// probe decisions.
    #[test]
    fn probe_is_deterministic() {
        let run = || {
            let mut c = ctl(TechniqueKind::Ss, "ss,gss,fac,tss");
            prime(&mut c, 2e-5, 5e-5);
            c.probe(10_000)
        };
        assert_eq!(run(), run());
    }
}
