//! The central work queue — the **chunk assignment** half of every
//! self-scheduling step (§3).
//!
//! The paper's key observation: of the two per-step operations, only the
//! assignment (advancing `(i, lp_start)`) needs exclusive access; the chunk
//! *calculation* can run anywhere. [`WorkQueue`] is that shared state. The
//! CCA master owns one privately; the DCA coordinator exposes it through the
//! two-phase [`WorkQueue::begin_step`]/[`WorkQueue::commit`] protocol; the
//! RMA variant mirrors it with atomics in [`crate::substrate::rma`].

pub mod adaptive;

use crate::techniques::{LoopParams, Technique};


/// One scheduled chunk: `size` loop iterations starting at `start`,
/// calculated at scheduling step `step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    /// Scheduling-step index `i`.
    pub step: u64,
    /// First loop iteration of the chunk (`lp_start`).
    pub start: u64,
    /// Number of iterations (already clipped to the remaining work).
    pub size: u64,
}

impl Assignment {
    /// Exclusive end of the chunk's iteration range.
    pub fn end(&self) -> u64 {
        self.start + self.size
    }
}

/// Central scheduling state `(i, lp_start)` over a loop of `n` iterations.
#[derive(Debug, Clone)]
pub struct WorkQueue {
    n: u64,
    next_start: u64,
    next_step: u64,
    min_chunk: u64,
}

impl WorkQueue {
    pub fn new(n: u64, min_chunk: u64) -> Self {
        WorkQueue { n, next_start: 0, next_step: 0, min_chunk: min_chunk.max(1) }
    }

    pub fn from_params(params: &LoopParams) -> Self {
        Self::new(params.n, params.min_chunk)
    }

    /// Remaining unscheduled iterations `R_i`.
    pub fn remaining(&self) -> u64 {
        self.n - self.next_start
    }

    /// Scheduling step index `i` of the next assignment.
    pub fn step(&self) -> u64 {
        self.next_step
    }

    /// `lp_start` — the first unscheduled iteration.
    pub fn lp_start(&self) -> u64 {
        self.next_start
    }

    /// True when every iteration has been assigned.
    pub fn is_done(&self) -> bool {
        self.next_start >= self.n
    }

    /// Clip a requested (unclipped) size to `[min_chunk, remaining]`.
    pub fn clip(&self, unclipped: u64) -> u64 {
        unclipped.max(self.min_chunk).min(self.remaining())
    }

    /// **One-shot assignment** (CCA master path): clip `unclipped`, advance
    /// the queue, return the chunk. `None` once the loop is exhausted.
    pub fn assign(&mut self, unclipped: u64) -> Option<Assignment> {
        if self.is_done() {
            return None;
        }
        let size = self.clip(unclipped);
        let a = Assignment { step: self.next_step, start: self.next_start, size };
        self.next_start += size;
        self.next_step += 1;
        Some(a)
    }

    /// **Phase 1 of the DCA two-sided protocol**: hand out the next step
    /// index (and the current `R_i`, needed by AF/PLS) without assigning
    /// iterations yet. The caller computes the chunk size remotely and comes
    /// back through [`WorkQueue::commit`].
    ///
    /// Steps are *reserved* — two concurrent workers get distinct `i`.
    pub fn begin_step(&mut self) -> Option<StepTicket> {
        if self.is_done() {
            return None;
        }
        let t = StepTicket { step: self.next_step, remaining: self.remaining() };
        self.next_step += 1;
        Some(t)
    }

    /// Forcibly retire every unassigned iteration (tenant eviction /
    /// session drain): the queue reports done from here on, outstanding
    /// [`WorkQueue::begin_step`] tickets fail their commit, and the granted
    /// prefix `[0, lp_start)` stays exactly as scheduled. Returns the
    /// number of iterations dropped.
    pub fn drain_remaining(&mut self) -> u64 {
        let dropped = self.remaining();
        self.next_start = self.n;
        dropped
    }

    /// **Phase 2 of the DCA protocol**: commit a worker-calculated size for a
    /// previously reserved step. Iteration ranges are granted in commit
    /// order (disjointness is what matters — DLS assumes independent
    /// iterations, §1). Returns `None` if the loop filled up in between.
    pub fn commit(&mut self, ticket: StepTicket, unclipped: u64) -> Option<Assignment> {
        if self.is_done() {
            return None;
        }
        let size = self.clip(unclipped);
        let a = Assignment { step: ticket.step, start: self.next_start, size };
        self.next_start += size;
        Some(a)
    }
}

/// A reserved scheduling step handed to a DCA worker (phase 1 reply).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepTicket {
    /// The reserved step index `i`.
    pub step: u64,
    /// `R_i` snapshot at reservation time (consumed by AF and recursive PLS).
    pub remaining: u64,
}

/// Generate the full schedule of a technique using the **closed (DCA)** form.
/// This is what Table 2 / Fig. 1 report.
pub fn closed_form_schedule(tech: &Technique, params: &LoopParams) -> Vec<Assignment> {
    let mut q = WorkQueue::from_params(params);
    let mut out = Vec::new();
    while let Some(t) = q.begin_step() {
        let k = tech.closed_chunk(t.step);
        if let Some(a) = q.commit(t, k) {
            out.push(a);
        }
    }
    out
}

/// Generate the full schedule using the **recursive (CCA)** form.
pub fn recursive_schedule(tech: &Technique, params: &LoopParams) -> Vec<Assignment> {
    let mut q = WorkQueue::from_params(params);
    let mut st = tech.fresh_recursive();
    let mut out = Vec::new();
    while !q.is_done() {
        let k = tech.recursive_chunk(&mut st, q.remaining());
        match q.assign(k) {
            Some(a) => out.push(a),
            None => break,
        }
    }
    out
}

/// Verify a schedule covers `[0, n)` exactly once, in order, with no overlap
/// and no gap. Returns a description of the first violation.
pub fn verify_coverage(schedule: &[Assignment], n: u64) -> Result<(), String> {
    let mut cursor = 0u64;
    for (idx, a) in schedule.iter().enumerate() {
        if a.start != cursor {
            return Err(format!(
                "chunk {idx}: starts at {} but previous coverage ended at {cursor}",
                a.start
            ));
        }
        if a.size == 0 {
            return Err(format!("chunk {idx}: zero-sized"));
        }
        cursor = a.end();
        if cursor > n {
            return Err(format!("chunk {idx}: overruns N={n} (end={cursor})"));
        }
    }
    if cursor != n {
        return Err(format!("coverage ends at {cursor}, expected N={n}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::techniques::{TechniqueKind, TechniqueKind::*};

    #[test]
    fn assign_clips_last_chunk() {
        let mut q = WorkQueue::new(10, 1);
        assert_eq!(q.assign(7).unwrap().size, 7);
        let last = q.assign(7).unwrap();
        assert_eq!(last.size, 3);
        assert!(q.assign(1).is_none());
    }

    #[test]
    fn min_chunk_enforced() {
        let mut q = WorkQueue::new(10, 3);
        assert_eq!(q.assign(1).unwrap().size, 3);
    }

    #[test]
    fn two_phase_matches_one_shot_sizes() {
        let mut a = WorkQueue::new(100, 1);
        let mut b = WorkQueue::new(100, 1);
        for req in [10u64, 20, 5, 40, 50] {
            let one = a.assign(req);
            let t = b.begin_step().map(|t| b.commit(t, req)).flatten();
            assert_eq!(one.map(|x| (x.start, x.size)), t.map(|x| (x.start, x.size)));
        }
    }

    #[test]
    fn tickets_reserve_distinct_steps() {
        let mut q = WorkQueue::new(100, 1);
        let t1 = q.begin_step().unwrap();
        let t2 = q.begin_step().unwrap();
        assert_ne!(t1.step, t2.step);
        // Commit out of order — ranges stay disjoint and contiguous.
        let a2 = q.commit(t2, 30).unwrap();
        let a1 = q.commit(t1, 30).unwrap();
        assert_eq!(a2.start, 0);
        assert_eq!(a1.start, 30);
    }

    #[test]
    fn drain_kills_outstanding_tickets_but_keeps_granted_prefix() {
        let mut q = WorkQueue::new(100, 1);
        let t1 = q.begin_step().unwrap();
        let a1 = q.commit(t1, 30).unwrap();
        let t2 = q.begin_step().unwrap();
        assert_eq!(q.drain_remaining(), 70);
        assert!(q.is_done());
        assert!(q.commit(t2, 30).is_none());
        assert!(q.begin_step().is_none());
        assert_eq!(q.drain_remaining(), 0);
        verify_coverage(&[a1], 30).unwrap();
    }

    #[test]
    fn all_closed_schedules_cover_exactly() {
        let params = crate::techniques::LoopParams::new(1000, 4);
        for kind in TechniqueKind::ALL {
            if !kind.has_closed_form() {
                continue;
            }
            let t = Technique::new(kind, &params);
            let s = closed_form_schedule(&t, &params);
            verify_coverage(&s, params.n).unwrap_or_else(|e| panic!("{kind}: {e}"));
        }
    }

    #[test]
    fn all_recursive_schedules_cover_exactly() {
        let params = crate::techniques::LoopParams::new(1000, 4);
        for kind in [Static, Ss, Fsc, Gss, Tap, Tss, Fac2, Tfss, Fiss, Viss, Rnd, Pls] {
            let t = Technique::new(kind, &params);
            let s = recursive_schedule(&t, &params);
            verify_coverage(&s, params.n).unwrap_or_else(|e| panic!("{kind}: {e}"));
        }
    }

    #[test]
    fn verify_coverage_catches_violations() {
        let gap = vec![
            Assignment { step: 0, start: 0, size: 5 },
            Assignment { step: 1, start: 6, size: 4 },
        ];
        assert!(verify_coverage(&gap, 10).is_err());
        let overrun = vec![Assignment { step: 0, start: 0, size: 11 }];
        assert!(verify_coverage(&overrun, 10).is_err());
        let short = vec![Assignment { step: 0, start: 0, size: 9 }];
        assert!(verify_coverage(&short, 10).is_err());
        let ok = vec![
            Assignment { step: 0, start: 0, size: 5 },
            Assignment { step: 1, start: 5, size: 5 },
        ];
        assert!(verify_coverage(&ok, 10).is_ok());
    }
}
