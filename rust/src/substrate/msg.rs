//! Two-sided message-passing fabric: MPI_Send/MPI_Recv semantics between
//! `P` ranks inside one process. Each rank owns an [`Endpoint`]; sends are
//! non-blocking (buffered, like eager-protocol MPI), receives block.
//!
//! All existing MPI runtimes fully support two-sided communication — that is
//! exactly why the paper re-implements DCA on top of it (§1 contribution 1).
//! This fabric is the substrate both the CCA master–worker and the DCA
//! coordinator models run on in the real threaded engine.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// A routed message.
#[derive(Debug)]
pub struct Envelope<T> {
    pub src: u32,
    pub payload: T,
}

/// One rank's endpoint into the fabric.
pub struct Endpoint<T> {
    rank: u32,
    rx: Receiver<Envelope<T>>,
    txs: Arc<Vec<Sender<Envelope<T>>>>,
    sent: Arc<AtomicU64>,
}

/// Errors surfaced by the fabric.
#[derive(Debug, PartialEq, Eq)]
pub enum CommError {
    /// Destination endpoint dropped (rank finished/terminated).
    Disconnected,
    /// No message arrived within the timeout.
    Timeout,
    /// Destination rank out of range.
    NoSuchRank(u32),
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Disconnected => write!(f, "peer disconnected"),
            CommError::Timeout => write!(f, "receive timed out"),
            CommError::NoSuchRank(r) => write!(f, "no such rank: {r}"),
        }
    }
}

impl std::error::Error for CommError {}

/// Build a fully connected fabric of `p` endpoints (ranks `0..p`).
/// Returns one endpoint per rank plus a shared message counter.
pub fn fabric<T: Send>(p: u32) -> (Vec<Endpoint<T>>, Arc<AtomicU64>) {
    let mut txs = Vec::with_capacity(p as usize);
    let mut rxs = Vec::with_capacity(p as usize);
    for _ in 0..p {
        let (tx, rx) = mpsc::channel();
        txs.push(tx);
        rxs.push(rx);
    }
    let txs = Arc::new(txs);
    let sent = Arc::new(AtomicU64::new(0));
    let eps = rxs
        .into_iter()
        .enumerate()
        .map(|(rank, rx)| Endpoint {
            rank: rank as u32,
            rx,
            txs: Arc::clone(&txs),
            sent: Arc::clone(&sent),
        })
        .collect();
    (eps, sent)
}

impl<T: Send> Endpoint<T> {
    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// Non-blocking buffered send to `dst` (eager MPI_Send).
    pub fn send(&self, dst: u32, payload: T) -> Result<(), CommError> {
        let tx = self.txs.get(dst as usize).ok_or(CommError::NoSuchRank(dst))?;
        tx.send(Envelope { src: self.rank, payload }).map_err(|_| CommError::Disconnected)?;
        self.sent.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Blocking receive (MPI_Recv with MPI_ANY_SOURCE).
    pub fn recv(&self) -> Result<Envelope<T>, CommError> {
        self.rx.recv().map_err(|_| CommError::Disconnected)
    }

    /// Receive with a timeout — used by service loops to detect quiescence.
    pub fn recv_timeout(&self, d: Duration) -> Result<Envelope<T>, CommError> {
        self.rx.recv_timeout(d).map_err(|e| match e {
            RecvTimeoutError::Timeout => CommError::Timeout,
            RecvTimeoutError::Disconnected => CommError::Disconnected,
        })
    }

    /// Non-blocking receive (MPI_Iprobe + MPI_Recv).
    pub fn try_recv(&self) -> Option<Envelope<T>> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn ping_pong() {
        let (mut eps, sent) = fabric::<u64>(2);
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            let m = b.recv().unwrap();
            assert_eq!(m.src, 0);
            b.send(0, m.payload + 1).unwrap();
        });
        a.send(1, 41).unwrap();
        let r = a.recv().unwrap();
        assert_eq!(r.payload, 42);
        assert_eq!(r.src, 1);
        h.join().unwrap();
        assert_eq!(sent.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn many_to_one_any_source() {
        let (mut eps, _) = fabric::<u32>(5);
        let master = eps.remove(0);
        let workers: Vec<_> = eps.drain(..).collect();
        let hs: Vec<_> = workers
            .into_iter()
            .map(|w| {
                thread::spawn(move || {
                    w.send(0, w.rank()).unwrap();
                })
            })
            .collect();
        let mut got = vec![];
        for _ in 0..4 {
            got.push(master.recv().unwrap().payload);
        }
        got.sort();
        assert_eq!(got, vec![1, 2, 3, 4]);
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn send_to_missing_rank_errors() {
        let (eps, _) = fabric::<u8>(1);
        assert_eq!(eps[0].send(9, 0).unwrap_err(), CommError::NoSuchRank(9));
    }

    #[test]
    fn timeout_on_empty() {
        let (eps, _) = fabric::<u8>(1);
        assert_eq!(
            eps[0].recv_timeout(Duration::from_millis(5)).unwrap_err(),
            CommError::Timeout
        );
    }

    #[test]
    fn try_recv_nonblocking() {
        let (mut eps, _) = fabric::<u8>(2);
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        assert!(a.try_recv().is_none());
        b.send(0, 7).unwrap();
        // Give the channel a moment (same process, should be immediate).
        let m = a.recv().unwrap();
        assert_eq!(m.payload, 7);
    }

    #[test]
    fn ordering_preserved_pairwise() {
        let (mut eps, _) = fabric::<u32>(2);
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        for i in 0..100 {
            a.send(1, i).unwrap();
        }
        for i in 0..100 {
            assert_eq!(b.recv().unwrap().payload, i);
        }
    }
}
