//! Simulated distributed-memory substrates — the stand-ins for MPI on the
//! paper's miniHPC cluster (DESIGN.md §Substitutions):
//!
//! * [`msg`] — **two-sided** point-to-point messaging (MPI_Send/Recv
//!   semantics) over in-process channels; what this paper's new DCA
//!   implementation and all CCA libraries (LB tool, LB4MPI, DSS) use.
//! * [`rma`] — **one-sided** passive-target window with atomic fetch-ops
//!   (MPI-3.1 RMA semantics); what the PDP'19 DCA used.
//! * [`topology`] — rank→node placement and latency classes.
//! * [`delay`] — the injected CPU-slowdown of §6's scenarios.

pub mod delay;
pub mod msg;
pub mod rma;
pub mod topology;
