//! The injected CPU slowdown of §6: a constant delay added to the chunk
//! calculation (or, for the §7 future-work ablation, the assignment).
//!
//! In the **real** threaded engine the delay must actually burn CPU — the
//! paper injects it as computation inside the chunk-calculation function, so
//! a 10 µs delay on the CCA master really does serialize behind the request
//! queue. `thread::sleep` is too coarse (and yields the core), so we spin.
//! In the **DES** the delay is just a number added to virtual time.

use std::time::{Duration, Instant};

/// Busy-wait for `seconds` of wall-clock time (0 returns immediately).
///
/// Spinning (not sleeping) matches the paper's mechanism: the injected delay
/// occupies the PE, so on a non-dedicated master it also steals time from
/// the master's own iteration execution.
#[inline]
pub fn spin_for(seconds: f64) {
    if seconds <= 0.0 {
        return;
    }
    let dur = Duration::from_secs_f64(seconds);
    let start = Instant::now();
    while start.elapsed() < dur {
        std::hint::spin_loop();
    }
}

/// A delay site's configuration for one run.
#[derive(Debug, Clone, Copy, Default)]
pub struct InjectedDelay {
    /// Seconds added to every chunk **calculation**.
    pub calculation: f64,
    /// Seconds added to every chunk **assignment** (§7 ablation).
    pub assignment: f64,
}

impl InjectedDelay {
    /// The paper's §6 setup: delay only the calculation.
    pub fn calculation_only(seconds: f64) -> Self {
        InjectedDelay { calculation: seconds, assignment: 0.0 }
    }

    /// The §7 future-work ablation: delay only the assignment.
    pub fn assignment_only(seconds: f64) -> Self {
        InjectedDelay { calculation: 0.0, assignment: seconds }
    }

    pub fn none() -> Self {
        Self::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spin_zero_is_instant() {
        let t = Instant::now();
        spin_for(0.0);
        assert!(t.elapsed() < Duration::from_millis(1));
    }

    #[test]
    fn spin_waits_roughly_right() {
        let t = Instant::now();
        spin_for(2e-3);
        let e = t.elapsed();
        assert!(e >= Duration::from_micros(1900), "elapsed {e:?}");
        assert!(e < Duration::from_millis(50), "elapsed {e:?}");
    }

    #[test]
    fn sites() {
        let c = InjectedDelay::calculation_only(1e-5);
        assert_eq!(c.calculation, 1e-5);
        assert_eq!(c.assignment, 0.0);
        let a = InjectedDelay::assignment_only(1e-4);
        assert_eq!(a.calculation, 0.0);
        assert_eq!(a.assignment, 1e-4);
    }
}
