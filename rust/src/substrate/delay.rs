//! The injected CPU slowdown of §6: a constant delay added to the chunk
//! calculation (or, for the §7 future-work ablation, the assignment).
//!
//! In the **real** threaded engine the delay must actually burn CPU — the
//! paper injects it as computation inside the chunk-calculation function, so
//! a 10 µs delay on the CCA master really does serialize behind the request
//! queue. `thread::sleep` is too coarse (and yields the core), so we spin.
//! In the **DES** the delay is just a number added to virtual time.

use std::time::{Duration, Instant};

/// Busy-wait for `seconds` of wall-clock time (0 returns immediately).
///
/// Spinning (not sleeping) matches the paper's mechanism: the injected delay
/// occupies the PE, so on a non-dedicated master it also steals time from
/// the master's own iteration execution.
#[inline]
pub fn spin_for(seconds: f64) {
    if seconds <= 0.0 {
        return;
    }
    let dur = Duration::from_secs_f64(seconds);
    let start = Instant::now();
    while start.elapsed() < dur {
        std::hint::spin_loop();
    }
}

/// Distribution of the injected **calculation** delay across invocations.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum DelayDist {
    /// Every invocation pays exactly `calculation` seconds (the paper's §6
    /// scenarios).
    #[default]
    Constant,
    /// Exponentially distributed with mean `calculation` — bursty
    /// perturbation; deterministic per `(seed, rank, virtual time)` so DES
    /// runs stay replayable.
    Exponential,
}

/// A delay site's configuration for one run.
#[derive(Debug, Clone, Copy, Default)]
pub struct InjectedDelay {
    /// Seconds added to every chunk **calculation** (the mean, when
    /// `dist` is [`DelayDist::Exponential`]).
    pub calculation: f64,
    /// Seconds added to every chunk **assignment** (§7 ablation).
    pub assignment: f64,
    /// Distribution of the calculation delay.
    pub dist: DelayDist,
    /// Seed for the exponential draws.
    pub seed: u64,
}

impl InjectedDelay {
    /// The paper's §6 setup: delay only the calculation.
    pub fn calculation_only(seconds: f64) -> Self {
        InjectedDelay { calculation: seconds, ..Self::default() }
    }

    /// The §7 future-work ablation: delay only the assignment.
    pub fn assignment_only(seconds: f64) -> Self {
        InjectedDelay { assignment: seconds, ..Self::default() }
    }

    /// Exponentially distributed calculation delay with the given mean.
    pub fn exponential_calculation(mean_seconds: f64, seed: u64) -> Self {
        InjectedDelay {
            calculation: mean_seconds,
            dist: DelayDist::Exponential,
            seed,
            ..Self::default()
        }
    }

    pub fn none() -> Self {
        Self::default()
    }

    /// The calculation delay paid by `rank` for a calculation starting at
    /// virtual time `t_ns`. Constant mode ignores the arguments; exponential
    /// mode draws deterministically from `(seed, rank, t_ns)`, so a replay
    /// of the same simulation sees identical delays.
    pub fn calculation_at(&self, rank: u32, t_ns: u64) -> f64 {
        match self.dist {
            DelayDist::Constant => self.calculation,
            DelayDist::Exponential => {
                if self.calculation <= 0.0 {
                    return 0.0;
                }
                let bits = crate::techniques::rnd::splitmix64(
                    self.seed ^ ((rank as u64) << 32) ^ t_ns.wrapping_mul(0x9e37_79b9_7f4a_7c15),
                );
                // u ∈ [0, 1); inverse-CDF draw, guarded against ln(0).
                let u = (bits >> 11) as f64 / (1u64 << 53) as f64;
                -self.calculation * (1.0 - u).max(1e-18).ln()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spin_zero_is_instant() {
        let t = Instant::now();
        spin_for(0.0);
        assert!(t.elapsed() < Duration::from_millis(1));
    }

    #[test]
    fn spin_waits_roughly_right() {
        let t = Instant::now();
        spin_for(2e-3);
        let e = t.elapsed();
        assert!(e >= Duration::from_micros(1900), "elapsed {e:?}");
        assert!(e < Duration::from_millis(50), "elapsed {e:?}");
    }

    #[test]
    fn sites() {
        let c = InjectedDelay::calculation_only(1e-5);
        assert_eq!(c.calculation, 1e-5);
        assert_eq!(c.assignment, 0.0);
        assert_eq!(c.dist, DelayDist::Constant);
        let a = InjectedDelay::assignment_only(1e-4);
        assert_eq!(a.calculation, 0.0);
        assert_eq!(a.assignment, 1e-4);
    }

    #[test]
    fn constant_ignores_rank_and_time() {
        let d = InjectedDelay::calculation_only(2e-5);
        assert_eq!(d.calculation_at(0, 0), 2e-5);
        assert_eq!(d.calculation_at(7, 123_456), 2e-5);
    }

    #[test]
    fn exponential_is_deterministic_and_varies() {
        let d = InjectedDelay::exponential_calculation(1e-4, 42);
        let a = d.calculation_at(3, 1_000);
        let b = d.calculation_at(3, 1_000);
        assert_eq!(a, b, "same (rank, t) must replay identically");
        let c = d.calculation_at(4, 1_000);
        assert_ne!(a, c, "draws differ across ranks");
        assert!(a >= 0.0 && c >= 0.0);
    }

    #[test]
    fn exponential_mean_approximately_right() {
        let mean = 1e-4;
        let d = InjectedDelay::exponential_calculation(mean, 7);
        let n = 20_000u64;
        let sum: f64 = (0..n).map(|i| d.calculation_at((i % 16) as u32, i * 977)).sum();
        let got = sum / n as f64;
        assert!(
            (got - mean).abs() < 0.05 * mean,
            "sample mean {got} should be within 5% of {mean}"
        );
    }

    #[test]
    fn exponential_zero_mean_is_zero() {
        let d = InjectedDelay::exponential_calculation(0.0, 1);
        assert_eq!(d.calculation_at(0, 99), 0.0);
    }
}
