//! Cluster topology: rank→node→rack placement and message latency classes,
//! mirroring miniHPC's 16 dual-socket nodes × 16 ranks — extended with an
//! optional rack tier so the latency *triple* (intra-node, inter-node,
//! inter-rack) needed by three-level scheduling trees has a physical home.

use crate::config::ClusterConfig;

/// Rank→node→rack placement with per-pair latency lookup.
#[derive(Debug, Clone)]
pub struct Topology {
    ranks_per_node: u32,
    /// Nodes per rack (`total nodes` when the cluster has a single rack,
    /// i.e. `racks` doesn't evenly divide the node count).
    nodes_per_rack: u32,
    total_ranks: u32,
    intra: f64,
    inter: f64,
    inter_rack: f64,
}

impl Topology {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let nodes = cfg.nodes.max(1);
        let racks = if cfg.racks >= 1 && nodes % cfg.racks.max(1) == 0 {
            cfg.racks.max(1)
        } else {
            1
        };
        Topology {
            ranks_per_node: cfg.ranks_per_node.max(1),
            nodes_per_rack: nodes / racks,
            total_ranks: cfg.total_ranks().max(1),
            intra: cfg.intra_node_latency,
            inter: cfg.inter_node_latency,
            inter_rack: cfg.inter_rack_latency,
        }
    }

    pub fn total_ranks(&self) -> u32 {
        self.total_ranks
    }

    /// Ranks per node (clamped to ≥ 1 at construction).
    pub fn ranks_per_node(&self) -> u32 {
        self.ranks_per_node
    }

    /// Number of physical nodes implied by the placement (⌈ranks/rpn⌉).
    pub fn nodes(&self) -> u32 {
        self.total_ranks.div_ceil(self.ranks_per_node)
    }

    /// Physical node hosting `rank` (block placement, like `mpirun -bynode`
    /// off — consecutive ranks fill a node first, the paper's 16-per-node).
    pub fn node_of(&self, rank: u32) -> u32 {
        rank / self.ranks_per_node
    }

    /// The rank acting as node master for `node` under the two-level
    /// hierarchical model: the first rank placed on that node.
    pub fn master_of_node(&self, node: u32) -> u32 {
        node * self.ranks_per_node
    }

    /// The node master responsible for `rank` (may be `rank` itself).
    pub fn master_of(&self, rank: u32) -> u32 {
        self.master_of_node(self.node_of(rank))
    }

    /// Number of racks implied by the placement.
    pub fn racks(&self) -> u32 {
        self.nodes().div_ceil(self.nodes_per_rack)
    }

    /// Rack hosting `node` (blocks of consecutive nodes).
    pub fn rack_of_node(&self, node: u32) -> u32 {
        node / self.nodes_per_rack
    }

    /// Rack hosting `rank`.
    pub fn rack_of(&self, rank: u32) -> u32 {
        self.rack_of_node(self.node_of(rank))
    }

    /// One-way message latency between two ranks, seconds: 0 to self,
    /// intra-node within a node, inter-node within a rack, inter-rack
    /// otherwise (the third class is unreachable on single-rack clusters).
    pub fn latency(&self, a: u32, b: u32) -> f64 {
        if a == b {
            0.0
        } else if self.node_of(a) == self.node_of(b) {
            self.intra
        } else if self.rack_of(a) == self.rack_of(b) {
            self.inter
        } else {
            self.inter_rack
        }
    }

    /// Mean one-way latency from `rank` to every *other* rank — useful for
    /// summarizing where a master/coordinator should live.
    pub fn mean_latency_from(&self, rank: u32) -> f64 {
        let others = (self.total_ranks - 1).max(1) as f64;
        (0..self.total_ranks)
            .filter(|&r| r != rank)
            .map(|r| self.latency(rank, r))
            .sum::<f64>()
            / others
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn minihpc() -> Topology {
        Topology::new(&ClusterConfig::minihpc())
    }

    #[test]
    fn block_placement() {
        let t = minihpc();
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(15), 0);
        assert_eq!(t.node_of(16), 1);
        assert_eq!(t.node_of(255), 15);
    }

    #[test]
    fn latency_classes() {
        let t = minihpc();
        assert_eq!(t.latency(3, 3), 0.0);
        assert_eq!(t.latency(0, 5), 0.5e-6); // same node
        assert_eq!(t.latency(0, 20), 2.0e-6); // cross node
        assert_eq!(t.latency(20, 0), t.latency(0, 20));
    }

    #[test]
    fn mean_latency_dominated_by_inter_node() {
        let t = minihpc();
        let m = t.mean_latency_from(0);
        // 15 intra-node peers, 240 inter-node peers.
        let expect = (15.0 * 0.5e-6 + 240.0 * 2.0e-6) / 255.0;
        assert!((m - expect).abs() < 1e-12);
    }

    #[test]
    fn single_node_all_intra() {
        let t = Topology::new(&ClusterConfig::small(8));
        for r in 1..8 {
            assert_eq!(t.latency(0, r), 0.5e-6);
        }
    }

    #[test]
    fn node_of_covers_every_rank_in_blocks() {
        let t = minihpc();
        for rank in 0..t.total_ranks() {
            assert_eq!(t.node_of(rank), rank / 16, "rank {rank}");
        }
        assert_eq!(t.nodes(), 16);
        assert_eq!(t.ranks_per_node(), 16);
    }

    #[test]
    fn masters_are_first_rank_per_node() {
        let t = minihpc();
        for node in 0..t.nodes() {
            let m = t.master_of_node(node);
            assert_eq!(m % 16, 0);
            assert_eq!(t.node_of(m), node);
        }
        assert_eq!(t.master_of(0), 0);
        assert_eq!(t.master_of(15), 0);
        assert_eq!(t.master_of(16), 16);
        assert_eq!(t.master_of(255), 240);
        // A master is always intra-node to every rank it serves.
        for rank in 0..t.total_ranks() {
            let m = t.master_of(rank);
            let lat = t.latency(rank, m);
            assert!(lat <= 0.5e-6, "rank {rank} → master {m} must be intra-node");
        }
    }

    #[test]
    fn intra_vs_inter_selection_boundaries() {
        let t = minihpc();
        // Last rank of node 0 vs first rank of node 1: adjacent ranks,
        // different nodes ⇒ inter-node latency.
        assert_eq!(t.latency(15, 16), 2.0e-6);
        // First and last rank of the same node ⇒ intra-node latency.
        assert_eq!(t.latency(16, 31), 0.5e-6);
    }

    #[test]
    fn zero_ranks_per_node_clamps_to_one() {
        // A degenerate config must not divide by zero: rpn clamps to 1, so
        // every rank lands on its own node and all traffic is inter-node.
        let cfg = ClusterConfig { nodes: 4, ranks_per_node: 0, ..ClusterConfig::minihpc() };
        let t = Topology::new(&cfg);
        assert_eq!(t.ranks_per_node(), 1);
        assert_eq!(t.node_of(3), 3);
        assert_eq!(t.latency(0, 1), 2.0e-6);
        assert_eq!(t.latency(2, 2), 0.0);
    }

    #[test]
    fn rack_tier_latency_triple() {
        // 16 nodes in 4 racks of 4: same node → intra, same rack → inter,
        // across racks → the third class.
        let cfg = ClusterConfig { racks: 4, ..ClusterConfig::minihpc() };
        let t = Topology::new(&cfg);
        assert_eq!(t.racks(), 4);
        assert_eq!(t.rack_of(0), 0);
        assert_eq!(t.rack_of(63), 0); // node 3, last rank of rack 0
        assert_eq!(t.rack_of(64), 1); // node 4, first rank of rack 1
        assert_eq!(t.rack_of(255), 3);
        assert_eq!(t.latency(0, 5), 0.5e-6); // same node
        assert_eq!(t.latency(0, 20), 2.0e-6); // same rack, different node
        assert_eq!(t.latency(0, 64), 6.0e-6); // different rack
        assert_eq!(t.latency(64, 0), t.latency(0, 64));
        assert_eq!(t.latency(64, 64), 0.0);
    }

    #[test]
    fn single_rack_never_pays_the_rack_class() {
        let t = minihpc(); // racks = 1
        assert_eq!(t.racks(), 1);
        for a in [0u32, 15, 16, 255] {
            for b in [0u32, 15, 16, 255] {
                assert!(t.latency(a, b) <= 2.0e-6, "({a},{b})");
            }
        }
    }

    #[test]
    fn non_dividing_racks_collapse_to_one() {
        // 16 nodes cannot split into 3 racks — the tier is ignored.
        let cfg = ClusterConfig { racks: 3, ..ClusterConfig::minihpc() };
        let t = Topology::new(&cfg);
        assert_eq!(t.racks(), 1);
        assert_eq!(t.latency(0, 255), 2.0e-6);
    }

    #[test]
    fn one_rank_per_node_is_all_inter() {
        let cfg = ClusterConfig { nodes: 8, ranks_per_node: 1, ..ClusterConfig::minihpc() };
        let t = Topology::new(&cfg);
        assert_eq!(t.total_ranks(), 8);
        assert_eq!(t.nodes(), 8);
        for a in 0..8u32 {
            assert_eq!(t.master_of(a), a, "every rank is its own master");
            for b in 0..8u32 {
                let expect = if a == b { 0.0 } else { 2.0e-6 };
                assert_eq!(t.latency(a, b), expect);
            }
        }
    }
}
