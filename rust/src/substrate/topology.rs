//! Cluster topology: rank→node placement and message latency classes,
//! mirroring miniHPC's 16 dual-socket nodes × 16 ranks.

use crate::config::ClusterConfig;

/// Rank→node placement with per-pair latency lookup.
#[derive(Debug, Clone)]
pub struct Topology {
    ranks_per_node: u32,
    total_ranks: u32,
    intra: f64,
    inter: f64,
}

impl Topology {
    pub fn new(cfg: &ClusterConfig) -> Self {
        Topology {
            ranks_per_node: cfg.ranks_per_node.max(1),
            total_ranks: cfg.total_ranks().max(1),
            intra: cfg.intra_node_latency,
            inter: cfg.inter_node_latency,
        }
    }

    pub fn total_ranks(&self) -> u32 {
        self.total_ranks
    }

    /// Physical node hosting `rank` (block placement, like `mpirun -bynode`
    /// off — consecutive ranks fill a node first, the paper's 16-per-node).
    pub fn node_of(&self, rank: u32) -> u32 {
        rank / self.ranks_per_node
    }

    /// One-way message latency between two ranks, seconds.
    pub fn latency(&self, a: u32, b: u32) -> f64 {
        if a == b {
            0.0
        } else if self.node_of(a) == self.node_of(b) {
            self.intra
        } else {
            self.inter
        }
    }

    /// Mean one-way latency from `rank` to every *other* rank — useful for
    /// summarizing where a master/coordinator should live.
    pub fn mean_latency_from(&self, rank: u32) -> f64 {
        let others = (self.total_ranks - 1).max(1) as f64;
        (0..self.total_ranks)
            .filter(|&r| r != rank)
            .map(|r| self.latency(rank, r))
            .sum::<f64>()
            / others
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn minihpc() -> Topology {
        Topology::new(&ClusterConfig::minihpc())
    }

    #[test]
    fn block_placement() {
        let t = minihpc();
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(15), 0);
        assert_eq!(t.node_of(16), 1);
        assert_eq!(t.node_of(255), 15);
    }

    #[test]
    fn latency_classes() {
        let t = minihpc();
        assert_eq!(t.latency(3, 3), 0.0);
        assert_eq!(t.latency(0, 5), 0.5e-6); // same node
        assert_eq!(t.latency(0, 20), 2.0e-6); // cross node
        assert_eq!(t.latency(20, 0), t.latency(0, 20));
    }

    #[test]
    fn mean_latency_dominated_by_inter_node() {
        let t = minihpc();
        let m = t.mean_latency_from(0);
        // 15 intra-node peers, 240 inter-node peers.
        let expect = (15.0 * 0.5e-6 + 240.0 * 2.0e-6) / 255.0;
        assert!((m - expect).abs() < 1e-12);
    }

    #[test]
    fn single_node_all_intra() {
        let t = Topology::new(&ClusterConfig::small(8));
        for r in 1..8 {
            assert_eq!(t.latency(0, r), 0.5e-6);
        }
    }
}
