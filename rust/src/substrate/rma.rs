//! One-sided RMA window: the MPI-3.1 passive-target substrate of the
//! original DCA (PDP'19, Fig. 3). The coordinator *hosts* the scheduling
//! state; workers access it directly with atomic fetch-ops — no coordinator
//! CPU involvement on the request path at all.
//!
//! The protocol (matching DESIGN.md §5):
//!
//! 1. `i ← fetch_add(step, 1)` — reserve a scheduling step;
//! 2. compute `K_i` **locally, lock-free** (the closed form makes this
//!    possible — no other PE's chunk is needed);
//! 3. `start ← fetch_add_clipped(lp_start, K_i)` — claim the iteration range.
//!
//! Because `K_i` depends only on `i`, the expensive part (2) runs fully in
//! parallel even under injected slowdowns; only two cheap atomics serialize.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sched::Assignment;

/// The shared window: `(i, lp_start)` plus loop bounds.
#[derive(Debug)]
pub struct RmaWindow {
    n: u64,
    min_chunk: u64,
    step: AtomicU64,
    lp_start: AtomicU64,
}

impl RmaWindow {
    pub fn new(n: u64, min_chunk: u64) -> Self {
        RmaWindow {
            n,
            min_chunk: min_chunk.max(1),
            step: AtomicU64::new(0),
            lp_start: AtomicU64::new(0),
        }
    }

    /// Total loop iterations `N`.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Phase 1: reserve the next scheduling step (exclusive fetch-add).
    /// Also returns the current `lp_start` snapshot so adaptive callers can
    /// estimate `R_i`. `None` once all iterations are claimed.
    pub fn reserve_step(&self) -> Option<(u64, u64)> {
        let lp = self.lp_start.load(Ordering::Acquire);
        if lp >= self.n {
            return None;
        }
        Some((self.step.fetch_add(1, Ordering::AcqRel), lp))
    }

    /// Phase 3: claim `unclipped` iterations. CAS loop implements the
    /// clipped fetch-add (`min_chunk ≤ size ≤ remaining`). `None` when the
    /// loop filled up between reserve and claim.
    pub fn claim(&self, step: u64, unclipped: u64) -> Option<Assignment> {
        let mut cur = self.lp_start.load(Ordering::Acquire);
        loop {
            if cur >= self.n {
                return None;
            }
            let size = unclipped.max(self.min_chunk).min(self.n - cur);
            match self.lp_start.compare_exchange_weak(
                cur,
                cur + size,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(Assignment { step, start: cur, size }),
                Err(now) => cur = now,
            }
        }
    }

    /// True when every iteration has been claimed.
    pub fn is_done(&self) -> bool {
        self.lp_start.load(Ordering::Acquire) >= self.n
    }

    /// Scheduling steps issued so far.
    pub fn steps_issued(&self) -> u64 {
        self.step.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::verify_coverage;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn sequential_reserve_claim() {
        let w = RmaWindow::new(100, 1);
        let (i, lp) = w.reserve_step().unwrap();
        assert_eq!((i, lp), (0, 0));
        let a = w.claim(i, 30).unwrap();
        assert_eq!((a.start, a.size), (0, 30));
        let (i2, lp2) = w.reserve_step().unwrap();
        assert_eq!((i2, lp2), (1, 30));
    }

    #[test]
    fn claim_clips_to_remaining() {
        let w = RmaWindow::new(10, 1);
        let (i, _) = w.reserve_step().unwrap();
        assert_eq!(w.claim(i, 100).unwrap().size, 10);
        assert!(w.is_done());
        assert!(w.reserve_step().is_none());
        assert!(w.claim(99, 1).is_none());
    }

    #[test]
    fn concurrent_claims_are_disjoint_and_complete() {
        let n = 100_000u64;
        let w = Arc::new(RmaWindow::new(n, 1));
        let mut handles = vec![];
        for t in 0..8u64 {
            let w = Arc::clone(&w);
            handles.push(thread::spawn(move || {
                let mut mine = vec![];
                while let Some((i, _)) = w.reserve_step() {
                    // Varying sizes to stress the CAS loop.
                    let k = 1 + (i * (t + 1)) % 97;
                    if let Some(a) = w.claim(i, k) {
                        mine.push(a);
                    }
                }
                mine
            }));
        }
        let mut all: Vec<Assignment> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_by_key(|a| a.start);
        verify_coverage(&all, n).unwrap();
        // Steps are unique.
        let mut steps: Vec<u64> = all.iter().map(|a| a.step).collect();
        steps.sort();
        steps.dedup();
        assert_eq!(steps.len(), all.len());
    }

    #[test]
    fn min_chunk_respected() {
        let w = RmaWindow::new(100, 5);
        let (i, _) = w.reserve_step().unwrap();
        assert_eq!(w.claim(i, 1).unwrap().size, 5);
    }
}
