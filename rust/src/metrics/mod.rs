//! Measurement utilities: running statistics, loop-execution summaries, and
//! the load-imbalance metrics the paper reports (Table 3, Figs. 4–5).



/// Streaming univariate statistics (Welford's algorithm).
#[derive(Debug, Clone)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Default for Stats {
    fn default() -> Self {
        Self::new()
    }
}

impl Stats {
    pub fn new() -> Self {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Stats::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    /// Coefficient of variation σ/µ (Table 3's load-imbalance indicator).
    pub fn cov(&self) -> f64 {
        if self.mean() == 0.0 {
            0.0
        } else {
            self.stddev() / self.mean()
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Merge another accumulator into this one (parallel reduction).
    pub fn merge(&mut self, other: &Stats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Summary of one parallel loop execution — what Figs. 4–5 plot per bar.
#[derive(Debug, Clone)]
pub struct LoopStats {
    /// `T_loop^par` — parallel loop time (max PE finish time), seconds.
    pub t_par: f64,
    /// Total scheduling steps `S` (number of chunks).
    pub chunks: u64,
    /// Mean PE finish time, seconds.
    pub mean_finish: f64,
    /// Load-imbalance metric: `max/mean − 1` over PE finish times.
    pub imbalance: f64,
    /// Coefficient of variation of PE finish times.
    pub cov_finish: f64,
    /// Total time PEs spent waiting on scheduling (queueing + service + comm).
    pub sched_overhead: f64,
    /// Messages exchanged with the master/coordinator.
    pub messages: u64,
}

impl LoopStats {
    /// Build from per-PE finish times and bookkeeping counters.
    pub fn from_finish_times(
        finish: &[f64],
        chunks: u64,
        sched_overhead: f64,
        messages: u64,
    ) -> Self {
        let s = Stats::from_slice(finish);
        LoopStats {
            t_par: s.max(),
            chunks,
            mean_finish: s.mean(),
            imbalance: if s.mean() > 0.0 { s.max() / s.mean() - 1.0 } else { 0.0 },
            cov_finish: s.cov(),
            sched_overhead,
            messages,
        }
    }
}

/// Mean and spread over experiment repetitions (paper: 20 reps/experiment).
#[derive(Debug, Clone)]
pub struct RepeatedRuns {
    pub t_par_mean: f64,
    pub t_par_stddev: f64,
    pub t_par_min: f64,
    pub t_par_max: f64,
    pub reps: u64,
}

impl RepeatedRuns {
    pub fn from_runs(runs: &[LoopStats]) -> Self {
        let s = Stats::from_slice(&runs.iter().map(|r| r.t_par).collect::<Vec<_>>());
        RepeatedRuns {
            t_par_mean: s.mean(),
            t_par_stddev: s.stddev(),
            t_par_min: s.min(),
            t_par_max: s.max(),
            reps: s.count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Stats::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 1.25).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.sum() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_bulk() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 5.0).collect();
        let bulk = Stats::from_slice(&xs);
        let mut a = Stats::from_slice(&xs[..37]);
        let b = Stats::from_slice(&xs[37..]);
        a.merge(&b);
        assert!((a.mean() - bulk.mean()).abs() < 1e-9);
        assert!((a.var() - bulk.var()).abs() < 1e-9);
        assert_eq!(a.count(), bulk.count());
    }

    #[test]
    fn cov_computation() {
        let xs = [0.0, 0.0205]; // mean 0.01025, stddev 0.01025 ⇒ c.o.v. 1.0
        let s = Stats::from_slice(&xs);
        assert!((s.cov() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn loop_stats_imbalance() {
        let l = LoopStats::from_finish_times(&[1.0, 1.0, 1.0, 2.0], 17, 0.1, 34);
        assert_eq!(l.t_par, 2.0);
        assert!((l.imbalance - 0.6).abs() < 1e-12); // 2/1.25 − 1
        assert_eq!(l.chunks, 17);
    }

    #[test]
    fn empty_stats_are_zeroed() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.cov(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn repeated_runs_summary() {
        let runs: Vec<LoopStats> = [70.0, 72.0, 71.0]
            .iter()
            .map(|&t| LoopStats::from_finish_times(&[t], 10, 0.0, 20))
            .collect();
        let r = RepeatedRuns::from_runs(&runs);
        assert_eq!(r.reps, 3);
        assert!((r.t_par_mean - 71.0).abs() < 1e-12);
        assert_eq!(r.t_par_min, 70.0);
        assert_eq!(r.t_par_max, 72.0);
    }
}
