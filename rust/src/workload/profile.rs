//! Iteration-cost models for the discrete-event simulator.
//!
//! The DES does not execute iterations — it advances virtual PE clocks by
//! each iteration's *modelled* cost. An [`IterationCost`] maps an iteration
//! index to seconds; implementations range from recorded real profiles to
//! the calibrated statistical models of Table 3.

use crate::metrics::Stats;
use crate::techniques::rnd::splitmix64;
use crate::workload::Workload;
use std::sync::Arc;

/// A per-iteration execution-time model.
#[derive(Clone)]
pub enum IterationCost {
    /// Every iteration costs the same.
    Constant(f64),
    /// Recorded costs, one per iteration (e.g. from a real workload pass).
    Table(Arc<Vec<f64>>),
    /// Gaussian(µ, σ) cost, deterministic per index via counter-based RNG,
    /// truncated at `min`. Models PSIA's near-uniform iterations.
    Gaussian { mu: f64, sigma: f64, min: f64, seed: u64 },
    /// Delegate to a workload's cost model (e.g. Mandelbrot escape counts).
    FromWorkload(Arc<dyn Workload>),
}

impl std::fmt::Debug for IterationCost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IterationCost::Constant(c) => write!(f, "Constant({c})"),
            IterationCost::Table(t) => write!(f, "Table(len={})", t.len()),
            IterationCost::Gaussian { mu, sigma, .. } => {
                write!(f, "Gaussian(mu={mu}, sigma={sigma})")
            }
            IterationCost::FromWorkload(w) => write!(f, "FromWorkload({})", w.name()),
        }
    }
}

impl IterationCost {
    /// Cost of iteration `i`, seconds. Deterministic in `i`.
    pub fn cost(&self, i: u64) -> f64 {
        match self {
            IterationCost::Constant(c) => *c,
            IterationCost::Table(t) => t[(i as usize).min(t.len() - 1)],
            IterationCost::Gaussian { mu, sigma, min, seed } => {
                let z = gaussian_draw(*seed, i);
                (mu + sigma * z).max(*min)
            }
            IterationCost::FromWorkload(w) => w.cost(i),
        }
    }

    /// Total cost of the contiguous range `[start, start+len)`.
    pub fn range_cost(&self, start: u64, len: u64) -> f64 {
        match self {
            // O(1) fast path for the constant model.
            IterationCost::Constant(c) => *c * len as f64,
            // §Perf: direct slice sum (vectorizes; no per-index enum
            // dispatch/clamp) — this is the DES's innermost loop: every
            // simulated chunk sums its iterations' costs.
            IterationCost::Table(t) => {
                let lo = (start as usize).min(t.len());
                let hi = ((start + len) as usize).min(t.len());
                t[lo..hi].iter().sum::<f64>()
                    + (len as usize - (hi - lo)) as f64 * t.last().copied().unwrap_or(0.0)
            }
            _ => (start..start + len).map(|i| self.cost(i)).sum(),
        }
    }

    /// PSIA's Table 3 model: Gaussian(0.07298, 0.00885) truncated at 0.0345.
    pub fn psia_table3(seed: u64) -> Self {
        IterationCost::Gaussian { mu: 0.07298, sigma: 0.00885, min: 0.0345, seed }
    }

    /// Record a real workload's cost model into a dense table (amortizes
    /// expensive `cost()` implementations for repeated DES runs).
    pub fn record(w: &dyn Workload) -> Self {
        IterationCost::Table(Arc::new((0..w.n()).map(|i| w.cost(i)).collect()))
    }

    /// Record a [`crate::workload::mandelbrot::Mandelbrot`] exploiting the
    /// set's conjugate symmetry: on this symmetric window the pixel grid
    /// maps `c(x, y) = conj(c(x, W−y))` for `y ≥ 1`, so escape counts (and
    /// costs) repeat — §Perf: halves the table-build time that dominates
    /// figure setup.
    pub fn record_mandelbrot(m: &crate::workload::mandelbrot::Mandelbrot) -> Self {
        let w = m.width as u64;
        let symmetric = (m.y_min + m.y_max).abs() < 1e-12;
        if !symmetric {
            return Self::record(m);
        }
        let mut table = vec![0.0f64; (w * w) as usize];
        for x in 0..w {
            let half = w / 2;
            for y in 0..=half {
                let c = m.cost(x * w + y);
                table[(x * w + y) as usize] = c;
                // conj pair: c_im(W−y) = −c_im(y) for y ≥ 1.
                if y >= 1 && w - y > half {
                    table[(x * w + (w - y)) as usize] = c;
                }
            }
        }
        IterationCost::Table(Arc::new(table))
    }

    /// Summary statistics over the first `n` iterations.
    pub fn stats(&self, n: u64) -> Stats {
        let mut s = Stats::new();
        for i in 0..n {
            s.push(self.cost(i));
        }
        s
    }
}

/// Standard-normal draw, deterministic in `(seed, i)` (Box–Muller over two
/// SplitMix64 uniforms). Public: experiment runners use it for per-PE speed
/// jitter across repetitions.
pub fn gaussian_draw(seed: u64, i: u64) -> f64 {
    let a = splitmix64(seed ^ i.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let b = splitmix64(a ^ 0xdead_beef_cafe_f00d);
    let u1 = ((a >> 11) as f64 + 0.5) / (1u64 << 53) as f64; // (0,1)
    let u2 = (b >> 11) as f64 / (1u64 << 53) as f64;
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mandelbrot::Mandelbrot;

    #[test]
    fn constant_range_cost() {
        let c = IterationCost::Constant(0.5);
        assert_eq!(c.range_cost(10, 4), 2.0);
    }

    #[test]
    fn gaussian_matches_moments() {
        let g = IterationCost::psia_table3(99);
        let s = g.stats(50_000);
        assert!((s.mean() - 0.07298).abs() < 0.001, "mean={}", s.mean());
        assert!((s.stddev() - 0.00885).abs() < 0.001, "sd={}", s.stddev());
        assert!(s.min() >= 0.0345);
    }

    #[test]
    fn gaussian_deterministic() {
        let g = IterationCost::psia_table3(7);
        for i in [0u64, 5, 1000] {
            assert_eq!(g.cost(i), g.cost(i));
        }
    }

    #[test]
    fn recorded_table_matches_workload() {
        let m = Mandelbrot::tiny();
        let t = IterationCost::record(&m);
        for i in [0u64, 17, 999] {
            assert_eq!(t.cost(i), m.cost(i));
        }
    }

    #[test]
    fn symmetric_record_matches_full_record() {
        let m = Mandelbrot::tiny();
        let full = IterationCost::record(&m);
        let sym = IterationCost::record_mandelbrot(&m);
        for i in 0..m.n() {
            assert_eq!(full.cost(i), sym.cost(i), "pixel {i}");
        }
    }

    #[test]
    fn asymmetric_window_falls_back() {
        let mut m = Mandelbrot::tiny();
        m.y_min = -1.0; // break the symmetry
        let full = IterationCost::record(&m);
        let sym = IterationCost::record_mandelbrot(&m);
        for i in (0..m.n()).step_by(97) {
            assert_eq!(full.cost(i), sym.cost(i));
        }
    }

    #[test]
    fn range_cost_sums() {
        let m = Mandelbrot::tiny();
        let t = IterationCost::record(&m);
        let direct: f64 = (100..110).map(|i| m.cost(i)).sum();
        assert!((t.range_cost(100, 10) - direct).abs() < 1e-12);
    }
}
