//! Mandelbrot set calculations (Listing 3): the loop body iterates
//! `z ← z⁴ + c` until `|z| ≥ 2` or a conversion threshold `CT` is reached.
//! One loop iteration = one pixel of a `W×W` image over a complex-plane
//! window. Iteration cost is proportional to the escape count — points
//! inside the set cost the full `CT`, far-outside points escape immediately,
//! giving the heavy-tailed distribution of Table 3 (c.o.v. 1.824).

use super::Workload;

/// Mandelbrot workload over a `width × width` image.
#[derive(Debug, Clone)]
pub struct Mandelbrot {
    /// Image width `W`; `N = W²`.
    pub width: u32,
    /// Conversion threshold `CT` (paper: 1,000,000; scale down for wall-clock
    /// tractability — the *shape* of the cost distribution is CT-invariant).
    pub ct: u32,
    /// Complex-plane window.
    pub x_min: f64,
    pub x_max: f64,
    pub y_min: f64,
    pub y_max: f64,
    /// Seconds per inner `z ← z⁴+c` step for the cost model (calibrated so
    /// the mean iteration time matches Table 3's 0.01025 s at CT=1e6).
    pub sec_per_step: f64,
}

impl Mandelbrot {
    /// Paper configuration: 512×512 image (N=262,144), CT=1,000,000, over
    /// the classic (−2..1, −1.5..1.5) window.
    pub fn paper(ct: u32) -> Self {
        Mandelbrot {
            width: 512,
            ct,
            x_min: -2.0,
            x_max: 1.0,
            y_min: -1.5,
            y_max: 1.5,
            sec_per_step: Self::calibrated_sec_per_step(ct),
        }
    }

    /// A small instance for tests: 64×64, CT=256.
    pub fn tiny() -> Self {
        Mandelbrot {
            width: 64,
            ct: 256,
            x_min: -2.0,
            x_max: 1.0,
            y_min: -1.5,
            y_max: 1.5,
            sec_per_step: Self::calibrated_sec_per_step(256),
        }
    }

    /// Choose `sec_per_step` so the *mean* modelled iteration time lands at
    /// Table 3's 0.01025 s: the mean escape count over this window is
    /// ≈ 0.222·CT (measured; dominated by in-set pixels), hence
    /// 0.01025/(0.222·CT).
    fn calibrated_sec_per_step(ct: u32) -> f64 {
        0.01025 / (0.222 * ct as f64)
    }

    /// Map a linear iteration index to the complex constant `c`.
    #[inline]
    pub fn c_of(&self, i: u64) -> (f64, f64) {
        let w = self.width as u64;
        let x = (i / w) as f64;
        let y = (i % w) as f64;
        let wf = self.width as f64;
        (
            self.x_min + x / wf * (self.x_max - self.x_min),
            self.y_min + y / wf * (self.y_max - self.y_min),
        )
    }

    /// Escape count for pixel `i`: the number of `z ← z⁴ + c` steps executed
    /// before `|z| ≥ 2`, capped at `CT` (Listing 3's inner loop).
    #[inline]
    pub fn escape_count(&self, i: u64) -> u32 {
        let (cre, cim) = self.c_of(i);
        let mut zre = 0.0f64;
        let mut zim = 0.0f64;
        let mut k = 0u32;
        while k < self.ct {
            // |z|² ≥ 4 ⇔ |z| ≥ 2
            let r2 = zre * zre + zim * zim;
            if r2 >= 4.0 {
                break;
            }
            // z² = (a²−b², 2ab); z⁴ = (z²)²
            let (a2, b2) = (zre * zre - zim * zim, 2.0 * zre * zim);
            let (a4, b4) = (a2 * a2 - b2 * b2, 2.0 * a2 * b2);
            zre = a4 + cre;
            zim = b4 + cim;
            k += 1;
        }
        k
    }

    /// True when pixel `i` is (numerically) inside the set (black in V).
    pub fn in_set(&self, i: u64) -> bool {
        self.escape_count(i) == self.ct
    }
}

impl Workload for Mandelbrot {
    fn n(&self) -> u64 {
        self.width as u64 * self.width as u64
    }

    fn execute(&self, i: u64) -> u64 {
        self.escape_count(i) as u64
    }

    fn cost(&self, i: u64) -> f64 {
        // Cost model: proportional to the escape count, plus a fixed pixel
        // setup term. Table 3's min of 1 µs anchors the setup cost.
        1e-6 + self.escape_count(i) as f64 * self.sec_per_step
    }

    fn name(&self) -> &'static str {
        "Mandelbrot"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::characterize;

    #[test]
    fn origin_is_in_set() {
        let m = Mandelbrot::tiny();
        // c = (-2, -1.5) corner escapes immediately; find the pixel for c≈0.
        // x index such that x_min + x/W·3 = 0 ⇒ x = 2W/3.
        let w = m.width as u64;
        let i = (2 * w / 3) * w + w / 2;
        let (cre, cim) = m.c_of(i);
        assert!(cre.abs() < 0.1 && cim.abs() < 0.1, "c=({cre},{cim})");
        assert!(m.in_set(i), "c≈0 must not escape");
    }

    #[test]
    fn far_corner_escapes_fast() {
        let m = Mandelbrot::tiny();
        assert!(m.escape_count(0) <= 2, "corner c=(-2,-1.5) escapes in ≤2 steps");
    }

    #[test]
    fn cost_is_heavy_tailed() {
        let m = Mandelbrot::tiny();
        let c = characterize(&m);
        assert!(c.cov > 1.0, "Mandelbrot c.o.v. should exceed 1 (got {})", c.cov);
        assert!(c.max_iter_time / c.min_iter_time > 50.0);
    }

    #[test]
    fn deterministic() {
        let m = Mandelbrot::tiny();
        for i in [0u64, 100, 2048, 4095] {
            assert_eq!(m.execute(i), m.execute(i));
        }
    }

    #[test]
    fn n_is_width_squared() {
        assert_eq!(Mandelbrot::paper(1000).n(), 262_144);
    }
}
