//! PSIA — parallel spin-image algorithm (Listing 2): converts a 3D point
//! cloud into a set of 2D "spin images". One loop iteration generates one
//! spin image: it scans **all** object points, bins those within the support
//! angle into a `W×W` histogram around the oriented point `P`.
//!
//! The paper's input is a proprietary 3D object; we substitute a seeded
//! synthetic point cloud (unit sphere + radial noise) with the paper's
//! parameters (image 5×5, bin 0.01, support angle 0.5). Iteration times are
//! near-uniform (every iteration scans the same M points; only the bin-test
//! branch varies), reproducing Table 3's low c.o.v.

use super::Workload;
use crate::techniques::rnd::splitmix64;

/// A 3D point with its (unit) normal vector.
#[derive(Debug, Clone, Copy)]
pub struct Point3 {
    pub p: [f32; 3],
    pub n: [f32; 3],
}

/// PSIA workload: `n_images` spin images over a synthetic oriented cloud.
#[derive(Debug, Clone)]
pub struct Psia {
    /// Oriented points (positions + normals).
    pub cloud: Vec<Point3>,
    /// Number of spin images to generate (= loop iterations `N`).
    pub n_images: u64,
    /// Spin-image width `W` (paper: 5 ⇒ 5×5 images).
    pub image_width: u32,
    /// Bin size `B` (paper: 0.01).
    pub bin_size: f32,
    /// Support angle `S` in radians (paper: 0.5).
    pub support_angle: f32,
    /// Modelled seconds per scanned point (calibrated to Table 3's
    /// µ = 0.07298 s at the paper's cloud size).
    pub sec_per_point: f64,
}

impl Psia {
    /// Synthetic cloud of `m` oriented points on a noisy unit sphere.
    pub fn synthetic(m: usize, n_images: u64, seed: u64) -> Self {
        let mut cloud = Vec::with_capacity(m);
        let mut s = seed;
        for _ in 0..m {
            s = splitmix64(s);
            let u = (s >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
            s = splitmix64(s);
            let v = (s >> 11) as f64 / (1u64 << 53) as f64;
            s = splitmix64(s);
            let noise = 1.0 + 0.05 * ((s >> 11) as f64 / (1u64 << 53) as f64 - 0.5);
            let theta = 2.0 * std::f64::consts::PI * u;
            let phi = (2.0 * v - 1.0).acos();
            let dir = [
                (phi.sin() * theta.cos()) as f32,
                (phi.sin() * theta.sin()) as f32,
                phi.cos() as f32,
            ];
            cloud.push(Point3 {
                p: [
                    dir[0] * noise as f32,
                    dir[1] * noise as f32,
                    dir[2] * noise as f32,
                ],
                // Normals point radially (outward) — exact for a sphere.
                n: dir,
            });
        }
        Psia {
            cloud,
            n_images,
            image_width: 5,
            // The paper's bin_size=0.01 is in its (proprietary) object's
            // coordinate units; for the synthetic unit-sphere substitute we
            // scale the bin so the W·B support spans the object (DESIGN.md
            // §Substitutions) — same accept-fraction structure.
            bin_size: 0.45,
            support_angle: 0.5,
            sec_per_point: 0.07298 / m as f64,
        }
    }

    /// Paper-scale instance: N = 262,144 spin images.
    pub fn paper(cloud_points: usize) -> Self {
        Self::synthetic(cloud_points, 262_144, 0x5e1a_5e1a)
    }

    /// Tiny instance for tests.
    pub fn tiny() -> Self {
        Self::synthetic(128, 4096, 42)
    }

    /// The oriented point a given loop iteration spins around. Iterations
    /// beyond the cloud reuse points cyclically (the paper generates M ≥ N
    /// images from its object; the synthetic cloud is smaller).
    #[inline]
    fn spin_point(&self, i: u64) -> &Point3 {
        &self.cloud[(i % self.cloud.len() as u64) as usize]
    }

    /// Generate the spin image for iteration `i` (Listing 2 inner loop).
    /// Returns the `W×W` histogram.
    pub fn spin_image(&self, i: u64) -> Vec<u32> {
        let w = self.image_width as usize;
        let mut img = vec![0u32; w * w];
        let sp = self.spin_point(i);
        let cos_support = self.support_angle.cos();
        for x in &self.cloud {
            // acos(n_i · n_j) ≤ S  ⇔  n_i · n_j ≥ cos S
            let dot_nn = sp.n[0] * x.n[0] + sp.n[1] * x.n[1] + sp.n[2] * x.n[2];
            if dot_nn < cos_support {
                continue;
            }
            let d = [x.p[0] - sp.p[0], x.p[1] - sp.p[1], x.p[2] - sp.p[2]];
            // β: signed distance along the normal; α: radial distance.
            let beta = sp.n[0] * d[0] + sp.n[1] * d[1] + sp.n[2] * d[2];
            let d2 = d[0] * d[0] + d[1] * d[1] + d[2] * d[2];
            let alpha2 = (d2 - beta * beta).max(0.0);
            let alpha = alpha2.sqrt();
            // Listing 2: k = ⌈(W/2 − β)/B⌉, l = ⌈α/B⌉ — W/2 is in bin units
            // (support half-width = W·B/2), as in Johnson's original.
            let k = ((w as f32 * self.bin_size / 2.0 - beta) / self.bin_size).ceil();
            let l = (alpha / self.bin_size).ceil();
            if k >= 0.0 && (k as usize) < w && l >= 0.0 && (l as usize) < w {
                img[k as usize * w + l as usize] += 1;
            }
        }
        img
    }
}

impl Workload for Psia {
    fn n(&self) -> u64 {
        self.n_images
    }

    fn execute(&self, i: u64) -> u64 {
        // Checksum of the histogram keeps the work observable.
        self.spin_image(i)
            .iter()
            .enumerate()
            .map(|(j, &v)| (j as u64 + 1).wrapping_mul(v as u64))
            .fold(0u64, |a, x| a.wrapping_add(x))
    }

    fn cost(&self, i: u64) -> f64 {
        // Every iteration scans all M points; the support-angle branch makes
        // cost mildly data-dependent. Model: full scan ± binning work that
        // varies smoothly with the spin point's position. The 0.21
        // coefficient calibrates σ to Table 3: n_z is ~uniform on [−1,1]
        // (std 1/√3), so σ/µ = 0.21/√3 ≈ 0.121 = 0.00885/0.07298.
        let sp = self.spin_point(i);
        self.cloud.len() as f64 * self.sec_per_point * (1.0 + 0.21 * sp.n[2] as f64)
    }

    fn name(&self) -> &'static str {
        "PSIA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::characterize;

    #[test]
    fn spin_image_self_point_binned() {
        let p = Psia::tiny();
        // Each image must bin at least the spin point itself (β=0, α=0 ⇒
        // k=⌈W/2/B⌉ — out of range for W=5, so just require determinism and
        // some non-trivial content overall).
        let img = p.spin_image(0);
        assert_eq!(img.len(), 25);
        assert_eq!(img, p.spin_image(0));
    }

    #[test]
    fn low_cov_like_table3() {
        let p = Psia::tiny();
        let c = characterize(&p);
        assert!(c.cov < 0.5, "PSIA c.o.v. should be low (got {})", c.cov);
        assert!(c.cov > 0.0, "but not zero");
    }

    #[test]
    fn cloud_is_seeded_deterministic() {
        let a = Psia::synthetic(64, 100, 7);
        let b = Psia::synthetic(64, 100, 7);
        assert_eq!(a.cloud.len(), 64);
        for (x, y) in a.cloud.iter().zip(&b.cloud) {
            assert_eq!(x.p, y.p);
        }
    }

    #[test]
    fn normals_are_unit() {
        let p = Psia::tiny();
        for pt in &p.cloud {
            let n2 = pt.n[0] * pt.n[0] + pt.n[1] * pt.n[1] + pt.n[2] * pt.n[2];
            assert!((n2 - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn execute_checksum_varies() {
        let p = Psia::tiny();
        let c0 = p.execute(0);
        assert!((1..64).any(|i| p.execute(i) != c0), "images should differ");
    }
}
