//! Parametric synthetic workloads for property tests and ablations:
//! controlled iteration-cost shapes that stress specific scheduler
//! behaviours (front-loaded vs back-loaded load, bimodal spikes, …).

use super::Workload;
use crate::techniques::rnd::splitmix64;

/// Shape of the synthetic cost curve across the iteration space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CostShape {
    /// All iterations equal.
    Uniform,
    /// Cost decreases linearly — heavy work first (favours FAC2 over GSS,
    /// per §2's discussion).
    FrontLoaded,
    /// Cost increases linearly — heavy work last (stresses decreasing
    /// techniques' tail behaviour).
    BackLoaded,
    /// Two cost levels, a fraction `spike_frac` of iterations expensive.
    Bimodal { spike_ratio: f64, spike_frac: f64 },
    /// Uniformly random in [0.5µ, 1.5µ].
    Jittered,
}

/// A synthetic workload with a parameterized cost shape.
#[derive(Debug, Clone)]
pub struct Synthetic {
    pub n: u64,
    /// Mean iteration cost, seconds.
    pub mu: f64,
    pub shape: CostShape,
    pub seed: u64,
}

impl Synthetic {
    pub fn new(n: u64, mu: f64, shape: CostShape, seed: u64) -> Self {
        Synthetic { n, mu, shape, seed }
    }
}

impl Workload for Synthetic {
    fn n(&self) -> u64 {
        self.n
    }

    fn execute(&self, i: u64) -> u64 {
        splitmix64(self.seed ^ i)
    }

    fn cost(&self, i: u64) -> f64 {
        let frac = i as f64 / self.n.max(1) as f64;
        match self.shape {
            CostShape::Uniform => self.mu,
            // Linear 2µ→~0 and mirror keep the mean at µ.
            CostShape::FrontLoaded => 2.0 * self.mu * (1.0 - frac),
            CostShape::BackLoaded => 2.0 * self.mu * frac,
            CostShape::Bimodal { spike_ratio, spike_frac } => {
                let r = splitmix64(self.seed ^ i.wrapping_mul(0x2545_f491_4f6c_dd1d));
                let u = (r >> 11) as f64 / (1u64 << 53) as f64;
                // Normalize so the mean stays µ.
                let base = self.mu / (1.0 - spike_frac + spike_frac * spike_ratio);
                if u < spike_frac {
                    base * spike_ratio
                } else {
                    base
                }
            }
            CostShape::Jittered => {
                let r = splitmix64(self.seed ^ i.wrapping_mul(0xd6e8_feb8_6659_fd93));
                let u = (r >> 11) as f64 / (1u64 << 53) as f64;
                self.mu * (0.5 + u)
            }
        }
    }

    fn name(&self) -> &'static str {
        "Synthetic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::characterize;

    #[test]
    fn uniform_has_zero_cov() {
        let w = Synthetic::new(1000, 0.01, CostShape::Uniform, 1);
        assert_eq!(characterize(&w).cov, 0.0);
    }

    #[test]
    fn means_are_preserved() {
        for shape in [
            CostShape::Uniform,
            CostShape::FrontLoaded,
            CostShape::BackLoaded,
            CostShape::Bimodal { spike_ratio: 10.0, spike_frac: 0.1 },
            CostShape::Jittered,
        ] {
            let w = Synthetic::new(20_000, 0.01, shape, 3);
            let c = characterize(&w);
            assert!(
                (c.mean_iter_time - 0.01).abs() < 0.002,
                "{shape:?}: mean={}",
                c.mean_iter_time
            );
        }
    }

    #[test]
    fn front_loaded_decreases() {
        let w = Synthetic::new(100, 1.0, CostShape::FrontLoaded, 1);
        assert!(w.cost(0) > w.cost(50));
        assert!(w.cost(50) > w.cost(99));
    }

    #[test]
    fn bimodal_has_two_levels() {
        let w = Synthetic::new(
            10_000,
            0.01,
            CostShape::Bimodal { spike_ratio: 20.0, spike_frac: 0.05 },
            9,
        );
        let mut lo = 0;
        let mut hi = 0;
        for i in 0..10_000 {
            if w.cost(i) > 0.05 {
                hi += 1;
            } else {
                lo += 1;
            }
        }
        assert!(hi > 200 && hi < 800, "hi={hi}");
        assert!(lo > 9000);
    }
}
