//! The paper's two evaluation applications (§6) plus synthetic profiles:
//!
//! * [`mandelbrot`] — the Mandelbrot set (`z ← z⁴ + c`, Listing 3); highly
//!   irregular iteration times (Table 3: c.o.v. 1.824).
//! * [`psia`] — parallel spin-image calculations (Listing 2); mildly
//!   irregular (c.o.v. 0.256).
//! * [`profile`] — per-iteration execution-time models feeding the DES.
//! * [`synthetic`] — parametric workload generators for property tests and
//!   ablations.
//!
//! A [`Workload`] provides both *real compute* (for the threaded engine and
//! the PJRT path) and an *iteration-cost model* (for the DES).

pub mod mandelbrot;
pub mod profile;
pub mod psia;
pub mod synthetic;

pub use profile::IterationCost;

use crate::metrics::Stats;

/// A schedulable parallel loop: `n` independent iterations with a way to
/// execute any single iteration and a cost model for simulation.
pub trait Workload: Send + Sync {
    /// Total loop iterations `N`.
    fn n(&self) -> u64;

    /// Execute iteration `i` for real, returning an opaque result checksum
    /// (to keep the optimizer honest and validate against references).
    fn execute(&self, i: u64) -> u64;

    /// Execute the contiguous chunk `[start, start+len)`, returning a
    /// combined checksum. The default iterates [`Workload::execute`];
    /// batch-capable backends (the PJRT tile executor) override this.
    fn execute_range(&self, start: u64, len: u64) -> u64 {
        (start..start + len).fold(0u64, |acc, i| acc.wrapping_add(self.execute(i)))
    }

    /// Modelled execution time of iteration `i` in seconds (for the DES).
    fn cost(&self, i: u64) -> f64;

    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
}

/// Table 3-style summary of a workload's main loop.
#[derive(Debug, Clone)]
pub struct LoopCharacteristics {
    pub name: &'static str,
    pub n: u64,
    pub max_iter_time: f64,
    pub min_iter_time: f64,
    pub mean_iter_time: f64,
    pub stddev: f64,
    pub cov: f64,
}

/// Compute the Table 3 row for a workload from its cost model.
pub fn characterize(w: &dyn Workload) -> LoopCharacteristics {
    let mut s = Stats::new();
    for i in 0..w.n() {
        s.push(w.cost(i));
    }
    LoopCharacteristics {
        name: w.name(),
        n: w.n(),
        max_iter_time: s.max(),
        min_iter_time: s.min(),
        mean_iter_time: s.mean(),
        stddev: s.stddev(),
        cov: s.cov(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Constant(u64);
    impl Workload for Constant {
        fn n(&self) -> u64 {
            self.0
        }
        fn execute(&self, i: u64) -> u64 {
            i
        }
        fn cost(&self, _i: u64) -> f64 {
            0.5
        }
        fn name(&self) -> &'static str {
            "constant"
        }
    }

    #[test]
    fn characterize_constant() {
        let c = characterize(&Constant(100));
        assert_eq!(c.n, 100);
        assert_eq!(c.mean_iter_time, 0.5);
        assert_eq!(c.cov, 0.0);
        assert_eq!(c.min_iter_time, c.max_iter_time);
    }
}
