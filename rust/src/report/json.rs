//! Minimal JSON emission (the build environment has no serde): enough to
//! export experiment results for external plotting.

/// A JSON value builder.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(vec![])
    }

    /// Add a field to an object (panics on non-objects — builder misuse).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("field() on non-object"),
        }
        self
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Integral values print without a trailing ".0".
                    if x.fract() == 0.0 && x.abs() < 9e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl Json {
    /// Parse a JSON document (recursive descent; enough for config/meta
    /// files — strings with escapes, numbers, bools, null, arrays, objects).
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes: Vec<char> = s.chars().collect();
        let mut p = Parser { c: &bytes, i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// One adaptive technique-slot rebind as a JSON object — the switch-event
/// trace entry shared by the DES and threaded exports.
pub fn switch_event_json(e: &crate::sched::adaptive::SwitchEvent) -> Json {
    Json::obj()
        .field("at_s", e.at_s)
        .field("level", e.level)
        .field("master", e.master)
        .field("from", e.from)
        .field("to", e.to)
        .field("predicted_ratio", e.predicted_ratio)
}

/// The switch-event trace as a JSON array.
pub fn switch_events_json(events: &[crate::sched::adaptive::SwitchEvent]) -> Json {
    Json::Arr(events.iter().map(switch_event_json).collect())
}

/// Export one threaded-engine run (any model, including the N-level hier
/// engine) for external plotting — the same fields the DES export carries,
/// plus the two-tier and per-level message splits. `levels` is the
/// scheduling-tree depth of hierarchical runs; `adaptive` marks
/// controller-driven runs (both drive the model label), whose switch-event
/// trace is exported alongside.
pub fn run_result_json(
    app: &str,
    technique: crate::techniques::TechniqueKind,
    model: crate::config::ExecutionModel,
    nodes: u32,
    levels: u32,
    adaptive: bool,
    n: u64,
    r: &crate::coordinator::RunResult,
) -> Json {
    Json::obj()
        .field("app", app)
        .field("technique", technique)
        .field("model", model.label_adaptive(levels, adaptive))
        .field("levels", levels)
        .field("adaptive", adaptive)
        .field("workers", r.per_rank.len() as u64)
        .field("nodes", nodes)
        .field("n", n)
        .field("t_par", r.stats.t_par)
        .field("chunks", r.stats.chunks)
        .field("messages", r.stats.messages)
        .field("messages_intra_node", r.intra_node_messages)
        .field("messages_inter_node", r.inter_node_messages)
        .field("messages_per_level", r.level_messages.clone())
        .field("sched_wait", r.stats.sched_overhead)
        .field("imbalance", r.stats.imbalance)
        .field("switches", r.switch_events.len() as u64)
        .field("switch_events", switch_events_json(&r.switch_events))
        .field("checksum", format!("{:#x}", r.checksum))
}

struct Parser<'a> {
    c: &'a [char],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.c.len() && self.c[self.i].is_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Option<char> {
        self.ws();
        self.c.get(self.i).copied()
    }

    fn eat(&mut self, ch: char) -> Result<(), String> {
        if self.peek() == Some(ch) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{ch}' at {}", self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        for ch in word.chars() {
            if self.c.get(self.i) != Some(&ch) {
                return Err(format!("bad literal at {}", self.i));
            }
            self.i += 1;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end")? {
            't' => self.lit("true", Json::Bool(true)),
            'f' => self.lit("false", Json::Bool(false)),
            'n' => self.lit("null", Json::Null),
            '"' => self.string().map(Json::Str),
            '[' => {
                self.eat('[')?;
                let mut items = vec![];
                if self.peek() == Some(']') {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    match self.peek() {
                        Some(',') => self.i += 1,
                        Some(']') => {
                            self.i += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(format!("bad array at {}", self.i)),
                    }
                }
            }
            '{' => {
                self.eat('{')?;
                let mut fields = vec![];
                if self.peek() == Some('}') {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.eat(':')?;
                    let v = self.value()?;
                    fields.push((k, v));
                    match self.peek() {
                        Some(',') => self.i += 1,
                        Some('}') => {
                            self.i += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(format!("bad object at {}", self.i)),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat('"')?;
        let mut out = String::new();
        loop {
            let ch = *self.c.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match ch {
                '"' => return Ok(out),
                '\\' => {
                    let esc = *self.c.get(self.i).ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        'n' => out.push('\n'),
                        't' => out.push('\t'),
                        'r' => out.push('\r'),
                        'u' => {
                            let hex: String =
                                self.c.get(self.i..self.i + 4).ok_or("bad \\u")?.iter().collect();
                            self.i += 4;
                            let code = u32::from_str_radix(&hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).ok_or("bad codepoint")?);
                        }
                        c => out.push(c),
                    }
                }
                c => out.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        self.ws();
        let start = self.i;
        while self
            .c
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || "+-.eE".contains(*c))
        {
            self.i += 1;
        }
        let s: String = self.c[start..self.i].iter().collect();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<crate::config::ExecutionModel> for Json {
    fn from(m: crate::config::ExecutionModel) -> Json {
        Json::Str(m.name().to_string())
    }
}
impl From<crate::techniques::TechniqueKind> for Json {
    fn from(k: crate::techniques::TechniqueKind) -> Json {
        Json::Str(k.name().to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .field("name", "GSS")
            .field("t_par", 70.25)
            .field("chunks", 17u64)
            .field("sizes", vec![250u64, 188, 141])
            .field("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"GSS","t_par":70.25,"chunks":17,"sizes":[250,188,141],"ok":true}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn integral_floats_render_as_ints() {
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(3.5).render(), "3.5");
    }

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e1], "c": {"d": "x\ny"}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("a").unwrap().as_u64(), Some(1));
        let Json::Arr(b) = j.get("b").unwrap() else { panic!() };
        assert_eq!(b.len(), 3);
        assert_eq!(b[2].as_f64(), Some(-25.0));
        assert_eq!(j.get("c").unwrap().get("d").unwrap().as_str(), Some("x\ny"));
        // Render → parse is stable.
        let again = Json::parse(&j.render()).unwrap();
        assert_eq!(again.get("a").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn model_and_technique_render_as_names() {
        let j = Json::obj()
            .field("model", crate::config::ExecutionModel::HierDca)
            .field("tech", crate::techniques::TechniqueKind::Fac2);
        assert_eq!(j.render(), r#"{"model":"HIER-DCA","tech":"FAC"}"#);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("42 junk").is_err());
    }

    #[test]
    fn run_result_export_carries_message_split() {
        use crate::coordinator::{RankSummary, RunResult};
        use crate::metrics::LoopStats;
        let r = RunResult {
            stats: LoopStats::from_finish_times(&[2.0, 2.5], 7, 0.1, 36),
            per_rank: vec![RankSummary::default(), RankSummary::default()],
            checksum: 0x1234,
            intra_node_messages: 28,
            inter_node_messages: 8,
            level_messages: vec![8, 28],
            fast_grants: 0,
            switch_events: vec![],
        };
        let j = run_result_json(
            "PSIA",
            crate::techniques::TechniqueKind::Fac2,
            crate::config::ExecutionModel::HierDca,
            2,
            2,
            false,
            4096,
            &r,
        );
        let parsed = Json::parse(&j.render()).unwrap();
        assert_eq!(parsed.get("model").unwrap().as_str(), Some("HIER-DCA"));
        assert_eq!(parsed.get("levels").unwrap().as_u64(), Some(2));
        assert_eq!(parsed.get("messages_intra_node").unwrap().as_u64(), Some(28));
        assert_eq!(parsed.get("messages_inter_node").unwrap().as_u64(), Some(8));
        let Json::Arr(per_level) = parsed.get("messages_per_level").unwrap() else {
            panic!("messages_per_level must be an array")
        };
        assert_eq!(per_level.len(), 2);
        assert_eq!(per_level[0].as_u64(), Some(8));
        assert_eq!(per_level[1].as_u64(), Some(28));
        assert_eq!(parsed.get("workers").unwrap().as_u64(), Some(2));
        assert_eq!(parsed.get("checksum").unwrap().as_str(), Some("0x1234"));
        // Depth-annotated label for deeper trees.
        let j3 = run_result_json(
            "PSIA",
            crate::techniques::TechniqueKind::Fac2,
            crate::config::ExecutionModel::HierDca,
            2,
            3,
            false,
            4096,
            &r,
        );
        let parsed3 = Json::parse(&j3.render()).unwrap();
        assert_eq!(parsed3.get("model").unwrap().as_str(), Some("HIER-DCA(3)"));
    }

    #[test]
    fn adaptive_export_labels_and_traces_switches() {
        use crate::coordinator::{RankSummary, RunResult};
        use crate::metrics::LoopStats;
        use crate::sched::adaptive::SwitchEvent;
        use crate::techniques::TechniqueKind;
        let r = RunResult {
            stats: LoopStats::from_finish_times(&[1.0], 3, 0.0, 12),
            per_rank: vec![RankSummary::default()],
            checksum: 0,
            intra_node_messages: 12,
            inter_node_messages: 0,
            level_messages: vec![12],
            fast_grants: 0,
            switch_events: vec![SwitchEvent {
                at_s: 0.25,
                level: 1,
                master: 3,
                from: TechniqueKind::Ss,
                to: TechniqueKind::Fac2,
                predicted_ratio: 0.4,
            }],
        };
        let j = run_result_json(
            "PSIA",
            TechniqueKind::Fac2,
            crate::config::ExecutionModel::HierDca,
            2,
            2,
            true,
            1024,
            &r,
        );
        let parsed = Json::parse(&j.render()).unwrap();
        assert_eq!(parsed.get("model").unwrap().as_str(), Some("HIER-DCA+ADAPT"));
        assert!(matches!(parsed.get("adaptive"), Some(Json::Bool(true))));
        assert_eq!(parsed.get("switches").unwrap().as_u64(), Some(1));
        let Json::Arr(events) = parsed.get("switch_events").unwrap() else {
            panic!("switch_events must be an array")
        };
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("from").unwrap().as_str(), Some("SS"));
        assert_eq!(events[0].get("to").unwrap().as_str(), Some("FAC"));
        assert_eq!(events[0].get("level").unwrap().as_u64(), Some(1));
        assert_eq!(events[0].get("master").unwrap().as_u64(), Some(3));
    }
}
