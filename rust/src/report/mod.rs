//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §4 experiment index) plus text/JSON rendering.

pub mod figures;
pub mod selector;
pub mod json;

use crate::metrics::RepeatedRuns;
use crate::techniques::TechniqueKind;

/// One bar of Figs. 4–5: a (technique × approach × delay) cell summarized
/// over repetitions.
#[derive(Debug, Clone)]
pub struct FigureRow {
    pub technique: TechniqueKind,
    pub model: crate::config::ExecutionModel,
    /// Injected delay, seconds.
    pub delay: f64,
    pub runs: RepeatedRuns,
    /// Total chunks of the first repetition (S, for context).
    pub chunks: u64,
}

/// Render rows in the paper's figure layout: one block per delay scenario,
/// techniques as rows, one `T_par ± sd` column pair per execution model
/// present in the data (CCA/DCA in the paper's figures; DCA-RMA and
/// HIER-DCA join when the sweep includes them). A final ratio column
/// compares the last model against the first (DCA/CCA in the default
/// two-model layout). Model labels derive from `hier_levels` (the
/// scheduling-tree depth of the hierarchical cells, e.g. `HIER-DCA(3)`),
/// and column widths follow the labels so deeper trees render without
/// truncation.
pub fn render_figure(title: &str, rows: &[FigureRow], hier_levels: u32) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "== {title} ==").unwrap();
    let models: Vec<crate::config::ExecutionModel> = crate::config::ExecutionModel::ALL
        .into_iter()
        .filter(|m| rows.iter().any(|r| r.model == *m))
        .collect();
    let labels: Vec<String> = models.iter().map(|m| m.label(hier_levels)).collect();
    // Each model column fits its own header ("<label> T_par[s]"); 17 keeps
    // the classic layout stable ("HIER-DCA T_par[s]").
    let widths: Vec<usize> =
        labels.iter().map(|l| (l.len() + " T_par[s]".len()).max(17)).collect();
    let ratio_label = if models.len() >= 2 {
        format!("{}/{}", labels[labels.len() - 1], labels[0])
    } else {
        String::new()
    };
    let ratio_width = ratio_label.len().max(12);
    let mut delays: Vec<f64> = rows.iter().map(|r| r.delay).collect();
    delays.sort_by(f64::total_cmp);
    delays.dedup();
    for d in delays {
        writeln!(out, "\n-- injected delay: {:.0} µs --", d * 1e6).unwrap();
        write!(out, "{:<8}", "tech").unwrap();
        for (label, &w) in labels.iter().zip(&widths) {
            write!(out, " {:>w$} {:>9}", format!("{label} T_par[s]"), "±sd").unwrap();
        }
        if models.len() >= 2 {
            write!(out, " {:>ratio_width$}", ratio_label).unwrap();
        }
        writeln!(out).unwrap();
        for kind in TechniqueKind::EVALUATED {
            let find = |m: crate::config::ExecutionModel| {
                rows.iter().find(|r| {
                    r.technique == kind && r.model == m && (r.delay - d).abs() < 1e-12
                })
            };
            let cells: Vec<Option<&FigureRow>> = models.iter().map(|&m| find(m)).collect();
            if cells.iter().all(Option::is_none) {
                continue;
            }
            write!(out, "{:<8}", kind.name()).unwrap();
            for (c, &w) in cells.iter().zip(&widths) {
                match c {
                    Some(r) => write!(
                        out,
                        " {:>w$.3} {:>9.3}",
                        r.runs.t_par_mean, r.runs.t_par_stddev
                    )
                    .unwrap(),
                    None => write!(out, " {:>w$} {:>9}", "n/a", "-").unwrap(),
                }
            }
            if models.len() >= 2 {
                match (cells[cells.len() - 1], cells[0]) {
                    (Some(last), Some(first)) if first.runs.t_par_mean > 0.0 => write!(
                        out,
                        " {:>ratio_width$.3}",
                        last.runs.t_par_mean / first.runs.t_par_mean
                    )
                    .unwrap(),
                    _ => write!(out, " {:>ratio_width$}", "-").unwrap(),
                }
            }
            writeln!(out).unwrap();
        }
    }
    out
}

/// Render a threaded-engine run summary (the `run` subcommand's report) —
/// shared across all four models, with the two-tier message split the hier
/// engine produces (flat engines report all traffic as intra-node).
pub fn render_run_summary(r: &crate::coordinator::RunResult) -> String {
    let mut out = format!(
        "T_par = {:.3}s   chunks = {}   messages = {} (intra-node {}, inter-node {})   \
         sched-wait = {:.3}s   imbalance = {:.4}   checksum = {:#x}\n",
        r.stats.t_par,
        r.stats.chunks,
        r.stats.messages,
        r.intra_node_messages,
        r.inter_node_messages,
        r.stats.sched_overhead,
        r.stats.imbalance,
        r.checksum,
    );
    out.push_str(&render_switch_events(&r.switch_events));
    out
}

/// Render an adaptive switch-event trace (empty string for static runs) —
/// the one definition behind the `run` summary and the `simulate`/`hier`
/// console reports.
pub fn render_switch_events(events: &[crate::sched::adaptive::SwitchEvent]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    if events.is_empty() {
        return out;
    }
    writeln!(out, "adaptive switches = {}:", events.len()).unwrap();
    for e in events {
        writeln!(
            out,
            "  t={:.4}s level {} master {}: {} → {} (predicted ratio {:.3})",
            e.at_s,
            e.level,
            e.master,
            e.from.name(),
            e.to.name(),
            e.predicted_ratio
        )
        .unwrap();
    }
    out
}

/// Render the Table 2 layout (chunk sequences per technique).
pub fn render_table2(rows: &[(TechniqueKind, Vec<u64>)]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "== Table 2: chunk sizes (N=1000, P=4, closed/DCA forms) ==").unwrap();
    writeln!(out, "{:<8} {:>7}  sizes", "tech", "#chunks").unwrap();
    for (kind, sizes) in rows {
        let shown: Vec<String> = if sizes.len() > 24 {
            sizes[..12]
                .iter()
                .map(u64::to_string)
                .chain(std::iter::once("…".into()))
                .chain(sizes[sizes.len() - 3..].iter().map(u64::to_string))
                .collect()
        } else {
            sizes.iter().map(u64::to_string).collect()
        };
        writeln!(out, "{:<8} {:>7}  {}", kind.name(), sizes.len(), shown.join(", ")).unwrap();
    }
    out
}

/// Render Table 3 (loop characteristics).
pub fn render_table3(rows: &[crate::workload::LoopCharacteristics]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "== Table 3: main-loop characteristics ==").unwrap();
    writeln!(
        out,
        "{:<12} {:>9} {:>11} {:>11} {:>11} {:>11} {:>8}",
        "app", "N", "max[s]", "min[s]", "mean[s]", "stddev[s]", "c.o.v."
    )
    .unwrap();
    for c in rows {
        writeln!(
            out,
            "{:<12} {:>9} {:>11.6} {:>11.6} {:>11.6} {:>11.6} {:>8.3}",
            c.name, c.n, c.max_iter_time, c.min_iter_time, c.mean_iter_time, c.stddev, c.cov
        )
        .unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecutionModel;
    use crate::metrics::{LoopStats, RepeatedRuns};

    fn row(kind: TechniqueKind, model: ExecutionModel, delay: f64, t: f64) -> FigureRow {
        let ls = LoopStats::from_finish_times(&[t], 10, 0.0, 20);
        FigureRow {
            technique: kind,
            model,
            delay,
            runs: RepeatedRuns::from_runs(&[ls]),
            chunks: 10,
        }
    }

    #[test]
    fn figure_renders_pairs() {
        let rows = vec![
            row(TechniqueKind::Gss, ExecutionModel::Cca, 0.0, 70.0),
            row(TechniqueKind::Gss, ExecutionModel::Dca, 0.0, 69.0),
        ];
        let s = render_figure("Fig 4", &rows, 2);
        assert!(s.contains("GSS"));
        assert!(s.contains("70.000"));
        assert!(s.contains("0 µs"));
    }

    #[test]
    fn figure_renders_all_four_models_with_gaps() {
        let rows = vec![
            row(TechniqueKind::Af, ExecutionModel::Cca, 0.0, 70.0),
            row(TechniqueKind::Af, ExecutionModel::Dca, 0.0, 69.0),
            // AF×DCA-RMA is unsupported — its cell must render as n/a.
            row(TechniqueKind::Af, ExecutionModel::HierDca, 0.0, 68.0),
            row(TechniqueKind::Af, ExecutionModel::DcaRma, 100e-6, 71.0),
        ];
        let s = render_figure("sweep", &rows, 2);
        assert!(s.contains("HIER-DCA"));
        assert!(s.contains("DCA-RMA"));
        assert!(s.contains("n/a"));
        assert!(s.contains("68.000"));
        assert!(s.contains("100 µs"));
    }

    /// Depth-annotated hierarchy labels render (header, ratio column, data
    /// rows aligned to the widened columns) without truncation.
    #[test]
    fn figure_renders_depth3_labels_untruncated() {
        let rows = vec![
            row(TechniqueKind::Gss, ExecutionModel::Cca, 0.0, 70.0),
            row(TechniqueKind::Gss, ExecutionModel::HierDca, 0.0, 67.5),
        ];
        let s = render_figure("depth-3 sweep", &rows, 3);
        assert!(s.contains("HIER-DCA(3) T_par[s]"), "{s}");
        assert!(s.contains("HIER-DCA(3)/CCA"), "{s}");
        assert!(s.contains("67.500"), "{s}");
        assert!(!s.contains("HIER-DCA T_par"), "two-level label must not appear: {s}");
        // Every non-empty line of a block is at least as wide as its header.
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with("GSS")).collect();
        assert!(!lines.is_empty());
    }

    #[test]
    fn table2_truncates_long_sequences() {
        let rows = vec![(TechniqueKind::Ss, vec![1u64; 1000])];
        let s = render_table2(&rows);
        assert!(s.contains("…"));
        assert!(s.contains("1000"));
    }

    #[test]
    fn run_summary_shows_message_split() {
        use crate::coordinator::{RankSummary, RunResult};
        let r = RunResult {
            stats: LoopStats::from_finish_times(&[1.5], 10, 0.25, 52),
            per_rank: vec![RankSummary::default()],
            checksum: 0xBEEF,
            intra_node_messages: 40,
            inter_node_messages: 12,
            level_messages: vec![12, 40],
            fast_grants: 0,
            switch_events: vec![],
        };
        let s = render_run_summary(&r);
        assert!(s.contains("intra-node 40"), "{s}");
        assert!(s.contains("inter-node 12"), "{s}");
        assert!(s.contains("0xbeef"), "{s}");
        assert!(!s.contains("adaptive switches"), "static runs stay clean: {s}");
        let adaptive = RunResult {
            switch_events: vec![crate::sched::adaptive::SwitchEvent {
                at_s: 0.5,
                level: 1,
                master: 2,
                from: TechniqueKind::Ss,
                to: TechniqueKind::Gss,
                predicted_ratio: 0.3,
            }],
            ..r
        };
        let s = render_run_summary(&adaptive);
        assert!(s.contains("adaptive switches = 1"), "{s}");
        assert!(s.contains("SS → GSS"), "{s}");
    }
}
