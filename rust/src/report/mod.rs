//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §4 experiment index) plus text/JSON rendering.

pub mod figures;
pub mod selector;
pub mod json;

use crate::metrics::RepeatedRuns;
use crate::techniques::TechniqueKind;

/// One bar of Figs. 4–5: a (technique × approach × delay) cell summarized
/// over repetitions.
#[derive(Debug, Clone)]
pub struct FigureRow {
    pub technique: TechniqueKind,
    pub model: crate::config::ExecutionModel,
    /// Injected delay, seconds.
    pub delay: f64,
    pub runs: RepeatedRuns,
    /// Total chunks of the first repetition (S, for context).
    pub chunks: u64,
}

/// Render rows in the paper's figure layout: one block per delay scenario,
/// techniques as rows, CCA/DCA side by side.
pub fn render_figure(title: &str, rows: &[FigureRow]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "== {title} ==").unwrap();
    let mut delays: Vec<f64> = rows.iter().map(|r| r.delay).collect();
    delays.sort_by(f64::total_cmp);
    delays.dedup();
    for d in delays {
        writeln!(out, "\n-- injected delay: {:.0} µs --", d * 1e6).unwrap();
        writeln!(
            out,
            "{:<8} {:>12} {:>12} {:>9} {:>9} {:>8}",
            "tech", "CCA T_par[s]", "DCA T_par[s]", "CCA ±sd", "DCA ±sd", "DCA/CCA"
        )
        .unwrap();
        for kind in TechniqueKind::EVALUATED {
            let find = |m: crate::config::ExecutionModel| {
                rows.iter().find(|r| {
                    r.technique == kind && r.model == m && (r.delay - d).abs() < 1e-12
                })
            };
            let cca = find(crate::config::ExecutionModel::Cca);
            let dca = find(crate::config::ExecutionModel::Dca);
            if let (Some(c), Some(dd)) = (cca, dca) {
                writeln!(
                    out,
                    "{:<8} {:>12.3} {:>12.3} {:>9.3} {:>9.3} {:>8.3}",
                    kind.name(),
                    c.runs.t_par_mean,
                    dd.runs.t_par_mean,
                    c.runs.t_par_stddev,
                    dd.runs.t_par_stddev,
                    dd.runs.t_par_mean / c.runs.t_par_mean
                )
                .unwrap();
            }
        }
    }
    out
}

/// Render the Table 2 layout (chunk sequences per technique).
pub fn render_table2(rows: &[(TechniqueKind, Vec<u64>)]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "== Table 2: chunk sizes (N=1000, P=4, closed/DCA forms) ==").unwrap();
    writeln!(out, "{:<8} {:>7}  sizes", "tech", "#chunks").unwrap();
    for (kind, sizes) in rows {
        let shown: Vec<String> = if sizes.len() > 24 {
            sizes[..12]
                .iter()
                .map(u64::to_string)
                .chain(std::iter::once("…".into()))
                .chain(sizes[sizes.len() - 3..].iter().map(u64::to_string))
                .collect()
        } else {
            sizes.iter().map(u64::to_string).collect()
        };
        writeln!(out, "{:<8} {:>7}  {}", kind.name(), sizes.len(), shown.join(", ")).unwrap();
    }
    out
}

/// Render Table 3 (loop characteristics).
pub fn render_table3(rows: &[crate::workload::LoopCharacteristics]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "== Table 3: main-loop characteristics ==").unwrap();
    writeln!(
        out,
        "{:<12} {:>9} {:>11} {:>11} {:>11} {:>11} {:>8}",
        "app", "N", "max[s]", "min[s]", "mean[s]", "stddev[s]", "c.o.v."
    )
    .unwrap();
    for c in rows {
        writeln!(
            out,
            "{:<12} {:>9} {:>11.6} {:>11.6} {:>11.6} {:>11.6} {:>8.3}",
            c.name, c.n, c.max_iter_time, c.min_iter_time, c.mean_iter_time, c.stddev, c.cov
        )
        .unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecutionModel;
    use crate::metrics::{LoopStats, RepeatedRuns};

    fn row(kind: TechniqueKind, model: ExecutionModel, delay: f64, t: f64) -> FigureRow {
        let ls = LoopStats::from_finish_times(&[t], 10, 0.0, 20);
        FigureRow { technique: kind, model, delay, runs: RepeatedRuns::from_runs(&[ls]), chunks: 10 }
    }

    #[test]
    fn figure_renders_pairs() {
        let rows = vec![
            row(TechniqueKind::Gss, ExecutionModel::Cca, 0.0, 70.0),
            row(TechniqueKind::Gss, ExecutionModel::Dca, 0.0, 69.0),
        ];
        let s = render_figure("Fig 4", &rows);
        assert!(s.contains("GSS"));
        assert!(s.contains("70.000"));
        assert!(s.contains("0 µs"));
    }

    #[test]
    fn table2_truncates_long_sequences() {
        let rows = vec![(TechniqueKind::Ss, vec![1u64; 1000])];
        let s = render_table2(&rows);
        assert!(s.contains("…"));
        assert!(s.contains("1000"));
    }
}
