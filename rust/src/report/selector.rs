//! §7 future work, implemented: *"enable dynamic selection of the
//! scheduling approach (DCA or CCA) that minimizes applications' execution
//! time"* — realized the way the authors' own follow-up (SimAS, ref [23])
//! does it: simulate the candidate configurations on the calibrated DES and
//! pick the winner before launching the real run.
//!
//! The probe simulates a *prefix* of the loop (cost-model truncation keeps
//! it cheap) for every candidate execution model and returns the model with
//! the lowest predicted `T_loop^par`.

use crate::config::{ClusterConfig, ExecutionModel, HierParams, SchedPath};
use crate::des::{simulate, DesConfig};
use crate::substrate::delay::InjectedDelay;
use crate::techniques::{LoopParams, TechniqueKind};
use crate::workload::IterationCost;

/// Outcome of a selection probe.
#[derive(Debug, Clone)]
pub struct Selection {
    /// The chosen model.
    pub model: ExecutionModel,
    /// Predicted `T_par` per candidate, in candidate order.
    pub predictions: Vec<(ExecutionModel, f64)>,
    /// Fraction of the loop simulated.
    pub prefix_fraction: f64,
}

/// SimAS-style selection: simulate `prefix_fraction` of the loop for each
/// candidate model and choose the fastest. Unviable cells are skipped:
/// AF×DCA-RMA (no closed form, §4) and HierDca on geometries where dedicated
/// masters would leave no computing rank.
pub fn select_approach(
    technique: TechniqueKind,
    n: u64,
    cluster: &ClusterConfig,
    cost: &IterationCost,
    delay: InjectedDelay,
    hier: HierParams,
    sched_path: SchedPath,
    candidates: &[ExecutionModel],
    prefix_fraction: f64,
) -> anyhow::Result<Selection> {
    let frac = prefix_fraction.clamp(0.01, 1.0);
    let prefix_n = ((n as f64 * frac) as u64).max(cluster.total_ranks() as u64 * 2);
    let mut predictions = Vec::new();
    for &model in candidates {
        if technique == TechniqueKind::Af && model == ExecutionModel::DcaRma {
            continue;
        }
        if model == ExecutionModel::HierDca && !crate::hier::hier_feasible(cluster, &hier) {
            continue;
        }
        // Adaptive selection only exists on the DCA protocols; the other
        // candidates are probed statically rather than rejected (and the
        // flat DCA adaptive restrictions — AF start, pure lock-free — fall
        // back to a static probe the same way).
        let mut hier = hier;
        let flat_adaptive_ok = technique != TechniqueKind::Af && sched_path != SchedPath::LockFree;
        if !(model == ExecutionModel::HierDca || (model == ExecutionModel::Dca && flat_adaptive_ok))
        {
            hier.adaptive = Default::default();
        }
        let cfg = DesConfig {
            sched_path,
            delay,
            hier,
            ..DesConfig::new(
                LoopParams::new(prefix_n.min(n), cluster.total_ranks()),
                technique,
                model,
                cluster.clone(),
                cost.clone(),
            )
        };
        predictions.push((model, simulate(&cfg)?.t_par()));
    }
    anyhow::ensure!(!predictions.is_empty(), "no viable candidate models");
    let model = predictions
        .iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(m, _)| *m)
        .unwrap();
    Ok(Selection { model, predictions, prefix_fraction: frac })
}

/// Convenience: choose between CCA and DCA (the §7 pair).
pub fn select_cca_or_dca(
    technique: TechniqueKind,
    n: u64,
    cluster: &ClusterConfig,
    cost: &IterationCost,
    delay: InjectedDelay,
) -> anyhow::Result<Selection> {
    select_approach(
        technique,
        n,
        cluster,
        cost,
        delay,
        HierParams::default(),
        SchedPath::default(),
        &[ExecutionModel::Cca, ExecutionModel::Dca],
        0.15,
    )
}

/// Full arbitration over **all four** execution models (CCA, DCA, DCA-RMA,
/// HIER-DCA) — the SimAS candidate-set diversity argument: model selection
/// under perturbation pays off most when the candidates differ structurally.
pub fn select_model(
    technique: TechniqueKind,
    n: u64,
    cluster: &ClusterConfig,
    cost: &IterationCost,
    delay: InjectedDelay,
    hier: HierParams,
    sched_path: SchedPath,
) -> anyhow::Result<Selection> {
    select_approach(
        technique,
        n,
        cluster,
        cost,
        delay,
        hier,
        sched_path,
        &ExecutionModel::ALL,
        0.15,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn saturating_cluster() -> ClusterConfig {
        ClusterConfig {
            nodes: 8,
            ranks_per_node: 16,
            break_after: 0,
            ..ClusterConfig::minihpc()
        }
    }

    /// Under a heavy calculation delay with fine chunks (the Fig 5c regime)
    /// the selector must pick DCA.
    #[test]
    fn picks_dca_under_calculation_slowdown() {
        let s = select_cca_or_dca(
            TechniqueKind::Ss,
            131_072,
            &saturating_cluster(),
            &IterationCost::Constant(0.01),
            InjectedDelay::calculation_only(100e-6),
        )
        .unwrap();
        assert_eq!(s.model, ExecutionModel::Dca, "{:?}", s.predictions);
    }

    /// With the delay in the assignment instead (§7's reversal), DCA's
    /// extra synchronized accesses mean CCA must not lose.
    #[test]
    fn does_not_pick_dca_under_assignment_slowdown() {
        let s = select_cca_or_dca(
            TechniqueKind::Ss,
            131_072,
            &saturating_cluster(),
            &IterationCost::Constant(0.01),
            InjectedDelay::assignment_only(200e-6),
        )
        .unwrap();
        let cca = s.predictions.iter().find(|(m, _)| *m == ExecutionModel::Cca).unwrap().1;
        let dca = s.predictions.iter().find(|(m, _)| *m == ExecutionModel::Dca).unwrap().1;
        assert!(cca <= dca * 1.02, "CCA {cca} should not lose under assignment delay");
    }

    #[test]
    fn af_rma_candidate_skipped() {
        let s = select_approach(
            TechniqueKind::Af,
            10_000,
            &ClusterConfig::small(4),
            &IterationCost::Constant(1e-4),
            InjectedDelay::none(),
            HierParams::default(),
            SchedPath::default(),
            &[ExecutionModel::Dca, ExecutionModel::DcaRma],
            0.2,
        )
        .unwrap();
        assert_eq!(s.predictions.len(), 1);
        assert_eq!(s.model, ExecutionModel::Dca);
    }

    #[test]
    fn predictions_cover_candidates() {
        let s = select_approach(
            TechniqueKind::Gss,
            50_000,
            &ClusterConfig::small(8),
            &IterationCost::psia_table3(3),
            InjectedDelay::none(),
            HierParams::default(),
            SchedPath::default(),
            &[ExecutionModel::Cca, ExecutionModel::Dca, ExecutionModel::DcaRma],
            0.1,
        )
        .unwrap();
        assert_eq!(s.predictions.len(), 3);
        for (_, t) in &s.predictions {
            assert!(*t > 0.0);
        }
    }

    /// The selector now arbitrates over all four models; every viable
    /// candidate must yield a prediction, and HIER-DCA is among them.
    #[test]
    fn four_model_arbitration() {
        let cluster = ClusterConfig { nodes: 4, ranks_per_node: 8, ..ClusterConfig::minihpc() };
        let s = select_model(
            TechniqueKind::Gss,
            40_000,
            &cluster,
            &IterationCost::Constant(1e-4),
            InjectedDelay::none(),
            HierParams::default(),
            SchedPath::default(),
        )
        .unwrap();
        assert_eq!(s.predictions.len(), 4);
        assert!(s
            .predictions
            .iter()
            .any(|(m, _)| *m == ExecutionModel::HierDca));
        for (_, t) in &s.predictions {
            assert!(*t > 0.0);
        }
    }

    /// A depth-3 candidate (2 racks × 2 nodes × 4 ranks) arbitrates
    /// alongside the flat models without panics, and an unresolvable level
    /// plan just drops the hierarchical candidate instead of failing the
    /// whole selection.
    #[test]
    fn depth3_candidate_selects_and_bad_plans_are_skipped() {
        let cluster = ClusterConfig {
            nodes: 4,
            ranks_per_node: 4,
            racks: 2,
            ..ClusterConfig::minihpc()
        };
        let hier = HierParams::with_inner(TechniqueKind::Ss)
            .with_levels(3)
            .with_fanouts(&[2, 2, 4]);
        let s = select_model(
            TechniqueKind::Fac2,
            20_000,
            &cluster,
            &IterationCost::Constant(1e-4),
            InjectedDelay::none(),
            hier,
            SchedPath::default(),
        )
        .unwrap();
        assert_eq!(s.predictions.len(), 4);
        for (_, t) in &s.predictions {
            assert!(*t > 0.0);
        }
        // Fan-outs that don't divide the rank count: the hierarchical
        // candidate is infeasible and silently skipped.
        let bad = HierParams::default().with_levels(3).with_fanouts(&[3, 3, 3]);
        let s = select_model(
            TechniqueKind::Fac2,
            20_000,
            &cluster,
            &IterationCost::Constant(1e-4),
            InjectedDelay::none(),
            bad,
            SchedPath::default(),
        )
        .unwrap();
        assert_eq!(s.predictions.len(), 3);
        assert!(s.predictions.iter().all(|(m, _)| *m != ExecutionModel::HierDca));
    }

    /// Under the assignment-site slowdown the flat coordinator serializes
    /// every commit; the hierarchical model spreads commits over the node
    /// masters, so HIER-DCA must not lose to flat DCA there. (A batched
    /// outer technique — here FAC — is the intended hierarchy operating
    /// point: an SS outer level would degenerate to 1-iteration node-chunks.)
    #[test]
    fn hier_competitive_under_assignment_slowdown() {
        let cluster = ClusterConfig { nodes: 8, ranks_per_node: 16, ..ClusterConfig::minihpc() };
        let s = select_model(
            TechniqueKind::Fac2,
            65_536,
            &cluster,
            &IterationCost::Constant(0.0005),
            InjectedDelay::assignment_only(100e-6),
            HierParams::default(),
            SchedPath::default(),
        )
        .unwrap();
        let hier = s
            .predictions
            .iter()
            .find(|(m, _)| *m == ExecutionModel::HierDca)
            .unwrap()
            .1;
        let dca = s
            .predictions
            .iter()
            .find(|(m, _)| *m == ExecutionModel::Dca)
            .unwrap()
            .1;
        assert!(hier <= dca * 1.05, "hier {hier} should not lose to flat DCA {dca}");
    }
}
