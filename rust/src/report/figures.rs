//! Experiment runners: one function per paper artifact (DESIGN.md §4).
//!
//! * [`table2_rows`] / [`fig1_series`] — chunk sequences (Fig. 1, Table 2);
//! * [`table3_rows`] — loop characteristics (Table 3);
//! * [`run_figure`] — the §6 factorial experiment (Figs. 4, 5): technique ×
//!   approach × injected delay over the simulated 256-rank cluster.

use std::sync::Arc;

use super::FigureRow;
use crate::config::{ClusterConfig, DelaySite, ExecutionModel, HierParams};
use crate::des::{simulate, DesConfig};
use crate::metrics::{LoopStats, RepeatedRuns};
use crate::sched::closed_form_schedule;
use crate::substrate::delay::InjectedDelay;
use crate::techniques::{LoopParams, Technique, TechniqueKind};
use crate::workload::mandelbrot::Mandelbrot;
use crate::workload::profile::gaussian_draw;
use crate::workload::psia::Psia;
use crate::workload::{characterize, IterationCost, LoopCharacteristics, Workload};

/// The two §6 applications.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum App {
    Psia,
    Mandelbrot,
}

impl App {
    pub fn name(&self) -> &'static str {
        match self {
            App::Psia => "PSIA",
            App::Mandelbrot => "Mandelbrot",
        }
    }

    /// Per-iteration cost model, calibrated to Table 3. `scale_ct` trades
    /// Mandelbrot fidelity for build time (cost *shape* is CT-invariant).
    pub fn cost_model(&self, seed: u64, scale_ct: u32) -> IterationCost {
        match self {
            App::Psia => IterationCost::psia_table3(seed),
            App::Mandelbrot => {
                IterationCost::record_mandelbrot(&Mandelbrot::paper(scale_ct))
            }
        }
    }
}

/// Table 2: the closed-form (DCA) chunk sequence per technique at the
/// paper's example point (N=1000, P=4 by default).
pub fn table2_rows(params: &LoopParams) -> Vec<(TechniqueKind, Vec<u64>)> {
    TechniqueKind::ALL
        .iter()
        .filter(|k| k.has_closed_form())
        .map(|&kind| {
            let t = Technique::new(kind, params);
            let sizes = closed_form_schedule(&t, params).iter().map(|a| a.size).collect::<Vec<_>>();
            (kind, sizes)
        })
        .collect()
}

/// Fig. 1: chunk-size series (chunk index → size) for plotting.
pub fn fig1_series(params: &LoopParams) -> Vec<(TechniqueKind, Vec<u64>)> {
    table2_rows(params)
}

/// Table 3: characteristics of the two applications' main loops.
/// `mandelbrot_ct` scales the conversion threshold (paper: 1,000,000).
pub fn table3_rows(n: u64, mandelbrot_ct: u32, psia_cloud: usize) -> Vec<LoopCharacteristics> {
    let mut psia = Psia::paper(psia_cloud);
    psia.n_images = n;
    let mut mandel = Mandelbrot::paper(mandelbrot_ct);
    // Match N by shrinking the image if asked for fewer iterations.
    if n < mandel.n() {
        let w = (n as f64).sqrt() as u32;
        mandel.width = w.max(8);
    }
    vec![characterize(&psia), characterize(&mandel)]
}

/// Configuration for a Figs. 4–5 regeneration run.
#[derive(Debug, Clone)]
pub struct FigureConfig {
    pub app: App,
    /// Loop size (paper: 262,144).
    pub n: u64,
    /// Cluster geometry (paper: 16×16 = 256 ranks).
    pub cluster: ClusterConfig,
    /// Repetitions per cell (paper: 20).
    pub reps: u32,
    pub techniques: Vec<TechniqueKind>,
    pub models: Vec<ExecutionModel>,
    /// Injected delays in seconds (paper: 0, 10µs, 100µs).
    pub delays: Vec<f64>,
    pub delay_site: DelaySite,
    /// Base seed; repetition r perturbs PE speeds with seed+r.
    pub seed: u64,
    /// Std-dev of per-PE speed jitter across repetitions (system noise).
    pub speed_jitter: f64,
    /// Mandelbrot CT used for the cost profile (scaled from 1e6).
    pub mandelbrot_ct: u32,
    /// Two-level parameters for cells running [`ExecutionModel::HierDca`].
    pub hier: HierParams,
}

impl FigureConfig {
    /// The paper's full factorial cell set for one application.
    pub fn paper(app: App) -> Self {
        FigureConfig {
            app,
            n: 262_144,
            cluster: ClusterConfig::minihpc(),
            reps: 20,
            techniques: TechniqueKind::EVALUATED.to_vec(),
            models: vec![ExecutionModel::Cca, ExecutionModel::Dca],
            delays: vec![0.0, 10e-6, 100e-6],
            delay_site: DelaySite::Calculation,
            seed: 0xF1605,
            speed_jitter: 0.005,
            mandelbrot_ct: 2_000,
            hier: HierParams::default(),
        }
    }

    /// A scaled-down configuration for quick runs and tests.
    pub fn quick(app: App) -> Self {
        let cluster = ClusterConfig { nodes: 4, ranks_per_node: 4, ..ClusterConfig::minihpc() };
        FigureConfig {
            n: 16_384,
            cluster,
            reps: 3,
            mandelbrot_ct: 500,
            ..Self::paper(app)
        }
    }
}

/// Run the factorial experiment; returns one row per (technique × model ×
/// delay) cell. Skips AF×DCA-RMA (unsupported by design).
pub fn run_figure(cfg: &FigureConfig) -> anyhow::Result<Vec<FigureRow>> {
    // Build (or record) the cost model once; repetitions share it and vary
    // only the PE-speed jitter, like repeated runs on the same inputs.
    let base_cost = Arc::new(cfg.app.cost_model(cfg.seed, cfg.mandelbrot_ct));
    let mut rows = Vec::new();
    for &technique in &cfg.techniques {
        for &model in &cfg.models {
            if technique == TechniqueKind::Af && model == ExecutionModel::DcaRma {
                continue;
            }
            for &d in &cfg.delays {
                let mut runs: Vec<LoopStats> = Vec::with_capacity(cfg.reps as usize);
                let mut chunks = 0;
                for rep in 0..cfg.reps {
                    let params = LoopParams::new(cfg.n, cfg.cluster.total_ranks());
                    let delay = match cfg.delay_site {
                        DelaySite::Calculation => InjectedDelay::calculation_only(d),
                        DelaySite::Assignment => InjectedDelay::assignment_only(d),
                    };
                    let pe_speed: Vec<f64> = (0..cfg.cluster.total_ranks() as u64)
                        .map(|pe| {
                            1.0 + cfg.speed_jitter
                                * gaussian_draw(cfg.seed ^ (rep as u64) << 32, pe)
                        })
                        .collect();
                    let des = DesConfig {
                        delay,
                        pe_speed,
                        hier: cfg.hier,
                        ..DesConfig::new(
                            params,
                            technique,
                            model,
                            cfg.cluster.clone(),
                            (*base_cost).clone(),
                        )
                    };
                    let r = simulate(&des)?;
                    if rep == 0 {
                        chunks = r.stats.chunks;
                    }
                    runs.push(r.stats);
                }
                rows.push(FigureRow {
                    technique,
                    model,
                    delay: d,
                    runs: RepeatedRuns::from_runs(&runs),
                    chunks,
                });
            }
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_twelve_closed_rows() {
        let rows = table2_rows(&LoopParams::new(1000, 4));
        assert_eq!(rows.len(), 12); // 13 techniques − AF
        for (kind, sizes) in &rows {
            assert_eq!(sizes.iter().sum::<u64>(), 1000, "{kind}");
        }
    }

    #[test]
    fn quick_psia_figure_shape() {
        let mut cfg = FigureConfig::quick(App::Psia);
        cfg.techniques = vec![TechniqueKind::Static, TechniqueKind::Gss];
        cfg.delays = vec![0.0, 100e-6];
        cfg.reps = 2;
        let rows = run_figure(&cfg).unwrap();
        assert_eq!(rows.len(), 2 * 2 * 2);
        for r in &rows {
            assert!(r.runs.t_par_mean > 0.0);
            assert_eq!(r.runs.reps, 2);
        }
        // Paper shape: under 100 µs delay, DCA ≤ CCA for GSS.
        let find = |m, d: f64| {
            rows.iter()
                .find(|r| {
                    r.technique == TechniqueKind::Gss
                        && r.model == m
                        && (r.delay - d).abs() < 1e-9
                })
                .unwrap()
                .runs
                .t_par_mean
        };
        let cca = find(ExecutionModel::Cca, 100e-6);
        let dca = find(ExecutionModel::Dca, 100e-6);
        assert!(dca <= cca * 1.02, "DCA {dca} should not exceed CCA {cca}");
    }

    #[test]
    fn quick_figure_with_hier_model() {
        let mut cfg = FigureConfig::quick(App::Psia);
        cfg.techniques = vec![TechniqueKind::Fac2];
        cfg.models = vec![ExecutionModel::Cca, ExecutionModel::Dca, ExecutionModel::HierDca];
        cfg.delays = vec![0.0];
        cfg.reps = 2;
        let rows = run_figure(&cfg).unwrap();
        assert_eq!(rows.len(), 3);
        let hier = rows
            .iter()
            .find(|r| r.model == ExecutionModel::HierDca)
            .expect("hier row present");
        assert!(hier.runs.t_par_mean > 0.0);
        assert!(hier.chunks > 0);
    }

    #[test]
    fn table3_shape_matches_paper() {
        let rows = table3_rows(4096, 300, 256);
        assert_eq!(rows.len(), 2);
        let psia = &rows[0];
        let mandel = &rows[1];
        assert_eq!(psia.name, "PSIA");
        assert!(psia.cov < 0.5, "PSIA c.o.v. low, got {}", psia.cov);
        assert!(mandel.cov > 1.0, "Mandelbrot c.o.v. high, got {}", mandel.cov);
    }
}
