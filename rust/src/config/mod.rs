//! Experiment configuration — the encoded form of the paper's Table 4
//! factorial design, serializable to/from JSON for the CLI and benches.

use crate::techniques::{CandidateSet, LoopParams, TechniqueKind};


/// Which chunk-calculation approach drives the run (the paper's central
/// comparison, extended with the hierarchical follow-up of arXiv 1903.09510).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecutionModel {
    /// Centralized: master performs calculation **and** assignment (§3).
    Cca,
    /// Distributed over two-sided messages: coordinator assigns, workers
    /// calculate (§4–5, this paper's contribution).
    Dca,
    /// Distributed over the one-sided RMA window (the PDP'19 predecessor).
    DcaRma,
    /// Two-level hierarchical DCA (§7 future work / arXiv 1903.09510): a
    /// global coordinator hands *node-chunks* to per-node masters over the
    /// inter-node fabric; each master re-subdivides its node-chunk among its
    /// local ranks with an (optionally different) inner technique over the
    /// intra-node fabric. See [`crate::hier`].
    HierDca,
}

impl ExecutionModel {
    /// All execution models, in comparison order.
    pub const ALL: [ExecutionModel; 4] = [
        ExecutionModel::Cca,
        ExecutionModel::Dca,
        ExecutionModel::DcaRma,
        ExecutionModel::HierDca,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ExecutionModel::Cca => "CCA",
            ExecutionModel::Dca => "DCA",
            ExecutionModel::DcaRma => "DCA-RMA",
            ExecutionModel::HierDca => "HIER-DCA",
        }
    }

    /// Rendered label for a run of scheduling-tree depth `levels`: the flat
    /// models keep their names, the hierarchy is annotated with its depth
    /// once it deviates from the classic two-level form (`HIER-DCA(3)`), so
    /// depth-3 runs render and select without colliding with two-level rows.
    pub fn label(&self, levels: u32) -> String {
        match self {
            ExecutionModel::HierDca if levels != 2 && levels != 0 => {
                format!("HIER-DCA({levels})")
            }
            m => m.name().to_string(),
        }
    }

    /// [`Self::label`] with the adaptive-selection marker: a run whose
    /// technique slots are controller-driven renders as `HIER-DCA(3)+ADAPT`
    /// (or `DCA+ADAPT` for the flat engine), so adaptive rows never collide
    /// with static baselines in reports, JSON exports, or the bench gate.
    pub fn label_adaptive(&self, levels: u32, adaptive: bool) -> String {
        let mut l = self.label(levels);
        if adaptive {
            l.push_str("+ADAPT");
        }
        l
    }

    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        // Adaptive-marked labels parse back to the model (the marker itself
        // is configuration, like the depth annotation).
        if let Some(head) = s.strip_suffix("+ADAPT").or_else(|| s.strip_suffix("+adapt")) {
            return Self::parse(head);
        }
        match s.to_ascii_uppercase().as_str() {
            "CCA" => Some(ExecutionModel::Cca),
            "DCA" => Some(ExecutionModel::Dca),
            "DCA-RMA" | "DCARMA" | "RMA" => Some(ExecutionModel::DcaRma),
            "HIER-DCA" | "HIERDCA" | "HIER" => Some(ExecutionModel::HierDca),
            // Depth-annotated hierarchy labels ("HIER-DCA(3)") parse back to
            // the model; the depth itself is configured via `--levels`.
            up if up.starts_with("HIER") && up.ends_with(')') => up
                .split_once('(')
                .filter(|(_, depth)| {
                    depth.strip_suffix(')').is_some_and(|n| n.parse::<u32>().is_ok())
                })
                .and_then(|(head, _)| Self::parse(head)),
            _ => None,
        }
    }
}

impl std::fmt::Display for ExecutionModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Where the injected slowdown lands (§6 injects it into the chunk
/// *calculation*; §7 flags the *assignment* variant as future work — we
/// implement both, see DESIGN.md experiment A2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DelaySite {
    /// Delay the chunk-calculation function (paper's §6 scenarios).
    Calculation,
    /// Delay the chunk-assignment critical section (paper's §7 prediction:
    /// this should favour CCA, which sends fewer messages).
    Assignment,
}

/// Which grant protocol the self-scheduling chunk exchange uses.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum SchedPath {
    /// The paper's two-phase reserve/commit message exchange (§4) at every
    /// level — the default; all committed baselines run here.
    #[default]
    TwoPhase,
    /// The lock-free fast path: techniques whose chunk size is a pure
    /// function of the scheduling step (everything except the
    /// measurement-coupled AF/TAP) reserve a chunk with a **single CAS** on
    /// the ledger's packed `(start, seq)` word, sized by an array lookup in
    /// the precomputed [`crate::techniques::ChunkTable`] — one atomic op
    /// replacing the whole request/reply exchange (the arXiv 1901.02773
    /// endpoint; on shared memory, a one-word CAS). AF/TAP levels, staged
    /// prefetch refills, and cross-level fetches fall back to the two-phase
    /// protocol; both paths emit the identical serial schedule.
    ///
    /// Under adaptive selection ([`AdaptiveParams`]), the candidate set is
    /// restricted to fast-path techniques so a rebind can always republish a
    /// fresh chunk table and the subtree never has to leave the CAS path.
    LockFree,
    /// Adaptive: start on the lock-free fast path wherever it applies, and
    /// **demote per subtree to the two-phase protocol** the moment that
    /// subtree's adaptive controller rebinds its technique slot to a
    /// measurement-coupled technique (TAP) whose sizes cannot be tabulated —
    /// the rebind breaks the "chunk size is a pure function of the step"
    /// assumption the CAS path is built on, exactly when it happens.
    /// Without adaptivity, `Auto` behaves like [`SchedPath::LockFree`]
    /// (including all its fallbacks). The flat DCA engines have no agent
    /// left to drive rebinding once the coordinator disappears, so flat
    /// adaptive `Auto` runs the two-phase protocol from the start.
    Auto,
}

impl SchedPath {
    pub fn name(&self) -> &'static str {
        match self {
            SchedPath::TwoPhase => "two-phase",
            SchedPath::LockFree => "lockfree",
            SchedPath::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "two-phase" | "twophase" | "2p" => Some(SchedPath::TwoPhase),
            "lockfree" | "lock-free" | "cas" => Some(SchedPath::LockFree),
            "auto" => Some(SchedPath::Auto),
            _ => None,
        }
    }

    /// Does this path request CAS grants where they are applicable?
    pub fn wants_lockfree(&self) -> bool {
        matches!(self, SchedPath::LockFree | SchedPath::Auto)
    }
}

impl std::fmt::Display for SchedPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// How a level master derives its prefetch watermark (the iteration count
/// below which it requests the *next* chunk from its parent while the
/// current one is still being consumed).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum WatermarkMode {
    /// No prefetch: fetch on exhaustion (the original arXiv 1903.09510
    /// behavior).
    #[default]
    Off,
    /// Fixed iteration count, identical for every level master.
    Fixed(u64),
    /// Adaptive (SimAS-style feedback): each level master tracks an EWMA of
    /// its observed parent-fetch round trip and derives the watermark as
    /// `⌈rtt / per-iteration drain time⌉` from its subtree's measured
    /// throughput — the round trip is hidden exactly, no hand tuning.
    /// Falls back to fetch-on-exhaustion until both are measured.
    Auto,
}

/// Deepest supported scheduling-tree depth (`--levels`): 1 = flat (the DCA
/// protocol root ↔ ranks), 2 = the classic two-level hierarchy, 3 = rack →
/// node → socket. One spare level beyond the ROADMAP's three-level target.
pub const MAX_LEVELS: usize = 4;

/// SimAS-style adaptive technique selection (`--adaptive`): each subtree
/// master owns an [`crate::sched::adaptive::AdaptiveController`] that keeps
/// per-subtree EWMAs of observed iteration mean/σ, per-grant scheduling
/// overhead, and drain rate, and at the probe cadence runs a cheap
/// closed-form probe (chunk-table prefix sums — no nested simulation) over
/// the candidate set, re-binding the subtree's re-bindable technique slot
/// when a candidate is predicted to beat the current binding. Applies to
/// the hierarchical subtree ledgers (levels ≥ 1; the root's outer technique
/// stays static) and to the flat DCA coordinator.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdaptiveParams {
    /// Master switch (default off — every committed baseline is static).
    pub enabled: bool,
    /// Grants between probes (0 ⇒ [`Self::DEFAULT_PROBE_INTERVAL`]).
    pub probe_interval: u32,
    /// Candidate techniques (empty ⇒ [`CandidateSet::default_probe`]).
    pub candidates: CandidateSet,
}

impl AdaptiveParams {
    /// Default probe cadence, in grants served by the subtree's ledger.
    pub const DEFAULT_PROBE_INTERVAL: u32 = 64;

    /// Adaptive selection with the defaults.
    pub fn on() -> Self {
        AdaptiveParams { enabled: true, ..Self::default() }
    }

    /// Effective probe cadence (≥ 1).
    pub fn probe_interval(&self) -> u32 {
        match self.probe_interval {
            0 => Self::DEFAULT_PROBE_INTERVAL,
            n => n,
        }
    }

    /// Effective candidate set.
    pub fn candidates(&self) -> CandidateSet {
        if self.candidates.is_empty() {
            CandidateSet::default_probe()
        } else {
            self.candidates
        }
    }
}

/// One resolved level of the recursive scheduling tree: the technique that
/// sizes the chunks this level's holder (the root for level 0, a level-d
/// master otherwise) hands to its `fanout` children, and the nominal one-way
/// latency class its protocol messages cross.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelSpec {
    /// Technique sizing this level's chunks, bound per parent chunk to
    /// `P = fanout`.
    pub technique: TechniqueKind,
    /// Children per master at this level (leaf ranks at the deepest level).
    pub fanout: u32,
    /// Nominal one-way latency class of this level's protocol messages,
    /// seconds (the DES charges actual rank-pair latency, which collapses to
    /// this class whenever masters are placed on the physical hierarchy).
    pub latency: f64,
}

/// The fully resolved scheduling tree of one run: `levels[0]` is the root
/// (outer) level, `levels[k-1]` the leaf-serving level. The fanout product
/// equals the total rank count.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelPlan {
    pub levels: Vec<LevelSpec>,
}

impl LevelPlan {
    /// Tree depth `k`.
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Ranks spanned by one subtree rooted at a level-`d` master:
    /// `S_d = Π_{i≥d} fanout_i` (`S_0` = all ranks, `S_k` would be 1).
    pub fn subtree_ranks(&self, d: usize) -> u32 {
        self.levels[d..].iter().map(|l| l.fanout).product()
    }

    /// Number of masters at level `d` (`M_0 = 1`, the root).
    pub fn masters_at(&self, d: usize) -> u32 {
        self.levels[..d].iter().map(|l| l.fanout).product()
    }

    /// The rank hosting level-`d` master `j` (block placement: the first
    /// rank of its subtree; the root lives on rank 0).
    pub fn host_rank(&self, d: usize, j: u32) -> u32 {
        if d == 0 {
            0
        } else {
            j * self.subtree_ranks(d)
        }
    }

    /// Technique of each level, outer first.
    pub fn techs(&self) -> Vec<TechniqueKind> {
        self.levels.iter().map(|l| l.technique).collect()
    }
}

/// Parameters of the hierarchical model ([`ExecutionModel::HierDca`]),
/// generalized from the fixed two-level pair to a recursive depth-`k` tree.
///
/// The *outer* (level 0) technique is the experiment's main `technique`;
/// this struct adds what the flat models don't have: the per-level
/// techniques below it, the tree depth and fan-outs, and the prefetch
/// policy every level master applies against its parent. The default
/// geometry (`levels = 2`, fanouts from `ClusterConfig`/engine config)
/// reproduces the classic two-level hierarchy exactly; [`Self::plan`]
/// resolves the final [`LevelPlan`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HierParams {
    /// Deepest-level (leaf-serving) technique; `None` ⇒ reuse the outer
    /// technique. At depth 2 this is the classic "inner" technique.
    pub inner: Option<TechniqueKind>,
    /// Techniques of the intermediate levels `1..k-1` (only consulted when
    /// `levels ≥ 3`); `None` ⇒ reuse the outer technique.
    pub mids: [Option<TechniqueKind>; MAX_LEVELS - 2],
    /// Prefetch watermark policy of every level master.
    pub watermark: WatermarkMode,
    /// Staged-queue capacity per level master: how many parent chunks may be
    /// buffered behind the current one (1 = the PR 2 single-slot stage;
    /// deeper queues cover multi-chunk stalls on very high-latency fabrics).
    /// 0 is clamped to 1.
    pub prefetch_depth: u32,
    /// Scheduling-tree depth `k` (0 is clamped to the default 2).
    pub levels: u32,
    /// Explicit per-level fan-outs, outer first; 0 = derive (depth 2 derives
    /// `[nodes, ranks/node]` from the cluster geometry; deeper trees derive
    /// only the *last* unset fanout from the total rank count).
    pub fanouts: [u32; MAX_LEVELS],
    /// SimAS-style adaptive per-subtree technique selection. Lives here —
    /// rather than on the per-run configs — so both substrates and the flat
    /// DCA engines read one policy definition (like the prefetch watermark).
    pub adaptive: AdaptiveParams,
    /// Extend the lock-free CAS fast path to **master-tier** fetches
    /// (levels `0..k-1`): a child master's parent fetch becomes one fused
    /// op at the parent's atomic unit instead of the four-message two-phase
    /// exchange, feeding the child ledger through the staged-chunk MPSC.
    /// Opt-in; requires `SchedPath::{LockFree, Auto}`, takes effect only at
    /// levels whose technique has a closed form, and is mutually exclusive
    /// with `adaptive`.
    pub master_lockfree: bool,
}

impl HierParams {
    /// Use `inner` at the deepest level, regardless of the outer technique.
    pub fn with_inner(inner: TechniqueKind) -> Self {
        HierParams { inner: Some(inner), ..Self::default() }
    }

    /// Enable prefetch at a fixed watermark (in iterations).
    pub fn with_watermark(self, watermark: u64) -> Self {
        HierParams { watermark: WatermarkMode::Fixed(watermark), ..self }
    }

    /// Enable the adaptive (EWMA round-trip-derived) watermark.
    pub fn with_auto_watermark(self) -> Self {
        HierParams { watermark: WatermarkMode::Auto, ..self }
    }

    /// Set the staged prefetch-queue capacity.
    pub fn with_prefetch_depth(self, depth: u32) -> Self {
        HierParams { prefetch_depth: depth, ..self }
    }

    /// Set the scheduling-tree depth.
    pub fn with_levels(self, levels: u32) -> Self {
        HierParams { levels, ..self }
    }

    /// Set explicit fan-outs (outer first; at most [`MAX_LEVELS`] entries).
    pub fn with_fanouts(self, fanouts: &[u32]) -> Self {
        let mut out = self;
        out.fanouts = [0; MAX_LEVELS];
        for (slot, f) in out.fanouts.iter_mut().zip(fanouts) {
            *slot = *f;
        }
        out
    }

    /// Set the technique of intermediate level `1 ≤ d < k-1`.
    pub fn with_mid(self, d: usize, kind: TechniqueKind) -> Self {
        let mut out = self;
        out.mids[d - 1] = Some(kind);
        out
    }

    /// Enable SimAS-style adaptive technique selection with the defaults.
    pub fn with_adaptive(self) -> Self {
        HierParams { adaptive: AdaptiveParams { enabled: true, ..self.adaptive }, ..self }
    }

    /// Set the adaptive probe cadence (grants between probes).
    pub fn with_probe_interval(self, grants: u32) -> Self {
        HierParams {
            adaptive: AdaptiveParams { probe_interval: grants, ..self.adaptive },
            ..self
        }
    }

    /// Set the adaptive candidate set.
    pub fn with_candidates(self, candidates: CandidateSet) -> Self {
        HierParams { adaptive: AdaptiveParams { candidates, ..self.adaptive }, ..self }
    }

    /// Extend the lock-free fast path to master-tier fetches.
    pub fn with_master_lockfree(self) -> Self {
        HierParams { master_lockfree: true, ..self }
    }

    /// Resolve the inner technique given the experiment's outer technique.
    pub fn inner_or(&self, outer: TechniqueKind) -> TechniqueKind {
        self.inner.unwrap_or(outer)
    }

    /// Tree depth `k` (clamped to `[1, MAX_LEVELS]`, 0 ⇒ the default 2).
    pub fn depth(&self) -> usize {
        match self.levels {
            0 => 2,
            k => (k as usize).min(MAX_LEVELS),
        }
    }

    /// Staged-queue capacity (≥ 1).
    pub fn staged_capacity(&self) -> usize {
        self.prefetch_depth.max(1) as usize
    }

    /// Technique of level `d` given the experiment's outer technique.
    pub fn tech_of_level(&self, d: usize, outer: TechniqueKind) -> TechniqueKind {
        let k = self.depth();
        if d == 0 {
            outer
        } else if d == k - 1 {
            self.inner_or(outer)
        } else {
            self.mids[d - 1].unwrap_or(outer)
        }
    }

    /// Resolve the per-level fan-outs for `p` ranks: explicit entries win;
    /// at depth 2 the default is the classic `[default_nodes, p/nodes]`; at
    /// any depth a single trailing 0 is derived from `p`. The product must
    /// equal `p`.
    fn resolve_fanouts(&self, p: u32, default_nodes: u32) -> anyhow::Result<Vec<u32>> {
        let k = self.depth();
        anyhow::ensure!(p >= 1, "need at least one rank");
        let mut fanouts: Vec<u32> = self.fanouts[..k].to_vec();
        if fanouts.iter().all(|&f| f == 0) {
            match k {
                1 => fanouts[0] = p,
                2 => fanouts[0] = default_nodes.max(1),
                _ => anyhow::bail!(
                    "a {k}-level tree needs explicit fan-outs (--fanout a,b,…)"
                ),
            }
        }
        // Derive the single trailing 0 from the total rank count.
        if fanouts[k - 1] == 0 {
            let given: u32 = fanouts[..k - 1].iter().product();
            anyhow::ensure!(
                given >= 1 && p % given == 0,
                "fan-outs {:?} do not divide the rank count {p}",
                &fanouts[..k - 1]
            );
            fanouts[k - 1] = p / given;
        }
        anyhow::ensure!(
            fanouts.iter().all(|&f| f >= 1),
            "every level needs a fan-out ≥ 1 (got {fanouts:?})"
        );
        let prod: u64 = fanouts.iter().map(|&f| f as u64).product();
        anyhow::ensure!(
            prod == p as u64,
            "fan-out product {prod} must equal the rank count {p} (fan-outs {fanouts:?})"
        );
        Ok(fanouts)
    }

    /// Resolve the full [`LevelPlan`] for a DES run of `p` ranks on
    /// `cluster` (latency classes come from the cluster's latency triple).
    pub fn plan(
        &self,
        outer: TechniqueKind,
        p: u32,
        cluster: &ClusterConfig,
    ) -> anyhow::Result<LevelPlan> {
        let k = self.depth();
        let fanouts = self.resolve_fanouts(p, cluster.nodes)?;
        let levels = fanouts
            .iter()
            .enumerate()
            .map(|(d, &fanout)| LevelSpec {
                technique: self.tech_of_level(d, outer),
                fanout,
                latency: cluster.level_latency(d, k),
            })
            .collect();
        Ok(LevelPlan { levels })
    }

    /// Resolve the [`LevelPlan`] for the threaded engine (`default_nodes`
    /// plays the role the cluster geometry plays for the DES; latencies are
    /// real, so the nominal classes are zeroed).
    pub fn plan_threaded(
        &self,
        outer: TechniqueKind,
        p: u32,
        default_nodes: u32,
    ) -> anyhow::Result<LevelPlan> {
        let fanouts = self.resolve_fanouts(p, default_nodes)?;
        let levels = fanouts
            .iter()
            .enumerate()
            .map(|(d, &fanout)| LevelSpec {
                technique: self.tech_of_level(d, outer),
                fanout,
                latency: 0.0,
            })
            .collect();
        Ok(LevelPlan { levels })
    }
}

/// Simulated cluster geometry and communication costs (miniHPC stand-in).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of physical nodes (paper: 16).
    pub nodes: u32,
    /// MPI ranks per node (paper: 16 ⇒ 256 total).
    pub ranks_per_node: u32,
    /// Racks the nodes are grouped into (1 = the paper's single-rack
    /// miniHPC; must divide `nodes` to take effect). Together with the two
    /// node-level classes this forms the latency *triple* the three-level
    /// hierarchy schedules against.
    pub racks: u32,
    /// One-way message latency within a node, seconds.
    pub intra_node_latency: f64,
    /// One-way message latency across nodes in the same rack, seconds.
    pub inter_node_latency: f64,
    /// One-way message latency across racks, seconds (only reachable when
    /// `racks > 1`).
    pub inter_rack_latency: f64,
    /// Master/coordinator service time to handle one message, seconds
    /// (dequeue + match + reply build; excludes chunk calculation).
    pub service_time: f64,
    /// Cost of evaluating one chunk-size formula, seconds (excludes the
    /// injected delay).
    pub calc_time: f64,
    /// `breakAfter` — iterations the non-dedicated master/coordinator (rank
    /// 0) executes between servicing rounds (the LB-tool parameter, §3).
    /// `0` = dedicated master. The optimal value is application-dependent:
    /// with long iterations (PSIA: 73 ms) anything above 1 starves the
    /// request queue for seconds at a time (see the A3 ablation).
    pub break_after: u32,
}

impl ClusterConfig {
    /// The paper's miniHPC testbed: 16 dual-socket Xeon nodes × 16 ranks
    /// in one rack (the inter-rack class defaults to 3× inter-node and only
    /// matters once `racks > 1`).
    pub fn minihpc() -> Self {
        ClusterConfig {
            nodes: 16,
            ranks_per_node: 16,
            racks: 1,
            intra_node_latency: 0.5e-6,
            inter_node_latency: 2.0e-6,
            inter_rack_latency: 6.0e-6,
            service_time: 0.5e-6,
            calc_time: 0.2e-6,
            break_after: 1,
        }
    }

    /// A small geometry for unit tests and laptop runs.
    pub fn small(ranks: u32) -> Self {
        ClusterConfig {
            nodes: 1,
            ranks_per_node: ranks,
            ..Self::minihpc()
        }
    }

    pub fn total_ranks(&self) -> u32 {
        self.nodes * self.ranks_per_node
    }

    /// Nominal one-way latency class of protocol level `d` in a `k`-level
    /// scheduling tree placed on this cluster's physical hierarchy: the
    /// deepest level is intra-node, the top level crosses the widest tier
    /// (racks when `racks > 1`), everything between is inter-node.
    pub fn level_latency(&self, d: usize, k: usize) -> f64 {
        if k >= 2 && d == k - 1 {
            self.intra_node_latency
        } else if d == 0 && self.racks > 1 {
            self.inter_rack_latency
        } else {
            self.inter_node_latency
        }
    }
}

/// One cell of the factorial design (Table 4): application × technique ×
/// approach × injected delay.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Loop + technique parameters.
    pub loop_params: LoopParams,
    /// Scheduling technique under test.
    pub technique: TechniqueKind,
    /// CCA / DCA / DCA-RMA.
    pub model: ExecutionModel,
    /// Injected slowdown, seconds (paper: 0, 10e-6, 100e-6).
    pub injected_delay: f64,
    /// Where the delay is injected.
    pub delay_site: DelaySite,
    /// Cluster geometry.
    pub cluster: ClusterConfig,
    /// Experiment repetitions (paper: 20).
    pub repetitions: u32,
    /// Base RNG seed; repetition `r` uses `seed + r`.
    pub seed: u64,
}

impl ExperimentConfig {
    /// Paper-style experiment over `n` iterations on the miniHPC geometry.
    pub fn paper_default(
        n: u64,
        technique: TechniqueKind,
        model: ExecutionModel,
        injected_delay: f64,
    ) -> Self {
        let cluster = ClusterConfig::minihpc();
        ExperimentConfig {
            loop_params: LoopParams::new(n, cluster.total_ranks()),
            technique,
            model,
            injected_delay,
            delay_site: DelaySite::Calculation,
            cluster,
            repetitions: 20,
            seed: 0xD15_C0DE,
        }
    }

    /// The paper's three slowdown scenarios, in seconds.
    pub const DELAYS: [f64; 3] = [0.0, 10e-6, 100e-6];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minihpc_geometry() {
        let c = ClusterConfig::minihpc();
        assert_eq!(c.total_ranks(), 256);
    }

    #[test]
    fn model_parse() {
        assert_eq!(ExecutionModel::parse("cca"), Some(ExecutionModel::Cca));
        assert_eq!(ExecutionModel::parse("DCA"), Some(ExecutionModel::Dca));
        assert_eq!(ExecutionModel::parse("dca-rma"), Some(ExecutionModel::DcaRma));
        assert_eq!(ExecutionModel::parse("???"), None);
    }

    #[test]
    fn hier_parse_aliases() {
        for alias in ["HIER", "HIERDCA", "HIER-DCA", "hier", "hierdca", "hier-dca"] {
            assert_eq!(
                ExecutionModel::parse(alias),
                Some(ExecutionModel::HierDca),
                "alias {alias}"
            );
        }
    }

    /// Property: `name()` round-trips through `parse()` for every variant,
    /// under arbitrary per-character case flips (seeded SplitMix64 — no
    /// external proptest crate in this build environment).
    #[test]
    fn model_name_parse_roundtrip_property() {
        use crate::techniques::rnd::splitmix64;
        assert_eq!(ExecutionModel::ALL.len(), 4);
        for model in ExecutionModel::ALL {
            assert_eq!(ExecutionModel::parse(model.name()), Some(model));
            let mut s = 0x0515_CADE ^ model.name().len() as u64;
            for _case in 0..64 {
                let mangled: String = model
                    .name()
                    .chars()
                    .map(|c| {
                        s = splitmix64(s);
                        if s & 1 == 0 {
                            c.to_ascii_lowercase()
                        } else {
                            c.to_ascii_uppercase()
                        }
                    })
                    .collect();
                assert_eq!(
                    ExecutionModel::parse(&mangled),
                    Some(model),
                    "mangled '{mangled}' must parse back to {model}"
                );
            }
        }
    }

    #[test]
    fn hier_params_inner_resolution() {
        let same = HierParams::default();
        assert_eq!(same.inner_or(TechniqueKind::Gss), TechniqueKind::Gss);
        assert_eq!(same.watermark, WatermarkMode::Off, "prefetch is opt-in");
        assert_eq!(same.depth(), 2, "classic two-level by default");
        assert_eq!(same.staged_capacity(), 1, "single staged slot by default");
        let mixed = HierParams::with_inner(TechniqueKind::Ss);
        assert_eq!(mixed.inner_or(TechniqueKind::Gss), TechniqueKind::Ss);
        let prefetching = mixed.with_watermark(64);
        assert_eq!(prefetching.inner, Some(TechniqueKind::Ss));
        assert_eq!(prefetching.watermark, WatermarkMode::Fixed(64));
        assert_eq!(prefetching.with_auto_watermark().watermark, WatermarkMode::Auto);
        assert_eq!(prefetching.with_prefetch_depth(3).staged_capacity(), 3);
    }

    #[test]
    fn level_techs_resolve_outer_mid_inner() {
        let h = HierParams::with_inner(TechniqueKind::Ss)
            .with_levels(3)
            .with_mid(1, TechniqueKind::Gss);
        assert_eq!(h.tech_of_level(0, TechniqueKind::Fac2), TechniqueKind::Fac2);
        assert_eq!(h.tech_of_level(1, TechniqueKind::Fac2), TechniqueKind::Gss);
        assert_eq!(h.tech_of_level(2, TechniqueKind::Fac2), TechniqueKind::Ss);
        // Unset mids inherit the outer technique.
        let plain = HierParams::default().with_levels(4);
        assert_eq!(plain.tech_of_level(2, TechniqueKind::Tss), TechniqueKind::Tss);
    }

    #[test]
    fn plan_depth2_matches_cluster_geometry() {
        let cluster = ClusterConfig { nodes: 4, ranks_per_node: 8, ..ClusterConfig::minihpc() };
        let plan = HierParams::with_inner(TechniqueKind::Ss)
            .plan(TechniqueKind::Fac2, 32, &cluster)
            .unwrap();
        assert_eq!(plan.depth(), 2);
        assert_eq!(plan.levels[0].fanout, 4);
        assert_eq!(plan.levels[1].fanout, 8);
        assert_eq!(plan.levels[0].technique, TechniqueKind::Fac2);
        assert_eq!(plan.levels[1].technique, TechniqueKind::Ss);
        assert_eq!(plan.levels[0].latency, cluster.inter_node_latency);
        assert_eq!(plan.levels[1].latency, cluster.intra_node_latency);
        assert_eq!(plan.subtree_ranks(0), 32);
        assert_eq!(plan.subtree_ranks(1), 8);
        assert_eq!(plan.masters_at(1), 4);
        assert_eq!(plan.host_rank(1, 3), 24);
        assert_eq!(plan.host_rank(0, 0), 0);
    }

    #[test]
    fn plan_depth3_uses_rack_latency_and_derives_last_fanout() {
        let cluster = ClusterConfig {
            nodes: 8,
            ranks_per_node: 4,
            racks: 2,
            ..ClusterConfig::minihpc()
        };
        let plan = HierParams::default()
            .with_levels(3)
            .with_fanouts(&[2, 4])
            .plan(TechniqueKind::Gss, 32, &cluster)
            .unwrap();
        assert_eq!(plan.depth(), 3);
        assert_eq!(
            plan.levels.iter().map(|l| l.fanout).collect::<Vec<_>>(),
            vec![2, 4, 4],
            "trailing fan-out derived from the rank count"
        );
        assert_eq!(plan.levels[0].latency, cluster.inter_rack_latency);
        assert_eq!(plan.levels[1].latency, cluster.inter_node_latency);
        assert_eq!(plan.levels[2].latency, cluster.intra_node_latency);
        assert_eq!(plan.masters_at(2), 8);
        assert_eq!(plan.host_rank(2, 5), 20);
        assert_eq!(plan.host_rank(1, 1), 16);
    }

    #[test]
    fn plan_rejects_bad_fanouts() {
        let cluster = ClusterConfig { nodes: 4, ranks_per_node: 4, ..ClusterConfig::minihpc() };
        // Product ≠ rank count.
        assert!(HierParams::default()
            .with_levels(3)
            .with_fanouts(&[3, 3, 3])
            .plan(TechniqueKind::Gss, 16, &cluster)
            .is_err());
        // Non-dividing prefix.
        assert!(HierParams::default()
            .with_levels(3)
            .with_fanouts(&[3, 2])
            .plan(TechniqueKind::Gss, 16, &cluster)
            .is_err());
        // Depth 3 with no fan-outs at all cannot be derived.
        assert!(HierParams::default()
            .with_levels(3)
            .plan(TechniqueKind::Gss, 16, &cluster)
            .is_err());
        // Depth 1 degenerates to one flat level over all ranks.
        let flat = HierParams::default()
            .with_levels(1)
            .plan(TechniqueKind::Gss, 16, &cluster)
            .unwrap();
        assert_eq!(flat.levels.len(), 1);
        assert_eq!(flat.levels[0].fanout, 16);
    }

    #[test]
    fn model_labels_derive_from_level_count() {
        assert_eq!(ExecutionModel::HierDca.label(2), "HIER-DCA");
        assert_eq!(ExecutionModel::HierDca.label(3), "HIER-DCA(3)");
        assert_eq!(ExecutionModel::HierDca.label(1), "HIER-DCA(1)");
        assert_eq!(ExecutionModel::Cca.label(3), "CCA");
        // Depth-annotated labels parse back to the model.
        assert_eq!(ExecutionModel::parse("HIER-DCA(3)"), Some(ExecutionModel::HierDca));
        assert_eq!(ExecutionModel::parse("hier-dca(4)"), Some(ExecutionModel::HierDca));
        assert_eq!(ExecutionModel::parse("HIER-DCA(x)"), None);
    }

    #[test]
    fn level_latency_triple() {
        let one_rack = ClusterConfig::minihpc();
        assert_eq!(one_rack.level_latency(0, 2), one_rack.inter_node_latency);
        assert_eq!(one_rack.level_latency(1, 2), one_rack.intra_node_latency);
        let racked = ClusterConfig { racks: 4, ..ClusterConfig::minihpc() };
        assert_eq!(racked.level_latency(0, 3), racked.inter_rack_latency);
        assert_eq!(racked.level_latency(1, 3), racked.inter_node_latency);
        assert_eq!(racked.level_latency(2, 3), racked.intra_node_latency);
    }

    #[test]
    fn paper_default_wires_geometry_into_loop_params() {
        let c = ExperimentConfig::paper_default(
            262_144,
            TechniqueKind::Gss,
            ExecutionModel::Dca,
            10e-6,
        );
        assert_eq!(c.loop_params.p, 256);
        assert_eq!(c.repetitions, 20);
        assert_eq!(c.technique, TechniqueKind::Gss);
        assert_eq!(c.model, ExecutionModel::Dca);
        assert_eq!(c.loop_params.n, 262_144);
    }

    #[test]
    fn paper_delays() {
        assert_eq!(ExperimentConfig::DELAYS, [0.0, 10e-6, 100e-6]);
    }

    #[test]
    fn sched_path_parse_roundtrip() {
        assert_eq!(SchedPath::default(), SchedPath::TwoPhase, "baselines stay two-phase");
        for p in [SchedPath::TwoPhase, SchedPath::LockFree, SchedPath::Auto] {
            assert_eq!(SchedPath::parse(p.name()), Some(p));
        }
        assert_eq!(SchedPath::parse("CAS"), Some(SchedPath::LockFree));
        assert_eq!(SchedPath::parse("lock-free"), Some(SchedPath::LockFree));
        assert_eq!(SchedPath::parse("AUTO"), Some(SchedPath::Auto));
        assert_eq!(SchedPath::parse("???"), None);
        assert!(!SchedPath::TwoPhase.wants_lockfree());
        assert!(SchedPath::LockFree.wants_lockfree());
        assert!(SchedPath::Auto.wants_lockfree());
    }

    #[test]
    fn adaptive_params_defaults_and_builders() {
        let off = HierParams::default();
        assert!(!off.adaptive.enabled, "adaptive is opt-in: baselines stay static");
        let on = HierParams::default().with_adaptive();
        assert!(on.adaptive.enabled);
        assert_eq!(on.adaptive.probe_interval(), AdaptiveParams::DEFAULT_PROBE_INTERVAL);
        assert_eq!(on.adaptive.candidates(), CandidateSet::default_probe());
        let tuned = on
            .with_probe_interval(8)
            .with_candidates(CandidateSet::parse("ss,gss").unwrap());
        assert_eq!(tuned.adaptive.probe_interval(), 8);
        assert_eq!(tuned.adaptive.candidates().len(), 2);
        // The knobs compose with the rest of HierParams without clobbering.
        let combined = HierParams::with_inner(TechniqueKind::Ss).with_adaptive().with_levels(3);
        assert!(combined.adaptive.enabled);
        assert_eq!(combined.inner, Some(TechniqueKind::Ss));
        assert_eq!(combined.depth(), 3);
    }

    #[test]
    fn adaptive_labels_render_and_parse() {
        assert_eq!(ExecutionModel::HierDca.label_adaptive(2, true), "HIER-DCA+ADAPT");
        assert_eq!(ExecutionModel::HierDca.label_adaptive(3, true), "HIER-DCA(3)+ADAPT");
        assert_eq!(ExecutionModel::Dca.label_adaptive(1, true), "DCA+ADAPT");
        assert_eq!(ExecutionModel::HierDca.label_adaptive(2, false), "HIER-DCA");
        assert_eq!(
            ExecutionModel::parse("HIER-DCA(3)+ADAPT"),
            Some(ExecutionModel::HierDca)
        );
        assert_eq!(ExecutionModel::parse("dca+adapt"), Some(ExecutionModel::Dca));
        assert_eq!(ExecutionModel::parse("+ADAPT"), None);
    }
}
