//! Experiment configuration — the encoded form of the paper's Table 4
//! factorial design, serializable to/from JSON for the CLI and benches.

use crate::techniques::{LoopParams, TechniqueKind};


/// Which chunk-calculation approach drives the run (the paper's central
/// comparison, extended with the hierarchical follow-up of arXiv 1903.09510).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecutionModel {
    /// Centralized: master performs calculation **and** assignment (§3).
    Cca,
    /// Distributed over two-sided messages: coordinator assigns, workers
    /// calculate (§4–5, this paper's contribution).
    Dca,
    /// Distributed over the one-sided RMA window (the PDP'19 predecessor).
    DcaRma,
    /// Two-level hierarchical DCA (§7 future work / arXiv 1903.09510): a
    /// global coordinator hands *node-chunks* to per-node masters over the
    /// inter-node fabric; each master re-subdivides its node-chunk among its
    /// local ranks with an (optionally different) inner technique over the
    /// intra-node fabric. See [`crate::hier`].
    HierDca,
}

impl ExecutionModel {
    /// All execution models, in comparison order.
    pub const ALL: [ExecutionModel; 4] = [
        ExecutionModel::Cca,
        ExecutionModel::Dca,
        ExecutionModel::DcaRma,
        ExecutionModel::HierDca,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ExecutionModel::Cca => "CCA",
            ExecutionModel::Dca => "DCA",
            ExecutionModel::DcaRma => "DCA-RMA",
            ExecutionModel::HierDca => "HIER-DCA",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "CCA" => Some(ExecutionModel::Cca),
            "DCA" => Some(ExecutionModel::Dca),
            "DCA-RMA" | "DCARMA" | "RMA" => Some(ExecutionModel::DcaRma),
            "HIER-DCA" | "HIERDCA" | "HIER" => Some(ExecutionModel::HierDca),
            _ => None,
        }
    }
}

impl std::fmt::Display for ExecutionModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Where the injected slowdown lands (§6 injects it into the chunk
/// *calculation*; §7 flags the *assignment* variant as future work — we
/// implement both, see DESIGN.md experiment A2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DelaySite {
    /// Delay the chunk-calculation function (paper's §6 scenarios).
    Calculation,
    /// Delay the chunk-assignment critical section (paper's §7 prediction:
    /// this should favour CCA, which sends fewer messages).
    Assignment,
}

/// Parameters of the hierarchical two-level model ([`ExecutionModel::HierDca`]).
///
/// The *outer* technique (which sizes node-chunks at the global coordinator
/// level) is the experiment's main `technique`; this struct only adds what
/// the flat models don't have: the *inner* technique each node master uses
/// to re-subdivide its node-chunk among its local ranks, and the outer-level
/// prefetch watermark. The node geometry (`nodes` × `ranks_per_node`) comes
/// from [`ClusterConfig`] (DES) or the engine config (threaded).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HierParams {
    /// Intra-node (inner) technique; `None` ⇒ reuse the outer technique.
    pub inner: Option<TechniqueKind>,
    /// Outer-level prefetch: a node master requests its *next* node-chunk
    /// once the current one has ≤ this many unassigned iterations left,
    /// hiding the inter-node round trip plus the outer chunk calculation
    /// behind the tail of the current chunk. `None` ⇒ fetch on exhaustion
    /// (the original arXiv 1903.09510 behavior).
    pub prefetch_watermark: Option<u64>,
}

impl HierParams {
    /// Use `inner` within nodes, regardless of the outer technique.
    pub fn with_inner(inner: TechniqueKind) -> Self {
        HierParams { inner: Some(inner), ..Self::default() }
    }

    /// Enable outer-level prefetch at the given watermark (in iterations).
    pub fn with_watermark(self, watermark: u64) -> Self {
        HierParams { prefetch_watermark: Some(watermark), ..self }
    }

    /// Resolve the inner technique given the experiment's outer technique.
    pub fn inner_or(&self, outer: TechniqueKind) -> TechniqueKind {
        self.inner.unwrap_or(outer)
    }
}

/// Simulated cluster geometry and communication costs (miniHPC stand-in).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of physical nodes (paper: 16).
    pub nodes: u32,
    /// MPI ranks per node (paper: 16 ⇒ 256 total).
    pub ranks_per_node: u32,
    /// One-way message latency within a node, seconds.
    pub intra_node_latency: f64,
    /// One-way message latency across nodes, seconds.
    pub inter_node_latency: f64,
    /// Master/coordinator service time to handle one message, seconds
    /// (dequeue + match + reply build; excludes chunk calculation).
    pub service_time: f64,
    /// Cost of evaluating one chunk-size formula, seconds (excludes the
    /// injected delay).
    pub calc_time: f64,
    /// `breakAfter` — iterations the non-dedicated master/coordinator (rank
    /// 0) executes between servicing rounds (the LB-tool parameter, §3).
    /// `0` = dedicated master. The optimal value is application-dependent:
    /// with long iterations (PSIA: 73 ms) anything above 1 starves the
    /// request queue for seconds at a time (see the A3 ablation).
    pub break_after: u32,
}

impl ClusterConfig {
    /// The paper's miniHPC testbed: 16 dual-socket Xeon nodes × 16 ranks.
    pub fn minihpc() -> Self {
        ClusterConfig {
            nodes: 16,
            ranks_per_node: 16,
            intra_node_latency: 0.5e-6,
            inter_node_latency: 2.0e-6,
            service_time: 0.5e-6,
            calc_time: 0.2e-6,
            break_after: 1,
        }
    }

    /// A small geometry for unit tests and laptop runs.
    pub fn small(ranks: u32) -> Self {
        ClusterConfig {
            nodes: 1,
            ranks_per_node: ranks,
            ..Self::minihpc()
        }
    }

    pub fn total_ranks(&self) -> u32 {
        self.nodes * self.ranks_per_node
    }
}

/// One cell of the factorial design (Table 4): application × technique ×
/// approach × injected delay.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Loop + technique parameters.
    pub loop_params: LoopParams,
    /// Scheduling technique under test.
    pub technique: TechniqueKind,
    /// CCA / DCA / DCA-RMA.
    pub model: ExecutionModel,
    /// Injected slowdown, seconds (paper: 0, 10e-6, 100e-6).
    pub injected_delay: f64,
    /// Where the delay is injected.
    pub delay_site: DelaySite,
    /// Cluster geometry.
    pub cluster: ClusterConfig,
    /// Experiment repetitions (paper: 20).
    pub repetitions: u32,
    /// Base RNG seed; repetition `r` uses `seed + r`.
    pub seed: u64,
}

impl ExperimentConfig {
    /// Paper-style experiment over `n` iterations on the miniHPC geometry.
    pub fn paper_default(
        n: u64,
        technique: TechniqueKind,
        model: ExecutionModel,
        injected_delay: f64,
    ) -> Self {
        let cluster = ClusterConfig::minihpc();
        ExperimentConfig {
            loop_params: LoopParams::new(n, cluster.total_ranks()),
            technique,
            model,
            injected_delay,
            delay_site: DelaySite::Calculation,
            cluster,
            repetitions: 20,
            seed: 0xD15_C0DE,
        }
    }

    /// The paper's three slowdown scenarios, in seconds.
    pub const DELAYS: [f64; 3] = [0.0, 10e-6, 100e-6];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minihpc_geometry() {
        let c = ClusterConfig::minihpc();
        assert_eq!(c.total_ranks(), 256);
    }

    #[test]
    fn model_parse() {
        assert_eq!(ExecutionModel::parse("cca"), Some(ExecutionModel::Cca));
        assert_eq!(ExecutionModel::parse("DCA"), Some(ExecutionModel::Dca));
        assert_eq!(ExecutionModel::parse("dca-rma"), Some(ExecutionModel::DcaRma));
        assert_eq!(ExecutionModel::parse("???"), None);
    }

    #[test]
    fn hier_parse_aliases() {
        for alias in ["HIER", "HIERDCA", "HIER-DCA", "hier", "hierdca", "hier-dca"] {
            assert_eq!(
                ExecutionModel::parse(alias),
                Some(ExecutionModel::HierDca),
                "alias {alias}"
            );
        }
    }

    /// Property: `name()` round-trips through `parse()` for every variant,
    /// under arbitrary per-character case flips (seeded SplitMix64 — no
    /// external proptest crate in this build environment).
    #[test]
    fn model_name_parse_roundtrip_property() {
        use crate::techniques::rnd::splitmix64;
        assert_eq!(ExecutionModel::ALL.len(), 4);
        for model in ExecutionModel::ALL {
            assert_eq!(ExecutionModel::parse(model.name()), Some(model));
            let mut s = 0x0515_CADE ^ model.name().len() as u64;
            for _case in 0..64 {
                let mangled: String = model
                    .name()
                    .chars()
                    .map(|c| {
                        s = splitmix64(s);
                        if s & 1 == 0 {
                            c.to_ascii_lowercase()
                        } else {
                            c.to_ascii_uppercase()
                        }
                    })
                    .collect();
                assert_eq!(
                    ExecutionModel::parse(&mangled),
                    Some(model),
                    "mangled '{mangled}' must parse back to {model}"
                );
            }
        }
    }

    #[test]
    fn hier_params_inner_resolution() {
        let same = HierParams::default();
        assert_eq!(same.inner_or(TechniqueKind::Gss), TechniqueKind::Gss);
        assert_eq!(same.prefetch_watermark, None, "prefetch is opt-in");
        let mixed = HierParams::with_inner(TechniqueKind::Ss);
        assert_eq!(mixed.inner_or(TechniqueKind::Gss), TechniqueKind::Ss);
        let prefetching = mixed.with_watermark(64);
        assert_eq!(prefetching.inner, Some(TechniqueKind::Ss));
        assert_eq!(prefetching.prefetch_watermark, Some(64));
    }

    #[test]
    fn paper_default_wires_geometry_into_loop_params() {
        let c = ExperimentConfig::paper_default(
            262_144,
            TechniqueKind::Gss,
            ExecutionModel::Dca,
            10e-6,
        );
        assert_eq!(c.loop_params.p, 256);
        assert_eq!(c.repetitions, 20);
        assert_eq!(c.technique, TechniqueKind::Gss);
        assert_eq!(c.model, ExecutionModel::Dca);
        assert_eq!(c.loop_params.n, 262_144);
    }

    #[test]
    fn paper_delays() {
        assert_eq!(ExperimentConfig::DELAYS, [0.0, 10e-6, 100e-6]);
    }
}
