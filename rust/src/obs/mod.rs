//! Streaming observability: a lock-cheap metrics registry for the threaded
//! engines plus the NDJSON stream-record vocabulary sampled by the DES
//! loops at virtual-time ticks.
//!
//! Two exposure surfaces, both **normatively documented** in
//! `docs/metrics-schema.md` (the doc-sync test `tests/docs_schema.rs` fails
//! the build when either side drifts):
//!
//! * [`MetricsRegistry::render_prometheus`] — the Prometheus text
//!   exposition format, served offline by `dca-dls metrics-dump` (no
//!   network listener; production deployments shell out or mount the
//!   one-shot into a textfile collector).
//! * [`stream`] — one self-describing JSON record per virtual-time
//!   interval (`--stream-metrics <path|->`): per-subtree grant rates,
//!   µ̂/σ̂/ô EWMAs, queue depths, switch/rebind events, per-tenant state.
//!
//! The registry is built for the grant path: counters are single relaxed
//! atomic adds, gauges one atomic store, histograms one relaxed add into a
//! fixed log-bucketed array plus a CAS-loop float sum — no locks anywhere
//! after registration (registration itself takes the registry mutex once
//! per engine start and is idempotent, so every thread can re-register and
//! receive the same handles).

pub mod stream;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonically increasing event count (Prometheus `counter`).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (Prometheus `gauge`), stored as
/// `f64` bits in one atomic word.
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, x: f64) {
        self.bits.store(x.to_bits(), Ordering::Relaxed);
    }

    /// Atomic increment (CAS loop — gauges move rarely compared to the
    /// counter hot path).
    pub fn add(&self, dx: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + dx).to_bits();
            match self
                .bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log-bucket count of a [`Histogram`]: bucket `i` covers
/// `(base·2^(i−1), base·2^i]`, so the buckets span `base … base·2^(B−1)`
/// with one `+Inf` overflow bucket — fixed at registration, never resized.
pub const HISTOGRAM_BUCKETS: usize = 24;

/// Fixed log-bucketed histogram (Prometheus `histogram`): observation cost
/// is one relaxed atomic add into the bucket array plus a CAS-loop float
/// sum — no locks, no allocation.
#[derive(Debug)]
pub struct Histogram {
    /// Upper bound of the first bucket; each subsequent bound doubles.
    base: f64,
    /// `HISTOGRAM_BUCKETS` finite buckets + the `+Inf` overflow bucket.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Histogram {
    fn new(base: f64) -> Self {
        Histogram {
            base: if base > 0.0 { base } else { 1.0 },
            buckets: (0..=HISTOGRAM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Upper bound of finite bucket `i`.
    fn bound(&self, i: usize) -> f64 {
        self.base * (1u64 << i) as f64
    }

    pub fn observe(&self, x: f64) {
        let mut idx = HISTOGRAM_BUCKETS; // +Inf overflow
        for i in 0..HISTOGRAM_BUCKETS {
            if x <= self.bound(i) {
                idx = i;
                break;
            }
        }
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + x).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> f64 {
        match self.count() {
            0 => 0.0,
            n => self.sum() / n as f64,
        }
    }
}

#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn type_name(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

#[derive(Debug)]
struct Entry {
    name: String,
    help: String,
    metric: Metric,
}

/// The process-wide (or run-scoped) metric registry. Registration is
/// idempotent by name — every engine thread can call the `register_*`
/// helpers with the same name and receive clones of one shared handle —
/// and takes the only lock in the subsystem; reads and updates afterwards
/// are lock-free.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    entries: Mutex<Vec<Entry>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    fn register(&self, name: &str, help: &str, make: impl FnOnce() -> Metric) -> Metric {
        debug_assert!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
                && name.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_'),
            "invalid metric name {name:?}"
        );
        let mut entries = self.entries.lock().expect("metrics registry lock");
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            return e.metric.clone();
        }
        let metric = make();
        entries.push(Entry { name: name.to_string(), help: help.to_string(), metric: metric.clone() });
        metric
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        match self.register(name, help, || Metric::Counter(Arc::new(Counter::default()))) {
            Metric::Counter(c) => c,
            m => panic!("metric {name:?} already registered as a {}", m.type_name()),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        match self.register(name, help, || Metric::Gauge(Arc::new(Gauge::default()))) {
            Metric::Gauge(g) => g,
            m => panic!("metric {name:?} already registered as a {}", m.type_name()),
        }
    }

    /// Register a log-bucketed histogram whose first bucket tops out at
    /// `base` (each of the [`HISTOGRAM_BUCKETS`] bounds doubles the last).
    pub fn histogram(&self, name: &str, help: &str, base: f64) -> Arc<Histogram> {
        match self.register(name, help, || Metric::Histogram(Arc::new(Histogram::new(base)))) {
            Metric::Histogram(h) => h,
            m => panic!("metric {name:?} already registered as a {}", m.type_name()),
        }
    }

    /// Render every registered metric in the Prometheus text exposition
    /// format (`# HELP` / `# TYPE` / samples), sorted by metric name so the
    /// dump is deterministic.
    pub fn render_prometheus(&self) -> String {
        let entries = self.entries.lock().expect("metrics registry lock");
        let mut sorted: Vec<&Entry> = entries.iter().collect();
        sorted.sort_by(|a, b| a.name.cmp(&b.name));
        let mut out = String::new();
        for e in sorted {
            out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
            out.push_str(&format!("# TYPE {} {}\n", e.name, e.metric.type_name()));
            match &e.metric {
                Metric::Counter(c) => out.push_str(&format!("{} {}\n", e.name, c.get())),
                Metric::Gauge(g) => out.push_str(&format!("{} {}\n", e.name, g.get())),
                Metric::Histogram(h) => {
                    let mut cum = 0u64;
                    for i in 0..HISTOGRAM_BUCKETS {
                        cum += h.buckets[i].load(Ordering::Relaxed);
                        out.push_str(&format!(
                            "{}_bucket{{le=\"{}\"}} {}\n",
                            e.name,
                            h.bound(i),
                            cum
                        ));
                    }
                    cum += h.buckets[HISTOGRAM_BUCKETS].load(Ordering::Relaxed);
                    out.push_str(&format!("{}_bucket{{le=\"+Inf\"}} {}\n", e.name, cum));
                    out.push_str(&format!("{}_sum {}\n", e.name, h.sum()));
                    out.push_str(&format!("{}_count {}\n", e.name, h.count()));
                }
            }
        }
        out
    }
}

/// The grant-path handle bundle every threaded engine updates — registered
/// idempotently, so each worker/coordinator thread re-registers and shares
/// the same underlying atomics. Names and semantics are normative in
/// `docs/metrics-schema.md`.
#[derive(Debug, Clone)]
pub struct EngineMetrics {
    /// `dcadls_sched_grants_total` — chunks granted (both protocols).
    pub grants: Arc<Counter>,
    /// `dcadls_sched_fast_grants_total` — CAS fast-path grants.
    pub fast_grants: Arc<Counter>,
    /// `dcadls_sched_messages_total` — scheduling-protocol messages.
    pub messages: Arc<Counter>,
    /// `dcadls_sched_iters_total` — iterations granted.
    pub iters: Arc<Counter>,
    /// `dcadls_sched_switches_total` — adaptive technique rebinds.
    pub switches: Arc<Counter>,
    /// `dcadls_sched_chunk_iters` — granted chunk sizes, iterations.
    pub chunk_iters: Arc<Histogram>,
    /// `dcadls_sched_grant_wait_seconds` — per-grant scheduling wait.
    pub grant_wait: Arc<Histogram>,
    /// `dcadls_pdes_rounds_total` — PDES horizon rounds completed.
    pub pdes_rounds: Arc<Counter>,
    /// `dcadls_pdes_horizon_stalls_total` — rounds that advanced no event.
    pub pdes_horizon_stalls: Arc<Counter>,
    /// `dcadls_pdes_mailbox_depth` — high-water cross-shard mailbox depth.
    pub pdes_mailbox_depth: Arc<Gauge>,
    /// `dcadls_pdes_rollbacks_total` — optimistic-window rollbacks replayed.
    pub pdes_rollbacks: Arc<Counter>,
    /// `dcadls_pdes_speculated_events_total` — events executed past the
    /// safe horizon (including any replayed after a rollback).
    pub pdes_speculated_events: Arc<Counter>,
    /// `dcadls_pdes_window_ns` — optimistic window bound of the last run.
    pub pdes_window_ns: Arc<Gauge>,
    /// `dcadls_pdes_checkpoint_bytes` — incremental-checkpoint journal
    /// bytes retired (committed or replayed).
    pub pdes_checkpoint_bytes: Arc<Counter>,
    /// `dcadls_pdes_window_multiple` — deepest realized speculation
    /// window of the last run, in lookahead multiples.
    pub pdes_window_multiple: Arc<Gauge>,
    /// `dcadls_pdes_arbiter_epochs_total` — demand-summary exchanges of
    /// sharded multi-tenant session loops.
    pub pdes_arbiter_epochs: Arc<Counter>,
}

impl EngineMetrics {
    pub fn register(r: &MetricsRegistry) -> Self {
        EngineMetrics {
            grants: r.counter(
                "dcadls_sched_grants_total",
                "Chunks granted by the scheduling protocol (both grant paths).",
            ),
            fast_grants: r.counter(
                "dcadls_sched_fast_grants_total",
                "Chunks granted through the lock-free CAS fast path.",
            ),
            messages: r.counter(
                "dcadls_sched_messages_total",
                "Scheduling-protocol messages exchanged (two-phase grants cost 4).",
            ),
            iters: r.counter(
                "dcadls_sched_iters_total",
                "Loop iterations granted to workers.",
            ),
            switches: r.counter(
                "dcadls_sched_switches_total",
                "Adaptive technique-slot rebinds decided by controllers.",
            ),
            chunk_iters: r.histogram(
                "dcadls_sched_chunk_iters",
                "Granted chunk sizes, in iterations (log buckets from 1).",
                1.0,
            ),
            grant_wait: r.histogram(
                "dcadls_sched_grant_wait_seconds",
                "Wall-clock wait per scheduling grant, seconds (log buckets from 100ns).",
                1e-7,
            ),
            pdes_rounds: r.counter(
                "dcadls_pdes_rounds_total",
                "PDES horizon rounds completed by the sharded event loop.",
            ),
            pdes_horizon_stalls: r.counter(
                "dcadls_pdes_horizon_stalls_total",
                "Shard-rounds that reached the barrier without executing any event.",
            ),
            pdes_mailbox_depth: r.gauge(
                "dcadls_pdes_mailbox_depth",
                "High-water depth of any cross-shard SPSC mailbox, messages.",
            ),
            pdes_rollbacks: r.counter(
                "dcadls_pdes_rollbacks_total",
                "Optimistic-window rollbacks (checkpoint restores + replays).",
            ),
            pdes_speculated_events: r.counter(
                "dcadls_pdes_speculated_events_total",
                "Events executed past the safe horizon by the hybrid mode.",
            ),
            pdes_window_ns: r.gauge(
                "dcadls_pdes_window_ns",
                "Optimistic window bound of the most recent sharded run, ns \
(0 = conservative).",
            ),
            pdes_checkpoint_bytes: r.counter(
                "dcadls_pdes_checkpoint_bytes",
                "Incremental-checkpoint journal bytes retired by speculating \
shards (committed or replayed); full-clone fallbacks contribute 0.",
            ),
            pdes_window_multiple: r.gauge(
                "dcadls_pdes_window_multiple",
                "Deepest realized speculation window of the most recent \
sharded run, in lookahead multiples (0 = never speculated).",
            ),
            pdes_arbiter_epochs: r.counter(
                "dcadls_pdes_arbiter_epochs_total",
                "Demand-summary barrier exchanges performed by sharded \
multi-tenant session loops.",
            ),
        }
    }

    /// Account one granted chunk of `iters` iterations obtained after
    /// `wait_s` seconds of scheduling wait (`fast` = CAS fast path; a
    /// two-phase grant also pays its 4 protocol messages).
    pub fn on_grant(&self, iters: u64, wait_s: f64, fast: bool) {
        self.grants.inc();
        self.iters.add(iters);
        self.chunk_iters.observe(iters as f64);
        self.grant_wait.observe(wait_s);
        if fast {
            self.fast_grants.inc();
        } else {
            self.messages.add(4);
        }
    }

    /// Fold one finished PDES run (`DesResult::pdes`) into the registry:
    /// rounds, stalls, rollbacks, and speculated events accumulate across
    /// runs; the mailbox gauge keeps the high-water mark seen by any run;
    /// the window gauge tracks the most recent run's bound.
    pub fn on_pdes(&self, p: &crate::des::PdesSummary) {
        self.pdes_rounds.add(p.rounds);
        self.pdes_horizon_stalls.add(p.horizon_stalls);
        if p.mailbox_depth_max as f64 > self.pdes_mailbox_depth.get() {
            self.pdes_mailbox_depth.set(p.mailbox_depth_max as f64);
        }
        self.pdes_rollbacks.add(p.rollbacks);
        self.pdes_speculated_events.add(p.speculated_events);
        self.pdes_window_ns.set(p.window_ns as f64);
        self.pdes_checkpoint_bytes.add(p.checkpoint_bytes);
        self.pdes_window_multiple.set(p.window_multiple as f64);
        self.pdes_arbiter_epochs.add(p.arbiter_epochs);
    }
}

/// Multi-tenant session gauges/counters updated by
/// [`crate::tenant::scheduler::Scheduler`].
#[derive(Debug, Clone)]
pub struct SessionMetrics {
    /// `dcadls_tenants_active` — tenants admitted and not yet terminal.
    pub active: Arc<Gauge>,
    /// `dcadls_tenants_admitted_total` — tenants ever admitted.
    pub admitted: Arc<Counter>,
}

impl SessionMetrics {
    pub fn register(r: &MetricsRegistry) -> Self {
        SessionMetrics {
            active: r.gauge(
                "dcadls_tenants_active",
                "Tenants currently admitted and not yet Completed/Evicted.",
            ),
            admitted: r.counter(
                "dcadls_tenants_admitted_total",
                "Tenants admitted to the session scheduler since start.",
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = MetricsRegistry::new();
        let c = r.counter("t_total", "help");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = r.gauge("t_gauge", "help");
        g.set(2.5);
        g.add(-1.0);
        assert!((g.get() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn registration_is_idempotent_and_shares_state() {
        let r = MetricsRegistry::new();
        let a = r.counter("shared_total", "help");
        let b = r.counter("shared_total", "help");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "both handles hit one atomic");
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        let r = MetricsRegistry::new();
        r.counter("m", "help");
        r.gauge("m", "help");
    }

    #[test]
    fn histogram_log_buckets() {
        let h = Histogram::new(1.0);
        for x in [0.5, 1.0, 3.0, 100.0, 1e9] {
            h.observe(x);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - (0.5 + 1.0 + 3.0 + 100.0 + 1e9)).abs() < 1.0);
        // 0.5 and 1.0 land in bucket 0 (≤ 1); 3.0 in bucket 2 (≤ 4);
        // 100.0 in bucket 7 (≤ 128); 1e9 overflows to +Inf.
        assert_eq!(h.buckets[0].load(Ordering::Relaxed), 2);
        assert_eq!(h.buckets[2].load(Ordering::Relaxed), 1);
        assert_eq!(h.buckets[7].load(Ordering::Relaxed), 1);
        assert_eq!(h.buckets[HISTOGRAM_BUCKETS].load(Ordering::Relaxed), 1);
    }

    #[test]
    fn prometheus_render_shape() {
        let r = MetricsRegistry::new();
        let m = EngineMetrics::register(&r);
        m.on_grant(128, 2e-6, false);
        m.on_grant(64, 1e-6, true);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE dcadls_sched_grants_total counter"));
        assert!(text.contains("dcadls_sched_grants_total 2"));
        assert!(text.contains("dcadls_sched_fast_grants_total 1"));
        assert!(text.contains("dcadls_sched_messages_total 4"));
        assert!(text.contains("dcadls_sched_iters_total 192"));
        assert!(text.contains("# TYPE dcadls_sched_chunk_iters histogram"));
        assert!(text.contains("dcadls_sched_chunk_iters_count 2"));
        assert!(text.contains("_bucket{le=\"+Inf\"} 2"));
        // PDES counters render (at zero) even when no sharded run fed them.
        assert!(text.contains("# TYPE dcadls_pdes_rounds_total counter"));
        assert!(text.contains("# TYPE dcadls_pdes_horizon_stalls_total counter"));
        assert!(text.contains("# TYPE dcadls_pdes_mailbox_depth gauge"));
        // Deterministic ordering: every # HELP line sorted by name.
        let helps: Vec<&str> =
            text.lines().filter(|l| l.starts_with("# HELP")).collect();
        let mut sorted = helps.clone();
        sorted.sort();
        assert_eq!(helps, sorted);
    }

    #[test]
    fn histogram_cumulative_buckets_render() {
        let r = MetricsRegistry::new();
        let h = r.histogram("lat_seconds", "help", 1e-6);
        h.observe(0.5e-6);
        h.observe(1.5e-6);
        h.observe(3e-6);
        let text = r.render_prometheus();
        assert!(text.contains("lat_seconds_bucket{le=\"0.000001\"} 1"));
        assert!(text.contains("lat_seconds_bucket{le=\"0.000002\"} 2"));
        assert!(text.contains("lat_seconds_bucket{le=\"0.000004\"} 3"));
        assert!(text.contains("lat_seconds_count 3"));
        assert!((h.mean() - (0.5e-6 + 1.5e-6 + 3e-6) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn pdes_fold_accumulates_and_keeps_high_water() {
        let summary = |rounds, stalls, mailbox, rollbacks, spec, window| crate::des::PdesSummary {
            shards: 4,
            threads: 2,
            mode: crate::des::pdes::PdesMode::Hybrid,
            rounds,
            lookahead_ns: 1_000,
            window_ns: window,
            horizon_stalls: stalls,
            mailbox_depth_max: mailbox,
            rollbacks,
            speculated_events: spec,
            checkpoint_bytes: 100 * rollbacks,
            window_multiple: rollbacks.min(8),
            arbiter_epochs: rounds / 2,
        };
        let r = MetricsRegistry::new();
        let m = EngineMetrics::register(&r);
        m.on_pdes(&summary(10, 2, 7, 3, 40, 1_000));
        // Lower mailbox mark must not regress the gauge; the window and
        // window-multiple gauges track the latest run.
        m.on_pdes(&summary(5, 0, 3, 1, 10, 500));
        assert_eq!(m.pdes_rounds.get(), 15);
        assert_eq!(m.pdes_horizon_stalls.get(), 2);
        assert!((m.pdes_mailbox_depth.get() - 7.0).abs() < 1e-12);
        assert_eq!(m.pdes_rollbacks.get(), 4);
        assert_eq!(m.pdes_speculated_events.get(), 50);
        assert!((m.pdes_window_ns.get() - 500.0).abs() < 1e-12);
        assert_eq!(m.pdes_checkpoint_bytes.get(), 400);
        assert!((m.pdes_window_multiple.get() - 1.0).abs() < 1e-12);
        assert_eq!(m.pdes_arbiter_epochs.get(), 7);
        let text = r.render_prometheus();
        assert!(text.contains("dcadls_pdes_rounds_total 15"));
        assert!(text.contains("dcadls_pdes_horizon_stalls_total 2"));
        assert!(text.contains("dcadls_pdes_mailbox_depth 7"));
        assert!(text.contains("dcadls_pdes_rollbacks_total 4"));
        assert!(text.contains("dcadls_pdes_speculated_events_total 50"));
        assert!(text.contains("dcadls_pdes_window_ns 500"));
        assert!(text.contains("dcadls_pdes_checkpoint_bytes 400"));
        assert!(text.contains("dcadls_pdes_window_multiple 1"));
        assert!(text.contains("dcadls_pdes_arbiter_epochs_total 7"));
    }

    #[test]
    fn session_metrics_register() {
        let r = MetricsRegistry::new();
        let s = SessionMetrics::register(&r);
        s.admitted.inc();
        s.active.add(1.0);
        s.active.add(-1.0);
        let text = r.render_prometheus();
        assert!(text.contains("dcadls_tenants_admitted_total 1"));
        assert!(text.contains("dcadls_tenants_active 0"));
    }
}
