//! NDJSON stream records: the self-describing events emitted by
//! `--stream-metrics <path|->`. One JSON object per line; every record
//! carries the `schema` tag so consumers can dispatch without sniffing.
//!
//! Record vocabulary (normative field tables live in
//! `docs/metrics-schema.md`):
//!
//! * `interval` — sampled by the DES loops at virtual-time ticks; the
//!   common core built by [`interval_record`], extended per loop with
//!   `queue_depth` (flat), `subtrees` (hierarchical), or
//!   `tenants`/`active_tenants` (session).
//! * `switch` — one per adaptive technique rebind, generated from the
//!   run's recorded [`SwitchEvent`]s and merged into virtual-time order.
//! * `tenant` — one terminal record per tenant with turnaround/slowdown.

use crate::report::json::Json;
use crate::sched::adaptive::{AdaptiveController, SwitchEvent};
use crate::techniques::TechniqueKind;

/// Schema tag stamped on every stream record.
pub const STREAM_SCHEMA: &str = "dca-dls/stream/v1";

/// Hard cap on interval records per run, so a tiny `--stream-interval`
/// against a long virtual horizon cannot exhaust memory. When the cap is
/// hit sampling stops; the truncation is visible as a gap before the run's
/// final record.
pub const MAX_STREAM_RECORDS: usize = 100_000;

/// Virtual-time tick source for the DES loops: `due(now_ns)` is polled
/// right after the event loop advances `now`, and yields each elapsed tick
/// boundary (in seconds) at most [`MAX_STREAM_RECORDS`] times.
#[derive(Debug, Clone)]
pub struct Sampler {
    interval_ns: u64,
    next_ns: u64,
    emitted: usize,
}

impl Sampler {
    /// `None` when `interval_s` is zero/negative (streaming disabled).
    pub fn from_interval_s(interval_s: f64) -> Option<Self> {
        if !(interval_s > 0.0) {
            return None;
        }
        let interval_ns = ((interval_s * 1e9).round() as u64).max(1);
        Some(Sampler { interval_ns, next_ns: interval_ns, emitted: 0 })
    }

    /// Sampling interval in seconds (used for grant-rate normalisation).
    pub fn interval_s(&self) -> f64 {
        self.interval_ns as f64 * 1e-9
    }

    /// Time of the `k`-th tick (0-based), in seconds — exactly the value
    /// [`Sampler::due`] yields for it (same integer arithmetic), so a
    /// post-run merge of per-shard tick series can rebuild the boundary
    /// grid bit-for-bit.
    pub fn tick_at(&self, k: usize) -> f64 {
        ((k as u64 + 1) * self.interval_ns) as f64 * 1e-9
    }

    /// Next elapsed tick at or before `now_ns`, if any. Call in a loop to
    /// drain multiple boundaries crossed by one large event-time jump.
    pub fn due(&mut self, now_ns: u64) -> Option<f64> {
        if self.emitted >= MAX_STREAM_RECORDS || now_ns < self.next_ns {
            return None;
        }
        let t = self.next_ns as f64 * 1e-9;
        self.next_ns += self.interval_ns;
        self.emitted += 1;
        Some(t)
    }
}

/// Envelope shared by every stream record: `schema`, `event`, `t`
/// (virtual seconds).
fn envelope(event: &str, t_s: f64) -> Json {
    Json::obj().field("schema", STREAM_SCHEMA).field("event", event).field("t", t_s)
}

/// Core counters every `interval` record carries; loop-specific fields are
/// appended by the caller with [`Json::field`].
#[derive(Debug, Clone, Copy, Default)]
pub struct IntervalSample {
    /// Tick time, virtual seconds.
    pub t: f64,
    /// Cumulative chunks granted at the tick.
    pub chunks: u64,
    /// Chunks granted during this interval (for `grant_rate`).
    pub chunks_delta: u64,
    /// Interval length in seconds.
    pub interval_s: f64,
    /// Cumulative scheduling messages.
    pub messages: u64,
    /// Cumulative lock-free fast-path grants.
    pub fast_grants: u64,
    /// Loop iterations not yet granted.
    pub remaining: u64,
}

/// Build the common core of an `interval` record.
pub fn interval_record(s: &IntervalSample) -> Json {
    let rate = if s.interval_s > 0.0 { s.chunks_delta as f64 / s.interval_s } else { 0.0 };
    envelope("interval", s.t)
        .field("chunks", s.chunks)
        .field("grant_rate", rate)
        .field("messages", s.messages)
        .field("fast_grants", s.fast_grants)
        .field("remaining", s.remaining)
}

/// Per-subtree entry for hierarchical `interval` records: the master's
/// bound technique, ledger state, and (when adaptive) its EWMAs.
pub fn subtree_entry(
    level: u32,
    master: u32,
    technique: TechniqueKind,
    remaining: u64,
    parked: u64,
    adapt: Option<&AdaptiveController>,
) -> Json {
    let mut j = envelope_free()
        .field("level", u64::from(level))
        .field("master", u64::from(master))
        .field("technique", technique)
        .field("remaining", remaining)
        .field("parked", parked);
    if let Some(ctl) = adapt {
        j = append_ewmas(j, ctl);
    }
    j
}

/// Bare object for nested entries (no envelope — only top-level records
/// carry `schema`/`event`/`t`).
fn envelope_free() -> Json {
    Json::obj()
}

/// Append `mu_hat`/`sigma_hat`/`overhead_hat` for a primed controller.
pub fn append_ewmas(mut j: Json, ctl: &AdaptiveController) -> Json {
    if let Some(mu) = ctl.mu_hat() {
        j = j.field("mu_hat", mu);
    }
    if let Some(sigma) = ctl.sigma_hat() {
        j = j.field("sigma_hat", sigma);
    }
    if let Some(oh) = ctl.overhead_hat() {
        j = j.field("overhead_hat", oh);
    }
    j
}

/// Per-tenant entry for session `interval` records.
pub fn tenant_entry(
    id: u64,
    name: &str,
    state: &str,
    technique: TechniqueKind,
    granted_iters: u64,
    n: u64,
) -> Json {
    envelope_free()
        .field("tenant", id)
        .field("name", name)
        .field("state", state)
        .field("technique", technique)
        .field("granted_iters", granted_iters)
        .field("n", n)
}

/// One `switch` record per adaptive rebind, generated post-run from the
/// recorded [`SwitchEvent`]s (same fields as `report::json::switch_event_json`,
/// wrapped in the stream envelope).
pub fn switch_record(e: &SwitchEvent) -> Json {
    envelope("switch", e.at_s)
        .field("level", u64::from(e.level))
        .field("master", u64::from(e.master))
        .field("from", e.from)
        .field("to", e.to)
        .field("predicted_ratio", e.predicted_ratio)
}

/// Terminal `tenant` record: one per tenant after the session drains.
pub fn tenant_record(
    id: u64,
    name: &str,
    state: &str,
    arrival_s: f64,
    completion_s: f64,
    slowdown: Option<f64>,
) -> Json {
    let mut j = envelope("tenant", completion_s)
        .field("tenant", id)
        .field("name", name)
        .field("state", state)
        .field("arrival", arrival_s)
        .field("turnaround", completion_s - arrival_s);
    if let Some(s) = slowdown {
        j = j.field("slowdown", s);
    }
    j
}

/// Merge streams (interval + post-run switch/tenant records) into
/// virtual-time order; the sort is stable so same-tick records keep their
/// relative order.
pub fn sorted_by_time(mut records: Vec<Json>) -> Vec<Json> {
    records.sort_by(|a, b| {
        let ta = a.get("t").and_then(Json::as_f64).unwrap_or(0.0);
        let tb = b.get("t").and_then(Json::as_f64).unwrap_or(0.0);
        ta.partial_cmp(&tb).unwrap_or(std::cmp::Ordering::Equal)
    });
    records
}

/// Write records as NDJSON to `dest` — a file path, or `-` for stdout.
pub fn write_ndjson(dest: &str, records: &[Json]) -> anyhow::Result<()> {
    let mut out = String::with_capacity(records.len() * 128);
    for r in records {
        out.push_str(&r.render());
        out.push('\n');
    }
    if dest == "-" {
        use std::io::Write;
        std::io::stdout().write_all(out.as_bytes())?;
    } else {
        std::fs::write(dest, out)
            .map_err(|e| anyhow::anyhow!("writing stream to {dest}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_yields_each_crossed_tick_once() {
        let mut s = Sampler::from_interval_s(1e-3).expect("enabled");
        assert_eq!(s.due(500_000), None, "before first tick");
        let t1 = s.due(1_000_000).expect("first tick");
        assert!((t1 - 1e-3).abs() < 1e-12);
        assert_eq!(s.due(1_000_000), None, "tick consumed");
        // A large jump drains multiple boundaries one at a time.
        let t2 = s.due(3_500_000).expect("second tick");
        let t3 = s.due(3_500_000).expect("third tick");
        assert!((t2 - 2e-3).abs() < 1e-12);
        assert!((t3 - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn sampler_disabled_for_zero_interval() {
        assert!(Sampler::from_interval_s(0.0).is_none());
        assert!(Sampler::from_interval_s(-1.0).is_none());
    }

    #[test]
    fn interval_record_core_fields() {
        let r = interval_record(&IntervalSample {
            t: 0.25,
            chunks: 100,
            chunks_delta: 10,
            interval_s: 0.05,
            messages: 400,
            fast_grants: 0,
            remaining: 5_000,
        });
        assert_eq!(r.get("schema").and_then(Json::as_str), Some(STREAM_SCHEMA));
        assert_eq!(r.get("event").and_then(Json::as_str), Some("interval"));
        assert_eq!(r.get("chunks").and_then(Json::as_u64), Some(100));
        assert!((r.get("grant_rate").and_then(Json::as_f64).unwrap() - 200.0).abs() < 1e-9);
        assert_eq!(r.get("remaining").and_then(Json::as_u64), Some(5_000));
    }

    #[test]
    fn records_sort_by_virtual_time() {
        let records = vec![
            envelope("interval", 0.2),
            envelope("switch", 0.05),
            envelope("interval", 0.1),
        ];
        let sorted = sorted_by_time(records);
        let ts: Vec<f64> =
            sorted.iter().map(|r| r.get("t").and_then(Json::as_f64).unwrap()).collect();
        assert_eq!(ts, vec![0.05, 0.1, 0.2]);
    }

    #[test]
    fn ndjson_is_one_parseable_object_per_line() {
        let dir = std::env::temp_dir().join("dca_dls_stream_test.ndjson");
        let dest = dir.to_str().expect("utf8 tmp path");
        let records =
            vec![envelope("interval", 0.1).field("chunks", 1u64), envelope("switch", 0.2)];
        write_ndjson(dest, &records).expect("write");
        let text = std::fs::read_to_string(dest).expect("read back");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).expect("valid JSON per line");
            assert_eq!(j.get("schema").and_then(Json::as_str), Some(STREAM_SCHEMA));
        }
        let _ = std::fs::remove_file(dest);
    }
}
