//! The hierarchical scheduling protocol's shared state machine: the
//! **per-level chunk ledger** every master of the scheduling tree drives —
//! at any depth, on either substrate (DES service personality in
//! [`crate::hier`] or real thread in [`crate::coordinator::hier`]). Keeping
//! the reserve/commit/stale-`seq` semantics in one place means the
//! event-by-event simulation and the wall-clock engine validate literally
//! the same protocol definition, and every tree level nests the same one.
//!
//! A [`NodeLedger`] owns the master's *current* level-chunk as a local
//! [`WorkQueue`] over `[0, len)` plus the iteration offset that maps local
//! grants back to absolute loop ranges. Sub-chunks follow the DCA two-phase
//! protocol one level down:
//!
//! 1. [`NodeLedger::reserve`] hands out a local step (phase 1); the
//!    requester calculates its sub-chunk size with the *inner* technique
//!    bound to the node-chunk's length;
//! 2. [`NodeLedger::commit`] grants the absolute range (phase 2) — or NACKs
//!    with [`InnerCommit::Stale`] when the step was reserved from a
//!    node-chunk that has since been replaced, forcing the requester back
//!    to a fresh phase 1 instead of silently committing a size computed for
//!    the old chunk.
//!
//! Every node-chunk installation bumps a **sequence number** carried on
//! phase-1 replies and echoed on commits; that `seq` is what makes the
//! stale-chunk race detectable on both substrates.
//!
//! **Parent-level prefetch** (the ROADMAP follow-on): the ledger holds a
//! FIFO queue of *staged* chunks behind the current one, up to a
//! configurable capacity ([`NodeLedger::with_staged_capacity`]; 1 = the
//! single-slot stage of the original implementation). A master configured
//! with a prefetch watermark requests the next chunk while the current one
//! still has `≤ watermark` unassigned iterations; replies are staged via
//! [`NodeLedger::install`] and promoted the moment the current chunk drains
//! — the parent round trip plus the chunk calculation are hidden behind the
//! tail of the current chunk instead of stalling the whole subtree, and
//! deeper queues keep hiding them across multi-chunk stalls on very
//! high-latency fabrics.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::sched::{Assignment, StepTicket, WorkQueue};
use crate::techniques::{ChunkTable, LoopParams, TableCache, Technique, TechniqueKind};

/// EWMA weight of the newest round-trip sample in the adaptive-watermark
/// estimate (newer trips dominate, but one outlier doesn't).
pub const RTT_EWMA_ALPHA: f64 = 0.5;

/// EWMA of a master's observed parent-fetch round trips, seconds. Part of
/// the shared protocol definition — like [`NodeLedger::wants_prefetch`],
/// single-sourced here so the DES and the threaded engine cannot diverge
/// on the adaptive-watermark policy.
#[derive(Debug, Default, Clone, Copy)]
pub struct RttEwma {
    ewma_s: f64,
}

impl RttEwma {
    /// Fold in one observed round trip (seconds).
    pub fn observe(&mut self, rtt_s: f64) {
        self.ewma_s = if self.ewma_s > 0.0 {
            RTT_EWMA_ALPHA * rtt_s + (1.0 - RTT_EWMA_ALPHA) * self.ewma_s
        } else {
            rtt_s
        };
    }

    /// The current estimate (`None` until the first sample).
    pub fn value(&self) -> Option<f64> {
        (self.ewma_s > 0.0).then_some(self.ewma_s)
    }
}

/// The [`crate::config::WatermarkMode::Auto`] watermark: the iteration
/// count consumed during one parent round trip, `⌈rtt / µ⌉`, where `µ` is
/// the subtree's measured per-iteration drain time — prefetching at this
/// level hides the fetch exactly. Falls back to 0 (fetch on exhaustion)
/// until both quantities are measured.
pub fn auto_watermark(rtt: Option<f64>, mu: Option<f64>) -> u64 {
    match (rtt, mu) {
        (Some(rtt), Some(mu)) if mu > 0.0 => (rtt / mu).ceil() as u64,
        _ => 0,
    }
}

/// `params` with `n`/`p` overridden (keeps the technique parameterization —
/// FSC/TAP constants, batch counts, seeds — from the experiment config).
pub fn with_np(params: &LoopParams, n: u64, p: u32) -> LoopParams {
    let mut out = params.clone();
    out.n = n.max(1);
    out.p = p.max(1);
    out
}

/// The AF stale-snapshot re-cap both coordinator tiers apply at commit time:
/// clamp a worker-calculated size to `⌈R/p⌉` against the *fresh* remaining
/// count (the phase-1 `R_i` snapshot is stale once peers commit — the same
/// rule as the flat DCA coordinator, §4).
pub fn af_recap(size: u64, remaining: u64, p: u32) -> u64 {
    size.min(remaining.div_ceil(p.max(1) as u64).max(1))
}

/// Outcome of committing a locally calculated sub-chunk size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InnerCommit {
    /// The absolute iteration range granted for this sub-chunk.
    Granted(Assignment),
    /// Stale `seq`: the node-chunk was replaced while this commit was in
    /// flight, but the ledger still has work — NACK; re-serve the requester
    /// as a fresh phase-1 reserve against the *current* chunk.
    Stale,
    /// No unassigned work anywhere in the ledger — the requester parks (or
    /// terminates, once the global loop is exhausted).
    Drained,
}

/// The master's current (and optionally staged) level-chunk.
#[derive(Debug, Clone)]
struct Chunk {
    /// Local queue over `[0, len)`; granted ranges are offset to absolute.
    q: WorkQueue,
    offset: u64,
    len: u64,
    /// Technique this chunk was bound to at install time (the slot's value
    /// then — rebinds never retroactively change a live chunk's sizing).
    kind: TechniqueKind,
    /// Inner technique bound to this chunk's size (`None` for AF, which has
    /// no closed form).
    tech: Option<Technique>,
}

/// Per-level chunk ledger — see the module docs for the protocol.
///
/// The inner technique is a **re-bindable slot**: [`NodeLedger::rebind`]
/// changes what the *next* installed chunk is bound to, and
/// [`NodeLedger::rebind_now`] additionally splits a live chunk at its
/// unassigned remainder — re-installed under a fresh `seq`, so in-flight
/// commits against the replaced chunk NACK through the existing
/// stale-`seq` protocol and re-reserve against the new binding. Switches
/// are therefore race-free on both substrates without any new machinery:
/// the chunk boundary IS the synchronization point.
#[derive(Debug, Clone)]
pub struct NodeLedger {
    /// The technique slot: what the next installed chunk binds to.
    inner_kind: TechniqueKind,
    /// Template the inner technique is re-bound from per chunk.
    base: LoopParams,
    rpn: u32,
    /// Sequence number of the *current* chunk (0 = nothing installed yet).
    seq: u64,
    current: Option<Chunk>,
    /// Prefetched chunks queued behind the current one (FIFO), promoted one
    /// at a time as `current` drains.
    staged: VecDeque<Assignment>,
    /// Capacity of the staged queue (≥ 1).
    staged_cap: usize,
}

impl NodeLedger {
    /// A ledger subdividing chunks among `rpn` children with `inner_kind`
    /// (bound per chunk from the `base` parameterization), with a
    /// single-slot staged buffer (see [`Self::with_staged_capacity`]).
    pub fn new(inner_kind: TechniqueKind, base: &LoopParams, rpn: u32) -> Self {
        NodeLedger {
            inner_kind,
            base: base.clone(),
            rpn: rpn.max(1),
            seq: 0,
            current: None,
            staged: VecDeque::new(),
            staged_cap: 1,
        }
    }

    /// Set the staged-queue capacity: how many prefetched chunks may wait
    /// behind the current one (clamped to ≥ 1).
    pub fn with_staged_capacity(mut self, cap: usize) -> Self {
        self.staged_cap = cap.max(1);
        self
    }

    fn current_live(&self) -> bool {
        self.current.as_ref().is_some_and(|c| !c.q.is_done())
    }

    /// Does the ledger hold any unassigned iterations (current or staged)?
    pub fn has_work(&self) -> bool {
        self.current_live() || !self.staged.is_empty()
    }

    /// Unassigned iterations left in the *current* chunk (the prefetch
    /// watermark is compared against this).
    pub fn remaining(&self) -> u64 {
        self.current.as_ref().map_or(0, |c| c.q.remaining())
    }

    /// Is at least one chunk staged behind the current one?
    pub fn staged(&self) -> bool {
        !self.staged.is_empty()
    }

    /// Chunks currently staged behind the current one.
    pub fn staged_len(&self) -> usize {
        self.staged.len()
    }

    /// Should the master holding this ledger issue a prefetch? True once
    /// the current chunk has drained to the watermark and the staged queue
    /// has a free slot; always false when prefetch is disabled (`None`).
    /// Single-sourced here so the DES and the threaded engine cannot
    /// diverge on the prefetch policy.
    pub fn wants_prefetch(&self, watermark: Option<u64>) -> bool {
        match watermark {
            Some(w) => self.staged.len() < self.staged_cap && self.remaining() <= w,
            None => false,
        }
    }

    /// Length of the current chunk (0 before the first install) — the
    /// quantity phase-1 replies carry so remote requesters can bind the
    /// inner technique themselves.
    pub fn current_len(&self) -> u64 {
        self.current.as_ref().map_or(0, |c| c.len)
    }

    /// Sequence number of the current chunk.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Accept a chunk from the parent level: installed immediately when the
    /// ledger is empty, appended to the staged FIFO otherwise. Masters keep
    /// a single parent request in flight, so at most `staged_cap` chunks
    /// ever wait here.
    pub fn install(&mut self, a: Assignment) {
        if self.current_live() || !self.staged.is_empty() {
            debug_assert!(self.staged.len() < self.staged_cap, "staged queue overflow");
            self.staged.push_back(a);
        } else {
            self.install_now(a);
        }
    }

    fn install_now(&mut self, a: Assignment) {
        self.seq += 1;
        let kind = self.inner_kind;
        let tech = kind
            .has_closed_form()
            .then(|| Technique::new(kind, &with_np(&self.base, a.size, self.rpn)));
        self.current = Some(Chunk {
            q: WorkQueue::new(a.size, self.base.min_chunk),
            offset: a.start,
            len: a.size,
            kind,
            tech,
        });
    }

    /// The slot's current value — what the next installed chunk binds to.
    pub fn bound_kind(&self) -> TechniqueKind {
        self.inner_kind
    }

    /// Technique the chunk identified by `seq` was bound to (`None` when
    /// that chunk has been replaced — its commit will NACK anyway).
    pub fn chunk_kind(&self, seq: u64) -> Option<TechniqueKind> {
        match &self.current {
            Some(c) if self.seq == seq => Some(c.kind),
            _ => None,
        }
    }

    /// Re-bind the technique slot: takes effect at the **next** chunk
    /// install (the current chunk, if live, keeps its binding).
    pub fn rebind(&mut self, kind: TechniqueKind) {
        self.inner_kind = kind;
    }

    /// Re-bind the slot **immediately**: if a chunk is live, its unassigned
    /// remainder is carved off and re-installed as a fresh chunk under the
    /// new binding — `seq` advances, so every in-flight commit against the
    /// old chunk NACKs ([`InnerCommit::Stale`]) and re-reserves against the
    /// new technique. Returns `true` when a live chunk was split (`false`:
    /// only the slot moved; nothing to re-serve).
    pub fn rebind_now(&mut self, kind: TechniqueKind) -> bool {
        self.inner_kind = kind;
        let Some(c) = self.current.as_ref() else { return false };
        if c.q.is_done() {
            return false;
        }
        let remainder = Assignment {
            step: 0,
            start: c.offset + c.q.lp_start(),
            size: c.q.remaining(),
        };
        self.install_now(remainder);
        true
    }

    /// Phase 1: reserve the next local step, promoting the next staged
    /// chunk first if the current one has drained. `None` means the ledger
    /// is empty — the caller parks the requester and (if none is in flight)
    /// triggers a parent fetch.
    pub fn reserve(&mut self) -> Option<(u64, u64, u64)> {
        if !self.current_live() {
            let staged = self.staged.pop_front()?;
            self.install_now(staged);
        }
        let seq = self.seq;
        let c = self.current.as_mut().expect("live chunk after promotion");
        let t = c.q.begin_step().expect("non-done local queue yields a step");
        Some((t.step, t.remaining, seq))
    }

    /// Phase 2: commit `size` for a step reserved from node-chunk `seq`.
    /// Applies the inner-AF `⌈R/rpn⌉` re-cap against the fresh remaining
    /// count; detects the stale-`seq` race (see [`InnerCommit`]).
    pub fn commit(&mut self, step: u64, size: u64, seq: u64) -> InnerCommit {
        let granted = match self.current.as_mut() {
            Some(c) if !c.q.is_done() && self.seq == seq => {
                // The re-cap follows the CHUNK's binding, not the slot's —
                // a rebound slot must not re-cap a still-live AF chunk's
                // commits differently (or vice versa).
                let size = if c.kind == TechniqueKind::Af {
                    af_recap(size, c.q.remaining(), self.rpn)
                } else {
                    size
                };
                let ticket = StepTicket { step, remaining: c.q.remaining() };
                let a = c.q.commit(ticket, size).expect("non-done local queue commits");
                Some(Assignment { step: a.step, start: a.start + c.offset, size: a.size })
            }
            _ => None,
        };
        match granted {
            Some(a) => InnerCommit::Granted(a),
            None if self.has_work() => InnerCommit::Stale,
            None => InnerCommit::Drained,
        }
    }

    /// Closed-form sub-chunk size for `step` of chunk `seq` — the inner
    /// technique bound to the current node-chunk. `None` when the chunk was
    /// replaced in flight (the commit will NACK, so the size is moot) or
    /// the inner technique has no closed form (AF).
    pub fn closed_inner_size(&self, step: u64, seq: u64) -> Option<u64> {
        match &self.current {
            Some(c) if self.seq == seq => {
                Some(c.tech.as_ref().expect("closed-form inner technique").closed_chunk(step))
            }
            _ => None,
        }
    }

    /// The lock-free fast path in its **serial form** — the DES's model of
    /// the CAS: reserve + closed-form sizing + commit fused into one atomic
    /// action. Grant order ≡ step order, so the emitted schedule is exactly
    /// the technique's canonical serial schedule — the same schedule
    /// [`ChunkTable`] precomputes for the threaded CAS loop (pinned by the
    /// `fast_grant_matches_chunk_table` test). Promotes staged chunks like
    /// [`Self::reserve`]; `None` when the ledger is empty (the caller parks
    /// the requester and triggers the two-phase parent fetch).
    ///
    /// Requires a closed-form, non-measurement-coupled inner technique —
    /// AF/TAP stay on the two-phase protocol.
    pub fn fast_grant(&mut self) -> Option<Assignment> {
        debug_assert!(
            self.inner_kind.supports_fast_path(),
            "{} cannot take the lock-free fast path",
            self.inner_kind
        );
        let (step, _remaining, seq) = self.reserve()?;
        let size = self.closed_inner_size(step, seq).expect("closed form bound to live chunk");
        match self.commit(step, size, seq) {
            InnerCommit::Granted(a) => Some(a),
            other => unreachable!("fused reserve/commit cannot fail: {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// the lock-free fast path (threaded form)

/// Bits of the packed ledger word holding the chunk `seq`; the remaining
/// high bits hold the local start cursor.
pub const FAST_SEQ_BITS: u32 = 24;
/// Bits holding the local start cursor (40 ⇒ loops up to ~10¹² iterations).
pub const FAST_START_BITS: u32 = 64 - FAST_SEQ_BITS;
const FAST_SEQ_MASK: u64 = (1 << FAST_SEQ_BITS) - 1;

/// Can a loop (or chunk) of `n` iterations be cursored by the packed word?
/// Callers fall back to the two-phase protocol when this is false.
pub fn fast_len_ok(n: u64) -> bool {
    n < (1 << FAST_START_BITS)
}

#[inline]
fn pack(start: u64, seq: u64) -> u64 {
    (start << FAST_SEQ_BITS) | (seq & FAST_SEQ_MASK)
}

#[inline]
fn unpack(word: u64) -> (u64, u64) {
    (word >> FAST_SEQ_BITS, word & FAST_SEQ_MASK)
}

/// Snapshot of the chunk currently published on an [`AtomicLedger`].
#[derive(Debug, Clone)]
pub struct FastChunk {
    /// Install sequence number (compared modulo 2^[`FAST_SEQ_BITS`] against
    /// the packed word).
    pub seq: u64,
    /// Absolute iteration offset of the chunk.
    pub offset: u64,
    /// Precomputed serial schedule of the chunk.
    pub table: Arc<ChunkTable>,
}

/// The **lock-free chunk ledger**: the two-phase protocol's hot state — the
/// local start cursor plus the chunk `seq` — packed into one `AtomicU64`,
/// so a closed-form grant is a single CAS loop around an array lookup
/// instead of a reserve/commit message exchange. The stale-`seq` race the
/// two-phase protocol NACKs is prevented structurally here: the `seq` lives
/// *inside* the compared word, so a CAS against a replaced chunk simply
/// fails and the loop re-reads.
///
/// Single writer (the owning master publishes installs), any number of
/// granting readers. The published chunk metadata sits behind an `RwLock`
/// that grant loops only touch once per install (they cache the snapshot by
/// `seq`), keeping the steady-state grant at load + lookup + CAS.
///
/// Caveat: `seq` is compared modulo 2^24 — after 16.7 M installs *of one
/// ledger* an ABA pairing is theoretically possible; [`Self::publish`]
/// debug-asserts long before that.
#[derive(Debug, Default)]
pub struct AtomicLedger {
    /// `start << FAST_SEQ_BITS | seq`; `seq = 0` means nothing published.
    word: AtomicU64,
    chunk: RwLock<Option<FastChunk>>,
}

impl AtomicLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish a freshly installed chunk (single-writer: the owning master,
    /// and only once the previous chunk has fully drained).
    ///
    /// # Panics
    /// When `seq` masks to 0 or exceeds [`FAST_SEQ_BITS`]: a seq that packs
    /// to 0 would read as "nothing published" and silently lose the whole
    /// chunk, so overflow is a hard error even in release builds (16.7 M
    /// installs of ONE ledger — far beyond any simulated scenario).
    pub fn publish(&self, seq: u64, offset: u64, table: Arc<ChunkTable>) {
        assert!(seq > 0 && seq <= FAST_SEQ_MASK, "ledger seq overflow would ABA the packed word");
        debug_assert!(fast_len_ok(table.n()), "chunk too long for the packed cursor");
        *self.chunk.write().expect("ledger chunk lock") = Some(FastChunk {
            seq,
            offset,
            table,
        });
        self.word.store(pack(0, seq), Ordering::Release);
    }

    fn snapshot(&self) -> Option<FastChunk> {
        self.chunk.read().expect("ledger chunk lock").clone()
    }

    /// The lock-free grant: `(assignment, remaining_after, seq)`, or `None`
    /// when nothing is published or the published chunk has drained — the
    /// caller falls back to the two-phase slow path (park + parent fetch).
    pub fn try_grant(&self) -> Option<(Assignment, u64, u64)> {
        let mut cached: Option<FastChunk> = None;
        loop {
            let word = self.word.load(Ordering::Acquire);
            let (start, seqm) = unpack(word);
            if seqm == 0 {
                return None;
            }
            if cached.as_ref().is_none_or(|fc| fc.seq & FAST_SEQ_MASK != seqm) {
                cached = self.snapshot();
            }
            let Some(fc) = cached.as_ref().filter(|fc| fc.seq & FAST_SEQ_MASK == seqm) else {
                // The snapshot lags the word mid-publish — re-read both.
                std::hint::spin_loop();
                continue;
            };
            let Some((step, size)) = fc.table.grant_from(start) else {
                return None; // drained
            };
            let next = pack(start + size, seqm);
            if self
                .word
                .compare_exchange_weak(word, next, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let remaining = fc.table.n() - (start + size);
                return Some((
                    Assignment { step, start: fc.offset + start, size },
                    remaining,
                    fc.seq,
                ));
            }
        }
    }

    /// Atomically retire the published chunk: CAS the cursor to the chunk's
    /// end so no further grant can succeed, and return the **unassigned
    /// remainder** `(absolute start, length)` — `None` when nothing is
    /// published or it had already drained. Single-writer like
    /// [`Self::publish`]; racing [`Self::try_grant`]s either land before
    /// the freeze (their iterations are excluded from the remainder) or
    /// fail their CAS against the moved cursor and observe a drained
    /// ledger. This is what makes a mid-chunk technique rebind race-free on
    /// the lock-free path: freeze, then republish the remainder under the
    /// new table (and a fresh `seq`).
    pub fn freeze(&self) -> Option<(u64, u64)> {
        loop {
            let word = self.word.load(Ordering::Acquire);
            let (start, seqm) = unpack(word);
            if seqm == 0 {
                return None;
            }
            let Some(fc) = self.snapshot().filter(|fc| fc.seq & FAST_SEQ_MASK == seqm) else {
                std::hint::spin_loop();
                continue;
            };
            let n = fc.table.n();
            if start >= n {
                return None; // already drained
            }
            if self
                .word
                .compare_exchange_weak(word, pack(n, seqm), Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some((fc.offset + start, n - start));
            }
        }
    }

    /// Unassigned iterations left in the published chunk (0 when empty or
    /// drained) — the prefetch watermark is compared against this.
    pub fn remaining(&self) -> u64 {
        let (start, seqm) = unpack(self.word.load(Ordering::Acquire));
        if seqm == 0 {
            return 0;
        }
        match self.snapshot() {
            Some(fc) if fc.seq & FAST_SEQ_MASK == seqm => fc.table.n().saturating_sub(start),
            _ => 0,
        }
    }

    /// Does the published chunk still hold unassigned iterations?
    pub fn live(&self) -> bool {
        self.remaining() > 0
    }
}

/// Master-side owner of an [`AtomicLedger`]: staging FIFO, `seq`
/// allocation, and per-length table binding — [`NodeLedger`]'s
/// install/promotion semantics for the lock-free leaf level of the threaded
/// engine. The master holds this; its children hold clones of
/// [`Self::shared`] and grant straight off the CAS word.
#[derive(Debug)]
pub struct FastLedger {
    shared: Arc<AtomicLedger>,
    kind: TechniqueKind,
    base: LoopParams,
    rpn: u32,
    cache: TableCache,
    staged: VecDeque<Assignment>,
    staged_cap: usize,
    seq: u64,
}

impl FastLedger {
    /// Wrap `shared` for chunks subdivided among `rpn` children with
    /// `inner_kind` (parameterized from `base`), staging up to `staged_cap`
    /// prefetched chunks (clamped to ≥ 1, like the two-phase ledger).
    pub fn new(
        shared: Arc<AtomicLedger>,
        inner_kind: TechniqueKind,
        base: &LoopParams,
        rpn: u32,
        staged_cap: usize,
    ) -> Self {
        let rpn = rpn.max(1);
        FastLedger {
            shared,
            kind: inner_kind,
            base: base.clone(),
            rpn,
            cache: TableCache::new(inner_kind, base, rpn),
            staged: VecDeque::new(),
            staged_cap: staged_cap.max(1),
            seq: 0,
        }
    }

    /// The slot's current binding.
    pub fn bound_kind(&self) -> TechniqueKind {
        self.kind
    }

    /// Re-bind the slot to another **fast-path** technique: the memoized
    /// table cache is invalidated (tables are per-technique), and a live
    /// published chunk is frozen and immediately republished over its
    /// unassigned remainder under the new technique's table and a fresh
    /// `seq` — racing CAS grants either land before the freeze or retry
    /// against the new word. Returns `true` when a live chunk was split.
    ///
    /// # Panics
    /// When `kind` cannot take the fast path (demote instead — see
    /// [`Self::demote`]).
    pub fn rebind(&mut self, kind: TechniqueKind) -> bool {
        assert!(kind.supports_fast_path(), "{kind} must demote, not rebind, the fast ledger");
        self.kind = kind;
        self.cache = TableCache::new(kind, &self.base, self.rpn);
        match self.shared.freeze() {
            Some((start, len)) => {
                self.publish_now(Assignment { step: 0, start, size: len });
                true
            }
            None => false,
        }
    }

    /// Tear the fast ledger down for a two-phase demotion (the
    /// `SchedPath::Auto` fallback when adaptivity selects a
    /// measurement-coupled technique): freezes the published chunk and
    /// returns every unassigned range — the live remainder first, then the
    /// staged FIFO in order — for the caller to install into the two-phase
    /// [`NodeLedger`]. The shared word stays drained forever after, so
    /// workers fall back to the message protocol on their next grant.
    pub fn demote(mut self) -> Vec<Assignment> {
        let mut out = Vec::with_capacity(1 + self.staged.len());
        if let Some((start, len)) = self.shared.freeze() {
            out.push(Assignment { step: 0, start, size: len });
        }
        out.extend(self.staged.drain(..));
        out
    }

    /// The workers' granting handle.
    pub fn shared(&self) -> &Arc<AtomicLedger> {
        &self.shared
    }

    /// Accept a chunk from the parent level: published immediately when the
    /// ledger is empty, staged behind the current chunk otherwise (same
    /// policy as [`NodeLedger::install`]).
    pub fn install(&mut self, a: Assignment) {
        if self.shared.live() || !self.staged.is_empty() {
            debug_assert!(self.staged.len() < self.staged_cap, "staged queue overflow");
            self.staged.push_back(a);
        } else {
            self.publish_now(a);
        }
    }

    fn publish_now(&mut self, a: Assignment) {
        self.seq += 1;
        let table = self.cache.get(a.size);
        self.shared.publish(self.seq, a.start, table);
    }

    /// Master-side grant (serving a parked/slow-path child): tries the CAS
    /// word, promoting staged chunks as the current one drains. Returns the
    /// assignment plus the remaining count (for the prefetch check); `None`
    /// once current *and* staged are empty.
    pub fn grant(&mut self) -> Option<(Assignment, u64)> {
        loop {
            if let Some((a, remaining, _seq)) = self.shared.try_grant() {
                return Some((a, remaining));
            }
            let staged = self.staged.pop_front()?;
            self.publish_now(staged);
        }
    }

    /// Any unassigned iterations left (published or staged)?
    pub fn has_work(&self) -> bool {
        self.shared.live() || !self.staged.is_empty()
    }

    /// Chunks staged behind the published one.
    pub fn staged_len(&self) -> usize {
        self.staged.len()
    }

    /// Same prefetch predicate as [`NodeLedger::wants_prefetch`], over the
    /// CAS word's remaining count.
    pub fn wants_prefetch(&self, watermark: Option<u64>) -> bool {
        match watermark {
            Some(w) => self.staged.len() < self.staged_cap && self.shared.remaining() <= w,
            None => false,
        }
    }

    /// Drain a master-tier staging ring into this ledger: every chunk a
    /// fused parent fetch deposited since the last drain is installed in
    /// FIFO order (published immediately or staged, like any install).
    pub fn absorb_staged(&mut self, staged: &StagedChunkQueue) -> usize {
        let mut n = 0;
        while let Some(a) = staged.pop() {
            self.install(a);
            n += 1;
        }
        n
    }
}

// ---------------------------------------------------------------------------
// master-tier chunk staging (threaded form)

/// A small bounded **lock-free MPSC ring** of parent-granted chunks — the
/// master-tier extension of the CAS fast path. Whoever completes a fused
/// master-tier fetch on a subtree's behalf stages the granted chunk here
/// (multi-producer: sibling helpers race), and the subtree's owning master
/// drains it into [`FastLedger::install`] between grants
/// ([`FastLedger::absorb_staged`]) — the parent round trip feeds the ledger
/// without ever serializing on the parent's CPU or taking a lock.
///
/// Classic bounded ring with per-slot sequence counters: a producer claims
/// a slot with one CAS on `tail`, writes the chunk, then publishes by
/// bumping the slot's counter; the single consumer reads in FIFO order
/// guarded by the same counters. [`Self::push`] hands the chunk back when
/// the ring is full — callers treat that as backpressure and fall back to
/// the two-phase protocol.
#[derive(Debug)]
pub struct StagedChunkQueue {
    slots: Box<[StagedSlot]>,
    mask: u64,
    /// Next producer position (claimed by CAS).
    tail: AtomicU64,
    /// Next consumer position (single consumer — plain stores).
    head: AtomicU64,
}

#[derive(Debug)]
struct StagedSlot {
    seq: AtomicU64,
    chunk: UnsafeCell<MaybeUninit<Assignment>>,
}

// SAFETY: slot payloads are only written by the producer that claimed the
// slot's position (unique by the `tail` CAS) and only read after its
// publishing `seq` store, with Acquire/Release pairing on `seq`.
unsafe impl Send for StagedChunkQueue {}
unsafe impl Sync for StagedChunkQueue {}

impl StagedChunkQueue {
    /// A ring of at least `capacity` slots (rounded up to a power of two,
    /// minimum 2).
    pub fn with_capacity(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two() as u64;
        let slots = (0..cap)
            .map(|i| StagedSlot {
                seq: AtomicU64::new(i),
                chunk: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        StagedChunkQueue {
            slots,
            mask: cap - 1,
            tail: AtomicU64::new(0),
            head: AtomicU64::new(0),
        }
    }

    /// Stage one granted chunk (any thread). `Err(a)` hands the chunk back
    /// when the ring is full.
    pub fn push(&self, a: Assignment) -> Result<(), Assignment> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[(pos & self.mask) as usize];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq.wrapping_sub(pos) as i64;
            if diff == 0 {
                match self.tail.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS gave this thread exclusive claim
                        // on `pos`; the consumer waits for the `seq` bump.
                        unsafe { (*slot.chunk.get()).write(a) };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if diff < 0 {
                return Err(a); // a full lap behind: the ring is full
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Take the oldest staged chunk (the owning master only — single
    /// consumer).
    pub fn pop(&self) -> Option<Assignment> {
        let pos = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(pos & self.mask) as usize];
        let seq = slot.seq.load(Ordering::Acquire);
        if seq.wrapping_sub(pos.wrapping_add(1)) as i64 != 0 {
            return None; // nothing published at the head yet
        }
        // SAFETY: the producer's Release store on `seq` published this
        // slot's payload; no other consumer exists.
        let a = unsafe { (*slot.chunk.get()).assume_init() };
        slot.seq.store(pos.wrapping_add(self.mask + 1), Ordering::Release);
        self.head.store(pos.wrapping_add(1), Ordering::Relaxed);
        Some(a)
    }

    /// Chunks currently staged (approximate under concurrent pushes).
    pub fn len(&self) -> usize {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Relaxed);
        tail.wrapping_sub(head) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::verify_coverage;

    fn ledger(inner: TechniqueKind, rpn: u32) -> NodeLedger {
        NodeLedger::new(inner, &LoopParams::new(10_000, rpn * 4), rpn)
    }

    fn chunk(start: u64, size: u64) -> Assignment {
        Assignment { step: 0, start, size }
    }

    #[test]
    fn reserve_commit_covers_a_chunk() {
        let mut l = ledger(TechniqueKind::Gss, 4);
        assert!(!l.has_work());
        assert!(l.reserve().is_none());
        l.install(chunk(100, 40));
        assert_eq!(l.current_len(), 40);
        let mut granted = Vec::new();
        while let Some((step, _remaining, seq)) = l.reserve() {
            let size = l.closed_inner_size(step, seq).unwrap();
            match l.commit(step, size, seq) {
                InnerCommit::Granted(a) => granted.push(a),
                other => panic!("unexpected {other:?}"),
            }
        }
        granted.sort_by_key(|a| a.start);
        assert_eq!(granted.first().unwrap().start, 100);
        let total: u64 = granted.iter().map(|a| a.size).sum();
        assert_eq!(total, 40);
        let rebased: Vec<Assignment> = granted
            .iter()
            .map(|a| Assignment { step: a.step, start: a.start - 100, size: a.size })
            .collect();
        verify_coverage(&rebased, 40).unwrap();
    }

    #[test]
    fn stale_seq_commit_nacks_instead_of_granting() {
        let mut l = ledger(TechniqueKind::Ss, 2);
        l.install(chunk(0, 3));
        let (step, _, seq) = l.reserve().unwrap();
        // Drain the rest of chunk 1 and replace it while the commit for
        // `step` is conceptually in flight.
        while let Some((s, _, q)) = l.reserve() {
            match l.commit(s, 1, q) {
                InnerCommit::Granted(_) => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        // One reserved-but-uncommitted step: its late commit on the drained
        // chunk is Drained (no replacement yet)...
        assert_eq!(l.commit(step, 1, seq), InnerCommit::Drained);
        // ...but once a fresh chunk is installed, the same stale commit must
        // NACK into a re-reserve, not grant into the new chunk.
        l.install(chunk(50, 8));
        assert_eq!(l.commit(step, 1, seq), InnerCommit::Stale);
        let (s2, _, q2) = l.reserve().unwrap();
        assert_eq!(q2, seq + 1);
        assert!(matches!(l.commit(s2, 1, q2), InnerCommit::Granted(_)));
    }

    #[test]
    fn staged_chunk_promoted_only_after_current_drains() {
        let mut l = ledger(TechniqueKind::Ss, 2);
        l.install(chunk(0, 2));
        let seq1 = l.seq();
        // Prefetched next chunk arrives while the current one is live.
        l.install(chunk(2, 3));
        assert!(l.staged());
        assert_eq!(l.current_len(), 2, "staged chunk must not replace current");
        // Drain current.
        for _ in 0..2 {
            let (s, _, q) = l.reserve().unwrap();
            assert_eq!(q, seq1);
            assert!(matches!(l.commit(s, 1, q), InnerCommit::Granted(_)));
        }
        // Next reserve promotes the staged chunk with a bumped seq.
        let (s, _, q) = l.reserve().unwrap();
        assert_eq!(q, seq1 + 1);
        assert!(!l.staged());
        assert_eq!(l.current_len(), 3);
        let InnerCommit::Granted(a) = l.commit(s, 1, q) else { panic!("grant") };
        assert_eq!(a.start, 2);
    }

    #[test]
    fn deep_staged_queue_promotes_in_fifo_order() {
        let mut l = ledger(TechniqueKind::Ss, 2).with_staged_capacity(3);
        l.install(chunk(0, 1));
        l.install(chunk(1, 2));
        l.install(chunk(3, 4));
        l.install(chunk(7, 1));
        assert_eq!(l.staged_len(), 3);
        assert!(!l.wants_prefetch(Some(1_000)), "full queue must not prefetch");
        let mut starts = Vec::new();
        while let Some((s, _, q)) = l.reserve() {
            let InnerCommit::Granted(a) = l.commit(s, 1, q) else { panic!("grant") };
            starts.push(a.start);
        }
        assert_eq!(starts, vec![0, 1, 2, 3, 4, 5, 6, 7], "FIFO promotion, no gaps");
        assert!(!l.has_work());
    }

    #[test]
    fn wants_prefetch_honors_queue_capacity() {
        let mut l = ledger(TechniqueKind::Ss, 2).with_staged_capacity(2);
        l.install(chunk(0, 8));
        assert!(l.wants_prefetch(Some(8)));
        l.install(chunk(8, 8));
        assert!(l.wants_prefetch(Some(8)), "one slot still free");
        l.install(chunk(16, 8));
        assert!(!l.wants_prefetch(Some(8)), "queue full");
        assert!(!l.wants_prefetch(None), "disabled prefetch never fires");
        // Draining the current chunk frees nothing (promotion refills from
        // the queue), but consuming a staged chunk does.
        while let Some((s, _, q)) = l.reserve() {
            if matches!(l.commit(s, 8, q), InnerCommit::Drained) {
                break;
            }
            if l.staged_len() < 2 {
                break;
            }
        }
        assert!(l.wants_prefetch(Some(1_000)));
    }

    #[test]
    fn single_slot_capacity_matches_the_original_stage() {
        let mut l = ledger(TechniqueKind::Ss, 2); // default capacity 1
        l.install(chunk(0, 2));
        assert!(l.wants_prefetch(Some(2)));
        l.install(chunk(2, 2));
        assert!(!l.wants_prefetch(Some(1_000)), "single slot occupied");
        assert_eq!(l.staged_len(), 1);
    }

    #[test]
    fn af_commit_recapped_against_fresh_remaining() {
        let mut l = ledger(TechniqueKind::Af, 4);
        l.install(chunk(0, 100));
        let (step, _, seq) = l.reserve().unwrap();
        // A wildly optimistic size is clamped to ⌈R/rpn⌉ = 25.
        let InnerCommit::Granted(a) = l.commit(step, 10_000, seq) else { panic!("grant") };
        assert_eq!(a.size, 25);
    }

    #[test]
    fn closed_inner_size_is_seq_guarded() {
        let mut l = ledger(TechniqueKind::Gss, 4);
        l.install(chunk(0, 64));
        let (step, _, seq) = l.reserve().unwrap();
        assert!(l.closed_inner_size(step, seq).is_some());
        assert_eq!(l.closed_inner_size(step, seq + 1), None);
    }

    #[test]
    fn auto_watermark_needs_both_measurements() {
        assert_eq!(auto_watermark(None, None), 0);
        assert_eq!(auto_watermark(Some(1e-3), None), 0);
        assert_eq!(auto_watermark(None, Some(1e-5)), 0);
        // One 1 ms round trip at 10 µs/iteration drain ⇒ 100 iterations.
        assert_eq!(auto_watermark(Some(1e-3), Some(1e-5)), 100);
        // Ceiling, and a degenerate µ never divides by zero.
        assert_eq!(auto_watermark(Some(1.05e-3), Some(1e-4)), 11);
        assert_eq!(auto_watermark(Some(1e-3), Some(0.0)), 0);
    }

    #[test]
    fn rtt_ewma_tracks_with_memory() {
        let mut e = RttEwma::default();
        assert_eq!(e.value(), None, "no sample yet");
        e.observe(1.0);
        assert_eq!(e.value(), Some(1.0), "first sample is taken verbatim");
        e.observe(0.0);
        assert_eq!(e.value(), Some(0.5), "α = 0.5 halves toward new samples");
        e.observe(0.5);
        assert_eq!(e.value(), Some(0.5));
    }

    #[test]
    fn af_recap_floor_is_one() {
        assert_eq!(af_recap(10, 0, 4), 1);
        assert_eq!(af_recap(10, 7, 4), 2);
        assert_eq!(af_recap(1, 1_000, 4), 1);
    }

    /// The serial fast path (fused reserve/commit) and the precomputed
    /// chunk table emit the identical schedule for every fast-path
    /// technique, across chunk installs of varying lengths — the tentpole's
    /// provable-equivalence claim at the protocol layer.
    #[test]
    fn fast_grant_matches_chunk_table() {
        use crate::techniques::TableCache;
        for kind in TechniqueKind::ALL {
            if !kind.supports_fast_path() {
                continue;
            }
            let base = LoopParams::new(10_000, 16);
            let rpn = 4;
            let mut l = NodeLedger::new(kind, &base, rpn).with_staged_capacity(2);
            let mut cache = TableCache::new(kind, &base, rpn);
            for (start, len) in [(0u64, 517u64), (517, 130), (647, 1), (648, 2048)] {
                l.install(chunk(start, len));
                let table = cache.get(len);
                let mut cursor = 0u64;
                while let Some((step, size)) = table.grant_from(cursor) {
                    let a = l.fast_grant().unwrap_or_else(|| panic!("{kind}: ledger dry"));
                    assert_eq!(
                        (a.step, a.start, a.size),
                        (step, start + cursor, size),
                        "{kind}: chunk [{start},{len}) @ step {step}"
                    );
                    cursor += size;
                }
                assert!(l.fast_grant().is_none(), "{kind}: drained with the table");
            }
        }
    }

    #[test]
    fn atomic_ledger_grants_the_canonical_schedule() {
        use crate::sched::closed_form_schedule;
        use crate::techniques::{ChunkTable, Technique};
        let params = LoopParams::new(1_000, 4);
        let ledger = AtomicLedger::new();
        assert_eq!(ledger.try_grant(), None, "nothing published yet");
        assert_eq!(ledger.remaining(), 0);
        let table =
            std::sync::Arc::new(ChunkTable::build(TechniqueKind::Gss, &params).unwrap());
        ledger.publish(1, 500, table);
        let tech = Technique::new(TechniqueKind::Gss, &params);
        let want = closed_form_schedule(&tech, &params);
        for a in &want {
            let (got, remaining, seq) = ledger.try_grant().expect("live chunk");
            assert_eq!((got.step, got.start, got.size), (a.step, a.start + 500, a.size));
            assert_eq!(remaining, params.n - (a.start + a.size));
            assert_eq!(seq, 1);
        }
        assert_eq!(ledger.try_grant(), None, "drained");
        assert!(!ledger.live());
    }

    #[test]
    fn atomic_ledger_republish_invalidates_the_old_word() {
        use crate::techniques::ChunkTable;
        let params = LoopParams::new(10, 2);
        let ledger = AtomicLedger::new();
        let t = std::sync::Arc::new(ChunkTable::build(TechniqueKind::Ss, &params).unwrap());
        ledger.publish(1, 0, std::sync::Arc::clone(&t));
        let (a, _, seq1) = ledger.try_grant().unwrap();
        assert_eq!((a.start, a.size, seq1), (0, 1, 1));
        // Drain and republish at a new offset: grants come from the new
        // chunk with a bumped seq, never from the stale word.
        while ledger.try_grant().is_some() {}
        ledger.publish(2, 100, t);
        let (b, remaining, seq2) = ledger.try_grant().unwrap();
        assert_eq!((b.step, b.start, b.size), (0, 100, 1));
        assert_eq!(seq2, 2);
        assert_eq!(remaining, 9);
        assert_eq!(ledger.remaining(), 9);
    }

    /// Contended smoke test: many threads CAS-granting concurrently still
    /// cover the loop exactly once with the canonical chunk multiset.
    #[test]
    fn atomic_ledger_concurrent_grants_cover_exactly() {
        use crate::techniques::ChunkTable;
        let params = LoopParams::new(50_000, 8);
        let table =
            std::sync::Arc::new(ChunkTable::build(TechniqueKind::Ss, &params).unwrap());
        let steps = table.steps();
        let ledger = std::sync::Arc::new(AtomicLedger::new());
        ledger.publish(1, 0, table);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let l = std::sync::Arc::clone(&ledger);
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((a, _, _)) = l.try_grant() {
                    got.push(a);
                }
                got
            }));
        }
        let mut all: Vec<Assignment> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        assert_eq!(all.len() as u64, steps);
        all.sort_unstable_by_key(|a| a.start);
        verify_coverage(&all, 50_000).unwrap();
    }

    #[test]
    fn fast_ledger_stages_and_promotes_like_the_node_ledger() {
        let base = LoopParams::new(10_000, 8);
        let shared = Arc::new(AtomicLedger::new());
        let mut f = FastLedger::new(Arc::clone(&shared), TechniqueKind::Ss, &base, 2, 2);
        assert!(!f.has_work());
        assert!(f.grant().is_none());
        f.install(chunk(0, 2));
        f.install(chunk(2, 3));
        f.install(chunk(5, 1));
        assert_eq!(f.staged_len(), 2);
        assert!(!f.wants_prefetch(Some(1_000)), "staged queue full");
        assert!(!f.wants_prefetch(None), "disabled prefetch never fires");
        // Workers drain the published chunk straight off the CAS word…
        let mut starts = Vec::new();
        while let Some((a, _, _)) = shared.try_grant() {
            starts.push(a.start);
        }
        assert_eq!(starts, vec![0, 1], "published chunk only");
        // …and the master's grant promotes the staged FIFO in order.
        while let Some((a, _rem)) = f.grant() {
            starts.push(a.start);
        }
        assert_eq!(starts, vec![0, 1, 2, 3, 4, 5], "FIFO promotion, no gaps");
        assert!(!f.has_work());
        assert!(f.wants_prefetch(Some(0)), "empty ledger is below any watermark");
    }

    #[test]
    fn fast_len_guard() {
        assert!(fast_len_ok(0));
        assert!(fast_len_ok((1 << 40) - 1));
        assert!(!fast_len_ok(1 << 40));
    }

    /// The tentpole's race-freedom claim, deterministically: a mid-run
    /// rebind splits the live chunk at a fresh `seq`, the in-flight commit
    /// against the old chunk NACKs (`Stale`), and the re-reserve sizes
    /// against the NEW technique over exactly the unassigned remainder.
    #[test]
    fn rebind_now_splits_and_nacks_stale_commits() {
        let mut l = ledger(TechniqueKind::Ss, 4);
        l.install(chunk(100, 40));
        assert_eq!(l.bound_kind(), TechniqueKind::Ss);
        // Two reserved steps: one committed before the rebind, one left in
        // flight across it.
        let (s1, _, q1) = l.reserve().unwrap();
        let (s2, _, q2) = l.reserve().unwrap();
        let InnerCommit::Granted(a1) = l.commit(s1, 1, q1) else { panic!("grant") };
        assert_eq!((a1.start, a1.size), (100, 1));
        // Rebind mid-chunk: 39 unassigned iterations re-install under GSS.
        assert!(l.rebind_now(TechniqueKind::Gss));
        assert_eq!(l.bound_kind(), TechniqueKind::Gss);
        assert_eq!(l.seq(), q1 + 1, "split bumps the seq");
        assert_eq!(l.current_len(), 39, "remainder only");
        assert_eq!(l.chunk_kind(l.seq()), Some(TechniqueKind::Gss));
        assert_eq!(l.chunk_kind(q1), None, "old chunk is gone");
        // The in-flight commit NACKs instead of granting into the new chunk.
        assert_eq!(l.commit(s2, 1, q2), InnerCommit::Stale);
        // Re-reserve: sized by GSS bound to the 39-iteration remainder.
        let (s3, _, q3) = l.reserve().unwrap();
        assert_eq!(q3, l.seq());
        let size = l.closed_inner_size(s3, q3).unwrap();
        assert_eq!(size, 10, "GSS step 0 over (39, 4) = ceil(39/4)");
        let InnerCommit::Granted(a3) = l.commit(s3, size, q3) else { panic!("grant") };
        assert_eq!(a3.start, 101, "remainder continues where the grants stopped");
        // Drain and verify the split lost nothing.
        let mut granted = vec![a1, a3];
        while let Some((s, _, q)) = l.reserve() {
            let k = l.closed_inner_size(s, q).unwrap();
            let InnerCommit::Granted(a) = l.commit(s, k, q) else { panic!("grant") };
            granted.push(a);
        }
        granted.sort_by_key(|a| a.start);
        let rebased: Vec<Assignment> = granted
            .iter()
            .map(|a| Assignment { step: a.step, start: a.start - 100, size: a.size })
            .collect();
        verify_coverage(&rebased, 40).unwrap();
    }

    #[test]
    fn rebind_defers_to_the_next_install() {
        let mut l = ledger(TechniqueKind::Ss, 4);
        l.install(chunk(0, 10));
        l.rebind(TechniqueKind::Gss);
        // Current chunk keeps its SS binding…
        assert_eq!(l.chunk_kind(l.seq()), Some(TechniqueKind::Ss));
        let (s, _, q) = l.reserve().unwrap();
        assert_eq!(l.closed_inner_size(s, q), Some(1), "still SS");
        assert!(matches!(l.commit(s, 1, q), InnerCommit::Granted(_)));
        // …and the next install binds GSS.
        l.install(chunk(10, 8));
        while l.chunk_kind(l.seq()) == Some(TechniqueKind::Ss) {
            let (s, _, q) = l.reserve().unwrap();
            l.commit(s, 1, q);
        }
        let (s, _, q) = l.reserve().unwrap();
        assert_eq!(l.chunk_kind(q), Some(TechniqueKind::Gss));
        assert_eq!(l.closed_inner_size(s, q), Some(2), "GSS over (8, 4)");
    }

    #[test]
    fn rebind_now_without_live_chunk_only_moves_the_slot() {
        let mut l = ledger(TechniqueKind::Ss, 4);
        assert!(!l.rebind_now(TechniqueKind::Gss), "nothing to split");
        assert_eq!(l.bound_kind(), TechniqueKind::Gss);
        l.install(chunk(0, 8));
        assert_eq!(l.chunk_kind(l.seq()), Some(TechniqueKind::Gss));
    }

    #[test]
    fn atomic_ledger_freeze_returns_the_unassigned_remainder() {
        use crate::techniques::ChunkTable;
        let params = LoopParams::new(10, 2);
        let ledger = AtomicLedger::new();
        assert_eq!(ledger.freeze(), None, "nothing published");
        let t = std::sync::Arc::new(ChunkTable::build(TechniqueKind::Ss, &params).unwrap());
        ledger.publish(1, 100, std::sync::Arc::clone(&t));
        // Take three grants, freeze the rest.
        for _ in 0..3 {
            ledger.try_grant().unwrap();
        }
        assert_eq!(ledger.freeze(), Some((103, 7)));
        assert_eq!(ledger.try_grant(), None, "frozen word grants nothing");
        assert_eq!(ledger.remaining(), 0);
        assert_eq!(ledger.freeze(), None, "idempotently drained");
        // Republish over the remainder: grants resume there.
        ledger.publish(2, 103, t);
        let (a, _, seq) = ledger.try_grant().unwrap();
        assert_eq!((a.start, seq), (103, 2));
    }

    #[test]
    fn fast_ledger_rebind_republishes_the_remainder() {
        let base = LoopParams::new(10_000, 8);
        let shared = Arc::new(AtomicLedger::new());
        let mut f = FastLedger::new(Arc::clone(&shared), TechniqueKind::Ss, &base, 4, 2);
        f.install(chunk(0, 40));
        // Drain 5 SS grants off the CAS word, then rebind to GSS.
        for _ in 0..5 {
            shared.try_grant().unwrap();
        }
        assert!(f.rebind(TechniqueKind::Gss));
        assert_eq!(f.bound_kind(), TechniqueKind::Gss);
        // The republished chunk is the 35-iteration remainder under GSS.
        let (a, _, seq) = shared.try_grant().unwrap();
        assert_eq!((a.step, a.start, a.size), (0, 5, 9), "GSS step 0 over (35, 4)");
        assert_eq!(seq, 2, "republish bumped the seq");
        let mut starts = vec![a.start];
        while let Some((a, _rem)) = f.grant() {
            starts.push(a.start);
        }
        starts.sort_unstable();
        assert_eq!(starts[0], 5);
        assert!(*starts.last().unwrap() < 40);
        assert!(!f.has_work());
    }

    #[test]
    fn fast_ledger_demote_hands_back_every_unassigned_range() {
        let base = LoopParams::new(10_000, 8);
        let shared = Arc::new(AtomicLedger::new());
        let mut f = FastLedger::new(Arc::clone(&shared), TechniqueKind::Ss, &base, 2, 3);
        f.install(chunk(0, 10));
        f.install(chunk(10, 5));
        f.install(chunk(15, 3));
        for _ in 0..4 {
            shared.try_grant().unwrap();
        }
        let moved = f.demote();
        assert_eq!(
            moved,
            vec![chunk(4, 6), chunk(10, 5), chunk(15, 3)],
            "remainder first, staged FIFO after"
        );
        assert_eq!(shared.try_grant(), None, "demoted word grants nothing ever again");
        // The moved ranges install cleanly into a two-phase ledger.
        let mut l = ledger(TechniqueKind::Tap, 2).with_staged_capacity(3);
        l.rebind(TechniqueKind::Tap);
        for a in moved {
            l.install(a);
        }
        let mut total = 0;
        while let Some((s, _, q)) = l.reserve() {
            let k = l.closed_inner_size(s, q).unwrap();
            let InnerCommit::Granted(a) = l.commit(s, k, q) else { panic!("grant") };
            total += a.size;
        }
        assert_eq!(total, 14, "6 + 5 + 3 unassigned iterations survive the demotion");
    }

    #[test]
    fn staged_queue_is_fifo_and_bounded() {
        let q = StagedChunkQueue::with_capacity(2);
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        q.push(chunk(0, 4)).unwrap();
        q.push(chunk(4, 4)).unwrap();
        assert_eq!(q.len(), 2);
        // Full ring: the chunk is handed back, not dropped.
        assert_eq!(q.push(chunk(8, 4)), Err(chunk(8, 4)));
        assert_eq!(q.pop(), Some(chunk(0, 4)));
        q.push(chunk(8, 4)).unwrap();
        assert_eq!(q.pop(), Some(chunk(4, 4)));
        assert_eq!(q.pop(), Some(chunk(8, 4)));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    /// Multi-producer smoke test: racing stagers lose no chunk and the
    /// consumer drains every one exactly once.
    #[test]
    fn staged_queue_concurrent_producers_lose_nothing() {
        let q = Arc::new(StagedChunkQueue::with_capacity(8));
        const PRODUCERS: u64 = 4;
        const PER: u64 = 256;
        let mut handles = Vec::new();
        for t in 0..PRODUCERS {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..PER {
                    let a = chunk(t * PER + i, 1);
                    let mut item = a;
                    while let Err(back) = q.push(item) {
                        item = back;
                        std::thread::yield_now();
                    }
                }
            }));
        }
        let mut got = Vec::new();
        while got.len() < (PRODUCERS * PER) as usize {
            match q.pop() {
                Some(a) => got.push(a),
                None => std::thread::yield_now(),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.pop(), None, "nothing beyond the staged total");
        got.sort_unstable_by_key(|a| a.start);
        verify_coverage(&got, PRODUCERS * PER).unwrap();
    }

    /// The staging ring feeds [`FastLedger`] installs: drained chunks
    /// publish/stage exactly like direct installs and grants cover them.
    #[test]
    fn staged_queue_drains_into_fast_ledger() {
        let base = LoopParams::new(10_000, 8);
        let shared = Arc::new(AtomicLedger::new());
        let mut f = FastLedger::new(Arc::clone(&shared), TechniqueKind::Ss, &base, 2, 4);
        let q = StagedChunkQueue::with_capacity(4);
        q.push(chunk(0, 3)).unwrap();
        q.push(chunk(3, 2)).unwrap();
        q.push(chunk(5, 4)).unwrap();
        assert_eq!(f.absorb_staged(&q), 3);
        assert!(q.is_empty());
        let mut starts = Vec::new();
        while let Some((a, _rem)) = f.grant() {
            starts.push(a.start);
        }
        assert_eq!(starts, vec![0, 1, 2, 3, 4, 5, 6, 7, 8], "FIFO installs, no gaps");
        assert!(!f.has_work());
    }

    #[test]
    fn with_np_overrides_only_n_and_p() {
        let base = LoopParams::new(1_000, 16);
        let out = with_np(&base, 64, 4);
        assert_eq!(out.n, 64);
        assert_eq!(out.p, 4);
        assert_eq!(out.fiss_b, base.fiss_b);
        assert_eq!(out.rnd_seed, base.rnd_seed);
        let clamped = with_np(&base, 0, 0);
        assert_eq!(clamped.n, 1);
        assert_eq!(clamped.p, 1);
    }
}
