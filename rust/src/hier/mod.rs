//! Recursive N-level hierarchical self-scheduling — the `HierDca`
//! execution model.
//!
//! Generalizes the §7 future-work direction the authors pursued in
//! *Hierarchical Dynamic Loop Self-Scheduling on Distributed-Memory Systems
//! Using an MPI+MPI Approach* (arXiv 1903.09510) from the fixed two-level
//! pair to a depth-`k` scheduling tree described by a
//! [`crate::config::LevelPlan`] (technique + fan-out + latency class per
//! level):
//!
//! * **Level 0 (the root)** — rank 0 hosts the loop's global ledger,
//!   pre-installed with the whole iteration space, and hands out **level-0
//!   chunks** to the `fanout₀` level-1 masters through the DCA two-phase
//!   protocol. Chunk sizes are computed **on the requesting masters** with
//!   the level-0 technique bound to `P = fanout₀` — distributed chunk
//!   calculation at tree granularity.
//! * **Levels 1..k-1 (intermediate and leaf-serving masters)** — each
//!   level-`d` master (the first rank of its subtree, block placement) owns
//!   a [`protocol::NodeLedger`] that re-subdivides the chunks it fetched
//!   from its level-`d-1` parent among its `fanout_d` children with the
//!   level-`d` technique, over that level's (cheaper) latency class. The
//!   deepest masters serve leaf ranks, which self-schedule exactly like
//!   flat DCA workers.
//!
//! Depth 1 degenerates to the flat DCA protocol (root ↔ all ranks), depth 2
//! is the classic two-level hierarchy, depth 3 is the ROADMAP's rack → node
//! → socket tree over the cluster's latency *triple*
//! ([`crate::substrate::topology::Topology`] rack tier). Every level nests
//! the **same serving loop**: two-phase reserve/commit against the shared
//! ledger, stale-`seq` NACKs, park-and-fetch on exhaustion, and staged
//! prefetch — two-level behavior is bit-identical to the previous
//! hard-coded implementation.
//!
//! A physical rank can host several master personas (rank 0 hosts the root
//! plus one persona per level of its subtree spine); all personas of a rank
//! share one serial CPU and one service queue, so coordination and
//! mastering contend exactly as on the real machine.
//!
//! Like the flat models, every lowest-level master is **non-dedicated**
//! when `break_after > 0`: it interleaves its own iteration execution with
//! servicing its children.
//!
//! AF (no closed form, §4) is supported at *every* level through the same
//! extra synchronization the flat DCA coordinator uses: performance reports
//! piggyback on requests, the phase-1 reply carries the `(D, E)`
//! aggregates, and the requester evaluates Eq. 11 locally. At master levels
//! the "PE statistics" are per-subtree throughput (iterations per
//! wall-second of an installed chunk); at the leaf level they are the usual
//! per-rank chunk stats.
//!
//! The per-level chunk ledger (two-phase reserve/commit, stale-`seq` NACK,
//! staged prefetch queue) lives in [`protocol`] and is shared verbatim with
//! the **threaded** engine, [`crate::coordinator::hier`] — the DES and the
//! wall-clock engine validate one protocol definition at every depth.
//! [`crate::config::HierParams::watermark`] enables prefetch on both
//! substrates: masters request the next chunk while the current one still
//! has work. [`crate::config::WatermarkMode::Auto`] derives the watermark
//! per level master from an EWMA of its observed parent-fetch round trip
//! and its subtree's measured drain rate, so the round trip is hidden
//! without hand tuning.

pub mod protocol;

use std::collections::VecDeque;
use std::sync::Arc;

use crate::config::{ClusterConfig, ExecutionModel, HierParams, LevelPlan, SchedPath, WatermarkMode};
use crate::coordinator::protocol::{AfInfo, PerfReport};
use crate::des::heap::{ns, secs, EventHeap};
use crate::des::{min_latency_ns, pdes, resolved_des_threads, DesConfig, DesResult, PdesSummary};
use crate::metrics::LoopStats;
use crate::obs::stream::{self, IntervalSample, Sampler};
use crate::report::json::Json;
use crate::sched::adaptive::{AdaptiveController, SwitchEvent};
use crate::sched::Assignment;
use crate::substrate::topology::Topology;
use crate::techniques::af::{af_requester_chunk, AfCalculator, AfGlobals, PeStats};
use crate::techniques::TechniqueKind;
use protocol::{auto_watermark, with_np, InnerCommit, NodeLedger, RttEwma};

/// Can `HierDca` run on this geometry? With dedicated masters
/// (`break_after == 0`) every lowest-level group needs at least one
/// non-master rank to execute iterations, and the level plan itself must
/// resolve. Single source of truth for [`simulate_hier`]'s validation and
/// the selector's candidate filtering.
pub fn hier_feasible(cluster: &ClusterConfig, hier: &HierParams) -> bool {
    hier.plan(TechniqueKind::Ss, cluster.total_ranks(), cluster)
        .is_ok_and(|plan| {
            cluster.break_after > 0 || plan.levels[plan.depth() - 1].fanout > 1
        })
}

/// Simulate one hierarchical (`HierDca`) run at any tree depth.
/// Deterministic: same config ⇒ identical result. Called through
/// [`crate::des::simulate`], which performs the model-independent
/// validation.
pub fn simulate_hier(cfg: &DesConfig) -> anyhow::Result<DesResult> {
    anyhow::ensure!(
        cfg.model == ExecutionModel::HierDca,
        "simulate_hier requires ExecutionModel::HierDca, got {}",
        cfg.model
    );
    anyhow::ensure!(
        cfg.params.p == cfg.cluster.total_ranks(),
        "LoopParams.p ({}) must equal cluster ranks ({})",
        cfg.params.p,
        cfg.cluster.total_ranks()
    );
    let plan = cfg.hier.plan(cfg.technique, cfg.params.p, &cfg.cluster)?;
    anyhow::ensure!(
        cfg.cluster.break_after > 0 || plan.levels[plan.depth() - 1].fanout > 1,
        "dedicated masters (break_after = 0) need a leaf fan-out ≥ 2, \
         otherwise no rank executes iterations"
    );
    anyhow::ensure!(
        !(cfg.hier.master_lockfree && cfg.hier.adaptive.enabled),
        "--master-lockfree cannot run with --adaptive: a rebind would race \
         in-flight fused master fetches"
    );
    if cfg.des_threads != 1 {
        return simulate_hier_pdes(cfg, &plan);
    }
    let mut sim = HierSim::new(cfg, &plan);
    sim.run();
    Ok(sim.into_result())
}

// ---------------------------------------------------------------------------
// events and tasks

/// A task queued at a hosting rank's serial CPU. `level` always names the
/// *protocol* level `d` (0 = root ↔ level-1 masters, `k-1` = leaf-serving
/// masters ↔ leaf ranks); master-tier child identities are level-`d+1`
/// master indices.
#[derive(Debug, Clone)]
enum Task {
    /// A leaf rank asks its master for a scheduling step (phase 1).
    LeafGet { w: u32, report: Option<PerfReport> },
    /// A leaf rank commits its locally calculated size (phase 2); `seq`
    /// names the chunk the step was reserved from.
    LeafCommit { w: u32, step: u64, size: u64, seq: u64 },
    /// Level-`level+1` master `from` asks its level-`level` parent for a
    /// step of the parent's chunk.
    MasterGet { level: u32, from: u32, report: Option<PerfReport> },
    /// Master `from` commits its chunk size to its parent.
    MasterCommit { level: u32, from: u32, step: u64, size: u64, seq: u64 },
    /// Parent reply: reserved step (+ AF aggregates). Handling it *is* the
    /// chunk calculation, on the child master's CPU.
    MasterStep { level: u32, to: u32, step: u64, remaining: u64, seq: u64, af: Option<AfInfo> },
    /// Parent reply: the committed chunk, to be installed into `to`'s
    /// ledger.
    MasterChunk { level: u32, to: u32, a: Assignment },
    /// Parent reply: the parent's share of the loop is exhausted for good.
    MasterDone { level: u32, to: u32 },
}

/// Leaf-protocol reply delivered to a worker rank.
#[derive(Debug, Clone, Copy)]
enum WReply {
    /// Reserved local step: the worker calculates its own sub-chunk size.
    Step { step: u64, remaining: u64, seq: u64, af: Option<AfInfo> },
    /// Committed sub-chunk (absolute iteration range).
    Chunk(Assignment),
    /// Terminate.
    Done,
}

#[derive(Debug, Clone)]
enum Ev {
    /// A message arrives at hosting rank `s`'s service queue.
    Arrive { s: u32, task: Task },
    /// Host `s`'s CPU finished its current action.
    ServerFree { s: u32 },
    /// A leaf reply reaches worker `w`.
    WorkerReply { w: u32, reply: WReply },
    /// Worker `w` finished its local sub-chunk calculation.
    CalcDone { w: u32, step: u64, size: u64, seq: u64 },
    /// Worker `w` finished executing its sub-chunk.
    ExecDone { w: u32 },
    /// Lock-free fast path: worker `w`'s fused CAS grant arrives at leaf
    /// group `s`'s atomic unit (the node ledger's cache line — serialized
    /// like the RMA window NIC, bypassing the master's CPU entirely).
    AtomArrive { s: u32, w: u32 },
    /// Group `s`'s atomic unit finished its current op.
    AtomFree { s: u32 },
    /// Master-tier fast path (`--master-lockfree`): child master `from`'s
    /// fused fetch arrives at persona `(d, j)`'s atomic unit — the parent
    /// ledger's cache line, bypassing the parent's CPU.
    MasterAtomArrive { d: u32, j: u32, from: u32 },
    /// Persona `(d, j)`'s atomic unit finished its current op.
    MasterAtomFree { d: u32, j: u32 },
}

// ---------------------------------------------------------------------------
// state

/// The lowest master's own worker personality (mirrors the flat DES's
/// `OwnState`).
#[derive(Debug, Clone)]
enum Own {
    NeedWork,
    Calc { step: u64, remaining: u64, seq: u64 },
    Commit { step: u64, size: u64, seq: u64 },
    Exec { cursor: u64, end: u64, first: u64 },
    /// Waiting for the next chunk (or the global Done).
    Parked,
    Finished,
}

/// One level-`d` master persona: the server side (its ledger and parked
/// children) plus its child side in protocol `d-1` (fetch state and subtree
/// throughput — unused for the root, which has no parent and is born
/// `global_done` with the whole loop installed).
#[derive(Debug, Clone)]
struct Persona {
    rank: u32,
    ledger: NodeLedger,
    /// Children whose requests arrived while the ledger was empty: leaf
    /// ranks at the deepest level, child master indices elsewhere.
    parked: VecDeque<u32>,
    /// AF calculator over this persona's children (when this level runs AF).
    af_calc: Option<AfCalculator>,
    // -- child side (role in protocol `d-1`) --
    fetching: bool,
    global_done: bool,
    /// Subtree chunk-throughput statistics (outer-AF feedback + the
    /// adaptive watermark's drain-rate estimate).
    stats: PeStats,
    pending_report: Option<PerfReport>,
    installed_ns: u64,
    installed_iters: u64,
    /// When the in-flight parent fetch was issued (adaptive watermark).
    fetch_sent_ns: u64,
    /// EWMA of observed parent-fetch round trips (shared protocol policy).
    rtt: RttEwma,
    /// SimAS-style controller re-binding this persona's technique slot
    /// (`--adaptive`; levels ≥ 1 — the root's ledger is installed once and
    /// its outer technique stays static).
    adapt: Option<AdaptiveController>,
}

/// One hosting rank (a lowest-level master): serial CPU, task queue, and
/// the own worker personality. Host 0 additionally runs the root persona
/// and every intermediate persona of its subtree spine.
#[derive(Debug, Clone)]
struct Server {
    rank: u32,
    queue: VecDeque<Task>,
    busy: bool,
    /// Last instant this CPU is known busy until (ns).
    cpu_busy_until_ns: u64,
    /// Total busy time spent servicing protocol messages (ns).
    service_ns: u64,
    own: Own,
    own_parked: bool,
}

/// Per-rank bookkeeping (all ranks, including masters' worker personality).
#[derive(Debug, Default, Clone)]
struct Wstate {
    chunks: u64,
    iters: u64,
    finish_ns: u64,
    wait_ns: u64,
    req_sent_ns: u64,
    stats: PeStats,
    last_report: Option<PerfReport>,
}

/// `Clone` because a PDES shard checkpoint (optimistic-window rollback)
/// is a full snapshot of this struct — `EventHeap` clones its `seq`
/// counter, so replayed pushes renumber identically.
#[derive(Clone)]
struct HierSim<'a> {
    cfg: &'a DesConfig,
    topo: Topology,
    heap: EventHeap<Ev>,
    now: u64,
    /// The resolved scheduling tree — the single source of the placement
    /// math (shared with the threaded engine's geometry).
    plan: LevelPlan,
    /// Tree depth `k`.
    k: usize,
    /// Children per level-`d` master (hot copy of `plan`'s fan-outs).
    /// (Per-level techniques live on the re-bindable ledger slots now —
    /// the configured plan is only their initial value.)
    fanouts: Vec<u32>,
    /// `personas[d][j]`: level-`d` master `j` (`personas[0]` = the root).
    personas: Vec<Vec<Persona>>,
    servers: Vec<Server>,
    workers: Vec<Wstate>,
    messages: u64,
    /// Message split by latency class (same-node vs cross-node endpoints).
    intra_msgs: u64,
    inter_msgs: u64,
    /// Message split by protocol level (outer first).
    level_msgs: Vec<u64>,
    assignments: Vec<Assignment>,
    chunks_granted: u64,
    /// Per-leaf-group lock-free fast path (`SchedPath::{LockFree, Auto}` +
    /// a fast-path leaf technique). Master-tier fetches always stay
    /// two-phase. Under `Auto`, a group is **demoted** to `false` the
    /// moment its adaptive controller rebinds the slot to a
    /// measurement-coupled technique (TAP) — per subtree, permanently.
    fast_group: Vec<bool>,
    /// Per-leaf-group atomic unit: pending fused ops + busy flag.
    atom_queue: Vec<VecDeque<u32>>,
    atom_busy: Vec<bool>,
    /// Master-tier fast path per protocol level `d < k-1`
    /// (`--master-lockfree` + a closed-form level technique): parent
    /// fetches become fused ops at the parent persona's atomic unit.
    master_fast: Vec<bool>,
    /// Per-persona master-tier atomic units (`[d][j]`, levels `0..k-1`):
    /// pending fused fetches (child master indices) + busy flag.
    matom_queue: Vec<Vec<VecDeque<u32>>>,
    matom_busy: Vec<Vec<bool>>,
    fast_grants: u64,
    events: u64,
    /// Technique-slot rebinds, in decision order.
    switch_events: Vec<SwitchEvent>,
    /// Iterations granted so far (`remaining = n - iters_granted` for the
    /// observability stream — cheaper than summing per-rank counters).
    iters_granted: u64,
    // observability stream
    sampler: Option<Sampler>,
    stream: Vec<Json>,
    last_tick_chunks: u64,
    /// Sharded-mode raw tick samples (one per sampler boundary), merged
    /// across shards post-run into the `interval` records a sequential
    /// run would have produced. Empty in sequential mode.
    ticks: Vec<HierTick>,
    // parallel-core sharding (None ⇒ the classic sequential loop)
    shard: Option<HierShardSpan>,
    /// Cross-shard sends staged during the current window:
    /// `(destination shard, arrival time, event)`.
    outbound: Vec<(u32, u64, Ev)>,
}

/// A shard's identity in the sharded (PDES) run. Shards group *contiguous
/// hosting servers* (leaf-protocol traffic therefore never crosses a
/// shard); `of_server[s]` maps every hosting server to its owning shard.
/// The grouping is geometry-derived and thread-independent.
#[derive(Debug, Clone)]
struct HierShardSpan {
    id: u32,
    of_server: Arc<Vec<u32>>,
}

/// One raw stream sample captured by a shard at a tick boundary: the
/// shard's *local* contribution to the distributed counters plus the
/// subtree entries of the personas it owns (pre-rendered — a subtree
/// entry is a pure function of persona state at the tick). The post-run
/// merge sums counters across shards (extending a finished shard's series
/// with its final values) and unions the subtree entries in `(level,
/// master)` order, reproducing the sequential records bit-for-bit.
#[derive(Debug, Clone)]
struct HierTick {
    chunks: u64,
    messages: u64,
    fast_grants: u64,
    iters_granted: u64,
    /// `(level, master, entry)` for every persona this shard owns.
    subtrees: Vec<(u32, u32, Json)>,
}

impl<'a> HierSim<'a> {
    fn new(cfg: &'a DesConfig, plan: &LevelPlan) -> Self {
        let n = cfg.params.n;
        let k = plan.depth();
        let fanouts: Vec<u32> = plan.levels.iter().map(|l| l.fanout).collect();
        let techs: Vec<TechniqueKind> = plan.techs();
        let staged_cap = cfg.hier.staged_capacity();
        let fast_initial =
            cfg.sched_path.wants_lockfree() && techs[k - 1].supports_fast_path();
        // Pure LockFree restricts leaf candidates to fast-path techniques so
        // a rebind never has to demote the subtree; Auto keeps the full set
        // and demotes instead.
        let leaf_fast_only = cfg.sched_path == SchedPath::LockFree && fast_initial;
        let mut personas: Vec<Vec<Persona>> = Vec::with_capacity(k);
        for d in 0..k {
            let masters = plan.masters_at(d);
            let level_params = with_np(&cfg.params, n, fanouts[d]);
            let level = (0..masters)
                .map(|j| Persona {
                    rank: plan.host_rank(d, j),
                    ledger: NodeLedger::new(techs[d], &cfg.params, fanouts[d])
                        .with_staged_capacity(staged_cap),
                    parked: VecDeque::new(),
                    af_calc: (techs[d] == TechniqueKind::Af)
                        .then(|| AfCalculator::new(&level_params)),
                    fetching: false,
                    global_done: d == 0,
                    stats: PeStats::default(),
                    pending_report: None,
                    installed_ns: 0,
                    installed_iters: 0,
                    fetch_sent_ns: 0,
                    rtt: RttEwma::default(),
                    // The root's chunk is installed once and never replaced;
                    // adaptivity drives the subtree ledgers below it.
                    adapt: (cfg.hier.adaptive.enabled && d > 0).then(|| {
                        AdaptiveController::new(
                            techs[d],
                            &cfg.params,
                            fanouts[d],
                            cfg.hier.adaptive,
                            leaf_fast_only && d == k - 1,
                        )
                    }),
                })
                .collect();
            personas.push(level);
        }
        // The root owns the whole loop from the start: one install of
        // `[0, N)`, never replaced (its `seq` stays 1, so no commit against
        // it can ever be stale).
        personas[0][0].ledger.install(Assignment { step: 0, start: 0, size: n });
        let servers = (0..plan.masters_at(k - 1))
            .map(|s| Server {
                rank: plan.host_rank(k - 1, s),
                queue: VecDeque::new(),
                busy: false,
                cpu_busy_until_ns: 0,
                service_ns: 0,
                own: Own::NeedWork,
                own_parked: false,
            })
            .collect();
        let n_servers = plan.masters_at(k - 1) as usize;
        // Master-tier fast path per level: opt-in, lock-free sched path,
        // closed-form technique, and never adaptive (rebinds would race the
        // fused fetches the same way measurement-coupled leaves would).
        let master_fast: Vec<bool> = (0..k)
            .map(|d| {
                d < k - 1
                    && cfg.hier.master_lockfree
                    && cfg.sched_path.wants_lockfree()
                    && techs[d].supports_fast_path()
                    && !cfg.hier.adaptive.enabled
            })
            .collect();
        let matom_queue: Vec<Vec<VecDeque<u32>>> =
            (0..k).map(|d| vec![VecDeque::new(); plan.masters_at(d) as usize]).collect();
        let matom_busy: Vec<Vec<bool>> =
            (0..k).map(|d| vec![false; plan.masters_at(d) as usize]).collect();
        HierSim {
            cfg,
            topo: Topology::new(&cfg.cluster),
            heap: EventHeap::for_latency_scale(
                2 * cfg.params.p as usize,
                min_latency_ns(&cfg.cluster),
            ),
            now: 0,
            plan: plan.clone(),
            k,
            fanouts,
            personas,
            servers,
            workers: vec![Wstate::default(); cfg.params.p as usize],
            messages: 0,
            intra_msgs: 0,
            inter_msgs: 0,
            level_msgs: vec![0; k],
            assignments: crate::des::assignments_buffer(cfg),
            chunks_granted: 0,
            fast_group: vec![fast_initial; n_servers],
            atom_queue: vec![VecDeque::new(); n_servers],
            atom_busy: vec![false; n_servers],
            master_fast,
            matom_queue,
            matom_busy,
            fast_grants: 0,
            events: 0,
            switch_events: Vec::new(),
            iters_granted: 0,
            sampler: Sampler::from_interval_s(cfg.stream_interval),
            stream: Vec::new(),
            last_tick_chunks: 0,
            ticks: Vec::new(),
            shard: None,
            outbound: Vec::new(),
        }
    }

    fn new_shard(cfg: &'a DesConfig, plan: &LevelPlan, span: HierShardSpan) -> Self {
        let mut sim = HierSim::new(cfg, plan);
        sim.shard = Some(span);
        sim
    }

    fn owns_server(&self, s: u32) -> bool {
        match &self.shard {
            None => true,
            Some(sh) => sh.of_server[s as usize] == sh.id,
        }
    }

    /// Hosting server whose shard must process this event.
    fn dest_server(&self, ev: &Ev) -> u32 {
        match ev {
            Ev::Arrive { s, .. }
            | Ev::ServerFree { s }
            | Ev::AtomArrive { s, .. }
            | Ev::AtomFree { s } => *s,
            Ev::WorkerReply { w, .. } | Ev::CalcDone { w, .. } | Ev::ExecDone { w } => {
                self.server_of_rank(*w)
            }
            Ev::MasterAtomArrive { d, j, .. } | Ev::MasterAtomFree { d, j } => {
                self.server_of_rank(self.host_rank(*d as usize, *j))
            }
        }
    }

    /// Push an event, staging it for the barrier exchange when its
    /// destination lives on another shard. Only master-protocol traffic
    /// (any level `d < k-1`) can cross shards — the partition groups whole
    /// hosting servers, so leaf sends always stay local — and every
    /// master-tier send site goes through here.
    fn route(&mut self, at: u64, ev: Ev) {
        let dst = match &self.shard {
            None => {
                self.heap.push(at, ev);
                return;
            }
            Some(sh) => sh.of_server[self.dest_server(&ev) as usize],
        };
        match &self.shard {
            Some(sh) if dst != sh.id => self.outbound.push((dst, at, ev)),
            _ => self.heap.push(at, ev),
        }
    }

    /// Is leaf group `s` (still) on the lock-free fast path?
    fn group_fast(&self, s: u32) -> bool {
        self.fast_group[s as usize]
    }

    /// Count one grant served from persona `(e, j)`'s ledger toward its
    /// probe cadence; on a due probe, rebind the slot mid-chunk
    /// ([`NodeLedger::rebind_now`] — in-flight commits NACK via the
    /// stale-`seq` protocol) and, at a leaf group whose new binding cannot
    /// take the fast path, demote the group to two-phase (`SchedPath::Auto`).
    fn adaptive_tick(&mut self, e: usize, j: u32) {
        let ji = j as usize;
        let due = match self.personas[e][ji].adapt.as_mut() {
            Some(ctl) => ctl.tick_grant(),
            None => return,
        };
        if !due {
            return;
        }
        let remaining = self.personas[e][ji].ledger.remaining();
        let from = self.personas[e][ji].ledger.bound_kind();
        let decision =
            self.personas[e][ji].adapt.as_mut().expect("checked above").probe(remaining);
        let Some((to, predicted_ratio)) = decision else { return };
        if e == self.k - 1 && !to.supports_fast_path() {
            // Demote BEFORE the rebind so no fused grant can ever race a
            // measurement-coupled binding.
            self.fast_group[ji] = false;
        }
        self.personas[e][ji].ledger.rebind_now(to);
        self.switch_events.push(SwitchEvent {
            at_s: secs(self.now),
            level: e as u32,
            master: j,
            from,
            to,
            predicted_ratio,
        });
    }

    // -- small helpers -----------------------------------------------------

    fn speed(&self, rank: u32) -> f64 {
        self.cfg.pe_speed.get(rank as usize).copied().unwrap_or(1.0).max(1e-9)
    }

    fn lat_ns(&self, a: u32, b: u32) -> u64 {
        ns(self.topo.latency(a, b))
    }

    fn min_chunk(&self) -> u64 {
        self.cfg.params.min_chunk.max(1)
    }

    fn exec_ns(&self, rank: u32, a: Assignment) -> u64 {
        ns(self.cfg.cost.range_cost(a.start, a.size) / self.speed(rank))
    }

    /// Rank hosting level-`d` master `j` (delegates to the plan — one
    /// definition of the placement math for both substrates).
    fn host_rank(&self, d: usize, j: u32) -> u32 {
        self.plan.host_rank(d, j)
    }

    /// Hosting-server index of a rank (its lowest-level master).
    fn server_of_rank(&self, rank: u32) -> u32 {
        rank / self.fanouts[self.k - 1]
    }

    fn persona_af_info(&self, d: usize, j: u32) -> Option<AfInfo> {
        self.personas[d][j as usize]
            .af_calc
            .as_ref()
            .and_then(|a| a.globals())
            .map(|g| AfInfo { d: g.d, e: g.e })
    }

    fn grant(&mut self, rank: u32, a: Assignment) {
        self.chunks_granted += 1;
        self.iters_granted += a.size;
        if self.cfg.record_assignments {
            self.assignments.push(a);
        }
        let ws = &mut self.workers[rank as usize];
        ws.chunks += 1;
        ws.iters += a.size;
    }

    // -- bootstrap ---------------------------------------------------------

    /// Seed the opening events. On a sharded run each shard seeds only the
    /// leaf groups it owns; every bootstrap send is group-local (worker →
    /// own master), so nothing is staged across shards here — the first
    /// root fetch chain starts inside the event loop proper.
    fn bootstrap(&mut self) {
        // Every non-master rank opens with a LeafGet to its master (a fused
        // CAS op on the fast path); hosting ranks kick their own CPU, which
        // parks its worker personality and triggers the first fetch chain
        // up to the root.
        let leaf_fanout = self.fanouts[self.k - 1];
        for w in 0..self.cfg.params.p {
            if w % leaf_fanout == 0 || !self.owns_server(self.server_of_rank(w)) {
                continue;
            }
            self.workers[w as usize].req_sent_ns = 0;
            if self.group_fast(self.server_of_rank(w)) {
                self.send_atomic(w, 0);
            } else {
                self.send_leaf(w, Task::LeafGet { w, report: None }, 0);
            }
        }
        for s in 0..self.servers.len() as u32 {
            if !self.owns_server(s) {
                continue;
            }
            if self.cfg.cluster.break_after == 0 {
                self.servers[s as usize].own = Own::Finished;
            }
            self.servers[s as usize].busy = true;
            self.heap.push(0, Ev::ServerFree { s });
        }
    }

    fn run(&mut self) {
        self.bootstrap();
        while let Some((t, ev)) = self.heap.pop() {
            debug_assert!(t >= self.now, "time went backwards");
            self.now = t;
            self.events += 1;
            if self.sampler.is_some() {
                self.sample_ticks();
            }
            self.dispatch(ev);
        }
    }

    /// One `subtrees` entry per master persona: the slot's current binding,
    /// its ledger's unconsumed iterations, parked children, and (when
    /// adaptive) its controller's EWMAs.
    fn subtree_entries(&self) -> Vec<Json> {
        let mut entries = Vec::new();
        for (d, level) in self.personas.iter().enumerate() {
            for (j, pr) in level.iter().enumerate() {
                entries.push(stream::subtree_entry(
                    d as u32,
                    j as u32,
                    pr.ledger.bound_kind(),
                    pr.ledger.remaining(),
                    pr.parked.len() as u64,
                    pr.adapt.as_ref(),
                ));
            }
        }
        entries
    }

    /// Raw sharded-mode sample: this shard's contribution to the
    /// distributed counters plus its owned personas' subtree entries. Also
    /// serves as a shard's "final value" when the post-run merge extends a
    /// finished shard's series past its last event.
    fn tick_sample(&self) -> HierTick {
        HierTick {
            chunks: self.chunks_granted,
            messages: self.messages,
            fast_grants: self.fast_grants,
            iters_granted: self.iters_granted,
            subtrees: self.owned_subtree_entries(),
        }
    }

    /// `(level, master, entry)` for every persona hosted on a server this
    /// shard owns. The ownership partition covers each persona exactly
    /// once (the root lives on server 0 → shard 0), so the merged union
    /// over shards reproduces [`Self::subtree_entries`] in `(level,
    /// master)` order.
    fn owned_subtree_entries(&self) -> Vec<(u32, u32, Json)> {
        let mut entries = Vec::new();
        for (d, level) in self.personas.iter().enumerate() {
            for (j, pr) in level.iter().enumerate() {
                if !self.owns_server(self.server_of_rank(self.host_rank(d, j as u32))) {
                    continue;
                }
                entries.push((
                    d as u32,
                    j as u32,
                    stream::subtree_entry(
                        d as u32,
                        j as u32,
                        pr.ledger.bound_kind(),
                        pr.ledger.remaining(),
                        pr.parked.len() as u64,
                        pr.adapt.as_ref(),
                    ),
                ));
            }
        }
        entries
    }

    /// Emit one `interval` record (core counters + the per-subtree array)
    /// per virtual-time tick boundary the event loop just crossed. Sharded
    /// runs record raw [`HierTick`] samples instead — every shard observes
    /// the same boundary grid ([`Sampler::due`] never skips a tick), so
    /// the post-run merge can sum counters index-by-index.
    fn sample_ticks(&mut self) {
        let Some(mut sampler) = self.sampler.take() else { return };
        while let Some(t) = sampler.due(self.now) {
            if self.shard.is_some() {
                let sample = self.tick_sample();
                self.ticks.push(sample);
                continue;
            }
            let record = stream::interval_record(&IntervalSample {
                t,
                chunks: self.chunks_granted,
                chunks_delta: self.chunks_granted - self.last_tick_chunks,
                interval_s: sampler.interval_s(),
                messages: self.messages,
                fast_grants: self.fast_grants,
                remaining: self.cfg.params.n - self.iters_granted,
            })
            .field("subtrees", self.subtree_entries());
            self.stream.push(record);
            self.last_tick_chunks = self.chunks_granted;
        }
        self.sampler = Some(sampler);
    }

    fn dispatch(&mut self, ev: Ev) {
        match ev {
            Ev::Arrive { s, task } => {
                let server = &mut self.servers[s as usize];
                server.queue.push_back(task);
                if !server.busy {
                    server.busy = true;
                    self.heap.push(self.now, Ev::ServerFree { s });
                }
            }
            Ev::ServerFree { s } => self.server_next_action(s),
            Ev::WorkerReply { w, reply } => self.worker_on_reply(w, reply),
            Ev::CalcDone { w, step, size, seq } => {
                self.workers[w as usize].req_sent_ns = self.now;
                self.send_leaf(w, Task::LeafCommit { w, step, size, seq }, 0);
            }
            Ev::ExecDone { w } => {
                self.workers[w as usize].req_sent_ns = self.now;
                if self.group_fast(self.server_of_rank(w)) {
                    self.send_atomic(w, 0);
                } else {
                    let report = self.workers[w as usize].last_report;
                    self.send_leaf(w, Task::LeafGet { w, report }, 0);
                }
            }
            Ev::AtomArrive { s, w } => {
                self.atom_queue[s as usize].push_back(w);
                if !self.atom_busy[s as usize] {
                    self.atom_busy[s as usize] = true;
                    self.heap.push(self.now, Ev::AtomFree { s });
                }
            }
            Ev::AtomFree { s } => self.atom_next_op(s),
            Ev::MasterAtomArrive { d, j, from } => {
                self.matom_queue[d as usize][j as usize].push_back(from);
                if !self.matom_busy[d as usize][j as usize] {
                    self.matom_busy[d as usize][j as usize] = true;
                    self.heap.push(self.now, Ev::MasterAtomFree { d, j });
                }
            }
            Ev::MasterAtomFree { d, j } => self.matom_next_op(d as usize, j),
        }
    }

    /// Issue worker `w`'s fused CAS op toward its group's atomic unit
    /// (travel = the intra-group latency class; not a protocol message).
    fn send_atomic(&mut self, w: u32, extra_ns: u64) {
        let s = self.server_of_rank(w);
        let mrank = self.servers[s as usize].rank;
        let at = self.now + extra_ns + self.lat_ns(w, mrank);
        self.heap.push(at, Ev::AtomArrive { s, w });
    }

    /// Serve one fused op at leaf group `s`'s atomic unit: reserve + table
    /// lookup + commit in a single `service_time` occupancy (a memory/NIC
    /// resource — NOT the master's CPU, and unscaled by its speed). The
    /// table lookup replaces the chunk calculation, so neither `calc_time`
    /// nor the injected calculation delay is paid — the fast path's whole
    /// payoff. Drained ledgers fall back to the two-phase slow path: the
    /// master parks the rank and runs the parent fetch protocol.
    fn atom_next_op(&mut self, s: u32) {
        let si = s as usize;
        let Some(w) = self.atom_queue[si].pop_front() else {
            self.atom_busy[si] = false;
            return;
        };
        let k1 = self.k - 1;
        if !self.fast_group[si] {
            // The group was demoted (`SchedPath::Auto` rebind to a
            // measurement-coupled technique) while this fused op was in
            // flight: it lands on the master's service queue as a plain
            // phase-1 request instead (the op already traveled, so no new
            // protocol message is charged).
            self.heap.push(self.now, Ev::Arrive { s, task: Task::LeafGet { w, report: None } });
            self.heap.push(self.now, Ev::AtomFree { s });
            self.atom_busy[si] = true;
            return;
        }
        let dur = ns(self.cfg.cluster.service_time);
        match self.personas[k1][si].ledger.fast_grant() {
            Some(a) => {
                self.fast_grants += 1;
                self.grant(w, a);
                self.adaptive_tick(k1, s);
                let mrank = self.servers[si].rank;
                let at = self.now + dur + self.lat_ns(mrank, w);
                self.heap.push(at, Ev::WorkerReply { w, reply: WReply::Chunk(a) });
                self.maybe_prefetch(k1, s, dur);
            }
            None if self.personas[k1][si].global_done => {
                let mrank = self.servers[si].rank;
                let at = self.now + dur + self.lat_ns(mrank, w);
                self.heap.push(at, Ev::WorkerReply { w, reply: WReply::Done });
            }
            None => {
                self.personas[k1][si].parked.push_back(w);
                self.maybe_fetch(k1, s, dur);
            }
        }
        self.heap.push(self.now + dur, Ev::AtomFree { s });
        self.atom_busy[si] = true;
    }

    /// Serve one fused master-tier fetch at persona `(d, j)`'s atomic unit:
    /// reserve + table lookup + commit in one `service_time` occupancy on
    /// the parent ledger's cache line — no parent CPU service, no chunk
    /// calculation, zero protocol messages. A drained parent parks the
    /// child on the two-phase slow path (re-served after the next install).
    fn matom_next_op(&mut self, d: usize, j: u32) {
        let ji = j as usize;
        let Some(from) = self.matom_queue[d][ji].pop_front() else {
            self.matom_busy[d][ji] = false;
            return;
        };
        let dur = ns(self.cfg.cluster.service_time);
        match self.personas[d][ji].ledger.fast_grant() {
            Some(a) => {
                self.fast_grants += 1;
                let task = Task::MasterChunk { level: d as u32, to: from, a };
                self.send_master_atom_reply(d, j, from, task, dur);
                self.maybe_prefetch(d, j, dur);
            }
            None if self.personas[d][ji].global_done => {
                let task = Task::MasterDone { level: d as u32, to: from };
                self.send_master_atom_reply(d, j, from, task, dur);
            }
            None => {
                self.personas[d][ji].parked.push_back(from);
                self.maybe_fetch(d, j, dur);
            }
        }
        self.heap.push(self.now + dur, Ev::MasterAtomFree { d: d as u32, j });
        self.matom_busy[d][ji] = true;
    }

    /// Deliver a fused-fetch reply to child master `to`: same travel as
    /// [`Self::send_master_reply`], charged zero protocol messages (the
    /// fused op is an RMA-style access, not a message exchange).
    fn send_master_atom_reply(&mut self, d: usize, j: u32, to: u32, task: Task, dur: u64) {
        let parent_rank = self.host_rank(d, j);
        let child_rank = self.host_rank(d + 1, to);
        let at = self.now + dur + self.lat_ns(parent_rank, child_rank);
        let s = self.server_of_rank(child_rank);
        self.route(at, Ev::Arrive { s, task });
    }

    // -- messaging ---------------------------------------------------------

    /// Count one message of protocol level `d`, classified by the
    /// endpoints' latency class.
    fn count_msg(&mut self, a: u32, b: u32, d: usize) {
        self.messages += 1;
        self.level_msgs[d] += 1;
        if self.topo.node_of(a) == self.topo.node_of(b) {
            self.intra_msgs += 1;
        } else {
            self.inter_msgs += 1;
        }
    }

    /// Send a worker-originated message to its leaf-serving master.
    fn send_leaf(&mut self, w: u32, task: Task, extra_ns: u64) {
        let s = self.server_of_rank(w);
        let mrank = self.servers[s as usize].rank;
        self.count_msg(w, mrank, self.k - 1);
        let at = self.now + extra_ns + self.lat_ns(w, mrank);
        self.heap.push(at, Ev::Arrive { s, task });
    }

    /// Send a leaf reply from hosting rank `s` to local rank `w`.
    fn send_worker(&mut self, s: u32, w: u32, reply: WReply, dur: u64) {
        let mrank = self.servers[s as usize].rank;
        self.count_msg(mrank, w, self.k - 1);
        let at = self.now + dur + self.lat_ns(mrank, w);
        self.heap.push(at, Ev::WorkerReply { w, reply });
    }

    /// Send a protocol-`d` reply from parent persona `(d, jp)` to child
    /// master `to` (a level-`d+1` index).
    fn send_master_reply(&mut self, d: usize, jp: u32, to: u32, task: Task, dur: u64) {
        let parent_rank = self.host_rank(d, jp);
        let child_rank = self.host_rank(d + 1, to);
        self.count_msg(parent_rank, child_rank, d);
        let at = self.now + dur + self.lat_ns(parent_rank, child_rank);
        let s = self.server_of_rank(child_rank);
        self.route(at, Ev::Arrive { s, task });
    }

    // -- hosting-rank CPU --------------------------------------------------

    fn server_next_action(&mut self, s: u32) {
        if let Some(task) = self.servers[s as usize].queue.pop_front() {
            let dur = self.service(s, task);
            let server = &mut self.servers[s as usize];
            server.service_ns += dur;
            server.busy = true;
            server.cpu_busy_until_ns = self.now + dur;
            self.heap.push(self.now + dur, Ev::ServerFree { s });
            return;
        }
        self.own_next_action(s);
    }

    /// Service one queued task on host `s`'s CPU; returns the (speed-
    /// scaled) CPU occupancy in ns and schedules replies/follow-ups.
    fn service(&mut self, s: u32, task: Task) -> u64 {
        let c = &self.cfg.cluster;
        let sp = self.speed(self.servers[s as usize].rank);
        match task {
            Task::LeafGet { w, report } => {
                let dur = ns(c.service_time / sp);
                self.record_leaf_report(s, w, report);
                self.leaf_get(s, w, dur);
                dur
            }
            Task::LeafCommit { w, step, size, seq } => {
                let dur = ns((c.service_time + self.cfg.delay.assignment) / sp);
                self.leaf_commit(s, w, step, size, seq, dur);
                dur
            }
            Task::MasterGet { level, from, report } => {
                let d = level as usize;
                let jp = from / self.fanouts[d];
                debug_assert_eq!(
                    self.server_of_rank(self.host_rank(d, jp)),
                    s,
                    "protocol-{d} requests are served by the owning persona's host"
                );
                let dur = ns(c.service_time / sp);
                if let Some(r) = report {
                    let idx = (from - jp * self.fanouts[d]) as usize;
                    if let Some(af) = self.personas[d][jp as usize].af_calc.as_mut() {
                        af.record(idx, r.iters, r.elapsed);
                    }
                    let now_s = secs(self.now);
                    if let Some(ctl) = self.personas[d][jp as usize].adapt.as_mut() {
                        ctl.observe_chunk(idx as u32, r.iters, r.elapsed, now_s);
                    }
                }
                self.serve_master_get(d, jp, from, dur);
                dur
            }
            Task::MasterCommit { level, from, step, size, seq } => {
                let d = level as usize;
                let dur = ns((c.service_time + self.cfg.delay.assignment) / sp);
                self.master_commit(d, from, step, size, seq, dur);
                dur
            }
            Task::MasterStep { level, to, step, remaining, seq, af } => {
                // The chunk CALCULATION runs here, on the child master's own
                // CPU — distributed across the tree, paying the injected
                // delay in parallel (the DCA idea, at every level).
                let d = level as usize;
                let child_rank = self.host_rank(d + 1, to);
                let dur =
                    ns((self.cfg.delay.calculation_at(child_rank, self.now) + c.calc_time) / sp);
                let size = self.master_calc(d, to, step, remaining, seq, af);
                let parent_rank = self.host_rank(d, to / self.fanouts[d]);
                self.count_msg(child_rank, parent_rank, d);
                let at = self.now + dur + self.lat_ns(child_rank, parent_rank);
                let s = self.server_of_rank(parent_rank);
                let commit = Task::MasterCommit { level, from: to, step, size, seq };
                self.route(at, Ev::Arrive { s, task: commit });
                dur
            }
            Task::MasterChunk { level, to, a } => {
                let dur = ns(c.service_time / sp);
                self.install_chunk(level as usize + 1, to, a);
                dur
            }
            Task::MasterDone { level, to } => {
                let dur = ns(c.service_time / sp);
                let e = level as usize + 1;
                let pr = &mut self.personas[e][to as usize];
                pr.global_done = true;
                pr.fetching = false;
                self.requeue_parked(e, to);
                dur
            }
        }
    }

    fn record_leaf_report(&mut self, s: u32, w: u32, report: Option<PerfReport>) {
        if let Some(r) = report {
            let mrank = self.servers[s as usize].rank;
            let idx = (w - mrank) as usize;
            let k1 = self.k - 1;
            if let Some(af) = self.personas[k1][s as usize].af_calc.as_mut() {
                af.record(idx, r.iters, r.elapsed);
            }
        }
    }

    /// Serve a leaf phase-1 request: reserve, terminate, or park the rank.
    /// On the lock-free fast path (reached only through the slow-path
    /// refill: a parked rank re-served after a chunk install) the master
    /// performs the fused CAS on the worker's behalf and replies with the
    /// chunk directly — still the canonical table schedule.
    fn leaf_get(&mut self, s: u32, w: u32, dur: u64) {
        let k1 = self.k - 1;
        if self.group_fast(s) {
            match self.personas[k1][s as usize].ledger.fast_grant() {
                Some(a) => {
                    self.fast_grants += 1;
                    self.grant(w, a);
                    self.adaptive_tick(k1, s);
                    self.send_worker(s, w, WReply::Chunk(a), dur);
                    self.maybe_prefetch(k1, s, dur);
                }
                None if self.personas[k1][s as usize].global_done => {
                    self.send_worker(s, w, WReply::Done, dur);
                }
                None => {
                    self.personas[k1][s as usize].parked.push_back(w);
                    self.maybe_fetch(k1, s, dur);
                }
            }
            return;
        }
        let af = self.persona_af_info(k1, s);
        if let Some((step, remaining, seq)) = self.personas[k1][s as usize].ledger.reserve() {
            self.send_worker(s, w, WReply::Step { step, remaining, seq, af }, dur);
        } else if self.personas[k1][s as usize].global_done {
            self.send_worker(s, w, WReply::Done, dur);
        } else {
            self.personas[k1][s as usize].parked.push_back(w);
            self.maybe_fetch(k1, s, dur);
        }
    }

    fn leaf_commit(&mut self, s: u32, w: u32, step: u64, size: u64, seq: u64, dur: u64) {
        let k1 = self.k - 1;
        match self.personas[k1][s as usize].ledger.commit(step, size, seq) {
            InnerCommit::Granted(abs) => {
                self.grant(w, abs);
                self.adaptive_tick(k1, s);
                self.send_worker(s, w, WReply::Chunk(abs), dur);
                self.maybe_prefetch(k1, s, dur);
            }
            // Stale seq: the chunk was replaced while this commit was in
            // flight. Re-serve the request as a fresh phase-1 Get so the
            // worker calculates against the *current* chunk instead of
            // silently committing a size computed for the old one.
            InnerCommit::Stale => self.leaf_get(s, w, dur),
            InnerCommit::Drained if self.personas[k1][s as usize].global_done => {
                self.send_worker(s, w, WReply::Done, dur);
            }
            // The ledger filled between this worker's Step and its Commit:
            // park it — it gets a fresh Step from the next chunk (its stale
            // size is discarded).
            InnerCommit::Drained => {
                self.personas[k1][s as usize].parked.push_back(w);
                self.maybe_fetch(k1, s, dur);
            }
        }
    }

    /// Serve a master-tier phase-1 request at persona `(d, jp)` from child
    /// master `from` — the same reserve/terminate/park logic as the leaf
    /// path, one level up.
    fn serve_master_get(&mut self, d: usize, jp: u32, from: u32, dur: u64) {
        if self.master_fast[d] {
            // Slow-path refill under `--master-lockfree` (a parked child
            // re-served after an install): the parent performs the fused
            // grant on the child's behalf and replies with the chunk
            // directly — the same shape as the leaf path's refill.
            match self.personas[d][jp as usize].ledger.fast_grant() {
                Some(a) => {
                    self.fast_grants += 1;
                    let task = Task::MasterChunk { level: d as u32, to: from, a };
                    self.send_master_reply(d, jp, from, task, dur);
                    self.maybe_prefetch(d, jp, dur);
                }
                None if self.personas[d][jp as usize].global_done => {
                    let done = Task::MasterDone { level: d as u32, to: from };
                    self.send_master_reply(d, jp, from, done, dur);
                }
                None => {
                    self.personas[d][jp as usize].parked.push_back(from);
                    self.maybe_fetch(d, jp, dur);
                }
            }
            return;
        }
        let af = self.persona_af_info(d, jp);
        if let Some((step, remaining, seq)) = self.personas[d][jp as usize].ledger.reserve() {
            self.send_master_reply(
                d,
                jp,
                from,
                Task::MasterStep { level: d as u32, to: from, step, remaining, seq, af },
                dur,
            );
        } else if self.personas[d][jp as usize].global_done {
            let done = Task::MasterDone { level: d as u32, to: from };
            self.send_master_reply(d, jp, from, done, dur);
        } else {
            self.personas[d][jp as usize].parked.push_back(from);
            self.maybe_fetch(d, jp, dur);
        }
    }

    fn master_commit(&mut self, d: usize, from: u32, step: u64, size: u64, seq: u64, dur: u64) {
        let jp = from / self.fanouts[d];
        match self.personas[d][jp as usize].ledger.commit(step, size, seq) {
            InnerCommit::Granted(abs) => {
                self.adaptive_tick(d, jp);
                self.send_master_reply(
                    d,
                    jp,
                    from,
                    Task::MasterChunk { level: d as u32, to: from, a: abs },
                    dur,
                );
                self.maybe_prefetch(d, jp, dur);
            }
            InnerCommit::Stale => self.serve_master_get(d, jp, from, dur),
            InnerCommit::Drained if self.personas[d][jp as usize].global_done => {
                self.send_master_reply(
                    d,
                    jp,
                    from,
                    Task::MasterDone { level: d as u32, to: from },
                    dur,
                );
            }
            InnerCommit::Drained => {
                self.personas[d][jp as usize].parked.push_back(from);
                self.maybe_fetch(d, jp, dur);
            }
        }
    }

    /// Resolve persona `(e, j)`'s prefetch watermark: fixed counts pass
    /// through; `Auto` applies the shared [`auto_watermark`] policy to the
    /// persona's EWMA round trip and subtree throughput.
    fn resolve_watermark(&self, e: usize, j: u32) -> Option<u64> {
        match self.cfg.hier.watermark {
            WatermarkMode::Off => None,
            WatermarkMode::Fixed(w) => Some(w),
            WatermarkMode::Auto => {
                let pr = &self.personas[e][j as usize];
                Some(auto_watermark(pr.rtt.value(), pr.stats.mu()))
            }
        }
    }

    /// Prefetch: once persona `(e, j)`'s current chunk drains to the
    /// watermark (and its staged queue has room), request the next chunk
    /// while the children keep consuming the tail — the parent round trip
    /// plus the chunk calculation are hidden instead of stalling the whole
    /// subtree.
    fn maybe_prefetch(&mut self, e: usize, j: u32, dur: u64) {
        let watermark = self.resolve_watermark(e, j);
        if self.personas[e][j as usize].ledger.wants_prefetch(watermark) {
            self.maybe_fetch(e, j, dur);
        }
    }

    /// Trigger the parent fetch for persona `(e, j)` unless one is already
    /// in flight (or there is no parent left to ask). Also finalizes the
    /// consumed chunk's throughput report (the upward-AF performance
    /// feedback) and stamps the fetch time for the round-trip EWMA.
    fn maybe_fetch(&mut self, e: usize, j: u32, dur: u64) {
        let ji = j as usize;
        if self.personas[e][ji].fetching || self.personas[e][ji].global_done {
            return;
        }
        self.personas[e][ji].fetching = true;
        if self.personas[e][ji].installed_iters > 0 {
            let iters = self.personas[e][ji].installed_iters;
            let elapsed = secs((self.now + dur).saturating_sub(self.personas[e][ji].installed_ns))
                .max(1e-12);
            self.personas[e][ji].stats.record(iters, elapsed);
            self.personas[e][ji].pending_report = Some(PerfReport { iters, elapsed });
            self.personas[e][ji].installed_iters = 0;
        }
        self.personas[e][ji].fetch_sent_ns = self.now + dur;
        let report = self.personas[e][ji].pending_report.take();
        let pd = e - 1;
        let child_rank = self.personas[e][ji].rank;
        let jp = j / self.fanouts[pd];
        let parent_rank = self.host_rank(pd, jp);
        let at = self.now + dur + self.lat_ns(child_rank, parent_rank);
        if self.master_fast[pd] {
            // Fused fetch: one atomic op on the parent's ledger line — no
            // protocol message, no parent CPU. The dropped report has no
            // consumer here: the gate excludes AF parents and adaptivity.
            self.route(at, Ev::MasterAtomArrive { d: pd as u32, j: jp, from: j });
        } else {
            self.count_msg(child_rank, parent_rank, pd);
            let s = self.server_of_rank(parent_rank);
            let task = Task::MasterGet { level: pd as u32, from: j, report };
            self.route(at, Ev::Arrive { s, task });
        }
    }

    /// Install a chunk fetched over protocol `e-1` into persona `(e, j)`'s
    /// ledger (staged behind the current chunk when one is live).
    fn install_chunk(&mut self, e: usize, j: u32, a: Assignment) {
        let pr = &mut self.personas[e][j as usize];
        if pr.fetch_sent_ns > 0 {
            pr.rtt.observe(secs(self.now.saturating_sub(pr.fetch_sent_ns)));
        }
        pr.ledger.install(a);
        pr.fetching = false;
        // Under prefetch, installs accumulate between throughput
        // finalizations (staged chunks arrive mid-consumption).
        if pr.installed_iters == 0 {
            pr.installed_ns = self.now;
        }
        pr.installed_iters += a.size;
        self.requeue_parked(e, j);
    }

    /// Re-enqueue parked child requests (each pays its service cost again)
    /// and, at the leaf level, wake the host's own personality if parked.
    fn requeue_parked(&mut self, e: usize, j: u32) {
        let s = self.server_of_rank(self.personas[e][j as usize].rank);
        while let Some(c) = self.personas[e][j as usize].parked.pop_front() {
            let task = if e == self.k - 1 {
                Task::LeafGet { w: c, report: None }
            } else {
                Task::MasterGet { level: e as u32, from: c, report: None }
            };
            self.servers[s as usize].queue.push_back(task);
        }
        if e == self.k - 1 && self.servers[s as usize].own_parked {
            self.servers[s as usize].own_parked = false;
            self.servers[s as usize].own = Own::NeedWork;
        }
    }

    /// Protocol-`d` chunk size, computed on child master `to` (closed form
    /// of the level technique bound to the parent's current chunk, or AF's
    /// Eq. 11 over subtree throughput).
    fn master_calc(
        &self,
        d: usize,
        to: u32,
        step: u64,
        remaining: u64,
        seq: u64,
        af: Option<AfInfo>,
    ) -> u64 {
        // The binding follows the parent CHUNK the step was reserved from
        // (the slot may have been rebound since — the configured level
        // technique is only its initial value).
        let jp = to / self.fanouts[d];
        match self.personas[d][jp as usize].ledger.chunk_kind(seq) {
            Some(TechniqueKind::Af) => af_requester_chunk(
                &self.personas[d + 1][to as usize].stats,
                af.map(|i| AfGlobals { d: i.d, e: i.e }),
                remaining,
                self.fanouts[d],
                self.min_chunk(),
            ),
            // Normal case: the parent chunk this step belongs to is still
            // installed; evaluate its bound closed form.
            Some(_) => self
                .personas[d][jp as usize]
                .ledger
                .closed_inner_size(step, seq)
                .unwrap_or_else(|| self.min_chunk()),
            // Replaced while this Step was in flight: the commit will NACK
            // and re-request, so the size is moot.
            None => self.min_chunk(),
        }
    }

    // -- worker ranks ------------------------------------------------------

    fn worker_on_reply(&mut self, w: u32, reply: WReply) {
        let sent = self.workers[w as usize].req_sent_ns;
        self.workers[w as usize].wait_ns += self.now.saturating_sub(sent);
        match reply {
            WReply::Step { step, remaining, seq, af } => {
                // Distributed leaf calculation on the worker's own clock —
                // the injected delay is paid here, in parallel.
                let dur = ns(
                    (self.cfg.delay.calculation_at(w, self.now) + self.cfg.cluster.calc_time)
                        / self.speed(w),
                );
                let size = self.worker_calc(w, step, remaining, seq, af);
                self.heap.push(self.now + dur, Ev::CalcDone { w, step, size, seq });
            }
            WReply::Chunk(a) => {
                let dur = self.exec_ns(w, a);
                let elapsed = secs(dur);
                let ws = &mut self.workers[w as usize];
                ws.stats.record(a.size, elapsed);
                ws.last_report = Some(PerfReport { iters: a.size, elapsed });
                // Leaf-controller observation at chunk-grant time — works on
                // BOTH grant paths (fused CAS grants carry no piggybacked
                // report; the simulated atomic unit samples the timing the
                // way an RMA-side profile would).
                let s = self.server_of_rank(w);
                let mrank = self.servers[s as usize].rank;
                let idx = w - mrank;
                let k1 = self.k - 1;
                let now_s = secs(self.now);
                if let Some(ctl) = self.personas[k1][s as usize].adapt.as_mut() {
                    ctl.observe_chunk(idx, a.size, elapsed, now_s);
                }
                self.heap.push(self.now + dur, Ev::ExecDone { w });
            }
            WReply::Done => {
                self.workers[w as usize].finish_ns = self.now;
            }
        }
    }

    /// Leaf sub-chunk size, calculated worker-side (closed form of the leaf
    /// technique bound to the current chunk, or AF's Eq. 11).
    fn worker_calc(&self, w: u32, step: u64, remaining: u64, seq: u64, af: Option<AfInfo>) -> u64 {
        let k1 = self.k - 1;
        let s = self.server_of_rank(w);
        match self.personas[k1][s as usize].ledger.chunk_kind(seq) {
            Some(TechniqueKind::Af) => af_requester_chunk(
                &self.workers[w as usize].stats,
                af.map(|i| AfGlobals { d: i.d, e: i.e }),
                remaining,
                self.fanouts[k1],
                self.min_chunk(),
            ),
            Some(_) => self
                .personas[k1][s as usize]
                .ledger
                .closed_inner_size(step, seq)
                .unwrap_or_else(|| self.min_chunk()),
            // Chunk replaced in flight — the commit will NACK anyway.
            None => self.min_chunk(),
        }
    }

    // -- the hosting rank's own worker personality --------------------------

    fn own_next_action(&mut self, s: u32) {
        let si = s as usize;
        let k1 = self.k - 1;
        let mrank = self.servers[si].rank;
        let sp = self.speed(mrank);
        let c = &self.cfg.cluster;
        let cluster_break = c.break_after.max(1) as u64;
        match std::mem::replace(&mut self.servers[si].own, Own::Finished) {
            Own::NeedWork if self.group_fast(s) => {
                // Lock-free: the master's own personality grants with one
                // fused CAS on its CPU — no Calc/Commit states, no
                // calculation delay (the table already holds the size).
                let dur = ns(c.service_time / sp);
                match self.personas[k1][si].ledger.fast_grant() {
                    Some(a) => {
                        self.fast_grants += 1;
                        self.grant(mrank, a);
                        self.adaptive_tick(k1, s);
                        self.servers[si].own =
                            Own::Exec { cursor: a.start, end: a.end(), first: a.start };
                        self.maybe_prefetch(k1, s, dur);
                    }
                    None if self.personas[k1][si].global_done => self.finish_own(s),
                    None => {
                        self.servers[si].own = Own::Parked;
                        self.servers[si].own_parked = true;
                        self.maybe_fetch(k1, s, dur);
                    }
                }
                self.finish_server_action(s, dur);
            }
            Own::NeedWork => {
                let dur = ns(c.service_time / sp);
                if let Some((step, remaining, seq)) = self.personas[k1][si].ledger.reserve() {
                    self.servers[si].own = Own::Calc { step, remaining, seq };
                } else if self.personas[k1][si].global_done {
                    self.finish_own(s);
                } else {
                    self.servers[si].own = Own::Parked;
                    self.servers[si].own_parked = true;
                    self.maybe_fetch(k1, s, dur);
                }
                self.finish_server_action(s, dur);
            }
            Own::Calc { step, remaining, seq } => {
                let dur = ns((self.cfg.delay.calculation_at(mrank, self.now) + c.calc_time) / sp);
                let af = self.persona_af_info(k1, s);
                let size = self.worker_calc(mrank, step, remaining, seq, af);
                self.servers[si].own = Own::Commit { step, size, seq };
                self.finish_server_action(s, dur);
            }
            Own::Commit { step, size, seq } => {
                let dur = ns((c.service_time + self.cfg.delay.assignment) / sp);
                match self.personas[k1][si].ledger.commit(step, size, seq) {
                    InnerCommit::Granted(abs) => {
                        self.grant(mrank, abs);
                        self.adaptive_tick(k1, s);
                        self.servers[si].own =
                            Own::Exec { cursor: abs.start, end: abs.end(), first: abs.start };
                        self.maybe_prefetch(k1, s, dur);
                    }
                    // Stale seq: a new chunk arrived between this
                    // personality's Calc and Commit — re-reserve from it.
                    InnerCommit::Stale => self.servers[si].own = Own::NeedWork,
                    InnerCommit::Drained if self.personas[k1][si].global_done => {
                        self.finish_own(s);
                    }
                    InnerCommit::Drained => {
                        self.servers[si].own = Own::Parked;
                        self.servers[si].own_parked = true;
                        self.maybe_fetch(k1, s, dur);
                    }
                }
                self.finish_server_action(s, dur);
            }
            Own::Exec { cursor, end, first } => {
                let seg = cluster_break.min(end - cursor);
                let dur = ns(self.cfg.cost.range_cost(cursor, seg) / sp);
                let new_cursor = cursor + seg;
                if new_cursor < end {
                    self.servers[si].own = Own::Exec { cursor: new_cursor, end, first };
                } else {
                    let iters = end - first;
                    let elapsed = self.cfg.cost.range_cost(first, iters) / sp;
                    self.workers[mrank as usize].stats.record(iters, elapsed);
                    if let Some(af) = self.personas[k1][si].af_calc.as_mut() {
                        af.record(0, iters, elapsed);
                    }
                    let now_s = secs(self.now + dur);
                    if let Some(ctl) = self.personas[k1][si].adapt.as_mut() {
                        ctl.observe_chunk(0, iters, elapsed, now_s);
                    }
                    self.servers[si].own = Own::NeedWork;
                }
                self.finish_server_action(s, dur);
            }
            Own::Parked => {
                self.servers[si].own = Own::Parked;
                self.servers[si].busy = false;
            }
            Own::Finished => {
                self.servers[si].own = Own::Finished;
                self.servers[si].busy = false;
            }
        }
    }

    fn finish_own(&mut self, s: u32) {
        let si = s as usize;
        self.servers[si].own = Own::Finished;
        let mrank = self.servers[si].rank as usize;
        self.workers[mrank].finish_ns = self.workers[mrank].finish_ns.max(self.now);
    }

    fn finish_server_action(&mut self, s: u32, dur: u64) {
        let server = &mut self.servers[s as usize];
        server.busy = true;
        server.cpu_busy_until_ns = self.now + dur;
        self.heap.push(self.now + dur, Ev::ServerFree { s });
    }

    // -- results -----------------------------------------------------------

    fn into_result(self) -> DesResult {
        let mut finish: Vec<f64> = self.workers.iter().map(|w| secs(w.finish_ns)).collect();
        for server in &self.servers {
            let r = server.rank as usize;
            finish[r] = finish[r].max(secs(server.cpu_busy_until_ns));
        }
        let wait: f64 = self.workers.iter().map(|w| secs(w.wait_ns)).sum();
        let stats =
            LoopStats::from_finish_times(&finish, self.chunks_granted, wait, self.messages);
        let final_record = self.sampler.is_some().then(|| {
            stream::interval_record(&IntervalSample {
                t: stats.t_par,
                chunks: self.chunks_granted,
                chunks_delta: self.chunks_granted - self.last_tick_chunks,
                interval_s: self.cfg.stream_interval,
                messages: self.messages,
                fast_grants: self.fast_grants,
                remaining: self.cfg.params.n - self.iters_granted,
            })
            .field("subtrees", self.subtree_entries())
        });
        let mut stream = self.stream;
        if let Some(record) = final_record {
            stream.push(record);
            stream.extend(self.switch_events.iter().map(stream::switch_record));
            stream = stream::sorted_by_time(stream);
        }
        DesResult {
            stats,
            finish,
            rank0_service_busy: secs(self.servers[0].service_ns),
            assignments: self.assignments,
            rma_ops: 0,
            intra_node_messages: self.intra_msgs,
            inter_node_messages: self.inter_msgs,
            level_messages: self.level_msgs,
            fast_grants: self.fast_grants,
            events: self.events,
            switch_events: self.switch_events,
            stream,
            pdes: None,
        }
    }
}

// ---------------------------------------------------------------------------
// sharded (PDES) execution

/// Cap per sharding tier: at most this many level-1 subtree groups, each
/// subdivided into at most this many server subgroups on depth-≥3 plans —
/// shard counts follow the tree geometry past 8 (up to 8 × 8 = 64) while
/// still bounding the per-shard full-state copies (each shard keeps a
/// complete `HierSim` but touches only its owned slice).
const HIER_SHARD_GROUPS_MAX: u32 = 8;

struct HierShard<'a> {
    sim: HierSim<'a>,
}

impl<'a> pdes::Shard for HierShard<'a> {
    type Msg = Ev;
    type Ckpt = HierSim<'a>;

    fn next_at(&self) -> Option<u64> {
        self.sim.heap.next_at()
    }

    fn advance(&mut self, horizon: u64, outbox: &mut pdes::Outbox<Ev>) -> u64 {
        let mut n = 0u64;
        while self.sim.heap.next_at().is_some_and(|t| t < horizon) {
            let (t, ev) = self.sim.heap.pop().expect("probed non-empty");
            self.sim.now = t;
            self.sim.events += 1;
            n += 1;
            if self.sim.sampler.is_some() {
                self.sim.sample_ticks();
            }
            self.sim.dispatch(ev);
        }
        for (dst, at, ev) in self.sim.outbound.drain(..) {
            outbox.send(dst as usize, at, ev);
        }
        n
    }

    fn deliver(&mut self, at: u64, msg: Ev) {
        self.sim.heap.push(at, msg);
    }

    fn save(&self) -> HierSim<'a> {
        self.sim.clone()
    }

    fn restore(&mut self, ckpt: HierSim<'a>) {
        self.sim = ckpt;
    }
}

/// Sharded (PDES) counterpart of the sequential hierarchical loop. Shards
/// group contiguous hosting servers, aligned to the `LevelPlan` tree: up
/// to [`HIER_SHARD_GROUPS_MAX`] level-1 subtree groups, each subdivided
/// into up to the same number of server subgroups on depth-≥3 plans
/// (rack-level groups containing node subgroups), so shard counts follow
/// the geometry past 8. Master-protocol traffic at any level may cross
/// shards; the lookahead below accounts for the cheapest such hop.
/// Deterministic for a fixed config regardless of `des_threads` *and* of
/// the partition (cross-shard delivery order is fixed by the executor).
fn simulate_hier_pdes(cfg: &DesConfig, plan: &LevelPlan) -> anyhow::Result<DesResult> {
    let k = plan.depth();
    let n_servers = plan.masters_at(k - 1);
    let n_sub = plan.levels[0].fanout;
    let groups = n_sub.min(HIER_SHARD_GROUPS_MAX);
    let sub_split = if k >= 3 { HIER_SHARD_GROUPS_MAX } else { 1 };
    let shards_n = if k < 2 { 1 } else { n_servers.min(groups.saturating_mul(sub_split)) };
    let of_server: Vec<u32> = (0..n_servers)
        .map(|s| ((u64::from(s) * u64::from(shards_n)) / u64::from(n_servers)) as u32)
        .collect();
    // Conservative lookahead: the cheapest parent→child hop — at any
    // protocol level — between masters whose hosts land on different
    // shards. Every cross-shard event pays at least this much travel on
    // top of its send time; leaf-protocol traffic never crosses (shards
    // group whole hosting servers).
    let topo = Topology::new(&cfg.cluster);
    let leaf_fanout = plan.levels[k - 1].fanout;
    let shard_of_rank = |r: u32| -> u32 { of_server[(r / leaf_fanout) as usize] };
    let mut lookahead = 0u64;
    if shards_n > 1 {
        lookahead = u64::MAX;
        for d in 0..k - 1 {
            for j2 in 0..plan.masters_at(d + 1) {
                let hp = plan.host_rank(d, j2 / plan.levels[d].fanout);
                let hc = plan.host_rank(d + 1, j2);
                if shard_of_rank(hp) != shard_of_rank(hc) {
                    lookahead = lookahead.min(ns(topo.latency(hp, hc)));
                }
            }
        }
        anyhow::ensure!(
            lookahead > 0 && lookahead < u64::MAX,
            "--des-threads needs a nonzero latency on every master hop that \
             crosses a shard boundary"
        );
    }
    let of_server = Arc::new(of_server);
    let mut shards: Vec<HierShard<'_>> = (0..shards_n)
        .map(|id| {
            let span = HierShardSpan { id, of_server: of_server.clone() };
            HierShard { sim: HierSim::new_shard(cfg, plan, span) }
        })
        .collect();
    for sh in shards.iter_mut() {
        sh.sim.bootstrap();
        debug_assert!(sh.sim.outbound.is_empty(), "hier bootstrap is shard-local");
    }
    // Two-tier routing: shards fold into their level-1 subtree group, so
    // same-group traffic rides direct SPSC lanes and cross-group traffic
    // shares one lane per (source shard, group).
    let rack_of: Vec<u32> = (0..shards_n)
        .map(|t| ((u64::from(t) * u64::from(groups)) / u64::from(shards_n)) as u32)
        .collect();
    // Hier shards keep the full-clone checkpoint fallback (trait default):
    // their per-subtree state is small and AF-style write-heavy aggregates
    // live on the hosting masters, so a journal would buy little.
    let opts = pdes::PdesOpts {
        mode: cfg.pdes_mode,
        rack_of,
        pin_shards: cfg.pin_shards,
        window_mult_max: cfg.window_mult_max,
        ..Default::default()
    };
    let (shards, report) =
        pdes::run_sharded(shards, lookahead, resolved_des_threads(cfg), &opts);
    Ok(merge_hier_shards(cfg, shards, &report))
}

/// Fold per-shard state into one [`DesResult`]. Every mutable quantity has
/// exactly one writer shard (ownership follows the hosting server), so the
/// merge is exact: element-wise max of finish times, sums of disjoint
/// counters, grant logs concatenated in shard order, switch traces merged
/// into `(time, level, master)` order, and the observability stream
/// rebuilt from per-shard tick series ([`merge_hier_stream`]).
fn merge_hier_shards(
    cfg: &DesConfig,
    shards: Vec<HierShard<'_>>,
    report: &pdes::PdesReport,
) -> DesResult {
    let sims: Vec<HierSim<'_>> = shards.into_iter().map(|sh| sh.sim).collect();
    let k = sims[0].k;
    let mut finish = vec![0f64; cfg.params.p as usize];
    let mut wait = 0.0f64;
    let mut rank0_service_ns = 0u64;
    let mut messages = 0u64;
    let mut intra = 0u64;
    let mut inter = 0u64;
    let mut level_msgs = vec![0u64; k];
    let mut fast_grants = 0u64;
    let mut chunks = 0u64;
    let mut events = 0u64;
    for (i, sim) in sims.iter().enumerate() {
        for (r, w) in sim.workers.iter().enumerate() {
            finish[r] = finish[r].max(secs(w.finish_ns));
            wait += secs(w.wait_ns);
        }
        for server in &sim.servers {
            let r = server.rank as usize;
            finish[r] = finish[r].max(secs(server.cpu_busy_until_ns));
        }
        if i == 0 {
            rank0_service_ns = sim.servers[0].service_ns;
        }
        messages += sim.messages;
        intra += sim.intra_msgs;
        inter += sim.inter_msgs;
        for (d, m) in sim.level_msgs.iter().enumerate() {
            level_msgs[d] += *m;
        }
        fast_grants += sim.fast_grants;
        chunks += sim.chunks_granted;
        events += sim.events;
    }
    let stats = LoopStats::from_finish_times(&finish, chunks, wait, messages);
    // Rebind decisions are per-persona (shard-local); the global trace is
    // their deterministic merge. Same-instant switches on different shards
    // order by `(level, master)` — the documented stream tie rule.
    let mut switch_events: Vec<SwitchEvent> =
        sims.iter().flat_map(|s| s.switch_events.iter().copied()).collect();
    switch_events.sort_by(|a, b| {
        a.at_s
            .partial_cmp(&b.at_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| (a.level, a.master).cmp(&(b.level, b.master)))
    });
    let stream = merge_hier_stream(cfg, &sims, stats.t_par, &switch_events);
    let mut assignments = Vec::new();
    for sim in sims {
        assignments.extend(sim.assignments);
    }
    DesResult {
        stats,
        finish,
        rank0_service_busy: secs(rank0_service_ns),
        assignments,
        rma_ops: 0,
        intra_node_messages: intra,
        inter_node_messages: inter,
        level_messages: level_msgs,
        fast_grants,
        events,
        switch_events,
        stream,
        pdes: Some(PdesSummary::from_report(report)),
    }
}

/// Rebuild the sequential run's `interval` stream from the per-shard raw
/// tick series. Exact, not approximate, because every shard observes the
/// same boundary grid ([`Sampler::due`] yields boundary `k` at index `k`
/// and never skips one), a shard whose events ended before boundary `k`
/// holds its counters at their final values from then on (last-value
/// extension via [`HierSim::tick_sample`]), and the caps align (every
/// sampler stops at the same `MAX_STREAM_RECORDS`). Counters sum across
/// shards per boundary; owned subtree entries union in `(level, master)`
/// order — the sequential iteration order.
fn merge_hier_stream(
    cfg: &DesConfig,
    sims: &[HierSim<'_>],
    t_par: f64,
    switch_events: &[SwitchEvent],
) -> Vec<Json> {
    let Some(sampler) = Sampler::from_interval_s(cfg.stream_interval) else {
        return Vec::new();
    };
    let finals: Vec<HierTick> = sims.iter().map(HierSim::tick_sample).collect();
    let max_ticks = sims.iter().map(|s| s.ticks.len()).max().unwrap_or(0);
    let merged_at = |i: Option<usize>| -> (u64, u64, u64, u64, Vec<Json>) {
        let mut chunks = 0u64;
        let mut messages = 0u64;
        let mut fast = 0u64;
        let mut iters = 0u64;
        let mut subtrees: Vec<&(u32, u32, Json)> = Vec::new();
        for (sim, fin) in sims.iter().zip(&finals) {
            let tick = i.and_then(|i| sim.ticks.get(i)).unwrap_or(fin);
            chunks += tick.chunks;
            messages += tick.messages;
            fast += tick.fast_grants;
            iters += tick.iters_granted;
            subtrees.extend(tick.subtrees.iter());
        }
        subtrees.sort_by_key(|(d, j, _)| (*d, *j));
        let entries = subtrees.into_iter().map(|(_, _, e)| e.clone()).collect();
        (chunks, messages, fast, iters, entries)
    };
    let mut stream = Vec::with_capacity(max_ticks + 1 + switch_events.len());
    let mut last_chunks = 0u64;
    for i in 0..max_ticks {
        let (chunks, messages, fast, iters, entries) = merged_at(Some(i));
        stream.push(
            stream::interval_record(&IntervalSample {
                t: sampler.tick_at(i),
                chunks,
                chunks_delta: chunks - last_chunks,
                interval_s: sampler.interval_s(),
                messages,
                fast_grants: fast,
                remaining: cfg.params.n - iters,
            })
            .field("subtrees", entries),
        );
        last_chunks = chunks;
    }
    let (chunks, messages, fast, iters, entries) = merged_at(None);
    stream.push(
        stream::interval_record(&IntervalSample {
            t: t_par,
            chunks,
            chunks_delta: chunks - last_chunks,
            interval_s: cfg.stream_interval,
            messages,
            fast_grants: fast,
            remaining: cfg.params.n - iters,
        })
        .field("subtrees", entries),
    );
    stream.extend(switch_events.iter().map(stream::switch_record));
    stream::sorted_by_time(stream)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, HierParams};
    use crate::des::simulate;
    use crate::sched::verify_coverage;
    use crate::substrate::delay::InjectedDelay;
    use crate::techniques::LoopParams;
    use crate::workload::IterationCost;

    fn cluster(nodes: u32, rpn: u32) -> ClusterConfig {
        ClusterConfig { nodes, ranks_per_node: rpn, ..ClusterConfig::minihpc() }
    }

    fn cfg(n: u64, nodes: u32, rpn: u32, kind: TechniqueKind) -> DesConfig {
        let cluster = cluster(nodes, rpn);
        DesConfig::new(
            LoopParams::new(n, cluster.total_ranks()),
            kind,
            ExecutionModel::HierDca,
            cluster,
            IterationCost::Constant(1e-5),
        )
    }

    #[test]
    fn covers_loop_all_techniques_small() {
        for kind in TechniqueKind::ALL {
            let c = cfg(2_000, 2, 4, kind);
            let r = simulate(&c).unwrap_or_else(|e| panic!("{kind}: {e}"));
            verify_coverage(&r.sorted_assignments(), 2_000)
                .unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert!(r.t_par() > 0.0, "{kind}");
            assert_eq!(r.rma_ops, 0);
            assert!(r.stats.messages > 0);
            assert_eq!(
                r.stats.messages,
                r.intra_node_messages + r.inter_node_messages,
                "{kind}: split must reconcile with the flat counter"
            );
            assert_eq!(
                r.stats.messages,
                r.level_messages.iter().sum::<u64>(),
                "{kind}: per-level split must reconcile too"
            );
            assert_eq!(r.level_messages.len(), 2, "{kind}: two protocol levels");
            assert!(r.inter_node_messages > 0, "{kind}: outer protocol crossed nodes");
        }
    }

    /// Prefetch keeps exact coverage, replays deterministically, and the
    /// split message counters reconcile.
    #[test]
    fn prefetch_covers_and_replays() {
        let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
        c.hier = HierParams::with_inner(TechniqueKind::Ss).with_watermark(16);
        let a = simulate(&c).unwrap();
        verify_coverage(&a.sorted_assignments(), 6_000).unwrap();
        let b = simulate(&c).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.t_par(), b.t_par());
        assert_eq!(a.stats.messages, a.intra_node_messages + a.inter_node_messages);
    }

    /// A deeper staged queue keeps exact coverage and replays.
    #[test]
    fn deep_prefetch_queue_covers_and_replays() {
        let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
        c.hier = HierParams::with_inner(TechniqueKind::Ss)
            .with_watermark(512)
            .with_prefetch_depth(3);
        let a = simulate(&c).unwrap();
        verify_coverage(&a.sorted_assignments(), 6_000).unwrap();
        let b = simulate(&c).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.t_par(), b.t_par());
    }

    /// The adaptive watermark keeps exact coverage and replays (its inputs
    /// are virtual-time round trips, deterministic on the DES).
    #[test]
    fn auto_watermark_covers_and_replays() {
        let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
        c.hier = HierParams::with_inner(TechniqueKind::Ss).with_auto_watermark();
        let a = simulate(&c).unwrap();
        verify_coverage(&a.sorted_assignments(), 6_000).unwrap();
        let b = simulate(&c).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.t_par(), b.t_par());
    }

    #[test]
    fn deterministic_replay() {
        let c = cfg(10_000, 4, 4, TechniqueKind::Fac2);
        let a = simulate(&c).unwrap();
        let b = simulate(&c).unwrap();
        assert_eq!(a.t_par(), b.t_par());
        assert_eq!(a.stats.messages, b.stats.messages);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn mixed_inner_technique_covers() {
        let mut c = cfg(5_000, 2, 8, TechniqueKind::Fac2);
        c.hier = HierParams::with_inner(TechniqueKind::Ss);
        let r = simulate(&c).unwrap();
        verify_coverage(&r.sorted_assignments(), 5_000).unwrap();
        // SS inside: sub-chunks of one iteration dominate the multiset.
        let ones = r.assignments.iter().filter(|a| a.size == 1).count();
        assert!(ones > r.assignments.len() / 2, "inner SS must produce unit chunks");
    }

    #[test]
    fn dedicated_masters_serve_but_do_not_compute() {
        let mut c = cfg(2_000, 2, 4, TechniqueKind::Gss);
        c.cluster.break_after = 0;
        let r = simulate(&c).unwrap();
        verify_coverage(&r.sorted_assignments(), 2_000).unwrap();
        assert!(r.rank0_service_busy > 0.0);
    }

    #[test]
    fn dedicated_masters_with_single_rank_nodes_rejected() {
        let mut c = cfg(100, 4, 1, TechniqueKind::Gss);
        c.cluster.break_after = 0;
        assert!(simulate(&c).is_err());
    }

    #[test]
    fn single_rank_nodes_work_when_masters_compute() {
        let c = cfg(1_000, 4, 1, TechniqueKind::Tss);
        let r = simulate(&c).unwrap();
        verify_coverage(&r.sorted_assignments(), 1_000).unwrap();
    }

    #[test]
    fn single_node_degenerates_gracefully() {
        let c = cfg(3_000, 1, 8, TechniqueKind::Gss);
        let r = simulate(&c).unwrap();
        verify_coverage(&r.sorted_assignments(), 3_000).unwrap();
    }

    #[test]
    fn af_both_levels_learns_and_covers() {
        let c = cfg(4_000, 2, 4, TechniqueKind::Af);
        let r = simulate(&c).unwrap();
        verify_coverage(&r.sorted_assignments(), 4_000).unwrap();
        let max = r.assignments.iter().map(|a| a.size).max().unwrap();
        assert!(max > 1, "AF should grow beyond bootstrap");
    }

    #[test]
    fn more_ranks_than_iterations() {
        let c = cfg(5, 2, 4, TechniqueKind::Gss);
        let r = simulate(&c).unwrap();
        verify_coverage(&r.sorted_assignments(), 5).unwrap();
    }

    /// Depth 1 degenerates to the flat root ↔ ranks protocol and still
    /// covers the loop exactly.
    #[test]
    fn depth1_flat_tree_covers() {
        let mut c = cfg(2_000, 2, 4, TechniqueKind::Gss);
        c.hier = HierParams::default().with_levels(1);
        let r = simulate(&c).unwrap();
        verify_coverage(&r.sorted_assignments(), 2_000).unwrap();
        assert_eq!(r.level_messages.len(), 1, "one protocol level");
        assert_eq!(r.stats.messages, r.level_messages[0]);
    }

    /// Depth 3 (2 racks × 2 nodes × 4 ranks) covers the loop and splits
    /// messages across three protocol levels.
    #[test]
    fn depth3_tree_covers_and_counts_levels() {
        let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
        c.cluster.racks = 2;
        c.hier = HierParams::with_inner(TechniqueKind::Ss)
            .with_levels(3)
            .with_fanouts(&[2, 2, 4]);
        let r = simulate(&c).unwrap();
        verify_coverage(&r.sorted_assignments(), 6_000).unwrap();
        assert_eq!(r.level_messages.len(), 3);
        assert!(r.level_messages.iter().all(|&m| m > 0), "{:?}", r.level_messages);
        assert_eq!(r.stats.messages, r.level_messages.iter().sum::<u64>());
        // The leaf protocol dominates: finer chunks, cheaper fabric.
        assert!(r.level_messages[2] > r.level_messages[0]);
        let b = simulate(&c).unwrap();
        assert_eq!(r.assignments, b.assignments, "depth-3 replay");
    }

    /// The lock-free leaf level covers exactly, replays deterministically,
    /// grants via CAS, and sends far fewer messages than two-phase.
    #[test]
    fn lockfree_leaf_covers_replays_and_cuts_messages() {
        let mk = |path| {
            let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
            c.hier = HierParams::with_inner(TechniqueKind::Ss);
            c.sched_path = path;
            simulate(&c).unwrap()
        };
        let two = mk(crate::config::SchedPath::TwoPhase);
        let fast = mk(crate::config::SchedPath::LockFree);
        verify_coverage(&fast.sorted_assignments(), 6_000).unwrap();
        assert!(fast.fast_grants > 0, "leaf grants took the CAS path");
        assert_eq!(two.fast_grants, 0);
        assert!(
            fast.stats.messages < two.stats.messages / 2,
            "CAS grants must replace most leaf messages ({} vs {})",
            fast.stats.messages,
            two.stats.messages
        );
        assert!(fast.t_par() <= two.t_par(), "fast {} vs {}", fast.t_par(), two.t_par());
        let replay = mk(crate::config::SchedPath::LockFree);
        assert_eq!(fast.assignments, replay.assignments, "lock-free replay");
        assert_eq!(fast.t_par(), replay.t_par());
    }

    /// AF/TAP leaves fall back to the two-phase protocol bit-identically.
    #[test]
    fn lockfree_falls_back_for_measurement_coupled_leaves() {
        for inner in [TechniqueKind::Af, TechniqueKind::Tap] {
            let mk = |path| {
                let mut c = cfg(3_000, 2, 4, TechniqueKind::Fac2);
                c.hier = HierParams::with_inner(inner);
                c.sched_path = path;
                simulate(&c).unwrap()
            };
            let two = mk(crate::config::SchedPath::TwoPhase);
            let fast = mk(crate::config::SchedPath::LockFree);
            assert_eq!(fast.fast_grants, 0, "{inner}: no CAS grants");
            assert_eq!(fast.assignments, two.assignments, "{inner}: identical runs");
            assert_eq!(fast.t_par(), two.t_par(), "{inner}");
        }
    }

    /// Lock-free leaf + prefetch (fixed and auto watermarks) keeps exact
    /// coverage and deterministic replay.
    #[test]
    fn lockfree_prefetch_covers_and_replays() {
        for hier in [
            HierParams::with_inner(TechniqueKind::Ss).with_watermark(64),
            HierParams::with_inner(TechniqueKind::Ss).with_auto_watermark(),
            HierParams::with_inner(TechniqueKind::Ss).with_watermark(256).with_prefetch_depth(3),
        ] {
            let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
            c.hier = hier;
            c.sched_path = crate::config::SchedPath::LockFree;
            let a = simulate(&c).unwrap();
            verify_coverage(&a.sorted_assignments(), 6_000).unwrap();
            let b = simulate(&c).unwrap();
            assert_eq!(a.assignments, b.assignments);
            assert_eq!(a.t_par(), b.t_par());
        }
    }

    /// `--master-lockfree`: master-tier fetches take the fused path —
    /// exact coverage, deterministic replay, more fast grants than the
    /// leaf-only fast path, and the level-0 message count collapses.
    #[test]
    fn master_lockfree_covers_replays_and_cuts_outer_messages() {
        let mk = |mlf: bool| {
            let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
            c.hier = HierParams::with_inner(TechniqueKind::Ss);
            if mlf {
                c.hier = c.hier.with_master_lockfree();
            }
            c.sched_path = crate::config::SchedPath::LockFree;
            simulate(&c).unwrap()
        };
        let leaf_only = mk(false);
        let fused = mk(true);
        verify_coverage(&fused.sorted_assignments(), 6_000).unwrap();
        assert!(
            fused.fast_grants > leaf_only.fast_grants,
            "master-tier fetches joined the fast path ({} vs {})",
            fused.fast_grants,
            leaf_only.fast_grants
        );
        assert!(
            fused.level_messages[0] < leaf_only.level_messages[0],
            "fused fetches must replace level-0 messages ({} vs {})",
            fused.level_messages[0],
            leaf_only.level_messages[0]
        );
        assert!(fused.t_par() <= leaf_only.t_par());
        let replay = mk(true);
        assert_eq!(fused.assignments, replay.assignments, "master-lockfree replay");
        assert_eq!(fused.t_par(), replay.t_par());
    }

    /// Depth 3 under `--master-lockfree`: intermediate masters both serve
    /// fused fetches from below and issue fused fetches upward.
    #[test]
    fn master_lockfree_depth3_covers_and_replays() {
        let mk = || {
            let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
            c.cluster.racks = 2;
            c.hier = HierParams::with_inner(TechniqueKind::Ss)
                .with_levels(3)
                .with_fanouts(&[2, 2, 4])
                .with_master_lockfree();
            c.sched_path = crate::config::SchedPath::LockFree;
            simulate(&c).unwrap()
        };
        let a = mk();
        verify_coverage(&a.sorted_assignments(), 6_000).unwrap();
        assert!(a.fast_grants > 0);
        let b = mk();
        assert_eq!(a.assignments, b.assignments, "depth-3 master-lockfree replay");
        assert_eq!(a.t_par(), b.t_par());
    }

    /// Without a lock-free sched path the flag is inert: bit-identical to
    /// the plain two-phase run.
    #[test]
    fn master_lockfree_inert_under_two_phase() {
        let mk = |mlf: bool| {
            let mut c = cfg(3_000, 2, 4, TechniqueKind::Fac2);
            c.hier = HierParams::with_inner(TechniqueKind::Ss);
            if mlf {
                c.hier = c.hier.with_master_lockfree();
            }
            c.sched_path = crate::config::SchedPath::TwoPhase;
            simulate(&c).unwrap()
        };
        let plain = mk(false);
        let flagged = mk(true);
        assert_eq!(plain.assignments, flagged.assignments);
        assert_eq!(plain.t_par(), flagged.t_par());
        assert_eq!(plain.stats.messages, flagged.stats.messages);
        assert_eq!(flagged.fast_grants, 0);
    }

    #[test]
    fn master_lockfree_rejects_adaptive() {
        let mut c = cfg(3_000, 2, 4, TechniqueKind::Fac2);
        c.hier = HierParams::with_inner(TechniqueKind::Ss).with_adaptive().with_master_lockfree();
        c.sched_path = crate::config::SchedPath::Auto;
        assert!(simulate(&c).is_err());
    }

    /// The sharded engine (`--des-threads > 1`) is bit-identical to the
    /// sequential loop: same schedule, same makespan, same counters, for
    /// every thread count.
    #[test]
    fn pdes_matches_sequential_engine() {
        let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
        c.hier = HierParams::with_inner(TechniqueKind::Ss);
        let seq = simulate(&c).unwrap();
        assert!(seq.pdes.is_none());
        for threads in [2u32, 4, 8] {
            c.des_threads = threads;
            let par = simulate(&c).unwrap();
            assert_eq!(seq.sorted_assignments(), par.sorted_assignments(), "t={threads}");
            assert_eq!(seq.t_par(), par.t_par(), "t={threads}");
            assert_eq!(seq.fast_grants, par.fast_grants, "t={threads}");
            assert_eq!(seq.level_messages, par.level_messages, "t={threads}");
            assert_eq!(seq.stats.messages, par.stats.messages, "t={threads}");
            let p = par.pdes.expect("sharded run reports its executor summary");
            assert!(p.shards > 1, "4 subtrees must shard");
            assert_eq!(p.threads, threads.min(p.shards));
            assert!(p.lookahead_ns > 0);
        }
    }

    /// Sharded depth-3 with the fused master tier: still bit-identical to
    /// sequential — cross-shard traffic is exclusively level-0 protocol.
    #[test]
    fn pdes_depth3_master_lockfree_matches_sequential() {
        let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
        c.cluster.racks = 2;
        c.hier = HierParams::with_inner(TechniqueKind::Ss)
            .with_levels(3)
            .with_fanouts(&[2, 2, 4])
            .with_master_lockfree();
        c.sched_path = crate::config::SchedPath::LockFree;
        let seq = simulate(&c).unwrap();
        c.des_threads = 4;
        let par = simulate(&c).unwrap();
        assert_eq!(seq.sorted_assignments(), par.sorted_assignments());
        assert_eq!(seq.t_par(), par.t_par());
        assert_eq!(seq.fast_grants, par.fast_grants);
        assert_eq!(seq.level_messages, par.level_messages);
    }

    /// A single-node tree has one level-1 subtree — the PDES path
    /// degenerates to one shard and still covers the loop.
    #[test]
    fn pdes_single_shard_degenerates() {
        let mut c = cfg(3_000, 1, 8, TechniqueKind::Gss);
        c.des_threads = 4;
        let r = simulate(&c).unwrap();
        verify_coverage(&r.sorted_assignments(), 3_000).unwrap();
        assert_eq!(r.pdes.as_ref().unwrap().shards, 1);
    }

    /// `record_assignments = false` still schedules everything (stats keep
    /// counting) without logging a single grant.
    #[test]
    fn unrecorded_run_matches_recorded_stats() {
        let mut c = cfg(4_000, 2, 4, TechniqueKind::Gss);
        let recorded = simulate(&c).unwrap();
        c.record_assignments = false;
        let bare = simulate(&c).unwrap();
        assert!(bare.assignments.is_empty());
        assert_eq!(bare.stats.chunks, recorded.assignments.len() as u64);
        assert_eq!(bare.t_par(), recorded.t_par());
        assert_eq!(bare.stats.messages, recorded.stats.messages);
        assert_eq!(bare.events, recorded.events);
    }

    /// Adaptive selection on the DES tree: coverage, deterministic replay,
    /// switch events at subtree levels only, and the per-group demotion
    /// accounting staying consistent (`messages = intra + inter = Σ levels`)
    /// across an Auto run that flips groups mid-flight.
    #[test]
    fn adaptive_auto_accounting_stays_consistent() {
        use crate::techniques::CandidateSet;
        let mk = || {
            let mut c = cfg(20_000, 2, 4, TechniqueKind::Fac2);
            c.hier = HierParams::with_inner(TechniqueKind::Ss)
                .with_adaptive()
                .with_probe_interval(8)
                .with_candidates(CandidateSet::parse("ss,tap").unwrap());
            c.sched_path = crate::config::SchedPath::Auto;
            c.delay = InjectedDelay::exponential_calculation(100e-6, 7);
            c.cost = IterationCost::Constant(1e-5);
            simulate(&c).unwrap()
        };
        let r = mk();
        verify_coverage(&r.sorted_assignments(), 20_000).unwrap();
        assert!(r.fast_grants > 0, "started lock-free");
        assert!(r.switch_events.iter().any(|e| e.to == TechniqueKind::Tap));
        assert_eq!(r.stats.messages, r.intra_node_messages + r.inter_node_messages);
        assert_eq!(r.stats.messages, r.level_messages.iter().sum::<u64>());
        let b = mk();
        assert_eq!(r.assignments, b.assignments, "auto-demotion replay");
        assert_eq!(r.t_par(), b.t_par());
    }

    /// Adaptivity leaves the unrecorded-run invariants intact: stats match
    /// the recorded twin with zero grant logging.
    #[test]
    fn adaptive_unrecorded_run_matches_recorded_stats() {
        use crate::techniques::CandidateSet;
        let mut c = cfg(8_000, 2, 4, TechniqueKind::Fac2);
        c.hier = HierParams::with_inner(TechniqueKind::Ss)
            .with_adaptive()
            .with_probe_interval(4)
            .with_candidates(CandidateSet::parse("ss,gss").unwrap());
        c.delay = InjectedDelay::exponential_calculation(50e-6, 13);
        let recorded = simulate(&c).unwrap();
        c.record_assignments = false;
        let bare = simulate(&c).unwrap();
        assert!(bare.assignments.is_empty());
        assert_eq!(bare.stats.chunks, recorded.assignments.len() as u64);
        assert_eq!(bare.t_par(), recorded.t_par());
        assert_eq!(bare.switch_events, recorded.switch_events);
    }

    #[test]
    fn hier_beats_serialized_cca_under_heavy_delay() {
        // The motivating regime: a large calculation delay serializes on the
        // flat CCA master but is paid in parallel at both hierarchy levels.
        let mk = |model| {
            let cluster = cluster(4, 4);
            let mut c = DesConfig::new(
                LoopParams::new(20_000, cluster.total_ranks()),
                TechniqueKind::Ss,
                model,
                cluster,
                IterationCost::Constant(1e-5),
            );
            c.delay = InjectedDelay::calculation_only(100e-6);
            if model == ExecutionModel::HierDca {
                c.technique = TechniqueKind::Fac2; // batched outer level
                c.hier = HierParams::with_inner(TechniqueKind::Ss);
            }
            simulate(&c).unwrap().t_par()
        };
        let cca = mk(ExecutionModel::Cca);
        let hier = mk(ExecutionModel::HierDca);
        assert!(hier < cca, "hier {hier} should beat serialized CCA {cca}");
    }

    /// The hierarchy's point, asserted directly: flat DCA makes rank 0
    /// service *every* chunk's two round trips, while under hier the same
    /// CPU services only its own node's share of the inner traffic plus a
    /// handful of outer messages — its busy time must drop accordingly.
    #[test]
    fn hier_offloads_the_global_coordinator() {
        let flat = {
            let cl = cluster(4, 4);
            let c = DesConfig::new(
                LoopParams::new(10_000, cl.total_ranks()),
                TechniqueKind::Ss,
                ExecutionModel::Dca,
                cl,
                IterationCost::Constant(1e-5),
            );
            simulate(&c).unwrap()
        };
        let hier = {
            let mut c = cfg(10_000, 4, 4, TechniqueKind::Fac2);
            c.hier = HierParams::with_inner(TechniqueKind::Ss);
            simulate(&c).unwrap()
        };
        verify_coverage(&hier.sorted_assignments(), 10_000).unwrap();
        assert!(
            hier.rank0_service_busy < flat.rank0_service_busy * 0.5,
            "hier coordinator busy {}s must be well below flat DCA's {}s",
            hier.rank0_service_busy,
            flat.rank0_service_busy
        );
    }
}
