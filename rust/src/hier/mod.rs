//! Two-level hierarchical self-scheduling — the `HierDca` execution model.
//!
//! Implements the §7 future-work direction the authors themselves pursued in
//! *Hierarchical Dynamic Loop Self-Scheduling on Distributed-Memory Systems
//! Using an MPI+MPI Approach* (arXiv 1903.09510): instead of every rank
//! self-scheduling against one global coordinator over the inter-node
//! fabric, the scheduling work is split across **two levels**:
//!
//! * **Outer level (inter-node)** — a *global coordinator* (rank 0) owns the
//!   loop's [`WorkQueue`] and hands out **node-chunks** through the DCA
//!   two-phase protocol (`OuterGet → OuterStep`, `OuterCommit →
//!   OuterChunk`). Node-chunk sizes are computed **on the node masters**
//!   with the experiment's outer technique bound to `P = nodes` — the
//!   distributed-chunk-calculation idea applied at node granularity.
//! * **Inner level (intra-node)** — each *node master* (the first rank of
//!   its node, [`Topology::master_of_node`]) re-subdivides its current
//!   node-chunk among its local ranks with the (possibly different) *inner*
//!   technique bound to `P = ranks_per_node`, again via two-phase DCA
//!   (`InnerGet → Step`, `InnerCommit → Chunk`) — but over the **intra-node
//!   latency class**, which is 4× cheaper on miniHPC.
//!
//! The mapping to arXiv 1903.09510 is direct: their MPI+MPI global/local
//! work-queues become the outer [`WorkQueue`] at the coordinator and one
//! local [`WorkQueue`] per master; their shared-memory window accesses
//! become intra-node messages; their two-level DLS technique pair is
//! [`crate::config::HierParams`] (outer = the experiment's technique, inner
//! configurable). The payoff they report — and that
//! `benches/hier_sweep.rs` reproduces on the calibrated DES — is that the
//! central coordinator handles `O(node-chunks)` messages instead of
//! `O(chunks)`, so perturbations that serialize on the flat coordinator
//! (the 100 µs-class slowdown scenarios) are absorbed by the per-node
//! masters in parallel, while the no-slowdown case stays within noise.
//!
//! Like the flat models, rank 0 plus every node master is **non-dedicated**
//! when `break_after > 0`: masters interleave their own iteration execution
//! (in `breakAfter` segments) with servicing their local ranks, and rank 0
//! additionally services the outer protocol on the same serial CPU.
//!
//! AF (no closed form, §4) is supported at *both* levels through the same
//! extra synchronization the flat DCA coordinator uses: performance reports
//! piggyback on requests, the phase-1 reply carries the `(D, E)` aggregates,
//! and the requester evaluates Eq. 11 locally. At the outer level the
//! "PE statistics" are per-node throughput (iterations per wall-second of a
//! node-chunk); at the inner level they are the usual per-rank chunk stats.
//!
//! The per-node chunk ledger (two-phase reserve/commit, stale-`seq` NACK,
//! staged prefetch install) lives in [`protocol`] and is shared verbatim
//! with the **threaded** two-level engine, [`crate::coordinator::hier`] —
//! the DES and the wall-clock engine validate one protocol definition.
//! [`crate::config::HierParams::prefetch_watermark`] enables outer-level
//! prefetch on both substrates: masters request the next node-chunk while
//! the current one still has work, hiding the inter-node round trip.

pub mod protocol;

use std::collections::VecDeque;

use crate::config::{ClusterConfig, ExecutionModel};
use crate::coordinator::protocol::{AfInfo, PerfReport};
use crate::des::heap::{ns, secs, EventHeap};
use crate::des::{DesConfig, DesResult};
use crate::metrics::LoopStats;
use crate::sched::{Assignment, StepTicket, WorkQueue};
use crate::substrate::topology::Topology;
use crate::techniques::af::{af_requester_chunk, AfCalculator, AfGlobals, PeStats};
use crate::techniques::{Technique, TechniqueKind};
use protocol::{af_recap, with_np, InnerCommit, NodeLedger};

/// Can `HierDca` run on this cluster geometry? With dedicated masters
/// (`break_after == 0`) every node needs at least one non-master rank to
/// execute iterations. Single source of truth for [`simulate_hier`]'s
/// validation and the selector's candidate filtering.
pub fn hier_feasible(cluster: &ClusterConfig) -> bool {
    cluster.break_after > 0 || cluster.ranks_per_node > 1
}

/// Simulate one hierarchical (`HierDca`) run. Deterministic: same config ⇒
/// identical result. Called through [`crate::des::simulate`], which performs
/// the model-independent validation.
pub fn simulate_hier(cfg: &DesConfig) -> anyhow::Result<DesResult> {
    anyhow::ensure!(
        cfg.model == ExecutionModel::HierDca,
        "simulate_hier requires ExecutionModel::HierDca, got {}",
        cfg.model
    );
    anyhow::ensure!(
        cfg.params.p == cfg.cluster.total_ranks(),
        "LoopParams.p ({}) must equal cluster ranks ({})",
        cfg.params.p,
        cfg.cluster.total_ranks()
    );
    anyhow::ensure!(
        hier_feasible(&cfg.cluster),
        "dedicated node masters (break_after = 0) need ranks_per_node ≥ 2, \
         otherwise no rank executes iterations"
    );
    let mut sim = HierSim::new(cfg);
    sim.run();
    Ok(sim.into_result())
}

// ---------------------------------------------------------------------------
// events and tasks

/// A task queued at a node master's serial CPU. Outer *requests* are only
/// ever routed to master 0, whose CPU doubles as the global coordinator —
/// coordination and node-0 mastering contend for the same core, exactly as
/// on the real machine.
#[derive(Debug)]
enum Task {
    /// A local rank asks for its next scheduling step (inner phase 1).
    InnerGet { w: u32, report: Option<PerfReport> },
    /// A local rank commits its locally calculated size (inner phase 2);
    /// `seq` names the node-chunk the step was reserved from.
    InnerCommit { w: u32, step: u64, size: u64, seq: u64 },
    /// A node master asks the global coordinator for an outer step.
    OuterGet { from: u32, report: Option<PerfReport> },
    /// A node master commits its node-chunk size to the coordinator.
    OuterCommit { from: u32, step: u64, size: u64 },
    /// Coordinator reply: reserved outer step (+ AF aggregates). Handling it
    /// *is* the outer chunk calculation, on the master's CPU.
    OuterStep { ticket: StepTicket, af: Option<AfInfo> },
    /// Coordinator reply: the committed node-chunk.
    OuterChunk(Assignment),
    /// Coordinator reply: the loop is exhausted.
    OuterDone,
}

/// Inner-protocol reply delivered to a worker rank.
#[derive(Debug, Clone, Copy)]
enum WReply {
    /// Reserved local step: the worker calculates its own sub-chunk size.
    Step { step: u64, remaining: u64, seq: u64, af: Option<AfInfo> },
    /// Committed sub-chunk (absolute iteration range).
    Chunk(Assignment),
    /// Terminate.
    Done,
}

#[derive(Debug)]
enum Ev {
    /// A message arrives at node master `m`'s service queue.
    Arrive { m: u32, task: Task },
    /// Master `m`'s CPU finished its current action.
    ServerFree { m: u32 },
    /// An inner reply reaches worker `w`.
    WorkerReply { w: u32, reply: WReply },
    /// Worker `w` finished its local sub-chunk calculation.
    CalcDone { w: u32, step: u64, size: u64, seq: u64 },
    /// Worker `w` finished executing its sub-chunk.
    ExecDone { w: u32 },
}

// ---------------------------------------------------------------------------
// state

/// The master's own worker personality (mirrors the flat DES's `OwnState`).
#[derive(Debug)]
enum Own {
    NeedWork,
    Calc { step: u64, remaining: u64, seq: u64 },
    Commit { step: u64, size: u64, seq: u64 },
    Exec { cursor: u64, end: u64, first: u64 },
    /// Waiting for the next node-chunk (or global Done).
    Parked,
    Finished,
}

/// Per-node master: serial CPU, local queue, parked requests, outer-protocol
/// state. Master 0 additionally hosts the global coordinator.
#[derive(Debug)]
struct Master {
    rank: u32,
    queue: VecDeque<Task>,
    busy: bool,
    /// Last instant this CPU is known busy until (ns).
    cpu_busy_until_ns: u64,
    /// Total busy time spent servicing protocol messages (ns).
    service_ns: u64,
    /// The shared-protocol chunk ledger this master subdivides from.
    ledger: NodeLedger,
    /// Local ranks whose requests arrived while no local work existed.
    parked: VecDeque<u32>,
    own_parked: bool,
    fetching: bool,
    global_done: bool,
    own: Own,
    /// Inner-AF calculator over this node's local ranks (index `rank % rpn`).
    inner_af: Option<AfCalculator>,
    /// Outer-AF: this node's chunk-throughput statistics.
    node_stats: PeStats,
    outer_report: Option<PerfReport>,
    installed_ns: u64,
    installed_iters: u64,
}

/// Per-rank bookkeeping (all ranks, including masters' worker personality).
#[derive(Debug, Default, Clone)]
struct Wstate {
    chunks: u64,
    iters: u64,
    finish_ns: u64,
    wait_ns: u64,
    req_sent_ns: u64,
    stats: PeStats,
    last_report: Option<PerfReport>,
}

struct HierSim<'a> {
    cfg: &'a DesConfig,
    topo: Topology,
    heap: EventHeap<Ev>,
    now: u64,
    nodes: u32,
    rpn: u32,
    inner_kind: TechniqueKind,
    // global coordinator state (CPU-wise hosted on master 0)
    outer_q: WorkQueue,
    outer_tech: Option<Technique>,
    outer_af: Option<AfCalculator>,
    masters: Vec<Master>,
    workers: Vec<Wstate>,
    messages: u64,
    /// Message split by latency class (same-node vs cross-node endpoints).
    intra_msgs: u64,
    inter_msgs: u64,
    assignments: Vec<Assignment>,
}

impl<'a> HierSim<'a> {
    fn new(cfg: &'a DesConfig) -> Self {
        let topo = Topology::new(&cfg.cluster);
        let nodes = topo.nodes();
        let rpn = topo.ranks_per_node();
        let outer_params = with_np(&cfg.params, cfg.params.n, nodes);
        let inner_kind = cfg.hier.inner_or(cfg.technique);
        let inner_proto = with_np(&cfg.params, cfg.params.n, rpn);
        let outer_is_af = cfg.technique == TechniqueKind::Af;
        let masters = (0..nodes)
            .map(|m| Master {
                rank: topo.master_of_node(m),
                queue: VecDeque::new(),
                busy: false,
                cpu_busy_until_ns: 0,
                service_ns: 0,
                ledger: NodeLedger::new(inner_kind, &cfg.params, rpn),
                parked: VecDeque::new(),
                own_parked: false,
                fetching: false,
                global_done: false,
                own: Own::NeedWork,
                inner_af: (inner_kind == TechniqueKind::Af)
                    .then(|| AfCalculator::new(&inner_proto)),
                node_stats: PeStats::default(),
                outer_report: None,
                installed_ns: 0,
                installed_iters: 0,
            })
            .collect();
        HierSim {
            cfg,
            topo,
            heap: EventHeap::new(),
            now: 0,
            nodes,
            rpn,
            inner_kind,
            outer_q: WorkQueue::from_params(&cfg.params),
            outer_tech: (!outer_is_af).then(|| Technique::new(cfg.technique, &outer_params)),
            outer_af: outer_is_af.then(|| AfCalculator::new(&outer_params)),
            masters,
            workers: vec![Wstate::default(); cfg.params.p as usize],
            messages: 0,
            intra_msgs: 0,
            inter_msgs: 0,
            assignments: Vec::new(),
        }
    }

    // -- small helpers -----------------------------------------------------

    fn speed(&self, rank: u32) -> f64 {
        self.cfg.pe_speed.get(rank as usize).copied().unwrap_or(1.0).max(1e-9)
    }

    fn lat_ns(&self, a: u32, b: u32) -> u64 {
        ns(self.topo.latency(a, b))
    }

    fn node_of(&self, rank: u32) -> u32 {
        self.topo.node_of(rank)
    }

    fn min_chunk(&self) -> u64 {
        self.cfg.params.min_chunk.max(1)
    }

    fn exec_ns(&self, rank: u32, a: Assignment) -> u64 {
        ns(self.cfg.cost.range_cost(a.start, a.size) / self.speed(rank))
    }

    fn inner_af_info(&self, m: u32) -> Option<AfInfo> {
        self.masters[m as usize]
            .inner_af
            .as_ref()
            .and_then(|a| a.globals())
            .map(|g| AfInfo { d: g.d, e: g.e })
    }

    fn outer_af_info(&self) -> Option<AfInfo> {
        self.outer_af.as_ref().and_then(|a| a.globals()).map(|g| AfInfo { d: g.d, e: g.e })
    }

    fn grant(&mut self, rank: u32, a: Assignment) {
        self.assignments.push(a);
        let ws = &mut self.workers[rank as usize];
        ws.chunks += 1;
        ws.iters += a.size;
    }

    // -- bootstrap ---------------------------------------------------------

    fn run(&mut self) {
        // Every non-master rank opens with an InnerGet to its node master;
        // masters kick their own CPU, which parks its worker personality and
        // triggers the first outer fetch.
        for w in 0..self.cfg.params.p {
            let m = self.node_of(w);
            if w == self.masters[m as usize].rank {
                continue;
            }
            self.workers[w as usize].req_sent_ns = 0;
            self.send_inner(w, Task::InnerGet { w, report: None }, 0);
        }
        for m in 0..self.nodes {
            if self.cfg.cluster.break_after == 0 {
                self.masters[m as usize].own = Own::Finished;
            }
            self.masters[m as usize].busy = true;
            self.heap.push(0, Ev::ServerFree { m });
        }
        while let Some((t, ev)) = self.heap.pop() {
            debug_assert!(t >= self.now, "time went backwards");
            self.now = t;
            self.dispatch(ev);
        }
    }

    fn dispatch(&mut self, ev: Ev) {
        match ev {
            Ev::Arrive { m, task } => {
                let master = &mut self.masters[m as usize];
                master.queue.push_back(task);
                if !master.busy {
                    master.busy = true;
                    self.heap.push(self.now, Ev::ServerFree { m });
                }
            }
            Ev::ServerFree { m } => self.server_next_action(m),
            Ev::WorkerReply { w, reply } => self.worker_on_reply(w, reply),
            Ev::CalcDone { w, step, size, seq } => {
                self.workers[w as usize].req_sent_ns = self.now;
                self.send_inner(w, Task::InnerCommit { w, step, size, seq }, 0);
            }
            Ev::ExecDone { w } => {
                self.workers[w as usize].req_sent_ns = self.now;
                let report = self.workers[w as usize].last_report;
                self.send_inner(w, Task::InnerGet { w, report }, 0);
            }
        }
    }

    // -- messaging ---------------------------------------------------------

    /// Count one message, classified by the endpoints' latency class.
    fn count_msg(&mut self, a: u32, b: u32) {
        self.messages += 1;
        if self.node_of(a) == self.node_of(b) {
            self.intra_msgs += 1;
        } else {
            self.inter_msgs += 1;
        }
    }

    /// Send a worker-originated message to its node master.
    fn send_inner(&mut self, w: u32, task: Task, extra_ns: u64) {
        let m = self.node_of(w);
        let mrank = self.masters[m as usize].rank;
        self.count_msg(w, mrank);
        let at = self.now + extra_ns + self.lat_ns(w, mrank);
        self.heap.push(at, Ev::Arrive { m, task });
    }

    /// Send a coordinator reply to node master `to`.
    fn send_to_master(&mut self, to: u32, task: Task, dur: u64) {
        let coord = self.masters[0].rank;
        let mrank = self.masters[to as usize].rank;
        self.count_msg(coord, mrank);
        let at = self.now + dur + self.lat_ns(coord, mrank);
        self.heap.push(at, Ev::Arrive { m: to, task });
    }

    /// Send an inner reply from master `m` to local rank `w`.
    fn send_worker(&mut self, m: u32, w: u32, reply: WReply, dur: u64) {
        let mrank = self.masters[m as usize].rank;
        self.count_msg(mrank, w);
        let at = self.now + dur + self.lat_ns(mrank, w);
        self.heap.push(at, Ev::WorkerReply { w, reply });
    }

    // -- master CPU --------------------------------------------------------

    fn server_next_action(&mut self, m: u32) {
        if let Some(task) = self.masters[m as usize].queue.pop_front() {
            let dur = self.service(m, task);
            let master = &mut self.masters[m as usize];
            master.service_ns += dur;
            master.busy = true;
            master.cpu_busy_until_ns = self.now + dur;
            self.heap.push(self.now + dur, Ev::ServerFree { m });
            return;
        }
        self.own_next_action(m);
    }

    /// Service one queued task on master `m`'s CPU; returns the (speed-
    /// scaled) CPU occupancy in ns and schedules replies/follow-ups.
    fn service(&mut self, m: u32, task: Task) -> u64 {
        let c = &self.cfg.cluster;
        let sp = self.speed(self.masters[m as usize].rank);
        match task {
            Task::InnerGet { w, report } => {
                let dur = ns(c.service_time / sp);
                self.record_inner_report(m, w, report);
                self.inner_get(m, w, dur);
                dur
            }
            Task::InnerCommit { w, step, size, seq } => {
                let dur = ns((c.service_time + self.cfg.delay.assignment) / sp);
                self.inner_commit(m, w, step, size, seq, dur);
                dur
            }
            Task::OuterGet { from, report } => {
                debug_assert_eq!(m, 0, "outer requests are served by the coordinator");
                let dur = ns(c.service_time / sp);
                if let (Some(af), Some(r)) = (self.outer_af.as_mut(), report) {
                    af.record(from as usize, r.iters, r.elapsed);
                }
                let reply = match self.outer_q.begin_step() {
                    Some(ticket) => Task::OuterStep { ticket, af: self.outer_af_info() },
                    None => Task::OuterDone,
                };
                self.send_to_master(from, reply, dur);
                dur
            }
            Task::OuterCommit { from, step, size } => {
                debug_assert_eq!(m, 0, "outer commits are served by the coordinator");
                let dur = ns((c.service_time + self.cfg.delay.assignment) / sp);
                // Outer AF: re-apply the ⌈R/nodes⌉ cap against the fresh
                // remaining count (the ticket snapshot is stale once other
                // masters commit — same rule as the flat DCA coordinator).
                let size = if self.cfg.technique == TechniqueKind::Af {
                    af_recap(size, self.outer_q.remaining(), self.nodes)
                } else {
                    size
                };
                let ticket = StepTicket { step, remaining: self.outer_q.remaining() };
                let reply = match self.outer_q.commit(ticket, size) {
                    Some(a) => Task::OuterChunk(a),
                    None => Task::OuterDone,
                };
                self.send_to_master(from, reply, dur);
                dur
            }
            Task::OuterStep { ticket, af } => {
                // The outer chunk CALCULATION runs here, on the master's own
                // CPU — distributed across nodes, paying the injected delay
                // in parallel (the DCA idea, one level up).
                let mrank = self.masters[m as usize].rank;
                let dur = ns((self.cfg.delay.calculation_at(mrank, self.now) + c.calc_time) / sp);
                let size = self.outer_calc(m, ticket, af);
                let coord = self.masters[0].rank;
                self.count_msg(mrank, coord);
                let at = self.now + dur + self.lat_ns(mrank, coord);
                self.heap.push(
                    at,
                    Ev::Arrive {
                        m: 0,
                        task: Task::OuterCommit { from: m, step: ticket.step, size },
                    },
                );
                dur
            }
            Task::OuterChunk(a) => {
                let dur = ns(c.service_time / sp);
                self.install_chunk(m, a);
                dur
            }
            Task::OuterDone => {
                let dur = ns(c.service_time / sp);
                let master = &mut self.masters[m as usize];
                master.global_done = true;
                master.fetching = false;
                self.requeue_parked(m);
                dur
            }
        }
    }

    fn record_inner_report(&mut self, m: u32, w: u32, report: Option<PerfReport>) {
        if let Some(r) = report {
            let mrank = self.masters[m as usize].rank;
            let idx = (w - mrank) as usize;
            if let Some(af) = self.masters[m as usize].inner_af.as_mut() {
                af.record(idx, r.iters, r.elapsed);
            }
        }
    }

    /// Reserve the next local step from `m`'s ledger, if it has work.
    /// Shared by the worker service path and the master's own personality.
    fn local_reserve(&mut self, m: u32) -> Option<(u64, u64, u64)> {
        self.masters[m as usize].ledger.reserve()
    }

    fn inner_get(&mut self, m: u32, w: u32, dur: u64) {
        let af = self.inner_af_info(m);
        if let Some((step, remaining, seq)) = self.local_reserve(m) {
            self.send_worker(m, w, WReply::Step { step, remaining, seq, af }, dur);
        } else if self.masters[m as usize].global_done {
            self.send_worker(m, w, WReply::Done, dur);
        } else {
            self.masters[m as usize].parked.push_back(w);
            self.maybe_fetch(m, dur);
        }
    }

    fn inner_commit(&mut self, m: u32, w: u32, step: u64, size: u64, seq: u64, dur: u64) {
        match self.masters[m as usize].ledger.commit(step, size, seq) {
            InnerCommit::Granted(abs) => {
                self.grant(w, abs);
                self.send_worker(m, w, WReply::Chunk(abs), dur);
                self.maybe_prefetch(m, dur);
            }
            // Stale seq: the node-chunk was replaced while this commit was
            // in flight. Re-serve the request as a fresh phase-1 Get so the
            // worker calculates against the *current* chunk instead of
            // silently committing a size computed for the old one.
            InnerCommit::Stale => self.inner_get(m, w, dur),
            InnerCommit::Drained if self.masters[m as usize].global_done => {
                self.send_worker(m, w, WReply::Done, dur);
            }
            // The local queue filled between this worker's Step and its
            // Commit: park it — it gets a fresh Step from the next
            // node-chunk (its stale size is discarded).
            InnerCommit::Drained => {
                self.masters[m as usize].parked.push_back(w);
                self.maybe_fetch(m, dur);
            }
        }
    }

    /// Outer-level prefetch: once the current node-chunk drains to the
    /// configured watermark, request the next one while the local ranks keep
    /// consuming the tail — the inter-node round trip plus the outer chunk
    /// calculation are hidden instead of stalling the whole node.
    fn maybe_prefetch(&mut self, m: u32, dur: u64) {
        if self.masters[m as usize].ledger.wants_prefetch(self.cfg.hier.prefetch_watermark) {
            self.maybe_fetch(m, dur);
        }
    }

    /// Trigger the outer fetch for master `m` unless one is already in
    /// flight. Also finalizes the consumed node-chunk's throughput report
    /// (the outer-AF performance feedback).
    fn maybe_fetch(&mut self, m: u32, dur: u64) {
        let mi = m as usize;
        if self.masters[mi].fetching || self.masters[mi].global_done {
            return;
        }
        self.masters[mi].fetching = true;
        if self.masters[mi].installed_iters > 0 {
            let iters = self.masters[mi].installed_iters;
            let elapsed =
                secs((self.now + dur).saturating_sub(self.masters[mi].installed_ns)).max(1e-12);
            self.masters[mi].node_stats.record(iters, elapsed);
            self.masters[mi].outer_report = Some(PerfReport { iters, elapsed });
            self.masters[mi].installed_iters = 0;
        }
        let report = self.masters[mi].outer_report.take();
        let mrank = self.masters[mi].rank;
        let coord = self.masters[0].rank;
        self.count_msg(mrank, coord);
        let at = self.now + dur + self.lat_ns(mrank, coord);
        self.heap.push(at, Ev::Arrive { m: 0, task: Task::OuterGet { from: m, report } });
    }

    fn install_chunk(&mut self, m: u32, a: Assignment) {
        let mi = m as usize;
        self.masters[mi].ledger.install(a);
        self.masters[mi].fetching = false;
        // Under prefetch, installs accumulate between throughput
        // finalizations (the staged chunk arrives mid-consumption).
        if self.masters[mi].installed_iters == 0 {
            self.masters[mi].installed_ns = self.now;
        }
        self.masters[mi].installed_iters += a.size;
        self.requeue_parked(m);
    }

    /// Re-enqueue parked local requests (each pays its service cost again)
    /// and wake the master's own personality if it was parked.
    fn requeue_parked(&mut self, m: u32) {
        let mi = m as usize;
        while let Some(w) = self.masters[mi].parked.pop_front() {
            self.masters[mi].queue.push_back(Task::InnerGet { w, report: None });
        }
        if self.masters[mi].own_parked {
            self.masters[mi].own_parked = false;
            self.masters[mi].own = Own::NeedWork;
        }
    }

    /// Outer chunk size, computed on master `m` (closed form of the outer
    /// technique at the reserved step, or AF's Eq. 11 over node throughput).
    fn outer_calc(&self, m: u32, ticket: StepTicket, af: Option<AfInfo>) -> u64 {
        if self.cfg.technique == TechniqueKind::Af {
            af_requester_chunk(
                &self.masters[m as usize].node_stats,
                af.map(|i| AfGlobals { d: i.d, e: i.e }),
                ticket.remaining,
                self.nodes,
                self.min_chunk(),
            )
        } else {
            self.outer_tech
                .as_ref()
                .expect("non-AF outer technique has a closed form")
                .closed_chunk(ticket.step)
        }
    }

    // -- worker ranks ------------------------------------------------------

    fn worker_on_reply(&mut self, w: u32, reply: WReply) {
        let sent = self.workers[w as usize].req_sent_ns;
        self.workers[w as usize].wait_ns += self.now.saturating_sub(sent);
        match reply {
            WReply::Step { step, remaining, seq, af } => {
                // Distributed inner calculation on the worker's own clock —
                // the injected delay is paid here, in parallel.
                let dur = ns(
                    (self.cfg.delay.calculation_at(w, self.now) + self.cfg.cluster.calc_time)
                        / self.speed(w),
                );
                let size = self.worker_calc(w, step, remaining, seq, af);
                self.heap.push(self.now + dur, Ev::CalcDone { w, step, size, seq });
            }
            WReply::Chunk(a) => {
                let dur = self.exec_ns(w, a);
                let elapsed = secs(dur);
                let ws = &mut self.workers[w as usize];
                ws.stats.record(a.size, elapsed);
                ws.last_report = Some(PerfReport { iters: a.size, elapsed });
                self.heap.push(self.now + dur, Ev::ExecDone { w });
            }
            WReply::Done => {
                self.workers[w as usize].finish_ns = self.now;
            }
        }
    }

    /// Inner sub-chunk size, calculated worker-side (closed form of the
    /// inner technique bound to the current node-chunk, or AF's Eq. 11).
    fn worker_calc(&self, w: u32, step: u64, remaining: u64, seq: u64, af: Option<AfInfo>) -> u64 {
        if self.inner_kind == TechniqueKind::Af {
            af_requester_chunk(
                &self.workers[w as usize].stats,
                af.map(|i| AfGlobals { d: i.d, e: i.e }),
                remaining,
                self.rpn,
                self.min_chunk(),
            )
        } else {
            // Normal case: the node-chunk this step belongs to is still
            // installed; evaluate its bound closed form. If the chunk was
            // replaced while this Step was in flight, the commit will NACK
            // and re-request, so the size is moot.
            let m = self.node_of(w);
            self.masters[m as usize]
                .ledger
                .closed_inner_size(step, seq)
                .unwrap_or_else(|| self.min_chunk())
        }
    }

    // -- master's own worker personality -----------------------------------

    fn own_next_action(&mut self, m: u32) {
        let mi = m as usize;
        let mrank = self.masters[mi].rank;
        let sp = self.speed(mrank);
        let c = &self.cfg.cluster;
        let cluster_break = c.break_after.max(1) as u64;
        match std::mem::replace(&mut self.masters[mi].own, Own::Finished) {
            Own::NeedWork => {
                let dur = ns(c.service_time / sp);
                if let Some((step, remaining, seq)) = self.local_reserve(m) {
                    self.masters[mi].own = Own::Calc { step, remaining, seq };
                } else if self.masters[mi].global_done {
                    self.finish_own(m);
                } else {
                    self.masters[mi].own = Own::Parked;
                    self.masters[mi].own_parked = true;
                    self.maybe_fetch(m, dur);
                }
                self.finish_server_action(m, dur);
            }
            Own::Calc { step, remaining, seq } => {
                let dur = ns((self.cfg.delay.calculation_at(mrank, self.now) + c.calc_time) / sp);
                let af = self.inner_af_info(m);
                let size = self.worker_calc(mrank, step, remaining, seq, af);
                self.masters[mi].own = Own::Commit { step, size, seq };
                self.finish_server_action(m, dur);
            }
            Own::Commit { step, size, seq } => {
                let dur = ns((c.service_time + self.cfg.delay.assignment) / sp);
                match self.masters[mi].ledger.commit(step, size, seq) {
                    InnerCommit::Granted(abs) => {
                        self.grant(mrank, abs);
                        self.masters[mi].own =
                            Own::Exec { cursor: abs.start, end: abs.end(), first: abs.start };
                        self.maybe_prefetch(m, dur);
                    }
                    // Stale seq: a new node-chunk arrived between this
                    // personality's Calc and Commit — re-reserve from it.
                    InnerCommit::Stale => self.masters[mi].own = Own::NeedWork,
                    InnerCommit::Drained if self.masters[mi].global_done => {
                        self.finish_own(m);
                    }
                    InnerCommit::Drained => {
                        self.masters[mi].own = Own::Parked;
                        self.masters[mi].own_parked = true;
                        self.maybe_fetch(m, dur);
                    }
                }
                self.finish_server_action(m, dur);
            }
            Own::Exec { cursor, end, first } => {
                let seg = cluster_break.min(end - cursor);
                let dur = ns(self.cfg.cost.range_cost(cursor, seg) / sp);
                let new_cursor = cursor + seg;
                if new_cursor < end {
                    self.masters[mi].own = Own::Exec { cursor: new_cursor, end, first };
                } else {
                    let iters = end - first;
                    let elapsed = self.cfg.cost.range_cost(first, iters) / sp;
                    self.workers[mrank as usize].stats.record(iters, elapsed);
                    if let Some(af) = self.masters[mi].inner_af.as_mut() {
                        af.record(0, iters, elapsed);
                    }
                    self.masters[mi].own = Own::NeedWork;
                }
                self.finish_server_action(m, dur);
            }
            Own::Parked => {
                self.masters[mi].own = Own::Parked;
                self.masters[mi].busy = false;
            }
            Own::Finished => {
                self.masters[mi].own = Own::Finished;
                self.masters[mi].busy = false;
            }
        }
    }

    fn finish_own(&mut self, m: u32) {
        let mi = m as usize;
        self.masters[mi].own = Own::Finished;
        let mrank = self.masters[mi].rank as usize;
        self.workers[mrank].finish_ns = self.workers[mrank].finish_ns.max(self.now);
    }

    fn finish_server_action(&mut self, m: u32, dur: u64) {
        let master = &mut self.masters[m as usize];
        master.busy = true;
        master.cpu_busy_until_ns = self.now + dur;
        self.heap.push(self.now + dur, Ev::ServerFree { m });
    }

    // -- results -----------------------------------------------------------

    fn into_result(self) -> DesResult {
        let mut finish: Vec<f64> = self.workers.iter().map(|w| secs(w.finish_ns)).collect();
        for master in &self.masters {
            let r = master.rank as usize;
            finish[r] = finish[r].max(secs(master.cpu_busy_until_ns));
        }
        let chunks = self.assignments.len() as u64;
        let wait: f64 = self.workers.iter().map(|w| secs(w.wait_ns)).sum();
        DesResult {
            stats: LoopStats::from_finish_times(&finish, chunks, wait, self.messages),
            finish,
            rank0_service_busy: secs(self.masters[0].service_ns),
            assignments: self.assignments,
            rma_ops: 0,
            intra_node_messages: self.intra_msgs,
            inter_node_messages: self.inter_msgs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, HierParams};
    use crate::des::simulate;
    use crate::sched::verify_coverage;
    use crate::substrate::delay::InjectedDelay;
    use crate::techniques::LoopParams;
    use crate::workload::IterationCost;

    fn cluster(nodes: u32, rpn: u32) -> ClusterConfig {
        ClusterConfig { nodes, ranks_per_node: rpn, ..ClusterConfig::minihpc() }
    }

    fn cfg(n: u64, nodes: u32, rpn: u32, kind: TechniqueKind) -> DesConfig {
        let cluster = cluster(nodes, rpn);
        DesConfig::new(
            LoopParams::new(n, cluster.total_ranks()),
            kind,
            ExecutionModel::HierDca,
            cluster,
            IterationCost::Constant(1e-5),
        )
    }

    fn sorted(r: &DesResult) -> Vec<Assignment> {
        let mut v = r.assignments.clone();
        v.sort_by_key(|a| a.start);
        v
    }

    #[test]
    fn covers_loop_all_techniques_small() {
        for kind in TechniqueKind::ALL {
            let c = cfg(2_000, 2, 4, kind);
            let r = simulate(&c).unwrap_or_else(|e| panic!("{kind}: {e}"));
            verify_coverage(&sorted(&r), 2_000).unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert!(r.t_par() > 0.0, "{kind}");
            assert_eq!(r.rma_ops, 0);
            assert!(r.stats.messages > 0);
            assert_eq!(
                r.stats.messages,
                r.intra_node_messages + r.inter_node_messages,
                "{kind}: split must reconcile with the flat counter"
            );
            assert!(r.inter_node_messages > 0, "{kind}: outer protocol crossed nodes");
        }
    }

    /// Prefetch keeps exact coverage, replays deterministically, and the
    /// split message counters reconcile.
    #[test]
    fn prefetch_covers_and_replays() {
        let mut c = cfg(6_000, 4, 4, TechniqueKind::Fac2);
        c.hier = HierParams::with_inner(TechniqueKind::Ss).with_watermark(16);
        let a = simulate(&c).unwrap();
        verify_coverage(&sorted(&a), 6_000).unwrap();
        let b = simulate(&c).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.t_par(), b.t_par());
        assert_eq!(a.stats.messages, a.intra_node_messages + a.inter_node_messages);
    }

    #[test]
    fn deterministic_replay() {
        let c = cfg(10_000, 4, 4, TechniqueKind::Fac2);
        let a = simulate(&c).unwrap();
        let b = simulate(&c).unwrap();
        assert_eq!(a.t_par(), b.t_par());
        assert_eq!(a.stats.messages, b.stats.messages);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn mixed_inner_technique_covers() {
        let mut c = cfg(5_000, 2, 8, TechniqueKind::Fac2);
        c.hier = HierParams::with_inner(TechniqueKind::Ss);
        let r = simulate(&c).unwrap();
        verify_coverage(&sorted(&r), 5_000).unwrap();
        // SS inside: sub-chunks of one iteration dominate the multiset.
        let ones = r.assignments.iter().filter(|a| a.size == 1).count();
        assert!(ones > r.assignments.len() / 2, "inner SS must produce unit chunks");
    }

    #[test]
    fn dedicated_masters_serve_but_do_not_compute() {
        let mut c = cfg(2_000, 2, 4, TechniqueKind::Gss);
        c.cluster.break_after = 0;
        let r = simulate(&c).unwrap();
        verify_coverage(&sorted(&r), 2_000).unwrap();
        assert!(r.rank0_service_busy > 0.0);
    }

    #[test]
    fn dedicated_masters_with_single_rank_nodes_rejected() {
        let mut c = cfg(100, 4, 1, TechniqueKind::Gss);
        c.cluster.break_after = 0;
        assert!(simulate(&c).is_err());
    }

    #[test]
    fn single_rank_nodes_work_when_masters_compute() {
        let c = cfg(1_000, 4, 1, TechniqueKind::Tss);
        let r = simulate(&c).unwrap();
        verify_coverage(&sorted(&r), 1_000).unwrap();
    }

    #[test]
    fn single_node_degenerates_gracefully() {
        let c = cfg(3_000, 1, 8, TechniqueKind::Gss);
        let r = simulate(&c).unwrap();
        verify_coverage(&sorted(&r), 3_000).unwrap();
    }

    #[test]
    fn af_both_levels_learns_and_covers() {
        let c = cfg(4_000, 2, 4, TechniqueKind::Af);
        let r = simulate(&c).unwrap();
        verify_coverage(&sorted(&r), 4_000).unwrap();
        let max = r.assignments.iter().map(|a| a.size).max().unwrap();
        assert!(max > 1, "AF should grow beyond bootstrap");
    }

    #[test]
    fn more_ranks_than_iterations() {
        let c = cfg(5, 2, 4, TechniqueKind::Gss);
        let r = simulate(&c).unwrap();
        verify_coverage(&sorted(&r), 5).unwrap();
    }

    #[test]
    fn hier_beats_serialized_cca_under_heavy_delay() {
        // The motivating regime: a large calculation delay serializes on the
        // flat CCA master but is paid in parallel at both hierarchy levels.
        let mk = |model| {
            let cluster = cluster(4, 4);
            let mut c = DesConfig::new(
                LoopParams::new(20_000, cluster.total_ranks()),
                TechniqueKind::Ss,
                model,
                cluster,
                IterationCost::Constant(1e-5),
            );
            c.delay = InjectedDelay::calculation_only(100e-6);
            if model == ExecutionModel::HierDca {
                c.technique = TechniqueKind::Fac2; // batched outer level
                c.hier = HierParams::with_inner(TechniqueKind::Ss);
            }
            simulate(&c).unwrap().t_par()
        };
        let cca = mk(ExecutionModel::Cca);
        let hier = mk(ExecutionModel::HierDca);
        assert!(hier < cca, "hier {hier} should beat serialized CCA {cca}");
    }

    /// The hierarchy's point, asserted directly: flat DCA makes rank 0
    /// service *every* chunk's two round trips, while under hier the same
    /// CPU services only its own node's share of the inner traffic plus a
    /// handful of outer messages — its busy time must drop accordingly.
    #[test]
    fn hier_offloads_the_global_coordinator() {
        let flat = {
            let cl = cluster(4, 4);
            let c = DesConfig::new(
                LoopParams::new(10_000, cl.total_ranks()),
                TechniqueKind::Ss,
                ExecutionModel::Dca,
                cl,
                IterationCost::Constant(1e-5),
            );
            simulate(&c).unwrap()
        };
        let hier = {
            let mut c = cfg(10_000, 4, 4, TechniqueKind::Fac2);
            c.hier = HierParams::with_inner(TechniqueKind::Ss);
            simulate(&c).unwrap()
        };
        verify_coverage(&sorted(&hier), 10_000).unwrap();
        assert!(
            hier.rank0_service_busy < flat.rank0_service_busy * 0.5,
            "hier coordinator busy {}s must be well below flat DCA's {}s",
            hier.rank0_service_busy,
            flat.rank0_service_busy
        );
    }
}
