//! `dca-dls` — CLI launcher for the DCA/DLS reproduction.
//!
//! Subcommands map one-to-one onto the paper's artifacts (DESIGN.md §4):
//! `table2`, `fig1`, `table3`, `fig4`, `fig5`, plus `simulate` (one factorial
//! cell), `run` (real threaded engine, optionally through the PJRT
//! artifacts), `sweep-breakafter` (A3 ablation) and `validate` (PJRT vs
//! native cross-check).

use std::collections::HashMap;
use std::sync::Arc;

use dca_dls::config::{
    ClusterConfig, DelaySite, ExecutionModel, HierParams, SchedPath, WatermarkMode,
};
use dca_dls::coordinator::{self, EngineConfig};
use dca_dls::des::{simulate, DesConfig};
use dca_dls::report::figures::{
    fig1_series, run_figure, table2_rows, table3_rows, App, FigureConfig,
};
use dca_dls::report::json::Json;
use dca_dls::report::{render_figure, render_table2, render_table3};
use dca_dls::runtime::workload::{PjrtMandelbrot, PjrtPsia};
use dca_dls::runtime::Runtime;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::tenant::spec::{parse_session_spec, render_session_json};
use dca_dls::tenant::{
    session_slowdowns, simulate_session, ArbitrationPolicy, SessionConfig, TenantSpec,
};
use dca_dls::workload::mandelbrot::Mandelbrot;
use dca_dls::workload::psia::Psia;
use dca_dls::workload::Workload;

const USAGE: &str = "\
dca-dls — Distributed Chunk Calculation for DLS (Eleliemy & Ciorba 2021)

USAGE: dca-dls <command> [--flag value]...

COMMANDS
  table2             chunk sequences, N=1000 P=4 (Table 2)   [--n --p]
  fig1               chunk-size series per technique (Fig 1) [--n --p]
  table3             loop characteristics (Table 3)          [--n --ct --cloud]
  fig4               PSIA factorial experiment (Fig 4)       [--quick --reps --delay-site --hier --inner T --watermark W|auto --json F]
  fig5               Mandelbrot factorial experiment (Fig 5) [--quick --reps --delay-site --hier --inner T --watermark W|auto --json F]
  simulate           one DES cell  [--app --tech --model --inner --delay-us --ranks --n
                       --sched-path two-phase|lockfree|auto --adaptive --probe-interval G --candidates t,…]
  hier               N-level HIER-DCA vs the flat models     [--app --tech --inner --levels K --fanout a,b,…
                       --techniques t0,t1,… --watermark W|auto --prefetch-depth Q --nodes --rpn
                       --racks R --rack-latency-us X --n --delay-us --delay-site --lockfree
                       --sched-path auto --adaptive --probe-interval G --candidates t,… --json F]
  run                real threaded engine [--app --tech --model --workers --n --pjrt --delay-us
                       --hier --inner T --nodes K --levels K --fanout a,b,… --techniques t0,t1,…
                       --watermark W|auto (0 = fetch on exhaustion) --prefetch-depth Q
                       --lockfree (single-CAS grants for closed-form techniques) --sched-path auto
                       --adaptive --probe-interval G --candidates t,… --json F]
  sweep-breakafter   A3 ablation: master breakAfter sweep [--app --tech]
  select             SimAS-style model auto-selection (§7, 4 models) [--app --tech --inner --levels K
                       --fanout a,b,… --watermark W|auto --delay-us --lockfree --sched-path P
                       --adaptive --probe-interval G --candidates t,…]
  tenants            multi-tenant DES session: many loops over ONE shared cluster
                       [--spec FILE | --demo K --seed S] [--ranks R
                        --policy fair|priority|fifo --lockfree --sched-path P
                        --slowdown --json F]
  validate           PJRT artifacts vs native implementations

MULTI-TENANT SESSIONS (tenants)
  Admits many self-scheduled loops (tenants) to one shared cluster; every
  rank arbitrates between the per-tenant chunk ledgers it hosts using the
  session policy (fair = weighted fair-share over granted iterations,
  priority = strict classes, fifo = arrival order). `--spec FILE` loads a
  JSON session spec (see rust/src/README.md); `--demo K` synthesizes K
  seeded tenants with staggered arrivals and overlapping placements.
  `--slowdown` re-runs each tenant solo and reports per-tenant slowdown.

    dca-dls tenants --demo 12 --ranks 64 --policy fair --slowdown

ADAPTIVE SELECTION (--adaptive)
  Every subtree master (and the flat DCA coordinator) re-binds its
  technique slot online, SimAS-style: per-subtree EWMAs of iteration
  mean/σ, per-grant overhead and drain rate feed a closed-form probe over
  the candidate set every --probe-interval grants. `--sched-path auto`
  starts lock-free and demotes a subtree to the two-phase protocol when
  its controller selects the measurement-coupled TAP; AF cannot be a
  candidate (no closed form to probe). Example:

    dca-dls hier --tech fac --inner ss --adaptive --probe-interval 16 \\
            --candidates ss,gss,fac --sched-path auto --delay-us 100

HIERARCHY DEPTH (--levels)
  The scheduling tree is depth 2 by default (coordinator → node masters →
  ranks). `--levels 3` nests a third tier — rack → node → socket — over the
  cluster's latency triple; fan-outs multiply to the rank count (a trailing
  entry may be omitted and is derived), and `--techniques` names one
  technique per level, outer first. Example: a 256-rank depth-3 sweep with
  4 racks of 4 nodes, FAC outer, GSS per rack, FSC within the node:

    dca-dls hier --levels 3 --fanout 4,4 --techniques fac,gss,fsc \\
            --racks 4 --rack-latency-us 100 --watermark auto

  `run --hier --levels 3 --fanout 2,2 --workers 16` drives the same tree on
  real threads.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, flags)) = parse(&args) else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let r = match cmd.as_str() {
        "table2" => cmd_table2(&flags),
        "fig1" => cmd_fig1(&flags),
        "table3" => cmd_table3(&flags),
        "fig4" => cmd_figure(App::Psia, "Figure 4 (PSIA)", &flags),
        "fig5" => cmd_figure(App::Mandelbrot, "Figure 5 (Mandelbrot)", &flags),
        "simulate" => cmd_simulate(&flags),
        "hier" => cmd_hier(&flags),
        "run" => cmd_run(&flags),
        "sweep-breakafter" => cmd_sweep_breakafter(&flags),
        "select" => cmd_select(&flags),
        "tenants" => cmd_tenants(&flags),
        "validate" => cmd_validate(),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `cmd --k v --flag` → (cmd, {k: v, flag: ""}).
fn parse(args: &[String]) -> Option<(String, HashMap<String, String>)> {
    let cmd = args.first()?.clone();
    let mut flags = HashMap::new();
    let mut i = 1;
    while i < args.len() {
        let a = args[i].strip_prefix("--")?;
        if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            flags.insert(a.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            flags.insert(a.to_string(), String::new());
            i += 1;
        }
    }
    Some((cmd, flags))
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn params_from(flags: &HashMap<String, String>) -> LoopParams {
    LoopParams::new(get(flags, "n", 1000u64), get(flags, "p", 4u32))
}

fn cmd_table2(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let params = params_from(flags);
    print!("{}", render_table2(&table2_rows(&params)));
    Ok(())
}

fn cmd_fig1(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let params = params_from(flags);
    println!("== Fig 1: chunk sizes per scheduling step (N={}, P={}) ==", params.n, params.p);
    for (kind, sizes) in fig1_series(&params) {
        println!("{:<8} pattern={:?}", kind.name(), kind.pattern());
        let pts: Vec<String> =
            sizes.iter().enumerate().map(|(i, s)| format!("({i},{s})")).collect();
        println!("  {}", pts.join(" "));
    }
    Ok(())
}

fn cmd_table3(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let n = get(flags, "n", 262_144u64);
    let ct = get(flags, "ct", 2_000u32);
    let cloud = get(flags, "cloud", 2_048usize);
    println!("(Mandelbrot CT scaled to {ct}; paper used 1,000,000 — shape is CT-invariant)");
    print!("{}", render_table3(&table3_rows(n, ct, cloud)));
    Ok(())
}

fn cmd_figure(app: App, title: &str, flags: &HashMap<String, String>) -> anyhow::Result<()> {
    reject_sched_path_flags(flags, title)?;
    reject_adaptive_flags(flags, title)?;
    let mut cfg = if flags.contains_key("quick") {
        FigureConfig::quick(app)
    } else {
        FigureConfig::paper(app)
    };
    cfg.reps = get(flags, "reps", cfg.reps);
    if let Some(site) = flags.get("delay-site") {
        cfg.delay_site = match site.as_str() {
            "assignment" => DelaySite::Assignment,
            _ => DelaySite::Calculation,
        };
    }
    anyhow::ensure!(
        !flags.contains_key("techniques"),
        "--techniques does not apply to figures (they sweep the outer techniques); \
         use --inner (and --levels/--fanout) for the hierarchy's lower levels"
    );
    cfg.cluster = apply_rack_flags(cfg.cluster, flags)?;
    if flags.contains_key("hier") {
        cfg.models.push(ExecutionModel::HierDca);
        cfg.hier = hier_of(flags)?;
    } else if HIER_ONLY_FLAGS.iter().any(|k| flags.contains_key(*k)) {
        anyhow::bail!(
            "--inner/--watermark/--levels/… only apply to the hierarchical model; \
             pass --hier as well"
        );
    }
    let rows = run_figure(&cfg)?;
    print!("{}", render_figure(title, &rows, cfg.hier.depth() as u32));
    if let Some(path) = flags.get("json") {
        let arr = Json::Arr(
            rows.iter()
                .map(|r| {
                    Json::obj()
                        .field("technique", r.technique.name())
                        .field("model", r.model.name())
                        .field("delay_us", r.delay * 1e6)
                        .field("t_par_mean", r.runs.t_par_mean)
                        .field("t_par_stddev", r.runs.t_par_stddev)
                        .field("chunks", r.chunks)
                })
                .collect(),
        );
        std::fs::write(path, arr.render())?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn app_of(flags: &HashMap<String, String>) -> App {
    match flags.get("app").map(String::as_str) {
        Some("mandelbrot") => App::Mandelbrot,
        _ => App::Psia,
    }
}

fn parse_tech(name: &str) -> anyhow::Result<TechniqueKind> {
    TechniqueKind::parse(name).ok_or_else(|| anyhow::anyhow!("unknown technique '{name}'"))
}

fn tech_of(flags: &HashMap<String, String>) -> anyhow::Result<TechniqueKind> {
    parse_tech(flags.get("tech").map(String::as_str).unwrap_or("GSS"))
}

/// The experiment's (outer, level-0) technique: `--techniques`' first entry
/// wins over `--tech`.
fn outer_tech_of(flags: &HashMap<String, String>) -> anyhow::Result<TechniqueKind> {
    match flags.get("techniques") {
        Some(raw) => parse_tech(raw.split(',').next().unwrap_or("").trim()),
        None => tech_of(flags),
    }
}

fn model_of(flags: &HashMap<String, String>) -> ExecutionModel {
    flags
        .get("model")
        .and_then(|m| ExecutionModel::parse(m))
        .unwrap_or(ExecutionModel::Dca)
}

/// Hierarchical-tree flags: `--inner T` (deepest-level technique, default:
/// same as outer), `--levels K` (tree depth, default 2), `--fanout a,b,…`
/// (children per level, outer first; a trailing entry may be omitted),
/// `--techniques t0,t1,…` (one technique per level, outer first — t0 also
/// overrides `--tech`, see [`outer_tech_of`]), `--watermark W|auto`
/// (prefetch: fixed iteration count, 0 = fetch on exhaustion, or the
/// EWMA-adaptive policy), `--prefetch-depth Q` (staged-queue capacity).
fn hier_of(flags: &HashMap<String, String>) -> anyhow::Result<HierParams> {
    let mut hier = match flags.get("inner") {
        None => HierParams::default(),
        Some(name) => HierParams::with_inner(
            TechniqueKind::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown inner technique '{name}'"))?,
        ),
    };
    if let Some(raw) = flags.get("levels") {
        let k: u32 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --levels '{raw}' (expect a tree depth)"))?;
        anyhow::ensure!(
            (1..=dca_dls::config::MAX_LEVELS as u32).contains(&k),
            "--levels must be in 1..={} (got {k})",
            dca_dls::config::MAX_LEVELS
        );
        hier = hier.with_levels(k);
    }
    if let Some(raw) = flags.get("fanout") {
        let fanouts: Vec<u32> = raw
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u32>()
                    .map_err(|_| anyhow::anyhow!("bad --fanout '{raw}' (expect a,b,…)"))
            })
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            !fanouts.is_empty() && fanouts.len() <= hier.depth(),
            "--fanout takes at most --levels ({}) entries, got {}",
            hier.depth(),
            fanouts.len()
        );
        hier = hier.with_fanouts(&fanouts);
    }
    if let Some(raw) = flags.get("techniques") {
        let kinds: Vec<TechniqueKind> = raw
            .split(',')
            .map(|s| parse_tech(s.trim()))
            .collect::<anyhow::Result<_>>()?;
        let k = hier.depth();
        anyhow::ensure!(
            kinds.len() == k,
            "--techniques needs one entry per level ({k}), got {}",
            kinds.len()
        );
        // kinds[0] is the outer technique (consumed by `outer_tech_of`).
        for (d, kind) in kinds.iter().enumerate().skip(1) {
            if d == k - 1 {
                hier.inner = Some(*kind);
            } else {
                hier = hier.with_mid(d, *kind);
            }
        }
    }
    if let Some(raw) = flags.get("watermark") {
        if raw == "auto" {
            hier = hier.with_auto_watermark();
        } else {
            let w: u64 = raw.parse().map_err(|_| {
                anyhow::anyhow!("bad --watermark '{raw}' (expect an iteration count or 'auto')")
            })?;
            if w > 0 {
                hier = hier.with_watermark(w);
            }
        }
    }
    if let Some(raw) = flags.get("prefetch-depth") {
        let q: u32 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --prefetch-depth '{raw}' (expect a chunk count)"))?;
        anyhow::ensure!(q >= 1, "--prefetch-depth must be ≥ 1");
        hier = hier.with_prefetch_depth(q);
    }
    Ok(hier)
}

/// Apply `--adaptive` / `--probe-interval G` / `--candidates t0,t1,…` to a
/// parsed [`HierParams`]. The cadence and candidate flags require
/// `--adaptive` (silently configuring a disabled controller would be a
/// trap); candidate parsing rejects AF with a clear error (no closed form
/// to probe).
fn apply_adaptive_flags(
    mut hier: HierParams,
    flags: &HashMap<String, String>,
) -> anyhow::Result<HierParams> {
    let enabled = flags.contains_key("adaptive");
    anyhow::ensure!(
        enabled || !(flags.contains_key("probe-interval") || flags.contains_key("candidates")),
        "--probe-interval/--candidates only apply with --adaptive"
    );
    if !enabled {
        return Ok(hier);
    }
    hier = hier.with_adaptive();
    if let Some(raw) = flags.get("probe-interval") {
        let g: u32 = raw.parse().map_err(|_| {
            anyhow::anyhow!("bad --probe-interval '{raw}' (expect a grant count ≥ 1)")
        })?;
        anyhow::ensure!(g >= 1, "--probe-interval must be ≥ 1");
        hier = hier.with_probe_interval(g);
    }
    if let Some(raw) = flags.get("candidates") {
        hier = hier.with_candidates(dca_dls::techniques::CandidateSet::parse(raw)?);
    }
    Ok(hier)
}

/// Commands whose scenarios are static by definition reject the adaptive
/// flags instead of silently ignoring them.
fn reject_adaptive_flags(flags: &HashMap<String, String>, cmd: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        !["adaptive", "probe-interval", "candidates"].iter().any(|k| flags.contains_key(*k)),
        "--adaptive/--probe-interval/--candidates are not supported by `{cmd}`; \
         use `simulate`, `hier`, `run`, or `select`"
    );
    Ok(())
}

/// Apply `--racks R` / `--rack-latency-us X` to a cluster. A rack count
/// that doesn't evenly divide the nodes is rejected here — `Topology`
/// would silently collapse it to a single rack while the run's header and
/// JSON kept claiming `R` racks.
fn apply_rack_flags(
    mut cluster: ClusterConfig,
    flags: &HashMap<String, String>,
) -> anyhow::Result<ClusterConfig> {
    cluster.racks = get(flags, "racks", cluster.racks);
    anyhow::ensure!(
        cluster.racks >= 1 && cluster.nodes % cluster.racks.max(1) == 0,
        "--racks ({}) must evenly divide the node count ({})",
        cluster.racks,
        cluster.nodes
    );
    if let Some(raw) = flags.get("rack-latency-us") {
        let us: f64 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --rack-latency-us '{raw}' (expect µs)"))?;
        cluster.inter_rack_latency = us * 1e-6;
    }
    Ok(cluster)
}

/// Flags that only make sense for the hierarchical model. (`--racks` /
/// `--rack-latency-us` are *cluster* properties, valid for any DES model —
/// see [`apply_rack_flags`].)
const HIER_ONLY_FLAGS: [&str; 7] = [
    "inner",
    "nodes",
    "watermark",
    "levels",
    "fanout",
    "techniques",
    "prefetch-depth",
];

/// `--lockfree` (or `--sched-path lockfree|two-phase`): grant protocol of
/// the DCA/HIER-DCA chunk exchange — see [`SchedPath`]. Unparsable values
/// error out rather than silently benchmarking the wrong path.
fn sched_path_of(flags: &HashMap<String, String>) -> anyhow::Result<SchedPath> {
    if flags.contains_key("lockfree") {
        return Ok(SchedPath::LockFree);
    }
    match flags.get("sched-path") {
        None => Ok(SchedPath::default()),
        Some(raw) => SchedPath::parse(raw).ok_or_else(|| {
            anyhow::anyhow!("bad --sched-path '{raw}' (expect 'two-phase' or 'lockfree')")
        }),
    }
}

/// Commands whose runs always use the two-phase protocol reject the
/// fast-path flags instead of silently ignoring them.
fn reject_sched_path_flags(flags: &HashMap<String, String>, cmd: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        !(flags.contains_key("lockfree") || flags.contains_key("sched-path")),
        "--lockfree/--sched-path are not supported by `{cmd}` (its scenarios compare \
         the two-phase protocol); use `simulate`, `hier`, or `run`"
    );
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let app = app_of(flags);
    let tech = outer_tech_of(flags)?;
    let model = model_of(flags);
    anyhow::ensure!(
        model == ExecutionModel::HierDca
            || !HIER_ONLY_FLAGS.iter().any(|k| flags.contains_key(*k)),
        "--inner/--watermark/--levels/… only apply to the hierarchical model; \
         pass --model hier as well"
    );
    let ranks = get(flags, "ranks", 256u32);
    let n = get(flags, "n", 262_144u64);
    let delay = get(flags, "delay-us", 0.0f64) * 1e-6;
    let cluster = apply_rack_flags(
        if ranks == 256 { ClusterConfig::minihpc() } else { ClusterConfig::small(ranks) },
        flags,
    )?;
    let cost = app.cost_model(0xF1605, get(flags, "ct", 2_000u32));
    let hier = apply_adaptive_flags(hier_of(flags)?, flags)?;
    let cfg = DesConfig {
        sched_path: sched_path_of(flags)?,
        record_assignments: true,
        params: LoopParams::new(n, cluster.total_ranks()),
        technique: tech,
        model,
        delay: InjectedDelay::calculation_only(delay),
        cluster,
        cost,
        pe_speed: vec![],
        hier,
    };
    let r = simulate(&cfg)?;
    println!(
        "{} {} {} delay={}µs ranks={ranks} N={n}",
        app.name(),
        tech.name(),
        model.label_adaptive(hier.depth() as u32, hier.adaptive.enabled),
        delay * 1e6
    );
    println!(
        "T_par = {:.3}s   chunks = {}   messages = {}   cov(finish) = {:.4}   imbalance = {:.4}",
        r.t_par(),
        r.stats.chunks,
        r.stats.messages,
        r.stats.cov_finish,
        r.stats.imbalance
    );
    print!("{}", dca_dls::report::render_switch_events(&r.switch_events));
    Ok(())
}

/// `hier`: one scenario, all four models side by side — the hierarchical
/// model's headline comparison (arXiv 1903.09510 reproduced on the DES,
/// generalized to any tree depth via `--levels`).
fn cmd_hier(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let app = app_of(flags);
    let tech = outer_tech_of(flags)?;
    // Adaptivity applies to the hierarchical row only here — the flat rows
    // are the static baselines the adaptive run is compared against (use
    // `simulate --model dca --adaptive` for flat adaptivity).
    let hier = apply_adaptive_flags(hier_of(flags)?, flags)?;
    let label = |m: ExecutionModel| {
        m.label_adaptive(
            hier.depth() as u32,
            hier.adaptive.enabled && m == ExecutionModel::HierDca,
        )
    };
    let levels = hier.depth() as u32;
    let nodes = get(flags, "nodes", 16u32);
    let rpn = get(flags, "rpn", 16u32);
    let n = get(flags, "n", 262_144u64);
    let delay = get(flags, "delay-us", 0.0f64) * 1e-6;
    let site = match flags.get("delay-site").map(String::as_str) {
        Some("assignment") => DelaySite::Assignment,
        _ => DelaySite::Calculation,
    };
    let cluster = apply_rack_flags(
        ClusterConfig { nodes, ranks_per_node: rpn, ..ClusterConfig::minihpc() },
        flags,
    )?;
    let racks = cluster.racks;
    let cost = app.cost_model(0xF1605, get(flags, "ct", 2_000u32));
    let plan = hier.plan(tech, cluster.total_ranks(), &cluster)?;
    let level_names: Vec<String> = plan
        .levels
        .iter()
        .map(|l| format!("{}×{}@{:.1}µs", l.technique.name(), l.fanout, l.latency * 1e6))
        .collect();
    println!(
        "== {} vs flat: {} [{}], {}×{} ranks ({} rack{}), N={n}, {}µs {} delay ==",
        label(ExecutionModel::HierDca),
        app.name(),
        level_names.join(" ▸ "),
        nodes,
        rpn,
        racks,
        if racks == 1 { "" } else { "s" },
        delay * 1e6,
        match site {
            DelaySite::Calculation => "calculation",
            DelaySite::Assignment => "assignment",
        },
    );
    let mut results: Vec<(ExecutionModel, Option<dca_dls::des::DesResult>)> = Vec::new();
    for model in ExecutionModel::ALL {
        if tech == TechniqueKind::Af && model == ExecutionModel::DcaRma {
            results.push((model, None));
            continue;
        }
        let mut model_hier = hier;
        if model != ExecutionModel::HierDca {
            model_hier.adaptive = Default::default();
        }
        let cfg = DesConfig {
            sched_path: sched_path_of(flags)?,
            record_assignments: true,
            params: LoopParams::new(n, cluster.total_ranks()),
            technique: tech,
            model,
            delay: match site {
                DelaySite::Calculation => InjectedDelay::calculation_only(delay),
                DelaySite::Assignment => InjectedDelay::assignment_only(delay),
            },
            cluster: cluster.clone(),
            cost: cost.clone(),
            pe_speed: vec![],
            hier: model_hier,
        };
        results.push((model, Some(simulate(&cfg)?)));
    }
    // The model column fits the longest (possibly depth-annotated) label.
    let mw = results.iter().map(|(m, _)| label(*m).len()).max().unwrap_or(10).max(10);
    println!(
        "{:<mw$} {:>12} {:>9} {:>11} {:>14}",
        "model", "T_par[s]", "chunks", "messages", "rank0 busy[s]"
    );
    for (model, r) in &results {
        match r {
            Some(r) => println!(
                "{:<mw$} {:>12.3} {:>9} {:>11} {:>14.3}",
                label(*model),
                r.t_par(),
                r.stats.chunks,
                r.stats.messages,
                r.rank0_service_busy
            ),
            None => println!("{:<mw$} {:>12}", label(*model), "n/a (AF)"),
        }
    }
    if hier.adaptive.enabled {
        let switches = results
            .iter()
            .find(|(m, _)| *m == ExecutionModel::HierDca)
            .and_then(|(_, r)| r.as_ref())
            .map(|r| r.switch_events.as_slice())
            .unwrap_or_default();
        if switches.is_empty() {
            println!("adaptive switches = 0");
        } else {
            print!("{}", dca_dls::report::render_switch_events(switches));
        }
    }
    if let Some(path) = flags.get("json") {
        let arr = Json::Arr(
            results
                .iter()
                .filter_map(|(m, r)| r.as_ref().map(|r| (m, r)))
                .map(|(m, r)| {
                    Json::obj()
                        .field("model", label(*m))
                        .field("levels", levels)
                        .field(
                            "adaptive",
                            hier.adaptive.enabled && *m == ExecutionModel::HierDca,
                        )
                        .field("technique", tech)
                        .field(
                            "level_techniques",
                            plan.techs()
                                .iter()
                                .map(|t| Json::from(t.name()))
                                .collect::<Vec<_>>(),
                        )
                        .field("nodes", nodes)
                        .field("ranks_per_node", rpn)
                        .field("racks", racks)
                        .field("n", n)
                        .field("delay_us", delay * 1e6)
                        .field(
                            "delay_site",
                            match site {
                                DelaySite::Calculation => "calculation",
                                DelaySite::Assignment => "assignment",
                            },
                        )
                        .field("t_par", r.t_par())
                        .field("chunks", r.stats.chunks)
                        .field("messages", r.stats.messages)
                        .field("messages_intra_node", r.intra_node_messages)
                        .field("messages_inter_node", r.inter_node_messages)
                        .field("messages_per_level", r.level_messages.clone())
                        .field("switches", r.switch_events.len() as u64)
                        .field(
                            "switch_events",
                            dca_dls::report::json::switch_events_json(&r.switch_events),
                        )
                })
                .collect(),
        );
        std::fs::write(path, arr.render())?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let app = app_of(flags);
    let tech = outer_tech_of(flags)?;
    let model = if flags.contains_key("hier") {
        ExecutionModel::HierDca
    } else {
        model_of(flags)
    };
    anyhow::ensure!(
        model == ExecutionModel::HierDca
            || !HIER_ONLY_FLAGS.iter().any(|k| flags.contains_key(*k)),
        "--inner/--nodes/--watermark/--levels/… only apply to the hierarchical engine; \
         pass --hier (or --model hier) as well"
    );
    anyhow::ensure!(
        !(flags.contains_key("racks") || flags.contains_key("rack-latency-us")),
        "--racks/--rack-latency-us are simulated-latency knobs; the threaded engine \
         runs on real fabrics — use `simulate`/`hier` for racked scenarios"
    );
    let workers = get(flags, "workers", 4u32);
    let delay = get(flags, "delay-us", 0.0f64) * 1e-6;
    let pjrt = flags.contains_key("pjrt");
    let workload: Arc<dyn Workload> = match (app, pjrt) {
        (App::Mandelbrot, false) => {
            let mut m = Mandelbrot::paper(get(flags, "ct", 256u32));
            m.width = 128;
            Arc::new(m)
        }
        (App::Mandelbrot, true) => Arc::new(PjrtMandelbrot::new(Runtime::default_dir())?),
        (App::Psia, false) => Arc::new(Psia::synthetic(512, 4096, 7)),
        (App::Psia, true) => Arc::new(PjrtPsia::new(Runtime::default_dir(), 4096, 7)?),
    };
    let n = get(flags, "n", workload.n().min(16_384));
    let mut cfg = EngineConfig::new(LoopParams::new(n, workers), tech, model);
    cfg.sched_path = sched_path_of(flags)?;
    cfg.delay = InjectedDelay::calculation_only(delay);
    if model == ExecutionModel::HierDca {
        cfg.nodes = get(flags, "nodes", if workers % 2 == 0 { 2 } else { 1 });
        cfg.hier = hier_of(flags)?;
        if cfg.hier.watermark == WatermarkMode::Off && !flags.contains_key("watermark") {
            // Default the threaded engine to prefetch at roughly one
            // sub-chunk per local rank; `--watermark 0` reverts to
            // fetch-on-exhaustion, `--watermark auto` adapts.
            cfg.hier = cfg.hier.with_watermark((workers / cfg.nodes.max(1)) as u64);
        }
    }
    cfg.hier = apply_adaptive_flags(cfg.hier, flags)?;
    // Flat engines are depth-1 trees by definition (root ↔ ranks) — keeps
    // the exported `levels` consistent with their one-entry per-level split.
    let levels = if model == ExecutionModel::HierDca { cfg.hier.depth() as u32 } else { 1 };
    let t0 = std::time::Instant::now();
    let r = coordinator::run(&cfg, workload)?;
    println!(
        "{} [{}] {} {} workers={workers} nodes={} N={n}",
        app.name(),
        if pjrt { "PJRT artifacts" } else { "native" },
        tech.name(),
        model.label_adaptive(levels, cfg.hier.adaptive.enabled),
        cfg.nodes
    );
    println!("wall = {:.3}s", t0.elapsed().as_secs_f64());
    print!("{}", dca_dls::report::render_run_summary(&r));
    dca_dls::sched::verify_coverage(&r.sorted_assignments(), n)
        .map_err(|e| anyhow::anyhow!("coverage violation: {e}"))?;
    println!("coverage: OK (every iteration scheduled exactly once)");
    if let Some(path) = flags.get("json") {
        let j = dca_dls::report::json::run_result_json(
            app.name(),
            tech,
            model,
            cfg.nodes,
            levels,
            cfg.hier.adaptive.enabled,
            n,
            &r,
        );
        std::fs::write(path, j.render())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep_breakafter(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    reject_sched_path_flags(flags, "sweep-breakafter")?;
    reject_adaptive_flags(flags, "sweep-breakafter")?;
    let app = app_of(flags);
    let tech = tech_of(flags)?;
    let cost = app.cost_model(0xF1605, 2_000);
    println!("== A3: breakAfter sweep ({}, {}, 64 ranks, N=65536) ==", app.name(), tech.name());
    println!("{:<11} {:>12} {:>12}", "breakAfter", "CCA T_par[s]", "DCA T_par[s]");
    for ba in [0u32, 1, 4, 16, 64, 256] {
        let mut t = vec![];
        for model in [ExecutionModel::Cca, ExecutionModel::Dca] {
            let cluster = ClusterConfig {
                nodes: 4,
                ranks_per_node: 16,
                break_after: ba,
                ..ClusterConfig::minihpc()
            };
            let cfg = DesConfig::new(
                LoopParams::new(65_536, cluster.total_ranks()),
                tech,
                model,
                cluster,
                cost.clone(),
            );
            t.push(simulate(&cfg)?.t_par());
        }
        let label = if ba == 0 { "dedicated".to_string() } else { ba.to_string() };
        println!("{label:<11} {:>12.3} {:>12.3}", t[0], t[1]);
    }
    Ok(())
}

fn cmd_select(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let app = app_of(flags);
    let tech = outer_tech_of(flags)?;
    let hier = apply_adaptive_flags(hier_of(flags)?, flags)?;
    // PR 4 wired --lockfree/--sched-path through `hier`/`run` only; the
    // selector probes each candidate on the requested grant path now, with
    // the same invalid-value error handling.
    let sched_path = sched_path_of(flags)?;
    let levels = hier.depth() as u32;
    let delay = get(flags, "delay-us", 0.0f64) * 1e-6;
    let cluster = apply_rack_flags(ClusterConfig::minihpc(), flags)?;
    let cost = app.cost_model(0xF1605, get(flags, "ct", 2_000u32));
    let s = dca_dls::report::selector::select_model(
        tech,
        262_144,
        &cluster,
        &cost,
        InjectedDelay::calculation_only(delay),
        hier,
        sched_path,
    )?;
    println!(
        "{} {} delay={}µs sched-path={} — predicted T_par on a {:.0}% prefix:",
        app.name(),
        tech.name(),
        delay * 1e6,
        sched_path.name(),
        s.prefix_fraction * 100.0
    );
    let label = |m: ExecutionModel| {
        m.label_adaptive(levels, hier.adaptive.enabled && m == ExecutionModel::HierDca)
    };
    let mw = s.predictions.iter().map(|(m, _)| label(*m).len()).max().unwrap_or(8).max(8);
    for (m, t) in &s.predictions {
        let mark = if *m == s.model { "  ← selected" } else { "" };
        println!("  {:<mw$} {t:.3}s{mark}", label(*m));
    }
    Ok(())
}

/// `tenants`: run a multi-tenant session on the DES substrate — from a
/// JSON spec file or a seeded `--demo` tenant set — and report per-tenant
/// turnaround, granted/dropped iterations and session-level fairness.
fn cmd_tenants(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let ranks = get(flags, "ranks", 64u32);
    let cluster = apply_rack_flags(
        if ranks == 256 { ClusterConfig::minihpc() } else { ClusterConfig::small(ranks) },
        flags,
    )?;
    let mut cfg = match flags.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read session spec '{path}': {e}"))?;
            parse_session_spec(&text, cluster)?
        }
        None => demo_session(cluster, get(flags, "demo", 8u32), get(flags, "seed", 42u64)),
    };
    if let Some(raw) = flags.get("policy") {
        cfg.policy = ArbitrationPolicy::parse(raw)?;
    }
    if flags.contains_key("lockfree") || flags.contains_key("sched-path") {
        cfg.sched_path = sched_path_of(flags)?;
    }
    let (outcome, slowdowns) = if flags.contains_key("slowdown") {
        let (o, s, mean) = session_slowdowns(&cfg)?;
        (o, Some((s, mean)))
    } else {
        (simulate_session(&cfg)?, None)
    };
    println!(
        "session: {} tenants over {} ranks  policy={}  path={:?}",
        outcome.tenants.len(),
        cfg.cluster.total_ranks(),
        cfg.policy,
        cfg.sched_path,
    );
    println!(
        "makespan = {:.4}s   events = {}   messages = {}   Jain fairness = {:.3}",
        outcome.makespan, outcome.events, outcome.messages, outcome.jain_fairness
    );
    if let Some((_, mean)) = &slowdowns {
        println!("mean slowdown vs solo = {mean:.3}");
    }
    println!(
        "{:>3}  {:<12} {:<5} {:>7} {:>6} {:>9} {:>9} {:>9} {:>8} {:>8}  {}",
        "id",
        "name",
        "tech",
        "N",
        "span",
        "arrival",
        "done",
        "turnarnd",
        "granted",
        "dropped",
        "state"
    );
    for t in &outcome.tenants {
        let spec = &cfg.tenants[t.id as usize];
        let span = if spec.span == 0 { cfg.cluster.total_ranks() } else { spec.span };
        println!(
            "{:>3}  {:<12} {:<5} {:>7} {:>6} {:>9.4} {:>9.4} {:>9.4} {:>8} {:>8}  {}",
            t.id,
            t.name,
            spec.technique.name(),
            spec.n,
            span,
            t.arrival,
            t.completion,
            t.turnaround,
            t.granted_iters,
            t.dropped_iters,
            t.state
        );
    }
    if let Some(path) = flags.get("json") {
        let rendered =
            render_session_json(&cfg, &outcome, slowdowns.as_ref().map(|(s, _)| s.as_slice()));
        std::fs::write(path, rendered)?;
        println!("\nwrote {path}");
    }
    Ok(())
}

/// Synthesize a seeded `--demo` tenant set: K loops with mixed closed-form
/// techniques, staggered arrivals, varied weights, and overlapping block
/// placements across the shared cluster.
fn demo_session(cluster: ClusterConfig, k: u32, seed: u64) -> SessionConfig {
    use dca_dls::techniques::rnd::splitmix64;
    const TECHS: [TechniqueKind; 5] = [
        TechniqueKind::Ss,
        TechniqueKind::Gss,
        TechniqueKind::Tss,
        TechniqueKind::Fac2,
        TechniqueKind::Fiss,
    ];
    let ranks = cluster.total_ranks();
    let mut cfg = SessionConfig::new(cluster);
    for i in 0..k.max(1) {
        let h = splitmix64(seed ^ (0xD15C0 + i as u64));
        let n = 500 + h % 1500;
        let tech = TECHS[((h >> 8) % TECHS.len() as u64) as usize];
        let span = (2u32 << ((h >> 16) % 4)).min(ranks);
        let offset = ((h >> 24) % ranks as u64) as u32;
        let weight = 1 + (h >> 32) % 4;
        cfg = cfg.admit(
            TenantSpec::new(format!("demo-{i}"), n, tech)
                .arriving_at(i as f64 * 2e-4)
                .weighted(weight)
                .placed_at(offset, span),
        );
    }
    cfg
}

fn cmd_validate() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    anyhow::ensure!(
        dir.join("meta.json").exists(),
        "artifacts not built — run `make artifacts`"
    );
    let rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());

    // Mandelbrot: exact f64 cross-check over scattered tiles.
    let w = PjrtMandelbrot::new(&dir)?;
    let native = rt.meta.mandelbrot_native();
    let mut checked = 0u64;
    let mut diverged = 0u64;
    for start in [0u64, 51_200, 130_048, 174_080, 200_704, 261_120] {
        for lane in 0..1024u64 {
            let got = w.execute(start + lane);
            if got != native.escape_count(start + lane) as u64 {
                diverged += 1;
            }
            checked += 1;
        }
    }
    anyhow::ensure!(diverged <= 8, "{diverged}/{checked} pixels diverged from native");
    println!(
        "mandelbrot: {}/{checked} pixels bit-exact vs native f64 ({diverged} FMA-contraction boundary pixels) OK",
        checked - diverged
    );

    // PSIA: tolerance on borderline f32 binning.
    let p = PjrtPsia::new(&dir, 256, 0x5e1a_5e1a)?;
    let mut mismatch = 0;
    for i in 0..32u64 {
        if p.execute(i) != p.native().execute(i) {
            mismatch += 1;
        }
    }
    anyhow::ensure!(mismatch <= 3, "{mismatch}/32 spin images diverged");
    println!(
        "spin_image: {}/32 images match native ({mismatch} borderline f32 bins) OK",
        32 - mismatch
    );
    println!("validate: OK");
    Ok(())
}
