//! `dca-dls` — CLI launcher for the DCA/DLS reproduction.
//!
//! Subcommands map one-to-one onto the paper's artifacts (DESIGN.md §4):
//! `table2`, `fig1`, `table3`, `fig4`, `fig5`, plus `simulate` (one factorial
//! cell), `run` (real threaded engine, optionally through the PJRT
//! artifacts), `sweep-breakafter` (A3 ablation) and `validate` (PJRT vs
//! native cross-check).

use std::collections::HashMap;
use std::sync::Arc;

use dca_dls::config::{
    ClusterConfig, DelaySite, ExecutionModel, HierParams, SchedPath, WatermarkMode,
};
use dca_dls::coordinator::{self, EngineConfig};
use dca_dls::des::{
    pdes::{PdesMode, WINDOW_MULT_MAX},
    simulate, DesConfig,
};
use dca_dls::report::figures::{
    fig1_series, run_figure, table2_rows, table3_rows, App, FigureConfig,
};
use dca_dls::report::json::Json;
use dca_dls::report::{render_figure, render_table2, render_table3};
use dca_dls::runtime::workload::{PjrtMandelbrot, PjrtPsia};
use dca_dls::obs::stream::write_ndjson;
use dca_dls::obs::{EngineMetrics, MetricsRegistry};
use dca_dls::scenario::{explain, parse_scenario, run_scenario, Body, RunReport};
use dca_dls::tenant::scheduler::{JobSpec, Scheduler, SchedulerOptions};
use dca_dls::runtime::Runtime;
use dca_dls::substrate::delay::InjectedDelay;
use dca_dls::techniques::{LoopParams, TechniqueKind};
use dca_dls::tenant::spec::{parse_session_spec, render_session_json};
use dca_dls::tenant::{
    session_slowdowns, simulate_session, ArbitrationPolicy, SessionConfig, TenantSpec,
};
use dca_dls::workload::mandelbrot::Mandelbrot;
use dca_dls::workload::psia::Psia;
use dca_dls::workload::{IterationCost, Workload};

const USAGE: &str = "\
dca-dls — Distributed Chunk Calculation for DLS (Eleliemy & Ciorba 2021)

USAGE
  dca-dls <command> [--flag value]...
  dca-dls help <command>        full flags + one worked example per command

PAPER ARTIFACTS
  table2           chunk sequences, N=1000 P=4 (Table 2)
  fig1             chunk-size series per technique (Fig 1)
  table3           loop characteristics (Table 3)
  fig4 / fig5      PSIA / Mandelbrot factorial experiments (Figs 4–5)

DES SUBSTRATE (virtual time)
  simulate         one DES cell: technique × execution model × delay
  hier             N-level HIER-DCA vs the flat models, side by side
  select           SimAS-style execution-model auto-selection (§7)
  tenants          multi-tenant session — many loops, ONE shared cluster

THREADED SUBSTRATE (real threads, wall clock)
  run              flat or hierarchical engine, optionally PJRT-backed
  sweep-breakafter A3 ablation: master breakAfter sweep
  metrics-dump     one instrumented run → Prometheus text on stdout

SCENARIO SUITE (versioned JSON specs — docs/scenario-spec.md)
  scenario list [DIR]          summarize the committed spec files
  scenario validate FILE...    parse-check specs without running them
  scenario explain FILE...     human summary of what a spec runs
  scenario run FILE... [--json] [--jobs N]
                               run the specs and check their expectations
                               exit 0 = pass, 1 = failed check, 2 = spec error

VALIDATION
  validate         PJRT artifacts vs the native implementations

PARALLEL DES CORE (docs/pdes.md)
  --des-threads N              (simulate, hier, tenants)
      shard the event loop across N worker threads (subtree/node-group
      partition, conservative or hybrid-optimistic rounds); 0 = auto
      (available parallelism, clamped to the shard count). Results are
      bit-identical to the sequential engine at every thread count.
      tenants: shards the session over its arbiter domains and fans out
      the --slowdown solo baselines (docs/tenancy.md).
  --des-mode conservative|hybrid   (simulate, hier, tenants, metrics-dump)
      round protocol of the parallel core (default hybrid: a per-shard
      controller opens bounded multi-Δ windows backed by incremental
      checkpoints, with rollback keeping results exact). tenants:
      hybrid deepens the arbiter-epoch windows (needs --des-threads).
  --pin-shards                 (simulate, hier, tenants, metrics-dump)
      best-effort core pinning of the shard workers (sched_setaffinity;
      no-op where unsupported). Never affects results.
  --master-lockfree            (simulate --model hier, hier)
      fused master-tier grants through the staged-chunk MPSC fast path

OBSERVABILITY
  --stream-metrics <path|->    (simulate, hier, tenants, scenario run)
      stream NDJSON interval/switch/tenant records in virtual-time order;
      '-' writes to stdout. --stream-interval S sets the sampling tick in
      virtual seconds (default 0.001). Schema: docs/metrics-schema.md.
";

/// The section `dca-dls help <command>` prints: grouped flags plus one
/// worked example per command. Kept in sync with [`USAGE`]'s command list.
fn help_section(cmd: &str) -> Option<&'static str> {
    Some(match cmd {
        "table2" => {
            "dca-dls table2 — chunk sequences per technique (paper Table 2)\n\
             \n\
             FLAGS\n\
             \x20 --n N        loop size (default 1000)\n\
             \x20 --p P        processing elements (default 4)\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls table2 --n 1000 --p 4\n"
        }
        "fig1" => {
            "dca-dls fig1 — chunk-size series per scheduling step (paper Fig 1)\n\
             \n\
             FLAGS\n\
             \x20 --n N        loop size (default 1000)\n\
             \x20 --p P        processing elements (default 4)\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls fig1 --n 2048 --p 8\n"
        }
        "table3" => {
            "dca-dls table3 — loop characteristics of the two applications (Table 3)\n\
             \n\
             FLAGS\n\
             \x20 --n N        loop size (default 262144)\n\
             \x20 --ct C       Mandelbrot iteration cap (default 2000)\n\
             \x20 --cloud K    PSIA point-cloud size (default 2048)\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls table3 --ct 2000\n"
        }
        "fig4" | "fig5" => {
            "dca-dls fig4|fig5 — factorial experiments (PSIA = Fig 4, Mandelbrot = Fig 5)\n\
             \n\
             SCOPE\n\
             \x20 --quick                  CI-sized factorial instead of the paper grid\n\
             \x20 --reps R                 repetitions per cell\n\
             \x20 --json FILE              also write the rows as JSON\n\
             \n\
             DELAY\n\
             \x20 --delay-site calculation|assignment   where the injected overhead is paid\n\
             \n\
             HIERARCHY (optional extra model)\n\
             \x20 --hier                   add HIER-DCA to the sweep\n\
             \x20 --inner T                deepest-level technique\n\
             \x20 --levels K  --fanout a,b,…   tree shape (outer first)\n\
             \x20 --watermark W|auto  --prefetch-depth Q   prefetch policy\n\
             \x20 --racks R  --rack-latency-us X           racked topology\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls fig4 --quick --reps 3 --hier --inner ss --watermark auto\n"
        }
        "simulate" => {
            "dca-dls simulate — one DES cell (virtual time)\n\
             \n\
             CELL\n\
             \x20 --app psia|mandelbrot    workload cost model (default psia)\n\
             \x20 --tech T                 scheduling technique (default gss)\n\
             \x20 --model cca|dca|dca-rma|hier   execution model (default dca)\n\
             \x20 --n N                    loop size (default 262144)\n\
             \x20 --ranks R                cluster size (default 256 = miniHPC)\n\
             \x20 --delay-us D             injected per-chunk calculation delay\n\
             \x20 --racks R  --rack-latency-us X   racked topology\n\
             \n\
             GRANT PATH\n\
             \x20 --sched-path two-phase|lockfree|auto   (--lockfree = shorthand)\n\
             \n\
             PARALLEL CORE (docs/pdes.md)\n\
             \x20 --des-threads N          sharded PDES event loop (bit-identical;\n\
             \x20                          0 = auto)\n\
             \x20 --des-mode conservative|hybrid   round protocol (default hybrid)\n\
             \x20 --pin-shards             best-effort core pinning of shard workers\n\
             \x20 --master-lockfree        fused master-tier grants (--model hier,\n\
             \x20                          needs a lock-free path, excludes --adaptive)\n\
             \n\
             HIERARCHY (--model hier)\n\
             \x20 --inner T  --levels K  --fanout a,b,…  --techniques t0,t1,…\n\
             \x20 --watermark W|auto  --prefetch-depth Q\n\
             \n\
             ADAPTIVE SELECTION\n\
             \x20 --adaptive  --probe-interval G  --candidates t,…\n\
             \n\
             OBSERVABILITY\n\
             \x20 --stream-metrics <path|->   NDJSON interval/switch records\n\
             \x20 --stream-interval S         sampling tick, virtual s (default 0.001)\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls simulate --tech fac --model hier --inner ss --delay-us 100 \\\n\
             \x20         --stream-metrics - --stream-interval 0.01\n"
        }
        "hier" => {
            "dca-dls hier — N-level HIER-DCA vs the flat models, one scenario\n\
             \n\
             CELL\n\
             \x20 --app psia|mandelbrot    workload cost model (default psia)\n\
             \x20 --tech T                 outer technique (default gss)\n\
             \x20 --n N                    loop size (default 262144)\n\
             \x20 --nodes K  --rpn R       cluster shape (default 16×16)\n\
             \x20 --racks R  --rack-latency-us X   racked topology\n\
             \x20 --delay-us D  --delay-site calculation|assignment\n\
             \n\
             TREE\n\
             \x20 --inner T                deepest-level technique\n\
             \x20 --levels K  --fanout a,b,…    depth + per-level fan-outs (outer first;\n\
             \x20                               a trailing fan-out may be omitted)\n\
             \x20 --techniques t0,t1,…     one technique per level, outer first\n\
             \x20 --watermark W|auto       prefetch watermark (0 = fetch on exhaustion)\n\
             \x20 --prefetch-depth Q       staged-queue capacity\n\
             \n\
             GRANT PATH / ADAPTIVE\n\
             \x20 --sched-path two-phase|lockfree|auto   (--lockfree = shorthand)\n\
             \x20 --adaptive  --probe-interval G  --candidates t,…\n\
             \n\
             PARALLEL CORE (docs/pdes.md)\n\
             \x20 --des-threads N          sharded PDES event loop (bit-identical;\n\
             \x20                          0 = auto)\n\
             \x20 --des-mode conservative|hybrid   round protocol (default hybrid)\n\
             \x20 --pin-shards             best-effort core pinning of shard workers\n\
             \x20 --master-lockfree        fused master-tier grants (needs a\n\
             \x20                          lock-free path, excludes --adaptive)\n\
             \n\
             OUTPUT\n\
             \x20 --json FILE              write all model rows as JSON\n\
             \x20 --stream-metrics <path|->  --stream-interval S\n\
             \x20                          NDJSON stream of the HIER-DCA row\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls hier --levels 3 --fanout 4,4 --techniques fac,gss,fsc \\\n\
             \x20         --racks 4 --rack-latency-us 100 --watermark auto\n"
        }
        "run" => {
            "dca-dls run — the real threaded engine (wall clock)\n\
             \n\
             CELL\n\
             \x20 --app psia|mandelbrot    workload (default psia)\n\
             \x20 --tech T                 technique (default gss)\n\
             \x20 --model cca|dca|dca-rma|hier   execution model (--hier = model hier)\n\
             \x20 --workers P              rank threads (default 4)\n\
             \x20 --n N                    loop size\n\
             \x20 --delay-us D             injected calculation delay\n\
             \x20 --pjrt                   execute through the PJRT artifacts\n\
             \n\
             HIERARCHY (--hier)\n\
             \x20 --nodes K  --levels K  --fanout a,b,…  --techniques t0,t1,…\n\
             \x20 --inner T  --watermark W|auto (0 = fetch on exhaustion)\n\
             \x20 --prefetch-depth Q\n\
             \n\
             GRANT PATH / ADAPTIVE\n\
             \x20 --lockfree | --sched-path two-phase|lockfree|auto\n\
             \x20 --adaptive  --probe-interval G  --candidates t,…\n\
             \n\
             OUTPUT\n\
             \x20 --json FILE              write the run summary as JSON\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls run --hier --levels 3 --fanout 2,2 --workers 16 --lockfree\n"
        }
        "sweep-breakafter" => {
            "dca-dls sweep-breakafter — A3 ablation: master breakAfter sweep\n\
             \n\
             FLAGS\n\
             \x20 --app psia|mandelbrot    workload cost model (default psia)\n\
             \x20 --tech T                 technique (default gss)\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls sweep-breakafter --app mandelbrot --tech fac\n"
        }
        "select" => {
            "dca-dls select — SimAS-style execution-model auto-selection (§7)\n\
             \n\
             Probes every execution model on a loop prefix and selects the\n\
             lowest predicted T_par.\n\
             \n\
             CELL\n\
             \x20 --app psia|mandelbrot  --tech T  --delay-us D\n\
             \x20 --racks R  --rack-latency-us X\n\
             \n\
             TREE / GRANT PATH / ADAPTIVE\n\
             \x20 --inner T  --levels K  --fanout a,b,…  --watermark W|auto\n\
             \x20 --lockfree | --sched-path P\n\
             \x20 --adaptive  --probe-interval G  --candidates t,…\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls select --tech fac --inner ss --delay-us 100 --sched-path auto\n"
        }
        "tenants" => {
            "dca-dls tenants — multi-tenant DES session over ONE shared cluster\n\
             \n\
             Admits many self-scheduled loops to one cluster; every rank\n\
             arbitrates between the per-tenant ledgers it hosts.\n\
             \n\
             TENANT SET\n\
             \x20 --spec FILE     JSON session spec (docs/scenario-spec.md §session)\n\
             \x20 --demo K        synthesize K seeded tenants   --seed S\n\
             \n\
             SESSION\n\
             \x20 --ranks R       shared cluster size (default 64)\n\
             \x20 --policy fair|priority|fifo\n\
             \x20 --lockfree | --sched-path P\n\
             \x20 --slowdown      re-run each tenant solo, report slowdown vs solo\n\
             \x20 --des-threads N shard the session over its arbiter domains and\n\
             \x20                 fan the --slowdown solo baselines out over N\n\
             \x20                 worker threads (0 = auto; bit-identical report,\n\
             \x20                 less wall time — docs/tenancy.md)\n\
             \x20 --des-mode conservative|hybrid   epoch protocol of the sharded\n\
             \x20                 loop (hybrid deepens the arbiter-epoch windows;\n\
             \x20                 needs --des-threads > 1 or 0 = auto)\n\
             \x20 --pin-shards    best-effort core pinning of shard workers\n\
             \x20 --json FILE     write the session report as JSON\n\
             \n\
             OBSERVABILITY\n\
             \x20 --stream-metrics <path|->  --stream-interval S\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls tenants --demo 12 --ranks 64 --policy fair --slowdown\n"
        }
        "scenario" => {
            "dca-dls scenario — versioned scenario specs (docs/scenario-spec.md)\n\
             \n\
             SUBCOMMANDS\n\
             \x20 list [DIR]         summarize every *.json spec (default scenarios/)\n\
             \x20 validate FILE...   parse-check without running\n\
             \x20 explain FILE...    print what each spec would run and check\n\
             \x20 run FILE... [--json] [--jobs N] [--stream-metrics <path|->]\n\
             \x20             [--stream-interval S]\n\
             \n\
             PARALLELISM\n\
             \x20 --jobs N   run the specs on up to N worker threads; reports print\n\
             \x20            in list order and the worst exit code wins (not\n\
             \x20            combinable with --stream-metrics)\n\
             \n\
             EXIT CODES (stable — scriptable)\n\
             \x20 0   every expectation held\n\
             \x20 1   the run finished but an expectation failed\n\
             \x20 2   spec error (bad JSON, unknown field, bad schema) or usage error\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls scenario run scenarios/*.json --jobs 4\n"
        }
        "metrics-dump" => {
            "dca-dls metrics-dump — one-shot Prometheus dump (no network)\n\
             \n\
             Runs a small instrumented threaded engine plus a two-job resident\n\
             scheduler pool against one shared MetricsRegistry, then a small\n\
             sharded DES cell that feeds the dcadls_pdes_* family (docs/pdes.md),\n\
             and prints the Prometheus text exposition to stdout. Every metric it\n\
             emits is documented in docs/metrics-schema.md.\n\
             \n\
             FLAGS\n\
             \x20 --n N          loop size (default 16384)\n\
             \x20 --workers P    pool size (default 4)\n\
             \x20 --tech T       technique (default gss)\n\
             \x20 --lockfree | --sched-path two-phase|lockfree|auto\n\
             \x20 --adaptive  --probe-interval G  --candidates t,…\n\
             \x20                exercise the switch counter too\n\
             \x20 --des-threads N  worker threads of the PDES sampler cell\n\
             \x20                (default 2; 0 = auto; 1 leaves dcadls_pdes_* at zero)\n\
             \x20 --des-mode conservative|hybrid   round protocol (default hybrid)\n\
             \x20 --pin-shards   best-effort core pinning of the sampler's shards\n\
             \x20 --master-lockfree  fuse the sampler's root tier\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls metrics-dump --n 20000 --workers 8 --lockfree\n"
        }
        "validate" => {
            "dca-dls validate — PJRT artifacts vs the native implementations\n\
             \n\
             Cross-checks the compiled PJRT workloads against the native Rust\n\
             implementations (bit-exact Mandelbrot modulo FMA contraction,\n\
             tolerance-bounded PSIA binning). No flags.\n\
             \n\
             EXAMPLE\n\
             \x20 dca-dls validate\n"
        }
        "help" => {
            "dca-dls help [command] — this overview, or one command's section\n"
        }
        _ => return None,
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // `help` and `scenario` take positional operands, which the flag parser
    // rejects by design — dispatch them before it runs.
    match args.first().map(String::as_str) {
        Some("help") => {
            match args.get(1) {
                None => print!("{USAGE}"),
                Some(c) => match help_section(c) {
                    Some(section) => print!("{section}"),
                    None => {
                        eprintln!("no help for unknown command '{c}'\n");
                        eprint!("{USAGE}");
                        std::process::exit(2);
                    }
                },
            }
            return;
        }
        Some("scenario") => cmd_scenario(&args[1..]),
        _ => {}
    }
    let Some((cmd, flags)) = parse(&args) else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let r = match cmd.as_str() {
        "table2" => cmd_table2(&flags),
        "fig1" => cmd_fig1(&flags),
        "table3" => cmd_table3(&flags),
        "fig4" => cmd_figure(App::Psia, "Figure 4 (PSIA)", &flags),
        "fig5" => cmd_figure(App::Mandelbrot, "Figure 5 (Mandelbrot)", &flags),
        "simulate" => cmd_simulate(&flags),
        "hier" => cmd_hier(&flags),
        "run" => cmd_run(&flags),
        "sweep-breakafter" => cmd_sweep_breakafter(&flags),
        "select" => cmd_select(&flags),
        "tenants" => cmd_tenants(&flags),
        "metrics-dump" => cmd_metrics_dump(&flags),
        "validate" => cmd_validate(),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `cmd --k v --flag` → (cmd, {k: v, flag: ""}).
fn parse(args: &[String]) -> Option<(String, HashMap<String, String>)> {
    let cmd = args.first()?.clone();
    let mut flags = HashMap::new();
    let mut i = 1;
    while i < args.len() {
        let a = args[i].strip_prefix("--")?;
        if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            flags.insert(a.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            flags.insert(a.to_string(), String::new());
            i += 1;
        }
    }
    Some((cmd, flags))
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn params_from(flags: &HashMap<String, String>) -> LoopParams {
    LoopParams::new(get(flags, "n", 1000u64), get(flags, "p", 4u32))
}

fn cmd_table2(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let params = params_from(flags);
    print!("{}", render_table2(&table2_rows(&params)));
    Ok(())
}

fn cmd_fig1(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let params = params_from(flags);
    println!("== Fig 1: chunk sizes per scheduling step (N={}, P={}) ==", params.n, params.p);
    for (kind, sizes) in fig1_series(&params) {
        println!("{:<8} pattern={:?}", kind.name(), kind.pattern());
        let pts: Vec<String> =
            sizes.iter().enumerate().map(|(i, s)| format!("({i},{s})")).collect();
        println!("  {}", pts.join(" "));
    }
    Ok(())
}

fn cmd_table3(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let n = get(flags, "n", 262_144u64);
    let ct = get(flags, "ct", 2_000u32);
    let cloud = get(flags, "cloud", 2_048usize);
    println!("(Mandelbrot CT scaled to {ct}; paper used 1,000,000 — shape is CT-invariant)");
    print!("{}", render_table3(&table3_rows(n, ct, cloud)));
    Ok(())
}

fn cmd_figure(app: App, title: &str, flags: &HashMap<String, String>) -> anyhow::Result<()> {
    reject_sched_path_flags(flags, title)?;
    reject_adaptive_flags(flags, title)?;
    reject_pdes_flags(flags, title)?;
    let mut cfg = if flags.contains_key("quick") {
        FigureConfig::quick(app)
    } else {
        FigureConfig::paper(app)
    };
    cfg.reps = get(flags, "reps", cfg.reps);
    if let Some(site) = flags.get("delay-site") {
        cfg.delay_site = match site.as_str() {
            "assignment" => DelaySite::Assignment,
            _ => DelaySite::Calculation,
        };
    }
    anyhow::ensure!(
        !flags.contains_key("techniques"),
        "--techniques does not apply to figures (they sweep the outer techniques); \
         use --inner (and --levels/--fanout) for the hierarchy's lower levels"
    );
    cfg.cluster = apply_rack_flags(cfg.cluster, flags)?;
    if flags.contains_key("hier") {
        cfg.models.push(ExecutionModel::HierDca);
        cfg.hier = hier_of(flags)?;
    } else if HIER_ONLY_FLAGS.iter().any(|k| flags.contains_key(*k)) {
        anyhow::bail!(
            "--inner/--watermark/--levels/… only apply to the hierarchical model; \
             pass --hier as well"
        );
    }
    let rows = run_figure(&cfg)?;
    print!("{}", render_figure(title, &rows, cfg.hier.depth() as u32));
    if let Some(path) = flags.get("json") {
        let arr = Json::Arr(
            rows.iter()
                .map(|r| {
                    Json::obj()
                        .field("technique", r.technique.name())
                        .field("model", r.model.name())
                        .field("delay_us", r.delay * 1e6)
                        .field("t_par_mean", r.runs.t_par_mean)
                        .field("t_par_stddev", r.runs.t_par_stddev)
                        .field("chunks", r.chunks)
                })
                .collect(),
        );
        std::fs::write(path, arr.render())?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn app_of(flags: &HashMap<String, String>) -> App {
    match flags.get("app").map(String::as_str) {
        Some("mandelbrot") => App::Mandelbrot,
        _ => App::Psia,
    }
}

fn parse_tech(name: &str) -> anyhow::Result<TechniqueKind> {
    TechniqueKind::parse(name).ok_or_else(|| anyhow::anyhow!("unknown technique '{name}'"))
}

fn tech_of(flags: &HashMap<String, String>) -> anyhow::Result<TechniqueKind> {
    parse_tech(flags.get("tech").map(String::as_str).unwrap_or("GSS"))
}

/// The experiment's (outer, level-0) technique: `--techniques`' first entry
/// wins over `--tech`.
fn outer_tech_of(flags: &HashMap<String, String>) -> anyhow::Result<TechniqueKind> {
    match flags.get("techniques") {
        Some(raw) => parse_tech(raw.split(',').next().unwrap_or("").trim()),
        None => tech_of(flags),
    }
}

fn model_of(flags: &HashMap<String, String>) -> ExecutionModel {
    flags
        .get("model")
        .and_then(|m| ExecutionModel::parse(m))
        .unwrap_or(ExecutionModel::Dca)
}

/// Hierarchical-tree flags: `--inner T` (deepest-level technique, default:
/// same as outer), `--levels K` (tree depth, default 2), `--fanout a,b,…`
/// (children per level, outer first; a trailing entry may be omitted),
/// `--techniques t0,t1,…` (one technique per level, outer first — t0 also
/// overrides `--tech`, see [`outer_tech_of`]), `--watermark W|auto`
/// (prefetch: fixed iteration count, 0 = fetch on exhaustion, or the
/// EWMA-adaptive policy), `--prefetch-depth Q` (staged-queue capacity).
fn hier_of(flags: &HashMap<String, String>) -> anyhow::Result<HierParams> {
    let mut hier = match flags.get("inner") {
        None => HierParams::default(),
        Some(name) => HierParams::with_inner(
            TechniqueKind::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown inner technique '{name}'"))?,
        ),
    };
    if let Some(raw) = flags.get("levels") {
        let k: u32 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --levels '{raw}' (expect a tree depth)"))?;
        anyhow::ensure!(
            (1..=dca_dls::config::MAX_LEVELS as u32).contains(&k),
            "--levels must be in 1..={} (got {k})",
            dca_dls::config::MAX_LEVELS
        );
        hier = hier.with_levels(k);
    }
    if let Some(raw) = flags.get("fanout") {
        let fanouts: Vec<u32> = raw
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u32>()
                    .map_err(|_| anyhow::anyhow!("bad --fanout '{raw}' (expect a,b,…)"))
            })
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            !fanouts.is_empty() && fanouts.len() <= hier.depth(),
            "--fanout takes at most --levels ({}) entries, got {}",
            hier.depth(),
            fanouts.len()
        );
        hier = hier.with_fanouts(&fanouts);
    }
    if let Some(raw) = flags.get("techniques") {
        let kinds: Vec<TechniqueKind> = raw
            .split(',')
            .map(|s| parse_tech(s.trim()))
            .collect::<anyhow::Result<_>>()?;
        let k = hier.depth();
        anyhow::ensure!(
            kinds.len() == k,
            "--techniques needs one entry per level ({k}), got {}",
            kinds.len()
        );
        // kinds[0] is the outer technique (consumed by `outer_tech_of`).
        for (d, kind) in kinds.iter().enumerate().skip(1) {
            if d == k - 1 {
                hier.inner = Some(*kind);
            } else {
                hier = hier.with_mid(d, *kind);
            }
        }
    }
    if let Some(raw) = flags.get("watermark") {
        if raw == "auto" {
            hier = hier.with_auto_watermark();
        } else {
            let w: u64 = raw.parse().map_err(|_| {
                anyhow::anyhow!("bad --watermark '{raw}' (expect an iteration count or 'auto')")
            })?;
            if w > 0 {
                hier = hier.with_watermark(w);
            }
        }
    }
    if let Some(raw) = flags.get("prefetch-depth") {
        let q: u32 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --prefetch-depth '{raw}' (expect a chunk count)"))?;
        anyhow::ensure!(q >= 1, "--prefetch-depth must be ≥ 1");
        hier = hier.with_prefetch_depth(q);
    }
    Ok(hier)
}

/// Apply `--adaptive` / `--probe-interval G` / `--candidates t0,t1,…` to a
/// parsed [`HierParams`]. The cadence and candidate flags require
/// `--adaptive` (silently configuring a disabled controller would be a
/// trap); candidate parsing rejects AF with a clear error (no closed form
/// to probe).
fn apply_adaptive_flags(
    mut hier: HierParams,
    flags: &HashMap<String, String>,
) -> anyhow::Result<HierParams> {
    let enabled = flags.contains_key("adaptive");
    anyhow::ensure!(
        enabled || !(flags.contains_key("probe-interval") || flags.contains_key("candidates")),
        "--probe-interval/--candidates only apply with --adaptive"
    );
    if !enabled {
        return Ok(hier);
    }
    hier = hier.with_adaptive();
    if let Some(raw) = flags.get("probe-interval") {
        let g: u32 = raw.parse().map_err(|_| {
            anyhow::anyhow!("bad --probe-interval '{raw}' (expect a grant count ≥ 1)")
        })?;
        anyhow::ensure!(g >= 1, "--probe-interval must be ≥ 1");
        hier = hier.with_probe_interval(g);
    }
    if let Some(raw) = flags.get("candidates") {
        hier = hier.with_candidates(dca_dls::techniques::CandidateSet::parse(raw)?);
    }
    Ok(hier)
}

/// Commands whose scenarios are static by definition reject the adaptive
/// flags instead of silently ignoring them.
fn reject_adaptive_flags(flags: &HashMap<String, String>, cmd: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        !["adaptive", "probe-interval", "candidates"].iter().any(|k| flags.contains_key(*k)),
        "--adaptive/--probe-interval/--candidates are not supported by `{cmd}`; \
         use `simulate`, `hier`, `run`, or `select`"
    );
    Ok(())
}

/// Apply `--racks R` / `--rack-latency-us X` to a cluster. A rack count
/// that doesn't evenly divide the nodes is rejected here — `Topology`
/// would silently collapse it to a single rack while the run's header and
/// JSON kept claiming `R` racks.
fn apply_rack_flags(
    mut cluster: ClusterConfig,
    flags: &HashMap<String, String>,
) -> anyhow::Result<ClusterConfig> {
    cluster.racks = get(flags, "racks", cluster.racks);
    anyhow::ensure!(
        cluster.racks >= 1 && cluster.nodes % cluster.racks.max(1) == 0,
        "--racks ({}) must evenly divide the node count ({})",
        cluster.racks,
        cluster.nodes
    );
    if let Some(raw) = flags.get("rack-latency-us") {
        let us: f64 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --rack-latency-us '{raw}' (expect µs)"))?;
        cluster.inter_rack_latency = us * 1e-6;
    }
    Ok(cluster)
}

/// Flags that only make sense for the hierarchical model. (`--racks` /
/// `--rack-latency-us` are *cluster* properties, valid for any DES model —
/// see [`apply_rack_flags`].)
const HIER_ONLY_FLAGS: [&str; 8] = [
    "inner",
    "nodes",
    "watermark",
    "levels",
    "fanout",
    "techniques",
    "prefetch-depth",
    "master-lockfree",
];

/// `--des-threads N`: worker threads for the sharded parallel DES core
/// (PDES) — see docs/pdes.md. 1 (the default) keeps the classic sequential
/// event loop; 0 means **auto** — clamp to the machine's available
/// parallelism and, inside the executor, to the shard count. Results are
/// bit-identical for every value.
fn des_threads_of(flags: &HashMap<String, String>) -> anyhow::Result<u32> {
    match flags.get("des-threads") {
        None => Ok(1),
        Some(raw) => raw.parse().map_err(|_| {
            anyhow::anyhow!(
                "bad --des-threads '{raw}' (expect a thread count, or 0 = auto)"
            )
        }),
    }
}

/// `--des-mode conservative|hybrid`: round protocol of the parallel DES
/// core. `hybrid` (the default) lets a per-shard controller open bounded
/// optimistic windows past the conservative horizon; both modes are
/// bit-identical to the sequential loop — see docs/pdes.md.
fn des_mode_of(flags: &HashMap<String, String>) -> anyhow::Result<PdesMode> {
    match flags.get("des-mode") {
        None => Ok(PdesMode::default()),
        Some(raw) => PdesMode::parse(raw).ok_or_else(|| {
            anyhow::anyhow!("bad --des-mode '{raw}' (expect conservative|hybrid)")
        }),
    }
}

/// Commands that never run the sharded DES core reject its flags instead
/// of silently ignoring them.
fn reject_pdes_flags(flags: &HashMap<String, String>, cmd: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        !(flags.contains_key("des-threads")
            || flags.contains_key("des-mode")
            || flags.contains_key("pin-shards")
            || flags.contains_key("master-lockfree")),
        "--des-threads/--des-mode/--pin-shards/--master-lockfree are not supported by \
         `{cmd}`; use `simulate`, `hier`, `metrics-dump`, or `tenants`"
    );
    Ok(())
}

/// `--lockfree` (or `--sched-path lockfree|two-phase`): grant protocol of
/// the DCA/HIER-DCA chunk exchange — see [`SchedPath`]. Unparsable values
/// error out rather than silently benchmarking the wrong path.
fn sched_path_of(flags: &HashMap<String, String>) -> anyhow::Result<SchedPath> {
    if flags.contains_key("lockfree") {
        return Ok(SchedPath::LockFree);
    }
    match flags.get("sched-path") {
        None => Ok(SchedPath::default()),
        Some(raw) => SchedPath::parse(raw).ok_or_else(|| {
            anyhow::anyhow!("bad --sched-path '{raw}' (expect 'two-phase' or 'lockfree')")
        }),
    }
}

/// Commands whose runs always use the two-phase protocol reject the
/// fast-path flags instead of silently ignoring them.
fn reject_sched_path_flags(flags: &HashMap<String, String>, cmd: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        !(flags.contains_key("lockfree") || flags.contains_key("sched-path")),
        "--lockfree/--sched-path are not supported by `{cmd}` (its scenarios compare \
         the two-phase protocol); use `simulate`, `hier`, or `run`"
    );
    Ok(())
}

/// Sampling tick used when `--stream-metrics` is given without an explicit
/// `--stream-interval` (virtual seconds).
const DEFAULT_STREAM_INTERVAL: f64 = 1e-3;

/// `--stream-metrics <path|->` + `--stream-interval S`: NDJSON streaming of
/// the DES observability records — `Some((dest, interval_s))` when on.
fn stream_flags(flags: &HashMap<String, String>) -> anyhow::Result<Option<(String, f64)>> {
    let Some(dest) = flags.get("stream-metrics") else {
        anyhow::ensure!(
            !flags.contains_key("stream-interval"),
            "--stream-interval only applies with --stream-metrics"
        );
        return Ok(None);
    };
    anyhow::ensure!(!dest.is_empty(), "--stream-metrics needs a path (or '-' for stdout)");
    let s = get(flags, "stream-interval", DEFAULT_STREAM_INTERVAL);
    anyhow::ensure!(s > 0.0, "--stream-interval must be > 0 (virtual seconds)");
    Ok(Some((dest.clone(), s)))
}

/// Write a run's stream records and (for file destinations) say where.
fn write_stream(dest: &str, records: &[Json]) -> anyhow::Result<()> {
    write_ndjson(dest, records)?;
    if dest != "-" {
        println!("streamed {} records to {dest}", records.len());
    }
    Ok(())
}

/// `scenario list|validate|explain|run` with the stable exit codes the
/// suite documents: 0 = ok, 1 = scenario failure, 2 = spec/usage error.
fn cmd_scenario(args: &[String]) -> ! {
    let code = match scenario_dispatch(args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn scenario_dispatch(args: &[String]) -> anyhow::Result<i32> {
    let rest = args.get(1..).unwrap_or_default();
    match args.first().map(String::as_str) {
        Some("list") => scenario_list(rest),
        Some("validate") => scenario_validate(rest),
        Some("explain") => scenario_explain(rest),
        Some("run") => scenario_run(rest),
        _ => anyhow::bail!(
            "usage: dca-dls scenario <list|validate|explain|run> … \
             (see `dca-dls help scenario`)"
        ),
    }
}

fn load_scenario(path: &str) -> anyhow::Result<dca_dls::scenario::Scenario> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read scenario '{path}': {e}"))?;
    parse_scenario(&text).map_err(|e| anyhow::anyhow!("{path}: {e:#}"))
}

fn scenario_list(args: &[String]) -> anyhow::Result<i32> {
    let dir = args.first().map(String::as_str).unwrap_or("scenarios");
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("cannot read scenario directory '{dir}': {e}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    paths.sort();
    if paths.is_empty() {
        println!("no scenario files (*.json) in '{dir}'");
        return Ok(0);
    }
    let mut bad = false;
    for path in &paths {
        match load_scenario(&path.display().to_string()) {
            Ok(sc) => {
                let kind = match &sc.body {
                    Body::Des(_) => "des",
                    Body::Session { .. } => "session",
                };
                println!("{:<28} {:<8} {}", sc.name, kind, sc.description);
            }
            Err(e) => {
                bad = true;
                eprintln!("spec error: {e:#}");
            }
        }
    }
    Ok(if bad { 2 } else { 0 })
}

fn scenario_validate(paths: &[String]) -> anyhow::Result<i32> {
    anyhow::ensure!(!paths.is_empty(), "usage: dca-dls scenario validate <spec.json>…");
    let mut bad = false;
    for path in paths {
        match load_scenario(path) {
            Ok(sc) => println!("{path}: ok ({})", sc.name),
            Err(e) => {
                bad = true;
                eprintln!("spec error: {e:#}");
            }
        }
    }
    Ok(if bad { 2 } else { 0 })
}

fn scenario_explain(paths: &[String]) -> anyhow::Result<i32> {
    anyhow::ensure!(!paths.is_empty(), "usage: dca-dls scenario explain <spec.json>…");
    for path in paths {
        print!("{}", explain(&load_scenario(path)?));
    }
    Ok(0)
}

/// `scenario run <spec.json>… [--json] [--jobs N] [--stream-metrics
/// <path|->] [--stream-interval S]` — any failed expectation makes the
/// whole invocation exit 1; parse or simulation errors exit 2. With
/// `--jobs N` the specs execute on up to N worker threads; reports still
/// print in list order and the exit code is the worst across all specs.
fn scenario_run(args: &[String]) -> anyhow::Result<i32> {
    let mut paths = Vec::new();
    let mut json = false;
    let mut jobs = 1usize;
    let mut stream_dest: Option<String> = None;
    let mut interval = 0.0f64;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => json = true,
            "--jobs" => {
                let raw =
                    args.get(i + 1).ok_or_else(|| anyhow::anyhow!("--jobs needs a count"))?;
                jobs = raw
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad --jobs '{raw}' (expect a count ≥ 1)"))?;
                anyhow::ensure!(jobs >= 1, "--jobs must be ≥ 1");
                i += 1;
            }
            "--stream-metrics" => {
                let dest = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow::anyhow!("--stream-metrics needs a path (or '-')"))?;
                stream_dest = Some(dest.clone());
                i += 1;
            }
            "--stream-interval" => {
                let raw = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow::anyhow!("--stream-interval needs a value"))?;
                interval = raw
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad --stream-interval '{raw}' (expect s)"))?;
                anyhow::ensure!(interval > 0.0, "--stream-interval must be > 0");
                i += 1;
            }
            flag if flag.starts_with("--") => {
                anyhow::bail!("unknown flag '{flag}' for `scenario run`")
            }
            path => paths.push(path.to_string()),
        }
        i += 1;
    }
    anyhow::ensure!(
        !paths.is_empty(),
        "usage: dca-dls scenario run <spec.json>… [--json] \
         [--stream-metrics <path|->] [--stream-interval S]"
    );
    anyhow::ensure!(
        stream_dest.is_some() || interval == 0.0,
        "--stream-interval only applies with --stream-metrics"
    );
    anyhow::ensure!(
        stream_dest.is_none() || paths.len() == 1,
        "--stream-metrics takes exactly one scenario per invocation"
    );
    if stream_dest.is_some() && interval == 0.0 {
        interval = DEFAULT_STREAM_INTERVAL;
    }
    anyhow::ensure!(
        jobs == 1 || stream_dest.is_none(),
        "--stream-metrics needs one run's virtual-time order; drop --jobs"
    );
    let mut failed = false;
    for (path, report) in run_scenario_set(&paths, interval, jobs.min(paths.len()))? {
        // A spec that parsed but whose run errors out is a *scenario*
        // failure (exit 1), not a spec error.
        let report = match report {
            Ok(report) => report,
            Err(e) => {
                eprintln!("{path}: run failed: {e:#}");
                failed = true;
                continue;
            }
        };
        if let Some(dest) = &stream_dest {
            write_ndjson(dest, &report.stream)?;
        }
        if json {
            println!("{}", scenario_report_json(&report).render());
        } else {
            for c in &report.checks {
                println!("  [{}] {}: {}", if c.ok { "PASS" } else { "FAIL" }, c.label, c.detail);
            }
            println!("{}: {}", report.name, if report.passed { "PASS" } else { "FAIL" });
        }
        failed |= !report.passed;
    }
    Ok(if failed { 1 } else { 0 })
}

/// Run every spec, sequentially (`jobs == 1`, specs load lazily exactly as
/// before) or on a small worker pool. Either way the returned reports are
/// in list order, so the printed output is independent of the thread
/// count; spec *parse* errors abort the whole invocation (exit 2) while
/// run errors stay per-scenario.
fn run_scenario_set(
    paths: &[String],
    interval: f64,
    jobs: usize,
) -> anyhow::Result<Vec<(String, anyhow::Result<RunReport>)>> {
    if jobs <= 1 {
        let mut out = Vec::with_capacity(paths.len());
        for path in paths {
            let sc = load_scenario(path)?;
            out.push((path.clone(), run_scenario(&sc, interval)));
        }
        return Ok(out);
    }
    let scs: Vec<_> = paths.iter().map(|p| load_scenario(p)).collect::<anyhow::Result<_>>()?;
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<anyhow::Result<RunReport>>> = Vec::new();
    slots.resize_with(scs.len(), || None);
    let slots = std::sync::Mutex::new(slots);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= scs.len() {
                    break;
                }
                let r = run_scenario(&scs[i], interval);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    let reports = slots.into_inner().unwrap();
    Ok(paths
        .iter()
        .cloned()
        .zip(reports.into_iter().map(|r| r.expect("every scenario ran")))
        .collect())
}

/// The `scenario run --json` report document (one JSON object per line for
/// multi-spec invocations) — see docs/scenario-spec.md.
fn scenario_report_json(r: &RunReport) -> Json {
    Json::obj()
        .field("schema", "dca-dls/scenario-report/v1")
        .field("name", r.name.as_str())
        .field("passed", r.passed)
        .field(
            "checks",
            Json::Arr(
                r.checks
                    .iter()
                    .map(|c| {
                        Json::obj()
                            .field("label", c.label.as_str())
                            .field("ok", c.ok)
                            .field("detail", c.detail.as_str())
                    })
                    .collect(),
            ),
        )
        .field("observed", r.observed.clone())
}

/// `metrics-dump`: drive one small instrumented threaded engine plus a
/// two-job resident scheduler pool against a shared registry, then print
/// the Prometheus text exposition — a one-shot, network-free stand-in for
/// a `/metrics` endpoint. A small sharded DES cell runs last so the
/// `dcadls_pdes_*` family is fed by a real PDES execution.
fn cmd_metrics_dump(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let workers = get(flags, "workers", 4u32);
    let tech = outer_tech_of(flags)?;
    let registry = Arc::new(MetricsRegistry::new());
    let workload: Arc<dyn Workload> = Arc::new(Psia::synthetic(512, 4096, 7));
    let n = get(flags, "n", 16_384u64).min(workload.n());
    let mut cfg = EngineConfig::new(LoopParams::new(n, workers), tech, ExecutionModel::Dca)
        .with_metrics(Arc::clone(&registry));
    cfg.sched_path = sched_path_of(flags)?;
    cfg.hier = apply_adaptive_flags(cfg.hier, flags)?;
    coordinator::run(&cfg, Arc::clone(&workload))?;
    // A tiny resident pool exercises the tenant metrics in the same dump.
    let pool = Scheduler::new_instrumented(
        SchedulerOptions { workers, ..SchedulerOptions::default() },
        Some(Arc::clone(&registry)),
    );
    pool.submit(JobSpec::new("dump-a", (n / 4).max(1), tech, Arc::clone(&workload)))?;
    pool.submit(JobSpec::new("dump-b", (n / 8).max(1), TechniqueKind::Ss, workload))?;
    pool.drain();
    // The PDES sampler cell: FAC2 over four node masters, SS inside each
    // node, sharded two ways by default (`--des-threads` overrides,
    // `--master-lockfree` fuses the root tier). `--des-threads 1` keeps
    // the sequential loop and leaves the dcadls_pdes_* family at zero.
    let des_threads = match flags.get("des-threads") {
        Some(_) => des_threads_of(flags)?,
        None => 2,
    };
    let cl = ClusterConfig { nodes: 4, ranks_per_node: 4, ..ClusterConfig::minihpc() };
    let mut des_hier = HierParams::with_inner(TechniqueKind::Ss);
    if flags.contains_key("master-lockfree") {
        des_hier = des_hier.with_master_lockfree();
    }
    let mut des_cfg = DesConfig::new(
        LoopParams::new(4_096, cl.total_ranks()),
        TechniqueKind::Fac2,
        ExecutionModel::HierDca,
        cl,
        IterationCost::Constant(1e-5),
    )
    .with_threads(des_threads)
    .with_pdes_mode(des_mode_of(flags)?)
    .with_pin_shards(flags.contains_key("pin-shards"));
    des_cfg.hier = des_hier;
    des_cfg.sched_path = sched_path_of(flags)?;
    let r = simulate(&des_cfg)?;
    if let Some(p) = &r.pdes {
        EngineMetrics::register(&registry).on_pdes(p);
    }
    print!("{}", registry.render_prometheus());
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let app = app_of(flags);
    let tech = outer_tech_of(flags)?;
    let model = model_of(flags);
    anyhow::ensure!(
        model == ExecutionModel::HierDca
            || !HIER_ONLY_FLAGS.iter().any(|k| flags.contains_key(*k)),
        "--inner/--watermark/--levels/… only apply to the hierarchical model; \
         pass --model hier as well"
    );
    let ranks = get(flags, "ranks", 256u32);
    let n = get(flags, "n", 262_144u64);
    let delay = get(flags, "delay-us", 0.0f64) * 1e-6;
    let cluster = apply_rack_flags(
        if ranks == 256 { ClusterConfig::minihpc() } else { ClusterConfig::small(ranks) },
        flags,
    )?;
    let cost = app.cost_model(0xF1605, get(flags, "ct", 2_000u32));
    let mut hier = apply_adaptive_flags(hier_of(flags)?, flags)?;
    if flags.contains_key("master-lockfree") {
        hier = hier.with_master_lockfree();
    }
    let stream = stream_flags(flags)?;
    let cfg = DesConfig {
        sched_path: sched_path_of(flags)?,
        record_assignments: true,
        stream_interval: stream.as_ref().map_or(0.0, |(_, s)| *s),
        des_threads: des_threads_of(flags)?,
        pdes_mode: des_mode_of(flags)?,
        pin_shards: flags.contains_key("pin-shards"),
        window_mult_max: WINDOW_MULT_MAX,
        params: LoopParams::new(n, cluster.total_ranks()),
        technique: tech,
        model,
        delay: InjectedDelay::calculation_only(delay),
        cluster,
        cost,
        pe_speed: vec![],
        hier,
    };
    let r = simulate(&cfg)?;
    if let Some((dest, _)) = &stream {
        write_stream(dest, &r.stream)?;
    }
    println!(
        "{} {} {} delay={}µs ranks={ranks} N={n}",
        app.name(),
        tech.name(),
        model.label_adaptive(hier.depth() as u32, hier.adaptive.enabled),
        delay * 1e6
    );
    println!(
        "T_par = {:.3}s   chunks = {}   messages = {}   cov(finish) = {:.4}   imbalance = {:.4}",
        r.t_par(),
        r.stats.chunks,
        r.stats.messages,
        r.stats.cov_finish,
        r.stats.imbalance
    );
    if let Some(p) = &r.pdes {
        println!(
            "PDES: {} shards × {} threads, {} mode, {} rounds, lookahead {}ns, \
             window {}ns, {} rollbacks, {} speculated events, \
             {} horizon stalls, mailbox depth ≤ {}",
            p.shards,
            p.threads,
            p.mode.as_str(),
            p.rounds,
            p.lookahead_ns,
            p.window_ns,
            p.rollbacks,
            p.speculated_events,
            p.horizon_stalls,
            p.mailbox_depth_max
        );
    }
    print!("{}", dca_dls::report::render_switch_events(&r.switch_events));
    Ok(())
}

/// `hier`: one scenario, all four models side by side — the hierarchical
/// model's headline comparison (arXiv 1903.09510 reproduced on the DES,
/// generalized to any tree depth via `--levels`).
fn cmd_hier(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let app = app_of(flags);
    let tech = outer_tech_of(flags)?;
    // Adaptivity applies to the hierarchical row only here — the flat rows
    // are the static baselines the adaptive run is compared against (use
    // `simulate --model dca --adaptive` for flat adaptivity).
    let mut hier = apply_adaptive_flags(hier_of(flags)?, flags)?;
    if flags.contains_key("master-lockfree") {
        hier = hier.with_master_lockfree();
    }
    let des_threads = des_threads_of(flags)?;
    let des_mode = des_mode_of(flags)?;
    let label = |m: ExecutionModel| {
        m.label_adaptive(
            hier.depth() as u32,
            hier.adaptive.enabled && m == ExecutionModel::HierDca,
        )
    };
    let levels = hier.depth() as u32;
    let nodes = get(flags, "nodes", 16u32);
    let rpn = get(flags, "rpn", 16u32);
    let n = get(flags, "n", 262_144u64);
    let delay = get(flags, "delay-us", 0.0f64) * 1e-6;
    let site = match flags.get("delay-site").map(String::as_str) {
        Some("assignment") => DelaySite::Assignment,
        _ => DelaySite::Calculation,
    };
    let cluster = apply_rack_flags(
        ClusterConfig { nodes, ranks_per_node: rpn, ..ClusterConfig::minihpc() },
        flags,
    )?;
    let racks = cluster.racks;
    let cost = app.cost_model(0xF1605, get(flags, "ct", 2_000u32));
    let plan = hier.plan(tech, cluster.total_ranks(), &cluster)?;
    let level_names: Vec<String> = plan
        .levels
        .iter()
        .map(|l| format!("{}×{}@{:.1}µs", l.technique.name(), l.fanout, l.latency * 1e6))
        .collect();
    println!(
        "== {} vs flat: {} [{}], {}×{} ranks ({} rack{}), N={n}, {}µs {} delay ==",
        label(ExecutionModel::HierDca),
        app.name(),
        level_names.join(" ▸ "),
        nodes,
        rpn,
        racks,
        if racks == 1 { "" } else { "s" },
        delay * 1e6,
        match site {
            DelaySite::Calculation => "calculation",
            DelaySite::Assignment => "assignment",
        },
    );
    let stream = stream_flags(flags)?;
    let mut results: Vec<(ExecutionModel, Option<dca_dls::des::DesResult>)> = Vec::new();
    for model in ExecutionModel::ALL {
        if tech == TechniqueKind::Af && model == ExecutionModel::DcaRma {
            results.push((model, None));
            continue;
        }
        let mut model_hier = hier;
        if model != ExecutionModel::HierDca {
            model_hier.adaptive = Default::default();
        }
        // The stream follows the headline HIER-DCA row only — one file,
        // one run's virtual-time order.
        let stream_interval = match (&stream, model) {
            (Some((_, s)), ExecutionModel::HierDca) => *s,
            _ => 0.0,
        };
        let cfg = DesConfig {
            sched_path: sched_path_of(flags)?,
            record_assignments: true,
            stream_interval,
            des_threads,
            pdes_mode: des_mode,
            pin_shards: flags.contains_key("pin-shards"),
            window_mult_max: WINDOW_MULT_MAX,
            params: LoopParams::new(n, cluster.total_ranks()),
            technique: tech,
            model,
            delay: match site {
                DelaySite::Calculation => InjectedDelay::calculation_only(delay),
                DelaySite::Assignment => InjectedDelay::assignment_only(delay),
            },
            cluster: cluster.clone(),
            cost: cost.clone(),
            pe_speed: vec![],
            hier: model_hier,
        };
        results.push((model, Some(simulate(&cfg)?)));
    }
    if let Some((dest, _)) = &stream {
        let r = results
            .iter()
            .find(|(m, _)| *m == ExecutionModel::HierDca)
            .and_then(|(_, r)| r.as_ref())
            .expect("the hier command always runs the HIER-DCA model");
        write_stream(dest, &r.stream)?;
    }
    // The model column fits the longest (possibly depth-annotated) label.
    let mw = results.iter().map(|(m, _)| label(*m).len()).max().unwrap_or(10).max(10);
    println!(
        "{:<mw$} {:>12} {:>9} {:>11} {:>14}",
        "model", "T_par[s]", "chunks", "messages", "rank0 busy[s]"
    );
    for (model, r) in &results {
        match r {
            Some(r) => println!(
                "{:<mw$} {:>12.3} {:>9} {:>11} {:>14.3}",
                label(*model),
                r.t_par(),
                r.stats.chunks,
                r.stats.messages,
                r.rank0_service_busy
            ),
            None => println!("{:<mw$} {:>12}", label(*model), "n/a (AF)"),
        }
    }
    for (model, r) in &results {
        if let Some(p) = r.as_ref().and_then(|r| r.pdes.as_ref()) {
            println!(
                "PDES {:<mw$} {} shards × {} threads, {} mode, {} rounds, \
                 lookahead {}ns, window {}ns, {} rollbacks, {} speculated, \
                 {} stalls, mailbox ≤ {}",
                label(*model),
                p.shards,
                p.threads,
                p.mode.as_str(),
                p.rounds,
                p.lookahead_ns,
                p.window_ns,
                p.rollbacks,
                p.speculated_events,
                p.horizon_stalls,
                p.mailbox_depth_max
            );
        }
    }
    if hier.adaptive.enabled {
        let switches = results
            .iter()
            .find(|(m, _)| *m == ExecutionModel::HierDca)
            .and_then(|(_, r)| r.as_ref())
            .map(|r| r.switch_events.as_slice())
            .unwrap_or_default();
        if switches.is_empty() {
            println!("adaptive switches = 0");
        } else {
            print!("{}", dca_dls::report::render_switch_events(switches));
        }
    }
    if let Some(path) = flags.get("json") {
        let arr = Json::Arr(
            results
                .iter()
                .filter_map(|(m, r)| r.as_ref().map(|r| (m, r)))
                .map(|(m, r)| {
                    let mut row = Json::obj()
                        .field("model", label(*m))
                        .field("levels", levels)
                        .field(
                            "adaptive",
                            hier.adaptive.enabled && *m == ExecutionModel::HierDca,
                        )
                        .field("technique", tech)
                        .field(
                            "level_techniques",
                            plan.techs()
                                .iter()
                                .map(|t| Json::from(t.name()))
                                .collect::<Vec<_>>(),
                        )
                        .field("nodes", nodes)
                        .field("ranks_per_node", rpn)
                        .field("racks", racks)
                        .field("n", n)
                        .field("delay_us", delay * 1e6)
                        .field(
                            "delay_site",
                            match site {
                                DelaySite::Calculation => "calculation",
                                DelaySite::Assignment => "assignment",
                            },
                        )
                        .field("t_par", r.t_par())
                        .field("chunks", r.stats.chunks)
                        .field("messages", r.stats.messages)
                        .field("messages_intra_node", r.intra_node_messages)
                        .field("messages_inter_node", r.inter_node_messages)
                        .field("messages_per_level", r.level_messages.clone())
                        .field("switches", r.switch_events.len() as u64)
                        .field(
                            "switch_events",
                            dca_dls::report::json::switch_events_json(&r.switch_events),
                        );
                    // Present only when the run was sharded (--des-threads
                    // ≥ 2): docs/metrics-schema.md §PDES summary.
                    if let Some(p) = &r.pdes {
                        row = row.field(
                            "pdes",
                            Json::obj()
                                .field("shards", p.shards)
                                .field("threads", p.threads)
                                .field("mode", p.mode.as_str())
                                .field("rounds", p.rounds)
                                .field("lookahead_ns", p.lookahead_ns)
                                .field("window_ns", p.window_ns)
                                .field("rollbacks", p.rollbacks)
                                .field("speculated_events", p.speculated_events)
                                .field("checkpoint_bytes", p.checkpoint_bytes)
                                .field("window_multiple", p.window_multiple)
                                .field("horizon_stalls", p.horizon_stalls)
                                .field("mailbox_depth_max", p.mailbox_depth_max),
                        );
                    }
                    row
                })
                .collect(),
        );
        std::fs::write(path, arr.render())?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    reject_pdes_flags(flags, "run")?;
    let app = app_of(flags);
    let tech = outer_tech_of(flags)?;
    let model = if flags.contains_key("hier") {
        ExecutionModel::HierDca
    } else {
        model_of(flags)
    };
    anyhow::ensure!(
        model == ExecutionModel::HierDca
            || !HIER_ONLY_FLAGS.iter().any(|k| flags.contains_key(*k)),
        "--inner/--nodes/--watermark/--levels/… only apply to the hierarchical engine; \
         pass --hier (or --model hier) as well"
    );
    anyhow::ensure!(
        !(flags.contains_key("racks") || flags.contains_key("rack-latency-us")),
        "--racks/--rack-latency-us are simulated-latency knobs; the threaded engine \
         runs on real fabrics — use `simulate`/`hier` for racked scenarios"
    );
    let workers = get(flags, "workers", 4u32);
    let delay = get(flags, "delay-us", 0.0f64) * 1e-6;
    let pjrt = flags.contains_key("pjrt");
    let workload: Arc<dyn Workload> = match (app, pjrt) {
        (App::Mandelbrot, false) => {
            let mut m = Mandelbrot::paper(get(flags, "ct", 256u32));
            m.width = 128;
            Arc::new(m)
        }
        (App::Mandelbrot, true) => Arc::new(PjrtMandelbrot::new(Runtime::default_dir())?),
        (App::Psia, false) => Arc::new(Psia::synthetic(512, 4096, 7)),
        (App::Psia, true) => Arc::new(PjrtPsia::new(Runtime::default_dir(), 4096, 7)?),
    };
    let n = get(flags, "n", workload.n().min(16_384));
    let mut cfg = EngineConfig::new(LoopParams::new(n, workers), tech, model);
    cfg.sched_path = sched_path_of(flags)?;
    cfg.delay = InjectedDelay::calculation_only(delay);
    if model == ExecutionModel::HierDca {
        cfg.nodes = get(flags, "nodes", if workers % 2 == 0 { 2 } else { 1 });
        cfg.hier = hier_of(flags)?;
        if cfg.hier.watermark == WatermarkMode::Off && !flags.contains_key("watermark") {
            // Default the threaded engine to prefetch at roughly one
            // sub-chunk per local rank; `--watermark 0` reverts to
            // fetch-on-exhaustion, `--watermark auto` adapts.
            cfg.hier = cfg.hier.with_watermark((workers / cfg.nodes.max(1)) as u64);
        }
    }
    cfg.hier = apply_adaptive_flags(cfg.hier, flags)?;
    // Flat engines are depth-1 trees by definition (root ↔ ranks) — keeps
    // the exported `levels` consistent with their one-entry per-level split.
    let levels = if model == ExecutionModel::HierDca { cfg.hier.depth() as u32 } else { 1 };
    let t0 = std::time::Instant::now();
    let r = coordinator::run(&cfg, workload)?;
    println!(
        "{} [{}] {} {} workers={workers} nodes={} N={n}",
        app.name(),
        if pjrt { "PJRT artifacts" } else { "native" },
        tech.name(),
        model.label_adaptive(levels, cfg.hier.adaptive.enabled),
        cfg.nodes
    );
    println!("wall = {:.3}s", t0.elapsed().as_secs_f64());
    print!("{}", dca_dls::report::render_run_summary(&r));
    dca_dls::sched::verify_coverage(&r.sorted_assignments(), n)
        .map_err(|e| anyhow::anyhow!("coverage violation: {e}"))?;
    println!("coverage: OK (every iteration scheduled exactly once)");
    if let Some(path) = flags.get("json") {
        let j = dca_dls::report::json::run_result_json(
            app.name(),
            tech,
            model,
            cfg.nodes,
            levels,
            cfg.hier.adaptive.enabled,
            n,
            &r,
        );
        std::fs::write(path, j.render())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep_breakafter(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    reject_sched_path_flags(flags, "sweep-breakafter")?;
    reject_adaptive_flags(flags, "sweep-breakafter")?;
    reject_pdes_flags(flags, "sweep-breakafter")?;
    let app = app_of(flags);
    let tech = tech_of(flags)?;
    let cost = app.cost_model(0xF1605, 2_000);
    println!("== A3: breakAfter sweep ({}, {}, 64 ranks, N=65536) ==", app.name(), tech.name());
    println!("{:<11} {:>12} {:>12}", "breakAfter", "CCA T_par[s]", "DCA T_par[s]");
    for ba in [0u32, 1, 4, 16, 64, 256] {
        let mut t = vec![];
        for model in [ExecutionModel::Cca, ExecutionModel::Dca] {
            let cluster = ClusterConfig {
                nodes: 4,
                ranks_per_node: 16,
                break_after: ba,
                ..ClusterConfig::minihpc()
            };
            let cfg = DesConfig::new(
                LoopParams::new(65_536, cluster.total_ranks()),
                tech,
                model,
                cluster,
                cost.clone(),
            );
            t.push(simulate(&cfg)?.t_par());
        }
        let label = if ba == 0 { "dedicated".to_string() } else { ba.to_string() };
        println!("{label:<11} {:>12.3} {:>12.3}", t[0], t[1]);
    }
    Ok(())
}

fn cmd_select(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    reject_pdes_flags(flags, "select")?;
    let app = app_of(flags);
    let tech = outer_tech_of(flags)?;
    let hier = apply_adaptive_flags(hier_of(flags)?, flags)?;
    // PR 4 wired --lockfree/--sched-path through `hier`/`run` only; the
    // selector probes each candidate on the requested grant path now, with
    // the same invalid-value error handling.
    let sched_path = sched_path_of(flags)?;
    let levels = hier.depth() as u32;
    let delay = get(flags, "delay-us", 0.0f64) * 1e-6;
    let cluster = apply_rack_flags(ClusterConfig::minihpc(), flags)?;
    let cost = app.cost_model(0xF1605, get(flags, "ct", 2_000u32));
    let s = dca_dls::report::selector::select_model(
        tech,
        262_144,
        &cluster,
        &cost,
        InjectedDelay::calculation_only(delay),
        hier,
        sched_path,
    )?;
    println!(
        "{} {} delay={}µs sched-path={} — predicted T_par on a {:.0}% prefix:",
        app.name(),
        tech.name(),
        delay * 1e6,
        sched_path.name(),
        s.prefix_fraction * 100.0
    );
    let label = |m: ExecutionModel| {
        m.label_adaptive(levels, hier.adaptive.enabled && m == ExecutionModel::HierDca)
    };
    let mw = s.predictions.iter().map(|(m, _)| label(*m).len()).max().unwrap_or(8).max(8);
    for (m, t) in &s.predictions {
        let mark = if *m == s.model { "  ← selected" } else { "" };
        println!("  {:<mw$} {t:.3}s{mark}", label(*m));
    }
    Ok(())
}

/// `tenants`: run a multi-tenant session on the DES substrate — from a
/// JSON spec file or a seeded `--demo` tenant set — and report per-tenant
/// turnaround, granted/dropped iterations and session-level fairness.
fn cmd_tenants(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let ranks = get(flags, "ranks", 64u32);
    let cluster = apply_rack_flags(
        if ranks == 256 { ClusterConfig::minihpc() } else { ClusterConfig::small(ranks) },
        flags,
    )?;
    let mut cfg = match flags.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read session spec '{path}': {e}"))?;
            parse_session_spec(&text, cluster)?
        }
        None => demo_session(cluster, get(flags, "demo", 8u32), get(flags, "seed", 42u64)),
    };
    if let Some(raw) = flags.get("policy") {
        cfg.policy = ArbitrationPolicy::parse(raw)?;
    }
    if flags.contains_key("lockfree") || flags.contains_key("sched-path") {
        cfg.sched_path = sched_path_of(flags)?;
    }
    anyhow::ensure!(
        !flags.contains_key("master-lockfree"),
        "--master-lockfree applies to the hierarchical DES (`simulate --model hier`, `hier`)"
    );
    // `--des-threads` shards the session over its arbiter domains and fans
    // the `--slowdown` solo baselines out — bit-identical report either way
    // (docs/tenancy.md). `--des-mode hybrid` only changes the epoch windows
    // of the sharded loop, so it demands actual shard workers.
    let des_threads = des_threads_of(flags)?;
    if let Some(raw) = flags.get("des-mode") {
        anyhow::ensure!(
            des_mode_of(flags)? != PdesMode::Hybrid || des_threads != 1,
            "bad --des-mode '{raw}' (needs --des-threads > 1, or 0 = auto)"
        );
    }
    cfg = cfg
        .with_des_threads(des_threads)
        .with_des_mode(des_mode_of(flags)?)
        .with_pin_shards(flags.contains_key("pin-shards"));
    let stream = stream_flags(flags)?;
    if let Some((_, s)) = &stream {
        cfg = cfg.with_stream_interval(*s);
    }
    let (outcome, slowdowns) = if flags.contains_key("slowdown") {
        let (o, s, mean) = session_slowdowns(&cfg)?;
        (o, Some((s, mean)))
    } else {
        (simulate_session(&cfg)?, None)
    };
    if let Some((dest, _)) = &stream {
        write_stream(dest, &outcome.stream)?;
    }
    println!(
        "session: {} tenants over {} ranks  policy={}  path={:?}",
        outcome.tenants.len(),
        cfg.cluster.total_ranks(),
        cfg.policy,
        cfg.sched_path,
    );
    println!(
        "makespan = {:.4}s   events = {}   messages = {}   Jain fairness = {:.3}",
        outcome.makespan, outcome.events, outcome.messages, outcome.jain_fairness
    );
    if let Some(p) = &outcome.pdes {
        println!(
            "PDES: {} shards × {} threads, {} mode, {} arbiter epochs, \
             epoch {}ns, window multiple ≤ {}, {} speculated events, {} rollbacks",
            p.shards,
            p.threads,
            p.mode.as_str(),
            p.arbiter_epochs,
            p.lookahead_ns,
            p.window_multiple.max(1),
            p.speculated_events,
            p.rollbacks,
        );
    }
    if let Some((_, mean)) = &slowdowns {
        println!("mean slowdown vs solo = {mean:.3}");
    }
    println!(
        "{:>3}  {:<12} {:<5} {:>7} {:>6} {:>9} {:>9} {:>9} {:>8} {:>8}  {}",
        "id",
        "name",
        "tech",
        "N",
        "span",
        "arrival",
        "done",
        "turnarnd",
        "granted",
        "dropped",
        "state"
    );
    for t in &outcome.tenants {
        let spec = &cfg.tenants[t.id as usize];
        let span = if spec.span == 0 { cfg.cluster.total_ranks() } else { spec.span };
        println!(
            "{:>3}  {:<12} {:<5} {:>7} {:>6} {:>9.4} {:>9.4} {:>9.4} {:>8} {:>8}  {}",
            t.id,
            t.name,
            spec.technique.name(),
            spec.n,
            span,
            t.arrival,
            t.completion,
            t.turnaround,
            t.granted_iters,
            t.dropped_iters,
            t.state
        );
    }
    if let Some(path) = flags.get("json") {
        let rendered =
            render_session_json(&cfg, &outcome, slowdowns.as_ref().map(|(s, _)| s.as_slice()));
        std::fs::write(path, rendered)?;
        println!("\nwrote {path}");
    }
    Ok(())
}

/// Synthesize a seeded `--demo` tenant set: K loops with mixed closed-form
/// techniques, staggered arrivals, varied weights, and overlapping block
/// placements across the shared cluster.
fn demo_session(cluster: ClusterConfig, k: u32, seed: u64) -> SessionConfig {
    use dca_dls::techniques::rnd::splitmix64;
    const TECHS: [TechniqueKind; 5] = [
        TechniqueKind::Ss,
        TechniqueKind::Gss,
        TechniqueKind::Tss,
        TechniqueKind::Fac2,
        TechniqueKind::Fiss,
    ];
    let ranks = cluster.total_ranks();
    let mut cfg = SessionConfig::new(cluster);
    for i in 0..k.max(1) {
        let h = splitmix64(seed ^ (0xD15C0 + i as u64));
        let n = 500 + h % 1500;
        let tech = TECHS[((h >> 8) % TECHS.len() as u64) as usize];
        let span = (2u32 << ((h >> 16) % 4)).min(ranks);
        let offset = ((h >> 24) % ranks as u64) as u32;
        let weight = 1 + (h >> 32) % 4;
        cfg = cfg.admit(
            TenantSpec::new(format!("demo-{i}"), n, tech)
                .arriving_at(i as f64 * 2e-4)
                .weighted(weight)
                .placed_at(offset, span),
        );
    }
    cfg
}

fn cmd_validate() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    anyhow::ensure!(
        dir.join("meta.json").exists(),
        "artifacts not built — run `make artifacts`"
    );
    let rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());

    // Mandelbrot: exact f64 cross-check over scattered tiles.
    let w = PjrtMandelbrot::new(&dir)?;
    let native = rt.meta.mandelbrot_native();
    let mut checked = 0u64;
    let mut diverged = 0u64;
    for start in [0u64, 51_200, 130_048, 174_080, 200_704, 261_120] {
        for lane in 0..1024u64 {
            let got = w.execute(start + lane);
            if got != native.escape_count(start + lane) as u64 {
                diverged += 1;
            }
            checked += 1;
        }
    }
    anyhow::ensure!(diverged <= 8, "{diverged}/{checked} pixels diverged from native");
    println!(
        "mandelbrot: {}/{checked} pixels bit-exact vs native f64 ({diverged} FMA-contraction boundary pixels) OK",
        checked - diverged
    );

    // PSIA: tolerance on borderline f32 binning.
    let p = PjrtPsia::new(&dir, 256, 0x5e1a_5e1a)?;
    let mut mismatch = 0;
    for i in 0..32u64 {
        if p.execute(i) != p.native().execute(i) {
            mismatch += 1;
        }
    }
    anyhow::ensure!(mismatch <= 3, "{mismatch}/32 spin images diverged");
    println!(
        "spin_image: {}/32 images match native ({mismatch} borderline f32 bins) OK",
        32 - mismatch
    );
    println!("validate: OK");
    Ok(())
}
