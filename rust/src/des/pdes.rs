//! Parallel DES core (PDES): a **two-mode, horizon-synchronized** round
//! executor over statically partitioned shards.
//!
//! Each shard owns a disjoint slice of the simulated machine (a
//! `LevelSpec` subtree in the hierarchical engine, a worker rank range in
//! the flat one) and runs its own calendar queue independently. Shards
//! synchronize only at horizon boundaries:
//!
//! 1. every shard publishes its earliest pending event time;
//! 2. the global minimum (GVT) plus the **lookahead** — the smallest
//!    cross-shard latency class — bounds a window `[GVT, GVT + Δ)`;
//! 3. shards process all local events inside the window in parallel,
//!    capturing cross-shard sends in the two-tier routing table;
//! 4. after a barrier, each shard drains its inbound channels in sender
//!    order and the next round begins.
//!
//! Conservatism: a message created at local time `t ≥ GVT` travels a
//! cross-shard link of latency `≥ Δ`, so it arrives at `t + lat ≥ GVT + Δ`
//! — never inside the window that created it. Delivering all channels at
//! round start therefore never delivers into a shard's past.
//!
//! **The hybrid round** ([`PdesMode::Hybrid`]) stretches each
//! synchronization round to cover up to `(2 + m)Δ` of simulated time,
//! `m ≤ window_mult_max`, so tight-latency clusters stop paying one
//! barrier set per `Δ`:
//!
//! * **committed** `[GVT, H)`, `H = GVT + Δ` — exactly the conservative
//!   window; its cross-shard sends are staged into the *committed* lane
//!   set and drained (sender order) right after the advance barrier, so
//!   tie order inside the committed window is identical to the
//!   conservative loop's.
//! * **safe extension** `[H, H + Δ)` — unconditionally advanced by every
//!   shard after the committed drain. Still provably conservative: a
//!   message arriving before `H + Δ` was sent before `H`, i.e. inside the
//!   committed window, and was just delivered. Extension sends go to the
//!   *safe* lane set; they arrive at `≥ H + Δ` and are **delivered before
//!   any shard executes past `H + Δ`** (the deliver-then-speculate rule),
//!   so they can never land in an executed past.
//! * **multi-Δ speculation** `[S, S + mΔ)`, `S = H + Δ` — entered only
//!   when *every* shard's [`WindowController`] proposes an open window;
//!   the round's multiple `m` is the global minimum of the per-shard
//!   proposals (a per-shard depth would let next-round traffic from a
//!   shallow shard land inside a deep shard's already-executed span).
//!   Each shard checkpoints at `S` — **incrementally** when the shard
//!   supports an undo journal ([`Shard::ckpt_begin`], cost scales with
//!   events speculated), falling back to [`Shard::save`]'s full clone —
//!   and speculates through the span with sends staged into the *opt*
//!   lane set. In-window cross-shard arrivals are then resolved by a
//!   barrier-paced **fixed-point loop**: a shard whose inbound opt
//!   arrival-time sequence changed (or whose sender re-executed) rolls
//!   back to its checkpoint, re-delivers clones of all current in-window
//!   arrivals, and re-speculates. Arrivals in `[S + kΔ, S + (k+1)Δ)` were
//!   sent before `S + kΔ`, so execution finalizes one `Δ` per iteration
//!   and the loop converges in at most `m` iterations (it exits the first
//!   time no shard is dirty — immediately, in the common high-slack
//!   round). At `m = 1` the span admits no in-window arrivals at all and
//!   speculation is risk-free. After convergence every history below
//!   `S + mΔ` is final, so the next round's GVT satisfies the
//!   conservative invariant again; the final drain delivers only
//!   arrivals `≥ S + mΔ` (the in-window ones were already delivered as
//!   clones inside the journal scope).
//!
//! The [`WindowController`] — EWMA of realized cross-shard slack and
//! committed-window event load, the `sched/adaptive.rs` idiom — opens the
//! window when stragglers are rare (slack EWMA ≥ 0.95) or rounds are
//! sparse, and **escalates** the proposed multiple (1 → 2 → 4 → … up to
//! the cap) after [`WINDOW_SAT_ROUNDS`] consecutive open rounds; any
//! rollback demotes the shard back to 1Δ.
//!
//! **Determinism is structural, not scheduled.** The shard count is fixed
//! by the partition geometry (never by the thread count), each shard's
//! event order is its own `(time, seq)` calendar order, window boundaries,
//! controller decisions, and the global multiple are pure functions of
//! shard states, and channel drains run in `(sender shard, FIFO)` order —
//! so the outcome is a function of the partition alone, in both modes.
//! Threads only decide *which core* runs a shard's window (optionally
//! pinned — [`PdesOpts::pin_shards`]); `--des-threads 1` and
//! `--des-threads 8` walk bit-identical per-shard histories, and a
//! rollback replay reconverges exactly.

use std::cell::UnsafeCell;
use std::mem;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Barrier;

/// Optimistic window controller: open the window when the realized slack
/// EWMA says stragglers are rare (≥ this fraction of Δ)…
const SLACK_SAFE: f64 = 0.95;
/// …or when the committed window is this sparse (events per round) — the
/// barrier-bound regime where even a replayed window is cheaper than an
/// extra synchronization round.
const SPARSE_EVENTS: f64 = 48.0;
/// Same smoothing as `sched/adaptive.rs::OBS_EWMA_ALPHA`.
const PDES_EWMA_ALPHA: f64 = 0.25;
/// Consecutive open rounds before the controller doubles its proposed
/// window multiple (the slack-saturation threshold of the multi-Δ
/// escalation).
pub const WINDOW_SAT_ROUNDS: u32 = 4;
/// Default cap on the window multiple (speculate at most this many Δ past
/// the safe extension).
pub const WINDOW_MULT_MAX: u32 = 8;

/// Executor mode: pure conservative horizon rounds (PR 8 behavior) or the
/// hybrid loop whose per-shard controllers may open the multi-Δ window.
/// Both modes produce bit-identical results; they differ only in how much
/// wall-clock a synchronization round buys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PdesMode {
    Conservative,
    #[default]
    Hybrid,
}

impl PdesMode {
    pub fn parse(s: &str) -> Option<PdesMode> {
        match s {
            "conservative" => Some(PdesMode::Conservative),
            "hybrid" => Some(PdesMode::Hybrid),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PdesMode::Conservative => "conservative",
            PdesMode::Hybrid => "hybrid",
        }
    }
}

/// Executor options beyond the lookahead/thread pair.
#[derive(Debug, Clone)]
pub struct PdesOpts {
    pub mode: PdesMode,
    /// Run [`Shard::reduce`] single-threaded between rounds (its own
    /// barrier pair). Callers enable this only when shards share
    /// deterministic global state — e.g. the flat engine's adaptive era
    /// table.
    pub reduce: bool,
    /// Rack id per shard for the two-tier routing table. Empty means one
    /// rack (a full direct mesh, the PR 8 topology). Same-rack pairs get a
    /// direct SPSC lane; cross-rack sends share one `(sender, rack)` lane
    /// scanned read-only by the rack's shards.
    pub rack_of: Vec<u32>,
    /// Cap on the hybrid window multiple (clamped to ≥ 1; 1 = single-Δ
    /// speculation, the risk-free window). Purely a depth limit — results
    /// are bit-identical at every value.
    pub window_mult_max: u32,
    /// Best-effort pin of each worker thread to its own core stripe
    /// (`sched_setaffinity`; no-op where unsupported), so a shard's
    /// calendar queue and SPSC lanes stay NUMA-local by first touch.
    pub pin_shards: bool,
}

impl Default for PdesOpts {
    fn default() -> Self {
        PdesOpts {
            mode: PdesMode::default(),
            reduce: false,
            rack_of: Vec::new(),
            window_mult_max: WINDOW_MULT_MAX,
            pin_shards: false,
        }
    }
}

impl PdesOpts {
    pub fn conservative() -> Self {
        PdesOpts { mode: PdesMode::Conservative, ..Default::default() }
    }
}

/// One shard of a partitioned simulation.
///
/// `advance` must process **every** local event strictly before `horizon`
/// (including events it creates inside the window) and route any event
/// addressed to another shard through the outbox instead of its own queue.
pub trait Shard: Send {
    /// A cross-shard message: the destination shard reinjects it into its
    /// calendar queue at the carried arrival time. `Clone` because
    /// cross-rack lanes are scanned (not drained) by their rack's shards,
    /// and in-window speculative arrivals are delivered as clones.
    type Msg: Send + Clone;

    /// State snapshot taken at speculation entry; restoring it must
    /// rewind the shard exactly (calendar queue, ledgers, counters,
    /// samplers).
    type Ckpt: Send;

    /// Earliest pending local event time (`None` when the queue is empty).
    fn next_at(&self) -> Option<u64>;

    /// Process all local events with `time < horizon`; returns the number
    /// of events executed (the speculated-events accounting).
    fn advance(&mut self, horizon: u64, outbox: &mut Outbox<Self::Msg>) -> u64;

    /// Inject a cross-shard arrival at absolute time `at`.
    fn deliver(&mut self, at: u64, msg: Self::Msg);

    /// Snapshot the shard for a possible rollback (the full-clone
    /// checkpoint fallback).
    fn save(&self) -> Self::Ckpt;

    /// Rewind to a snapshot taken by [`Shard::save`].
    fn restore(&mut self, ckpt: Self::Ckpt);

    /// Arm an **incremental** checkpoint: an undo journal over the
    /// shard's mutable state whose cost scales with the events the span
    /// executes, not the state size. Return `false` (the default) to make
    /// the executor fall back to [`Shard::save`]'s full clone.
    fn ckpt_begin(&mut self) -> bool {
        false
    }

    /// Discard the armed journal, keeping the span's effects; returns the
    /// journal's byte footprint (the `checkpoint_bytes` accounting).
    /// Called only after `ckpt_begin` returned `true`.
    fn ckpt_commit(&mut self) -> u64 {
        0
    }

    /// Replay the armed journal — rewinding the shard exactly to the
    /// `ckpt_begin` state — and **re-arm** it (a fixed-point iteration
    /// rolls back, redelivers, and speculates again). Returns the
    /// discarded journal's byte footprint. Called only after `ckpt_begin`
    /// returned `true`.
    fn ckpt_rollback(&mut self) -> u64 {
        0
    }

    /// Deterministic fixed-order cross-shard merge of shared state at a
    /// round boundary, run by one thread while all others hold at a
    /// barrier. Default: nothing is shared.
    fn reduce(_shards: &mut [&mut Self])
    where
        Self: Sized,
    {
    }
}

/// Per-sender staging area for cross-shard messages: one FIFO lane per
/// destination shard, appended during `advance`, moved into the routing
/// table by the executor.
pub struct Outbox<M> {
    lanes: Vec<Vec<(u64, M)>>,
}

impl<M> Outbox<M> {
    pub fn new(shards: usize) -> Self {
        Outbox { lanes: (0..shards).map(|_| Vec::new()).collect() }
    }

    /// Stage a message for shard `dst`, arriving at absolute time `at`.
    pub fn send(&mut self, dst: usize, at: u64, msg: M) {
        self.lanes[dst].push((at, msg));
    }
}

/// A phase-synchronized channel cell. There are no internal locks: the
/// round protocol itself is the synchronization — writers touch a cell
/// only in their exclusive phase, readers only after the barrier that
/// publishes the writes (the barrier waits establish the happens-before
/// edge). Direct lanes are single-producer/single-consumer; cross-rack
/// lanes are single-producer/multi-*reader* (receivers scan a shared
/// borrow and the producer clears the lane in its next write phase).
struct PhaseCell<T>(UnsafeCell<Vec<T>>);

// Safety: see the type docs — phase discipline guarantees exclusive
// mutable access, the barrier publishes writes.
unsafe impl<T: Send> Sync for PhaseCell<T> {}

impl<T> PhaseCell<T> {
    fn new() -> Self {
        PhaseCell(UnsafeCell::new(Vec::new()))
    }

    /// Safety: caller must hold phase-exclusive *write* access.
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut Vec<T> {
        &mut *self.0.get()
    }

    /// Safety: caller must be in a phase where no writer is active.
    unsafe fn get_ref(&self) -> &Vec<T> {
        &*self.0.get()
    }
}

/// The two-tier routing table for one lane set (committed, safe, or
/// opt): `direct[src][dst]` carries same-rack pairs, a
/// `shared[src][rack]` lane carries everything `src` sends into another
/// rack (entries tagged with the destination shard). Every (src, dst)
/// pair travels exactly one channel, so `(sender shard, FIFO)` drain
/// order is preserved; live channel state drops from the `S²` pair mesh
/// to `Σ_r S_r²` direct lanes plus `S · R` rack lanes.
struct RoutingTable<M> {
    rack_of: Vec<u32>,
    direct: Vec<Vec<PhaseCell<(u64, M)>>>,
    shared: Vec<Vec<PhaseCell<(usize, u64, M)>>>,
}

impl<M: Clone> RoutingTable<M> {
    fn new(rack_of: &[u32]) -> Self {
        let s_count = rack_of.len();
        let racks = rack_of.iter().copied().max().unwrap_or(0) as usize + 1;
        RoutingTable {
            rack_of: rack_of.to_vec(),
            direct: (0..s_count)
                .map(|_| (0..s_count).map(|_| PhaseCell::new()).collect())
                .collect(),
            shared: (0..s_count)
                .map(|_| (0..racks).map(|_| PhaseCell::new()).collect())
                .collect(),
        }
    }

    /// Sender `src` resets the scan-only rack lanes it produced last
    /// round (their readers finished at the close barrier; direct lanes
    /// were drained by their receivers).
    ///
    /// Safety: write phase of `src`'s owning thread.
    unsafe fn clear_sent(&self, src: usize) {
        for lane in &self.shared[src] {
            lane.get().clear();
        }
    }

    /// Sender `src` drops everything it staged this round (rollback).
    ///
    /// Safety: write phase of `src`'s owning thread.
    unsafe fn drop_staged(&self, src: usize) {
        for lane in &self.direct[src] {
            lane.get().clear();
        }
        for lane in &self.shared[src] {
            lane.get().clear();
        }
    }

    /// Move an outbox into the table. Safety: write phase of `src`.
    unsafe fn stage(&self, src: usize, outbox: &mut Outbox<M>) {
        for (dst, lane) in outbox.lanes.iter_mut().enumerate() {
            if lane.is_empty() {
                continue;
            }
            if self.rack_of[src] == self.rack_of[dst] {
                self.direct[src][dst].get().append(lane);
            } else {
                let shared = self.shared[src][self.rack_of[dst] as usize].get();
                shared.extend(lane.drain(..).map(|(at, m)| (dst, at, m)));
            }
        }
    }

    /// Earliest inbound arrival staged for `dst` (`u64::MAX` when none).
    /// Safety: read phase of `dst`'s owning thread.
    unsafe fn min_arrival(&self, dst: usize) -> u64 {
        let mut min = u64::MAX;
        let my_rack = self.rack_of[dst] as usize;
        for src in 0..self.rack_of.len() {
            if self.rack_of[src] as usize == my_rack {
                for (at, _) in self.direct[src][dst].get_ref() {
                    min = min.min(*at);
                }
            } else {
                for (d, at, _) in self.shared[src][my_rack].get_ref() {
                    if *d == dst {
                        min = min.min(*at);
                    }
                }
            }
        }
        min
    }

    /// Collect, per sender, the arrival-time sequence (in lane order, one
    /// `Vec` per source shard) of everything staged for `dst` below
    /// `max_at` — the fixed-point loop's exact change detector.
    ///
    /// Safety: read phase of `dst`'s owning thread.
    unsafe fn collect_arrivals(&self, dst: usize, max_at: u64, out: &mut [Vec<u64>]) {
        let my_rack = self.rack_of[dst] as usize;
        for src in 0..self.rack_of.len() {
            let v = &mut out[src];
            v.clear();
            if self.rack_of[src] as usize == my_rack {
                for (at, _) in self.direct[src][dst].get_ref() {
                    if *at < max_at {
                        v.push(*at);
                    }
                }
            } else {
                for (d, at, _) in self.shared[src][my_rack].get_ref() {
                    if *d == dst && *at < max_at {
                        v.push(*at);
                    }
                }
            }
        }
    }

    /// Deliver **clones** of every arrival staged for `dst` below
    /// `max_at`, in `(sender shard, FIFO)` order, leaving all lanes
    /// intact (senders may still drop/restage them; the receiver's
    /// journal makes the delivery retraction-safe). Returns the count.
    ///
    /// Safety: read phase of `dst`'s owning thread.
    unsafe fn scan_into_max<S: Shard<Msg = M>>(
        &self,
        dst: usize,
        max_at: u64,
        shard: &mut S,
    ) -> u64 {
        let mut delivered = 0u64;
        let my_rack = self.rack_of[dst] as usize;
        for src in 0..self.rack_of.len() {
            if self.rack_of[src] as usize == my_rack {
                for (at, msg) in self.direct[src][dst].get_ref() {
                    if *at < max_at {
                        shard.deliver(*at, msg.clone());
                        delivered += 1;
                    }
                }
            } else {
                for (d, at, msg) in self.shared[src][my_rack].get_ref() {
                    if *d == dst && *at < max_at {
                        shard.deliver(*at, msg.clone());
                        delivered += 1;
                    }
                }
            }
        }
        delivered
    }

    /// Deliver everything staged for `dst` in `(sender shard, FIFO)`
    /// order; returns the message count. Direct lanes are drained (the
    /// receiver is their single consumer), shared rack lanes are scanned
    /// read-only — every shard of the rack walks the same lane and picks
    /// its own entries; the producer clears it next round.
    ///
    /// Safety: read phase of `dst`'s owning thread.
    unsafe fn drain_into<S: Shard<Msg = M>>(&self, dst: usize, shard: &mut S) -> u64 {
        self.drain_into_min(dst, 0, shard)
    }

    /// Like [`Self::drain_into`] but deliver only arrivals `≥ min_at`:
    /// the below-bound entries were already delivered as in-window clones
    /// during the fixed-point loop. Every entry addressed to `dst` counts
    /// toward the returned total exactly once, delivered or not, so
    /// `messages_routed` stays the unique-message count.
    ///
    /// Safety: read phase of `dst`'s owning thread.
    unsafe fn drain_into_min<S: Shard<Msg = M>>(
        &self,
        dst: usize,
        min_at: u64,
        shard: &mut S,
    ) -> u64 {
        let mut count = 0u64;
        let my_rack = self.rack_of[dst] as usize;
        for src in 0..self.rack_of.len() {
            if self.rack_of[src] as usize == my_rack {
                for (at, msg) in self.direct[src][dst].get().drain(..) {
                    if at >= min_at {
                        shard.deliver(at, msg);
                    }
                    count += 1;
                }
            } else {
                for (d, at, msg) in self.shared[src][my_rack].get_ref() {
                    if *d == dst {
                        if *at >= min_at {
                            shard.deliver(*at, msg.clone());
                        }
                        count += 1;
                    }
                }
            }
        }
        count
    }
}

/// Per-shard EWMA driving the optimistic window decision — the
/// `sched/adaptive.rs` idiom (first sample taken verbatim).
#[derive(Debug, Clone, Copy, Default)]
struct Ewma {
    v: f64,
    primed: bool,
}

impl Ewma {
    fn observe(&mut self, x: f64) {
        if self.primed {
            self.v += PDES_EWMA_ALPHA * (x - self.v);
        } else {
            self.v = x;
            self.primed = true;
        }
    }
}

/// Adaptive window controller: one per shard, fed only by that shard's
/// own round observations, so its decisions are thread-count independent.
///
/// The gate (slack EWMA ≥ [`SLACK_SAFE`], or committed load ≤
/// [`SPARSE_EVENTS`]) opens single-Δ speculation; [`WINDOW_SAT_ROUNDS`]
/// consecutive open rounds — slack saturation — double the proposed
/// multiple up to the cap, and any rollback demotes it back to 1.
#[derive(Debug, Clone, Copy)]
pub(crate) struct WindowController {
    /// Realized cross-shard slack: (earliest inbound arrival − H) / Δ,
    /// clamped to [0, 1]; 1.0 on rounds with no inbound.
    slack: Ewma,
    /// Events executed inside the committed window per round.
    load: Ewma,
    /// Consecutive gate-open rounds since the last escalation/demotion.
    sat: u32,
    /// Current window multiple proposed while the gate is open.
    mult: u32,
}

impl Default for WindowController {
    fn default() -> Self {
        WindowController { slack: Ewma::default(), load: Ewma::default(), sat: 0, mult: 1 }
    }
}

impl WindowController {
    fn gate_open(&self) -> bool {
        self.slack.primed && (self.slack.v >= SLACK_SAFE || self.load.v <= SPARSE_EVENTS)
    }

    pub(crate) fn observe_round(&mut self, slack_norm: f64, committed_events: u64, mult_cap: u32) {
        self.slack.observe(slack_norm);
        self.load.observe(committed_events as f64);
        if self.gate_open() {
            self.sat = self.sat.saturating_add(1);
            if self.sat >= WINDOW_SAT_ROUNDS && self.mult < mult_cap {
                self.mult = (self.mult * 2).min(mult_cap);
                self.sat = 0;
            }
        } else {
            self.sat = 0;
        }
    }

    /// Window multiple this shard proposes for the coming round: 0 keeps
    /// the round conservative (committed + safe only); the executor takes
    /// the global minimum across shards.
    pub(crate) fn proposed_mult(&self) -> u64 {
        if self.gate_open() {
            self.mult as u64
        } else {
            0
        }
    }

    /// A straggler invalidated the speculated span: drop back to 1Δ.
    fn on_rollback(&mut self) {
        self.mult = 1;
        self.sat = 0;
    }
}

/// Checkpoint held across a speculated span: incremental (the shard's
/// own undo journal is armed) or the full-clone fallback.
enum SpecCkpt<C> {
    None,
    Full(C),
    Incr,
}

/// A shard plus its executor-side counters. Only the owning thread ever
/// touches a cell (static shard→thread map), so the `UnsafeCell` wrapper
/// below is exclusive by construction.
struct WorkerShard<S: Shard> {
    shard: S,
    ctl: WindowController,
    /// Checkpoint armed at speculation entry, held until convergence.
    ckpt: SpecCkpt<S::Ckpt>,
    /// Events executed inside the committed window this round.
    committed_events: u64,
    /// Inbound messages drained this round before the opt phase (depth
    /// bookkeeping across the phase split).
    inbound_depth: u64,
    /// Per-sender arrival-time sequences this shard last incorporated
    /// (the fixed-point change detector's reference).
    last_in: Vec<Vec<u64>>,
    /// Scratch for the current iteration's arrival-time sequences.
    pending_in: Vec<Vec<u64>>,
    /// Rounds where this shard had pending events but none inside the
    /// window — it idled at the barrier while other shards progressed.
    horizon_stalls: u64,
    /// Largest number of messages drained by this shard in one round.
    mailbox_depth_max: u64,
    /// Total cross-shard messages delivered to this shard.
    delivered: u64,
    /// Speculated spans a straggler invalidated (rolled back, clones
    /// redelivered in sender order, re-executed).
    rollbacks: u64,
    /// Events executed past the conservative horizon, including events a
    /// rollback discarded and the replay then re-executed.
    speculated_events: u64,
    /// Bytes of incremental-checkpoint journal this shard accumulated
    /// (0 when the shard only supports full-clone checkpoints).
    ckpt_bytes: u64,
    /// Largest window multiple this shard actually speculated under.
    mult_max: u64,
}

struct ShardCell<S: Shard>(UnsafeCell<WorkerShard<S>>);

// Safety: each cell is read/written only by its statically assigned
// thread (plus the single-threaded reduce step, barrier-fenced on both
// sides); barriers order the phases.
unsafe impl<S: Shard> Sync for ShardCell<S> {}

impl<S: Shard> ShardCell<S> {
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut WorkerShard<S> {
        &mut *self.0.get()
    }
}

/// Cross-thread state of the hybrid speculation phases: per-shard window
/// proposals (written in the controller phase, reduced to a global
/// minimum after the barrier) and the parity-indexed dirty flags of the
/// fixed-point loop (each shard writes its own flag in the read phase;
/// everyone reads the full array after the barrier).
struct SpecBoard {
    window_slots: Vec<AtomicU64>,
    dirty: [Vec<AtomicBool>; 2],
}

impl SpecBoard {
    fn new(s_count: usize) -> Self {
        SpecBoard {
            window_slots: (0..s_count).map(|_| AtomicU64::new(0)).collect(),
            dirty: [
                (0..s_count).map(|_| AtomicBool::new(false)).collect(),
                (0..s_count).map(|_| AtomicBool::new(false)).collect(),
            ],
        }
    }
}

/// Executor-level accounting of one PDES run — the source of the
/// per-shard `horizon_stalls` / `mailbox_depth_max` / `rollbacks` /
/// `speculated_events` / `checkpoint_bytes` observability fields.
#[derive(Debug, Clone)]
pub struct PdesReport {
    pub shards: usize,
    pub threads: usize,
    pub lookahead_ns: u64,
    pub mode: PdesMode,
    /// Base optimistic window (= lookahead in hybrid mode, 0 when the
    /// run is conservative or single-shard); the realized per-round span
    /// is `window_ns ×` the round's global multiple.
    pub window_ns: u64,
    /// Synchronization rounds executed.
    pub rounds: u64,
    /// Per-shard horizon-stall counts (see [`WorkerShard::horizon_stalls`]).
    pub horizon_stalls: Vec<u64>,
    /// Per-shard max messages drained in one round.
    pub mailbox_depth_max: Vec<u64>,
    /// Per-shard rollback counts (invalidated speculated spans).
    pub rollbacks: Vec<u64>,
    /// Per-shard events executed past the conservative horizon.
    pub speculated_events: Vec<u64>,
    /// Per-shard incremental-checkpoint journal bytes (0 on shards that
    /// fall back to full-clone checkpoints).
    pub checkpoint_bytes: Vec<u64>,
    /// Per-shard maximum realized window multiple (0 = never speculated).
    pub window_multiple: Vec<u64>,
    /// Total cross-shard messages routed.
    pub messages_routed: u64,
}

/// Deliver pre-round (bootstrap) outboxes: sender-order FIFO per
/// destination, exactly like the in-round delivery phase.
pub fn deliver_staged<S: Shard>(shards: &mut [S], mut staged: Vec<Outbox<S::Msg>>) {
    for dst in 0..shards.len() {
        for src_outbox in staged.iter_mut() {
            for (at, msg) in src_outbox.lanes[dst].drain(..) {
                shards[dst].deliver(at, msg);
            }
        }
    }
}

/// Run the conservative round loop to completion — PR 8's executor,
/// expressed as the two-mode loop with every window pinned to zero.
pub fn run_conservative<S: Shard>(
    shards: Vec<S>,
    lookahead_ns: u64,
    threads: u32,
) -> (Vec<S>, PdesReport) {
    run_sharded(shards, lookahead_ns, threads, &PdesOpts::conservative())
}

/// Run the round loop to completion and hand the shards back together
/// with the executor report.
///
/// `threads` is clamped to `[1, shards]`; the result is independent of it
/// by construction. `lookahead_ns` must be positive whenever more than
/// one shard exists (a zero-latency cross-shard link admits no
/// conservative window — partition callers must collapse to one shard).
pub fn run_sharded<S: Shard>(
    shards: Vec<S>,
    lookahead_ns: u64,
    threads: u32,
    opts: &PdesOpts,
) -> (Vec<S>, PdesReport) {
    let s_count = shards.len();
    assert!(s_count > 0, "PDES needs at least one shard");
    assert!(
        s_count == 1 || lookahead_ns > 0,
        "conservative PDES needs a positive lookahead across shards"
    );
    assert!(
        opts.rack_of.is_empty() || opts.rack_of.len() == s_count,
        "rack_of must map every shard"
    );
    let threads = (threads.max(1) as usize).min(s_count);
    let rack_of: Vec<u32> =
        if opts.rack_of.is_empty() { vec![0; s_count] } else { opts.rack_of.clone() };
    let mult_cap = opts.window_mult_max.max(1);

    let cells: Vec<ShardCell<S>> = shards
        .into_iter()
        .map(|shard| {
            ShardCell(UnsafeCell::new(WorkerShard {
                shard,
                ctl: WindowController::default(),
                ckpt: SpecCkpt::None,
                committed_events: 0,
                inbound_depth: 0,
                last_in: vec![Vec::new(); s_count],
                pending_in: vec![Vec::new(); s_count],
                horizon_stalls: 0,
                mailbox_depth_max: 0,
                delivered: 0,
                rollbacks: 0,
                speculated_events: 0,
                ckpt_bytes: 0,
                mult_max: 0,
            }))
        })
        .collect();
    let next_slots: Vec<AtomicU64> = (0..s_count).map(|_| AtomicU64::new(u64::MAX)).collect();
    let board = SpecBoard::new(s_count);
    let committed: RoutingTable<S::Msg> = RoutingTable::new(&rack_of);
    let safe: RoutingTable<S::Msg> = RoutingTable::new(&rack_of);
    let opt: RoutingTable<S::Msg> = RoutingTable::new(&rack_of);
    let barrier = Barrier::new(threads);
    let rounds = AtomicU64::new(0);
    let hybrid = opts.mode == PdesMode::Hybrid && s_count > 1;

    std::thread::scope(|scope| {
        for tid in 1..threads {
            let cells = &cells;
            let next_slots = &next_slots;
            let board = &board;
            let committed = &committed;
            let safe = &safe;
            let opt = &opt;
            let barrier = &barrier;
            let rounds = &rounds;
            let pin = opts.pin_shards;
            let reduce = opts.reduce;
            scope.spawn(move || {
                if pin {
                    pin_current_thread(tid, threads);
                }
                worker_loop(
                    tid, threads, lookahead_ns, hybrid, mult_cap, reduce, barrier, next_slots,
                    board, cells, committed, safe, opt, rounds,
                )
            });
        }
        if opts.pin_shards && threads > 1 {
            pin_current_thread(0, threads);
        }
        worker_loop(
            0, threads, lookahead_ns, hybrid, mult_cap, opts.reduce, &barrier, &next_slots,
            &board, &cells, &committed, &safe, &opt, &rounds,
        );
    });

    let mut shards = Vec::with_capacity(s_count);
    let mut horizon_stalls = Vec::with_capacity(s_count);
    let mut mailbox_depth_max = Vec::with_capacity(s_count);
    let mut rollbacks = Vec::with_capacity(s_count);
    let mut speculated_events = Vec::with_capacity(s_count);
    let mut checkpoint_bytes = Vec::with_capacity(s_count);
    let mut window_multiple = Vec::with_capacity(s_count);
    let mut messages_routed = 0;
    for cell in cells {
        let ws = cell.0.into_inner();
        horizon_stalls.push(ws.horizon_stalls);
        mailbox_depth_max.push(ws.mailbox_depth_max);
        rollbacks.push(ws.rollbacks);
        speculated_events.push(ws.speculated_events);
        checkpoint_bytes.push(ws.ckpt_bytes);
        window_multiple.push(ws.mult_max);
        messages_routed += ws.delivered;
        shards.push(ws.shard);
    }
    let report = PdesReport {
        shards: s_count,
        threads,
        lookahead_ns,
        mode: opts.mode,
        window_ns: if hybrid { lookahead_ns } else { 0 },
        rounds: rounds.load(Ordering::Relaxed),
        horizon_stalls,
        mailbox_depth_max,
        rollbacks,
        speculated_events,
        checkpoint_bytes,
        window_multiple,
        messages_routed,
    };
    (shards, report)
}

#[allow(clippy::too_many_arguments)]
fn worker_loop<S: Shard>(
    tid: usize,
    threads: usize,
    lookahead_ns: u64,
    hybrid: bool,
    mult_cap: u32,
    reduce: bool,
    barrier: &Barrier,
    next_slots: &[AtomicU64],
    board: &SpecBoard,
    cells: &[ShardCell<S>],
    committed: &RoutingTable<S::Msg>,
    safe: &RoutingTable<S::Msg>,
    opt: &RoutingTable<S::Msg>,
    rounds: &AtomicU64,
) {
    let s_count = cells.len();
    let mut outbox = Outbox::new(s_count);
    loop {
        // Phase A — publish each owned shard's earliest event time.
        for j in (tid..s_count).step_by(threads) {
            let ws = unsafe { cells[j].get() };
            next_slots[j].store(ws.shard.next_at().unwrap_or(u64::MAX), Ordering::Relaxed);
        }
        barrier.wait();

        // Every thread derives the same GVT and horizon from the slots.
        let gvt = next_slots.iter().map(|a| a.load(Ordering::Relaxed)).min().unwrap_or(u64::MAX);
        if gvt == u64::MAX {
            break;
        }
        let horizon = if s_count == 1 { u64::MAX } else { gvt.saturating_add(lookahead_ns) };

        // Phase B — advance owned shards through the committed window,
        // staging cross-shard sends into the committed lane set. This is
        // exactly the conservative window, in both modes.
        for j in (tid..s_count).step_by(threads) {
            let ws = unsafe { cells[j].get() };
            unsafe { committed.clear_sent(j) };
            if hybrid {
                unsafe {
                    safe.clear_sent(j);
                    opt.clear_sent(j);
                }
            }
            if ws.shard.next_at().is_some_and(|t| t >= horizon) {
                ws.horizon_stalls += 1;
            }
            ws.committed_events = ws.shard.advance(horizon, &mut outbox);
            unsafe { committed.stage(j, &mut outbox) };
        }
        barrier.wait();

        if !hybrid {
            // Conservative rounds: straight sender-order drain and close,
            // as in PR 8 — three barriers per Δ of simulated time.
            for j in (tid..s_count).step_by(threads) {
                let ws = unsafe { cells[j].get() };
                let depth = unsafe { committed.drain_into(j, &mut ws.shard) };
                ws.mailbox_depth_max = ws.mailbox_depth_max.max(depth);
                ws.delivered += depth;
            }
            close_round(tid, reduce, barrier, cells, rounds);
            continue;
        }

        // Phase C — drain the committed batch in sender order (identical
        // placement to the conservative loop, so committed-window tie
        // order matches), feed the controller and publish this shard's
        // window proposal, then advance through the safe extension
        // [H, H+Δ) — sound unconditionally: anything arriving before H+Δ
        // was sent before H and was just delivered.
        let safe_end = horizon.saturating_add(lookahead_ns);
        for j in (tid..s_count).step_by(threads) {
            let ws = unsafe { cells[j].get() };
            let min_arrival = unsafe { committed.min_arrival(j) };
            let depth = unsafe { committed.drain_into(j, &mut ws.shard) };
            ws.delivered += depth;
            ws.inbound_depth = depth;
            let slack_norm = if min_arrival == u64::MAX {
                1.0
            } else {
                (min_arrival.saturating_sub(horizon) as f64 / lookahead_ns as f64).clamp(0.0, 1.0)
            };
            ws.ctl.observe_round(slack_norm, ws.committed_events, mult_cap);
            board.window_slots[j].store(ws.ctl.proposed_mult(), Ordering::Relaxed);
            ws.shard.advance(safe_end, &mut outbox);
            unsafe { safe.stage(j, &mut outbox) };
        }
        barrier.wait();

        // The round's window multiple is the global minimum of the
        // per-shard proposals: every shard speculates to the same
        // spec_end or nobody does, so after in-round resolution the next
        // GVT is ≥ spec_end and the cross-round conservative invariant
        // holds (a per-shard depth would let next-round sends from a
        // shallow shard land inside a deep shard's executed span).
        let global_mult = board
            .window_slots
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .min()
            .unwrap_or(0);
        let spec_end = safe_end.saturating_add(lookahead_ns.saturating_mul(global_mult));

        // Phase D — deliver the safe batch FIRST (sender order), then,
        // window permitting, checkpoint and speculate through
        // [safe_end, spec_end). Delivering before speculating removes
        // every safe-lane rollback: safe sends arrive at ≥ H+Δ =
        // safe_end, and nothing past safe_end has executed yet.
        for j in (tid..s_count).step_by(threads) {
            let ws = unsafe { cells[j].get() };
            let depth = unsafe { safe.drain_into(j, &mut ws.shard) };
            ws.delivered += depth;
            ws.inbound_depth += depth;
            board.dirty[0][j].store(false, Ordering::Relaxed);
            board.dirty[1][j].store(false, Ordering::Relaxed);
            if global_mult > 0 {
                // Every shard arms a checkpoint — an idle shard can still
                // receive an in-window arrival and must execute it inside
                // the same resolution discipline.
                ws.mult_max = ws.mult_max.max(global_mult);
                for v in ws.last_in.iter_mut() {
                    v.clear();
                }
                ws.ckpt = if ws.shard.ckpt_begin() {
                    SpecCkpt::Incr
                } else {
                    SpecCkpt::Full(ws.shard.save())
                };
                ws.speculated_events += ws.shard.advance(spec_end, &mut outbox);
                unsafe { opt.stage(j, &mut outbox) };
            }
        }
        barrier.wait();

        if global_mult > 0 {
            // Fixed-point resolution of in-window cross-shard arrivals.
            // Read phase: a shard is dirty when some sender's in-window
            // arrival-time sequence differs from what it last
            // incorporated, or when such a sender itself re-executed last
            // iteration (its payloads may have changed at equal times).
            // Write phase: dirty shards roll back, redeliver clones of
            // ALL current in-window arrivals (journal scope makes the
            // clones retraction-safe), re-speculate, and restage.
            // Arrivals in [safe_end + kΔ, safe_end + (k+1)Δ) were sent
            // before safe_end + kΔ, so histories finalize one Δ per
            // iteration and the loop converges within global_mult
            // iterations; the cap is a backstop, not a correctness bound.
            for iter in 0..=(mult_cap as usize) {
                let cur = iter & 1;
                let prev = cur ^ 1;
                for j in (tid..s_count).step_by(threads) {
                    let ws = unsafe { cells[j].get() };
                    unsafe { opt.collect_arrivals(j, spec_end, &mut ws.pending_in) };
                    let mut dirty = false;
                    for src in 0..s_count {
                        if ws.pending_in[src] != ws.last_in[src]
                            || (!ws.pending_in[src].is_empty()
                                && board.dirty[prev][src].load(Ordering::Relaxed))
                        {
                            dirty = true;
                            break;
                        }
                    }
                    board.dirty[cur][j].store(dirty, Ordering::Relaxed);
                }
                barrier.wait();
                if !board.dirty[cur].iter().any(|d| d.load(Ordering::Relaxed)) {
                    break;
                }
                for j in (tid..s_count).step_by(threads) {
                    if !board.dirty[cur][j].load(Ordering::Relaxed) {
                        continue;
                    }
                    let ws = unsafe { cells[j].get() };
                    ws.rollbacks += 1;
                    ws.ctl.on_rollback();
                    match mem::replace(&mut ws.ckpt, SpecCkpt::None) {
                        SpecCkpt::Incr => {
                            ws.ckpt_bytes += ws.shard.ckpt_rollback();
                            ws.ckpt = SpecCkpt::Incr;
                        }
                        SpecCkpt::Full(c) => {
                            ws.shard.restore(c);
                            ws.ckpt = SpecCkpt::Full(ws.shard.save());
                        }
                        SpecCkpt::None => unreachable!("speculating shard lost its checkpoint"),
                    }
                    unsafe { opt.scan_into_max(j, spec_end, &mut ws.shard) };
                    mem::swap(&mut ws.last_in, &mut ws.pending_in);
                    ws.speculated_events += ws.shard.advance(spec_end, &mut outbox);
                    unsafe {
                        opt.drop_staged(j);
                        opt.stage(j, &mut outbox);
                    }
                }
                barrier.wait();
            }
        }

        // Phase E — converge: commit the checkpoints and drain the opt
        // lanes. In-window arrivals (< spec_end) were already delivered
        // as clones to their (rolled-back) receivers and are dropped
        // here; arrivals ≥ spec_end are beyond every executed history.
        for j in (tid..s_count).step_by(threads) {
            let ws = unsafe { cells[j].get() };
            match mem::replace(&mut ws.ckpt, SpecCkpt::None) {
                SpecCkpt::Incr => ws.ckpt_bytes += ws.shard.ckpt_commit(),
                SpecCkpt::Full(_) | SpecCkpt::None => {}
            }
            let depth = unsafe { opt.drain_into_min(j, spec_end, &mut ws.shard) };
            ws.delivered += depth;
            ws.mailbox_depth_max = ws.mailbox_depth_max.max(ws.inbound_depth + depth);
        }
        close_round(tid, reduce, barrier, cells, rounds);
    }
}

/// Round epilogue shared by both modes: count the round, hold everyone at
/// the close barrier (nobody may start the next advance — and write lanes
/// — until every drain has finished), then run the optional single-thread
/// reduction between two more barriers.
fn close_round<S: Shard>(
    tid: usize,
    reduce: bool,
    barrier: &Barrier,
    cells: &[ShardCell<S>],
    rounds: &AtomicU64,
) {
    if tid == 0 {
        rounds.fetch_add(1, Ordering::Relaxed);
    }
    barrier.wait();
    if reduce {
        if tid == 0 {
            let mut all: Vec<&mut S> = cells.iter().map(|c| unsafe { &mut c.get().shard }).collect();
            S::reduce(&mut all);
        }
        barrier.wait();
    }
}

/// Best-effort pin of the calling thread to a contiguous core stripe
/// (`tid`-th of `threads` equal slices). Raw `sched_setaffinity` syscall
/// — no libc dependency; returns whether the kernel accepted the mask.
/// Memory then follows by first touch: the shard's calendar queue and
/// lanes are allocated and used from the pinned thread.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
pub(crate) fn pin_current_thread(tid: usize, threads: usize) -> bool {
    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if threads == 0 || ncpu > 1024 {
        return false;
    }
    let per = (ncpu / threads).max(1);
    let lo = (tid * per) % ncpu;
    let mut mask = [0u64; 16]; // CPU_SETSIZE / 64
    for c in lo..(lo + per).min(ncpu) {
        mask[c / 64] |= 1u64 << (c % 64);
    }
    let ret: i64;
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret, // SYS_sched_setaffinity
            in("rdi") 0i64,                 // pid 0 = calling thread
            in("rsi") mask.len() * 8,
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
pub(crate) fn pin_current_thread(_tid: usize, _threads: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::heap::EventHeap;

    /// Toy shard: relays a token to the next shard over a 200 ns link,
    /// doing 14 ns of "local work" per hop, optionally with an
    /// independent local ticker chain (dense enough to keep speculated
    /// spans busy). Relay events land on even times and ticks on odd
    /// times, so no two events ever tie — the logs are strictly
    /// time-ordered and strict equality across modes is the honest
    /// invariant. A relay executed inside a speculated span arrives
    /// 214 ns later — inside any span of ≥ 2Δ — so escalated windows are
    /// repeatedly violated.
    #[derive(Clone)]
    struct PingShard {
        id: usize,
        peers: usize,
        heap: EventHeap<u64>,
        hops_left: u64,
        log: Vec<(u64, u64)>,
        shared_max: u64,
        /// Offer the executor incremental (undo-journal) checkpoints
        /// instead of the full-clone fallback.
        incr: bool,
        /// Armed journal sidecar: (hops_left, log.len, shared_max).
        undo: Option<(u64, usize, u64)>,
    }

    const TICK: u64 = u64::MAX; // marker event for the local ticker

    impl Shard for PingShard {
        type Msg = u64;
        type Ckpt = PingShard;

        fn next_at(&self) -> Option<u64> {
            self.heap.next_at()
        }

        fn advance(&mut self, horizon: u64, outbox: &mut Outbox<u64>) -> u64 {
            let mut n = 0;
            while self.heap.next_at().is_some_and(|t| t < horizon) {
                let (now, token) = self.heap.pop().unwrap();
                n += 1;
                if token == TICK {
                    self.log.push((now, TICK));
                    if now < 20_000 {
                        self.heap.push(now + 26, TICK);
                    }
                    continue;
                }
                self.log.push((now, token));
                if self.hops_left > 0 {
                    self.hops_left -= 1;
                    outbox.send((self.id + 1) % self.peers, now + 14 + 200, token + 1);
                }
            }
            n
        }

        fn deliver(&mut self, at: u64, msg: u64) {
            self.heap.push(at, msg);
        }

        fn save(&self) -> PingShard {
            self.clone()
        }

        fn restore(&mut self, ckpt: PingShard) {
            *self = ckpt;
        }

        fn ckpt_begin(&mut self) -> bool {
            if !self.incr {
                return false;
            }
            self.heap.undo_begin();
            self.undo = Some((self.hops_left, self.log.len(), self.shared_max));
            true
        }

        fn ckpt_commit(&mut self) -> u64 {
            self.undo = None;
            self.heap.undo_commit()
        }

        fn ckpt_rollback(&mut self) -> u64 {
            let (hops, log_len, shared_max) = self.undo.expect("incremental ckpt armed");
            self.hops_left = hops;
            self.log.truncate(log_len);
            self.shared_max = shared_max;
            self.heap.undo_rollback()
        }

        fn reduce(shards: &mut [&mut Self]) {
            // Fixed-order merge of a shared high-water mark.
            let max = shards.iter().map(|s| s.log.len() as u64).max().unwrap_or(0);
            for s in shards.iter_mut() {
                s.shared_max = s.shared_max.max(max);
            }
        }
    }

    fn make_shards(n: usize, hops: u64, ticker: bool, seed_token: bool) -> Vec<PingShard> {
        make_shards_ckpt(n, hops, ticker, seed_token, false)
    }

    fn make_shards_ckpt(
        n: usize,
        hops: u64,
        ticker: bool,
        seed_token: bool,
        incr: bool,
    ) -> Vec<PingShard> {
        let mut shards: Vec<PingShard> = (0..n)
            .map(|id| PingShard {
                id,
                peers: n,
                heap: EventHeap::new(),
                hops_left: hops,
                log: Vec::new(),
                shared_max: 0,
                incr,
                undo: None,
            })
            .collect();
        if seed_token {
            shards[0].heap.push(0, 0);
        }
        if ticker {
            for s in shards.iter_mut() {
                s.heap.push(1, TICK);
            }
        }
        shards
    }

    fn ping_run(threads: u32) -> (Vec<Vec<(u64, u64)>>, PdesReport) {
        let (shards, report) = run_conservative(make_shards(2, 20, false, true), 200, threads);
        (shards.into_iter().map(|s| s.log).collect(), report)
    }

    #[test]
    fn ping_pong_is_thread_count_invariant() {
        let (logs1, r1) = ping_run(1);
        let (logs2, r2) = ping_run(2);
        assert_eq!(logs1, logs2, "logs must not depend on thread count");
        assert_eq!(r1.rounds, r2.rounds);
        assert_eq!(r1.messages_routed, r2.messages_routed);
        // 40 hops total (20 per side), alternating shards, 214 ns apart.
        assert_eq!(logs1[0].len() + logs1[1].len(), 41);
        assert_eq!(logs1[0][0], (0, 0));
        assert_eq!(logs1[1][0], (214, 1));
        assert_eq!(r1.messages_routed, 40);
        assert!(r1.horizon_stalls.iter().sum::<u64>() > 0, "the idle side stalls");
        assert_eq!(r1.mailbox_depth_max, vec![1, 1]);
        assert_eq!(r1.mode, PdesMode::Conservative);
        assert_eq!(r1.window_ns, 0);
        assert_eq!(r1.rollbacks, vec![0, 0]);
        assert_eq!(r1.speculated_events, vec![0, 0]);
        assert_eq!(r1.checkpoint_bytes, vec![0, 0]);
        assert_eq!(r1.window_multiple, vec![0, 0]);
    }

    #[test]
    fn staged_bootstrap_delivery_is_sender_ordered() {
        let mut shards = make_shards(2, 0, false, false);
        let mut o0 = Outbox::new(2);
        let mut o1 = Outbox::new(2);
        o1.send(0, 5, 99); // later sender, same time: delivered second
        o0.send(0, 5, 42);
        deliver_staged(&mut shards, vec![o0, o1]);
        let (shards, _report) = run_conservative(shards, 200, 1);
        assert_eq!(shards[0].log, vec![(5, 42), (5, 99)]);
    }

    /// The adversarial shape from docs/pdes.md: the dense ticker keeps
    /// both shards in the sparse regime, so their controllers escalate
    /// to multi-Δ windows — and relays executed inside a ≥ 2Δ span
    /// arrive inside the receiver's speculated past, forcing rollbacks.
    /// The hybrid run must roll back, replay, and still converge on the
    /// conservative (and 1-thread) history exactly.
    #[test]
    fn hybrid_rolls_back_and_reconverges() {
        let (cons, rc) =
            run_sharded(make_shards(2, 40, true, true), 200, 2, &PdesOpts::conservative());
        let cons_logs: Vec<_> = cons.into_iter().map(|s| s.log).collect();
        for threads in [1, 2] {
            let (hyb, rh) = run_sharded(
                make_shards(2, 40, true, true),
                200,
                threads,
                &PdesOpts { mode: PdesMode::Hybrid, ..Default::default() },
            );
            let hyb_logs: Vec<_> = hyb.into_iter().map(|s| s.log).collect();
            assert_eq!(hyb_logs, cons_logs, "hybrid must be bit-identical (threads={threads})");
            assert_eq!(rh.mode, PdesMode::Hybrid);
            assert_eq!(rh.window_ns, 200);
            assert!(
                rh.rollbacks.iter().sum::<u64>() > 0,
                "straggler relays must invalidate escalated windows: {:?}",
                rh.rollbacks
            );
            assert!(rh.speculated_events.iter().sum::<u64>() > 0);
            assert!(
                rh.window_multiple.iter().max().copied().unwrap_or(0) >= 2,
                "the sparse regime must escalate past 1Δ: {:?}",
                rh.window_multiple
            );
            assert!(
                rh.rounds < rc.rounds,
                "the speculated spans must buy rounds ({} vs {})",
                rh.rounds,
                rc.rounds
            );
        }
    }

    /// Same workload on incremental (undo-journal) checkpoints: results
    /// stay bit-identical to the conservative history, rollbacks still
    /// happen, and the journal bytes are reported instead of full-clone
    /// silence.
    #[test]
    fn incremental_checkpoints_match_full_clones() {
        let (cons, _) =
            run_sharded(make_shards(2, 40, true, true), 200, 2, &PdesOpts::conservative());
        let cons_logs: Vec<_> = cons.into_iter().map(|s| s.log).collect();
        let opts = PdesOpts { mode: PdesMode::Hybrid, ..Default::default() };
        let (full, rf) = run_sharded(make_shards_ckpt(2, 40, true, true, false), 200, 2, &opts);
        let (incr, ri) = run_sharded(make_shards_ckpt(2, 40, true, true, true), 200, 2, &opts);
        let full_logs: Vec<_> = full.into_iter().map(|s| s.log).collect();
        let incr_logs: Vec<_> = incr.into_iter().map(|s| s.log).collect();
        assert_eq!(incr_logs, cons_logs, "incremental ckpts must preserve bit-identity");
        assert_eq!(full_logs, cons_logs);
        assert_eq!(ri.rounds, rf.rounds, "ckpt kind must not steer the protocol");
        assert_eq!(ri.rollbacks, rf.rollbacks);
        assert_eq!(ri.speculated_events, rf.speculated_events);
        assert_eq!(rf.checkpoint_bytes, vec![0, 0], "full clones report no journal bytes");
        assert!(
            ri.checkpoint_bytes.iter().sum::<u64>() > 0,
            "journaled spans must report their footprint: {:?}",
            ri.checkpoint_bytes
        );
        assert!(ri.rollbacks.iter().sum::<u64>() > 0);
    }

    /// Hybrid rollback accounting is itself thread-count invariant: the
    /// controller sees only per-shard observations and the global
    /// multiple is a pure function of their states.
    #[test]
    fn hybrid_report_is_thread_count_invariant() {
        let opts = PdesOpts { mode: PdesMode::Hybrid, ..Default::default() };
        let (_, r1) = run_sharded(make_shards(2, 40, true, true), 200, 1, &opts);
        let (_, r2) = run_sharded(make_shards(2, 40, true, true), 200, 2, &opts);
        assert_eq!(r1.rounds, r2.rounds);
        assert_eq!(r1.rollbacks, r2.rollbacks);
        assert_eq!(r1.speculated_events, r2.speculated_events);
        assert_eq!(r1.checkpoint_bytes, r2.checkpoint_bytes);
        assert_eq!(r1.window_multiple, r2.window_multiple);
        assert_eq!(r1.messages_routed, r2.messages_routed);
    }

    /// Capping the multiple at 1 keeps speculation to the risk-free
    /// single-Δ span: no in-window arrival can exist, so rollbacks are
    /// structurally zero — and the history still matches.
    #[test]
    fn single_delta_cap_never_rolls_back() {
        let (cons, _) =
            run_sharded(make_shards(2, 40, true, true), 200, 2, &PdesOpts::conservative());
        let cons_logs: Vec<_> = cons.into_iter().map(|s| s.log).collect();
        let opts =
            PdesOpts { mode: PdesMode::Hybrid, window_mult_max: 1, ..Default::default() };
        let (hyb, rh) = run_sharded(make_shards(2, 40, true, true), 200, 2, &opts);
        let hyb_logs: Vec<_> = hyb.into_iter().map(|s| s.log).collect();
        assert_eq!(hyb_logs, cons_logs);
        assert_eq!(rh.rollbacks, vec![0, 0], "1Δ spans admit no stragglers");
        assert!(rh.speculated_events.iter().sum::<u64>() > 0);
        assert_eq!(rh.window_multiple.iter().max().copied().unwrap_or(0), 1);
    }

    /// Two-tier routing: a 4-shard ring across 2 racks must behave
    /// exactly like the flat mesh, in both modes.
    #[test]
    fn rack_routing_matches_the_flat_mesh() {
        let (mesh, rm) =
            run_sharded(make_shards(4, 60, true, true), 200, 2, &PdesOpts::conservative());
        let mesh_logs: Vec<_> = mesh.into_iter().map(|s| s.log).collect();
        for mode in [PdesMode::Conservative, PdesMode::Hybrid] {
            let opts = PdesOpts { mode, rack_of: vec![0, 0, 1, 1], ..Default::default() };
            for threads in [1, 4] {
                let (racked, rr) = run_sharded(make_shards(4, 60, true, true), 200, threads, &opts);
                let logs: Vec<_> = racked.into_iter().map(|s| s.log).collect();
                assert_eq!(logs, mesh_logs, "{mode:?} threads={threads}");
                assert_eq!(rr.messages_routed, rm.messages_routed);
            }
        }
    }

    /// The reduce hook runs between rounds, single-threaded, and its
    /// fixed-order merge lands identically at every thread count.
    #[test]
    fn reduce_hook_is_deterministic() {
        let run = |threads| {
            let opts = PdesOpts {
                mode: PdesMode::Hybrid,
                reduce: true,
                rack_of: vec![0, 0, 1, 1],
                ..Default::default()
            };
            let (shards, _) = run_sharded(make_shards(4, 30, true, true), 200, threads, &opts);
            shards.into_iter().map(|s| s.shared_max).collect::<Vec<_>>()
        };
        let base = run(1);
        assert!(base.iter().all(|&m| m > 0), "reduce must have run: {base:?}");
        assert_eq!(base, run(2));
        assert_eq!(base, run(4));
    }

    /// Pinning is declared best-effort: whatever the platform says, the
    /// run must complete and stay bit-identical to the unpinned one.
    #[test]
    fn pinned_run_matches_unpinned() {
        let opts = PdesOpts { mode: PdesMode::Hybrid, pin_shards: true, ..Default::default() };
        let (pinned, rp) = run_sharded(make_shards(2, 40, true, true), 200, 2, &opts);
        let (plain, rr) = run_sharded(
            make_shards(2, 40, true, true),
            200,
            2,
            &PdesOpts { mode: PdesMode::Hybrid, ..Default::default() },
        );
        let pinned_logs: Vec<_> = pinned.into_iter().map(|s| s.log).collect();
        let plain_logs: Vec<_> = plain.into_iter().map(|s| s.log).collect();
        assert_eq!(pinned_logs, plain_logs);
        assert_eq!(rp.rounds, rr.rounds);
        assert_eq!(rp.rollbacks, rr.rollbacks);
    }

    /// Controller escalation dynamics: gate-open rounds double the
    /// multiple after the saturation threshold, a rollback demotes to 1,
    /// and a closed gate proposes 0 without losing the learned depth.
    #[test]
    fn window_controller_escalates_and_demotes() {
        let mut ctl = WindowController::default();
        assert_eq!(ctl.proposed_mult(), 0, "unprimed controllers stay conservative");
        // Sparse rounds (load ≤ SPARSE_EVENTS) open the gate immediately.
        ctl.observe_round(0.0, 1, 8);
        assert_eq!(ctl.proposed_mult(), 1);
        for _ in 0..WINDOW_SAT_ROUNDS {
            ctl.observe_round(0.0, 1, 8);
        }
        assert_eq!(ctl.proposed_mult(), 2, "saturation must double the multiple");
        for _ in 0..WINDOW_SAT_ROUNDS {
            ctl.observe_round(0.0, 1, 8);
        }
        assert_eq!(ctl.proposed_mult(), 4);
        ctl.on_rollback();
        assert_eq!(ctl.proposed_mult(), 1, "rollback demotes to 1Δ");
        // Dense, low-slack rounds close the gate entirely.
        let mut busy = WindowController::default();
        for _ in 0..20 {
            busy.observe_round(0.0, 10_000, 8);
        }
        assert_eq!(busy.proposed_mult(), 0);
        // The cap clamps escalation (and 3 is not a power of two).
        let mut capped = WindowController::default();
        for _ in 0..50 {
            capped.observe_round(1.0, 1, 3);
        }
        assert_eq!(capped.proposed_mult(), 3);
    }
}
