//! Parallel DES core (PDES): a **conservative, horizon-synchronized**
//! round executor over statically partitioned shards.
//!
//! Each shard owns a disjoint slice of the simulated machine (a
//! `LevelSpec` subtree in the hierarchical engine, a worker rank range in
//! the flat one) and runs its own calendar queue independently. Shards
//! synchronize only at horizon boundaries:
//!
//! 1. every shard publishes its earliest pending event time;
//! 2. the global minimum (GVT) plus the **lookahead** — the smallest
//!    cross-shard latency class — bounds a window `[GVT, GVT + Δ)`;
//! 3. shards process all local events inside the window in parallel,
//!    capturing cross-shard sends in per-pair SPSC mailboxes;
//! 4. after a barrier, each shard drains its inbound mailboxes in sender
//!    order and the next round begins.
//!
//! Conservatism: a message created at local time `t ≥ GVT` travels a
//! cross-shard link of latency `≥ Δ`, so it arrives at `t + lat ≥ GVT + Δ`
//! — never inside the window that created it. Delivering all mailboxes at
//! round start therefore never delivers into a shard's past.
//!
//! **Determinism is structural, not scheduled.** The shard count is fixed
//! by the partition geometry (never by the thread count), each shard's
//! event order is its own `(time, seq)` calendar order, window boundaries
//! are a pure function of shard states, and mailbox drains run in
//! `(sender shard, FIFO)` order — so the outcome is a function of the
//! partition alone. Threads only decide *which core* runs a shard's
//! window; `--des-threads 1` and `--des-threads 8` walk bit-identical
//! per-shard histories.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Barrier;

/// One shard of a partitioned simulation.
///
/// `advance` must process **every** local event strictly before `horizon`
/// (including events it creates inside the window) and route any event
/// addressed to another shard through the outbox instead of its own queue.
pub trait Shard: Send {
    /// A cross-shard message: the destination shard reinjects it into its
    /// calendar queue at the carried arrival time.
    type Msg: Send;

    /// Earliest pending local event time (`None` when the queue is empty).
    fn next_at(&self) -> Option<u64>;

    /// Process all local events with `time < horizon`.
    fn advance(&mut self, horizon: u64, outbox: &mut Outbox<Self::Msg>);

    /// Inject a cross-shard arrival at absolute time `at`.
    fn deliver(&mut self, at: u64, msg: Self::Msg);
}

/// Per-sender staging area for cross-shard messages: one FIFO lane per
/// destination shard, appended during `advance`, drained by the executor
/// at the barrier.
pub struct Outbox<M> {
    lanes: Vec<Vec<(u64, M)>>,
}

impl<M> Outbox<M> {
    pub fn new(shards: usize) -> Self {
        Outbox { lanes: (0..shards).map(|_| Vec::new()).collect() }
    }

    /// Stage a message for shard `dst`, arriving at absolute time `at`.
    pub fn send(&mut self, dst: usize, at: u64, msg: M) {
        self.lanes[dst].push((at, msg));
    }

    fn is_empty(&self) -> bool {
        self.lanes.iter().all(Vec::is_empty)
    }
}

/// A single-producer / single-consumer mailbox for one (sender, receiver)
/// shard pair. There are no internal locks: the round protocol itself is
/// the synchronization. The sender's thread appends only during the
/// advance phase, the receiver's thread drains only during the delivery
/// phase, and a [`Barrier`] separates the phases (barrier waits establish
/// the happens-before edge), so the two sides never touch the cell
/// concurrently.
struct SpscMailbox<M>(UnsafeCell<Vec<(u64, M)>>);

// Safety: see the type docs — phase discipline guarantees exclusive
// access, the barrier publishes writes.
unsafe impl<M: Send> Sync for SpscMailbox<M> {}

impl<M> SpscMailbox<M> {
    fn new() -> Self {
        SpscMailbox(UnsafeCell::new(Vec::new()))
    }

    /// Safety: caller must hold phase-exclusive access (sender in the
    /// advance phase, receiver in the delivery phase).
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut Vec<(u64, M)> {
        &mut *self.0.get()
    }
}

/// A shard plus its executor-side counters. Only the owning thread ever
/// touches a cell (static shard→thread map), so the `UnsafeCell` wrapper
/// below is exclusive by construction.
struct WorkerShard<S> {
    shard: S,
    /// Rounds where this shard had pending events but none inside the
    /// window — it idled at the barrier while other shards progressed.
    horizon_stalls: u64,
    /// Largest number of messages drained from this shard's inbound
    /// mailboxes in one round.
    mailbox_depth_max: u64,
    /// Total cross-shard messages delivered to this shard.
    delivered: u64,
}

struct ShardCell<S>(UnsafeCell<WorkerShard<S>>);

// Safety: each cell is read/written only by its statically assigned
// thread; barriers order the phases.
unsafe impl<S: Send> Sync for ShardCell<S> {}

impl<S> ShardCell<S> {
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut WorkerShard<S> {
        &mut *self.0.get()
    }
}

/// Executor-level accounting of one PDES run — the source of the
/// per-shard `horizon_stalls` / `mailbox_depth_max` observability fields.
#[derive(Debug, Clone)]
pub struct PdesReport {
    pub shards: usize,
    pub threads: usize,
    pub lookahead_ns: u64,
    /// Synchronization rounds executed.
    pub rounds: u64,
    /// Per-shard horizon-stall counts (see [`WorkerShard::horizon_stalls`]).
    pub horizon_stalls: Vec<u64>,
    /// Per-shard max messages drained in one round.
    pub mailbox_depth_max: Vec<u64>,
    /// Total cross-shard messages routed.
    pub messages_routed: u64,
}

/// Deliver pre-round (bootstrap) outboxes: sender-order FIFO per
/// destination, exactly like the in-round delivery phase.
pub fn deliver_staged<S: Shard>(shards: &mut [S], mut staged: Vec<Outbox<S::Msg>>) {
    for dst in 0..shards.len() {
        for src_outbox in staged.iter_mut() {
            for (at, msg) in src_outbox.lanes[dst].drain(..) {
                shards[dst].deliver(at, msg);
            }
        }
    }
}

/// Run the conservative round loop to completion and hand the shards
/// back together with the executor report.
///
/// `threads` is clamped to `[1, shards]`; the result is independent of it
/// by construction. `lookahead_ns` must be positive whenever more than
/// one shard exists (a zero-latency cross-shard link admits no
/// conservative window — partition callers must collapse to one shard).
pub fn run_conservative<S: Shard>(
    shards: Vec<S>,
    lookahead_ns: u64,
    threads: u32,
) -> (Vec<S>, PdesReport) {
    let s_count = shards.len();
    assert!(s_count > 0, "PDES needs at least one shard");
    assert!(
        s_count == 1 || lookahead_ns > 0,
        "conservative PDES needs a positive lookahead across shards"
    );
    let threads = (threads.max(1) as usize).min(s_count);

    let cells: Vec<ShardCell<S>> = shards
        .into_iter()
        .map(|shard| {
            ShardCell(UnsafeCell::new(WorkerShard {
                shard,
                horizon_stalls: 0,
                mailbox_depth_max: 0,
                delivered: 0,
            }))
        })
        .collect();
    let next_slots: Vec<AtomicU64> = (0..s_count).map(|_| AtomicU64::new(u64::MAX)).collect();
    let mailbox: Vec<Vec<SpscMailbox<S::Msg>>> = (0..s_count)
        .map(|_| (0..s_count).map(|_| SpscMailbox::new()).collect())
        .collect();
    let barrier = Barrier::new(threads);
    let rounds = AtomicU64::new(0);

    std::thread::scope(|scope| {
        for tid in 1..threads {
            let cells = &cells;
            let next_slots = &next_slots;
            let mailbox = &mailbox;
            let barrier = &barrier;
            let rounds = &rounds;
            scope.spawn(move || {
                worker_loop(tid, threads, lookahead_ns, barrier, next_slots, cells, mailbox, rounds)
            });
        }
        worker_loop(0, threads, lookahead_ns, &barrier, &next_slots, &cells, &mailbox, &rounds);
    });

    let mut shards = Vec::with_capacity(s_count);
    let mut horizon_stalls = Vec::with_capacity(s_count);
    let mut mailbox_depth_max = Vec::with_capacity(s_count);
    let mut messages_routed = 0;
    for cell in cells {
        let ws = cell.0.into_inner();
        horizon_stalls.push(ws.horizon_stalls);
        mailbox_depth_max.push(ws.mailbox_depth_max);
        messages_routed += ws.delivered;
        shards.push(ws.shard);
    }
    let report = PdesReport {
        shards: s_count,
        threads,
        lookahead_ns,
        rounds: rounds.load(Ordering::Relaxed),
        horizon_stalls,
        mailbox_depth_max,
        messages_routed,
    };
    (shards, report)
}

#[allow(clippy::too_many_arguments)]
fn worker_loop<S: Shard>(
    tid: usize,
    threads: usize,
    lookahead_ns: u64,
    barrier: &Barrier,
    next_slots: &[AtomicU64],
    cells: &[ShardCell<S>],
    mailbox: &[Vec<SpscMailbox<S::Msg>>],
    rounds: &AtomicU64,
) {
    let s_count = cells.len();
    let mut outbox = Outbox::new(s_count);
    loop {
        // Phase A — publish each owned shard's earliest event time.
        for j in (tid..s_count).step_by(threads) {
            let ws = unsafe { cells[j].get() };
            next_slots[j].store(ws.shard.next_at().unwrap_or(u64::MAX), Ordering::Relaxed);
        }
        barrier.wait();

        // Every thread derives the same GVT and horizon from the slots.
        let gvt = next_slots.iter().map(|a| a.load(Ordering::Relaxed)).min().unwrap_or(u64::MAX);
        if gvt == u64::MAX {
            break;
        }
        let horizon = if s_count == 1 { u64::MAX } else { gvt.saturating_add(lookahead_ns) };

        // Phase B — advance owned shards through the window, staging
        // cross-shard sends into this shard's outbound mailbox row.
        for j in (tid..s_count).step_by(threads) {
            let ws = unsafe { cells[j].get() };
            if ws.shard.next_at().is_some_and(|t| t >= horizon) {
                ws.horizon_stalls += 1;
            }
            ws.shard.advance(horizon, &mut outbox);
            if !outbox.is_empty() {
                for (dst, lane) in outbox.lanes.iter_mut().enumerate() {
                    if !lane.is_empty() {
                        // Sender side of the (j, dst) SPSC pair.
                        unsafe { mailbox[j][dst].get() }.append(lane);
                    }
                }
            }
        }
        barrier.wait();

        // Phase C — drain inbound mailboxes in sender order.
        for j in (tid..s_count).step_by(threads) {
            let ws = unsafe { cells[j].get() };
            let mut depth = 0u64;
            for row in mailbox.iter() {
                // Receiver side of the (src, j) SPSC pair.
                let inbox = unsafe { row[j].get() };
                depth += inbox.len() as u64;
                for (at, msg) in inbox.drain(..) {
                    ws.shard.deliver(at, msg);
                }
            }
            ws.mailbox_depth_max = ws.mailbox_depth_max.max(depth);
            ws.delivered += depth;
        }
        if tid == 0 {
            rounds.fetch_add(1, Ordering::Relaxed);
        }
        // Close the round: nobody may start the next advance (and write
        // mailboxes) until every drain above has finished.
        barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::heap::EventHeap;

    /// Toy shard: relays a token to its peer `hops` times over a
    /// 100 ns link, doing 7 ns of "local work" per hop.
    struct PingShard {
        id: usize,
        heap: EventHeap<u64>,
        hops_left: u64,
        log: Vec<(u64, u64)>,
    }

    impl Shard for PingShard {
        type Msg = u64;

        fn next_at(&self) -> Option<u64> {
            self.heap.next_at()
        }

        fn advance(&mut self, horizon: u64, outbox: &mut Outbox<u64>) {
            while self.heap.next_at().is_some_and(|t| t < horizon) {
                let (now, token) = self.heap.pop().unwrap();
                self.log.push((now, token));
                if self.hops_left > 0 {
                    self.hops_left -= 1;
                    outbox.send(1 - self.id, now + 7 + 100, token + 1);
                }
            }
        }

        fn deliver(&mut self, at: u64, msg: u64) {
            self.heap.push(at, msg);
        }
    }

    fn ping_run(threads: u32) -> (Vec<Vec<(u64, u64)>>, PdesReport) {
        let mut shards: Vec<PingShard> = (0..2)
            .map(|id| PingShard { id, heap: EventHeap::new(), hops_left: 20, log: Vec::new() })
            .collect();
        shards[0].heap.push(0, 0);
        let (shards, report) = run_conservative(shards, 100, threads);
        (shards.into_iter().map(|s| s.log).collect(), report)
    }

    #[test]
    fn ping_pong_is_thread_count_invariant() {
        let (logs1, r1) = ping_run(1);
        let (logs2, r2) = ping_run(2);
        assert_eq!(logs1, logs2, "logs must not depend on thread count");
        assert_eq!(r1.rounds, r2.rounds);
        assert_eq!(r1.messages_routed, r2.messages_routed);
        // 40 hops total (20 per side), alternating shards, 107 ns apart.
        assert_eq!(logs1[0].len() + logs1[1].len(), 41);
        assert_eq!(logs1[0][0], (0, 0));
        assert_eq!(logs1[1][0], (107, 1));
        assert_eq!(r1.messages_routed, 40);
        assert!(r1.horizon_stalls.iter().sum::<u64>() > 0, "the idle side stalls");
        assert_eq!(r1.mailbox_depth_max, vec![1, 1]);
    }

    #[test]
    fn staged_bootstrap_delivery_is_sender_ordered() {
        let mut shards: Vec<PingShard> = (0..2)
            .map(|id| PingShard { id, heap: EventHeap::new(), hops_left: 0, log: Vec::new() })
            .collect();
        let mut o0 = Outbox::new(2);
        let mut o1 = Outbox::new(2);
        o1.send(0, 5, 99); // later sender, same time: delivered second
        o0.send(0, 5, 42);
        deliver_staged(&mut shards, vec![o0, o1]);
        let (shards, _report) = run_conservative(shards, 100, 1);
        assert_eq!(shards[0].log, vec![(5, 42), (5, 99)]);
    }
}
